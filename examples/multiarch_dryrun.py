"""Walkthrough: lower one cell of each architecture family onto the
production mesh and print its roofline terms — the multi-pod dry-run in
example form.

    PYTHONPATH=src python examples/multiarch_dryrun.py

(This spawns the dry-run module in-process; it sets the 512-placeholder-
device XLA flag, so run it in a fresh interpreter, not inside a session
that already initialized jax.)
"""
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
CELLS = [
    ("dpmf", "train_1m"),            # the paper's model
    ("gemma-7b", "decode_32k"),      # dense LM serving
    ("gat-cora", "full_graph_sm"),   # GNN
    ("fm", "retrieval_cand"),        # recsys retrieval
]

env = dict(os.environ)
env["PYTHONPATH"] = os.path.join(REPO, "src")
env.pop("XLA_FLAGS", None)

for arch, shape in CELLS:
    print(f"=== {arch} :: {shape} (16x16 production mesh) ===")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
         "--shape", shape, "--mesh", "single", "--force"],
        env=env, text=True, capture_output=True, timeout=900,
    )
    print(proc.stdout.strip())
    if proc.returncode != 0:
        print(proc.stderr[-2000:])
        sys.exit(1)
print("all example cells lowered + compiled OK")
