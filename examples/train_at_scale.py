"""End-to-end driver: train a ~100M-parameter DP-MF model for a few hundred
steps on synthetic ratings, with checkpointing and fault-tolerant stepping.

    PYTHONPATH=src python examples/train_at_scale.py [--steps 300]

The model is 600k users x 200k items x k=128 => (600k + 200k) * 128 ~= 102M
parameters.  Uses the paper's full pipeline: dense first epoch, one-shot
threshold + rearrangement, dynamically pruned steps after.
"""
import argparse
import time

from repro.core import DPMFTrainer, TrainConfig, work_speedup
from repro.data import synthetic_ratings, train_test_split


def main() -> None:
    parser = argparse.ArgumentParser()
    parser.add_argument("--steps", type=int, default=300)
    parser.add_argument("--batch-size", type=int, default=16384)
    parser.add_argument("--ckpt", default="/tmp/dpmf_100m_ckpt")
    args = parser.parse_args()

    num_ratings = args.steps * args.batch_size // 2  # ~2 epochs of steps
    print(f"generating {num_ratings:,} synthetic ratings (600k x 200k, k*=16)")
    ds = synthetic_ratings(600_000, 200_000, num_ratings, k_true=16, seed=0)
    train_ds, test_ds = train_test_split(ds, 0.1, seed=0)

    config = TrainConfig(
        k=128,
        epochs=4,
        batch_size=args.batch_size,
        pruning_rate=0.3,
        optimizer="adagrad",
        checkpoint_dir=args.ckpt,
        checkpoint_every_epochs=1,
    )
    trainer = DPMFTrainer(config, train_ds, test_ds)
    n_params = (ds.num_users + ds.num_items) * config.k
    print(f"model: {n_params / 1e6:.1f}M parameters")
    if trainer.maybe_restore():
        print(f"resumed at epoch {trainer.epoch}")

    start = time.perf_counter()
    trainer.run()
    wall = time.perf_counter() - start
    steps = sum(
        len(train_ds) // config.batch_size for _ in trainer.history
    )
    print(f"{steps} steps in {wall:.1f}s "
          f"({steps / wall:.1f} steps/s, batch {config.batch_size})")
    print(f"final test MAE: {trainer.history[-1].test_mae:.4f}")
    print(f"work speedup vs dense: {work_speedup(trainer.history):.2f}x")
    print(f"checkpoints: {args.ckpt}")


if __name__ == "__main__":
    main()
