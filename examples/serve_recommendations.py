"""Serving example: train briefly, then serve batched top-k recommendation
requests through the serving engine (streaming pruned top-k — the (B, n)
score matrix is never materialized).

    PYTHONPATH=src python examples/serve_recommendations.py
"""
import time

import numpy as np

from repro.core import DPMFTrainer, TrainConfig
from repro.data import paper_dataset, train_test_split
from repro.serving import MicroBatcher, ServingEngine

ds = paper_dataset("movielens100k", seed=0, scale=0.3)
train_ds, test_ds = train_test_split(ds, 0.2, seed=0)

trainer = DPMFTrainer(
    TrainConfig(k=32, epochs=6, pruning_rate=0.3), train_ds, test_ds
)
trainer.run()
print(f"trained: test MAE {trainer.history[-1].test_mae:.4f}")

# Load once: per-item ranks, masked factors, and tile layout are precomputed
# here, not per request.
engine = ServingEngine(trainer.params, trainer.t_p, trainer.t_q)

for user, recs in zip([3, 14, 15], engine.recommend([3, 14, 15], topk=5)):
    line = ", ".join(f"item {r['item']} ({r['score']:.2f})" for r in recs)
    print(f"user {user}: {line}")

# micro-batched single-user traffic: tickets collapse into one engine batch
batcher = MicroBatcher(engine, topk=5)
tickets = [batcher.submit(u) for u in (3, 14, 15, 3)]
results = batcher.drain()
assert np.array_equal(results[tickets[0]][1], results[tickets[3]][1])
print(f"micro-batched {len(tickets)} tickets in one flush")

# batched-request latency through the streaming scoring path
rng = np.random.default_rng(0)
batch_users = rng.integers(0, ds.num_users, 256)
engine.topk(batch_users, topk=10)  # warm the jit cache
start = time.perf_counter()
engine.topk(batch_users, topk=10)
dt = time.perf_counter() - start
print(f"256 top-10 requests in {dt * 1e3:.1f} ms "
      f"({256 / dt:.0f} req/s on 1 CPU core, no (B, n) score matrix)")
