"""Serving example: train briefly, then serve top-k recommendations through
the serving engine (streaming pruned top-k — the (B, n) score matrix is
never materialized) three ways: a synchronous batch, the synchronous
micro-batcher, and the async request pipeline (continuous batching from
concurrent clients).

    PYTHONPATH=src python examples/serve_recommendations.py
"""
import time
from concurrent.futures import ThreadPoolExecutor

import numpy as np

from repro.core import DPMFTrainer, TrainConfig
from repro.data import paper_dataset, train_test_split
from repro.serving import MicroBatcher, ServingEngine

ds = paper_dataset("movielens100k", seed=0, scale=0.3)
train_ds, test_ds = train_test_split(ds, 0.2, seed=0)

trainer = DPMFTrainer(
    TrainConfig(k=32, epochs=6, pruning_rate=0.3), train_ds, test_ds
)
trainer.run()
print(f"trained: test MAE {trainer.history[-1].test_mae:.4f}")

# Load once: per-item ranks, masked factors, and tile layout are precomputed
# here, not per request.
engine = ServingEngine(trainer.params, trainer.t_p, trainer.t_q)

for user, recs in zip([3, 14, 15], engine.recommend([3, 14, 15], topk=5)):
    line = ", ".join(f"item {r['item']} ({r['score']:.2f})" for r in recs)
    print(f"user {user}: {line}")

# micro-batched single-user traffic: tickets collapse into one engine batch
batcher = MicroBatcher(engine, topk=5)
tickets = [batcher.submit(u) for u in (3, 14, 15, 3)]
results = batcher.drain()
assert np.array_equal(results[tickets[0]][1], results[tickets[3]][1])
print(f"micro-batched {len(tickets)} tickets in one flush")

# batched-request latency through the streaming scoring path
rng = np.random.default_rng(0)
batch_users = rng.integers(0, ds.num_users, 256)
engine.topk(batch_users, topk=10)  # warm the jit cache
start = time.perf_counter()
engine.topk(batch_users, topk=10)
dt = time.perf_counter() - start
print(f"256 top-10 requests in {dt * 1e3:.1f} ms "
      f"({256 / dt:.0f} req/s on 1 CPU core, no (B, n) score matrix)")

# async pipeline: concurrent clients submit single-user requests and block
# on futures; the scheduler thread coalesces them into shared scoring
# launches (continuous batching) with per-request timeouts.  Results are
# byte-identical to the synchronous path.
queue = engine.start(linger_ms=1.0)   # engine.submit() now routes here

def one_client(user):
    scores, items = engine.submit(int(user), topk=10, timeout=30).result(30)
    return items

for b in (1, 2, 4, 8, 16, 32):        # warm the buckets batches can hit
    engine.topk(batch_users[:b], topk=10)
start = time.perf_counter()
with ThreadPoolExecutor(max_workers=32) as pool:
    async_items = list(pool.map(one_client, batch_users))
dt = time.perf_counter() - start
sync_scores, sync_items = engine.topk(batch_users, topk=10)
assert all(np.array_equal(a, s) for a, s in zip(async_items, sync_items))
print(f"async: 256 requests from 32 clients in {dt * 1e3:.1f} ms "
      f"({256 / dt:.0f} req/s; {queue.batches_served} launches, "
      f"results identical to the sync path)")
engine.stop()
