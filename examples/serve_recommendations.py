"""Serving example: train briefly, checkpoint, then serve batched top-k
recommendation requests through the dynamically-pruned scoring path (the
Pallas pruned-matmul kernel, interpret mode on CPU).

    PYTHONPATH=src python examples/serve_recommendations.py
"""
import time

import jax.numpy as jnp
import numpy as np

from repro.core import DPMFTrainer, TrainConfig
from repro.core.mf import predict_all_items
from repro.data import paper_dataset, train_test_split

ds = paper_dataset("movielens100k", seed=0, scale=0.3)
train_ds, test_ds = train_test_split(ds, 0.2, seed=0)

trainer = DPMFTrainer(
    TrainConfig(k=32, epochs=6, pruning_rate=0.3), train_ds, test_ds
)
trainer.run()
print(f"trained: test MAE {trainer.history[-1].test_mae:.4f}")

users = jnp.asarray([3, 14, 15], jnp.int32)
scores = predict_all_items(
    trainer.params, users, trainer.t_p, trainer.t_q, use_kernel=True
)
top = np.asarray(jnp.argsort(-scores, axis=1)[:, :5])
for row, user in enumerate(np.asarray(users)):
    recs = ", ".join(
        f"item {item} ({float(scores[row, item]):.2f})" for item in top[row]
    )
    print(f"user {user}: {recs}")

# batched-request latency (XLA masked path — the production CPU fallback)
rng = np.random.default_rng(0)
batch_users = jnp.asarray(rng.integers(0, ds.num_users, 256), jnp.int32)
start = time.perf_counter()
predict_all_items(
    trainer.params, batch_users, trainer.t_p, trainer.t_q, use_kernel=False
).block_until_ready()
dt = time.perf_counter() - start
print(f"256 catalog-scoring requests in {dt * 1e3:.1f} ms "
      f"({256 / dt:.0f} req/s on 1 CPU core)")
