"""Evaluation on a live stream: prequential MAE + pruned-vs-dense NDCG@10.

    PYTHONPATH=src python examples/eval_on_stream.py [--events 512]

The evaluation loop this repo's fourth pillar exists for, end to end:

1. train a small dynamically-pruned MF model;
2. measure ranking quality of the *pruned* serving engine against the dense
   brute-force oracle (HR@10 / NDCG@10 / recall@10) — the paper's error
   band, expressed in the quantity a recommender actually serves;
3. replay a held-out rating stream **prequentially**: every event batch is
   scored by the current model (test-then-learn) before the online updater
   applies it, printing the windowed MAE as it evolves — no stale test set;
4. hot-swap the refreshed factors into the live engine and re-measure the
   pruned-vs-dense ranking gap after the stream.

CI runs this script as part of the smoke job.
"""
import argparse
import time

from repro.core.trainer import DPMFTrainer, TrainConfig
from repro.data.ratings import paper_dataset, train_test_split
from repro.eval import PrequentialEvaluator, evaluate_engine, evaluate_oracle
from repro.online import OnlineUpdater, ReplaySource, SnapshotPublisher, \
    iter_microbatches
from repro.serving import ServingEngine


def gap_line(tag, pruned, dense):
    """One comparison line: pruned engine vs dense oracle metrics."""
    return (f"{tag}: NDCG@{pruned.topk} {pruned.ndcg:.4f} vs dense "
            f"{dense.ndcg:.4f} (gap {dense.ndcg - pruned.ndcg:+.4f}), "
            f"HR {pruned.hr:.4f} vs {dense.hr:.4f}")


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--events", type=int, default=512)
    parser.add_argument("--scale", type=float, default=0.05)
    parser.add_argument("--topk", type=int, default=10)
    parser.add_argument("--window", type=int, default=256)
    args = parser.parse_args()

    # 1. train a pruned model on a small split of the paper's dataset
    ds = paper_dataset("movielens100k", seed=0, scale=args.scale)
    rest, test_ds = train_test_split(ds, 0.2, seed=0)
    train_ds, stream_ds = train_test_split(rest, 0.3, seed=1)
    config = TrainConfig(k=16, epochs=3, batch_size=1024, pruning_rate=0.3,
                         ranking_topk=args.topk, seed=0)
    trainer = DPMFTrainer(config, train_ds, test_ds)
    trainer.run()
    last = trainer.history[-1]
    print(f"trained: test MAE {last.test_mae:.4f}, NDCG@{args.topk} "
          f"{last.ndcg:.4f}, work_fraction {last.work_fraction:.2f}")

    # 2. ranking quality of the PRUNED engine vs the dense oracle
    engine = ServingEngine(trainer.params, trainer.t_p, trainer.t_q,
                           use_kernel=False)
    pruned = evaluate_engine(engine, test_ds, args.topk)
    dense = evaluate_oracle(trainer.params, test_ds, args.topk)
    print(gap_line("before stream", pruned, dense))

    # 3. prequential replay: score-then-apply every micro-batch
    updater = OnlineUpdater.from_trainer(trainer, batch_size=64)
    publisher = SnapshotPublisher(engine, updater)
    evaluator = PrequentialEvaluator(updater, window=args.window)
    source = ReplaySource(stream_ds, epochs=None, shuffle=True, seed=0)
    start = time.perf_counter()
    for b, batch in enumerate(
        iter_microbatches(source, 64, max_events=args.events)
    ):
        evaluator.consume(batch)
        if (b + 1) % 4 == 0:
            stats = evaluator.stats
            print(f"  {stats.events:5d} events: windowed MAE "
                  f"{stats.window_mae:.4f} (cumulative {stats.mae:.4f})")
            publisher.publish()   # hot-swap the refreshed factors
    publisher.publish()
    rate = evaluator.stats.events / (time.perf_counter() - start)
    stats = evaluator.stats
    print(f"prequential over {stats.events} events: MAE {stats.mae:.4f}, "
          f"RMSE {stats.rmse:.4f} ({rate:.0f} events/s, engine now at "
          f"version {engine.version})")

    # 4. the gap after refresh — same engine, now serving the swapped factors
    pruned = evaluate_engine(engine, test_ds, args.topk)
    dense = evaluate_oracle(engine.params, test_ds, args.topk)
    print(gap_line("after stream ", pruned, dense))


if __name__ == "__main__":
    main()
