"""Implicit-feedback workload end to end: clicks in, pruned top-k out.

    PYTHONPATH=src python examples/implicit_stream.py [--events 384]

The rating-free pipeline the workloads package exists for:

1. train a confidence-weighted implicit MF model (WALS-style: positives at
   confidence ``1 + alpha`` plus sampled negatives) with dynamic pruning —
   the same fused update the explicit objective uses;
2. serve it through the pruned top-k engine and check the ranking gap vs
   the dense brute-force oracle (and exact parity at thresholds 0);
3. replay a **rating-free click stream** prequentially: every click batch
   is first scored by the engine the user would actually have hit ("was
   the clicked item in our top-k?"), then converted to a WALS micro-batch
   and applied — live hit-rate/MRR, segmented into new vs established
   users, with no ratings anywhere in the stream;
4. encode a few SASRec sessions and serve them through the *same* pruned
   engine — session vectors are just user rows the engine has never had
   to special-case.

CI runs this script as part of the workloads smoke job.
"""
import argparse
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.trainer import DPMFTrainer, TrainConfig
from repro.data import synthetic_ratings, train_test_split
from repro.data import clicks
from repro.eval import PrequentialRankingEvaluator, evaluate_engine, \
    evaluate_oracle
from repro.models import recsys
from repro.online import OnlineUpdater, ReplaySource, SnapshotPublisher, \
    iter_microbatches
from repro.serving import ServingEngine
from repro.workloads import implicit_event_batch, serve_sessions, \
    session_engine, strip_ratings


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--events", type=int, default=384)
    parser.add_argument("--topk", type=int, default=10)
    parser.add_argument("--alpha", type=float, default=8.0)
    parser.add_argument("--negatives", type=int, default=2)
    args = parser.parse_args()

    # 1. implicit training: clicks become weighted binary preferences
    ds = synthetic_ratings(num_users=400, num_items=3000, num_ratings=12000,
                           seed=0)
    rest, stream_ds = train_test_split(ds, 0.25, seed=1)
    train_ds, test_ds = train_test_split(rest, 0.2, seed=2)
    config = TrainConfig(k=16, epochs=3, batch_size=2048, lr=0.02,
                         pruning_rate=0.3, ranking_topk=args.topk,
                         objective="implicit", implicit_alpha=args.alpha,
                         implicit_negatives=args.negatives, seed=0)
    trainer = DPMFTrainer(config, train_ds, test_ds)
    trainer.run()
    last = trainer.history[-1]
    print(f"implicit-trained: HR@{args.topk} {last.hr:.4f}, NDCG "
          f"{last.ndcg:.4f}, work_fraction {last.work_fraction:.2f} "
          f"(alpha {args.alpha}, {args.negatives} negatives/positive)")

    # 2. pruned engine vs dense oracle on the binarized holdout
    engine = ServingEngine(trainer.params, trainer.t_p, trainer.t_q,
                           use_kernel=False)
    holdout = trainer.test_ds
    pruned = evaluate_engine(engine, holdout, args.topk)
    dense = evaluate_oracle(trainer.params, holdout, args.topk)
    dense_engine = ServingEngine(trainer.params, 0.0, 0.0, use_kernel=False)
    assert evaluate_engine(dense_engine, holdout, args.topk) == dense
    print(f"serving: pruned NDCG@{args.topk} {pruned.ndcg:.4f} vs dense "
          f"{dense.ndcg:.4f} (gap {dense.ndcg - pruned.ndcg:+.4f}; "
          f"engine == oracle exactly at thresholds 0)")

    # 3. rating-free prequential ranking: score the click, then learn it
    updater = OnlineUpdater.from_trainer(trainer, batch_size=64)
    publisher = SnapshotPublisher(engine, updater)
    evaluator = PrequentialRankingEvaluator(
        updater, topk=args.topk,
        update_fn=functools.partial(
            implicit_event_batch, num_items=3000, alpha=args.alpha,
            negatives=args.negatives, rng=np.random.default_rng(0),
        ),
    )
    source = strip_ratings(
        ReplaySource(stream_ds, epochs=None, shuffle=True, seed=0)
    )
    start = time.perf_counter()
    for b, batch in enumerate(
        iter_microbatches(source, 64, max_events=args.events)
    ):
        assert batch.rating is None   # genuinely rating-free end to end
        evaluator.consume(batch)
        if (b + 1) % 3 == 0:
            stats = evaluator.stats
            print(f"  {stats.events:5d} clicks: windowed HR@{args.topk} "
                  f"{stats.window_hit_rate:.4f} (cumulative "
                  f"{stats.hit_rate:.4f}, MRR {stats.mrr:.4f})")
            publisher.publish()
    publisher.publish()
    stats = evaluator.stats
    rate = stats.events / (time.perf_counter() - start)
    cohorts = stats.cohorts
    print(f"prequential over {stats.events} clicks: HR@{args.topk} "
          f"{stats.hit_rate:.4f}, MRR {stats.mrr:.4f} ({rate:.0f} clicks/s; "
          f"new users {cohorts['new']['hit_rate']:.4f} over "
          f"{cohorts['new']['events']}, established "
          f"{cohorts['established']['hit_rate']:.4f} over "
          f"{cohorts['established']['events']})")

    # 4. sequential coda: SASRec session vectors through the same engine
    cfg = recsys.SASRecConfig(n_items=60, embed_dim=16, n_blocks=2,
                              n_heads=2, seq_len=10)
    sasrec = recsys.init_sasrec_params(jax.random.PRNGKey(1), cfg)
    sessions = jnp.asarray(
        clicks.sasrec_batch(5, seq_len=10, n_items=60, seed=4)["seq"]
    )
    sengine = session_engine(sasrec, sessions, cfg, t_p=0.0, t_q=0.0)
    _, item_ids = serve_sessions(sengine, np.arange(5), topk=5)
    print("sequential: SASRec sessions served by the unchanged pruned "
          "engine; next-item ids per session:")
    for row in np.asarray(item_ids):
        print(f"  {list(map(int, row))}")


if __name__ == "__main__":
    main()
