"""Quickstart: dynamic-pruning MF in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py

Trains FunkSVD on a MovieLens-100K-shaped synthetic dataset twice — dense
baseline vs dynamically pruned — and prints the paper's headline metrics
(MAE, percentage-MAE, work-proportional speedup).
"""
from repro.core import DPMFTrainer, TrainConfig, percentage_mae, work_speedup
from repro.data import paper_dataset, train_test_split

ds = paper_dataset("movielens100k", seed=0, scale=0.5)
train_ds, test_ds = train_test_split(ds, test_fraction=0.2, seed=0)

dense = DPMFTrainer(
    TrainConfig(k=30, epochs=15, pruning_rate=0.0, lr=0.1, init_method="libmf"),
    train_ds, test_ds,
)
dense.run()

pruned = DPMFTrainer(
    TrainConfig(k=30, epochs=15, pruning_rate=0.3, lr=0.1, init_method="libmf"),
    train_ds, test_ds,
)
pruned.run()

mae_org = dense.history[-1].test_mae
mae_acc = pruned.history[-1].test_mae
print(f"dense  MAE: {mae_org:.4f}")
print(f"pruned MAE: {mae_acc:.4f}  (P_MAE = {percentage_mae(mae_acc, mae_org):+.2f}%)")
print(f"thresholds: T_p={pruned.history[-1].t_p:.4f} T_q={pruned.history[-1].t_q:.4f}")
print(f"work-proportional speedup: {work_speedup(pruned.history):.2f}x "
      f"(paper reports 1.2-1.65x wall-clock)")
