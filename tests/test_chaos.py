"""Fault tolerance: chaos harness, failover routing, supervision, integrity.

Covers the ISSUE-9 acceptance surface:

* the deterministic fault harness — exact per-(site, target) event-count
  firing, fire-once semantics, seed-derived schedules, zero-op when
  disarmed;
* payload CRC integrity — every bus message carries a checksum, a
  corrupted delivery is NAKed (stale ack) and the forced ``kind=full``
  heal converges the sink bitwise;
* replica death — ``LocalReplica.kill`` fails every queued future with
  ``ReplicaDiedError`` and later submits raise fast;
* failover routing — a replica dying at submit time or mid-flight never
  strands or errors a caller future while any replica survives; pins on
  dead replicas re-pin; all-dead surfaces ``NoHealthyReplicaError``;
* the supervisor state machine — hard evidence (``alive`` false) declares
  DEAD immediately, heartbeat misses walk HEALTHY → SUSPECT → DEAD,
  respawn rebuilds from a healthy peer and readmits only at the fleet
  version, the respawn budget brakes crash loops;
* a hypothesis property: a fleet fed an adversarial seeded wire schedule
  (drop/duplicate/reorder/corrupt/kill) converges bitwise to a fault-free
  reference once healed;
* trainer fault wiring — ``max_step_retries`` recovers an injected slab
  failure bitwise, ``StragglerDetector`` flags timing outliers.
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import Future

import numpy as np
import pytest

import jax

from repro.core import mf
from repro.online import EventBatch, OnlineUpdater
from repro.serving import ServingEngine
from repro.serving.fleet import (
    EngineDeltaSink,
    FleetSupervisor,
    LocalReplica,
    NoHealthyReplicaError,
    ReplicaDiedError,
    ReplicaState,
    Router,
    ServingFleet,
    make_message,
    payload_checksum,
    state_message,
    verify_message,
)
from repro.testing import faults
from repro.testing.faults import FaultAction, FaultError, FaultPlan

from tests.hypothesis_compat import given, settings, st


def _params(m=40, n=300, k=8, variant="bias", seed=0):
    return mf.init_params(
        jax.random.PRNGKey(seed), m, n, k, variant=variant,
        **({"global_mean": 3.5} if variant != "funk" else {}),
    )


def _batch(rng, m, n, size=24):
    return EventBatch(
        user=rng.integers(0, m, size).astype(np.int32),
        item=rng.integers(0, n, size).astype(np.int32),
        rating=rng.uniform(1, 5, size).astype(np.float32),
    )


def _messages(n_publishes=3, m=40, n=300, seed=0, full_at=()):
    rng = np.random.default_rng(seed)
    params = _params(m, n)
    upd = OnlineUpdater(params, None, 0.0, 0.0, batch_size=32, seed=seed)
    msgs = []
    for v in range(1, n_publishes + 1):
        upd.apply(_batch(rng, m, n))
        msgs.append(make_message(
            upd.snapshot(), v, v - 1, full=(v in full_at), compress=True,
        ))
    return msgs, upd


def _assert_engines_bitwise(engine, ref_engine):
    a = jax.tree_util.tree_leaves(engine.params)
    b = jax.tree_util.tree_leaves(ref_engine.params)
    assert len(a) == len(b)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# fault harness
# ---------------------------------------------------------------------------


def test_fault_plan_fires_at_exact_count_once():
    plan = FaultPlan([FaultAction(site="s", op="kill", at=2, target="x")])
    assert plan.fire("s", "x") == []
    assert plan.fire("s", "y") == []          # other targets don't advance x
    assert plan.fire("s", "x") == []
    hits = plan.fire("s", "x")                # x's event #2
    assert [h.op for h in hits] == ["kill"]
    assert plan.fire("s", "x") == []          # fire-once
    assert plan.pending == 0
    assert plan.fired == [("s", "x", "kill", 2)]


def test_fault_plan_empty_target_matches_all():
    plan = FaultPlan([FaultAction(site="s", op="error", at=0)])
    assert [h.op for h in plan.fire("s", "anything")] == ["error"]


def test_fault_plan_from_seed_deterministic():
    sites = [("bus.deliver", ["r0", "r1"], ["drop", "dup", "corrupt"]),
             ("replica.submit", ["r0"], ["kill"])]
    a = FaultPlan.from_seed(7, sites=sites, n_actions=6, horizon=16)
    b = FaultPlan.from_seed(7, sites=sites, n_actions=6, horizon=16)
    assert a._actions == b._actions
    c = FaultPlan.from_seed(8, sites=sites, n_actions=6, horizon=16)
    assert a._actions != c._actions
    for act in a._actions:
        assert 0 <= act.at < 16


def test_harness_disarmed_is_noop():
    assert faults._PLAN is None
    assert faults.fire("s", "x") == ()
    plan = FaultPlan([FaultAction(site="s", op="kill", at=0)])
    with faults.installed(plan):
        assert faults._PLAN is plan
        assert [h.op for h in faults.fire("s")] == ["kill"]
    assert faults._PLAN is None               # always disarmed on exit


# ---------------------------------------------------------------------------
# payload CRC + corrupt-delta NAK
# ---------------------------------------------------------------------------


def test_messages_carry_valid_checksums():
    for compress in (True, False):
        msgs, upd = _messages(2)
        full = state_message(upd.params, upd.t_p, upd.t_q, version=3,
                             compress=compress)
        for msg in msgs + [full]:
            assert msg.payload_crc >= 0
            assert verify_message(msg)


def test_corrupt_message_fails_verification():
    msgs, _ = _messages(1)
    bad = faults.corrupt_message(msgs[0])
    assert verify_message(msgs[0])            # original untouched
    assert not verify_message(bad)
    assert bad.payload_crc == msgs[0].payload_crc


def test_legacy_message_without_crc_passes():
    import dataclasses as dc

    msgs, _ = _messages(1)
    legacy = dc.replace(msgs[0], payload_crc=-1)
    assert verify_message(legacy)


def test_payload_checksum_covers_every_leaf():
    msgs, _ = _messages(1, full_at=(1,))
    tree = dict(msgs[0].tree)
    base = payload_checksum(tree)
    key = sorted(tree)[0]
    tree.pop(key)
    assert payload_checksum(tree) != base


def test_sink_naks_corrupt_delta_then_heals_bitwise():
    msgs, upd = _messages(3)
    engine = ServingEngine(_params(), 0.0, 0.0)
    sink = EngineDeltaSink(engine, replica_id="r0")
    assert sink.apply_update(msgs[0]) == 1
    # corrupted v2: NAK — the ack stays at 1, nothing was folded
    assert sink.apply_update(faults.corrupt_message(msgs[1])) == 1
    assert sink.corrupt_dropped == 1
    # v3 arrives with a gap (v2 lost): still stale
    assert sink.apply_update(msgs[2]) < 3
    # the publisher heals laggards with kind=full — always applies
    heal = state_message(upd.params, upd.t_p, upd.t_q, version=3)
    assert sink.apply_update(heal) == 3
    _assert_engines_bitwise(engine, ServingEngine(upd.params, 0.0, 0.0))
    engine.stop()


# ---------------------------------------------------------------------------
# replica death
# ---------------------------------------------------------------------------


def test_local_replica_kill_fails_pending_and_raises_fast():
    rep = LocalReplica("r0", _params(), 0.0, 0.0,
                       queue_kwargs={"linger_ms": 200.0, "max_batch": 64})
    futs = [rep.submit(u, 5, timeout=30.0) for u in range(4)]
    rep.kill()
    for fut in futs:
        with pytest.raises(ReplicaDiedError):
            fut.result(timeout=10.0)
    assert not rep.alive and not rep.ping()
    with pytest.raises(ReplicaDiedError):      # submit-after-death: fast
        rep.submit(1, 5)
    with pytest.raises(ReplicaDiedError):
        rep.apply_update(_messages(1)[0][0])


def test_kill_seam_fires_inside_submit():
    rep = LocalReplica("r0", _params(), 0.0, 0.0,
                       queue_kwargs={"linger_ms": 0.5})
    plan = FaultPlan([FaultAction(site="replica.submit", op="kill", at=1,
                                  target="r0")])
    with faults.installed(plan):
        rep.submit(0, 5, timeout=10.0).result(10.0)
        with pytest.raises(ReplicaDiedError):
            rep.submit(1, 5)                   # the killing submit raises
    assert not rep.alive and plan.pending == 0


# ---------------------------------------------------------------------------
# failover routing
# ---------------------------------------------------------------------------


def test_router_failover_submit_time_no_lost_requests():
    params = _params()
    reps = [LocalReplica(f"r{i}", params, 0.0, 0.0,
                         queue_kwargs={"linger_ms": 0.5}) for i in range(2)]
    router = Router(reps)
    plan = FaultPlan([FaultAction(site="replica.submit", op="kill", at=3,
                                  target="r0")])
    with faults.installed(plan):
        futs = [router.submit(u % 40, 5, timeout=30.0) for u in range(64)]
        for fut in futs:
            scores, items = fut.result(timeout=30.0)
            assert len(np.asarray(items)) == 5
    assert plan.pending == 0
    assert router.failovers >= 1
    assert not router.is_healthy(0) and router.is_healthy(1)
    for rep in reps:
        rep.close()


def test_router_failover_mid_flight_future():
    """A replica dying AFTER accepting the request must not strand the
    caller's future: the done-callback relay resubmits elsewhere."""

    class _Pending:
        replica_id = "p"
        version = 0

        def __init__(self):
            self.inner = Future()

        def submit(self, *a, **k):
            return self.inner

        def depth(self):
            return 0

    class _Healthy:
        replica_id = "h"
        version = 0

        def submit(self, user_id, topk=10, **k):
            fut = Future()
            fut.set_result((np.zeros(topk), np.arange(topk)))
            return fut

        def depth(self):
            return 1  # lose the least-depth tiebreak to _Pending

    pending = _Pending()
    router = Router([pending, _Healthy()], policy="least")
    outer = router.submit(7, topk=5)
    assert not outer.done()                    # parked on the dying replica
    pending.inner.set_exception(ReplicaDiedError("mid-flight death"))
    scores, items = outer.result(timeout=10.0)
    assert len(np.asarray(items)) == 5
    assert router.failovers == 1 and not router.is_healthy(0)


def test_router_repins_affinity_of_dead_replica():
    params = _params()
    reps = [LocalReplica(f"r{i}", params, 0.0, 0.0,
                         queue_kwargs={"linger_ms": 0.5}) for i in range(2)]
    router = Router(reps)
    user = 7
    pinned = router.pick(user)
    assert router.pick(user) == pinned
    router.mark_unhealthy(pinned)
    repinned = router.pick(user)
    assert repinned != pinned
    assert router.affinity_repins == 1
    assert router.pick(user) == repinned       # the new pin sticks
    for rep in reps:
        rep.close()


def test_router_all_dead_fails_future_with_no_healthy():
    rep = LocalReplica("r0", _params(), 0.0, 0.0,
                       queue_kwargs={"linger_ms": 0.5})
    router = Router([rep])
    router.mark_unhealthy(0)
    with pytest.raises(NoHealthyReplicaError):
        router.submit(1, 5).result(timeout=10.0)
    rep.close()


def test_router_skips_unhealthy_on_update_thresholds_stats():
    msgs, _ = _messages(1)
    params = _params()
    reps = [LocalReplica(f"r{i}", params, 0.0, 0.0,
                         queue_kwargs={"linger_ms": 0.5}) for i in range(2)]
    router = Router(reps)
    router.mark_unhealthy(0)
    assert router.apply_update(msgs[0]) == {"r1": 1}
    assert list(router.apply_thresholds(0.01, 0.02)) == ["r1"]
    stats = router.stats()
    by_id = {r["replica_id"]: r for r in stats["replicas"]}
    assert by_id["r0"] == {"replica_id": "r0", "healthy": False}
    assert by_id["r1"]["healthy"] and by_id["r1"]["version"] == 1
    assert router.version == 1                 # dead replica doesn't drag it
    for rep in reps:
        rep.close()


def test_router_marks_dead_on_rollout_and_publisher_heals():
    """A replica dying mid-rollout is skipped (marked unhealthy), and its
    stale ack forces the publisher's next publish out kind=full."""
    from repro.online import SnapshotPublisher

    params = _params()
    rng = np.random.default_rng(3)
    upd = OnlineUpdater(params, None, 0.0, 0.0, batch_size=32, seed=3)
    reps = [LocalReplica(f"r{i}", params, 0.0, 0.0,
                         queue_kwargs={"linger_ms": 0.5}) for i in range(2)]
    router = Router(reps)
    pub = SnapshotPublisher(None, upd, compress=True)
    pub.subscribe(router)
    upd.apply(_batch(rng, 40, 300))
    pub.publish()
    reps[0].kill()
    upd.apply(_batch(rng, 40, 300))
    r = pub.publish()                          # r0 dies mid-rollout: skipped
    assert not router.is_healthy(0)
    assert pub.lag() >= 1
    # supervisor-equivalent repair: fresh replica, readmit, next publish full
    fresh = LocalReplica("r0", params, 0.0, 0.0,
                         queue_kwargs={"linger_ms": 0.5})
    router.replace_replica(0, fresh)
    upd.apply(_batch(rng, 40, 300))
    healed = pub.publish()
    assert healed.kind == "full"
    assert all(rep.version == pub.version for rep in router.replicas)
    _assert_engines_bitwise(fresh.engine, ServingEngine(upd.params, 0.0, 0.0))
    for rep in router.replicas:
        rep.close()


# ---------------------------------------------------------------------------
# supervisor
# ---------------------------------------------------------------------------


def test_supervisor_detects_kill_respawns_and_readmits():
    msgs, upd = _messages(2)
    fleet = ServingFleet(_params(), 0.0, 0.0, replicas=2, backend="local",
                         queue_kwargs={"linger_ms": 0.5})
    fleet.apply_update(msgs[0])
    fleet.apply_update(msgs[1])
    sup = FleetSupervisor(fleet.router, dead_after=1)
    old = fleet.replicas[0]
    old.kill()
    sup.poll_once()                            # hard evidence: immediate
    assert sup.states[0] is ReplicaState.HEALTHY  # ... and fully recovered
    replacement = fleet.replicas[0]
    assert replacement is not old
    assert replacement.version == 2            # converged before readmission
    assert fleet.router.is_healthy(0)
    rep = sup.report()
    assert rep["deaths"] == 1 and rep["recovered"] == 1
    assert rep["incidents"][0]["mttr_s"] is not None
    # the readmitted replica serves and replicates again
    scores, items = fleet.submit(3, 5, timeout=10.0).result(10.0)
    assert len(np.asarray(items)) == 5
    heal = state_message(upd.params, upd.t_p, upd.t_q, version=3)
    fleet.apply_update(heal)
    assert all(r.version == 3 for r in fleet.replicas)
    fleet.close()


def test_supervisor_suspect_ladder_needs_consecutive_misses():
    class _Flaky:
        replica_id = "f"
        version = 0
        alive = True

        def __init__(self):
            self.pings = []

        def ping(self, timeout=5.0):
            ok = self.pings.pop(0) if self.pings else True
            return ok

        def depth(self):
            return 0

    flaky = _Flaky()
    router = Router([flaky, _Flaky()])
    sup = FleetSupervisor(router, dead_after=2, respawn=False)
    flaky.pings = [False, True, False, False]
    sup.poll_once()
    assert sup.states[0] is ReplicaState.SUSPECT   # one miss: suspicion only
    assert router.is_healthy(0)                    # still takes traffic
    sup.poll_once()
    assert sup.states[0] is ReplicaState.HEALTHY   # recovered ping resets
    sup.poll_once()
    sup.poll_once()                                # two consecutive misses
    assert sup.states[0] is ReplicaState.DEAD
    assert not router.is_healthy(0)
    assert sup.report()["deaths"] == 1


def test_supervisor_respawn_budget_brakes_crash_loop():
    rep0 = LocalReplica("r0", _params(), 0.0, 0.0,
                        queue_kwargs={"linger_ms": 0.5})
    rep1 = LocalReplica("r1", _params(), 0.0, 0.0,
                        queue_kwargs={"linger_ms": 0.5})
    router = Router([rep0, rep1])
    sup = FleetSupervisor(router, dead_after=1, max_respawns=2)
    for _ in range(4):                         # keeps dying after respawn
        router.replicas[0].kill()
        sup.poll_once()
    assert sup.report()["respawns"] == 2       # budget, not 4
    assert sup.states[0] is ReplicaState.DEAD
    assert not router.is_healthy(0)
    router.close()


def test_supervisor_no_respawn_mode_only_fences():
    rep0 = LocalReplica("r0", _params(), 0.0, 0.0,
                        queue_kwargs={"linger_ms": 0.5})
    rep1 = LocalReplica("r1", _params(), 0.0, 0.0,
                        queue_kwargs={"linger_ms": 0.5})
    router = Router([rep0, rep1])
    sup = FleetSupervisor(router, dead_after=1, respawn=False)
    rep0.kill()
    sup.poll_once()
    assert sup.states[0] is ReplicaState.DEAD
    assert not router.is_healthy(0)
    assert router.replicas[0] is rep0          # no replacement spawned
    scores, items = router.submit(1, 5, timeout=10.0).result(10.0)
    assert len(np.asarray(items)) == 5         # survivor carries the load
    router.close()


def test_supervisor_background_thread_recovers_kill():
    fleet = ServingFleet(_params(), 0.0, 0.0, replicas=2, backend="local",
                         queue_kwargs={"linger_ms": 0.5})
    sup = fleet.supervise(probe_interval_s=0.01, dead_after=1)
    fleet.replicas[1].kill()
    deadline = time.monotonic() + 30.0
    while time.monotonic() < deadline:
        r = sup.report()
        if r["deaths"] and r["recovered"] == r["deaths"]:
            break
        time.sleep(0.01)
    sup.stop()
    r = sup.report()
    assert r["deaths"] >= 1 and r["recovered"] == r["deaths"]
    assert r["mttr_max_s"] is not None
    assert fleet.router.is_healthy(1)
    fleet.close()


def test_supervisor_uses_state_provider_for_heal():
    msgs, upd = _messages(2)
    fleet = ServingFleet(_params(), 0.0, 0.0, replicas=2, backend="local",
                         queue_kwargs={"linger_ms": 0.5})
    fleet.apply_update(msgs[0])
    fleet.apply_update(msgs[1])
    calls = []

    def provider():
        calls.append(1)
        return state_message(upd.params, upd.t_p, upd.t_q, version=2)

    sup = FleetSupervisor(fleet.router, dead_after=1, state_provider=provider)
    fleet.replicas[0].kill()
    sup.poll_once()
    assert calls                               # healed through the provider
    assert fleet.replicas[0].version == 2
    _assert_engines_bitwise(fleet.replicas[0].engine,
                            ServingEngine(upd.params, 0.0, 0.0))
    fleet.close()


# ---------------------------------------------------------------------------
# property: adversarial wire schedules converge after heal
# ---------------------------------------------------------------------------


@settings(max_examples=12, deadline=None)
@given(st.integers(min_value=0, max_value=10_000))
def test_fleet_bitwise_convergent_under_adversarial_schedule(seed):
    """Drop/duplicate/reorder/corrupt/kill the wire per a seeded schedule;
    after the kind=full heal every surviving sink must be bitwise equal to
    a fault-free reference engine fed the clean stream."""
    rng = np.random.default_rng(seed)
    msgs, upd = _messages(4, m=24, n=120)
    heal = state_message(upd.params, upd.t_p, upd.t_q, version=5)

    ref = ServingEngine(_params(24, 120), 0.0, 0.0)
    ref_sink = EngineDeltaSink(ref, replica_id="ref")
    for msg in msgs:
        ref_sink.apply_update(msg)
    ref_sink.apply_update(heal)

    for r in range(2):
        engine = ServingEngine(_params(24, 120), 0.0, 0.0)
        sink = EngineDeltaSink(engine, replica_id=f"r{r}")
        deliveries = []
        killed_at = None
        for i, msg in enumerate(msgs):
            op = rng.choice(["ok", "drop", "dup", "corrupt", "kill"],
                            p=[0.4, 0.15, 0.15, 0.15, 0.15])
            if op == "drop":
                continue
            if op == "kill" and killed_at is None:
                killed_at = i               # dies here; misses the rest
                break
            delivery = (faults.corrupt_message(msg) if op == "corrupt"
                        else msg)
            deliveries.append(delivery)
            if op == "dup":
                deliveries.append(delivery)
        if len(deliveries) > 1 and rng.random() < 0.5:
            rng.shuffle(deliveries)         # reorder
        for delivery in deliveries:
            ack = sink.apply_update(delivery)
            assert ack <= 4                 # never acks past the stream
        # a killed sink "respawns" at version 0 — same heal path
        if killed_at is not None:
            engine.stop()
            engine = ServingEngine(_params(24, 120), 0.0, 0.0)
            sink = EngineDeltaSink(engine, replica_id=f"r{r}")
        assert sink.apply_update(heal) == 5  # kind=full always lands
        assert sink.version == 5
        _assert_engines_bitwise(engine, ref)
        engine.stop()
    ref.stop()


# ---------------------------------------------------------------------------
# trainer fault wiring
# ---------------------------------------------------------------------------


def _store_cfg(store_dir, **kw):
    from repro.core.trainer import TrainConfig

    base = dict(k=8, epochs=1, batch_size=64, lr=0.05, lam=0.02,
                pruning_rate=0.5, seed=0, store_dir=store_dir, slab_steps=4,
                prefetch_slabs=2)
    base.update(kw)
    return TrainConfig(**base)


@pytest.fixture(scope="module")
def ratings_store(tmp_path_factory):
    from repro.data import synthetic_ratings
    from repro.store import build_store

    store_dir = str(tmp_path_factory.mktemp("chaos_store") / "store")
    build_store(synthetic_ratings(300, 100, 4096, seed=0), store_dir)
    return store_dir


def test_trainer_retries_injected_slab_failure_bitwise(ratings_store):
    from repro.core.trainer import DPMFTrainer

    clean = DPMFTrainer(_store_cfg(ratings_store))
    clean.run_epoch()
    assert clean.history[-1].step_retries == 0

    faulted = DPMFTrainer(_store_cfg(ratings_store, max_step_retries=2))
    plan = FaultPlan([FaultAction(site="trainer.slab", op="error", at=1)])
    with faults.installed(plan):
        faulted.run_epoch()
    assert plan.pending == 0
    record = faulted.history[-1]
    assert record.step_retries >= 1
    # the retry is donation-safe: the faulted run ends bitwise identical
    np.testing.assert_array_equal(np.asarray(faulted.params.p),
                                  np.asarray(clean.params.p))
    np.testing.assert_array_equal(np.asarray(faulted.params.q),
                                  np.asarray(clean.params.q))


def test_trainer_retry_exhaustion_raises_step_failure(ratings_store):
    from repro.core.trainer import DPMFTrainer
    from repro.distributed import StepFailure

    trainer = DPMFTrainer(_store_cfg(ratings_store, max_step_retries=1))
    plan = FaultPlan([FaultAction(site="trainer.slab", op="error", at=0),
                      FaultAction(site="trainer.slab", op="error", at=1)])
    with faults.installed(plan):
        with pytest.raises(StepFailure):
            trainer.run_epoch()


def test_trainer_failure_injector_hook(ratings_store):
    from repro.core.trainer import DPMFTrainer
    from repro.distributed import FailureInjector

    trainer = DPMFTrainer(_store_cfg(ratings_store, max_step_retries=1))
    trainer.failure_injector = FailureInjector((0,))
    trainer.run_epoch()
    assert trainer.failure_injector.failures == 1
    assert trainer.history[-1].step_retries == 1


def test_straggler_detector_flags_outlier():
    from repro.distributed import StragglerDetector

    det = StragglerDetector(window=20, z_threshold=4.0, min_samples=10)
    assert not any(det.record(0.1 + 1e-4 * i) for i in range(15))
    assert det.record(10.0)                    # 100x the window mean
    assert det.flagged == 1
    assert not det.record(0.1)                 # back to normal


def test_trainer_epoch_record_carries_fault_fields(ratings_store):
    from repro.core.trainer import DPMFTrainer

    trainer = DPMFTrainer(_store_cfg(ratings_store))
    trainer.run_epoch()
    record = trainer.history[-1]
    assert record.step_retries == 0
    assert record.straggler_slabs >= 0


# ---------------------------------------------------------------------------
# process replicas (slow: spawn + re-import)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_process_replica_death_fails_pending_and_raises_fast():
    """Satellite-1 regression: SIGKILLing the child must fail every pending
    future with ReplicaDiedError (not hang to timeout) and make later
    submits raise immediately."""
    from repro.serving.fleet import ProcessReplica, state_message as sm

    boot = sm(_params(), 0.0, 0.0, version=0)
    rep = ProcessReplica("victim", init_msg=boot,
                         queue_kwargs={"linger_ms": 200.0, "max_batch": 64})
    try:
        futs = [rep.submit(u, 5, timeout=60.0) for u in range(8)]
        rep.kill()
        t0 = time.monotonic()
        for fut in futs:
            with pytest.raises(ReplicaDiedError):
                fut.result(timeout=30.0)
        assert time.monotonic() - t0 < 20.0    # failed fast, not timed out
        deadline = time.monotonic() + 10.0
        while rep.alive and time.monotonic() < deadline:
            time.sleep(0.05)
        assert not rep.alive
        assert rep.ping(timeout=2.0) is False
        with pytest.raises(ReplicaDiedError):
            rep.submit(1, 5)
        with pytest.raises(ReplicaDiedError):
            rep.apply_update(_messages(1)[0][0])
    finally:
        rep.close(timeout=10.0)
