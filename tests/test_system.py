"""End-to-end behaviour tests for the paper's system (DP-MF trainer)."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import DPMFTrainer, TrainConfig, percentage_mae, work_speedup
from repro.data import paper_dataset, synthetic_ratings, train_test_split


@pytest.fixture(scope="module")
def movielens_small():
    ds = synthetic_ratings(400, 600, 20000, seed=0)
    return train_test_split(ds, 0.2, seed=0)


def _run(train_ds, test_ds, **overrides):
    defaults = dict(k=24, epochs=5, batch_size=2048, pruning_rate=0.0,
                    optimizer="adagrad", seed=0)
    defaults.update(overrides)
    trainer = DPMFTrainer(TrainConfig(**defaults), train_ds, test_ds)
    trainer.run()
    return trainer


def test_dense_training_learns(movielens_small):
    train_ds, test_ds = movielens_small
    trainer = _run(train_ds, test_ds)
    maes = [r.test_mae for r in trainer.history]
    assert maes[-1] < maes[0], maes
    assert all(np.isfinite(m) for m in maes)
    # rate 0 => thresholds stay 0 and no work is ever skipped
    assert trainer.history[-1].t_p == 0.0
    assert trainer.mean_work_fraction() == 1.0


def test_pruned_training_full_pipeline(movielens_small):
    """The paper's claims, end to end: pruning reduces executed work
    (speedup > 1), costs bounded extra error, thresholds match Eq. 7/8."""
    train_ds, test_ds = movielens_small
    dense = _run(train_ds, test_ds, epochs=8)
    pruned = _run(train_ds, test_ds, epochs=8, pruning_rate=0.3)

    # work really skipped from epoch 2 on
    assert pruned.mean_work_fraction() < 0.95
    assert work_speedup(pruned.history) > 1.05
    # thresholds were calibrated once, after epoch 1
    assert pruned.history[0].t_p == 0.0
    assert pruned.history[1].t_p > 0.0
    assert all(
        r.t_p == pruned.history[1].t_p for r in pruned.history[1:]
    ), "threshold must be determined once (paper §4.2)"
    # rearrangement happened
    assert pruned.perm is not None
    assert sorted(np.asarray(pruned.perm).tolist()) == list(range(24))

    # Bounded error increase.  The paper's <=20% P_MAE regime needs the LibMF
    # protocol (non-negative init, convergence-level epochs) — covered by
    # benchmarks/bench_paper_figures.fig11; this quick test uses zero-mean
    # init at 8 epochs where truncation costs more.
    pmae = percentage_mae(pruned.history[-1].test_mae, dense.history[-1].test_mae)
    assert pmae < 100.0, f"error blow-up: {pmae}%"


def test_pruned_equals_dense_at_rate_zero(movielens_small):
    """rate=0 shares the code path and must give bit-identical history."""
    train_ds, test_ds = movielens_small
    a = _run(train_ds, test_ds, epochs=3)
    b = _run(train_ds, test_ds, epochs=3, pruning_rate=0.0)
    np.testing.assert_allclose(
        np.asarray(a.params.p), np.asarray(b.params.p), rtol=0, atol=0
    )


@pytest.mark.parametrize("optimizer", ["sgd", "adagrad", "adadelta", "adam"])
def test_optimizer_agnostic(movielens_small, optimizer):
    """Paper §5.3: the method applies across optimizers."""
    train_ds, test_ds = movielens_small
    # plain minibatch SGD accumulates duplicate-row updates additively; a
    # smaller lr keeps it stable at batch 2048 (the paper steps per rating)
    lr = {"sgd": 0.005, "adagrad": 0.05, "adadelta": 1.0, "adam": 0.005}[optimizer]
    trainer = _run(train_ds, test_ds, epochs=4, pruning_rate=0.3,
                   optimizer=optimizer, lr=lr)
    assert np.isfinite(trainer.history[-1].test_mae)
    assert trainer.mean_work_fraction() < 1.0


@pytest.mark.parametrize("variant", ["bias", "svdpp"])
def test_variant_agnostic(movielens_small, variant):
    """BiasSVD and SVD++ share the training process (paper §2.1)."""
    train_ds, test_ds = movielens_small
    trainer = _run(train_ds, test_ds, epochs=4, pruning_rate=0.3, variant=variant)
    maes = [r.test_mae for r in trainer.history]
    assert all(np.isfinite(m) for m in maes)
    assert maes[-1] < maes[0] * 1.5


@pytest.mark.parametrize("overrides", [
    dict(strategy="twin"),
    dict(init_method="uniform"),
    dict(lr=0.15),
])
def test_hyperparameter_agnostic(movielens_small, overrides):
    """Paper §5.3: twin learners / uniform init / other learning rates."""
    train_ds, test_ds = movielens_small
    trainer = _run(train_ds, test_ds, epochs=4, pruning_rate=0.3, **overrides)
    assert np.isfinite(trainer.history[-1].test_mae)


def test_fused_kernel_training_path(movielens_small):
    """FunkSVD+SGD routed through the fused Pallas kernel (interpret mode)
    trains to a comparable MAE as the XLA path."""
    train_ds, test_ds = movielens_small
    xla = _run(train_ds, test_ds, epochs=3, pruning_rate=0.3, lr=0.005,
               optimizer="sgd", use_fused_kernel=False)
    pal = _run(train_ds, test_ds, epochs=3, pruning_rate=0.3, lr=0.005,
               optimizer="sgd", use_fused_kernel=True)
    assert abs(xla.history[-1].test_mae - pal.history[-1].test_mae) < 0.05


def test_paper_dataset_shapes():
    ds = paper_dataset("movielens100k", scale=0.1)
    assert ds.num_users == 94 and ds.num_items == 168
    ds = paper_dataset("jester", scale=0.01)
    assert ds.rating_min == -10.0 and ds.rating_max == 10.0
