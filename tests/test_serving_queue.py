"""Async request pipeline (`serving/queue.py`): continuous batching must
change wall-clock only — never results, never ordering guarantees.

Determinism-critical tests drive a ``start=False`` queue with
:meth:`RequestQueue.drain_once` so batch composition is pinned; the thread
stress test runs the real scheduler thread under concurrent submitters.
"""
import threading
import time

import numpy as np
import jax
import pytest

from repro.core import mf
from repro.serving import (
    QueueFullError,
    RequestQueue,
    RequestTimeout,
    ServingEngine,
)


@pytest.fixture(scope="module")
def engine():
    params = mf.init_params(
        jax.random.PRNGKey(0), 60, 500, 16, variant="bias", global_mean=3.0
    )
    return ServingEngine(
        params, 0.03, 0.03, use_kernel=False, block_n=128, max_batch=32
    )


# ---------------------------------------------------------------------------
# parity: queue-fed == synchronous path, byte for byte
# ---------------------------------------------------------------------------


def test_queue_batch_byte_identical_to_sync(engine):
    """One pinned batch (duplicates included) vs engine.topk on the same
    users: scores and indices must match bitwise, and duplicate user ids
    must fan out to identical rows."""
    users = [7, 3, 41, 3, 19, 7]
    q = RequestQueue(engine, start=False)
    futs = [q.submit(u, 6) for u in users]
    assert q.drain_once() == len(users)
    want_s, want_i = engine.topk(sorted(set(users)), 6)
    row = {u: r for r, u in enumerate(sorted(set(users)))}
    for u, fut in zip(users, futs):
        got_s, got_i = fut.result(0)
        assert np.array_equal(got_s, want_s[row[u]])
        assert np.array_equal(got_i, want_i[row[u]])
    q.close()


def test_queue_stress_threads_match_sequential(engine):
    """N threads x mixed-size (mixed-topk) requests through the live
    scheduler: every future completes and equals the sequential
    single-request result bitwise."""
    rng = np.random.default_rng(0)
    topks = (3, 7)
    expected = {
        k: engine.topk(np.arange(engine.num_users), k) for k in topks
    }
    q = RequestQueue(engine, linger_ms=1.0, max_pending=1024)
    failures = []

    def client(seed):
        crng = np.random.default_rng(seed)
        for _ in range(25):
            u = int(crng.integers(0, engine.num_users))
            k = int(crng.choice(topks))
            got_s, got_i = q.submit(u, k, timeout=120).result(timeout=120)
            want_s, want_i = expected[k]
            if not (
                np.array_equal(got_s, want_s[u])
                and np.array_equal(got_i, want_i[u])
            ):
                failures.append((u, k))

    threads = [
        threading.Thread(target=client, args=(seed,)) for seed in range(8)
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=300)
        assert not t.is_alive(), "client thread hung"
    q.close()
    assert not failures, f"queue results diverged from sequential: {failures}"
    assert q.requests_served == 8 * 25
    assert q.batches_served <= q.requests_served  # coalescing happened at all
    del rng


# ---------------------------------------------------------------------------
# scheduling policy: deadline order, topk buckets
# ---------------------------------------------------------------------------


def test_deadline_order_within_bucket(engine):
    """Futures of one batch resolve in deadline order, not submit order."""
    q = RequestQueue(engine, start=False)
    order = []
    timeouts = [50.0, 10.0, 30.0, 20.0, 40.0]
    futs = []
    for tag, timeout in enumerate(timeouts):
        fut = q.submit(tag % engine.num_users, 5, timeout=timeout)
        fut.add_done_callback(lambda f, tag=tag: order.append(tag))
        futs.append(fut)
    assert q.drain_once() == len(timeouts)
    want = [tag for tag, _ in sorted(enumerate(timeouts), key=lambda p: p[1])]
    assert order == want
    q.close()


def test_earliest_deadline_picks_the_bucket(engine):
    """A batch is one topk bucket: the earliest-deadline request defines it
    and other buckets wait for the next launch."""
    q = RequestQueue(engine, start=False)
    late = [q.submit(u, 7, timeout=60.0) for u in (1, 2, 3)]
    urgent = q.submit(4, 3, timeout=5.0)
    assert q.drain_once() == 1  # only the topk=3 bucket
    assert urgent.done() and not any(f.done() for f in late)
    assert q.drain_once() == 3
    assert all(f.done() for f in late)
    q.close()


def test_mixed_topk_never_share_a_launch(engine):
    batches = []

    def spy(users, topk):
        batches.append((len(users), topk))
        return engine.topk(users, topk)

    q = RequestQueue(engine, score_fn=spy, start=False)
    for i in range(6):
        q.submit(i, 3 if i % 2 else 7)
    while q.drain_once():
        pass
    assert len(batches) == 2
    assert {(n, k) for n, k in batches} == {(3, 3), (3, 7)}
    assert q.batches_served == 2 and q.requests_served == 6
    q.close()


# ---------------------------------------------------------------------------
# timeouts, admission control, lifecycle
# ---------------------------------------------------------------------------


def test_expired_request_fails_not_scores(engine):
    q = RequestQueue(engine, start=False)
    doomed = q.submit(1, 5, timeout=1e-4)
    alive = q.submit(2, 5, timeout=60.0)
    time.sleep(0.01)
    assert q.drain_once() == 1  # only the live request reaches the engine
    with pytest.raises(RequestTimeout):
        doomed.result(0)
    assert alive.done() and q.expired == 1
    q.close()


def test_backpressure_rejects_and_counts(engine):
    q = RequestQueue(engine, max_pending=2, start=False)
    q.submit(1, 5)
    q.submit(2, 5)
    with pytest.raises(QueueFullError):
        q.submit(3, 5)
    assert q.rejected == 1
    assert q.drain_once() == 2  # the queue itself still drains fine
    q.close()


def test_backpressure_block_waits_for_space(engine):
    q = RequestQueue(engine, max_pending=1, start=False)
    first = q.submit(1, 5)
    drained = threading.Timer(0.05, q.drain_once)
    drained.start()
    fut = q.submit(2, 5, block=True, block_timeout=10.0)  # waits ~50ms
    drained.join()
    assert first.done() and not fut.done()
    assert q.drain_once() == 1 and fut.done()
    q.close()


def test_bad_request_fails_its_own_submit(engine):
    q = RequestQueue(engine, start=False)
    ok = q.submit(5, 5)
    with pytest.raises(ValueError):
        q.submit(engine.num_users + 7, 5)  # unknown user
    with pytest.raises(ValueError):
        q.submit(0, engine.n_items + 1)  # topk > n_items
    assert q.drain_once() == 1 and ok.done()
    q.close()


def test_close_drains_pending(engine):
    q = RequestQueue(engine)
    futs = [q.submit(u, 4) for u in range(10)]
    q.close()
    assert all(f.done() for f in futs)
    for f in futs:
        f.result(0)  # no exceptions
    with pytest.raises(RuntimeError):
        q.submit(0, 4)


def test_close_cancel_pending_fails_fast(engine):
    q = RequestQueue(engine, start=False)
    futs = [q.submit(u, 4) for u in range(3)]
    q.close(cancel_pending=True)
    for f in futs:
        with pytest.raises(RequestTimeout):
            f.result(0)


def test_cancelled_future_does_not_kill_scheduler(engine):
    """A caller cancelling its future (the natural follow-up to a client-side
    timeout) must not crash the scheduler thread: later requests still
    complete and the cancelled one is simply skipped."""
    q = RequestQueue(engine, start=False)
    doomed = q.submit(1, 5)
    assert doomed.cancel()
    survivor = q.submit(2, 5)
    assert q.drain_once() == 1  # the cancelled request never reaches scoring
    assert survivor.done() and doomed.cancelled()
    survivor.result(0)
    # the live scheduler keeps serving after a cancel too
    q.start()
    fut = q.submit(3, 5)
    fut.result(timeout=60)
    q.close()


def test_expired_requests_wake_blocked_submitters(engine):
    """Expiry frees queue space: a submitter blocked on backpressure must be
    woken when the scheduler drops expired entries, not wait forever."""
    q = RequestQueue(engine, max_pending=1, start=False)
    q.submit(1, 5, timeout=1e-4)  # will expire, freeing the only slot
    time.sleep(0.01)
    unblocked = []

    def blocked_submit():
        unblocked.append(q.submit(2, 5, block=True, block_timeout=30.0))

    t = threading.Thread(target=blocked_submit)
    t.start()
    time.sleep(0.05)           # let the submitter reach the wait
    assert q.drain_once() == 0  # only the expired request: nothing scored
    t.join(timeout=5)
    assert not t.is_alive(), "submitter still blocked after expiry freed space"
    assert q.drain_once() == 1 and unblocked[0].done()
    q.close()


# ---------------------------------------------------------------------------
# engine submit/poll frontend
# ---------------------------------------------------------------------------


def test_engine_concurrent_first_submit_single_queue():
    """Racing first submits must auto-start exactly one queue, never raise
    'already has a running request queue'."""
    params = mf.init_params(jax.random.PRNGKey(2), 20, 200, 8)
    eng = ServingEngine(params, 0.0, 0.0, use_kernel=False, block_n=64)
    barrier = threading.Barrier(8)
    errors, futs = [], []

    def first_submit(u):
        barrier.wait()
        try:
            futs.append(eng.submit(u, 4))
        except Exception as exc:  # noqa: BLE001
            errors.append(exc)

    threads = [threading.Thread(target=first_submit, args=(u,)) for u in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=60)
    assert not errors, errors
    for f in futs:
        f.result(timeout=60)
    eng.stop()


def test_engine_submit_autostarts_and_stops(engine):
    fut = engine.submit(3, 5)
    got_s, got_i = fut.result(timeout=60)
    want_s, want_i = engine.topk([3], 5)
    assert np.array_equal(got_s, want_s[0]) and np.array_equal(got_i, want_i[0])
    with pytest.raises(RuntimeError):
        engine.start()  # already running
    engine.stop()
    engine.stop()  # idempotent
    assert engine._queue is None


def test_engine_queue_sharded_scoring_parity(engine):
    """Queue-fed scoring through topk_sharded on a 1-way mesh must equal the
    local sync path bitwise (the 2-D layouts are covered on the 4-device CI
    mesh and the slow subprocess test in test_serving.py)."""
    mesh = jax.make_mesh((1,), ("model",))
    engine.start(mesh=mesh)
    try:
        futs = [engine.submit(u, 6) for u in (0, 9, 33)]
        want_s, want_i = engine.topk([0, 9, 33], 6)
        for r, fut in enumerate(futs):
            got_s, got_i = fut.result(timeout=120)
            assert np.array_equal(got_s, want_s[r])
            assert np.array_equal(got_i, want_i[r])
    finally:
        engine.stop()


# ---------------------------------------------------------------------------
# priority classes
# ---------------------------------------------------------------------------


def test_priority_orders_within_deadline_bucket(engine):
    """No-deadline requests share one (infinite) bucket: lower priority
    values schedule first regardless of arrival order — with max_batch
    smaller than the backlog, the late high-priority request still makes
    the first launch and low-priority work waits."""
    batches = []

    def spy(users, topk):
        batches.append(list(users))
        return engine.topk(users, topk)

    q = RequestQueue(engine, score_fn=spy, start=False, max_batch=2)
    low = [q.submit(u, 5, priority=10) for u in (11, 12, 13)]
    urgent = q.submit(14, 5, priority=0)
    assert q.drain_once() == 2
    assert urgent.done()            # the high-priority request made batch 1
    assert 14 in batches[0]
    assert sum(f.done() for f in low) == 1  # only one low-prio slot remained
    while q.drain_once():
        pass
    assert all(f.done() for f in low)
    q.close()


def test_priority_never_starves_high_priority_under_flood(engine):
    """Continuous low-priority arrivals must not delay a high-priority
    request past the very next launch (the ROADMAP fairness item)."""
    q = RequestQueue(engine, start=False, max_batch=8)
    for u in range(16):
        q.submit(u % engine.num_users, 5, priority=10)
    for round_ in range(6):
        # a flood keeps arriving...
        for u in range(8):
            q.submit((round_ * 8 + u) % engine.num_users, 5, priority=10)
        # ...and one user-facing request lands
        vip = q.submit(round_ % engine.num_users, 5, priority=0)
        assert q.drain_once() > 0
        assert vip.done(), f"high-priority request starved in round {round_}"
    q.close()


def test_priority_does_not_override_earlier_deadline_bucket(engine):
    """A whole deadline bucket earlier beats any priority: urgency first,
    class second."""
    q = RequestQueue(engine, start=False, max_batch=1,
                     deadline_bucket_ms=50.0)
    slow_high = q.submit(1, 5, timeout=60.0, priority=0)
    fast_low = q.submit(2, 5, timeout=1.0, priority=10)
    assert q.drain_once() == 1
    assert fast_low.done() and not slow_high.done()
    assert q.drain_once() == 1
    assert slow_high.done()
    q.close()


def test_engine_submit_passes_priority(engine):
    fut_low = engine.submit(1, 5, priority=10)
    fut_high = engine.submit(2, 5, priority=0)
    for fut in (fut_low, fut_high):
        scores, items = fut.result(timeout=60)
        assert scores.shape == (5,) and items.shape == (5,)
    engine.stop()


# ---------------------------------------------------------------------------
# linger waits for SCHEDULABLE requests, not raw heap length (ISSUE-7 bugfix)
# ---------------------------------------------------------------------------


def test_linger_counts_only_schedulable_winning_bucket(engine):
    """The linger wait must fill the batch with requests that can actually
    join it.  Before the fix, raw heap length was compared to ``max_batch``,
    so other-topk-bucket (and expired) entries ended the linger early and
    the winning bucket launched underfilled."""
    calls = []
    real = engine.topk

    def spy(users, topk):
        calls.append((list(users), topk))
        return real(users, topk)

    q = RequestQueue(engine, score_fn=spy, max_batch=3, linger_ms=500.0)
    f0 = q.submit(10, 10, timeout=30.0, priority=0)
    # two other-bucket requests: with the bug, heap length hits max_batch=3
    # and the linger ends with the topk=10 bucket holding a single request
    other = [q.submit(u, 5, timeout=30.0, priority=5) for u in (11, 12)]
    time.sleep(0.1)
    late = [q.submit(u, 10, timeout=30.0, priority=0) for u in (13, 14)]
    for fut in [f0, *other, *late]:
        fut.result(timeout=60)
    q.close()
    first_topk10 = next(c for c in calls if c[1] == 10)
    assert sorted(first_topk10[0]) == [10, 13, 14], (
        "linger ended early: winning-bucket batch launched underfilled"
    )


def test_schedulable_locked_ignores_expired_and_other_buckets(engine):
    """Unit view of the counting rule the linger loop relies on."""
    q = RequestQueue(engine, start=False, max_batch=8)
    q.submit(1, 10, timeout=60.0)
    q.submit(2, 10, timeout=60.0)
    q.submit(3, 5, timeout=60.0)        # other topk bucket
    expired = q.submit(4, 10, timeout=1e-9)  # will be expired by now
    time.sleep(0.01)
    with q._cond:
        assert q._schedulable_locked() == 2
    # all-expired heap counts zero schedulable
    q2 = RequestQueue(engine, start=False)
    q2.submit(5, 10, timeout=1e-9)
    time.sleep(0.01)
    with q2._cond:
        assert q2._schedulable_locked() == 0
    q.close()
    q2.close()
    with pytest.raises(RequestTimeout):
        expired.result(0)
