"""Cold-row eviction (`store/eviction.py`) + its remap threading through
the online updater, publisher, delta bus, and serving engine.

The contracts under test, in the order the module docstring states them:

* spill/revive is **bitwise**: an evicted row that comes back (because an
  event touched its external id) is exactly the row that left, factor +
  bias + optimizer state;
* compaction relocates but never alters surviving rows, and never touches
  the item table;
* the remap epoch is a barrier the whole delta fabric respects: a restart
  that folds the checkpoint chain across a compaction reconstructs the
  same external-id view (remap table included) as a live bus follower,
  down to identical top-k scores.
"""
import numpy as np
import jax
import pytest

from hypothesis_compat import given, settings, st
from repro.core import mf
from repro.online import EventBatch, OnlineUpdater
from repro.online.publisher import SnapshotPublisher, fold_deltas
from repro.serving import ServingEngine
from repro.serving.fleet import EngineDeltaSink
from repro.store import EvictionConfig, IdRemap, UserEvictor


def _params(m=24, n=40, k=6, seed=0, variant="bias"):
    return mf.init_params(
        jax.random.PRNGKey(seed), m, n, k, variant=variant, global_mean=3.0
    )


def _updater(m=24, n=40, *, variant="bias", seed=0, **kw):
    return OnlineUpdater(
        _params(m, n, seed=seed, variant=variant), None, 0.0, 0.0,
        batch_size=8, seed=seed, **kw,
    )


def _evictor(tmp_path, max_users, target=None):
    return UserEvictor(EvictionConfig(
        max_users=max_users, spill_dir=str(tmp_path / "spill"),
        target_users=target,
    ))


def _batch(rng, ext_max, size=8):
    return EventBatch(
        user=rng.integers(0, ext_max, size).astype(np.int32),
        item=rng.integers(0, 40, size).astype(np.int32),
        rating=rng.uniform(1, 5, size).astype(np.float32),
    )


def _live_rows(upd):
    """{ext_id: (p_row, bias_row)} for every currently-resident ext id."""
    remap = upd.evictor.remap
    p = np.asarray(upd.params.p)
    b = np.asarray(upd.params.user_bias)
    out = {}
    for ext in range(remap.num_external):
        phys = int(remap.ext_to_phys[ext])
        if phys >= 0:
            out[ext] = (p[phys].copy(), b[phys].copy())
    return out


# ---------------------------------------------------------------------------
# IdRemap basics
# ---------------------------------------------------------------------------

def test_idremap_lookup_unknown_and_spilled():
    remap = IdRemap(ext_to_phys=np.array([0, -1, 1], np.int32), epoch=3)
    got = remap.lookup(np.array([0, 1, 2, 7, -2]))
    assert got.tolist() == [0, -1, 1, -1, -1]
    assert remap.num_external == 3
    frozen = remap.as_array()
    frozen[0] = 99
    assert remap.ext_to_phys[0] == 0, "as_array must copy"


def test_eviction_config_validates_target():
    with pytest.raises(ValueError, match="target_users"):
        UserEvictor(EvictionConfig(max_users=10, spill_dir="/tmp/x",
                                   target_users=11))


def test_bind_rejects_svdpp(tmp_path):
    from repro.data import build_user_history, synthetic_ratings

    ds = synthetic_ratings(12, 20, 256, seed=0)
    hist = build_user_history(ds, max_hist=4)
    upd = OnlineUpdater(
        _params(12, 20, variant="svdpp"), None, 0.0, 0.0,
        user_history=hist, batch_size=8,
    )
    with pytest.raises(ValueError, match="SVD"):
        upd.attach_evictor(_evictor(tmp_path, 10))


# ---------------------------------------------------------------------------
# spill / revive / compaction invariants
# ---------------------------------------------------------------------------

def test_evict_bounds_table_and_preserves_survivors(tmp_path):
    rng = np.random.default_rng(0)
    upd = _updater(m=16)
    upd.attach_evictor(_evictor(tmp_path, max_users=24, target=18))
    for ext_max in (16, 24, 30):   # grow past the watermark
        upd.apply(_batch(rng, ext_max))
    q_before = np.asarray(upd.params.q).copy()
    before = _live_rows(upd)
    report = upd.evictor.maybe_evict()
    assert report is not None and report["remap_epoch"] == 1
    assert upd.num_users == 18 <= 24
    assert np.array_equal(np.asarray(upd.params.q), q_before), (
        "user eviction must not touch the item table")
    after = _live_rows(upd)
    for ext, (p_row, b_row) in after.items():
        assert np.array_equal(p_row, before[ext][0]), (
            f"survivor ext {ext} factor row changed under compaction")
        assert np.array_equal(b_row, before[ext][1])
    # external domain is grow-only: nobody was forgotten, only spilled
    spilled = set(upd.evictor.spilled_external_ids().tolist())
    assert spilled == set(before) - set(after)


def test_revive_is_bitwise_roundtrip(tmp_path):
    rng = np.random.default_rng(1)
    upd = _updater(m=12)
    upd.attach_evictor(_evictor(tmp_path, max_users=16, target=10))
    for ext_max in (12, 20):
        upd.apply(_batch(rng, ext_max, size=16))
    before = _live_rows(upd)
    opt_before = {
        key: np.asarray(upd.opt_state.p[key]).copy()
        for key in upd.opt_state.p
        if np.asarray(upd.opt_state.p[key]).ndim >= 1
        and np.asarray(upd.opt_state.p[key]).shape[0] == upd.num_users
    }
    phys_before = {
        ext: int(upd.evictor.remap.ext_to_phys[ext]) for ext in before
    }
    assert upd.evictor.maybe_evict() is not None
    spilled = upd.evictor.spilled_external_ids()
    assert spilled.size
    # scoring-only lookups leave spilled rows on disk...
    assert (upd.evictor.remap.lookup(spilled) == -1).all()
    # ...but an update revives them, bitwise
    phys = upd.evictor.resolve(spilled.astype(np.int32))
    p = np.asarray(upd.params.p)
    b = np.asarray(upd.params.user_bias)
    for ext, row in zip(spilled.tolist(), phys.tolist()):
        assert np.array_equal(p[row], before[ext][0])
        assert np.array_equal(b[row], before[ext][1])
        for key, table in opt_before.items():
            assert np.array_equal(
                np.asarray(upd.opt_state.p[key])[row],
                table[phys_before[ext]],
            ), f"optimizer state {key} not restored for ext {ext}"
    assert upd.evictor.revivals == spilled.size


@given(
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    ops=st.lists(
        st.tuples(st.sampled_from(["apply", "grow", "evict"]),
                  st.integers(min_value=0, max_value=2**31 - 1)),
        min_size=3, max_size=12,
    ),
)
@settings(max_examples=20, deadline=None)
def test_evict_grow_evict_preserves_live_predictions(tmp_path_factory,
                                                     seed, ops):
    """Any evict→grow→evict interleaving: every external user's factor/bias
    rows survive relocation and spill/revive bitwise, so their predictions
    are unchanged by the memory manager."""
    tmp_path = tmp_path_factory.mktemp("evict_prop")
    rng = np.random.default_rng(seed)
    upd = _updater(m=10)
    upd.attach_evictor(_evictor(tmp_path, max_users=14, target=10))
    shadow = {}

    def snapshot_live():
        for ext, rows in _live_rows(upd).items():
            shadow[ext] = rows

    snapshot_live()
    ext_domain = 10
    for op, op_seed in ops:
        op_rng = np.random.default_rng(op_seed)
        if op == "grow":
            ext_domain += int(op_rng.integers(1, 6))
        if op in ("apply", "grow"):
            upd.apply(_batch(op_rng, ext_domain))
            snapshot_live()
        else:
            report = upd.evictor.maybe_evict()
            if report is not None:
                live = _live_rows(upd)
                for ext, (p_row, b_row) in live.items():
                    assert np.array_equal(p_row, shadow[ext][0]), (
                        f"ext {ext} factor row corrupted by eviction")
                    assert np.array_equal(b_row, shadow[ext][1])
    # final reconciliation: revive everything and demand bitwise parity
    # with the last value each row was seen holding
    all_ext = np.arange(upd.evictor.remap.num_external, dtype=np.int32)
    phys = upd.evictor.resolve(all_ext)
    p = np.asarray(upd.params.p)
    b = np.asarray(upd.params.user_bias)
    for ext, row in zip(all_ext.tolist(), phys.tolist()):
        assert np.array_equal(p[row], shadow[ext][0])
        assert np.array_equal(b[row], shadow[ext][1])


# ---------------------------------------------------------------------------
# remap threading: publisher -> bus -> engine, and the folded restart
# ---------------------------------------------------------------------------

def _drive(upd, pub, ev, rng, *, publishes=6):
    """Apply/publish loop that forces at least one compaction mid-chain."""
    bumps = 0
    for i in range(publishes):
        upd.apply(_batch(rng, 20 + 6 * i, size=16))
        if i >= 2 and ev.maybe_evict() is not None:
            bumps += 1
        pub.publish()
    assert bumps >= 1, "test setup never crossed a remap epoch"
    return bumps


def test_restart_across_remap_epoch_matches_live_replica(tmp_path):
    """`fold_deltas` over a chain containing a compaction reconstructs the
    remap table and serves every external user bitwise-identically to a
    replica that followed the bus live."""
    rng = np.random.default_rng(7)
    params = _params(m=20, n=40)
    upd = OnlineUpdater(params, None, 0.0, 0.0, batch_size=16, seed=7)
    ev = _evictor(tmp_path, max_users=30, target=24)
    upd.attach_evictor(ev)
    primary = ServingEngine(params, 0.0, 0.0)
    pub = SnapshotPublisher(primary, upd,
                            checkpoint_dir=str(tmp_path / "chain"), keep=32)
    follower = pub.subscribe(
        EngineDeltaSink(ServingEngine(params, 0.0, 0.0), replica_id="r0")
    )
    _drive(upd, pub, ev, rng)
    pub.close()
    live = follower.engine
    assert live.remap_epoch == ev.remap.epoch >= 1

    extras = {}
    folded, f_tp, f_tq, _, last = fold_deltas(
        str(tmp_path / "chain"), params, 0.0, 0.0, extras=extras,
    )
    assert last == pub.version
    assert extras["remap_epoch"] == ev.remap.epoch
    assert np.array_equal(extras["user_remap"], ev.remap.as_array())
    restarted = ServingEngine(
        folded, f_tp, f_tq,
        user_remap=extras["user_remap"], remap_epoch=extras["remap_epoch"],
    )
    users = np.arange(ev.remap.num_external, dtype=np.int32)
    s_live, i_live = live.topk(users, 5)
    s_cold, i_cold = restarted.topk(users, 5)
    np.testing.assert_array_equal(np.asarray(i_cold), np.asarray(i_live))
    np.testing.assert_array_equal(np.asarray(s_cold), np.asarray(s_live))
    # both views agree with the updater's own external-id geometry
    assert restarted.num_users == upd.num_users == live.num_users


def test_delta_after_remap_bump_keeps_following(tmp_path):
    """The publish *after* a compaction heals followers via kind=full; the
    ones after that go back to cheap deltas, remap intact."""
    rng = np.random.default_rng(3)
    params = _params(m=20, n=40)
    upd = OnlineUpdater(params, None, 0.0, 0.0, batch_size=16, seed=3)
    ev = _evictor(tmp_path, max_users=28, target=20)
    upd.attach_evictor(ev)
    pub = SnapshotPublisher(ServingEngine(params, 0.0, 0.0), upd)
    follower = pub.subscribe(
        EngineDeltaSink(ServingEngine(params, 0.0, 0.0), replica_id="r0")
    )
    upd.apply(_batch(rng, 20, size=16))
    pub.publish()                           # bootstrap
    upd.apply(_batch(rng, 40, size=16))     # past watermark
    assert ev.maybe_evict() is not None
    assert pub.publish().kind == "full"     # remap-epoch barrier
    # touch only still-resident users: no growth, no revival -> cheap delta
    live_ext = np.flatnonzero(ev.remap.ext_to_phys >= 0).astype(np.int32)
    upd.apply(EventBatch(
        user=rng.choice(live_ext, 16).astype(np.int32),
        item=rng.integers(0, 40, 16).astype(np.int32),
        rating=rng.uniform(1, 5, 16).astype(np.float32),
    ))
    report = pub.publish()
    assert report.kind == "delta"
    assert follower.engine.remap_epoch == ev.remap.epoch == 1
    users = np.arange(ev.remap.num_external, dtype=np.int32)
    ref = ServingEngine(
        upd.params, upd.t_p, upd.t_q,
        user_remap=ev.remap.as_array(), remap_epoch=ev.remap.epoch,
    )
    s_ref, i_ref = ref.topk(users, 5)
    s_got, i_got = follower.engine.topk(users, 5)
    np.testing.assert_array_equal(np.asarray(i_got), np.asarray(i_ref))
    np.testing.assert_array_equal(np.asarray(s_got), np.asarray(s_ref))
