"""Soft dependency on ``hypothesis`` for the property-test modules.

The container image does not always ship hypothesis, and a bare
``from hypothesis import ...`` fails the whole module at *collection* time,
taking every non-property test in the module down with it.  Importing
``given``/``settings``/``st`` from here instead degrades gracefully: with
hypothesis installed the real objects are re-exported; without it the
``@given`` tests are marked skipped and everything else in the module still
collects and runs.

Pin the real dependency via requirements.txt for CI runs.
"""
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only without hypothesis
    import pytest

    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Stands in for ``hypothesis.strategies`` so module-level strategy
        expressions (``st.floats(...)``, ``@st.composite``, ...) evaluate."""

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, name):
            return self

    st = _AnyStrategy()

    def given(*args, **kwargs):
        return lambda fn: pytest.mark.skip(
            reason="hypothesis not installed (see requirements.txt)"
        )(fn)

    def settings(*args, **kwargs):
        return lambda fn: fn
