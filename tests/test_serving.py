"""Serving engine: streaming top-k parity with the dense oracle, full
checkpoint round-trips (biases/implicit included), micro-batching, and the
catalog-sharded merge."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import mf
from repro.core.ranks import effective_ranks
from repro.core.trainer import DPMFTrainer, TrainConfig
from repro.data import synthetic_ratings, train_test_split
from repro.kernels import ops, ref
from repro.serving import (
    LRUCache,
    MicroBatcher,
    ServingEngine,
    bucket_size,
    load_mf_checkpoint,
)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _random_factors(m, n, k, seed=0):
    rng = np.random.default_rng(seed)
    p = jnp.asarray(rng.normal(0, 0.1, (m, k)).astype(np.float32))
    q = jnp.asarray(rng.normal(0, 0.1, (n, k)).astype(np.float32))
    return p, q


# ---------------------------------------------------------------------------
# kernel / streaming top-k vs the dense argsort oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("t", [0.0, 0.05])
@pytest.mark.parametrize("with_bias", [False, True])
def test_streaming_topk_matches_oracle(t, with_bias):
    p, q = _random_factors(40, 900, 24)
    bias = (
        jnp.asarray(np.random.default_rng(3).normal(0, 0.3, (900,)),
                    dtype=jnp.float32)
        if with_bias else None
    )
    r_u, r_i = effective_ranks(p, t), effective_ranks(q, t)
    want_s, want_i = ref.pruned_topk_ref(p, q, r_u, r_i, 11, item_bias=bias)
    got_s, got_i = ops.pruned_topk(
        p, q, t, t, 11, item_bias=bias, use_kernel=False, block_n=128
    )
    assert np.array_equal(np.asarray(want_i), np.asarray(got_i))
    np.testing.assert_allclose(
        np.asarray(want_s), np.asarray(got_s), rtol=1e-5, atol=1e-5
    )


@pytest.mark.parametrize("t", [0.0, 0.05])
def test_pallas_topk_kernel_matches_oracle(t):
    p, q = _random_factors(40, 700, 24, seed=1)
    r_u, r_i = effective_ranks(p, t), effective_ranks(q, t)
    want_s, want_i = ref.pruned_topk_ref(p, q, r_u, r_i, 9)
    got_s, got_i = ops.pruned_topk(
        p, q, t, t, 9, use_kernel=True, interpret=True
    )
    assert np.array_equal(np.asarray(want_i), np.asarray(got_i))
    np.testing.assert_allclose(
        np.asarray(want_s), np.asarray(got_s), rtol=1e-5, atol=1e-5
    )


def test_topk_validates_k():
    p, q = _random_factors(4, 16, 8)
    with pytest.raises(ValueError):
        ops.pruned_topk(p, q, 0.0, 0.0, 17, use_kernel=False)
    with pytest.raises(ValueError):
        ops.pruned_topk(p, q, 0.0, 0.0, 0, use_kernel=False)


# ---------------------------------------------------------------------------
# engine vs predict_all_items (the retired serve path) across variants
# ---------------------------------------------------------------------------


def _dense_oracle(params, users, t_p, t_q, topk, hist=None):
    scores = mf.predict_all_items(
        params, users, t_p, t_q, use_kernel=False, hist=hist
    )
    idx = jnp.argsort(-scores, axis=1)[:, :topk].astype(jnp.int32)
    return np.asarray(jnp.take_along_axis(scores, idx, axis=1)), np.asarray(idx)


@pytest.mark.parametrize("variant", ["funk", "bias", "svdpp"])
def test_engine_matches_dense_serve_path(variant):
    m, n, k = 80, 1200, 16
    rng = np.random.default_rng(4)
    params = mf.init_params(
        jax.random.PRNGKey(0), m, n, k, variant=variant, global_mean=3.1
    )
    hist = (
        rng.integers(0, n, (m, 6)).astype(np.int32)
        if variant == "svdpp" else None
    )
    t = 0.04
    engine = ServingEngine(
        params, t, t, use_kernel=False, max_batch=32, block_n=256,
        user_history=hist,
    )
    users = rng.integers(0, m, 41).astype(np.int32)  # odd size: pad + chunk
    got_s, got_i = engine.topk(users, 7)
    want_s, want_i = _dense_oracle(
        params, jnp.asarray(users), t, t, 7,
        hist=None if hist is None else jnp.asarray(hist[users]),
    )
    assert np.array_equal(want_i, got_i)
    np.testing.assert_allclose(want_s, got_s, rtol=1e-5, atol=1e-5)


def test_engine_kernel_path_matches_stream_path():
    params = mf.init_params(jax.random.PRNGKey(6), 40, 600, 16,
                            variant="bias", global_mean=3.0)
    stream = ServingEngine(params, 0.04, 0.04, use_kernel=False, block_n=128)
    kernel = ServingEngine(params, 0.04, 0.04, use_kernel=True,
                           interpret=True, max_batch=16)
    users = np.arange(13, dtype=np.int32)
    want_s, want_i = stream.topk(users, 6)
    got_s, got_i = kernel.topk(users, 6)
    assert np.array_equal(want_i, got_i)
    np.testing.assert_allclose(want_s, got_s, rtol=1e-5, atol=1e-5)


def test_engine_svdpp_missing_history_falls_back_to_p():
    """allow_missing_history serves SVD++ checkpoints from p alone (empty
    histories hit only the implicit table's zero padding row)."""
    params = mf.init_params(jax.random.PRNGKey(7), 20, 300, 8,
                            variant="svdpp", global_mean=3.0)
    with pytest.raises(ValueError):
        ServingEngine(params, 0.0, 0.0, use_kernel=False, block_n=64)
    engine = ServingEngine(params, 0.0, 0.0, use_kernel=False, block_n=64,
                           allow_missing_history=True)
    users = np.arange(5, dtype=np.int32)
    got_s, got_i = engine.topk(users, 4)
    want_s, want_i = _dense_oracle(
        params, jnp.asarray(users), 0.0, 0.0, 4, hist=None
    )
    assert np.array_equal(want_i, got_i)
    np.testing.assert_allclose(want_s, got_s, rtol=1e-5, atol=1e-5)


def test_engine_hot_user_cache_consistent():
    m, n, k = 30, 400, 8
    rng = np.random.default_rng(5)
    params = mf.init_params(jax.random.PRNGKey(1), m, n, k, variant="svdpp",
                            global_mean=3.0)
    hist = rng.integers(0, n, (m, 4)).astype(np.int32)
    engine = ServingEngine(params, 0.02, 0.02, use_kernel=False,
                           block_n=128, user_history=hist, cache_size=8)
    cold_s, cold_i = engine.topk([3, 5, 3], 5)
    assert engine.vector_cache.misses > 0
    warm_s, warm_i = engine.topk([3, 5, 3], 5)
    assert engine.vector_cache.hits > 0
    assert np.array_equal(cold_i, warm_i)
    np.testing.assert_allclose(cold_s, warm_s, rtol=0, atol=0)


# ---------------------------------------------------------------------------
# checkpoint round-trip: biases and implicit factors survive serving restore
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrip_full_params(tmp_path):
    """BiasSVD checkpoints must serve with biases — the old loader dropped
    everything but p/q and silently served wrong scores."""
    ds = synthetic_ratings(60, 90, 2000, seed=0)
    train_ds, test_ds = train_test_split(ds, 0.2, seed=0)
    cfg = TrainConfig(
        k=8, epochs=2, batch_size=512, pruning_rate=0.3, variant="bias",
        checkpoint_dir=str(tmp_path / "ckpt"),
    )
    trainer = DPMFTrainer(cfg, train_ds, test_ds)
    trainer.run()

    params, t_p, t_q, perm, meta = load_mf_checkpoint(str(tmp_path / "ckpt"))
    assert params.user_bias is not None and params.item_bias is not None
    assert params.global_mean is not None
    np.testing.assert_array_equal(np.asarray(params.p),
                                  np.asarray(trainer.params.p))
    np.testing.assert_array_equal(np.asarray(params.user_bias),
                                  np.asarray(trainer.params.user_bias))
    assert float(t_p) == float(trainer.t_p)
    assert perm is not None

    engine = ServingEngine.from_checkpoint(
        str(tmp_path / "ckpt"), use_kernel=False, block_n=64
    )
    users = np.asarray([0, 7, 13], np.int32)
    got_s, got_i = engine.topk(users, 5)
    want_s, want_i = _dense_oracle(
        trainer.params, jnp.asarray(users), trainer.t_p, trainer.t_q, 5
    )
    assert np.array_equal(want_i, got_i)
    np.testing.assert_allclose(want_s, got_s, rtol=1e-5, atol=1e-5)


def test_checkpoint_roundtrip_svdpp_implicit(tmp_path):
    from repro import checkpoint as ckpt_lib

    params = mf.init_params(jax.random.PRNGKey(2), 20, 30, 8, variant="svdpp",
                            global_mean=2.5)
    tree = {
        "params": params,
        "t_p": jnp.float32(0.03),
        "t_q": jnp.float32(0.04),
        "perm": jnp.arange(8, dtype=jnp.int32),
    }
    ckpt_lib.save(str(tmp_path / "ck"), 7, tree)
    loaded, t_p, t_q, perm, meta = load_mf_checkpoint(str(tmp_path / "ck"))
    assert loaded.implicit is not None
    np.testing.assert_array_equal(np.asarray(loaded.implicit),
                                  np.asarray(params.implicit))
    assert float(t_q) == pytest.approx(0.04)
    assert meta["step"] == 7


# ---------------------------------------------------------------------------
# micro-batching plumbing
# ---------------------------------------------------------------------------


def test_bucket_size_quantizes():
    assert [bucket_size(i, 8) for i in (1, 2, 3, 5, 8, 11)] == [1, 2, 4, 8, 8, 8]
    with pytest.raises(ValueError):
        bucket_size(0, 8)


def test_bucket_size_power_of_two_boundaries():
    """Exact powers of two map to themselves; one past a power doubles; the
    max_batch cap wins even when it is not itself a power of two."""
    for exp in range(0, 8):
        n = 1 << exp
        assert bucket_size(n, 256) == n
        assert bucket_size(n + 1, 256) == min(2 * n, 256)
    assert bucket_size(-1 + (1 << 8), 256) == 256
    # a non-power-of-two cap still bounds the bucket
    assert bucket_size(5, 6) == 6
    assert bucket_size(7, 6) == 6
    assert bucket_size(1, 1) == 1
    with pytest.raises(ValueError):
        bucket_size(-3, 8)


def test_lru_cache_evicts_in_order():
    cache = LRUCache(2)
    cache.put(1, "a")
    cache.put(2, "b")
    assert cache.get(1) == "a"      # refreshes 1
    cache.put(3, "c")               # evicts 2
    assert cache.get(2) is None
    assert cache.get(1) == "a" and cache.get(3) == "c"
    assert len(cache) == 2


def test_lru_cache_zero_capacity_disabled():
    """capacity<=0 means 'cache off': puts are dropped, gets miss, and the
    miss counter still ticks (the engine uses 0 for non-SVD++ variants)."""
    cache = LRUCache(0)
    cache.put(1, "a")
    assert cache.get(1) is None
    assert len(cache) == 0
    assert (cache.hits, cache.misses) == (0, 1)


def test_lru_cache_update_existing_key_refreshes():
    """Re-putting a key must update in place (len stays) AND refresh its
    recency, so it survives the next eviction."""
    cache = LRUCache(2)
    cache.put(1, "a")
    cache.put(2, "b")
    cache.put(1, "a2")              # update, not insert
    assert len(cache) == 2
    cache.put(3, "c")               # evicts 2 (1 was refreshed by the put)
    assert cache.get(2) is None
    assert cache.get(1) == "a2"


def test_lru_cache_hit_miss_counters_exact():
    cache = LRUCache(4)
    assert cache.get(9) is None
    cache.put(9, "x")
    assert cache.get(9) == "x"
    assert cache.get(9) == "x"
    assert cache.get(10) is None
    assert (cache.hits, cache.misses) == (2, 2)


def test_microbatcher_rejects_bad_ids_at_submit():
    """A bad user id must fail its own submit, not poison queued tickets."""
    params = mf.init_params(jax.random.PRNGKey(8), 16, 100, 8)
    engine = ServingEngine(params, 0.0, 0.0, use_kernel=False, block_n=64)
    batcher = MicroBatcher(engine, topk=3)
    good = batcher.submit(5)
    with pytest.raises(ValueError):
        batcher.submit(999)
    results = batcher.drain()
    assert good in results and len(results) == 1


def test_microbatcher_validates_topk_at_construction():
    params = mf.init_params(jax.random.PRNGKey(8), 16, 100, 8)
    engine = ServingEngine(params, 0.0, 0.0, use_kernel=False, block_n=64)
    with pytest.raises(ValueError, match="topk"):
        MicroBatcher(engine, topk=101)
    with pytest.raises(ValueError, match="topk"):
        MicroBatcher(engine, topk=0)
    MicroBatcher(engine, topk=100)  # topk == n_items is legal


def test_engine_validates_topk_bounds():
    """topk > n_items (or <= 0) must raise a clear request error up front,
    never a shape failure deep inside the lax.top_k trace — on every entry
    point."""
    params = mf.init_params(jax.random.PRNGKey(9), 12, 64, 8)
    engine = ServingEngine(params, 0.0, 0.0, use_kernel=False, block_n=32)
    for bad in (0, -1, 65):
        with pytest.raises(ValueError, match=r"topk must be in \[1, 64\]"):
            engine.topk([0], bad)
        with pytest.raises(ValueError, match=r"topk must be in \[1, 64\]"):
            engine.topk_sharded([0], bad, mesh=jax.make_mesh((1,), ("model",)))
    s, i = engine.topk([0], 64)  # the boundary itself works
    assert s.shape == (1, 64) and i.shape == (1, 64)


def test_microbatcher_fans_out_duplicates():
    params = mf.init_params(jax.random.PRNGKey(3), 16, 200, 8)
    engine = ServingEngine(params, 0.0, 0.0, use_kernel=False, block_n=64)
    batcher = MicroBatcher(engine, topk=4)
    t1, t2, t3 = batcher.submit(5), batcher.submit(9), batcher.submit(5)
    results = batcher.drain()
    assert set(results) == {t1, t2, t3}
    assert np.array_equal(results[t1][1], results[t3][1])
    _, want_i = engine.topk([9], 4)
    assert np.array_equal(results[t2][1], want_i[0])
    assert batcher.drain() == {}


# ---------------------------------------------------------------------------
# catalog-sharded serving
# ---------------------------------------------------------------------------


def test_sharded_topk_single_device_mesh():
    """The shard_map path on a trivial 1-way mesh must equal the local path
    (exercises specs + the cross-shard merge plumbing without subprocess)."""
    params = mf.init_params(jax.random.PRNGKey(4), 24, 500, 16,
                            variant="bias", global_mean=3.0)
    engine = ServingEngine(params, 0.03, 0.03, use_kernel=False, block_n=128)
    mesh = jax.make_mesh((1,), ("model",))
    users = np.arange(10, dtype=np.int32)
    want_s, want_i = engine.topk(users, 6)
    got_s, got_i = engine.topk_sharded(users, 6, mesh=mesh)
    assert np.array_equal(want_i, got_i)
    np.testing.assert_allclose(want_s, got_s, rtol=1e-5, atol=1e-5)


def test_sharded_topk_2d_mesh_inprocess():
    """User-axis x item-axis (2-D) sharding parity.  Needs >= 4 local
    devices — skipped on the default 1-device run, exercised by the CI
    serving job (XLA_FLAGS=--xla_force_host_platform_device_count=4)."""
    if len(jax.devices()) < 4:
        pytest.skip("needs >= 4 devices (run under the 4-device CI mesh job)")
    params = mf.init_params(jax.random.PRNGKey(11), 32, 900, 16,
                            variant="bias", global_mean=3.0)
    engine = ServingEngine(params, 0.03, 0.03, use_kernel=False, block_n=128)
    users = np.arange(13, dtype=np.int32)  # odd: exercises row-slab padding
    want_s, want_i = engine.topk(users, 6)
    for shape, names in [
        ((4,), ("model",)),            # 1-D: items only (the PR-1 layout)
        ((2, 2), ("data", "model")),   # 2-D: users x items
        ((4, 1), ("data", "model")),   # degenerate: users only
    ]:
        mesh = jax.make_mesh(shape, names)
        got_s, got_i = engine.topk_sharded(users, 6, mesh=mesh)
        assert np.array_equal(want_i, got_i), (shape, names)
        np.testing.assert_allclose(want_s, got_s, rtol=1e-5, atol=1e-5)


@pytest.mark.slow
def test_sharded_topk_multi_device():
    """Real 8-way sharding in a subprocess (device count must be set before
    jax initializes): the 1-D item-only layout, the 2-D user x item layout,
    and a single-user request whose batch must be padded to the user-slab
    multiple — all byte-identical to the local path."""
    code = """
        import numpy as np, jax
        from repro.core import mf
        from repro.serving import ServingEngine
        params = mf.init_params(jax.random.PRNGKey(0), 48, 2100, 24,
                                variant="bias", global_mean=3.0)
        engine = ServingEngine(params, 0.04, 0.04, use_kernel=False,
                               block_n=128)
        users = np.arange(17, dtype=np.int32)
        want_s, want_i = engine.topk(users, 9)
        for shape, names in [((8,), ("model",)),
                             ((2, 4), ("data", "model")),
                             ((4, 2), ("data", "model"))]:
            mesh = jax.make_mesh(shape, names)
            got_s, got_i = engine.topk_sharded(users, 9, mesh=mesh)
            assert np.array_equal(want_i, got_i), (shape, names)
            np.testing.assert_allclose(want_s, got_s, rtol=1e-5, atol=1e-5)
        # bucket 1 < data extent: the engine must pad the user slab
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        one_s, one_i = engine.topk_sharded(users[3:4], 9, mesh=mesh)
        assert np.array_equal(one_i, want_i[3:4])
        np.testing.assert_allclose(one_s, want_s[3:4], rtol=1e-5, atol=1e-5)
        print("SHARDED_TOPK_OK")
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        env=env, capture_output=True, text=True, timeout=600,
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "SHARDED_TOPK_OK" in proc.stdout


def test_sharded_topk_kernel_path_single_device_mesh():
    """Kernel-path (use_kernel=True) scoring under shard_map on a 1-way
    mesh: the per-shard Pallas pruned-topk kernel + cross-shard merge must
    equal the dense oracle exactly."""
    params = mf.init_params(jax.random.PRNGKey(5), 24, 500, 16,
                            variant="bias", global_mean=3.0)
    engine = ServingEngine(params, 0.03, 0.03, use_kernel=True,
                           interpret=True, max_batch=16)
    mesh = jax.make_mesh((1,), ("model",))
    users = np.arange(9, dtype=np.int32)
    want_s, want_i = _dense_oracle(
        params, jnp.asarray(users), 0.03, 0.03, 6
    )
    got_s, got_i = engine.topk_sharded(users, 6, mesh=mesh)
    assert np.array_equal(want_i, got_i)
    np.testing.assert_allclose(want_s, got_s, rtol=1e-5, atol=1e-5)


def test_sharded_topk_kernel_path_4device_mesh():
    """Kernel-path scoring on the forced 4-device CPU mesh (the ROADMAP
    open item): item slabs shard over "model", each shard runs the fused
    pruned-score+top-k kernel in interpret mode, results pin to the dense
    oracle.  Skipped unless the CI serving-mesh job's device count is
    forced (XLA_FLAGS=--xla_force_host_platform_device_count=4)."""
    if len(jax.devices()) < 4:
        pytest.skip("needs >= 4 devices (run under the 4-device CI mesh job)")
    params = mf.init_params(jax.random.PRNGKey(12), 32, 1100, 24,
                            variant="bias", global_mean=3.0)
    engine = ServingEngine(params, 0.04, 0.04, use_kernel=True,
                           interpret=True, max_batch=16)
    users = np.arange(13, dtype=np.int32)  # odd: row-slab padding
    want_s, want_i = _dense_oracle(
        params, jnp.asarray(users), 0.04, 0.04, 7
    )
    for shape, names in [
        ((4,), ("model",)),            # 1-D: item slabs only
        ((2, 2), ("data", "model")),   # 2-D: users x items
        ((4, 1), ("data", "model")),   # degenerate: users only
    ]:
        mesh = jax.make_mesh(shape, names)
        got_s, got_i = engine.topk_sharded(users, 7, mesh=mesh)
        assert np.array_equal(want_i, got_i), (shape, names)
        np.testing.assert_allclose(want_s, got_s, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# latent-axis compaction (ISSUE-7: the FLOP-shedding behind SLO degradation)
# ---------------------------------------------------------------------------


def _grid_params(m, n, k, live_cols, seed=0):
    """Factor tables on the 1/8 grid (exact f32 dot products), with item
    factors zero beyond ``live_cols`` so effective ranks — and therefore the
    compacted latent width — are bounded by construction."""
    rng = np.random.default_rng(seed)
    p = (rng.integers(-16, 17, (m, k)) / 8.0).astype(np.float32)
    q = np.zeros((n, k), np.float32)
    live = (rng.integers(1, 17, (n, live_cols)) / 8.0).astype(np.float32)
    q[:, :live_cols] = live * rng.choice([-1.0, 1.0], (n, live_cols))
    return mf.MFParams(jnp.asarray(p), jnp.asarray(q), None, None, None, None)


def test_compact_latent_bitwise_equal_and_actually_truncates():
    """compact_latent=True must serve byte-identical results (grid inputs
    make exact equality the contract) while the streaming layout really is
    narrower than k."""
    k, live = 32, 12
    params = _grid_params(20, 500, k, live, seed=3)
    t = 0.05  # every |factor| >= 1/8 > t: ranks == live column count
    plain = ServingEngine(params, t, t, use_kernel=False, block_n=128)
    compact = ServingEngine(params, t, t, use_kernel=False, block_n=128,
                            compact_latent=True)
    users = np.arange(20)
    s0, i0 = plain.topk(users, 7)
    s1, i1 = compact.topk(users, 7)
    assert np.array_equal(np.asarray(i0), np.asarray(i1))
    assert np.array_equal(np.asarray(s0), np.asarray(s1))
    q_tiles = compact._snap.stream_layout()[0]
    assert q_tiles.shape[2] == 16   # round8(12) — truncated from 32
    assert plain._snap.stream_layout()[0].shape[2] == k


def test_compact_latent_disabled_at_rate_zero():
    """t == 0 means pruning disabled: compaction must not alter the layout
    and serving stays bitwise dense."""
    params = _grid_params(16, 300, 24, 24, seed=4)
    compact = ServingEngine(params, 0.0, 0.0, use_kernel=False, block_n=64,
                            compact_latent=True)
    assert compact._snap.stream_layout()[0].shape[2] == 24
    want_s, want_i = _dense_oracle(params, jnp.arange(16), 0.0, 0.0, 5)
    got_s, got_i = compact.topk(np.arange(16), 5)
    assert np.array_equal(want_i, np.asarray(got_i))
    assert np.array_equal(want_s, np.asarray(got_s))


def test_compact_swap_rebuilds_when_rank_outgrows_width():
    """An online update that grows a touched row's effective rank past the
    compacted width must force a full layout rebuild (a patch would silently
    truncate the new factors)."""
    k, live = 32, 12
    params = _grid_params(20, 500, k, live, seed=5)
    t = 0.05
    engine = ServingEngine(params, t, t, use_kernel=False, block_n=128,
                           compact_latent=True)
    engine.topk(np.arange(4), 5)  # force the (narrow) layout build
    assert engine._snap.stream_layout()[0].shape[2] == 16
    # touched item now uses ALL k latent columns
    q_new = np.asarray(params.q).copy()
    q_new[7] = (np.arange(k) % 8 + 1) / 8.0
    new_params = params._replace(q=jnp.asarray(q_new))
    engine.swap(new_params, t, t, touched_users=np.array([0]),
                touched_items=np.array([7]))
    # the rebuild widened the layout to cover the grown rank
    assert engine._snap.stream_layout()[0].shape[2] == k
    fresh = ServingEngine(new_params, t, t, use_kernel=False, block_n=128)
    s0, i0 = fresh.topk(np.arange(20), 7)
    s1, i1 = engine.topk(np.arange(20), 7)
    assert np.array_equal(np.asarray(i0), np.asarray(i1))
    assert np.array_equal(np.asarray(s0), np.asarray(s1))


def test_compact_swap_patches_when_rank_fits():
    """Touched rows whose ranks stay inside the compacted width keep the
    incremental patch path — and results stay bitwise right."""
    k, live = 32, 12
    params = _grid_params(20, 500, k, live, seed=6)
    t = 0.05
    engine = ServingEngine(params, t, t, use_kernel=False, block_n=128,
                           compact_latent=True)
    engine.topk(np.arange(4), 5)
    q_new = np.asarray(params.q).copy()
    q_new[3, :10] = (np.arange(10) % 8 + 1) / 8.0  # rank 10 <= width 16
    new_params = params._replace(q=jnp.asarray(q_new))
    engine.swap(new_params, t, t, touched_users=np.array([0]),
                touched_items=np.array([3]))
    assert engine._snap.stream_layout()[0].shape[2] == 16  # still compact
    fresh = ServingEngine(new_params, t, t, use_kernel=False, block_n=128)
    s0, i0 = fresh.topk(np.arange(20), 7)
    s1, i1 = engine.topk(np.arange(20), 7)
    assert np.array_equal(np.asarray(i0), np.asarray(i1))
    assert np.array_equal(np.asarray(s0), np.asarray(s1))
