"""Checkpoint substrate: atomic roundtrip, retention, async, resume-identical
training, elastic reshard."""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import checkpoint as ckpt
from repro.core import DPMFTrainer, TrainConfig
from repro.data import synthetic_ratings, train_test_split


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "a": jnp.asarray(rng.normal(size=(8, 4)).astype(np.float32)),
        "nested": {"b": jnp.arange(5), "c": jnp.float32(3.5)},
    }


def test_roundtrip(tmp_path):
    tree = _tree()
    ckpt.save(str(tmp_path), 7, tree)
    restored, meta = ckpt.restore(str(tmp_path), tree)
    assert meta["step"] == 7
    jax.tree_util.tree_map(
        lambda x, y: np.testing.assert_array_equal(np.asarray(x), np.asarray(y)),
        tree, restored,
    )


def test_keep_n_retention(tmp_path):
    tree = _tree()
    for step in range(6):
        ckpt.save(str(tmp_path), step, tree, keep=3)
    assert ckpt.all_steps(str(tmp_path)) == [3, 4, 5]


def test_restore_shape_mismatch_raises(tmp_path):
    ckpt.save(str(tmp_path), 0, _tree())
    bad = _tree()
    bad["a"] = jnp.zeros((2, 2))
    with pytest.raises(ValueError, match="shape"):
        ckpt.restore(str(tmp_path), bad)


def test_async_checkpointer(tmp_path):
    acp = ckpt.AsyncCheckpointer(str(tmp_path), keep=2)
    for step in range(4):
        acp.save(step, _tree(step))
    acp.wait()
    assert ckpt.all_steps(str(tmp_path)) == [2, 3]
    restored, _ = ckpt.restore(str(tmp_path), _tree())
    np.testing.assert_array_equal(
        np.asarray(restored["a"]), np.asarray(_tree(3)["a"])
    )


def test_trainer_resume_bitwise_identical(tmp_path):
    """Kill-and-restart produces the same params as an uninterrupted run —
    the checkpoint + deterministic-data-order contract."""
    ds = synthetic_ratings(200, 300, 6000, seed=0)
    tr, te = train_test_split(ds, 0.2, seed=0)

    def config(ckpt_dir):
        return TrainConfig(k=16, epochs=6, batch_size=1024, pruning_rate=0.3,
                           seed=0, checkpoint_dir=ckpt_dir,
                           checkpoint_every_epochs=1)

    # uninterrupted
    full = DPMFTrainer(config(None), tr, te)
    full.run()

    # interrupted after 3 epochs, then a fresh process-equivalent resumes
    dir1 = str(tmp_path / "ck")
    first = DPMFTrainer(config(dir1), tr, te)
    for _ in range(3):
        first.run_epoch()
    first.save(first.epoch)
    first._ckpt.wait()

    second = DPMFTrainer(config(dir1), tr, te)
    assert second.maybe_restore()
    assert second.epoch == 3
    second.run()

    np.testing.assert_allclose(
        np.asarray(second.params.p), np.asarray(full.params.p), rtol=0, atol=0
    )
    np.testing.assert_allclose(
        np.asarray(second.params.q), np.asarray(full.params.q), rtol=0, atol=0
    )


def test_elastic_load_reshards(tmp_path):
    """elastic_load applies a caller-supplied shard_fn — mesh-independent
    restore (here: device_put to the single local device)."""
    tree = _tree()
    ckpt.save(str(tmp_path), 1, tree)
    dev = jax.devices()[0]

    def shard_fn(host_tree):
        return jax.tree_util.tree_map(lambda x: jax.device_put(x, dev), host_tree)

    restored, _ = ckpt.elastic_load(str(tmp_path), tree, shard_fn)
    leaf = jax.tree_util.tree_leaves(restored)[0]
    assert leaf.devices() == {dev}


def test_crash_leaves_no_partial_checkpoint(tmp_path, monkeypatch):
    """A writer that dies mid-save must not publish a loadable-but-corrupt
    step (atomic rename contract)."""
    import repro.checkpoint.checkpoint as mod

    real_rename = os.rename
    calls = {"n": 0}

    def exploding_rename(src, dst):
        if "step_" in os.path.basename(dst) and calls["n"] == 0:
            calls["n"] += 1
            raise RuntimeError("simulated preemption mid-publish")
        return real_rename(src, dst)

    monkeypatch.setattr(mod.os, "rename", exploding_rename)
    with pytest.raises(RuntimeError):
        ckpt.save(str(tmp_path), 5, _tree())
    assert ckpt.all_steps(str(tmp_path)) == []  # nothing published
    monkeypatch.undo()
    ckpt.save(str(tmp_path), 5, _tree())
    assert ckpt.all_steps(str(tmp_path)) == [5]
