"""Checkpoint substrate: atomic roundtrip, retention, async, resume-identical
training, elastic reshard."""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import checkpoint as ckpt
from repro.core import DPMFTrainer, TrainConfig
from repro.data import synthetic_ratings, train_test_split


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "a": jnp.asarray(rng.normal(size=(8, 4)).astype(np.float32)),
        "nested": {"b": jnp.arange(5), "c": jnp.float32(3.5)},
    }


def test_roundtrip(tmp_path):
    tree = _tree()
    ckpt.save(str(tmp_path), 7, tree)
    restored, meta = ckpt.restore(str(tmp_path), tree)
    assert meta["step"] == 7
    jax.tree_util.tree_map(
        lambda x, y: np.testing.assert_array_equal(np.asarray(x), np.asarray(y)),
        tree, restored,
    )


def test_keep_n_retention(tmp_path):
    tree = _tree()
    for step in range(6):
        ckpt.save(str(tmp_path), step, tree, keep=3)
    assert ckpt.all_steps(str(tmp_path)) == [3, 4, 5]


def test_restore_shape_mismatch_raises(tmp_path):
    ckpt.save(str(tmp_path), 0, _tree())
    bad = _tree()
    bad["a"] = jnp.zeros((2, 2))
    with pytest.raises(ValueError, match="shape"):
        ckpt.restore(str(tmp_path), bad)


def test_async_checkpointer(tmp_path):
    acp = ckpt.AsyncCheckpointer(str(tmp_path), keep=2)
    for step in range(4):
        acp.save(step, _tree(step))
    acp.wait()
    assert ckpt.all_steps(str(tmp_path)) == [2, 3]
    restored, _ = ckpt.restore(str(tmp_path), _tree())
    np.testing.assert_array_equal(
        np.asarray(restored["a"]), np.asarray(_tree(3)["a"])
    )


def test_trainer_resume_bitwise_identical(tmp_path):
    """Kill-and-restart produces the same params as an uninterrupted run —
    the checkpoint + deterministic-data-order contract."""
    ds = synthetic_ratings(200, 300, 6000, seed=0)
    tr, te = train_test_split(ds, 0.2, seed=0)

    def config(ckpt_dir):
        return TrainConfig(k=16, epochs=6, batch_size=1024, pruning_rate=0.3,
                           seed=0, checkpoint_dir=ckpt_dir,
                           checkpoint_every_epochs=1)

    # uninterrupted
    full = DPMFTrainer(config(None), tr, te)
    full.run()

    # interrupted after 3 epochs, then a fresh process-equivalent resumes
    dir1 = str(tmp_path / "ck")
    first = DPMFTrainer(config(dir1), tr, te)
    for _ in range(3):
        first.run_epoch()
    first.save(first.epoch)
    first._ckpt.wait()

    second = DPMFTrainer(config(dir1), tr, te)
    assert second.maybe_restore()
    assert second.epoch == 3
    second.run()

    np.testing.assert_allclose(
        np.asarray(second.params.p), np.asarray(full.params.p), rtol=0, atol=0
    )
    np.testing.assert_allclose(
        np.asarray(second.params.q), np.asarray(full.params.q), rtol=0, atol=0
    )


def test_elastic_load_reshards(tmp_path):
    """elastic_load applies a caller-supplied shard_fn — mesh-independent
    restore (here: device_put to the single local device)."""
    tree = _tree()
    ckpt.save(str(tmp_path), 1, tree)
    dev = jax.devices()[0]

    def shard_fn(host_tree):
        return jax.tree_util.tree_map(lambda x: jax.device_put(x, dev), host_tree)

    restored, _ = ckpt.elastic_load(str(tmp_path), tree, shard_fn)
    leaf = jax.tree_util.tree_leaves(restored)[0]
    assert leaf.devices() == {dev}


def test_crash_leaves_no_partial_checkpoint(tmp_path, monkeypatch):
    """A writer that dies mid-save must not publish a loadable-but-corrupt
    step (atomic symlink-swap publish contract)."""
    import repro.checkpoint.checkpoint as mod

    real_replace = os.replace
    calls = {"n": 0}

    def exploding_replace(src, dst):
        if ".lnk." in os.path.basename(src) and calls["n"] == 0:
            calls["n"] += 1
            raise RuntimeError("simulated preemption mid-publish")
        return real_replace(src, dst)

    monkeypatch.setattr(mod.os, "replace", exploding_replace)
    with pytest.raises(RuntimeError):
        ckpt.save(str(tmp_path), 5, _tree())
    assert ckpt.all_steps(str(tmp_path)) == []  # nothing published
    monkeypatch.undo()
    ckpt.save(str(tmp_path), 5, _tree())
    assert ckpt.all_steps(str(tmp_path)) == [5]


def test_resave_never_exposes_missing_checkpoint(tmp_path):
    """ISSUE-7 bugfix: the old publish (`rmtree(final)` + `rename`) opened
    a window where the step did not exist.  The symlink-swap publish must
    keep the step loadable at every instant while a writer re-saves it."""
    import threading

    directory = str(tmp_path)
    ckpt.save(directory, 3, _tree(0))
    stop = threading.Event()
    writer_error = []

    def writer():
        i = 1
        try:
            while not stop.is_set():
                ckpt.save(directory, 3, _tree(i % 5), keep=2)
                i += 1
        except BaseException as e:  # surfaced in the main thread
            writer_error.append(e)

    t = threading.Thread(target=writer, daemon=True)
    t.start()
    try:
        deadline = __import__("time").monotonic() + 5.0
        reads = 0
        while __import__("time").monotonic() < deadline:
            arrays, meta = ckpt.load_raw(directory, 3)
            # payload must be complete AND internally consistent
            assert meta["step"] == 3
            assert sorted(arrays) == meta["keys"]
            reads += 1
    finally:
        stop.set()
        t.join(30)
    assert not writer_error, writer_error
    assert reads > 10  # the loop actually raced the writer


def test_gc_sweeps_orphans_but_keeps_live_payloads(tmp_path, monkeypatch):
    import repro.checkpoint.checkpoint as mod

    directory = str(tmp_path)
    ckpt.save(directory, 1, _tree(0))
    # superseded payload: re-save the same step (old payload now orphaned)
    ckpt.save(directory, 1, _tree(1))
    data_dirs = [n for n in os.listdir(directory) if ".data." in n]
    assert len(data_dirs) == 2  # old payload lingers for in-flight readers
    # an eager sweep removes the orphan but never the live payload
    monkeypatch.setattr(mod, "_STALE_SECONDS", -1.0)
    ckpt.save(directory, 2, _tree(2))
    live = {
        os.readlink(os.path.join(directory, f"step_{s:012d}"))
        for s in ckpt.all_steps(directory)
    }
    remaining = {n for n in os.listdir(directory) if ".data." in n}
    assert remaining == live
    restored, meta = ckpt.restore(directory, _tree(), step=1)
    np.testing.assert_array_equal(
        np.asarray(restored["a"]), np.asarray(_tree(1)["a"])
    )


def test_retention_removes_link_and_payload(tmp_path):
    for step in range(5):
        ckpt.save(str(tmp_path), step, _tree(step), keep=2)
    assert ckpt.all_steps(str(tmp_path)) == [3, 4]
    names = os.listdir(str(tmp_path))
    # retired steps leave no symlink behind (their payloads wait for the
    # stale sweep only if a re-save superseded them; retention removes both)
    assert not any(n == "step_000000000000" for n in names)
    for s in (3, 4):
        restored, _ = ckpt.restore(str(tmp_path), _tree(), step=s)
        np.testing.assert_array_equal(
            np.asarray(restored["a"]), np.asarray(_tree(s)["a"])
        )


def test_legacy_real_directory_step_upgrades_to_symlink(tmp_path):
    """Directories written by the pre-symlink layout must re-save cleanly."""
    import json as json_lib

    legacy = tmp_path / "step_000000000007"
    legacy.mkdir()
    arrays = {"root": np.arange(3)}
    np.savez(str(legacy / "arrays.npz"), **arrays)
    (legacy / "metadata.json").write_text(
        json_lib.dumps({"step": 7, "keys": ["root"]})
    )
    assert ckpt.all_steps(str(tmp_path)) == [7]
    ckpt.save(str(tmp_path), 7, _tree(2))
    assert os.path.islink(str(tmp_path / "step_000000000007"))
    restored, _ = ckpt.restore(str(tmp_path), _tree(), step=7)
    np.testing.assert_array_equal(
        np.asarray(restored["a"]), np.asarray(_tree(2)["a"])
    )


def test_corrupt_payload_detected_and_restore_falls_back(tmp_path):
    """Satellite-6: a bit-flipped payload raises CorruptCheckpointError and
    restore(step=None) falls back to the newest intact step."""
    directory = str(tmp_path)
    ckpt.save(directory, 1, _tree(1))
    ckpt.save(directory, 2, _tree(2))
    npz = os.path.join(os.path.realpath(
        os.path.join(directory, "step_000000000002")), "arrays.npz")
    blob = bytearray(open(npz, "rb").read())
    blob[len(blob) // 2] ^= 0xFF
    open(npz, "wb").write(bytes(blob))

    with pytest.raises(ckpt.CorruptCheckpointError):
        ckpt.load_raw(directory, 2)
    # explicit step: the caller asked for those bytes — no silent fallback
    with pytest.raises(ckpt.CorruptCheckpointError):
        ckpt.restore(directory, _tree(), step=2)
    restored, meta = ckpt.restore(directory, _tree())
    assert meta["step"] == 1
    np.testing.assert_array_equal(
        np.asarray(restored["a"]), np.asarray(_tree(1)["a"])
    )


def test_truncated_payload_detected(tmp_path):
    directory = str(tmp_path)
    ckpt.save(directory, 3, _tree())
    npz = os.path.join(os.path.realpath(
        os.path.join(directory, "step_000000000003")), "arrays.npz")
    blob = open(npz, "rb").read()
    open(npz, "wb").write(blob[: len(blob) // 2])
    with pytest.raises(ckpt.CorruptCheckpointError):
        ckpt.restore(directory, _tree())


def test_every_step_corrupt_raises_cleanly(tmp_path):
    directory = str(tmp_path)
    ckpt.save(directory, 1, _tree())
    npz = os.path.join(os.path.realpath(
        os.path.join(directory, "step_000000000001")), "arrays.npz")
    open(npz, "wb").write(b"garbage")
    with pytest.raises(ckpt.CorruptCheckpointError, match="every retained"):
        ckpt.restore(directory, _tree())


def test_injected_fsync_failure_publishes_nothing(tmp_path):
    """Chaos seam: a failed fsync aborts the save before the symlink swap —
    the directory stays exactly as it was (no step, or the previous step)."""
    from repro.testing import faults
    from repro.testing.faults import FaultAction, FaultPlan

    directory = str(tmp_path)
    plan = FaultPlan([FaultAction(site="checkpoint.fsync", op="error", at=0)])
    with faults.installed(plan):
        with pytest.raises(OSError, match="injected fsync"):
            ckpt.save(directory, 5, _tree())
    assert plan.pending == 0
    assert ckpt.all_steps(directory) == []     # nothing published
    ckpt.save(directory, 5, _tree())           # disarmed: save works again
    assert ckpt.all_steps(directory) == [5]
    restored, _ = ckpt.restore(directory, _tree())
    np.testing.assert_array_equal(
        np.asarray(restored["a"]), np.asarray(_tree()["a"])
    )


def test_legacy_checkpoint_without_crc_loads(tmp_path):
    """Pre-CRC metadata (no payload_crc32 key) must keep loading."""
    import json as json_lib

    directory = str(tmp_path)
    ckpt.save(directory, 4, _tree())
    data_dir = os.path.realpath(os.path.join(directory, "step_000000000004"))
    meta_path = os.path.join(data_dir, "metadata.json")
    meta = json_lib.loads(open(meta_path).read())
    meta.pop("payload_crc32")
    open(meta_path, "w").write(json_lib.dumps(meta))
    restored, meta = ckpt.restore(directory, _tree())
    assert meta["step"] == 4
