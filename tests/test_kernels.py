"""Per-kernel validation: shape/dtype sweeps against the pure-jnp oracles
(interpret mode executes the Pallas kernel bodies on CPU)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.ranks import effective_ranks
from repro.kernels import fused_mf_sgd, pruned_matmul, ref, tile_block_stats


def _factors(m, n, k, dtype, seed=0, scale=0.1):
    rng = np.random.default_rng(seed)
    p = rng.normal(0, scale, (m, k)).astype(np.float32)
    q = rng.normal(0, scale, (n, k)).astype(np.float32)
    return jnp.asarray(p, dtype), jnp.asarray(q, dtype)


MATMUL_SHAPES = [
    # (m, n, k, bm, bn, bk) — aligned, ragged, tiny, tall/skinny
    (128, 128, 128, 64, 64, 32),
    (100, 77, 40, 32, 32, 16),
    (1, 300, 50, 8, 128, 64),
    (257, 63, 129, 128, 32, 128),
    (16, 16, 8, 16, 16, 8),
]


@pytest.mark.parametrize("m,n,k,bm,bn,bk", MATMUL_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("t", [0.0, 0.06, 0.5])
def test_pruned_matmul_vs_ref(m, n, k, bm, bn, bk, dtype, t):
    p, q = _factors(m, n, k, dtype)
    r_u = effective_ranks(p, t)
    r_i = effective_ranks(q, t)
    expected = ref.pruned_matmul_ref(p, q, r_u, r_i)
    got = pruned_matmul(p, q, t, t, block_m=bm, block_n=bn, block_k=bk)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(expected),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("b,k,bb", [(64, 32, 16), (33, 50, 8), (7, 16, 16), (256, 128, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("t", [0.0, 0.06])
def test_fused_mf_sgd_vs_ref(b, k, bb, dtype, t):
    rng = np.random.default_rng(1)
    p = jnp.asarray(rng.normal(0, 0.1, (b, k)).astype(np.float32), dtype)
    q = jnp.asarray(rng.normal(0, 0.1, (b, k)).astype(np.float32), dtype)
    r = jnp.asarray(rng.uniform(1, 5, (b,)).astype(np.float32))
    exp_p, exp_q, _, _, exp_e = ref.fused_mf_sgd_ref(
        p, q, r, jnp.float32(t), jnp.float32(t), lr=0.05, lam=0.02
    )
    got_p, got_q, got_bu, got_bi, got_e = fused_mf_sgd(
        p, q, r, t, t, lr=0.05, lam=0.02, block_b=bb
    )
    assert got_bu is None and got_bi is None  # unbiased call
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got_p), np.asarray(exp_p), rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(got_q), np.asarray(exp_q), rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(got_e), np.asarray(exp_e), rtol=tol, atol=tol)


def test_kernel_under_jit_grad_free():
    """Wrappers compose with jit (dry-run-style lowering works)."""
    p, q = _factors(64, 64, 32, jnp.float32)

    @jax.jit
    def f(p, q):
        return pruned_matmul(p, q, 0.06, 0.06, block_m=32, block_n=32, block_k=16)

    out = f(p, q)
    assert out.shape == (64, 64)
    assert bool(jnp.all(jnp.isfinite(out)))


def test_tile_stats_bounds_and_sorted_gain():
    """Tile-level skip fraction is an upper bound on element work; sorting
    the latent axis (rearrangement) tightens per-tile bounds vs a random
    permutation — the mechanism DESIGN.md §2 relies on."""
    rng = np.random.default_rng(2)
    k = 128
    # rank-correlated matrices: significance concentrated at low t (post-Alg.1)
    decay = np.exp(-np.arange(k) / 20.0)
    p = (rng.normal(0, 0.1, (512, k)) * decay).astype(np.float32)
    q = (rng.normal(0, 0.1, (512, k)) * decay).astype(np.float32)
    t = 0.05
    r_u = effective_ranks(jnp.asarray(p), t)
    r_i = effective_ranks(jnp.asarray(q), t)
    tile_sorted, elem = tile_block_stats(r_u, r_i, k, block_m=64, block_n=64, block_k=16)
    assert float(tile_sorted) >= float(elem) - 1e-6

    perm = rng.permutation(k)
    r_u_s = effective_ranks(jnp.asarray(p[:, perm]), t)
    r_i_s = effective_ranks(jnp.asarray(q[:, perm]), t)
    tile_shuffled, _ = tile_block_stats(r_u_s, r_i_s, k, block_m=64, block_n=64, block_k=16)
    assert float(tile_sorted) <= float(tile_shuffled) + 1e-6


def test_pruned_matmul_skips_match_prediction():
    """The kernel's computed output must be identical whether a K-block is
    skipped (bound) or computed-then-masked — checked by comparing against
    a run with pruning disabled but inputs pre-masked."""
    p, q = _factors(128, 128, 64, jnp.float32, seed=3)
    t = 0.08
    r_u = effective_ranks(p, t)
    r_i = effective_ranks(q, t)
    from repro.core.ranks import rank_mask

    p_masked = p * rank_mask(r_u, 64)
    q_masked = q * rank_mask(r_i, 64)
    dense_of_masked = pruned_matmul(
        p_masked, q_masked, 0.0, 0.0, block_m=32, block_n=32, block_k=16
    )
    pruned = pruned_matmul(p, q, t, t, block_m=32, block_n=32, block_k=16)
    np.testing.assert_allclose(
        np.asarray(pruned), np.asarray(dense_of_masked), rtol=1e-5, atol=1e-6
    )
