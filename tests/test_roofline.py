"""Unit tests for the roofline machinery: HLO collective parsing, depth
extrapolation, and term classification."""
import jax.numpy as jnp
import numpy as np

from repro.roofline import analysis, hw


HLO_SAMPLE = """
HloModule test
fused_computation {
  p0 = f32[256,1024]{1,0} parameter(0)
}
ENTRY main {
  %x = f32[256,1024]{1,0} parameter(0)
  %ar = f32[256,1024]{1,0} all-reduce(%x), replica_groups={{0,1}}, to_apply=%add
  %ag = bf16[512,64]{1,0} all-gather(%y), dimensions={0}
  %rs = f32[128,8]{1,0} reduce-scatter(%z), dimensions={0}
  %aa = (f32[16,16]{1,0}, f32[16,16]{1,0}) all-to-all(%a, %b)
  %cp = u8[1000]{0} collective-permute(%c), source_target_pairs={{0,1}}
  %ard = f32[4,4]{1,0} all-reduce-done(%ars)
  %ars = f32[4,4]{1,0} all-reduce-start(%x2)
  %dot1 = f32[8,8]{1,0} dot(%m, %n), lhs_contracting_dims={1}
}
"""


def test_collective_bytes_parser():
    out = analysis.collective_bytes(HLO_SAMPLE)
    assert out["all-reduce_bytes"] == 256 * 1024 * 4 + 4 * 4 * 4  # ar + ar-start
    assert out["all-gather_bytes"] == 512 * 64 * 2
    assert out["reduce-scatter_bytes"] == 128 * 8 * 4
    assert out["all-to-all_bytes"] == 2 * 16 * 16 * 4  # tuple result
    assert out["collective-permute_bytes"] == 1000
    assert out["all-reduce_count"] == 2  # -done excluded, -start counted once
    assert out["total_bytes"] == sum(
        out[f"{k}_bytes"] for k in (
            "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
            "collective-permute",
        )
    )


def test_op_histogram():
    hist = analysis.op_histogram(HLO_SAMPLE)
    assert hist.get("dot") == 1
    assert hist.get("all-gather") == 1


def test_extrapolate_depth_exact():
    """entry=5, body=3 -> c1=8, c2=11; total(L=10) must be 35."""
    c1 = {"cost": {"flops": 8.0, "bytes_accessed": 80.0},
          "collectives": {"total_bytes": 800.0}}
    c2 = {"cost": {"flops": 11.0, "bytes_accessed": 110.0},
          "collectives": {"total_bytes": 1100.0}}
    out = analysis.extrapolate_depth(c1, c2, 10)
    assert out["flops"] == 5 + 10 * 3
    assert out["bytes_accessed"] == 50 + 10 * 30
    assert out["collective_bytes"] == 500 + 10 * 300


def test_roofline_terms_classification():
    chips = 256
    # memory-bound: tiny flops, huge bytes
    t = analysis.roofline_terms(1e12, 1e15, 1e10, chips, model_flops=5e11)
    assert t["dominant"] == "memory"
    assert 0 < t["roofline_fraction"] <= 1.0
    assert abs(t["compute_s"] - 1e12 / (chips * hw.PEAK_BF16_FLOPS)) < 1e-12
    # collective-bound
    t2 = analysis.roofline_terms(1e12, 1e12, 1e15, chips)
    assert t2["dominant"] == "collective"


def test_shape_bytes_dtypes():
    assert analysis._shape_bytes("f32[10,10]") == 400
    assert analysis._shape_bytes("bf16[8]") == 16
    assert analysis._shape_bytes("pred[64]") == 64
    assert analysis._shape_bytes("(f32[2,2], s8[4])") == 16 + 4
    assert analysis._shape_bytes("f32[]") == 4  # scalar
