"""Coverage for the click-batch generators and the SASRec forward pass.

The sequential serving path (``workloads.sequential``) is built on these
two pieces; this module pins their contracts: seeded determinism and
mask/padding/vocab invariants for ``data/clicks.py``, and shape /
pad-zeroing / causality / rank-mask invariants for ``models/recsys.py``'s
SASRec encoder and retrieval.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import clicks
from repro.kernels import ref
from repro.models import recsys

CFG = recsys.SASRecConfig(
    n_items=40, embed_dim=16, n_blocks=2, n_heads=2, seq_len=12
)


# -- click-batch generators -------------------------------------------------

def test_sasrec_batch_shapes_and_vocab_bounds():
    batch = clicks.sasrec_batch(16, seq_len=20, n_items=100, seed=0)
    assert batch["seq"].shape == (16, 20)
    assert batch["pos"].shape == (16, 20)
    assert batch["neg"].shape == (16, 20)
    for key in ("seq", "pos", "neg"):
        assert batch[key].dtype == np.int32
    # ids live in [1, n_items]; 0 is reserved for padding
    assert batch["seq"].max() <= 100 and batch["seq"].min() >= 0
    assert (batch["seq"][batch["seq"] != 0] >= 1).all()
    assert batch["neg"].min() >= 1 and batch["neg"].max() <= 100
    # pos may inherit pad zeros from the shifted seq, but never invents ids
    assert batch["pos"].min() >= 0 and batch["pos"].max() <= 100
    assert (batch["pos"][:, -1] >= 1).all()   # fresh final target


def test_sasrec_batch_prefix_padding_invariant():
    batch = clicks.sasrec_batch(32, seq_len=16, n_items=50, seed=1)
    seq = batch["seq"]
    for row in seq:
        nz = np.flatnonzero(row)
        assert nz.size >= 8                      # lengths >= seq_len // 2
        # zeros form a contiguous prefix: first non-pad onward is all real
        assert (row[nz[0]:] != 0).all()
    # pos is seq shifted left by one over the shared region
    np.testing.assert_array_equal(batch["pos"][:, :-1], seq[:, 1:])


def test_sasrec_batch_deterministic_in_seed():
    a = clicks.sasrec_batch(8, seq_len=10, n_items=30, seed=7)
    b = clicks.sasrec_batch(8, seq_len=10, n_items=30, seed=7)
    c = clicks.sasrec_batch(8, seq_len=10, n_items=30, seed=8)
    for key in ("seq", "pos", "neg"):
        np.testing.assert_array_equal(a[key], b[key])
    assert not np.array_equal(a["seq"], c["seq"])


def test_criteo_batch_contract():
    vocabs = (100, 7, 5000)
    a = clicks.criteo_batch(24, n_dense=5, vocab_sizes=vocabs, seed=3)
    assert a["dense"].shape == (24, 5) and a["dense"].dtype == np.float32
    assert a["sparse"].shape == (24, 3) and a["sparse"].dtype == np.int32
    for field, vocab in enumerate(vocabs):
        col = a["sparse"][:, field]
        assert col.min() >= 0 and col.max() < vocab
    assert set(np.unique(a["label"])) <= {0.0, 1.0}
    b = clicks.criteo_batch(24, n_dense=5, vocab_sizes=vocabs, seed=3)
    np.testing.assert_array_equal(a["sparse"], b["sparse"])
    np.testing.assert_array_equal(a["dense"], b["dense"])


def test_bst_batch_contract():
    a = clicks.bst_batch(12, seq_len=6, n_items=80, n_profile=4, seed=2)
    assert a["hist"].shape == (12, 6)
    assert a["target"].shape == (12,)
    assert a["profile"].shape == (12, 4)
    assert a["hist"].min() >= 1 and a["hist"].max() <= 80
    assert a["target"].min() >= 1 and a["target"].max() <= 80
    assert set(np.unique(a["label"])) <= {0.0, 1.0}
    b = clicks.bst_batch(12, seq_len=6, n_items=80, n_profile=4, seed=2)
    np.testing.assert_array_equal(a["hist"], b["hist"])


def test_fm_batch_contract():
    a = clicks.fm_batch(10, n_fields=4, vocab_per_field=99, seed=0)
    assert a["ids"].shape == (10, 4)
    assert a["ids"].min() >= 0 and a["ids"].max() < 99
    assert set(np.unique(a["label"])) <= {0.0, 1.0}


# -- SASRec forward invariants ----------------------------------------------

@pytest.fixture(scope="module")
def sasrec():
    params = recsys.init_sasrec_params(jax.random.PRNGKey(0), CFG)
    batch = clicks.sasrec_batch(
        6, seq_len=CFG.seq_len, n_items=CFG.n_items, seed=5
    )
    return params, batch


def test_sasrec_encode_shape_and_dtype(sasrec):
    params, batch = sasrec
    h = recsys.sasrec_encode(params, jnp.asarray(batch["seq"]), CFG)
    assert h.shape == (6, CFG.seq_len, CFG.embed_dim)
    assert h.dtype == jnp.float32
    assert np.isfinite(np.asarray(h)).all()


def test_sasrec_encode_zeroes_pad_positions(sasrec):
    params, batch = sasrec
    h = np.asarray(recsys.sasrec_encode(params, jnp.asarray(batch["seq"]), CFG))
    pad = batch["seq"] == 0
    assert pad.any()   # the generator drew at least one short history
    np.testing.assert_array_equal(h[pad], np.zeros_like(h[pad]))
    assert (np.abs(h[~pad]).sum(axis=-1) > 0).all()


def test_sasrec_encode_is_causal(sasrec):
    """Changing the final item must not change any earlier hidden state —
    bitwise: a causally-masked key's score is overwritten before softmax."""
    params, batch = sasrec
    seq = batch["seq"].copy()
    h_before = np.asarray(recsys.sasrec_encode(params, jnp.asarray(seq), CFG))
    seq2 = seq.copy()
    seq2[:, -1] = (seq2[:, -1] % CFG.n_items) + 1   # different valid ids
    h_after = np.asarray(recsys.sasrec_encode(params, jnp.asarray(seq2), CFG))
    np.testing.assert_array_equal(h_before[:, :-1], h_after[:, :-1])
    assert not np.array_equal(h_before[:, -1], h_after[:, -1])


def test_sasrec_retrieval_rank_mask_matches_numpy_oracle(sasrec):
    """t_v > 0 retrieval == dense scores against the suffix-truncated table
    (first |v| < t_v factor onward zeroed), per Algorithm 2."""
    params, batch = sasrec
    seq = jnp.asarray(batch["seq"])
    t_v = 0.01
    got = np.asarray(
        recsys.sasrec_retrieval(params, seq, CFG, t_v, use_kernel=False)
    )
    h = np.asarray(recsys.sasrec_encode(params, seq, CFG)[:, -1])
    table = np.asarray(params["item_embed"])
    ranks = ref._ranks_np(table, t_v)
    assert (ranks < CFG.embed_dim).any()   # the threshold actually bites
    masked = table * ref._rank_mask_np(ranks, CFG.embed_dim)
    np.testing.assert_allclose(got, h @ masked.T, rtol=0, atol=1e-5)


def test_sasrec_retrieval_kernel_matches_xla(sasrec):
    params, batch = sasrec
    seq = jnp.asarray(batch["seq"])
    for t_v in (0.0, 0.01):
        want = recsys.sasrec_retrieval(params, seq, CFG, t_v, use_kernel=False)
        got = recsys.sasrec_retrieval(params, seq, CFG, t_v, use_kernel=True)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=0, atol=1e-5
        )


def test_sasrec_retrieval_candidate_subset(sasrec):
    params, batch = sasrec
    seq = jnp.asarray(batch["seq"])
    cand = jnp.asarray(np.int32([3, 17, 0, 40]))
    full = recsys.sasrec_retrieval(params, seq, CFG, 0.0, use_kernel=False)
    sub = recsys.sasrec_retrieval(
        params, seq, CFG, 0.0, use_kernel=False, cand_ids=cand
    )
    np.testing.assert_array_equal(
        np.asarray(sub), np.asarray(full)[:, np.asarray(cand)]
    )


def test_sasrec_loss_trains(sasrec):
    """The planted-signal batch is learnable: one SGD step lowers the loss."""
    params, batch = sasrec
    dev = {k: jnp.asarray(v) for k, v in batch.items()}
    loss, grads = jax.value_and_grad(recsys.sasrec_loss)(params, dev, CFG)
    assert np.isfinite(float(loss))
    stepped = jax.tree_util.tree_map(lambda p, g: p - 0.1 * g, params, grads)
    assert float(recsys.sasrec_loss(stepped, dev, CFG)) < float(loss)
