"""Incremental pruned updates (`online/updater.py`): parity with the
training step, power-of-two chunking, cold-start growth, drift recalibration."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import mf
from repro.core.trainer import DPMFTrainer, TrainConfig
from repro.data import build_user_history, synthetic_ratings, train_test_split
from repro.online import EventBatch, OnlineUpdater
from repro.optim.optimizers import RowOptimizer


def _batch(users, items, ratings):
    return EventBatch(
        user=np.asarray(users, np.int32),
        item=np.asarray(items, np.int32),
        rating=np.asarray(ratings, np.float32),
    )


def _params(m=30, n=40, k=8, variant="funk", seed=0):
    return mf.init_params(
        jax.random.PRNGKey(seed), m, n, k, variant=variant, global_mean=3.0
    )


# ---------------------------------------------------------------------------
# parity with mf.train_step
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("variant", ["funk", "bias"])
@pytest.mark.parametrize("optimizer", ["sgd", "adagrad"])
def test_apply_matches_train_step(variant, optimizer):
    """One full-bucket micro-batch must be EXACTLY one train_step."""
    params = _params(variant=variant)
    t = 0.05
    opt = RowOptimizer(name=optimizer)
    upd = OnlineUpdater(params, None, t, t, optimizer=optimizer,
                        lr=0.1, lam=0.02, batch_size=8)
    rng = np.random.default_rng(0)
    users = rng.integers(0, 30, 8)
    items = rng.integers(0, 40, 8)
    ratings = rng.uniform(1, 5, 8)
    upd.apply(_batch(users, items, ratings))

    want_params, _, want_metrics = mf.train_step(
        params, mf.init_opt_state(params, opt),
        {"user": jnp.asarray(users, jnp.int32),
         "item": jnp.asarray(items, jnp.int32),
         "rating": jnp.asarray(ratings, jnp.float32)},
        jnp.float32(t), jnp.float32(t), jnp.float32(0.1),
        jnp.ones((8,), jnp.float32), opt=opt, lam=0.02,
    )
    np.testing.assert_array_equal(np.asarray(upd.params.p),
                                  np.asarray(want_params.p))
    np.testing.assert_array_equal(np.asarray(upd.params.q),
                                  np.asarray(want_params.q))
    if variant == "bias":
        np.testing.assert_array_equal(np.asarray(upd.params.user_bias),
                                      np.asarray(want_params.user_bias))


def test_chunk_sizes_binary_decomposition():
    """Chunk shapes are powers of two (bounded jit cache), cover every
    event exactly once, and never need a padding row."""
    assert OnlineUpdater._chunk_sizes(5, 8) == [4, 1]
    assert OnlineUpdater._chunk_sizes(8, 8) == [8]
    assert OnlineUpdater._chunk_sizes(21, 8) == [8, 8, 4, 1]
    assert OnlineUpdater._chunk_sizes(1, 256) == [1]
    for total in range(1, 40):
        sizes = OnlineUpdater._chunk_sizes(total, 8)
        assert sum(sizes) == total
        assert all(s & (s - 1) == 0 and s <= 8 for s in sizes)


@pytest.mark.parametrize("optimizer", ["adagrad", "adam"])
def test_partial_batch_chunking_is_exact(optimizer):
    """A 5-event batch splits into [4, 1] chunks — identical to running
    train_step on those chunks by hand, for EMA-state optimizers too (no
    padding rows exist, so no duplicate-index scatter hazards)."""
    params = _params()
    opt = RowOptimizer(name=optimizer)
    upd = OnlineUpdater(params, None, 0.04, 0.04, optimizer=optimizer,
                        lr=0.1, lam=0.02, batch_size=8)
    rng = np.random.default_rng(1)
    users, items = rng.integers(0, 30, 5), rng.integers(0, 40, 5)
    ratings = rng.uniform(1, 5, 5)
    metrics = upd.apply(_batch(users, items, ratings))

    want_params = params
    want_state = mf.init_opt_state(params, opt)
    want_err = 0.0
    for sl in (slice(0, 4), slice(4, 5)):
        want_params, want_state, m = mf.train_step(
            want_params, want_state,
            {"user": jnp.asarray(users[sl], jnp.int32),
             "item": jnp.asarray(items[sl], jnp.int32),
             "rating": jnp.asarray(ratings[sl], jnp.float32)},
            jnp.float32(0.04), jnp.float32(0.04), jnp.float32(0.1),
            jnp.ones((8,), jnp.float32), opt=opt, lam=0.02,
        )
        want_err += float(m["abs_err"]) * (sl.stop - sl.start)
    np.testing.assert_array_equal(np.asarray(upd.params.p),
                                  np.asarray(want_params.p))
    np.testing.assert_array_equal(np.asarray(upd.params.q),
                                  np.asarray(want_params.q))
    state_key = "acc" if optimizer == "adagrad" else "v"
    np.testing.assert_array_equal(
        np.asarray(upd.opt_state.q[state_key]),
        np.asarray(want_state.q[state_key]),
    )
    assert metrics["abs_err"] == pytest.approx(want_err / 5, rel=1e-6)


def test_train_step_zero_weight_rows_are_inert():
    """The core weighted step (the hook for importance weighting): rows with
    weight 0 contribute nothing to factors, adagrad state, or metrics."""
    params = _params()
    opt = RowOptimizer(name="adagrad")
    rng = np.random.default_rng(1)
    users, items = rng.integers(0, 30, 5), rng.integers(0, 40, 5)
    ratings = rng.uniform(1, 5, 5)
    pad_u = np.pad(users, (0, 3), mode="edge")
    pad_i = np.pad(items, (0, 3), mode="edge")
    pad_r = np.pad(ratings, (0, 3), mode="edge")
    weight = np.asarray([1, 1, 1, 1, 1, 0, 0, 0], np.float32)
    got_params, got_state, got_m = mf.train_step(
        params, mf.init_opt_state(params, opt),
        {"user": jnp.asarray(pad_u, jnp.int32),
         "item": jnp.asarray(pad_i, jnp.int32),
         "rating": jnp.asarray(pad_r, jnp.float32),
         "weight": jnp.asarray(weight)},
        jnp.float32(0.04), jnp.float32(0.04), jnp.float32(0.1),
        jnp.ones((8,), jnp.float32), opt=opt, lam=0.02,
    )
    want_params, want_state, want_m = mf.train_step(
        params, mf.init_opt_state(params, opt),
        {"user": jnp.asarray(users, jnp.int32),
         "item": jnp.asarray(items, jnp.int32),
         "rating": jnp.asarray(ratings, jnp.float32)},
        jnp.float32(0.04), jnp.float32(0.04), jnp.float32(0.1),
        jnp.ones((8,), jnp.float32), opt=opt, lam=0.02,
    )
    np.testing.assert_array_equal(np.asarray(got_params.p),
                                  np.asarray(want_params.p))
    np.testing.assert_array_equal(np.asarray(got_params.q),
                                  np.asarray(want_params.q))
    np.testing.assert_array_equal(np.asarray(got_state.q["acc"]),
                                  np.asarray(want_state.q["acc"]))
    assert float(got_m["abs_err"]) == pytest.approx(
        float(want_m["abs_err"]), rel=1e-6
    )
    assert float(got_m["work_fraction"]) == pytest.approx(
        float(want_m["work_fraction"]), rel=1e-6
    )


def test_svdpp_apply_appends_history_and_touches_implicit():
    ds = synthetic_ratings(20, 25, 300, seed=0)
    params = _params(20, 25, 8, variant="svdpp")
    hist = build_user_history(ds, 4)
    upd = OnlineUpdater(params, None, 0.0, 0.0, user_history=hist,
                        batch_size=8)
    # user 3 rates a brand-new-to-them item
    before = upd.user_history[3].copy()
    new_item = int((set(range(25)) - set(before.tolist())).pop())
    upd.apply(_batch([3], [new_item], [4.0]))
    assert new_item in upd.user_history[3]
    snap = upd.snapshot()
    assert 3 in snap.touched_users
    assert new_item in snap.touched_items
    # every live history item of user 3 had its implicit row updated
    live = [i for i in upd.user_history[3] if i < 25]
    assert set(live) <= set(snap.touched_implicit_items.tolist())


def test_svdpp_requires_history():
    params = _params(variant="svdpp")
    with pytest.raises(ValueError, match="user_history"):
        OnlineUpdater(params, None, 0.0, 0.0)


# ---------------------------------------------------------------------------
# pruning does less work
# ---------------------------------------------------------------------------


def test_pruned_updates_do_less_work():
    params = _params(60, 80, 16, seed=2)
    rng = np.random.default_rng(2)
    users, items = rng.integers(0, 60, 64), rng.integers(0, 80, 64)
    ratings = rng.uniform(1, 5, 64)
    dense = OnlineUpdater(params, None, 0.0, 0.0, batch_size=64)
    m_dense = dense.apply(_batch(users, items, ratings))
    pruned = OnlineUpdater(params, None, 0.08, 0.08, batch_size=64)
    m_pruned = pruned.apply(_batch(users, items, ratings))
    assert m_dense["work_fraction"] == pytest.approx(1.0)
    assert m_pruned["work_fraction"] < 1.0
    assert pruned.mean_work_fraction < 1.0


# ---------------------------------------------------------------------------
# cold start growth
# ---------------------------------------------------------------------------


def test_cold_start_grows_tables_preserving_old_rows():
    params = _params(10, 12, 8, variant="bias")
    upd = OnlineUpdater(params, None, 0.03, 0.03, batch_size=8, seed=5)
    old_p = np.asarray(params.p).copy()
    old_q = np.asarray(params.q).copy()
    # user 14 and item 20 don't exist yet
    upd.apply(_batch([14, 2], [20, 3], [4.0, 2.0]))
    assert upd.num_users == 15 and upd.num_items == 21
    # untouched old rows byte-identical
    untouched_u = [u for u in range(10) if u != 2]
    np.testing.assert_array_equal(np.asarray(upd.params.p)[untouched_u],
                                  old_p[untouched_u])
    untouched_i = [i for i in range(12) if i != 3]
    np.testing.assert_array_equal(np.asarray(upd.params.q)[untouched_i],
                                  old_q[untouched_i])
    # new rows are initialized (not zero) and optimizer state grew with them
    assert np.abs(np.asarray(upd.params.p)[10:]).sum() > 0
    assert upd.opt_state.p["acc"].shape == (15, 8)
    assert upd.opt_state.q["acc"].shape == (21, 8)
    assert upd.params.user_bias.shape == (15, 1)
    assert upd.params.item_bias.shape == (21, 1)
    snap = upd.snapshot()
    # growth stays a row delta (grown rows are all touched); the engine
    # notices the changed catalog geometry by itself, so nothing here needs
    # the full-rebuild hammer
    assert not snap.full_rebuild
    assert {10, 11, 12, 13, 14} <= set(snap.touched_users.tolist())
    assert set(range(12, 21)) <= set(snap.touched_items.tolist())


def test_cold_start_svdpp_remaps_history_sentinel():
    params = _params(8, 10, 8, variant="svdpp")
    # hand-built histories: user 0 has items {1, 2}, everyone else empty —
    # the padding sentinel is the CURRENT catalog size, 10
    hist = np.full((8, 4), 10, np.int32)
    hist[0, :2] = [1, 2]
    n_pad_before = int((hist == 10).sum())
    upd = OnlineUpdater(params, None, 0.0, 0.0, user_history=hist,
                        batch_size=8)
    upd.apply(_batch([0], [12], [3.0]))  # item table grows 10 -> 13
    assert upd.num_items == 13
    assert upd.params.implicit.shape == (14, 8)
    # padding row is still the LAST row and still zero
    np.testing.assert_array_equal(
        np.asarray(upd.params.implicit[13]), np.zeros(8, np.float32)
    )
    # old sentinel 10 remapped to 13 (minus the slot the event filled)
    assert int((upd.user_history == 10).sum()) == 0
    assert int((upd.user_history == 13).sum()) == n_pad_before - 1
    assert 12 in upd.user_history[0]


def test_new_user_is_servable_after_update():
    """Cold-started rows must produce finite, usable predictions."""
    params = _params(10, 12, 8)
    upd = OnlineUpdater(params, None, 0.0, 0.0, batch_size=8, lr=0.2)
    for _ in range(5):
        upd.apply(_batch([11, 11], [0, 5], [5.0, 1.0]))
    pred, _ = mf.predict_pairs(
        upd.params, jnp.asarray([11, 11]), jnp.asarray([0, 5])
    )
    assert np.all(np.isfinite(np.asarray(pred)))
    # repeated 5-star ratings on item 0 vs 1-star on item 5 must separate
    assert float(pred[0]) > float(pred[1])


# ---------------------------------------------------------------------------
# drift recalibration
# ---------------------------------------------------------------------------


def test_recalibrate_preserves_predictions_and_permutes_state():
    ds = synthetic_ratings(60, 80, 4000, seed=0)
    train_ds, test_ds = train_test_split(ds, 0.2, seed=0)
    cfg = TrainConfig(k=12, epochs=3, batch_size=512, pruning_rate=0.3)
    tr = DPMFTrainer(cfg, train_ds, test_ds)
    tr.run()
    upd = OnlineUpdater.from_trainer(tr, batch_size=64)
    u = jnp.arange(20, dtype=jnp.int32)
    i = jnp.arange(20, dtype=jnp.int32)
    before, _ = mf.predict_pairs(upd.params, u, i)  # unpruned predictions
    acc_before = np.asarray(upd.opt_state.q["acc"]).copy()

    report = upd.maybe_recalibrate(force=True)
    assert report is not None and "perm" in report
    after, _ = mf.predict_pairs(upd.params, u, i)
    # the latent permutation preserves every inner product exactly
    np.testing.assert_allclose(np.asarray(before), np.asarray(after),
                               rtol=1e-6, atol=1e-6)
    # optimizer accumulators followed the same permutation
    np.testing.assert_array_equal(
        np.asarray(upd.opt_state.q["acc"]), acc_before[:, report["perm"]]
    )
    snap = upd.snapshot()
    assert snap.full_rebuild


def test_recalibrate_noop_within_budget_and_without_pruning():
    params = _params()
    upd = OnlineUpdater(params, None, 0.0, 0.0, pruning_rate=0.0)
    assert upd.drift() == 0.0
    assert upd.maybe_recalibrate() is None
    # with pruning: thresholds just solved from the current matrices drift ~0
    t_p, t_q = __import__(
        "repro.core.threshold", fromlist=["thresholds_from_matrices"]
    ).thresholds_from_matrices(params.p, params.q, 0.3)
    upd2 = OnlineUpdater(params, None, t_p, t_q, pruning_rate=0.3,
                         drift_budget=0.25)
    assert upd2.drift() < 0.05
    assert upd2.maybe_recalibrate() is None


# ---------------------------------------------------------------------------
# snapshot bookkeeping
# ---------------------------------------------------------------------------


def test_snapshot_resets_touched_sets():
    params = _params()
    upd = OnlineUpdater(params, None, 0.0, 0.0, batch_size=8)
    upd.apply(_batch([1, 2], [3, 4], [3.0, 4.0]))
    snap = upd.snapshot()
    assert set(snap.touched_users.tolist()) == {1, 2}
    assert set(snap.touched_items.tolist()) == {3, 4}
    assert snap.events_seen == 2
    empty = upd.snapshot()
    assert empty.touched_users.size == 0 and empty.touched_items.size == 0
    assert not empty.full_rebuild


def test_train_step_fractional_weight_scales_update_not_prediction():
    """Importance weighting: w=0.5 must halve the (SGD) update while the
    error is still computed against the FULL prediction, and the weighted
    metrics must not deflate."""
    params = _params()
    opt = RowOptimizer(name="sgd")
    u = jnp.asarray([3], jnp.int32)
    i = jnp.asarray([7], jnp.int32)
    r = jnp.asarray([4.0], jnp.float32)
    dim_mask = jnp.ones((8,), jnp.float32)

    full_params, _, full_m = mf.train_step(
        params, mf.init_opt_state(params, opt),
        {"user": u, "item": i, "rating": r},
        jnp.float32(0.0), jnp.float32(0.0), jnp.float32(0.1),
        dim_mask, opt=opt, lam=0.02,
    )
    half_params, _, half_m = mf.train_step(
        params, mf.init_opt_state(params, opt),
        {"user": u, "item": i, "rating": r,
         "weight": jnp.asarray([0.5], jnp.float32)},
        jnp.float32(0.0), jnp.float32(0.0), jnp.float32(0.1),
        dim_mask, opt=opt, lam=0.02,
    )
    full_delta = np.asarray(full_params.p - params.p)
    half_delta = np.asarray(half_params.p - params.p)
    np.testing.assert_allclose(half_delta, 0.5 * full_delta,
                               rtol=1e-6, atol=1e-7)
    # the error itself is against the full prediction -> same |err|, and the
    # weighted mean divides by sum(w)=0.5, not a clamped 1.0
    assert float(half_m["abs_err"]) == pytest.approx(
        float(full_m["abs_err"]), rel=1e-6
    )


def test_mesh_mode_updater_matches_single_device():
    """OnlineUpdater(mesh=...) — the distributed refresh path — matches the
    single-device updater through owner routing, a fractional time-decay
    weight column, and a cold-start growth event (rounded to mesh
    multiples)."""
    import jax

    if jax.device_count() < 4:
        pytest.skip("needs >= 4 devices (run under the 4-device CI mesh job)")
    from repro.distributed.mesh_compat import use_mesh
    from repro.online.stream import EventBatch

    mesh = jax.make_mesh((2, 2), ("data", "model"))
    m, n, k = 16, 8, 12
    params = mf.init_params(jax.random.PRNGKey(0), m, n, k)
    rng = np.random.default_rng(3)
    # 32 events = one power-of-two chunk on the single-device path, so the
    # adagrad accumulator sees identical batch boundaries on both sides
    batches = [
        EventBatch(
            user=rng.integers(0, m, 32).astype(np.int32),
            item=rng.integers(0, n, 32).astype(np.int32),
            rating=rng.uniform(1, 5, 32).astype(np.float32),
            weight=rng.uniform(0.25, 1.0, 32).astype(np.float32),
        )
        for _ in range(3)
    ]
    # cold start past both tables: growth must round to the mesh multiples
    batches.append(EventBatch(
        user=np.asarray([m + 1], np.int32),
        item=np.asarray([n + 2], np.int32),
        rating=np.asarray([4.5], np.float32),
        weight=np.asarray([0.5], np.float32),
    ))

    ref_upd = OnlineUpdater(params, None, 0.05, 0.05, optimizer="adagrad",
                            lr=0.03, batch_size=64, seed=9)
    with use_mesh(mesh):
        mesh_upd = OnlineUpdater(params, None, 0.05, 0.05,
                                 optimizer="adagrad", lr=0.03,
                                 batch_size=64, seed=9, mesh=mesh)
        for b in batches[:3]:
            ref_upd.apply(b)
            mesh_upd.apply(b)
        # exact parity over the routed, fractional-weight updates
        np.testing.assert_allclose(
            np.asarray(mesh_upd.params.p), np.asarray(ref_upd.params.p),
            atol=2e-7, rtol=0,
        )
        np.testing.assert_allclose(
            np.asarray(mesh_upd.params.q), np.asarray(ref_upd.params.q),
            atol=2e-7, rtol=0,
        )
        np.testing.assert_allclose(
            np.asarray(mesh_upd.opt_state.q["acc"]),
            np.asarray(ref_upd.opt_state.q["acc"]), atol=2e-7, rtol=0,
        )
        # cold start: growth rounds to mesh multiples; the rows that existed
        # before the growth event are untouched by it (fresh rows draw
        # different RNG streams on the two sides by design, so only the
        # pre-growth slabs compare)
        pre_p = np.asarray(mesh_upd.params.p)
        pre_q = np.asarray(mesh_upd.params.q)
        mesh_upd.apply(batches[3])
        assert mesh_upd.num_users % 2 == 0 and mesh_upd.num_users >= m + 2
        assert mesh_upd.num_items % 2 == 0 and mesh_upd.num_items >= n + 3
        np.testing.assert_allclose(
            np.asarray(mesh_upd.params.p[:m]), pre_p[:m], atol=2e-7, rtol=0
        )
        np.testing.assert_allclose(
            np.asarray(mesh_upd.params.q[:n]), pre_q[:n], atol=2e-7, rtol=0
        )
        # the grown row actually absorbed the event
        assert bool(np.all(np.isfinite(np.asarray(mesh_upd.params.p))))
        scores_after = mesh_upd.params.p[m + 1] @ mesh_upd.params.q[n + 2]
        assert np.isfinite(float(scores_after))
