"""Prequential test-then-learn evaluation: ordering (an event never scores
itself), exact agreement with an offline recompute, event-granular window
semantics, EMA decay, drift hooks, and cold-start scoring."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import mf, threshold
from repro.data import synthetic_ratings
from repro.eval import PrequentialEvaluator, recalibration_hook
from repro.eval.prequential import _EventWindow
from repro.online import (
    Event,
    EventBatch,
    IteratorSource,
    OnlineUpdater,
    ReplaySource,
    iter_microbatches,
)


def _updater(m=40, n=200, k=8, lr=0.1, variant="funk", seed=0, **kwargs):
    params = mf.init_params(jax.random.PRNGKey(seed), m, n, k,
                            variant=variant, global_mean=3.0)
    if variant == "svdpp":
        kwargs.setdefault(
            "user_history", np.full((m, 4), n, np.int32)  # all padding
        )
    return OnlineUpdater(params, t_p=0.0, t_q=0.0, lr=lr, **kwargs)


def _batch(users, items, ratings):
    return EventBatch(
        user=np.asarray(users, np.int32),
        item=np.asarray(items, np.int32),
        rating=np.asarray(ratings, np.float32),
    )


# ---------------------------------------------------------------------------
# test-then-learn ordering
# ---------------------------------------------------------------------------


def test_event_never_influences_its_own_prediction():
    upd = _updater(lr=0.5)  # big lr: pre/post predictions differ clearly
    ev = PrequentialEvaluator(upd)
    batch = _batch([3], [7], [5.0])
    pre_pred, _ = mf.predict_pairs(
        upd.params, jnp.asarray([3]), jnp.asarray([7]), 0.0, 0.0
    )
    pre_err = abs(5.0 - float(pre_pred[0]))
    metrics = ev.consume(batch)
    assert metrics["mae"] == pytest.approx(pre_err, abs=1e-6)
    # the model DID move — scoring the same event again gives a new error
    post_pred, _ = mf.predict_pairs(
        upd.params, jnp.asarray([3]), jnp.asarray([7]), 0.0, 0.0
    )
    post_err = abs(5.0 - float(post_pred[0]))
    assert abs(post_err - pre_err) > 1e-4
    assert post_err < pre_err  # and toward the rating


def test_svdpp_history_appended_after_scoring():
    """The SVD++ implicit-set append is part of the update: the scored
    prediction must use the PRE-event history (here: empty -> p_u alone)."""
    upd = _updater(variant="svdpp", lr=0.3)
    ev = PrequentialEvaluator(upd)
    u, i = 5, 9
    empty_hist = jnp.asarray(np.full((1, 4), upd.num_items, np.int32))
    pre_pred, _ = mf.predict_pairs(
        upd.params, jnp.asarray([u]), jnp.asarray([i]), 0.0, 0.0,
        hist=empty_hist,
    )
    metrics = ev.consume(_batch([u], [i], [4.0]))
    assert metrics["mae"] == pytest.approx(
        abs(4.0 - float(pre_pred[0])), abs=1e-6
    )
    assert i in upd.user_history[u]  # appended, but only after scoring


def test_cold_start_ids_are_scored_on_fresh_rows():
    upd = _updater(m=10, n=20)
    ev = PrequentialEvaluator(upd)
    metrics = ev.consume(_batch([25], [40], [3.0]))  # both ids unseen
    assert upd.num_users >= 26 and upd.num_items >= 41
    assert np.isfinite(metrics["mae"])
    assert ev.stats.events == 1


# ---------------------------------------------------------------------------
# offline recompute agreement (the acceptance bar: 1e-6)
# ---------------------------------------------------------------------------


def test_cumulative_mae_matches_offline_recompute():
    ds = synthetic_ratings(num_users=30, num_items=120, num_ratings=900,
                           seed=1)
    upd = _updater(m=30, n=120, lr=0.05)
    ev = PrequentialEvaluator(upd, window=64)
    abs_sum = sq_sum = 0.0
    count = 0
    for batch in iter_microbatches(ReplaySource(ds, epochs=1), 64):
        # offline recompute: same pruned forward pass, captured BEFORE the
        # updater applies the batch
        pred, _ = mf.predict_pairs(
            upd.params, jnp.asarray(batch.user), jnp.asarray(batch.item),
            upd.t_p, upd.t_q,
        )
        err = np.asarray(batch.rating, np.float64) - np.asarray(
            pred, np.float64
        )
        abs_sum += float(np.abs(err).sum())
        sq_sum += float((err * err).sum())
        count += len(batch)
        ev.consume(batch)
    stats = ev.stats
    assert stats.events == count == len(ds)
    assert stats.mae == pytest.approx(abs_sum / count, abs=1e-6)
    assert stats.rmse == pytest.approx(np.sqrt(sq_sum / count), abs=1e-6)


def test_score_only_does_not_move_the_model():
    upd = _updater()
    ev = PrequentialEvaluator(upd)
    p_before = np.asarray(upd.params.p).copy()
    ev.score(_batch([1, 2], [3, 4], [5.0, 1.0]))
    np.testing.assert_array_equal(p_before, np.asarray(upd.params.p))
    assert ev.stats.events == 2
    assert upd.events_seen == 0


# ---------------------------------------------------------------------------
# window + decay semantics
# ---------------------------------------------------------------------------


def test_event_window_is_event_granular():
    win = _EventWindow(5)
    win.extend(np.asarray([1.0, 2.0, 3.0]), np.zeros(3))
    assert win.count == 3
    assert win.means()[0] == pytest.approx(2.0)
    win.extend(np.asarray([4.0, 5.0, 6.0]), np.zeros(3))  # evicts 1.0
    assert win.count == 5
    assert win.means()[0] == pytest.approx((2 + 3 + 4 + 5 + 6) / 5)
    win.extend(np.arange(10, 17, dtype=np.float64), np.zeros(7))  # overflow
    assert win.count == 5
    assert win.means()[0] == pytest.approx((12 + 13 + 14 + 15 + 16) / 5)


def test_window_forgets_old_errors_but_cumulative_remembers():
    # lr=0: the model never moves, so errors are fully controlled by the
    # ratings we synthesize from the model's own predictions
    upd = _updater(lr=0.0)
    ev = PrequentialEvaluator(upd, window=50, half_life_events=10.0)

    def stream(n_events, offset, seed):
        rng = np.random.default_rng(seed)
        users = rng.integers(0, upd.num_users, n_events)
        items = rng.integers(0, upd.num_items, n_events)
        pred, _ = mf.predict_pairs(
            upd.params, jnp.asarray(users, dtype=jnp.int32),
            jnp.asarray(items, dtype=jnp.int32), 0.0, 0.0,
        )
        return _batch(users, items, np.asarray(pred) + offset)

    for _ in range(4):
        ev.consume(stream(25, 2.0, 7))     # phase 1: |err| = 2 exactly
    assert ev.stats.window_mae == pytest.approx(2.0, abs=1e-5)
    ev.consume(stream(25, 0.0, 8))         # phase 2: 50 zero-error events
    ev.consume(stream(25, 0.0, 9))
    stats = ev.stats
    assert stats.window_events == 50
    assert stats.window_mae == pytest.approx(0.0, abs=1e-6)   # window forgot
    assert stats.mae == pytest.approx(2.0 * 100 / 150, abs=1e-5)  # lifetime
    # EMA with a 10-event half-life has decayed ~2^-5 over phase 2 but not
    # to zero — strictly between the window and the cumulative view
    assert 0.0 < stats.ema_mae < stats.mae


def test_ema_half_life():
    upd = _updater(lr=0.0)
    ev = PrequentialEvaluator(upd, half_life_events=100.0)
    # constant-error stream: every view must agree (bias-corrected EMA too)
    pred, _ = mf.predict_pairs(
        upd.params, jnp.asarray([0]), jnp.asarray([0]), 0.0, 0.0
    )
    batch = _batch([0], [0], [float(pred[0]) + 1.5])
    for _ in range(30):
        ev.score(batch)
    assert ev.stats.ema_mae == pytest.approx(1.5, abs=1e-6)
    assert ev.stats.mae == pytest.approx(1.5, abs=1e-6)


def test_bad_constructor_args():
    upd = _updater()
    with pytest.raises(ValueError):
        PrequentialEvaluator(upd, window=0)
    with pytest.raises(ValueError):
        PrequentialEvaluator(upd, half_life_events=0.0)


# ---------------------------------------------------------------------------
# drift hooks
# ---------------------------------------------------------------------------


def test_recalibration_hook_fires_on_degradation():
    m, n, k = 60, 300, 8
    params = mf.init_params(jax.random.PRNGKey(3), m, n, k,
                            init_method="libmf")
    rate = 0.3
    t_p, t_q = threshold.thresholds_from_matrices(params.p, params.q, rate)
    upd = OnlineUpdater(params, t_p=t_p, t_q=t_q, lr=0.0,
                        pruning_rate=rate)
    ev = PrequentialEvaluator(upd, window=20, half_life_events=200.0)
    hook = recalibration_hook(upd, degradation=1.5, min_events=40,
                              cooldown_events=10)
    ev.add_drift_hook(hook)

    def batch(offset, seed):
        rng = np.random.default_rng(seed)
        users = rng.integers(0, m, 20)
        items = rng.integers(0, n, 20)
        pred, _ = mf.predict_pairs(
            upd.params, jnp.asarray(users, dtype=jnp.int32),
            jnp.asarray(items, dtype=jnp.int32), upd.t_p, upd.t_q,
        )
        return _batch(users, items, np.asarray(pred) + offset)

    for s in range(4):
        ev.consume(batch(0.1, s))     # healthy baseline
    assert not hook.fired
    ev.consume(batch(5.0, 99))        # windowed error spikes 50x
    assert hook.fired                  # recalibration keyed off prequential
    snap = upd.snapshot()
    assert snap.full_rebuild           # thresholds re-solved + rearranged


def test_hooks_called_with_stats_after_each_consume():
    upd = _updater()
    seen = []
    ev = PrequentialEvaluator(upd, drift_hooks=[lambda s: seen.append(s)])
    ev.consume(_batch([0, 1], [2, 3], [3.0, 4.0]))
    ev.consume(_batch([2], [4], [2.0]))
    assert [s.events for s in seen] == [2, 3]


# ---------------------------------------------------------------------------
# stream plumbing
# ---------------------------------------------------------------------------


def test_consume_reports_update_and_eval_metrics():
    upd = _updater()
    ev = PrequentialEvaluator(upd)
    source = IteratorSource([Event(1, 2, 4.0), Event(3, 4, 2.0)])
    for batch in iter_microbatches(source, 2):
        metrics = ev.consume(batch)
    assert {"mae", "rmse", "events", "abs_err", "work_fraction"} <= set(
        metrics
    )
    assert upd.events_seen == 2
