"""Event sources + micro-batch accumulation (`online/stream.py`)."""
import itertools

import numpy as np
import pytest

from repro.data import synthetic_ratings
from repro.online import (
    Event,
    EventBatch,
    IteratorSource,
    PoissonSource,
    ReplaySource,
    iter_microbatches,
)


def test_replay_source_in_order_once():
    ds = synthetic_ratings(20, 30, 100, seed=0)
    events = list(ReplaySource(ds))
    assert len(events) == 100
    assert [e.user for e in events] == list(ds.user)
    assert [e.item for e in events] == list(ds.item)
    np.testing.assert_allclose([e.rating for e in events], ds.rating)


def test_replay_source_shuffle_deterministic_per_epoch():
    ds = synthetic_ratings(20, 30, 60, seed=0)
    a = [e.user for e in ReplaySource(ds, epochs=2, shuffle=True, seed=3)]
    b = [e.user for e in ReplaySource(ds, epochs=2, shuffle=True, seed=3)]
    assert a == b                      # same seed, same stream
    assert len(a) == 120
    assert a[:60] != a[60:120]         # fresh permutation per pass
    c = [e.user for e in ReplaySource(ds, epochs=1, shuffle=True, seed=4)]
    assert c != a[:60]                 # seed changes the order


def test_poisson_source_deterministic_and_bounded():
    src = PoissonSource(50, 200, rate=100.0, seed=1)
    a = list(itertools.islice(iter(src), 300))
    b = list(itertools.islice(iter(src), 300))
    assert a == b
    assert all(0 <= e.user < 50 for e in a)
    assert all(0 <= e.item < 200 for e in a)
    assert all(1.0 <= e.rating <= 5.0 for e in a)
    ts = [e.timestamp for e in a]
    assert ts == sorted(ts) and ts[0] > 0
    # mean inter-arrival ~ 1/rate
    assert 0.5 / 100 < ts[-1] / len(ts) < 2.0 / 100


def test_poisson_source_cold_start_ids_extend_frontier():
    src = PoissonSource(10, 20, rate=10.0, seed=0,
                        new_user_prob=0.2, new_item_prob=0.2)
    events = list(itertools.islice(iter(src), 400))
    max_u = max(e.user for e in events)
    max_i = max(e.item for e in events)
    assert max_u >= 10 and max_i >= 20  # new ids appeared
    # new ids are introduced densely: one past the frontier, never sparse
    users = sorted({e.user for e in events if e.user >= 10})
    assert users == list(range(10, 10 + len(users)))
    items = sorted({e.item for e in events if e.item >= 20})
    assert items == list(range(20, 20 + len(items)))


def test_iterator_source_tuples_and_events():
    rows = [(1, 2, 3.0), Event(4, 5, 1.5, 9.0), (6, 7, 2.0)]
    out = list(IteratorSource(rows))
    assert [(e.user, e.item, e.rating) for e in out] == [
        (1, 2, 3.0), (4, 5, 1.5), (6, 7, 2.0)
    ]


def test_microbatches_sizes_and_tail_flush():
    ds = synthetic_ratings(20, 30, 100, seed=0)
    batches = list(iter_microbatches(ReplaySource(ds), 32))
    assert [len(b) for b in batches] == [32, 32, 32, 4]
    joined = np.concatenate([b.user for b in batches])
    np.testing.assert_array_equal(joined, ds.user)
    assert all(isinstance(b, EventBatch) for b in batches)


def test_microbatches_max_events_bounds_infinite_source():
    src = PoissonSource(10, 20, rate=10.0, seed=0)
    batches = list(iter_microbatches(src, 16, max_events=40))
    assert [len(b) for b in batches] == [16, 16, 8]


def test_microbatches_span_flushes_early():
    # 1 event/s simulated clock; a 2.5 s span bound closes batches at 3
    events = [Event(0, 0, 1.0, float(t)) for t in range(10)]
    batches = list(iter_microbatches(events, 100, max_batch_span_s=2.5))
    assert [len(b) for b in batches] == [3, 3, 3, 1]


def test_microbatches_validates_batch_size():
    with pytest.raises(ValueError):
        list(iter_microbatches([], 0))


def test_microbatches_half_life_weights():
    """half_life_s attaches recency weights: 0.5 per half-life of age
    relative to the newest event; the newest always carries 1.0."""
    events = [Event(0, 0, 1.0, float(t)) for t in (0.0, 10.0, 20.0)]
    (batch,) = list(iter_microbatches(events, 8, half_life_s=10.0))
    np.testing.assert_allclose(batch.weight, [0.25, 0.5, 1.0])
    # without the flag there is no weight column at all
    (plain,) = list(iter_microbatches(events, 8))
    assert plain.weight is None
    with pytest.raises(ValueError):
        EventBatch.from_events(events, half_life_s=0.0)


def test_time_decayed_events_move_factors_less():
    """The decayed weight flows through the updater into train_step's update
    gate: replaying the same event with an older timestamp moves the factor
    rows strictly less (prediction/error stay full-model, so the step
    direction is identical)."""
    import jax
    import jax.numpy as jnp

    from repro.core import mf
    from repro.online import OnlineUpdater

    params = mf.init_params(jax.random.PRNGKey(0), 8, 8, 12)

    def delta_for(age_s):
        events = [Event(3, 4, 5.0, 100.0 - age_s), Event(0, 1, 1.0, 100.0)]
        (batch,) = list(
            iter_microbatches(events, 8, half_life_s=30.0)
        )
        upd = OnlineUpdater(params, None, 0.0, 0.0, optimizer="sgd",
                            lr=0.05, seed=0)
        upd.apply(batch)
        return float(jnp.sum(jnp.abs(upd.params.p[3] - params.p[3])))

    fresh, stale, ancient = delta_for(0.0), delta_for(30.0), delta_for(90.0)
    assert fresh > stale > ancient > 0.0
    np.testing.assert_allclose(stale / fresh, 0.5, rtol=1e-4)
