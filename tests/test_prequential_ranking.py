"""Rating-free streams and prequential *ranking* evaluation.

Pins the satellite contracts: rating-free events flow through the stream
plumbing as ``rating=None`` batches, every rating-driven consumer rejects
them with the typed :class:`RatingFreeStreamError` (not a numpy crash),
and :class:`PrequentialRankingEvaluator` answers "was the clicked item in
the top-k we actually served?" strictly test-then-learn, segmented by
new/established user cohorts.
"""
import functools

import jax
import numpy as np
import pytest

from repro.core import mf
from repro.eval import (
    PrequentialEvaluator,
    PrequentialRankingEvaluator,
    dense_topk,
)
from repro.eval.prequential_ranking import _HitWindow
from repro.online import OnlineUpdater, RatingFreeStreamError
from repro.online.stream import Event, EventBatch, IteratorSource
from repro.serving.engine import ServingEngine
from repro.workloads import implicit_event_batch, strip_ratings

M, N, K = 20, 30, 8


def _params(seed=0):
    return mf.init_params(jax.random.PRNGKey(seed), M, N, K, variant="funk")


def _updater(seed=0, **kw):
    kw.setdefault("optimizer", "sgd")
    kw.setdefault("lr", 0.05)
    return OnlineUpdater(_params(seed), **kw)


# -- rating-free stream plumbing -------------------------------------------

def test_rating_free_events_make_rating_free_batches():
    batch = EventBatch.from_events(
        [Event(0, 1, None, 0.0), Event(2, 3, None, 1.0)]
    )
    assert batch.rating is None
    np.testing.assert_array_equal(batch.user, np.int32([0, 2]))
    # empty batches stay rated-shaped (no consumer branches on them)
    assert EventBatch.from_events([]).rating is not None


def test_mixed_rated_and_rating_free_events_rejected():
    with pytest.raises(ValueError, match="mix"):
        EventBatch.from_events([Event(0, 1, 4.0, 0.0), Event(1, 2, None, 1.0)])


def test_iterator_source_two_tuples_are_clicks():
    events = list(IteratorSource([(3, 7), (1, 2, 5.0)]))
    assert events[0].rating is None
    assert events[1].rating == 5.0


def test_strip_ratings_views_rated_stream_as_clicks():
    events = list(
        strip_ratings(IteratorSource([(1, 2, 5.0), (3, 4, 1.0)]))
    )
    assert [e.rating for e in events] == [None, None]
    assert [(e.user, e.item) for e in events] == [(1, 2), (3, 4)]
    assert events[1].timestamp == 1.0   # clock preserved


def test_rating_free_half_life_weights_still_apply():
    batch = EventBatch.from_events(
        [Event(0, 1, None, 0.0), Event(0, 2, None, 10.0)], half_life_s=10.0
    )
    assert batch.rating is None
    np.testing.assert_allclose(batch.weight, np.float32([0.5, 1.0]))


# -- typed rejection by rating-driven consumers ----------------------------

def _click_batch(n=4, seed=0):
    rng = np.random.default_rng(seed)
    return EventBatch.from_events(
        [
            Event(int(u), int(i), None, float(t))
            for t, (u, i) in enumerate(
                zip(rng.integers(0, M, n), rng.integers(0, N, n))
            )
        ]
    )


def test_online_updater_rejects_rating_free_batches():
    upd = _updater()
    before = np.asarray(upd.params.p).copy()
    with pytest.raises(RatingFreeStreamError, match="implicit_event_batch"):
        upd.apply(_click_batch())
    np.testing.assert_array_equal(np.asarray(upd.params.p), before)
    assert upd.events_seen == 0


def test_prequential_evaluator_rejects_rating_free_batches():
    ev = PrequentialEvaluator(_updater())
    with pytest.raises(
        RatingFreeStreamError, match="PrequentialRankingEvaluator"
    ):
        ev.score(_click_batch())
    assert ev.events == 0


def test_rating_free_error_is_a_type_error():
    # callers catching TypeError (the old failure mode's class) still work
    assert issubclass(RatingFreeStreamError, TypeError)


# -- ranking evaluator: hand-computed hits ---------------------------------

def _fixed_rank_fn(table):
    """rank_fn returning canned top-k rows per user id."""

    def rank(users, topk):
        idx = np.asarray([table[int(u)][:topk] for u in users], np.int32)
        return np.zeros_like(idx, np.float32), idx

    return rank


def test_hit_and_mrr_hand_computed():
    table = {0: [4, 9, 2], 1: [7, 8, 3], 2: [5, 1, 0]}
    ev = PrequentialRankingEvaluator(
        rank_fn=_fixed_rank_fn(table), topk=3, window=8
    )
    batch = EventBatch.from_events([
        Event(0, 9, None, 0.0),    # hit at position 2 -> rr 1/2
        Event(1, 3, None, 1.0),    # hit at position 3 -> rr 1/3
        Event(2, 8, None, 2.0),    # miss
    ])
    metrics = ev.score(batch)
    assert metrics["events"] == 3
    np.testing.assert_allclose(metrics["hit_rate"], 2 / 3)
    np.testing.assert_allclose(metrics["mrr"], (0.5 + 1 / 3) / 3)
    stats = ev.stats
    assert stats.events == 3 and stats.topk == 3
    np.testing.assert_allclose(stats.hit_rate, 2 / 3)
    np.testing.assert_allclose(stats.window_hit_rate, 2 / 3)
    flat = stats.as_dict()
    assert flat["new_events"] == 3 and flat["established_events"] == 0


def test_cohort_segmentation_pre_batch_attribution():
    table = {5: [1, 2, 3]}
    ev = PrequentialRankingEvaluator(
        rank_fn=_fixed_rank_fn(table), topk=3, new_user_events=2
    )
    # same user 4x in stream order: events 1-2 are "new", 3-4 "established";
    # hits: item 1 (hit), 9 (miss), 2 (hit), 3 (hit)
    batch = EventBatch.from_events([
        Event(5, 1, None, 0.0), Event(5, 9, None, 1.0),
        Event(5, 2, None, 2.0), Event(5, 3, None, 3.0),
    ])
    ev.score(batch)
    cohorts = ev.stats.cohorts
    assert cohorts["new"]["events"] == 2
    np.testing.assert_allclose(cohorts["new"]["hit_rate"], 0.5)
    assert cohorts["established"]["events"] == 2
    np.testing.assert_allclose(cohorts["established"]["hit_rate"], 1.0)


def test_unservable_users_and_items_count_as_misses():
    upd = _updater()
    ev = PrequentialRankingEvaluator(upd, topk=5)
    batch = EventBatch.from_events([
        Event(M + 50, 0, None, 0.0),    # user the serving side never saw
        Event(0, N + 50, None, 1.0),    # item outside the catalog
    ])
    metrics = ev.score(batch)
    assert metrics["hit_rate"] == 0.0 and metrics["events"] == 2
    # and scoring them did NOT grow the updater's tables (no update ran)
    assert upd.params.p.shape[0] == M


def test_score_never_reads_the_rating_column():
    upd = _updater(seed=3)
    rated = EventBatch.from_events(
        [Event(1, 2, 5.0, 0.0), Event(3, 4, 1.0, 1.0)]
    )
    clicks = EventBatch.from_events(
        [Event(1, 2, None, 0.0), Event(3, 4, None, 1.0)]
    )
    a = PrequentialRankingEvaluator(upd, topk=4).score(rated)
    b = PrequentialRankingEvaluator(upd, topk=4).score(clicks)
    assert a == b


# -- test-then-learn ordering ----------------------------------------------

def test_scoring_happens_strictly_before_update():
    calls = []

    class StubUpdater:
        def apply(self, batch):
            calls.append(("apply", len(batch)))
            return {"abs_err": 0.0}

    def rank(users, topk):
        calls.append(("rank", len(users)))
        return (
            np.zeros((len(users), topk), np.float32),
            np.zeros((len(users), topk), np.int32),
        )

    def rated(n, seed):
        rng = np.random.default_rng(seed)
        return EventBatch.from_events([
            Event(int(u), int(i), 1.0, float(t))
            for t, (u, i) in enumerate(
                zip(rng.integers(0, M, n), rng.integers(0, N, n))
            )
        ])

    ev = PrequentialRankingEvaluator(StubUpdater(), rank_fn=rank, topk=2)
    ev.consume(rated(3, seed=1))
    ev.consume(rated(2, seed=2))
    assert calls == [("rank", 3), ("apply", 3), ("rank", 2), ("apply", 2)]


def test_consume_rating_free_without_update_fn_scores_then_raises():
    upd = _updater()
    ev = PrequentialRankingEvaluator(upd, topk=3)
    with pytest.raises(RatingFreeStreamError, match="update_fn"):
        ev.consume(_click_batch())
    assert ev.events == 4          # the evaluation side still landed
    assert upd.events_seen == 0    # the update side did not


def test_consume_with_update_fn_trains_on_converted_clicks():
    upd = _updater()
    ev = PrequentialRankingEvaluator(
        upd, topk=3,
        update_fn=functools.partial(
            implicit_event_batch, num_items=N, alpha=4.0, negatives=2,
            rng=np.random.default_rng(0),
        ),
    )
    before = np.asarray(upd.params.p).copy()
    metrics = ev.consume(_click_batch(4))
    assert metrics["events"] == 4
    assert upd.events_seen == 4 * 3   # positives + 2 negatives each
    assert not np.array_equal(np.asarray(upd.params.p), before)
    # second batch: the model scored it BEFORE this batch's own update
    ev.consume(_click_batch(4, seed=9))
    assert ev.stats.events == 8


# -- ranking sources agree --------------------------------------------------

def test_engine_and_updater_paths_agree_at_threshold_zero():
    params = _params(7)
    upd = OnlineUpdater(params, optimizer="sgd")
    engine = ServingEngine(params, 0.0, 0.0)
    batch = _click_batch(6, seed=4)
    a = PrequentialRankingEvaluator(upd, topk=5).score(batch)
    b = PrequentialRankingEvaluator(engine=engine, topk=5).score(batch)
    assert a == b


def test_updater_path_uses_live_thresholds():
    params = _params(8)
    upd = OnlineUpdater(params, t_p=0.08, t_q=0.08, optimizer="sgd")
    ev = PrequentialRankingEvaluator(upd, topk=5)
    users = np.arange(6, dtype=np.int32)
    want_scores, want_idx = dense_topk(
        params, users, 5, t_p=upd.t_p, t_q=upd.t_q, hist=None
    )
    got_idx = ev._rank(users)
    np.testing.assert_array_equal(got_idx, np.asarray(want_idx))


# -- plumbing edge cases ----------------------------------------------------

def test_empty_batch_is_a_noop():
    ev = PrequentialRankingEvaluator(_updater(), topk=3)
    metrics = ev.score(EventBatch.from_events([]))
    assert metrics["events"] == 0 and np.isnan(metrics["hit_rate"])
    assert ev.events == 0


def test_hit_window_overflow_keeps_newest():
    win = _HitWindow(4)
    win.extend(np.float64([1, 1, 1]))
    win.extend(np.float64([0, 0, 0, 0, 0, 1]))   # overflows capacity
    np.testing.assert_allclose(win.mean(), 0.25)
    assert win.count == 4


def test_constructor_validation():
    with pytest.raises(ValueError, match="ranking source"):
        PrequentialRankingEvaluator()
    with pytest.raises(ValueError, match="topk"):
        PrequentialRankingEvaluator(_updater(), topk=0)
    with pytest.raises(ValueError, match="new_user_events"):
        PrequentialRankingEvaluator(_updater(), new_user_events=0)
    with pytest.raises(ValueError, match="window"):
        PrequentialRankingEvaluator(_updater(), window=0)
