"""Serving fleet: delta replication, version gating, routing, drain.

Covers the ISSUE-6 acceptance surface:

* lossless codec round trips bit-exact (scalars, empty, f32/i32, big rows);
* replication edge cases — duplicate delivery, out-of-order delivery,
  late join via ``kind=full`` + ``fold_deltas`` — all ending bitwise
  identical to a fresh single engine on the published params;
* rolling hot-swap across replicas under concurrent load: zero dropped
  requests, every replica converges to the published version;
* cache-affinity routing: repeat users stick to their replica, background
  priority traffic is never pinned, overloaded pins spill;
* graceful drain regression: ``engine.stop()`` under submit load strands
  no future and rejects (not resurrects) concurrent submits;
* a ``multiprocessing`` ProcessReplica smoke (marked slow).
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import Future

import numpy as np
import pytest

import jax

from repro.core import mf
from repro.distributed.compression import (
    CompressedArray,
    compress_array,
    decompress_array,
)
from repro.online import (
    EventBatch,
    OnlineUpdater,
    SnapshotPublisher,
    fold_deltas,
)
from repro.serving import ServingEngine, load_mf_checkpoint
from repro.serving.fleet import (
    EngineDeltaSink,
    LocalReplica,
    ProcessReplica,
    Router,
    ServingFleet,
    VersionGate,
    apply_message,
    make_message,
    state_from_message,
    state_message,
)


def _params(m=40, n=300, k=8, variant="bias", seed=0):
    return mf.init_params(
        jax.random.PRNGKey(seed), m, n, k, variant=variant,
        **({"global_mean": 3.5} if variant != "funk" else {}),
    )


def _batch(rng, m, n, size=24):
    return EventBatch(
        user=rng.integers(0, m, size).astype(np.int32),
        item=rng.integers(0, n, size).astype(np.int32),
        rating=rng.uniform(1, 5, size).astype(np.float32),
    )


def _messages(n_publishes=3, m=40, n=300, seed=0, full_at=()):  # helper
    """Drive an updater through ``n_publishes`` snapshots and return the
    (messages, final updater) — the canonical wire sequence for gate tests."""
    rng = np.random.default_rng(seed)
    params = _params(m, n)
    upd = OnlineUpdater(params, None, 0.0, 0.0, batch_size=32, seed=seed)
    msgs = []
    for v in range(1, n_publishes + 1):
        upd.apply(_batch(rng, m, n))
        msgs.append(make_message(
            upd.snapshot(), v, v - 1, full=(v in full_at), compress=True,
        ))
    return msgs, upd


def _assert_bitwise(engine_like, upd, topk=5):
    ref = ServingEngine(upd.params, upd.t_p, upd.t_q)
    users = np.arange(ref.num_users)
    s_ref, i_ref = ref.topk(users, topk)
    s, i = engine_like.topk(users, topk)
    np.testing.assert_array_equal(np.asarray(s), np.asarray(s_ref))
    np.testing.assert_array_equal(np.asarray(i), np.asarray(i_ref))


# ---------------------------------------------------------------------------
# lossless codec
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arr", [
    np.float32(3.5),
    np.zeros((), np.float64),
    np.empty((0, 8), np.float32),
    np.arange(7, dtype=np.int32),
    np.linspace(-2, 2, 4096, dtype=np.float32).reshape(64, 64),
    (np.random.default_rng(0).normal(size=(512, 24)) * 0.1).astype(np.float32),
], ids=["scalar32", "scalar64", "empty", "tiny-int", "grid", "factors"])
def test_codec_roundtrip_bit_exact(arr):
    c = compress_array(arr)
    back = decompress_array(c)
    assert back.shape == np.shape(arr)
    assert back.dtype == np.asarray(arr).dtype
    np.testing.assert_array_equal(back, np.asarray(arr))


def test_codec_compresses_factor_rows():
    rows = (np.random.default_rng(1).normal(size=(2048, 24)) * 0.1).astype(
        np.float32
    )
    c = compress_array(rows)
    assert c.codec == "shuffle-zlib"
    assert c.nbytes < c.raw_nbytes  # shuffle makes exponent bytes runs
    assert c.raw_nbytes == rows.nbytes


def test_codec_tiny_arrays_stored_raw():
    c = compress_array(np.arange(4, dtype=np.int8))
    assert c.codec == "raw" and c.nbytes == 4


# ---------------------------------------------------------------------------
# wire format
# ---------------------------------------------------------------------------


def test_message_matches_checkpoint_payload_and_applies_bitwise():
    msgs, upd = _messages(2)
    params0 = _params()
    state = (params0, 0.0, 0.0, None)
    for msg in msgs:
        state = apply_message(*state, msg)
    params, t_p, t_q, _ = state
    np.testing.assert_array_equal(np.asarray(params.p), np.asarray(upd.params.p))
    np.testing.assert_array_equal(np.asarray(params.q), np.asarray(upd.params.q))
    assert float(t_p) == float(upd.t_p) and float(t_q) == float(upd.t_q)


def test_message_wire_smaller_than_raw():
    msgs, _ = _messages(1, m=400, n=4000)
    assert msgs[0].wire_bytes < msgs[0].raw_bytes
    assert any(
        isinstance(v, CompressedArray) for v in msgs[0].tree.values()
    )


def test_state_message_roundtrip():
    params = _params(variant="svdpp")
    hist = np.random.default_rng(0).integers(0, 300, (40, 6)).astype(np.int32)
    msg = state_message(params, 0.1, 0.2, user_history=hist, version=7)
    got, t_p, t_q, history = state_from_message(msg)
    np.testing.assert_array_equal(np.asarray(got.p), np.asarray(params.p))
    np.testing.assert_array_equal(
        np.asarray(got.implicit), np.asarray(params.implicit)
    )
    np.testing.assert_array_equal(history, hist)
    assert msg.version == 7 and msg.kind == "full"


# ---------------------------------------------------------------------------
# version gating: duplicates, out-of-order, full fast-forward
# ---------------------------------------------------------------------------


def test_gate_applies_in_order_and_dedups():
    applied = []
    gate = VersionGate(lambda m: applied.append(m.version))
    msgs, _ = _messages(3)
    assert gate.offer(msgs[0]) == 1
    assert gate.offer(msgs[0]) == 1          # duplicate: acked, not applied
    assert gate.offer(msgs[1]) == 2
    assert gate.offer(msgs[2]) == 3
    assert applied == [1, 2, 3]
    assert gate.duplicates == 1 and gate.applied == 3


def test_gate_buffers_out_of_order_delivery():
    applied = []
    gate = VersionGate(lambda m: applied.append(m.version))
    msgs, _ = _messages(3)
    assert gate.offer(msgs[2]) == 0          # v3 before v1/v2: buffered
    assert gate.offer(msgs[1]) == 0          # v2 before v1: buffered
    assert applied == []
    assert gate.offer(msgs[0]) == 3          # v1 lands -> chain drains
    assert applied == [1, 2, 3]


def test_gate_full_fast_forwards_and_drops_stale_buffer():
    applied = []
    gate = VersionGate(lambda m: applied.append(m.version))
    msgs, _ = _messages(4, full_at=(3,))
    gate.offer(msgs[1])                      # v2 buffered (gap at v1)
    assert gate.offer(msgs[2]) == 3          # kind=full applies immediately
    assert applied == [3]
    assert gate.offer(msgs[0]) == 3          # v1 now stale: dropped
    assert gate.offer(msgs[1]) == 3          # v2 now stale: dropped
    assert gate.offer(msgs[3]) == 4
    assert applied == [3, 4]


def test_out_of_order_and_duplicates_converge_bitwise():
    msgs, upd = _messages(4, full_at=(2,))
    engine = ServingEngine(_params(), 0.0, 0.0)
    sink = EngineDeltaSink(engine)
    # adversarial delivery order with duplicates
    for msg in [msgs[1], msgs[0], msgs[0], msgs[3], msgs[2], msgs[1], msgs[3]]:
        sink.apply_update(msg)
    assert sink.version == 4
    _assert_bitwise(engine, upd)


# ---------------------------------------------------------------------------
# publisher as replication bus
# ---------------------------------------------------------------------------


def test_publisher_ships_to_subscribers_and_tracks_acks():
    rng = np.random.default_rng(2)
    params = _params()
    upd = OnlineUpdater(params, None, 0.0, 0.0, batch_size=32, seed=2)
    engines = [ServingEngine(params, 0.0, 0.0) for _ in range(2)]
    pub = SnapshotPublisher(None, upd)
    for i, e in enumerate(engines):
        pub.subscribe(EngineDeltaSink(e, replica_id=f"r{i}"))
    for _ in range(3):
        upd.apply(_batch(rng, 40, 300))
        report = pub.publish()
    assert report.acked == {"r0": 3, "r1": 3}
    assert pub.lag() == 0 and pub.version == 3
    assert report.wire_bytes > 0
    for e in engines:
        _assert_bitwise(e, upd)


def test_publisher_heals_lagging_subscriber_with_full():
    rng = np.random.default_rng(3)
    params = _params()
    upd = OnlineUpdater(params, None, 0.0, 0.0, batch_size=32, seed=3)
    pub = SnapshotPublisher(None, upd)
    engine = ServingEngine(params, 0.0, 0.0)
    sink = pub.subscribe(EngineDeltaSink(engine, replica_id="r0"))
    upd.apply(_batch(rng, 40, 300))
    pub.publish()
    # a second replica joins cold (version 0, missed v1): publisher sees the
    # stale ack and must ship kind=full next so its gate can apply it
    late_engine = ServingEngine(_params(seed=9), 0.0, 0.0)
    pub.subscribe(EngineDeltaSink(late_engine, replica_id="late"))
    upd.apply(_batch(rng, 40, 300))
    report = pub.publish()
    assert report.kind == "full"
    assert report.acked == {"r0": 2, "late": 2}
    _assert_bitwise(late_engine, upd)
    _assert_bitwise(engine, upd)
    del sink


def test_late_join_catches_up_from_checkpoints(tmp_path):
    """A replica bootstrapped from the delta-checkpoint chain via
    ``fold_deltas`` joins the live bus at the chain's last version and then
    follows deltas — bitwise identical to a bus-following replica."""
    rng = np.random.default_rng(4)
    params = _params()
    upd = OnlineUpdater(params, None, 0.0, 0.0, batch_size=32, seed=4)
    engine = ServingEngine(params, 0.0, 0.0)
    pub = SnapshotPublisher(engine, upd, checkpoint_dir=str(tmp_path), keep=8)
    sink = pub.subscribe(EngineDeltaSink(
        ServingEngine(params, 0.0, 0.0), replica_id="r0"
    ))
    for _ in range(3):
        upd.apply(_batch(rng, 40, 300))
        pub.publish()
    pub.close()  # join async checkpoint writes

    # late joiner: fold the chain onto the same base the fleet launched from
    folded, f_tp, f_tq, _, last = fold_deltas(
        str(tmp_path), params, 0.0, 0.0
    )
    assert last == pub.version == 3
    late = LocalReplica("late", folded, f_tp, f_tq, base_version=last,
                        queue_kwargs={"linger_ms": 0.5})
    pub.subscribe(late)

    # both replicas now follow the live bus
    upd.apply(_batch(rng, 40, 300))
    report = pub.publish()
    assert report.kind == "delta"           # no heal needed: joined current
    assert report.acked["late"] == 4 and report.acked["r0"] == 4
    _assert_bitwise(late.engine, upd)
    _assert_bitwise(sink.engine, upd)
    late.close()


# ---------------------------------------------------------------------------
# router
# ---------------------------------------------------------------------------


class _StubReplica:
    """Deterministic replica for routing tests: settable depth, counts."""

    def __init__(self, rid, depth=0):
        self.replica_id = rid
        self.version = 0
        self._depth = depth
        self.submitted = []

    def submit(self, user_id, topk=10, *, timeout=None, priority=0):
        self.submitted.append(user_id)
        fut = Future()
        fut.set_result((np.zeros(topk), np.arange(topk)))
        return fut

    def apply_update(self, msg):
        self.version = msg.version
        return self.version

    def depth(self):
        return self._depth

    def stats(self):
        return {"replica_id": self.replica_id, "version": self.version}

    def close(self):
        pass


def test_router_pins_repeat_users():
    reps = [_StubReplica("a"), _StubReplica("b")]
    router = Router(reps, overload_slack=4)
    first = router.pick(7)
    for _ in range(5):
        assert router.pick(7) == first
    assert router.affinity_hits == 5 and router.affinity_cold == 1


def test_router_background_priority_not_pinned():
    reps = [_StubReplica("a", depth=0), _StubReplica("b", depth=3)]
    router = Router(reps)
    assert router.pick(1, priority=1) == 0   # least depth
    assert router.affinity_cold == 0         # background never pins
    reps[0]._depth = 10
    assert router.pick(1, priority=1) == 1   # follows depth, no stickiness


def test_router_spills_overloaded_pin():
    reps = [_StubReplica("a", depth=0), _StubReplica("b", depth=0)]
    router = Router(reps, overload_slack=2)
    pin = router.pick(3)
    reps[pin]._depth = 100                   # pinned replica falls behind
    other = router.pick(3)
    assert other != pin and router.affinity_spills == 1
    reps[pin]._depth = 0                     # re-pinned to the new replica
    assert router.pick(3) == other


def test_router_random_policy_ignores_affinity():
    reps = [_StubReplica("a"), _StubReplica("b")]
    router = Router(reps, policy="random", seed=0)
    picks = {router.pick(5) for _ in range(64)}
    assert picks == {0, 1}
    assert router.affinity_hits == 0


def test_router_random_never_polls_depth():
    """ISSUE-7 bugfix: random routing must not pay a depth() poll per
    replica per request — for process replicas that is lock + dict work on
    the hot path for a signal the policy never reads."""

    class _NoDepth(_StubReplica):
        def depth(self):
            raise AssertionError("random policy polled depth()")

    router = Router([_NoDepth("a"), _NoDepth("b")], policy="random", seed=1)
    picks = {router.pick(u) for u in range(64)}
    assert picks == {0, 1}
    # the load-aware policies still read it, of course
    router_least = Router([_StubReplica("a"), _StubReplica("b", depth=5)],
                          policy="least")
    assert router_least.pick(0) == 0


def test_router_rolling_threshold_rollout_acks_every_replica():
    class _ThresholdStub(_StubReplica):
        def set_thresholds(self, t_p, t_q):
            self.thresholds = (t_p, t_q)
            return self.version

    reps = [_ThresholdStub("a"), _ThresholdStub("b")]
    router = Router(reps)
    acks = router.apply_thresholds(0.03, 0.04)
    assert acks == {"a": 0, "b": 0}
    assert all(r.thresholds == (0.03, 0.04) for r in reps)


def test_router_rolling_update_acks_every_replica():
    reps = [_StubReplica("a"), _StubReplica("b"), _StubReplica("c")]
    router = Router(reps)
    msgs, _ = _messages(1)
    acks = router.apply_update(msgs[0])
    assert acks == {"a": 1, "b": 1, "c": 1}
    assert router.version == 1


# ---------------------------------------------------------------------------
# fleet under load: rolling refresh, zero drops, convergence
# ---------------------------------------------------------------------------


def test_fleet_rolling_swap_under_load_zero_drops():
    rng = np.random.default_rng(5)
    params = _params()
    upd = OnlineUpdater(params, None, 0.0, 0.0, batch_size=32, seed=5)
    fleet = ServingFleet(params, 0.0, 0.0, replicas=2, backend="local",
                         queue_kwargs={"linger_ms": 0.5})
    pub = SnapshotPublisher(None, upd)
    pub.subscribe(fleet.router)

    failures, done = [], []
    stop = threading.Event()

    def client(seed):
        crng = np.random.default_rng(seed)
        while not stop.is_set():
            try:
                fleet.submit(int(crng.integers(0, 40)), 5,
                             timeout=30.0).result(60)
                done.append(1)
            except Exception as exc:  # noqa: BLE001
                failures.append(repr(exc))

    threads = [threading.Thread(target=client, args=(100 + i,), daemon=True)
               for i in range(4)]
    for t in threads:
        t.start()
    for _ in range(3):                        # three rolling refreshes
        upd.apply(_batch(rng, 40, 300))
        pub.publish()
        time.sleep(0.05)
    stop.set()
    for t in threads:
        t.join(timeout=60)
    versions = [r.version for r in fleet.replicas]
    fleet.close()
    assert not failures, failures[:3]
    assert len(done) > 0
    assert versions == [3, 3] == [pub.version] * 2
    for r in fleet.replicas:
        _assert_bitwise(r.engine, upd)


def test_fleet_affinity_warms_caches():
    """Same hot-user traffic: the affinity router must land a higher
    hot-user cache hit rate than random routing (per-replica cache smaller
    than the hot set, SVD++ so the cache is live)."""
    m, n, k = 120, 600, 8
    params = _params(m, n, k, variant="svdpp")
    hist = np.random.default_rng(0).integers(0, n, (m, 4)).astype(np.int32)
    hot = np.random.default_rng(1).choice(m, 40, replace=False)
    rng = np.random.default_rng(2)
    users = np.where(rng.random(240) < 0.8,
                     hot[rng.integers(0, len(hot), 240)],
                     rng.integers(0, m, 240))
    rates = {}
    for policy in ("affinity", "random"):
        # per-replica capacity 24: the hot set split across 2 pinned
        # replicas (~20 each) fits, but random routing exposes each replica
        # to all 40 hot users and thrashes
        fleet = ServingFleet(
            params, 0.0, 0.0, replicas=2, backend="local",
            user_history=hist,
            engine_kwargs={"cache_size": 24},
            queue_kwargs={"linger_ms": 0.5},
            router_kwargs={"policy": policy, "seed": 3},
        )
        # serial traffic: queue depths stay ~0, so the routing decision
        # (not overload spill) is what's under test
        for u in users:
            fleet.submit(int(u), 5, timeout=60.0).result(120)
        stats = fleet.stats()
        hits = sum(r["cache_hits"] for r in stats["replicas"])
        misses = sum(r["cache_misses"] for r in stats["replicas"])
        fleet.close()
        rates[policy] = hits / max(hits + misses, 1)
    assert rates["affinity"] > rates["random"], rates


# ---------------------------------------------------------------------------
# graceful drain regression
# ---------------------------------------------------------------------------


def test_engine_stop_strands_no_future_under_load():
    """Regression: ``stop()`` under concurrent submit load used to let a
    racing ``submit`` auto-start a fresh queue nobody owned — its futures
    hung forever.  Now every accepted future resolves and in-drain submits
    are rejected with ``RuntimeError``."""
    engine = ServingEngine(_params(), 0.0, 0.0)
    engine.start(linger_ms=0.5, max_pending=512)
    futures, rejected = [], []
    stop_submitting = threading.Event()

    def submitter(seed):
        srng = np.random.default_rng(seed)
        while not stop_submitting.is_set():
            try:
                futures.append(engine.submit(int(srng.integers(0, 40)), 5,
                                             timeout=30.0))
            except RuntimeError:
                rejected.append(1)           # stopping: expected, not a drop
            except Exception:
                rejected.append(1)

    threads = [threading.Thread(target=submitter, args=(i,), daemon=True)
               for i in range(4)]
    for t in threads:
        t.start()
    time.sleep(0.2)                          # build a backlog under load
    engine.stop()
    stop_submitting.set()
    for t in threads:
        t.join(timeout=60)
    deadline = time.monotonic() + 60
    pending = [f for f in futures if not f.done()]
    while pending and time.monotonic() < deadline:
        time.sleep(0.05)
        pending = [f for f in futures if not f.done()]
    assert not pending, f"{len(pending)} futures stranded by stop()"
    # and the engine is restartable afterwards
    scores, items = engine.submit(3, 5).result(60)
    assert len(items) == 5
    engine.stop()


def test_engine_stop_rejects_concurrent_submits():
    engine = ServingEngine(_params(), 0.0, 0.0)
    for _ in range(64):
        engine.submit(1, 5, timeout=30.0)
    results = []

    def stopper():
        engine.stop()

    t = threading.Thread(target=stopper)
    t.start()
    # submits racing the drain either land on the pre-stop queue or get a
    # clean rejection — never a zombie queue
    for _ in range(50):
        try:
            results.append(engine.submit(2, 5, timeout=30.0))
        except RuntimeError:
            pass
    t.join(60)
    for f in results:
        assert f.done() or f.result(60) is not None


# ---------------------------------------------------------------------------
# process replicas (slow: spawn + re-import)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_process_fleet_replicates_and_drains():
    rng = np.random.default_rng(6)
    params = _params(30, 200)
    upd = OnlineUpdater(params, None, 0.0, 0.0, batch_size=32, seed=6)
    fleet = ServingFleet(params, 0.0, 0.0, replicas=2, backend="process",
                         queue_kwargs={"linger_ms": 1.0})
    try:
        pub = SnapshotPublisher(None, upd)
        pub.subscribe(fleet.router)
        futs = [fleet.submit(int(u), 5, timeout=60.0)
                for u in rng.integers(0, 30, 8)]
        upd.apply(_batch(rng, 30, 200))
        report = pub.publish()
        assert report.acked == {"r0": 1, "r1": 1}
        futs += [fleet.submit(int(u), 5, timeout=60.0)
                 for u in rng.integers(0, 30, 8)]
        for f in futs:
            scores, items = f.result(120)
            assert len(np.asarray(items)) == 5
        ref = ServingEngine(upd.params, upd.t_p, upd.t_q)
        s_ref, i_ref = ref.topk(np.arange(30), 5)
        for r in fleet.replicas:
            rows = [r.submit(u, 5, timeout=60.0) for u in range(30)]
            got_s = np.stack([np.asarray(f.result(120)[0]) for f in rows])
            got_i = np.stack([np.asarray(f.result(120)[1]) for f in rows])
            np.testing.assert_array_equal(got_s, np.asarray(s_ref))
            np.testing.assert_array_equal(got_i, np.asarray(i_ref))
            assert r.stats()["version"] == 1
    finally:
        fleet.close()


@pytest.mark.slow
def test_process_replica_late_join_from_checkpoints(tmp_path):
    """Spawn a ProcessReplica from checkpoint dirs: training base +
    online delta chain folded in the child (the fleet's cold-start path)."""
    from repro.checkpoint import checkpoint as ckpt_lib

    rng = np.random.default_rng(7)
    params = _params(30, 200)
    base_dir, online_dir = str(tmp_path / "train"), str(tmp_path / "online")
    ckpt_lib.save(base_dir, 1, {"params": params,
                                "t_p": np.float32(0.0),
                                "t_q": np.float32(0.0)})
    upd = OnlineUpdater(params, None, 0.0, 0.0, batch_size=32, seed=7)
    pub = SnapshotPublisher(None, upd, checkpoint_dir=online_dir)
    for _ in range(2):
        upd.apply(_batch(rng, 30, 200))
        pub.publish()
    pub.close()

    base = load_mf_checkpoint(base_dir)
    rep = ProcessReplica("late", checkpoint=base_dir, online_dir=online_dir,
                         queue_kwargs={"linger_ms": 1.0})
    try:
        assert rep.version == 2
        ref = ServingEngine(upd.params, upd.t_p, upd.t_q)
        s_ref, i_ref = ref.topk(np.arange(30), 5)
        rows = [rep.submit(u, 5, timeout=60.0) for u in range(30)]
        got_s = np.stack([np.asarray(f.result(120)[0]) for f in rows])
        got_i = np.stack([np.asarray(f.result(120)[1]) for f in rows])
        np.testing.assert_array_equal(got_s, np.asarray(s_ref))
        np.testing.assert_array_equal(got_i, np.asarray(i_ref))
    finally:
        rep.close()
    del base
