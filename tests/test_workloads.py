"""Differential-oracle campaign for the implicit / BPR workloads.

Three independent oracles pin the new objectives to the pruned update the
paper defines:

* ``jax.grad`` of the masked loss (masks stop-gradiented, exactly as the
  steps treat them) — the analytic gradients in ``mf.train_step`` and
  ``workloads.bpr.bpr_train_step`` must BE that gradient;
* the NumPy transcription ``kernels.ref.bpr_step_ref`` on 1/8-grid
  factors — framework-independent semantics, scatter-add duplicates and
  all;
* the fused Pallas kernel vs the masked XLA formulation for the
  confidence-weighted objective.

Plus hypothesis property tests on the WALS confidence contract: weight 0
is bitwise inert, larger confidence moves factors further.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st  # noqa: F401

from repro.core import mf
from repro.core.ranks import effective_ranks, rank_mask
from repro.data import ratings as rdata
from repro.kernels import ref
from repro.optim.optimizers import RowOptimizer
from repro.workloads import (
    BPRSampler,
    binarize_positives,
    bpr_epoch_scan,
    bpr_train_step,
    confidence_weights,
    implicit_dataset,
)

K = 8
M, N = 24, 32


def _grid(rng, shape):
    """f32 multiples of 1/8 in [-2, 2]: float ops on them are exact."""
    return (rng.integers(-16, 17, shape) / 8.0).astype(np.float32)


def _grid_params(seed, variant="funk"):
    rng = np.random.default_rng(seed)
    p = jnp.asarray(_grid(rng, (M, K)))
    q = jnp.asarray(_grid(rng, (N, K)))
    if variant == "funk":
        return mf.MFParams(p, q, None, None, None, None)
    return mf.MFParams(
        p, q,
        user_bias=jnp.asarray(_grid(rng, (M, 1))),
        item_bias=jnp.asarray(_grid(rng, (N, 1))),
        global_mean=jnp.float32(0.5),
        implicit=None,
    )


def _triples(seed, b=40):
    """Random (user, pos, neg) with guaranteed duplicates and a pos==neg."""
    rng = np.random.default_rng(seed)
    u = rng.integers(0, M, b).astype(np.int32)
    i = rng.integers(0, N, b).astype(np.int32)
    j = rng.integers(0, N, b).astype(np.int32)
    u[1], i[1], j[1] = u[0], i[0], j[0]   # duplicated triple
    j[2] = i[2]                           # pos == neg
    return jnp.asarray(u), jnp.asarray(i), jnp.asarray(j)


ARGS = (jnp.float32(0.25), jnp.float32(0.25))   # t_p, t_q on the grid
LR, LAM = 0.5, 0.25                              # grid-friendly dyadics


# -- implicit dataset construction -----------------------------------------

def _log(seed=0, n=200):
    return rdata.synthetic_ratings(
        num_users=M, num_items=N, num_ratings=n, seed=seed
    )


def test_confidence_weights():
    w = confidence_weights(np.array([0.0, 1.0, 5.0]), alpha=40.0)
    np.testing.assert_array_equal(w, np.float32([1.0, 41.0, 201.0]))
    assert w.dtype == np.float32


def test_implicit_dataset_geometry_and_weights():
    ds = _log()
    out, weight = implicit_dataset(ds, alpha=10.0, negatives=3, seed=0)
    assert len(out) == len(ds) * 4
    assert weight.shape == (len(out),)
    assert (out.num_users, out.num_items) == (ds.num_users, ds.num_items)
    assert (out.rating_min, out.rating_max) == (0.0, 1.0)
    n = len(ds)
    # positives first: preference 1, confidence 1 + alpha*r
    np.testing.assert_array_equal(out.rating[:n], np.ones(n, np.float32))
    np.testing.assert_array_equal(
        weight[:n], confidence_weights(ds.rating, 10.0)
    )
    # negatives: preference 0 at floor confidence 1
    np.testing.assert_array_equal(out.rating[n:], np.zeros(3 * n, np.float32))
    np.testing.assert_array_equal(weight[n:], np.ones(3 * n, np.float32))


def test_implicit_negatives_avoid_positives_and_are_deterministic():
    ds = _log()
    pos = {(int(u), int(i)) for u, i in zip(ds.user, ds.item)}
    out, _ = implicit_dataset(ds, negatives=2, seed=3)
    n = len(ds)
    clashes = sum(
        (int(u), int(i)) in pos
        for u, i in zip(out.user[n:], out.item[n:])
    )
    assert clashes == 0  # catalog is much larger than any positive set
    out2, w2 = implicit_dataset(ds, negatives=2, seed=3)
    np.testing.assert_array_equal(out.item, out2.item)
    out3, _ = implicit_dataset(ds, negatives=2, seed=4)
    assert not np.array_equal(out.item[n:], out3.item[n:])


def test_binarize_positives():
    ds = _log()
    out = binarize_positives(ds)
    np.testing.assert_array_equal(out.user, ds.user)
    np.testing.assert_array_equal(out.item, ds.item)
    np.testing.assert_array_equal(out.rating, np.ones(len(ds), np.float32))
    assert (out.rating_min, out.rating_max) == (0.0, 1.0)


def test_implicit_dataset_rejects_negative_negatives():
    with pytest.raises(ValueError, match="negatives"):
        implicit_dataset(_log(), negatives=-1)


# -- oracle 1: jax.grad of the masked loss ---------------------------------

def _weighted_batch(seed, b=48):
    rng = np.random.default_rng(seed)
    u = rng.integers(0, M, b).astype(np.int32)
    i = rng.integers(0, N, b).astype(np.int32)
    u[1], i[1] = u[0], i[0]   # duplicate (u, i) row: scatter-add semantics
    return {
        "user": jnp.asarray(u),
        "item": jnp.asarray(i),
        "rating": jnp.asarray(rng.integers(0, 2, b).astype(np.float32)),
        "weight": jnp.asarray(
            confidence_weights(rng.integers(0, 2, b), alpha=4.0)
        ),
    }


def test_weighted_implicit_step_is_gradient_of_masked_loss():
    """The WALS update (confidence riding batch["weight"]) must equal one
    plain-SGD descent step on sum_b c_b*(0.5*err² + 0.5*lam*||rows∘m||²)
    with the pair masks held constant — pinned via jax.grad."""
    params = _grid_params(11)
    opt = RowOptimizer(name="sgd")
    state = mf.init_opt_state(params, opt)
    batch = _weighted_batch(12)
    t_p, t_q = ARGS

    def loss(p, q):
        x, y = p[batch["user"]], q[batch["item"]]
        m = jax.lax.stop_gradient(
            rank_mask(
                jnp.minimum(effective_ranks(x, t_p), effective_ranks(y, t_q)),
                K,
            )
        )
        err = batch["rating"] - jnp.sum(x * y * m, axis=-1)
        reg = jnp.sum(jnp.square(x * m), -1) + jnp.sum(jnp.square(y * m), -1)
        return jnp.sum(batch["weight"] * (0.5 * err**2 + 0.5 * LAM * reg))

    g_p, g_q = jax.grad(loss, argnums=(0, 1))(params.p, params.q)
    new_params, _, _ = mf.train_step(
        params, state, batch, t_p, t_q, jnp.float32(LR), jnp.ones((K,)),
        opt=opt, lam=LAM,
    )
    np.testing.assert_allclose(
        np.asarray(new_params.p), np.asarray(params.p - LR * g_p),
        rtol=0, atol=1e-5,
    )
    np.testing.assert_allclose(
        np.asarray(new_params.q), np.asarray(params.q - LR * g_q),
        rtol=0, atol=1e-5,
    )


@pytest.mark.parametrize("variant", ["funk", "bias"])
def test_bpr_step_is_gradient_of_masked_loss(variant):
    """bpr_train_step must be exact SGD on the masked pairwise loss
    -log σ(s_ui - s_uj) + 0.5·lam·(own-rank-masked norms), masks constant."""
    params = _grid_params(21, variant)
    opt = RowOptimizer(name="sgd")
    state = mf.init_opt_state(params, opt)
    u, i, j = _triples(22)
    t_p, t_q = ARGS

    def loss(p, q, bias):
        x, yi, yj = p[u], q[i], q[j]
        r_u = effective_ranks(x, t_p)
        r_i = effective_ranks(yi, t_q)
        r_j = effective_ranks(yj, t_q)
        sg = jax.lax.stop_gradient
        m_ui = sg(rank_mask(jnp.minimum(r_u, r_i), K))
        m_uj = sg(rank_mask(jnp.minimum(r_u, r_j), K))
        s_ui = jnp.sum(x * yi * m_ui, -1)
        s_uj = jnp.sum(x * yj * m_uj, -1)
        reg = (
            jnp.sum(jnp.square(x * sg(rank_mask(r_u, K))), -1)
            + jnp.sum(jnp.square(yi * sg(rank_mask(r_i, K))), -1)
            + jnp.sum(jnp.square(yj * sg(rank_mask(r_j, K))), -1)
        )
        if bias is not None:
            s_ui = s_ui + bias[i, 0]
            s_uj = s_uj + bias[j, 0]
            reg = reg + jnp.square(bias[i, 0]) + jnp.square(bias[j, 0])
        diff = s_ui - s_uj
        nll = jnp.log1p(jnp.exp(-jnp.abs(diff))) + jnp.maximum(-diff, 0.0)
        return jnp.sum(nll + 0.5 * LAM * reg)

    grads = jax.grad(loss, argnums=(0, 1, 2))(
        params.p, params.q, params.item_bias
    )
    new_params, _, _ = bpr_train_step(
        params, state, {"user": u, "pos": i, "neg": j},
        t_p, t_q, jnp.float32(LR), jnp.ones((K,)), opt=opt, lam=LAM,
    )
    np.testing.assert_allclose(
        np.asarray(new_params.p), np.asarray(params.p - LR * grads[0]),
        rtol=0, atol=1e-5,
    )
    np.testing.assert_allclose(
        np.asarray(new_params.q), np.asarray(params.q - LR * grads[1]),
        rtol=0, atol=1e-5,
    )
    if variant == "bias":
        np.testing.assert_allclose(
            np.asarray(new_params.item_bias),
            np.asarray(params.item_bias - LR * grads[2]),
            rtol=0, atol=1e-5,
        )
        # user bias and global mean cancel in the pairwise diff: untouched
        np.testing.assert_array_equal(
            np.asarray(new_params.user_bias), np.asarray(params.user_bias)
        )


# -- oracle 2: NumPy reference on the 1/8 grid -----------------------------

@pytest.mark.parametrize("variant", ["funk", "bias"])
@pytest.mark.parametrize("weighted", [False, True])
def test_bpr_step_matches_numpy_reference(variant, weighted):
    params = _grid_params(31, variant)
    opt = RowOptimizer(name="sgd")
    state = mf.init_opt_state(params, opt)
    u, i, j = _triples(32)
    rng = np.random.default_rng(33)
    w = (
        rng.integers(0, 3, u.shape[0]).astype(np.float32)
        if weighted else None
    )
    batch = {"user": u, "pos": i, "neg": j}
    if weighted:
        batch["weight"] = jnp.asarray(w)
    t_p, t_q = ARGS

    bias = (
        None if variant == "funk"
        else np.asarray(params.item_bias)[:, 0]
    )
    want_p, want_q, want_b, want_loss = ref.bpr_step_ref(
        np.asarray(params.p), np.asarray(params.q),
        np.asarray(u), np.asarray(i), np.asarray(j),
        float(t_p), float(t_q), lr=LR, lam=LAM, item_bias=bias, weight=w,
    )
    got, _, metrics = bpr_train_step(
        params, state, batch, t_p, t_q, jnp.float32(LR), jnp.ones((K,)),
        opt=opt, lam=LAM,
    )
    np.testing.assert_allclose(np.asarray(got.p), want_p, rtol=0, atol=1e-6)
    np.testing.assert_allclose(np.asarray(got.q), want_q, rtol=0, atol=1e-6)
    if variant == "bias":
        np.testing.assert_allclose(
            np.asarray(got.item_bias)[:, 0], want_b, rtol=0, atol=1e-6
        )
    assert abs(float(metrics["abs_err"]) - want_loss) < 1e-6
    # rows no triple touches stay bitwise identical
    touched_u = set(np.asarray(u).tolist())
    touched_q = set(np.asarray(i).tolist()) | set(np.asarray(j).tolist())
    for row in range(M):
        if row not in touched_u:
            np.testing.assert_array_equal(
                np.asarray(got.p[row]), np.asarray(params.p[row])
            )
    for row in range(N):
        if row not in touched_q:
            np.testing.assert_array_equal(
                np.asarray(got.q[row]), np.asarray(params.q[row])
            )


def test_bpr_threshold_zero_is_dense():
    """Rate 0 ≡ dense BPR: masks all-ones, bitwise-same as the unmasked
    reference run at thresholds 0."""
    params = _grid_params(41)
    opt = RowOptimizer(name="sgd")
    state = mf.init_opt_state(params, opt)
    u, i, j = _triples(42)
    want_p, want_q, _, _ = ref.bpr_step_ref(
        np.asarray(params.p), np.asarray(params.q),
        np.asarray(u), np.asarray(i), np.asarray(j),
        0.0, 0.0, lr=LR, lam=LAM,
    )
    got, _, metrics = bpr_train_step(
        params, state, {"user": u, "pos": i, "neg": j},
        jnp.float32(0.0), jnp.float32(0.0), jnp.float32(LR),
        jnp.ones((K,)), opt=opt, lam=LAM,
    )
    np.testing.assert_allclose(np.asarray(got.p), want_p, rtol=0, atol=1e-6)
    np.testing.assert_allclose(np.asarray(got.q), want_q, rtol=0, atol=1e-6)
    assert float(metrics["work_fraction"]) == 1.0


# -- oracle 3: fused Pallas kernel vs masked XLA, weighted objective -------

@pytest.mark.parametrize("variant", ["funk", "bias"])
def test_fused_kernel_matches_xla_for_implicit_objective(variant):
    """The confidence-weighted (implicit) batch takes the fused-kernel SGD
    path; it must match the masked XLA formulation."""
    params = _grid_params(51, variant)
    opt = RowOptimizer(name="sgd")
    state = mf.init_opt_state(params, opt)
    batch = _weighted_batch(52)
    args = (*ARGS, jnp.float32(0.05), jnp.ones((K,)))
    want, _, want_m = mf.train_step(
        params, state, batch, *args, opt=opt, lam=LAM, use_fused_kernel=False
    )
    got, _, got_m = mf.train_step(
        params, state, batch, *args, opt=opt, lam=LAM, use_fused_kernel=True
    )
    for name in ("p", "q", "user_bias", "item_bias"):
        a, b = getattr(want, name), getattr(got, name)
        if a is None:
            assert b is None
            continue
        np.testing.assert_allclose(
            np.asarray(b), np.asarray(a), rtol=0, atol=1e-6, err_msg=name
        )
    assert abs(float(want_m["abs_err"]) - float(got_m["abs_err"])) < 1e-5


# -- hypothesis: the confidence-weight contract ----------------------------

@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1), st.sampled_from(["sgd", "adagrad"]))
def test_weight_zero_rows_are_bitwise_inert(seed, opt_name):
    """Confidence 0 must be indistinguishable from the example not existing
    — bitwise, on params AND optimizer state (sgd/adagrad contract)."""
    rng = np.random.default_rng(seed)
    params = _grid_params(rng.integers(0, 2**31))
    opt = RowOptimizer(name=opt_name)
    state = mf.init_opt_state(params, opt)
    b = 16
    # distinct users/items per row so zeroed rows share nothing with live ones
    u = jnp.asarray(rng.permutation(M)[:b].astype(np.int32))
    i = jnp.asarray(rng.permutation(N)[:b].astype(np.int32))
    r = jnp.asarray(rng.integers(0, 2, b).astype(np.float32))
    keep = rng.integers(0, 2, b).astype(np.float32)
    batch = {"user": u, "item": i, "rating": r, "weight": jnp.asarray(keep)}
    args = (*ARGS, jnp.float32(0.5), jnp.ones((K,)))
    new_params, new_state, _ = mf.train_step(
        params, state, batch, *args, opt=opt, lam=LAM
    )
    dead = np.flatnonzero(keep == 0.0)
    for row in dead:
        np.testing.assert_array_equal(
            np.asarray(new_params.p[u[row]]), np.asarray(params.p[u[row]])
        )
        np.testing.assert_array_equal(
            np.asarray(new_params.q[i[row]]), np.asarray(params.q[i[row]])
        )
        for key, val in new_state.p.items():
            np.testing.assert_array_equal(
                np.asarray(val[u[row]]), np.asarray(state.p[key][u[row]])
            )


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1), st.floats(0.0, 4.0), st.floats(0.1, 8.0))
def test_monotone_confidence_moves_factors_more(seed, w_lo, w_extra):
    """For a single example under SGD, a strictly larger confidence never
    moves any factor coordinate less (|Δ| is elementwise non-decreasing in
    the weight — the update is linear in it)."""
    rng = np.random.default_rng(seed)
    params = _grid_params(rng.integers(0, 2**31))
    opt = RowOptimizer(name="sgd")
    state = mf.init_opt_state(params, opt)
    u = jnp.asarray(rng.integers(0, M, 1).astype(np.int32))
    i = jnp.asarray(rng.integers(0, N, 1).astype(np.int32))
    r = jnp.asarray(rng.integers(0, 2, 1).astype(np.float32))
    args = (*ARGS, jnp.float32(0.01), jnp.ones((K,)))

    def delta(w):
        batch = {"user": u, "item": i, "rating": r,
                 "weight": jnp.full((1,), w, jnp.float32)}
        out, _, _ = mf.train_step(
            params, state, batch, *args, opt=opt, lam=LAM
        )
        return (
            np.abs(np.asarray(out.p[u[0]] - params.p[u[0]])),
            np.abs(np.asarray(out.q[i[0]] - params.q[i[0]])),
        )

    dp_lo, dq_lo = delta(w_lo)
    dp_hi, dq_hi = delta(w_lo + w_extra)
    assert (dp_hi >= dp_lo - 1e-9).all()
    assert (dq_hi >= dq_lo - 1e-9).all()


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_bpr_weight_zero_triples_are_bitwise_inert(seed):
    rng = np.random.default_rng(seed)
    params = _grid_params(rng.integers(0, 2**31))
    opt = RowOptimizer(name="sgd")
    state = mf.init_opt_state(params, opt)
    b = 8
    u = jnp.asarray(rng.permutation(M)[:b].astype(np.int32))
    # disjoint pos/neg pools so a dead triple shares no row with a live one
    perm = rng.permutation(N)
    i, j = jnp.asarray(perm[:b].astype(np.int32)), jnp.asarray(
        perm[b:2 * b].astype(np.int32)
    )
    keep = rng.integers(0, 2, b).astype(np.float32)
    new_params, _, _ = bpr_train_step(
        params, state,
        {"user": u, "pos": i, "neg": j, "weight": jnp.asarray(keep)},
        *ARGS, jnp.float32(0.5), jnp.ones((K,)), opt=opt, lam=LAM,
    )
    for row in np.flatnonzero(keep == 0.0):
        np.testing.assert_array_equal(
            np.asarray(new_params.p[u[row]]), np.asarray(params.p[u[row]])
        )
        np.testing.assert_array_equal(
            np.asarray(new_params.q[i[row]]), np.asarray(params.q[i[row]])
        )
        np.testing.assert_array_equal(
            np.asarray(new_params.q[j[row]]), np.asarray(params.q[j[row]])
        )


# -- sampler & epoch scan ---------------------------------------------------

def test_bpr_sampler_deterministic_and_rejects_positives():
    ds = _log(n=150)
    sampler = BPRSampler(ds, batch_size=32, seed=5)
    t1 = sampler.epoch_triples(2)
    t2 = BPRSampler(ds, batch_size=32, seed=5).epoch_triples(2)
    for key in ("user", "pos", "neg"):
        np.testing.assert_array_equal(np.asarray(t1[key]), np.asarray(t2[key]))
    t3 = sampler.epoch_triples(3)
    assert not np.array_equal(np.asarray(t1["neg"]), np.asarray(t3["neg"]))
    pos = {(int(u), int(i)) for u, i in zip(ds.user, ds.item)}
    users = np.asarray(t1["user"]).ravel()
    negs = np.asarray(t1["neg"]).ravel()
    assert sum((int(u), int(n)) in pos for u, n in zip(users, negs)) == 0
    # every sampled pos really is one of the user's interactions
    poss = np.asarray(t1["pos"]).ravel()
    assert all((int(u), int(i)) in pos for u, i in zip(users, poss))


def test_bpr_sampler_oversized_batch_raises():
    ds = _log(n=20)
    sampler = BPRSampler(ds, batch_size=10_000, seed=0)
    assert sampler.batch_size == len(ds)   # clamped
    assert sampler.num_steps == 1
    empty = rdata.RatingsDataset(
        user=np.zeros(0, np.int32), item=np.zeros(0, np.int32),
        rating=np.zeros(0, np.float32), num_users=M, num_items=N,
    )
    with pytest.raises(ValueError, match="exceeds"):
        BPRSampler(empty, batch_size=4).epoch_triples(0)


def test_bpr_epoch_scan_matches_folded_steps():
    ds = _log(n=96)
    sampler = BPRSampler(ds, batch_size=24, seed=9)
    triples = sampler.epoch_triples(0)
    opt = RowOptimizer(name="adagrad")
    args = (*ARGS, jnp.float32(0.05), jnp.ones((K,)))

    params = _grid_params(61)
    state = mf.init_opt_state(params, opt)
    steps = triples["user"].shape[0]
    want_p, want_s = params, state
    for step in range(steps):
        batch = {key: val[step] for key, val in triples.items()}
        want_p, want_s, _ = bpr_train_step(
            want_p, want_s, batch, *args, opt=opt, lam=LAM
        )

    params2 = _grid_params(61)
    state2 = mf.init_opt_state(params2, opt)
    got_p, _, metrics = bpr_epoch_scan(
        params2, state2, triples, *args, opt=opt, lam=LAM
    )
    np.testing.assert_allclose(
        np.asarray(got_p.p), np.asarray(want_p.p), rtol=0, atol=1e-6
    )
    np.testing.assert_allclose(
        np.asarray(got_p.q), np.asarray(want_p.q), rtol=0, atol=1e-6
    )
    assert np.isfinite(float(metrics["abs_err"]))


# -- trainer integration ----------------------------------------------------

def _split(seed=0):
    ds = rdata.synthetic_ratings(
        num_users=40, num_items=50, num_ratings=500, seed=seed
    )
    return rdata.train_test_split(ds, test_fraction=0.2, seed=1)


def test_trainer_implicit_objective_end_to_end():
    from repro.core.trainer import DPMFTrainer, TrainConfig

    cfg = TrainConfig(
        k=K, epochs=2, batch_size=128, lr=0.02, lam=0.02, pruning_rate=0.3,
        objective="implicit", implicit_alpha=4.0, implicit_negatives=2,
        seed=0, ranking_topk=5,
    )
    tr, te = _split()
    trainer = DPMFTrainer(cfg, tr, te)
    assert len(trainer.train_ds) == len(tr) * 3
    assert set(np.unique(trainer.train_ds.rating)) <= {0.0, 1.0}
    assert trainer._train_weight is not None
    history = trainer.run()
    assert len(history) == 2
    assert all(np.isfinite(rec.test_mae) for rec in history)
    report = trainer.evaluate_ranking()
    assert report is not None and np.isfinite(report.ndcg)
    # pruning engaged after calibration
    assert history[1].work_fraction < 1.0


def test_trainer_bpr_objective_end_to_end():
    from repro.core.trainer import DPMFTrainer, TrainConfig

    cfg = TrainConfig(
        k=K, epochs=3, batch_size=64, lr=0.05, lam=0.02, pruning_rate=0.3,
        objective="bpr", seed=0, ranking_topk=5,
    )
    tr, te = _split()
    trainer = DPMFTrainer(cfg, tr, te)
    history = trainer.run()
    # abs_err carries the BPR loss: it must go down from the 0.693 start
    assert history[0].train_abs_err < float(np.log(2.0)) + 0.05
    assert history[-1].train_abs_err < history[0].train_abs_err
    # rating error is undefined for a pairwise objective
    assert all(np.isnan(rec.test_mae) for rec in history)
    assert np.isnan(trainer.evaluate())
    report = trainer.evaluate_ranking()
    assert report is not None and report.hr > 0.0


def test_trainer_objective_validation():
    from repro.core.trainer import DPMFTrainer, TrainConfig

    tr, te = _split()
    with pytest.raises(ValueError, match="unknown objective"):
        DPMFTrainer(TrainConfig(objective="pointwise"), tr, te)
    with pytest.raises(ValueError, match="scan"):
        DPMFTrainer(
            TrainConfig(objective="implicit", epoch_mode="python"), tr, te
        )
    with pytest.raises(ValueError, match="svdpp"):
        DPMFTrainer(TrainConfig(objective="bpr", variant="svdpp"), tr, te)
    with pytest.raises(ValueError, match="train_ds"):
        DPMFTrainer(TrainConfig(objective="bpr"), None, te)
