"""Documentation gates as tests: the public API of the serving/online/eval
trees stays >= 80% docstring-covered, and intra-repo markdown links in
README/docs/ROADMAP resolve — the same checks the CI docs job runs via
``tools/check_docs.py``."""
import os
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "tools"))

from check_docs import check_links, doc_coverage  # noqa: E402

GATED_TREES = [
    os.path.join(REPO, "src", "repro", tree)
    for tree in ("serving", "online", "eval")
]
LINKED_DOCS = [
    os.path.join(REPO, name)
    for name in ("README.md", "ROADMAP.md", "docs")
]


def test_docstring_coverage_gate():
    documented, total, missing = doc_coverage(GATED_TREES)
    assert total > 0
    pct = 100.0 * documented / total
    assert pct >= 80.0, (
        f"public-API docstring coverage {pct:.1f}% < 80%; undocumented:\n"
        + "\n".join(missing)
    )


def test_markdown_links_resolve():
    broken = check_links(LINKED_DOCS)
    assert not broken, "broken intra-repo links:\n" + "\n".join(broken)


def test_link_checker_catches_breakage(tmp_path):
    doc = tmp_path / "doc.md"
    doc.write_text(
        "[ok](doc.md) [web](https://example.com) [anchor](#x) "
        "[bad](missing.md)"
    )
    broken = check_links([str(doc)])
    assert broken == [f"{doc}: missing.md"]


def test_coverage_counts_public_defs_only(tmp_path):
    mod = tmp_path / "mod.py"
    mod.write_text(
        '"""module doc."""\n'
        "def documented():\n"
        '    """yes."""\n'
        "def undocumented():\n"
        "    pass\n"
        "def _private():\n"
        "    pass\n"
        "class C:\n"
        '    """doc."""\n'
        "    def method(self):\n"
        "        pass\n"
    )
    documented, total, missing = doc_coverage([str(mod)])
    # module + documented() + undocumented() + C + C.method
    assert total == 5
    assert documented == 3
    assert {m.rsplit(" ", 1)[1] for m in missing} == {
        "undocumented", "C.method",
    }


@pytest.mark.parametrize("tree", GATED_TREES)
def test_gated_trees_exist(tree):
    assert os.path.isdir(tree)
