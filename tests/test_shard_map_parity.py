"""Parity tests for the §Perf shard_map formulations against their XLA
references, on a real 8-device SPMD mesh (subprocess: the device count must
be set before jax initializes)."""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        env=env, capture_output=True, text=True, timeout=600,
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr
    return proc.stdout


@pytest.mark.slow
def test_moe_shard_map_matches_xla_path():
    out = _run("""
        import jax, jax.numpy as jnp
        from repro.distributed.mesh_compat import use_mesh
        from repro.models.moe import (MoEConfig, init_moe_params,
                                      moe_ffn_xla, moe_ffn_shard_map)
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        cfg = MoEConfig(num_experts=8, top_k=2, d_ff=32, num_shared=1,
                        capacity_factor=8.0)  # dropless => exact parity
        params = init_moe_params(jax.random.PRNGKey(0), 64, cfg,
                                 dtype=jnp.float32)
        x = 0.5 * jax.random.normal(jax.random.PRNGKey(1), (64, 64))
        ref, _ = moe_ffn_xla(x, params, cfg)
        with use_mesh(mesh):
            got, _ = jax.jit(lambda x, p: moe_ffn_shard_map(
                x, p, cfg, mesh=mesh.abstract_mesh))(x, params)
        diff = float(jnp.max(jnp.abs(ref - got)))
        assert diff < 1e-5, diff

        def loss_sm(p, x):
            o, _ = moe_ffn_shard_map(x, p, cfg, mesh=mesh.abstract_mesh)
            return jnp.sum(o ** 2)
        def loss_ref(p, x):
            o, _ = moe_ffn_xla(x, p, cfg)
            return jnp.sum(o ** 2)
        with use_mesh(mesh):
            g1 = jax.jit(jax.grad(loss_sm))(params, x)
        g2 = jax.grad(loss_ref)(params, x)
        for key in ("wg", "wi", "wo", "router"):
            d = float(jnp.max(jnp.abs(g1[key] - g2[key])))
            assert d < 1e-4, (key, d)
        print("MOE_PARITY_OK")
    """)
    assert "MOE_PARITY_OK" in out


@pytest.mark.slow
def test_mf_owner_compute_bit_exact():
    out = _run("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import mf
        from repro.distributed.mesh_compat import use_mesh
        from repro.optim.optimizers import RowOptimizer
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        m, n, k, B = 16, 8, 12, 16
        rng = np.random.default_rng(0)
        params = mf.init_params(jax.random.PRNGKey(0), m, n, k)
        for opt_name in ("adagrad", "sgd"):
            opt = RowOptimizer(name=opt_name)
            state = mf.init_opt_state(params, opt)
            users = np.concatenate(
                [rng.integers(s * 4, (s + 1) * 4, 4) for s in range(4)]
            ).astype(np.int32)  # ownership contract: shard s owns users [4s, 4s+4)
            batch = {
                "user": jnp.asarray(users),
                "item": jnp.asarray(rng.integers(0, n, B).astype(np.int32)),
                "rating": jnp.asarray(rng.uniform(1, 5, B).astype(np.float32)),
            }
            for t in (0.0, 0.05):
                ref_p, ref_s, _ = mf.train_step(
                    params, state, batch, jnp.float32(t), jnp.float32(t),
                    jnp.float32(0.05), jnp.ones((k,)), opt=opt, lam=0.02)
                with use_mesh(mesh):
                    sm_p, sm_s, _ = jax.jit(
                        lambda p, s, b, tp, tq: mf.train_step_shard_map(
                            p, s, b, tp, tq, lr=0.05, lam=0.02,
                            opt_name=opt_name, mesh=mesh.abstract_mesh)
                    )(params, state, batch, jnp.float32(t), jnp.float32(t))
                assert float(jnp.max(jnp.abs(ref_p.p - sm_p.p))) < 2e-8
                assert float(jnp.max(jnp.abs(ref_p.q - sm_p.q))) < 2e-8
                if opt_name == "adagrad":
                    assert float(jnp.max(jnp.abs(
                        ref_s.q["acc"] - sm_s.q["acc"]))) < 2e-8
        print("MF_PARITY_OK")
    """)
    assert "MF_PARITY_OK" in out


def test_moe_shard_map_fallback_without_mesh():
    """Outside any mesh context the dispatcher must fall back to the XLA
    path (smoke-test environments)."""
    import jax
    import jax.numpy as jnp

    from repro.models.moe import MoEConfig, init_moe_params, moe_ffn

    cfg = MoEConfig(num_experts=4, top_k=2, d_ff=16, capacity_factor=8.0)
    params = init_moe_params(jax.random.PRNGKey(0), 32, cfg, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (16, 32))
    out, aux = moe_ffn(x, params, cfg, use_shard_map=True)  # no ambient mesh
    assert out.shape == x.shape
    assert bool(jnp.isfinite(aux))


@pytest.mark.slow
def test_int8_error_feedback_tracks_fp32():
    """Multi-epoch training with int8-compressed gradient collectives:
    error feedback (per-sender quantization residuals folded into the next
    transmission) must keep the final-epoch training error within a tight
    tolerance of the fp32 exchange — and at least as close as plain int8
    without feedback."""
    out = _run("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import mf
        from repro.distributed.mesh_compat import use_mesh
        from repro.optim.optimizers import RowOptimizer

        mesh = jax.make_mesh((2, 2), ("data", "model"))
        m, n, k, B, steps, epochs = 16, 8, 12, 16, 40, 3
        rng = np.random.default_rng(0)
        params0 = mf.init_params(jax.random.PRNGKey(0), m, n, k)
        opt = RowOptimizer(name="adagrad")
        # ownership contract: rating b on data shard s => user in [8s, 8s+8)
        users = np.stack([
            np.concatenate([rng.integers(s * 8, (s + 1) * 8, B // 2)
                            for s in range(2)])
            for _ in range(steps)
        ]).astype(np.int32)
        batches = {
            "user": jnp.asarray(users),
            "item": jnp.asarray(
                rng.integers(0, n, (steps, B)).astype(np.int32)),
            "rating": jnp.asarray(
                rng.uniform(1, 5, (steps, B)).astype(np.float32)),
        }

        def final_err(gc):
            state = mf.init_opt_state(params0, opt)
            if gc == "int8_ef":
                with use_mesh(mesh):
                    state = mf.init_error_feedback_state(
                        params0, state, mesh)
            params = params0
            with use_mesh(mesh):
                for _ in range(epochs):
                    params, state, metrics = mf.train_epoch_scan_shard_map(
                        params, state, batches, 0.0, 0.0, lr=0.05,
                        lam=0.02, opt_name="adagrad", grad_compression=gc,
                        mesh=mesh.abstract_mesh)
            return float(metrics["abs_err"])

        fp32 = final_err("none")
        int8 = final_err("int8")
        ef = final_err("int8_ef")
        gap_int8 = abs(int8 - fp32) / fp32
        gap_ef = abs(ef - fp32) / fp32
        print("fp32", fp32, "int8", int8, "ef", ef)
        # residual accumulation: the EF run must stay within 1% of the
        # full-precision trajectory, and never meaningfully worse than
        # feedback-free int8 (both gaps are O(1e-4) at this scale, so the
        # comparison gets noise-level slack rather than strict ordering)
        assert gap_ef < 0.01, (gap_ef, ef, fp32)
        assert gap_ef <= gap_int8 + 5e-4, (gap_ef, gap_int8)
        print("INT8_EF_OK")
    """)
    assert "INT8_EF_OK" in out
