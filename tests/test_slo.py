"""SLO-aware adaptive pruning controller (ISSUE-7 tentpole).

Pins the control law (degrade on p99/depth/expiry, AIMD relax, quality
guardrail override), the per-priority-class rate schedule and its clamps,
and the application fan-out: primary engine swap, publisher serving-
threshold pin (a snapshot publish must not revert a degradation), and the
rolling per-replica fleet rollout.
"""
from __future__ import annotations

import time
from types import SimpleNamespace

import numpy as np
import pytest

import jax

from repro.core import mf
from repro.core.threshold import (
    empirical_pruned_fraction,
    measure_stats,
    threshold_for_rate,
)
from repro.online import EventBatch, OnlineUpdater, SnapshotPublisher
from repro.serving import (
    LatencyWindow,
    ServingEngine,
    SLOConfig,
    SLOController,
)
from repro.serving.fleet import ServingFleet, make_message


def _params(m=30, n=240, k=16, seed=0):
    return mf.init_params(
        jax.random.PRNGKey(seed), m, n, k, variant="bias", global_mean=3.5,
    )


def _slow_window(n=32, latency_s=0.120, capacity=64, priority=0):
    win = LatencyWindow(capacity)
    for _ in range(n):
        win.record(latency_s, priority=priority)
    return win


def _config(**kw):
    base = dict(p99_budget_ms=50.0, min_window=8, tick_interval_s=0.0)
    base.update(kw)
    return SLOConfig(**base)


# ---------------------------------------------------------------------------
# LatencyWindow
# ---------------------------------------------------------------------------


def test_latency_window_percentiles_and_count():
    win = LatencyWindow(8)
    assert np.isnan(win.percentile(99))
    for ms in (1, 2, 3, 4):
        win.record(ms / 1e3)
    assert win.count == 4
    assert win.percentile(50) == pytest.approx(2.5e-3)
    # ring: 12 records into capacity 8 keeps the last 8, count stays total
    for ms in range(5, 13):
        win.record(ms / 1e3)
    assert win.count == 12
    lat, _ = win.snapshot()
    assert lat.size == 8
    assert win.percentile(0) == pytest.approx(5e-3)


def test_latency_window_priority_filter():
    win = LatencyWindow(16)
    for _ in range(4):
        win.record(0.001, priority=0)
        win.record(0.100, priority=5)
    assert win.percentile(99, priority=0) < 0.01
    assert win.percentile(99, priority=5) > 0.05
    assert np.isnan(win.percentile(99, priority=3))


def test_latency_window_rejects_bad_capacity():
    with pytest.raises(ValueError):
        LatencyWindow(0)


# ---------------------------------------------------------------------------
# control law
# ---------------------------------------------------------------------------


def test_tick_degrades_on_p99_breach():
    params = _params()
    ctl = SLOController(
        config=_config(),
        window=_slow_window(),
        depth_fn=lambda: 0,
        expired_fn=lambda: 0,
        params_fn=lambda: params,
    )
    d = ctl.tick()
    assert d.action == "degrade"
    assert d.p99_ms > 50.0
    assert ctl.base_rate == pytest.approx(ctl.config.step_up)
    assert d.swapped and d.t_q > 0.0 and d.t_p > 0.0


def test_tick_degrades_on_depth_watermark_alone():
    params = _params()
    win = LatencyWindow(16)  # empty: no latency signal at all
    ctl = SLOController(
        config=_config(depth_high=10),
        window=win,
        depth_fn=lambda: 50,
        expired_fn=lambda: 0,
        params_fn=lambda: params,
    )
    assert ctl.tick().action == "degrade"


def test_tick_degrades_on_expiry():
    params = _params()
    expired = {"n": 0}
    ctl = SLOController(
        config=_config(),
        window=LatencyWindow(16),
        depth_fn=lambda: 0,
        expired_fn=lambda: expired["n"],
        params_fn=lambda: params,
    )
    expired["n"] = 3
    assert ctl.tick().action == "degrade"
    # expirations are counted per tick, not cumulatively
    d2 = ctl.tick()
    assert d2.expired == 0 and d2.action == "hold"


def test_tick_relaxes_when_comfortable():
    params = _params()
    win = _slow_window(capacity=32, n=32)
    ctl = SLOController(
        config=_config(),
        window=win,
        depth_fn=lambda: 0,
        expired_fn=lambda: 0,
        params_fn=lambda: params,
    )
    assert ctl.tick().action == "degrade"
    # flush the ring with fast completions: comfortably under budget now
    for _ in range(32):
        win.record(0.001)
    d = ctl.tick()
    assert d.action == "relax"
    assert ctl.base_rate == pytest.approx(
        ctl.config.step_up - ctl.config.step_down
    )


def test_relax_stops_at_measured_trained_floor():
    params = _params()
    rate = 0.3
    t_q = float(threshold_for_rate(measure_stats(params.q), rate))
    engine = ServingEngine(params, t_q, t_q)
    win = LatencyWindow(32)
    for _ in range(32):
        win.record(0.001)
    ctl = SLOController(
        engine,
        config=_config(),
        window=win,
        depth_fn=lambda: 0,
        expired_fn=lambda: 0,
    )
    measured = float(empirical_pruned_fraction(params.q, t_q))
    assert ctl.floor_rate == pytest.approx(measured)
    assert measured > 0.2  # the solve actually landed near the asked rate
    for _ in range(5):
        ctl.tick()
    assert ctl.base_rate == pytest.approx(ctl.floor_rate)
    engine.stop()


def test_degrade_clamps_at_max_rate():
    params = _params()
    ctl = SLOController(
        config=_config(max_rate=0.5, depth_high=1),
        window=LatencyWindow(16),
        depth_fn=lambda: 100,
        expired_fn=lambda: 0,
        params_fn=lambda: params,
    )
    for _ in range(10):
        ctl.tick()
    assert ctl.base_rate == pytest.approx(0.5)
    assert ctl.degrades == 10


def test_quality_pressure_relaxes_despite_overload():
    params = _params()
    ctl = SLOController(
        config=_config(depth_high=1),
        window=_slow_window(),
        depth_fn=lambda: 100,
        expired_fn=lambda: 0,
        params_fn=lambda: params,
    )
    assert ctl.tick().action == "degrade"
    hook = ctl.quality_hook()
    assert hook.controller is ctl
    hook(SimpleNamespace(
        events=200, window_events=50, window_mae=1.0, window_rmse=1.2,
        mae=0.6, rmse=0.8, ema_mae=0.5, ema_rmse=0.7,
    ))
    d = ctl.tick()  # still overloaded — quality wins anyway
    assert d.action == "quality_relax"
    assert ctl.quality_relaxes == 1
    # pressure is one-shot: next tick degrades again under the same load
    assert ctl.tick().action == "degrade"


def test_quality_pressure_needs_real_drift():
    params = _params()
    ctl = SLOController(
        config=_config(),
        window=LatencyWindow(16),
        depth_fn=lambda: 0,
        expired_fn=lambda: 0,
        params_fn=lambda: params,
    )
    # too few events / window error within bound: no pressure
    ctl.note_quality(SimpleNamespace(
        events=10, window_events=10, window_mae=9.0, window_rmse=9.0,
        mae=1.0, rmse=1.0, ema_mae=0.5, ema_rmse=0.5,
    ))
    assert not ctl._quality_pressure
    ctl.note_quality(SimpleNamespace(
        events=200, window_events=50, window_mae=0.55, window_rmse=0.7,
        mae=0.5, rmse=0.6, ema_mae=0.5, ema_rmse=0.6,
    ))
    assert not ctl._quality_pressure


def test_effective_rates_per_class_and_clamps():
    params = _params()
    ctl = SLOController(
        config=_config(max_rate=0.6, background_offset=0.2,
                       class_offsets={7: 0.05}),
        window=LatencyWindow(16),
        depth_fn=lambda: 0,
        expired_fn=lambda: 0,
        params_fn=lambda: params,
    )
    ctl.base_rate = 0.5
    rates = ctl.effective_rates((0, 3, 7))
    assert rates[0] == pytest.approx(0.5)
    assert rates[3] == pytest.approx(0.6)   # 0.5 + 0.2 clamped to max
    assert rates[7] == pytest.approx(0.55)  # explicit per-class offset
    # background is never served less pruned than interactive
    assert rates[3] >= rates[0] and rates[7] >= rates[0]


def test_applied_threshold_follows_most_latency_sensitive_class():
    params = _params()
    # only background traffic in the window: serve at the background rate
    win = _slow_window(n=32, priority=5)
    ctl = SLOController(
        config=_config(background_offset=0.2),
        window=win,
        depth_fn=lambda: 0,
        expired_fn=lambda: 0,
        params_fn=lambda: params,
    )
    d = ctl.tick()
    assert d.applied_class == 5
    assert d.applied_rate == pytest.approx(d.rates[5])
    # interactive traffic shows up: the applied threshold must follow the
    # most latency-sensitive class, not the background one
    for _ in range(16):
        win.record(0.120, priority=0)
    d2 = ctl.tick()
    assert d2.applied_class == 0
    assert d2.applied_rate == pytest.approx(d2.rates[0])
    assert d2.rates[5] >= d2.rates[0]


def test_small_rate_moves_skip_the_swap():
    params = _params()
    ctl = SLOController(
        config=_config(depth_high=1, step_up=0.001, rate_eps=0.01),
        window=LatencyWindow(16),
        depth_fn=lambda: 100,
        expired_fn=lambda: 0,
        params_fn=lambda: params,
    )
    first = ctl.tick()
    assert first.swapped  # first apply always lands
    moves = [ctl.tick().swapped for _ in range(5)]
    assert not any(moves)  # 0.001 steps stay under rate_eps
    assert ctl.swaps == 1


def test_maybe_tick_rate_limits():
    params = _params()
    ctl = SLOController(
        config=_config(tick_interval_s=30.0),
        window=LatencyWindow(16),
        depth_fn=lambda: 0,
        expired_fn=lambda: 0,
        params_fn=lambda: params,
    )
    assert ctl.maybe_tick() is not None
    assert ctl.maybe_tick() is None  # 30s have not elapsed
    assert ctl.ticks == 1


# ---------------------------------------------------------------------------
# application fan-out
# ---------------------------------------------------------------------------


def test_controller_applies_thresholds_to_engine():
    params = _params()
    engine = ServingEngine(params, 0.0, 0.0)
    dense_s, dense_i = engine.topk(np.arange(8), 5)
    ctl = SLOController(
        engine,
        config=_config(),
        window=_slow_window(),
        depth_fn=lambda: 0,
        expired_fn=lambda: 0,
    )
    d = ctl.tick()
    assert float(engine.t_q) == pytest.approx(d.t_q)
    assert float(engine.t_q) > 0.0
    s, i = engine.topk(np.arange(8), 5)  # pruned serving still works
    assert i.shape == dense_i.shape
    engine.stop()


def test_publisher_pin_survives_snapshot_publish():
    rng = np.random.default_rng(0)
    params = _params()
    engine = ServingEngine(params, 0.0, 0.0)
    upd = OnlineUpdater(params, None, 0.0, 0.0, batch_size=32, seed=0)
    pub = SnapshotPublisher(engine, upd)
    ctl = SLOController(
        engine,
        config=_config(),
        window=_slow_window(),
        depth_fn=lambda: 0,
        expired_fn=lambda: 0,
        publisher=pub,
    )
    d = ctl.tick()
    assert float(engine.t_q) == pytest.approx(d.t_q) and d.t_q > 0.0
    # a publish swaps new params in but must keep the SLO thresholds
    upd.apply(EventBatch(
        user=rng.integers(0, 30, 24).astype(np.int32),
        item=rng.integers(0, 240, 24).astype(np.int32),
        rating=rng.uniform(1, 5, 24).astype(np.float32),
    ))
    pub.publish()
    assert float(engine.t_q) == pytest.approx(d.t_q)
    assert float(engine.t_p) == pytest.approx(d.t_p)
    # unpinning reverts the NEXT publish to the model thresholds
    pub.clear_serving_thresholds()
    upd.apply(EventBatch(
        user=rng.integers(0, 30, 24).astype(np.int32),
        item=rng.integers(0, 240, 24).astype(np.int32),
        rating=rng.uniform(1, 5, 24).astype(np.float32),
    ))
    pub.publish()
    assert float(engine.t_q) == pytest.approx(float(upd.t_q))
    engine.stop()


def test_fleet_rolling_threshold_rollout():
    params = _params()
    fleet = ServingFleet(params, 0.0, 0.0, replicas=2, backend="local")
    try:
        ctl = SLOController(
            config=_config(),
            window=_slow_window(),
            depth_fn=lambda: 0,
            expired_fn=lambda: 0,
            router=fleet.router,
        )
        d = ctl.tick()
        assert d.t_q > 0.0
        for rep in fleet.replicas:
            assert float(rep.engine.t_q) == pytest.approx(d.t_q)
        # replicated snapshots must NOT revert the pinned thresholds
        upd = OnlineUpdater(params, None, 0.0, 0.0, batch_size=32, seed=1)
        rng = np.random.default_rng(1)
        upd.apply(EventBatch(
            user=rng.integers(0, 30, 24).astype(np.int32),
            item=rng.integers(0, 240, 24).astype(np.int32),
            rating=rng.uniform(1, 5, 24).astype(np.float32),
        ))
        msg = make_message(upd.snapshot(), 1, 0, full=False)
        fleet.apply_update(msg)
        for rep in fleet.replicas:
            assert rep.version == 1
            assert float(rep.engine.t_q) == pytest.approx(d.t_q)
    finally:
        fleet.close()


def test_queue_latency_feeds_the_controller():
    params = _params()
    engine = ServingEngine(params, 0.0, 0.0)
    queue = engine.start()
    try:
        futs = [engine.submit(u, 5) for u in range(8)]
        for f in futs:
            f.result(timeout=60)
        assert queue.latency.count >= 8
        ctl = SLOController(
            engine,
            queue=queue,
            config=_config(min_window=4, p99_budget_ms=1e9),
        )
        d = ctl.tick()
        assert d.completed >= 8
        assert np.isfinite(d.p99_ms)
        assert d.action in ("hold", "relax")
    finally:
        engine.stop()


def test_report_shape():
    params = _params()
    ctl = SLOController(
        config=_config(),
        window=_slow_window(),
        depth_fn=lambda: 0,
        expired_fn=lambda: 0,
        params_fn=lambda: params,
    )
    ctl.tick()
    rep = ctl.report()
    assert rep["ticks"] == 1 and rep["degrades"] == 1
    assert rep["applied_t_q"] > 0.0
    assert rep["last_decision"]["action"] == "degrade"
    assert isinstance(rep["rates"], dict)
    import json
    json.dumps(rep)  # report must be JSON-serializable as-is
