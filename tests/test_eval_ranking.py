"""Ranking metrics: numpy-oracle pinning (ties, topk == n, partial
holdouts), exact engine/oracle parity at threshold 0 on every serving path
(streaming, kernel, sharded), and the one-scan epoch variant."""
import math

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import mf
from repro.core.trainer import DPMFTrainer, TrainConfig
from repro.data import synthetic_ratings, train_test_split
from repro.eval import ranking as R
from repro.serving import ServingEngine


# ---------------------------------------------------------------------------
# numpy brute-force metric oracle
# ---------------------------------------------------------------------------


def np_ranking_metrics(topk_idx, relevant_sets):
    """Scalar-loop HR/NDCG/recall reference (the module's definitions)."""
    hr, ndcg, recall = [], [], []
    for ids, rel in zip(topk_idx, relevant_sets):
        rel = set(int(x) for x in rel)
        if not rel:
            continue
        hits = [1.0 if int(i) in rel else 0.0 for i in ids]
        dcg = sum(h / math.log2(j + 2) for j, h in enumerate(hits))
        idcg = sum(
            1.0 / math.log2(j + 2) for j in range(min(len(ids), len(rel)))
        )
        hr.append(1.0 if any(hits) else 0.0)
        ndcg.append(dcg / idcg)
        recall.append(sum(hits) / len(rel))
    n = max(len(hr), 1)
    return sum(hr) / n, sum(ndcg) / n, sum(recall) / n, len(hr)


def _as_padded(relevant_sets):
    width = max((len(r) for r in relevant_sets), default=1)
    width = max(width, 1)
    rel = np.full((len(relevant_sets), width), R.PAD_ITEM, np.int32)
    counts = np.zeros(len(relevant_sets), np.int32)
    for row, items in enumerate(relevant_sets):
        rel[row, : len(items)] = sorted(items)
        counts[row] = len(items)
    return rel, counts


def test_ranking_counts_matches_numpy_oracle():
    rng = np.random.default_rng(0)
    b, k, n_items = 64, 10, 200
    topk_idx = np.stack(
        [rng.choice(n_items, k, replace=False) for _ in range(b)]
    ).astype(np.int32)
    relevant_sets = [
        list(rng.choice(n_items, rng.integers(0, 30), replace=False))
        for _ in range(b)
    ]
    rel, counts = _as_padded(relevant_sets)
    out = R.ranking_counts(
        jnp.asarray(topk_idx), jnp.asarray(rel), jnp.asarray(counts)
    )
    want_hr, want_ndcg, want_recall, want_users = np_ranking_metrics(
        topk_idx, relevant_sets
    )
    assert float(out["weight_sum"]) == want_users
    denom = float(out["weight_sum"])
    np.testing.assert_allclose(float(out["hr_sum"]) / denom, want_hr,
                               rtol=1e-6)
    np.testing.assert_allclose(float(out["ndcg_sum"]) / denom, want_ndcg,
                               rtol=1e-6)
    np.testing.assert_allclose(float(out["recall_sum"]) / denom, want_recall,
                               rtol=1e-6)


def test_ranking_counts_pinned_cases():
    # perfect retrieval of a 2-item holdout in the top-2 -> all metrics 1
    out = R.ranking_counts(
        jnp.asarray([[5, 7, 1, 2]], np.int32),
        jnp.asarray([[5, 7]], np.int32),
        jnp.asarray([2], np.int32),
    )
    assert float(out["hr_sum"]) == 1.0
    assert float(out["recall_sum"]) == 1.0
    np.testing.assert_allclose(float(out["ndcg_sum"]), 1.0, rtol=1e-6)
    # single relevant item at the last position of K=4
    out = R.ranking_counts(
        jnp.asarray([[9, 8, 7, 5]], np.int32),
        jnp.asarray([[5]], np.int32),
        jnp.asarray([1], np.int32),
    )
    np.testing.assert_allclose(
        float(out["ndcg_sum"]), (1 / math.log2(5)) / 1.0, rtol=1e-6
    )
    # zero-relevance and zero-weight rows contribute nothing
    out = R.ranking_counts(
        jnp.asarray([[1, 2], [1, 2]], np.int32),
        jnp.asarray([[1, 2], [1, 2]], np.int32),
        jnp.asarray([0, 2], np.int32),
        jnp.asarray([1.0, 0.0], np.float32),
    )
    assert float(out["weight_sum"]) == 0.0
    assert float(out["hr_sum"]) == 0.0


def test_ranking_counts_holdout_larger_than_k():
    # |R_u| > K: IDCG truncates at K, recall divides by |R_u|
    ids = np.asarray([[0, 1, 2]], np.int32)
    rel, counts = _as_padded([[0, 1, 2, 3, 4]])
    out = R.ranking_counts(jnp.asarray(ids), jnp.asarray(rel),
                           jnp.asarray(counts))
    want_hr, want_ndcg, want_recall, _ = np_ranking_metrics(ids, [[0, 1, 2, 3, 4]])
    np.testing.assert_allclose(float(out["ndcg_sum"]), want_ndcg, rtol=1e-6)
    np.testing.assert_allclose(float(out["recall_sum"]), want_recall,
                               rtol=1e-6)
    assert float(out["recall_sum"]) == pytest.approx(3 / 5)


# ---------------------------------------------------------------------------
# relevance building
# ---------------------------------------------------------------------------


def test_relevance_from_dataset_dedup_and_min_rating():
    class DS:
        user = np.asarray([3, 1, 3, 3, 2, 1])
        item = np.asarray([7, 5, 7, 9, 4, 6])
        rating = np.asarray([5.0, 4.0, 5.0, 2.0, 1.0, 5.0])

    users, rel, counts = R.relevance_from_dataset(DS)
    assert users.tolist() == [1, 2, 3]
    assert counts.tolist() == [2, 1, 2]           # (3,7) deduplicated
    assert sorted(rel[2][rel[2] != R.PAD_ITEM].tolist()) == [7, 9]
    users, rel, counts = R.relevance_from_dataset(DS, min_rating=4.0)
    assert users.tolist() == [1, 3]               # user 2 filtered out
    assert counts.tolist() == [2, 1]
    with pytest.raises(ValueError):               # None means no cap, not 0
        R.relevance_from_dataset(DS, max_users=0)


def test_evaluators_accept_precomputed_relevance():
    params, ds = _random_setup(m=20, n=100)
    engine = ServingEngine(params, 0.0, 0.0, use_kernel=False, max_batch=8)
    relevance = R.relevance_from_dataset(ds)
    got = R.evaluate_engine(engine, topk=5, relevance=relevance)
    want = R.evaluate_engine(engine, ds, topk=5)
    assert got == want
    got = R.evaluate_oracle(params, topk=5, relevance=relevance)
    want = R.evaluate_oracle(params, ds, topk=5)
    assert got == want


# ---------------------------------------------------------------------------
# engine parity with the brute-force oracle
# ---------------------------------------------------------------------------


def _random_setup(m=50, n=700, k=16, variant="funk", seed=0):
    params = mf.init_params(
        jax.random.PRNGKey(seed), m, n, k, variant=variant, global_mean=3.0
    )
    ds = synthetic_ratings(num_users=m, num_items=n, num_ratings=1500,
                           seed=seed)
    return params, ds


@pytest.mark.parametrize("variant", ["funk", "bias"])
def test_engine_metrics_match_oracle_exactly_at_threshold_zero(variant):
    params, ds = _random_setup(variant=variant)
    engine = ServingEngine(params, 0.0, 0.0, use_kernel=False, max_batch=32)
    got = R.evaluate_engine(engine, ds, topk=10)
    want = R.evaluate_oracle(params, ds, topk=10)
    assert got == want  # exact equality, not approx: identical indices


def test_engine_metrics_match_oracle_kernel_path_threshold_zero():
    params, ds = _random_setup(n=520)
    engine = ServingEngine(params, 0.0, 0.0, use_kernel=True,
                           interpret=True, max_batch=16)
    got = R.evaluate_engine(engine, ds, topk=7, max_users=24)
    want = R.evaluate_oracle(params, ds, topk=7, max_users=24)
    assert got == want


def test_tie_scores_break_to_lower_index_both_paths():
    # factors on a coarse grid: duplicate scores are common, so parity here
    # pins the tie-break (lower item id first) on both sides
    rng = np.random.default_rng(2)
    m, n, k = 20, 150, 8
    p = jnp.asarray(np.round(rng.normal(0, 1, (m, k)) * 2) / 8, jnp.float32)
    q = jnp.asarray(np.round(rng.normal(0, 1, (n, k)) * 2) / 8, jnp.float32)
    params = mf.MFParams(p, q, None, None, None, None)
    ds = synthetic_ratings(num_users=m, num_items=n, num_ratings=400, seed=3)
    engine = ServingEngine(params, 0.0, 0.0, use_kernel=False, max_batch=16)
    got = R.evaluate_engine(engine, ds, topk=10)
    want = R.evaluate_oracle(params, ds, topk=10)
    assert got == want


def test_topk_equals_catalog_size():
    params, ds = _random_setup(m=12, n=40)
    engine = ServingEngine(params, 0.0, 0.0, use_kernel=False, max_batch=8)
    got = R.evaluate_engine(engine, ds, topk=40)   # K == n
    want = R.evaluate_oracle(params, ds, topk=40)
    assert got == want
    # every user's whole holdout is inside the full-catalog ranking
    assert got.hr == 1.0 and got.recall == 1.0


def test_pruned_engine_still_matches_pruned_oracle():
    # same thresholds both sides: the serving layouts introduce no error of
    # their own on top of pruning
    params, ds = _random_setup()
    t = 0.05
    engine = ServingEngine(params, t, t, use_kernel=False, max_batch=32)
    got = R.evaluate_engine(engine, ds, topk=10)
    want = R.evaluate_oracle(params, ds, topk=10, t_p=t, t_q=t)
    assert got == want


# ---------------------------------------------------------------------------
# the one-scan epoch variant
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("t", [0.0, 0.05])
def test_eval_ranking_epoch_scan_matches_oracle(t):
    params, ds = _random_setup()
    batches = R.pack_ranking_batches(ds, 16)
    sums = mf.eval_ranking_epoch_scan(
        params, batches, jnp.float32(t), jnp.float32(t), topk=10
    )
    got = R.report_from_sums(
        {key: float(val) for key, val in sums.items()}, 10
    )
    want = R.evaluate_oracle(params, ds, topk=10, t_p=t, t_q=t)
    assert got.users == want.users
    np.testing.assert_allclose(got.ndcg, want.ndcg, atol=1e-6)
    np.testing.assert_allclose(got.hr, want.hr, atol=1e-6)
    np.testing.assert_allclose(got.recall, want.recall, atol=1e-6)


def test_eval_ranking_epoch_scan_svdpp_history():
    m, n, k = 30, 300, 8
    params = mf.init_params(jax.random.PRNGKey(1), m, n, k, variant="svdpp",
                            global_mean=3.0)
    rng = np.random.default_rng(4)
    hist = rng.integers(0, n, (m, 5)).astype(np.int32)
    ds = synthetic_ratings(num_users=m, num_items=n, num_ratings=500, seed=5)
    batches = R.pack_ranking_batches(ds, 8)
    sums = mf.eval_ranking_epoch_scan(
        params, batches, jnp.float32(0.0), jnp.float32(0.0),
        jnp.asarray(hist), topk=9,
    )
    got = R.report_from_sums(
        {key: float(val) for key, val in sums.items()}, 9
    )
    want = R.evaluate_oracle(params, ds, topk=9, hist=hist)
    np.testing.assert_allclose(got.ndcg, want.ndcg, atol=1e-6)
    assert got.users == want.users


def test_trainer_logs_ranking_metrics():
    ds = synthetic_ratings(num_users=40, num_items=200, num_ratings=1200,
                           seed=0)
    train, test = train_test_split(ds, 0.25, seed=0)
    cfg = TrainConfig(k=8, epochs=2, batch_size=256, pruning_rate=0.3,
                      ranking_topk=10)
    trainer = DPMFTrainer(cfg, train, test)
    history = trainer.run()
    for record in history:
        assert 0.0 <= record.hr <= 1.0
        assert 0.0 <= record.ndcg <= 1.0
        assert 0.0 <= record.recall <= 1.0
    report = trainer.evaluate_ranking()
    assert report.topk == 10
    assert report.ndcg == pytest.approx(history[-1].ndcg)
    # off by default: no ranking fields, no packed batches
    plain = DPMFTrainer(TrainConfig(k=8, epochs=1, batch_size=256), train,
                        test)
    assert plain.evaluate_ranking() is None
    assert math.isnan(plain.run()[-1].ndcg)


# ---------------------------------------------------------------------------
# workload vectors: implicit-trained factors and SASRec session encodings
# ---------------------------------------------------------------------------


def _implicit_trained(seed=0):
    ds = synthetic_ratings(num_users=30, num_items=300, num_ratings=900,
                           seed=seed)
    train, test = train_test_split(ds, 0.25, seed=0)
    cfg = TrainConfig(k=8, epochs=2, batch_size=256, lr=0.02, lam=0.02,
                      pruning_rate=0.3, objective="implicit",
                      implicit_alpha=8.0, implicit_negatives=2, seed=seed)
    trainer = DPMFTrainer(cfg, train, test)
    trainer.run()
    return trainer, test


def test_implicit_trained_engine_matches_oracle_every_path():
    """Factors trained under the WALS objective serve exact top-k parity at
    threshold 0 on the streaming and kernel paths, and at the trained
    thresholds the engine still equals the equally-pruned oracle."""
    trainer, test = _implicit_trained()
    params = trainer.params
    want = R.evaluate_oracle(params, test, topk=10)
    for kw in (dict(use_kernel=False, max_batch=16),
               dict(use_kernel=True, interpret=True, max_batch=16)):
        engine = ServingEngine(params, 0.0, 0.0, **kw)
        assert R.evaluate_engine(engine, test, topk=10) == want, kw
    assert float(trainer.t_p) > 0.0   # calibration really ran
    pruned = ServingEngine(params, trainer.t_p, trainer.t_q,
                           use_kernel=False, max_batch=16)
    got = R.evaluate_engine(pruned, test, topk=10)
    want = R.evaluate_oracle(params, test, topk=10,
                             t_p=trainer.t_p, t_q=trainer.t_q)
    assert got == want


def _session_setup(seed=0, n_items=60, sessions=12):
    from repro.data import clicks
    from repro.models import recsys

    cfg = recsys.SASRecConfig(
        n_items=n_items, embed_dim=16, n_blocks=2, n_heads=2, seq_len=10
    )
    sasrec = recsys.init_sasrec_params(jax.random.PRNGKey(seed), cfg)
    seqs = clicks.sasrec_batch(
        sessions, seq_len=10, n_items=n_items, seed=seed
    )["seq"]
    return cfg, sasrec, jnp.asarray(seqs)


def test_sasrec_session_engine_matches_dense_oracle_every_path():
    from repro.models import recsys
    from repro.workloads import sequential

    cfg, sasrec, seqs = _session_setup()
    view = sequential.session_params(sasrec, seqs, cfg)
    sessions = np.arange(seqs.shape[0], dtype=np.int32)
    want_s, want_i = R.dense_topk(view, sessions, 10, t_p=0.0, t_q=0.0)
    for kw in (dict(use_kernel=False, max_batch=8),
               dict(use_kernel=True, interpret=True, max_batch=8)):
        engine = sequential.session_engine(sasrec, seqs, cfg, **kw)
        scores, ids = sequential.serve_sessions(engine, sessions, topk=10)
        assert np.array_equal(ids, np.asarray(want_i) + 1), kw
        assert np.array_equal(scores, np.asarray(want_s)), kw
    # the dense sasrec_retrieval argsort agrees too (padding row 0 dropped,
    # stable descending order = the same tie contract)
    dense = np.asarray(
        recsys.sasrec_retrieval(sasrec, seqs, cfg, 0.0, use_kernel=False)
    )[:, 1:]
    order = np.argsort(-dense, axis=1, kind="stable")[:, :10].astype(np.int32)
    assert np.array_equal(np.asarray(want_i), order)


def test_sasrec_session_pruned_and_full_catalog():
    """Session serving with a biting item threshold still matches the
    equally-pruned oracle, including topk == n (full catalog ranking)."""
    from repro.workloads import sequential

    cfg, sasrec, seqs = _session_setup(seed=1, n_items=40)
    view = sequential.session_params(sasrec, seqs, cfg)
    n = view.q.shape[0]
    sessions = np.arange(seqs.shape[0], dtype=np.int32)
    t_q = float(np.quantile(np.abs(np.asarray(view.q)), 0.4))
    engine = sequential.session_engine(
        sasrec, seqs, cfg, 0.0, t_q, use_kernel=False, max_batch=8
    )
    scores, ids = sequential.serve_sessions(engine, sessions, topk=n)
    want_s, want_i = R.dense_topk(view, sessions, n, t_p=0.0, t_q=t_q)
    assert np.array_equal(ids, np.asarray(want_i) + 1)
    assert np.array_equal(scores, np.asarray(want_s))
    # full-catalog ranking: every item id exactly once per session
    assert np.array_equal(np.sort(ids, axis=1),
                          np.tile(np.arange(1, n + 1), (len(sessions), 1)))


# ---------------------------------------------------------------------------
# sharded parity (runs meaningfully under the 4-device CI mesh job)
# ---------------------------------------------------------------------------


def test_evaluate_engine_sharded_matches_oracle_4device_mesh():
    """Ranking metrics through ``topk_sharded`` on the forced 4-device CPU
    mesh pin to the dense oracle exactly at t=0, and to the local pruned
    engine at trained thresholds.  Skipped unless the CI serving-mesh job's
    device count is forced."""
    if len(jax.devices()) < 4:
        pytest.skip("needs >= 4 devices (run under the 4-device CI mesh job)")
    params, ds = _random_setup(m=33, n=640, k=16)
    for shape, names in [((4,), ("model",)), ((2, 2), ("data", "model"))]:
        mesh = jax.make_mesh(shape, names)
        engine = ServingEngine(params, 0.0, 0.0, use_kernel=False,
                               max_batch=16)
        got = R.evaluate_engine(engine, ds, topk=8, mesh=mesh)
        want = R.evaluate_oracle(params, ds, topk=8)
        assert got == want, (shape, names)
        t = 0.05
        pruned = ServingEngine(params, t, t, use_kernel=False, max_batch=16)
        got = R.evaluate_engine(pruned, ds, topk=8, mesh=mesh)
        want = R.evaluate_engine(pruned, ds, topk=8)
        assert got == want, (shape, names)


def test_workload_vectors_sharded_match_oracle_4device_mesh():
    """The new workload vectors — implicit-trained factors and SASRec
    session encodings — keep exact oracle parity through ``topk_sharded``
    on the forced 4-device CPU mesh (the issue's acceptance bar)."""
    from repro.workloads import sequential

    if len(jax.devices()) < 4:
        pytest.skip("needs >= 4 devices (run under the 4-device CI mesh job)")
    trainer, test = _implicit_trained(seed=2)
    mesh = jax.make_mesh((4,), ("model",))
    engine = ServingEngine(trainer.params, 0.0, 0.0, use_kernel=False,
                           max_batch=16)
    got = R.evaluate_engine(engine, test, topk=8, mesh=mesh)
    want = R.evaluate_oracle(trainer.params, test, topk=8)
    assert got == want

    cfg, sasrec, seqs = _session_setup(seed=3)
    view = sequential.session_params(sasrec, seqs, cfg)
    sessions = np.arange(seqs.shape[0], dtype=np.int32)
    sengine = sequential.session_engine(
        sasrec, seqs, cfg, use_kernel=False, max_batch=8
    )
    scores, ids = sequential.serve_sessions(
        sengine, sessions, topk=8, mesh=mesh
    )
    want_s, want_i = R.dense_topk(view, sessions, 8, t_p=0.0, t_q=0.0)
    assert np.array_equal(ids, np.asarray(want_i) + 1)
    assert np.array_equal(scores, np.asarray(want_s))
