"""Hot swap + publisher + the end-to-end freshness contract.

Covers the acceptance criteria of the online subsystem: per-version
determinism of the engine's atomic swap, touched-rows-only cache/layout
invalidation, delta-checkpoint durability, zero dropped requests across
swaps under concurrent load, and model freshness (recommendations move,
MAE stays within 5% of a full retrain, pruned updates do less work).
"""
import threading
import time

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import mf
from repro.core.trainer import DPMFTrainer, TrainConfig
from repro.data import synthetic_ratings, train_test_split
from repro.data.ratings import RatingsDataset
from repro.online import (
    EventBatch,
    OnlineUpdater,
    ReplaySource,
    SnapshotPublisher,
    fold_deltas,
    iter_microbatches,
)
from repro.serving import ServingEngine, load_mf_checkpoint


def _params(m=40, n=600, k=16, variant="bias", seed=0):
    return mf.init_params(
        jax.random.PRNGKey(seed), m, n, k, variant=variant, global_mean=3.0
    )


def _perturb(params, touched_items, touched_users, seed=0, scale=0.05):
    rng = np.random.default_rng(seed)
    q = np.array(params.q)
    q[touched_items] += rng.normal(0, scale, (len(touched_items), q.shape[1])).astype(np.float32)
    p = np.array(params.p)
    p[touched_users] += rng.normal(0, scale, (len(touched_users), p.shape[1])).astype(np.float32)
    return params._replace(p=jnp.asarray(p), q=jnp.asarray(q))


# ---------------------------------------------------------------------------
# engine.swap semantics
# ---------------------------------------------------------------------------


def test_swap_incremental_matches_fresh_engine():
    """A touched-rows swap must serve exactly what a cold engine built from
    the new params serves — the patched tile/kernel layouts are not an
    approximation."""
    params = _params()
    engine = ServingEngine(params, 0.03, 0.03, use_kernel=False, block_n=128)
    users = np.arange(25, dtype=np.int32)
    engine.topk(users, 7)  # build the layout the swap will patch
    touched_i = np.asarray([0, 5, 128, 129, 599])
    touched_u = np.asarray([3, 9])
    new_params = _perturb(params, touched_i, touched_u)
    version = engine.swap(new_params, touched_users=touched_u,
                          touched_items=touched_i)
    assert version == 1 and engine.version == 1
    fresh = ServingEngine(new_params, 0.03, 0.03, use_kernel=False,
                          block_n=128)
    got_s, got_i = engine.topk(users, 7)
    want_s, want_i = fresh.topk(users, 7)
    assert np.array_equal(want_i, got_i)
    np.testing.assert_allclose(want_s, got_s, rtol=0, atol=0)


def test_swap_kernel_layout_patched():
    params = _params()
    engine = ServingEngine(params, 0.03, 0.03, use_kernel=True,
                           interpret=True, max_batch=16)
    users = np.arange(9, dtype=np.int32)
    engine.topk(users, 5)
    touched_i = np.asarray([1, 2, 300])
    new_params = _perturb(params, touched_i, np.asarray([0]))
    engine.swap(new_params, touched_users=[0], touched_items=touched_i)
    fresh = ServingEngine(new_params, 0.03, 0.03, use_kernel=True,
                          interpret=True, max_batch=16)
    got_s, got_i = engine.topk(users, 5)
    want_s, want_i = fresh.topk(users, 5)
    assert np.array_equal(want_i, got_i)
    np.testing.assert_allclose(want_s, got_s, rtol=0, atol=0)


def test_swap_threshold_change_forces_consistent_rebuild():
    """A swap that changes t_q cannot patch (every mask may change): it must
    rebuild and still match a fresh engine."""
    params = _params()
    engine = ServingEngine(params, 0.03, 0.03, use_kernel=False, block_n=128)
    engine.topk([0, 1], 5)
    engine.swap(params, 0.03, 0.06, touched_users=[], touched_items=[])
    fresh = ServingEngine(params, 0.03, 0.06, use_kernel=False, block_n=128)
    users = np.arange(20, dtype=np.int32)
    got_s, got_i = engine.topk(users, 6)
    want_s, want_i = fresh.topk(users, 6)
    assert np.array_equal(want_i, got_i)
    np.testing.assert_allclose(want_s, got_s, rtol=0, atol=0)


def test_swap_growth_and_shrink_rejected():
    params = _params(m=10, n=50, k=8)
    engine = ServingEngine(params, 0.0, 0.0, use_kernel=False, block_n=32)
    engine.topk([0], 5)
    grown = mf.init_params(jax.random.PRNGKey(1), 14, 60, 8, variant="bias",
                           global_mean=3.0)
    engine.swap(grown, touched_users=None, touched_items=None)
    assert engine.num_users == 14 and engine.n_items == 60
    s, i = engine.topk([13], 5)  # the new user is servable
    assert s.shape == (1, 5)
    with pytest.raises(ValueError, match="shrink"):
        engine.swap(params)


def test_swap_versions_are_deterministic_per_batch():
    """Results must come from exactly one version: a batch scored before the
    swap equals version-0 output, after equals version-1, and nothing in
    between ever mixes rows."""
    params = _params()
    engine = ServingEngine(params, 0.0, 0.0, use_kernel=False, block_n=128)
    users = np.arange(16, dtype=np.int32)
    v0_s, v0_i = engine.topk(users, 6)
    new_params = _perturb(params, np.arange(600), np.arange(40), scale=0.2)
    engine.swap(new_params, touched_users=None, touched_items=None)
    v1_s, v1_i = engine.topk(users, 6)
    fresh0 = ServingEngine(params, 0.0, 0.0, use_kernel=False, block_n=128)
    fresh1 = ServingEngine(new_params, 0.0, 0.0, use_kernel=False,
                           block_n=128)
    assert np.array_equal(v0_i, fresh0.topk(users, 6)[1])
    assert np.array_equal(v1_i, fresh1.topk(users, 6)[1])
    assert not np.array_equal(v0_i, v1_i)  # the swap actually changed output


def test_swap_touched_only_lru_invalidation_svdpp():
    """Untouched users keep their cached vectors across a swap; touched
    users and users whose HISTORY contains a touched implicit row are
    evicted — and post-swap results still match a cold engine exactly."""
    m, n, k = 20, 60, 8
    params = _params(m, n, k, variant="svdpp")
    rng = np.random.default_rng(0)
    hist = rng.integers(0, n, (m, 4)).astype(np.int32)
    hist[7] = [50, 51, 52, 53]     # user 7's history hits touched item 50
    hist[5] = [10, 11, 12, 13]     # user 5's history avoids touched rows
    engine = ServingEngine(params, 0.0, 0.0, use_kernel=False, block_n=32,
                           user_history=hist)
    engine.topk([3, 5, 7], 5)      # warm the cache
    assert len(engine.vector_cache) == 3

    touched_u, touched_i = [3], [50]
    new_params = _perturb(params, np.asarray(touched_i),
                          np.asarray(touched_u))
    y = np.array(params.implicit)
    y[50] += 0.3
    new_params = new_params._replace(implicit=jnp.asarray(y))
    engine.swap(new_params, touched_users=touched_u,
                touched_items=touched_i, touched_implicit_items=touched_i)

    # user 5 survived; users 3 (touched) and 7 (history hit) were evicted
    assert engine.vector_cache.get(5) is not None
    assert engine.vector_cache.get(3) is None
    assert engine.vector_cache.get(7) is None
    fresh = ServingEngine(new_params, 0.0, 0.0, use_kernel=False,
                          block_n=32, user_history=hist)
    got_s, got_i = engine.topk([3, 5, 7], 5)
    want_s, want_i = fresh.topk([3, 5, 7], 5)
    assert np.array_equal(want_i, got_i)
    np.testing.assert_allclose(want_s, got_s, rtol=0, atol=0)


# ---------------------------------------------------------------------------
# engine lifecycle (stop/start restart — regression for the swap-time drain)
# ---------------------------------------------------------------------------


def test_engine_stop_start_restart_cycle():
    params = _params(m=16, n=100, k=8, variant="funk")
    engine = ServingEngine(params, 0.0, 0.0, use_kernel=False, block_n=64)
    engine.stop()                      # stop before any start: no-op
    engine.start()
    s0 = engine.submit(1, 4).result(timeout=60)
    engine.stop()
    engine.stop()                      # idempotent
    engine.start()                     # restart after stop must work
    s1 = engine.submit(1, 4).result(timeout=60)
    assert np.array_equal(s0[1], s1[1])
    engine.stop()
    # submit after stop auto-starts a fresh queue
    s2 = engine.submit(1, 4).result(timeout=60)
    assert np.array_equal(s0[1], s2[1])
    engine.stop()


def test_engine_start_replaces_externally_closed_queue():
    params = _params(m=16, n=100, k=8, variant="funk")
    engine = ServingEngine(params, 0.0, 0.0, use_kernel=False, block_n=64)
    queue = engine.start()
    queue.close()                      # closed behind the engine's back
    queue2 = engine.start()            # must not raise "already running"
    assert queue2 is not queue
    engine.submit(0, 3).result(timeout=60)
    engine.stop()


# ---------------------------------------------------------------------------
# publisher + delta checkpoints
# ---------------------------------------------------------------------------


def test_publisher_delta_checkpoints_fold_to_live_state(tmp_path):
    ds = synthetic_ratings(60, 90, 3000, seed=0)
    train_ds, stream_ds = train_test_split(ds, 0.3, seed=0)
    cfg = TrainConfig(k=8, epochs=2, batch_size=512, pruning_rate=0.3,
                      variant="bias", checkpoint_dir=str(tmp_path / "base"))
    trainer = DPMFTrainer(cfg, train_ds, None)
    trainer.run()

    upd = OnlineUpdater.from_trainer(trainer, batch_size=64)
    engine = ServingEngine(trainer.params, trainer.t_p, trainer.t_q,
                           use_kernel=False, block_n=64)
    pub = SnapshotPublisher(engine, upd,
                            checkpoint_dir=str(tmp_path / "online"))
    for i, mb in enumerate(iter_microbatches(ReplaySource(stream_ds), 64)):
        upd.apply(mb)
        if i % 2 == 1:
            pub.publish()
    pub.publish()
    pub.close()

    base_params, t_p, t_q, _, _ = load_mf_checkpoint(str(tmp_path / "base"))
    folded, f_tp, f_tq, _, last = fold_deltas(
        str(tmp_path / "online"), base_params, t_p, t_q
    )
    np.testing.assert_array_equal(np.asarray(folded.p),
                                  np.asarray(upd.params.p))
    np.testing.assert_array_equal(np.asarray(folded.q),
                                  np.asarray(upd.params.q))
    np.testing.assert_array_equal(np.asarray(folded.user_bias),
                                  np.asarray(upd.params.user_bias))
    assert float(f_tq) == float(upd.t_q)
    assert last == engine.version


def test_publisher_full_checkpoint_after_recalibration(tmp_path):
    ds = synthetic_ratings(60, 90, 3000, seed=0)
    train_ds, stream_ds = train_test_split(ds, 0.3, seed=0)
    cfg = TrainConfig(k=8, epochs=2, batch_size=512, pruning_rate=0.3)
    trainer = DPMFTrainer(cfg, train_ds, None)
    trainer.run()
    upd = OnlineUpdater.from_trainer(trainer, batch_size=64)
    engine = ServingEngine(trainer.params, trainer.t_p, trainer.t_q,
                           use_kernel=False, block_n=64)
    pub = SnapshotPublisher(engine, upd,
                            checkpoint_dir=str(tmp_path / "online"))
    for mb in iter_microbatches(ReplaySource(stream_ds), 64):
        upd.apply(mb)
    assert upd.maybe_recalibrate(force=True) is not None
    report = pub.publish()
    pub.close()
    assert report.full_rebuild
    # a permuted latent axis cannot ride a row delta: the chain stays exact
    folded, _, _, _, _ = fold_deltas(
        str(tmp_path / "online"), trainer.params, trainer.t_p, trainer.t_q
    )
    np.testing.assert_array_equal(np.asarray(folded.p),
                                  np.asarray(upd.params.p))


# ---------------------------------------------------------------------------
# zero-downtime: swaps under concurrent load (acceptance criterion c)
# ---------------------------------------------------------------------------


def test_swaps_under_concurrent_load_drop_nothing():
    """>= 3 hot swaps while client threads hammer the async queue: every
    request completes, with the correct shape, from exactly one version."""
    params = _params(m=48, n=800, k=16)
    engine = ServingEngine(params, 0.03, 0.03, use_kernel=False, block_n=128)
    upd = OnlineUpdater(params, None, 0.03, 0.03, batch_size=64, lr=0.1)
    pub = SnapshotPublisher(engine, upd)
    for b in (1, 2, 4, 8):
        engine.topk(list(range(b)), 5)  # warm the buckets
    engine.start(linger_ms=1.0)

    stop = threading.Event()
    failures, completed = [], [0]
    lock = threading.Lock()

    def client(seed):
        rng = np.random.default_rng(seed)
        while not stop.is_set():
            user = int(rng.integers(0, 48))
            try:
                s, i = engine.submit(user, 5, timeout=60).result(timeout=120)
                assert s.shape == (5,) and i.shape == (5,)
                with lock:
                    completed[0] += 1
            except Exception as exc:  # noqa: BLE001
                with lock:
                    failures.append(repr(exc))

    threads = [threading.Thread(target=client, args=(s,)) for s in range(6)]
    for t in threads:
        t.start()
    rng = np.random.default_rng(9)
    try:
        for _ in range(4):  # > 3 consecutive swaps under load
            upd.apply(EventBatch(
                user=rng.integers(0, 48, 64).astype(np.int32),
                item=rng.integers(0, 800, 64).astype(np.int32),
                rating=rng.uniform(1, 5, 64).astype(np.float32),
            ))
            pub.publish()
            time.sleep(0.05)
    finally:
        stop.set()
        for t in threads:
            t.join(timeout=120)
        engine.stop()
    assert engine.version == 4
    assert not failures, failures[:5]
    assert completed[0] > 0


# ---------------------------------------------------------------------------
# end-to-end freshness (acceptance criteria a, b, d)
# ---------------------------------------------------------------------------


def _concat(a: RatingsDataset, b: RatingsDataset) -> RatingsDataset:
    return RatingsDataset(
        user=np.concatenate([a.user, b.user]),
        item=np.concatenate([a.item, b.item]),
        rating=np.concatenate([a.rating, b.rating]),
        num_users=a.num_users, num_items=a.num_items,
        rating_min=a.rating_min, rating_max=a.rating_max,
    )


def test_online_freshness_end_to_end():
    """Train -> serve -> stream held-out events -> hot-swap:

    (a) recommendations for touched users move to reflect new interactions;
    (b) online MAE lands within 5% of a full retrain on the same events;
    (d) the pruned incremental updates did measurably less than dense work.
    """
    ds = synthetic_ratings(200, 300, 15000, seed=0)
    rest, test_ds = train_test_split(ds, 0.2, seed=0)
    train_ds, stream_ds = train_test_split(rest, 0.25, seed=1)
    # epoch_mode="python" pins the host-loader data order this test's tight
    # 5% retrain-vs-online margin was calibrated against: at this toy scale
    # the pruning-threshold calibration after epoch 1 is sensitive to the
    # shuffle order, and the scan path draws a different (equally valid)
    # permutation.  The online subsystem under test is order-independent.
    cfg = TrainConfig(k=16, epochs=4, batch_size=1024, pruning_rate=0.3,
                      epoch_mode="python")

    retrain = DPMFTrainer(cfg, _concat(train_ds, stream_ds), test_ds)
    retrain.run()
    mae_retrain = retrain.evaluate()

    base = DPMFTrainer(cfg, train_ds, test_ds)
    base.run()
    engine = ServingEngine(base.params, base.t_p, base.t_q,
                           use_kernel=False, block_n=128)
    touched_users = np.unique(stream_ds.user)[:40]
    before_i = engine.topk(touched_users, 10)[1]

    upd = OnlineUpdater.from_trainer(base, batch_size=256, lr=0.02)
    pub = SnapshotPublisher(engine, upd)
    for ep in range(4):
        for mb in iter_microbatches(
            ReplaySource(stream_ds, shuffle=True, seed=ep), 256
        ):
            upd.apply(mb)
        pub.publish()

    # (d) pruned incremental updates skipped work
    assert upd.mean_work_fraction < 1.0

    # (a) the model moved for users with new interactions: their live top-10
    # changed for a clear majority (every set would be too strict — some
    # users' lists are genuinely stable)
    after_i = engine.topk(touched_users, 10)[1]
    changed = sum(
        not np.array_equal(before_i[r], after_i[r])
        for r in range(len(touched_users))
    )
    assert changed >= len(touched_users) // 2, (
        f"only {changed}/{len(touched_users)} touched users' top-10 moved"
    )
    # and the engine serves the updater's exact state (swap did its job)
    fresh = ServingEngine(upd.params, upd.t_p, upd.t_q,
                          use_kernel=False, block_n=128)
    np.testing.assert_array_equal(
        engine.topk(touched_users, 10)[1], fresh.topk(touched_users, 10)[1]
    )

    # (b) freshness quality: within 5% of the full retrain
    mae_online = upd.evaluate(test_ds)
    assert mae_online <= 1.05 * mae_retrain, (
        f"online MAE {mae_online:.4f} vs retrain {mae_retrain:.4f}"
    )


def test_online_svdpp_freshness_smoke():
    """SVD++ end to end: stream events extend histories, implicit rows
    update, the hot swap keeps serving exact (cold-engine-equal) results."""
    ds = synthetic_ratings(80, 120, 5000, seed=3)
    train_ds, stream_ds = train_test_split(ds, 0.25, seed=3)
    cfg = TrainConfig(k=8, epochs=2, batch_size=512, pruning_rate=0.3,
                      variant="svdpp", max_hist=8)
    trainer = DPMFTrainer(cfg, train_ds, None)
    trainer.run()
    upd = OnlineUpdater.from_trainer(trainer, batch_size=64)
    engine = ServingEngine(trainer.params, trainer.t_p, trainer.t_q,
                           use_kernel=False, block_n=64,
                           user_history=trainer.hist)
    pub = SnapshotPublisher(engine, upd)
    users = np.arange(30, dtype=np.int32)
    engine.topk(users, 6)  # warm cache + layout
    for mb in iter_microbatches(ReplaySource(stream_ds), 64, max_events=256):
        upd.apply(mb)
        pub.publish()
    fresh = ServingEngine(upd.params, upd.t_p, upd.t_q, use_kernel=False,
                          block_n=64, user_history=upd.user_history)
    got_s, got_i = engine.topk(users, 6)
    want_s, want_i = fresh.topk(users, 6)
    assert np.array_equal(want_i, got_i)
    np.testing.assert_allclose(want_s, got_s, rtol=0, atol=0)


def test_delta_fold_across_cold_start_growth(tmp_path):
    """Growth stays a row delta: folding the chain must grow the base tables
    and land exactly on the live state."""
    params = _params(m=10, n=40, k=8)
    upd = OnlineUpdater(params, None, 0.0, 0.0, batch_size=32, seed=2)
    engine = ServingEngine(params, 0.0, 0.0, use_kernel=False, block_n=32)
    pub = SnapshotPublisher(engine, upd,
                            checkpoint_dir=str(tmp_path / "online"))
    rng = np.random.default_rng(4)
    upd.apply(EventBatch(user=rng.integers(0, 10, 16).astype(np.int32),
                         item=rng.integers(0, 40, 16).astype(np.int32),
                         rating=rng.uniform(1, 5, 16).astype(np.float32)))
    pub.publish()
    upd.apply(EventBatch(user=np.asarray([13], np.int32),     # grows users
                         item=np.asarray([45], np.int32),     # grows items
                         rating=np.asarray([5.0], np.float32)))
    report = pub.publish()
    pub.close()
    assert not report.full_rebuild  # growth rides a delta, not a full dump
    folded, _, _, _, last = fold_deltas(
        str(tmp_path / "online"), params, 0.0, 0.0
    )
    assert folded.p.shape == (14, 8) and folded.q.shape == (46, 8)
    np.testing.assert_array_equal(np.asarray(folded.p),
                                  np.asarray(upd.params.p))
    np.testing.assert_array_equal(np.asarray(folded.q),
                                  np.asarray(upd.params.q))
    assert last == engine.version


def test_delta_chain_gc_anchor_and_break_detection(tmp_path):
    """Keep-N retention deletes old deltas; the publisher's periodic full
    anchors keep the surviving window replayable, and a chain with a
    missing predecessor raises instead of silently reconstructing stale
    state."""
    params = _params(m=12, n=30, k=8)
    upd = OnlineUpdater(params, None, 0.0, 0.0, batch_size=16, seed=0)
    engine = ServingEngine(params, 0.0, 0.0, use_kernel=False, block_n=32)
    keep = 4
    pub = SnapshotPublisher(engine, upd, keep=keep,
                            checkpoint_dir=str(tmp_path / "online"))
    rng = np.random.default_rng(1)
    for _ in range(10):  # > keep publishes: early deltas are GC'd
        upd.apply(EventBatch(
            user=rng.integers(0, 12, 16).astype(np.int32),
            item=rng.integers(0, 30, 16).astype(np.int32),
            rating=rng.uniform(1, 5, 16).astype(np.float32),
        ))
        pub.publish()
        pub.close()  # join each save so retention is deterministic
    from repro.checkpoint import checkpoint as ckpt_lib
    steps = ckpt_lib.all_steps(str(tmp_path / "online"))
    assert len(steps) == keep  # retention kicked in
    # the fold still reconstructs the exact live state (full anchor survives)
    folded, _, _, _, _ = fold_deltas(
        str(tmp_path / "online"), params, 0.0, 0.0
    )
    np.testing.assert_array_equal(np.asarray(folded.p),
                                  np.asarray(upd.params.p))
    np.testing.assert_array_equal(np.asarray(folded.q),
                                  np.asarray(upd.params.q))
    # sabotage: delete the anchor so the surviving deltas have no base
    fulls = [s for s in steps
             if ckpt_lib.load_metadata(str(tmp_path / "online"), s)["kind"]
             == "full"]
    assert fulls, "publisher must have written a periodic full anchor"
    for s in fulls:
        ckpt_lib._remove_step(str(tmp_path / "online"), s)
    with pytest.raises(ValueError, match="chain broken"):
        fold_deltas(str(tmp_path / "online"), params, 0.0, 0.0)


def test_publisher_resume_continues_chain_with_full_anchor(tmp_path):
    """A restarted publisher (fresh engine at version 0) must NOT overwrite
    existing chain steps: step numbering resumes from the directory frontier
    and the first post-restart checkpoint is a full anchor, so fold_deltas
    reconstructs the post-restart state."""
    params = _params(m=12, n=30, k=8)
    rng = np.random.default_rng(3)

    def feed(upd, pub, rounds):
        for _ in range(rounds):
            upd.apply(EventBatch(
                user=rng.integers(0, 12, 16).astype(np.int32),
                item=rng.integers(0, 30, 16).astype(np.int32),
                rating=rng.uniform(1, 5, 16).astype(np.float32),
            ))
            pub.publish()
        pub.close()

    # run 1: three deltas at steps 1..3
    upd1 = OnlineUpdater(params, None, 0.0, 0.0, batch_size=16, seed=0)
    eng1 = ServingEngine(params, 0.0, 0.0, use_kernel=False, block_n=32)
    pub1 = SnapshotPublisher(eng1, upd1,
                             checkpoint_dir=str(tmp_path / "online"))
    feed(upd1, pub1, 3)

    # restart: resume from the folded state, engine version resets to 0
    folded, f_tp, f_tq, _, last = fold_deltas(
        str(tmp_path / "online"), params, 0.0, 0.0
    )
    assert last == 3
    upd2 = OnlineUpdater(folded, None, f_tp, f_tq, batch_size=16, seed=1)
    eng2 = ServingEngine(folded, f_tp, f_tq, use_kernel=False, block_n=32)
    pub2 = SnapshotPublisher(eng2, upd2,
                             checkpoint_dir=str(tmp_path / "online"))
    feed(upd2, pub2, 2)

    from repro.checkpoint import checkpoint as ckpt_lib
    steps = ckpt_lib.all_steps(str(tmp_path / "online"))
    assert steps == [1, 2, 3, 4, 5]  # nothing overwritten
    meta4 = __import__("json").load(open(
        tmp_path / "online" / "step_000000000004" / "metadata.json"))
    assert meta4["kind"] == "full"  # post-restart anchor
    refolded, _, _, _, last2 = fold_deltas(
        str(tmp_path / "online"), params, 0.0, 0.0
    )
    assert last2 == 5
    np.testing.assert_array_equal(np.asarray(refolded.p),
                                  np.asarray(upd2.params.p))
    np.testing.assert_array_equal(np.asarray(refolded.q),
                                  np.asarray(upd2.params.q))


def test_swap_accepts_one_shot_iterators():
    """The touched sets are walked several times inside swap (layout patch,
    user-const patch, LRU pruning): generator arguments must behave exactly
    like lists, not silently empty out after the first pass."""
    params = _params()
    engine = ServingEngine(params, 0.03, 0.03, use_kernel=False, block_n=128)
    users = np.arange(25, dtype=np.int32)
    engine.topk(users, 7)
    touched_i = [0, 5, 599]
    touched_u = [3, 9]
    new_params = _perturb(params, np.asarray(touched_i),
                          np.asarray(touched_u))
    engine.swap(new_params, touched_users=iter(touched_u),
                touched_items=iter(touched_i))
    fresh = ServingEngine(new_params, 0.03, 0.03, use_kernel=False,
                          block_n=128)
    got_s, got_i = engine.topk(users, 7)
    want_s, want_i = fresh.topk(users, 7)
    assert np.array_equal(want_i, got_i)
    np.testing.assert_allclose(want_s, got_s, rtol=0, atol=0)
