"""Property tests: `kernels/pruned_topk.py` vs the dense argsort oracle.

Factors (and biases) are drawn on the 1/8 grid, so every pruned dot product
is a multiple of 1/64 well inside f32's exact-integer range: all scoring
paths compute the *exact* mathematical score regardless of tile shape or
summation order.  That makes two strong assertions safe:

* scores match the oracle **bitwise**, not just within a tolerance;
* score ties (e.g. duplicated item rows) are mathematically exact, so index
  parity genuinely pins the tie-breaking contract (lower item index wins,
  the stable-argsort order) across the streaming scan, the Pallas kernel's
  max-extraction merge, and the oracle.

Hypothesis drives the shape/threshold/duplication space (skipped gracefully
when hypothesis is absent — see ``hypothesis_compat``); the parametrized
edge cases below run everywhere and share the same checker, covering the
corners the issue names: ragged ranks, duplicate scores, ``topk == n``, and
tiny/odd tile shapes.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from hypothesis_compat import given, settings, st
from repro.core.ranks import effective_ranks
from repro.kernels import ops, ref


def _grid(rng, shape):
    """f32 values on the 1/8 grid in [-2, 2] — exactly representable."""
    return (rng.integers(-16, 17, shape) / 8.0).astype(np.float32)


def _duplicate_rows(rng, q):
    """Copy random rows over random rows: exact score ties across items."""
    n = q.shape[0]
    count = max(1, n // 2)
    q = q.copy()
    q[rng.integers(0, n, count)] = q[rng.integers(0, n, count)]
    return q


def _check_case(p, q, t_p, t_q, topk, bias, *, use_kernel, **blocks):
    p, q = jnp.asarray(p), jnp.asarray(q)
    b = None if bias is None else jnp.asarray(bias)
    r_u, r_i = effective_ranks(p, t_p), effective_ranks(q, t_q)
    want_s, want_i = ref.pruned_topk_ref(p, q, r_u, r_i, topk, item_bias=b)
    got_s, got_i = ops.pruned_topk(
        p, q, t_p, t_q, topk,
        item_bias=b, use_kernel=use_kernel, interpret=True, **blocks,
    )
    assert np.array_equal(np.asarray(want_i), np.asarray(got_i)), (
        "indices diverged from the dense argsort oracle"
    )
    assert np.array_equal(np.asarray(want_s), np.asarray(got_s)), (
        "scores diverged (grid inputs make exact equality the contract)"
    )


# ---------------------------------------------------------------------------
# hypothesis: the shape / threshold / tie space
# ---------------------------------------------------------------------------

_THRESHOLDS = [0.0, 1 / 16, 1 / 8, 3 / 8]  # 0 disables pruning; 3/8 is harsh


@st.composite
def topk_cases(draw):
    m = draw(st.integers(1, 20))
    n = draw(st.integers(1, 80))
    k = draw(st.integers(1, 24))
    topk = draw(st.integers(1, n))
    rng = np.random.default_rng(draw(st.integers(0, 2**31 - 1)))
    p = _grid(rng, (m, k))
    q = _grid(rng, (n, k))
    if draw(st.booleans()) and n >= 2:
        q = _duplicate_rows(rng, q)
    t_p = draw(st.sampled_from(_THRESHOLDS))
    t_q = draw(st.sampled_from(_THRESHOLDS))
    bias = _grid(rng, (n,)) if draw(st.booleans()) else None
    return p, q, t_p, t_q, topk, bias


@given(topk_cases(), st.sampled_from([1, 3, 7, 16, 128]))
@settings(max_examples=30, deadline=None)
def test_streaming_topk_property(case, block_n):
    """Ragged ranks, duplicate scores, k >= n, odd streaming tile widths."""
    p, q, t_p, t_q, topk, bias = case
    _check_case(p, q, t_p, t_q, topk, bias, use_kernel=False, block_n=block_n)


@given(topk_cases())
@settings(max_examples=10, deadline=None)
def test_pallas_kernel_topk_property(case):
    """Same space through the Pallas kernel (interpret mode) at small/odd
    block shapes, so tile padding, K-block skipping, and the in-kernel
    max-extraction merge all see ragged boundaries."""
    p, q, t_p, t_q, topk, bias = case
    _check_case(
        p, q, t_p, t_q, topk, bias,
        use_kernel=True, block_m=8, block_n=16, block_k=8,
    )


# ---------------------------------------------------------------------------
# deterministic edge cases (run with or without hypothesis)
# ---------------------------------------------------------------------------

_EDGE_CASES = [
    # (m, n, k, topk, t, dup, bias) — named by what they corner
    pytest.param(1, 1, 1, 1, 0.0, False, False, id="degenerate-1x1x1"),
    pytest.param(5, 9, 3, 9, 1 / 16, False, True, id="topk-equals-n"),
    pytest.param(8, 33, 7, 5, 1 / 8, True, True, id="dup-ties-odd-shapes"),
    pytest.param(16, 130, 24, 17, 3 / 8, True, False, id="harsh-ragged-ranks"),
    pytest.param(3, 12, 4, 12, 10.0, False, True, id="all-ranks-zero"),
]


@pytest.mark.parametrize("use_kernel", [False, True], ids=["stream", "kernel"])
@pytest.mark.parametrize("m,n,k,topk,t,dup,bias", _EDGE_CASES)
def test_topk_edge_cases(m, n, k, topk, t, dup, bias, use_kernel):
    rng = np.random.default_rng(m * 1000 + n)
    p = _grid(rng, (m, k))
    q = _grid(rng, (n, k))
    if dup and n >= 2:
        q = _duplicate_rows(rng, q)
    b = _grid(rng, (n,)) if bias else None
    blocks = (
        dict(block_m=8, block_n=16, block_k=8) if use_kernel
        else dict(block_n=7)
    )
    _check_case(p, q, t, t, topk, b, use_kernel=use_kernel, **blocks)


def test_topk_out_of_range_raises():
    """k > n is a request error, not a deep lax.top_k trace failure."""
    rng = np.random.default_rng(0)
    p, q = _grid(rng, (4, 8)), _grid(rng, (16, 8))
    for use_kernel in (False, True):
        with pytest.raises(ValueError, match="topk"):
            ops.pruned_topk(p, q, 0.0, 0.0, 17, use_kernel=use_kernel)
        with pytest.raises(ValueError, match="topk"):
            ops.pruned_topk(p, q, 0.0, 0.0, 0, use_kernel=use_kernel)


@pytest.mark.parametrize("use_kernel", [False, True], ids=["stream", "kernel"])
@pytest.mark.parametrize("t", [0.0, 1 / 16, 1 / 8])
def test_sasrec_session_vectors_topk_parity(t, use_kernel):
    """Session-shaped factor pairs through both top-k paths.

    Real SASRec final-state encodings (``workloads.sequential``) scored
    against the item embedding table — snapped to the 1/8 grid so the
    file's bitwise-equality contract holds through the kernel's split-k
    reduction.  This is the serving geometry the sequential workload
    produces: p rows are transformer outputs (dense, unnormalized), q is an
    embedding table with its padding row dropped, no biases, topk == n.
    """
    from repro.data import clicks
    from repro.models import recsys
    from repro.workloads import sequential

    cfg = recsys.SASRecConfig(
        n_items=33, embed_dim=16, n_blocks=2, n_heads=2, seq_len=8
    )
    import jax

    sasrec = recsys.init_sasrec_params(jax.random.PRNGKey(4), cfg)
    seqs = clicks.sasrec_batch(9, seq_len=8, n_items=33, seed=4)["seq"]
    view = sequential.session_params(sasrec, jnp.asarray(seqs), cfg)
    # snap to the grid; rescale first so the thresholds bite mid-row
    snap = lambda a: np.round(np.asarray(a) * 8.0).astype(np.float32) / 8.0
    p = snap(view.p)
    q = snap(view.q * 40.0)   # embed init is ~0.01-scale: lift onto the grid
    assert (np.abs(q) > 0).any()
    blocks = (
        dict(block_m=8, block_n=16, block_k=8) if use_kernel
        else dict(block_n=7)
    )
    _check_case(p, q, t, t, q.shape[0], None, use_kernel=use_kernel, **blocks)


def test_total_pruning_serves_bias_order():
    """Thresholds above every |factor|: all ranks 0, every dot product empty
    — the top-k must then be exactly the bias ordering (maximal tie stress
    everywhere bias repeats)."""
    rng = np.random.default_rng(7)
    p, q = _grid(rng, (6, 5)), _grid(rng, (40, 5))
    bias = _grid(rng, (40,))
    s, i = ops.pruned_topk(
        p, q, 10.0, 10.0, 40, item_bias=jnp.asarray(bias), use_kernel=False
    )
    order = np.argsort(-bias, kind="stable").astype(np.int32)
    assert np.array_equal(np.asarray(i), np.tile(order, (6, 1)))
    assert np.array_equal(np.asarray(s), np.tile(bias[order], (6, 1)))
