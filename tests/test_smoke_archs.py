"""Per-architecture smoke tests: a REDUCED config of each assigned arch runs
one forward/train step on CPU; shapes and finiteness asserted.  The full
configs are exercised only via the dry-run (ShapeDtypeStructs)."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import configs as cfg_lib
from repro.data import clicks
from repro.data import graphs as gd
from repro.models import gnn, recsys
from repro.models import transformer as tfm
from repro.optim.optimizers import Adam, Sgd

LM_ARCHS = [
    "gemma-7b", "qwen1.5-4b", "qwen3-4b", "deepseek-v2-lite-16b",
    "granite-moe-1b-a400m",
]


def test_registry_covers_assignment():
    assert set(cfg_lib.ASSIGNED_ARCHS) == {
        "gemma-7b", "qwen1.5-4b", "qwen3-4b", "deepseek-v2-lite-16b",
        "granite-moe-1b-a400m", "gat-cora", "fm", "sasrec", "bst",
        "dlrm-mlperf",
    }
    # 40 assigned cells (5 LM x 4 + 1 GNN x 4 + 4 recsys x 4)
    assert len(cfg_lib.all_cells(include_dpmf=False)) == 40


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_train_and_decode(arch):
    cfg = cfg_lib.get_smoke_config(arch)
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}

    loss0 = tfm.lm_loss(params, batch, cfg)
    assert np.isfinite(float(loss0))

    opt = Adam(lr=1e-2)
    state = opt.init(params)
    loss, grads = jax.value_and_grad(lambda p: tfm.lm_loss(p, batch, cfg))(params)
    params2, _ = opt.apply(params, state, grads)
    loss1 = tfm.lm_loss(params2, batch, cfg)
    assert np.isfinite(float(loss1))
    assert float(loss1) < float(loss0), "one Adam step should reduce loss"

    # decode one token against a cache; logits shape (B, V), no NaNs
    st = tfm.init_decode_state(cfg, 2, 32)
    logits, st = tfm.decode_step(params2, tokens[:, :1], st, cfg)
    assert logits.shape == (2, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert int(st.caches.length) == 1

    # prefill-consistency: stepwise decode == forward's last-position logits
    full, _ = tfm.forward(params2, tokens[:, :8], cfg)
    st = tfm.init_decode_state(cfg, 2, 16)
    for i in range(8):
        step_logits, st = tfm.decode_step(params2, tokens[:, i : i + 1], st, cfg)
    np.testing.assert_allclose(
        np.asarray(step_logits), np.asarray(full[:, -1]), rtol=2e-3, atol=2e-3
    )


def test_gat_smoke():
    cfg = cfg_lib.get_smoke_config("gat-cora")
    g = gd.synthetic_graph(200, 800, cfg.d_feat, n_classes=cfg.n_classes, seed=0)
    params = gnn.init_params(jax.random.PRNGKey(0), cfg)
    batch = {
        "features": jnp.asarray(g.features),
        "edges": jnp.asarray(g.edges),
        "labels": jnp.asarray(g.labels),
    }
    opt = Adam(lr=5e-3)
    state = opt.init(params)
    losses = []
    for _ in range(5):
        loss, grads = jax.value_and_grad(
            lambda p: gnn.loss_fn(p, batch, cfg)
        )(params)
        params, state = opt.apply(params, state, grads)
        losses.append(float(loss))
    assert losses[-1] < losses[0]
    logits = gnn.forward(params, batch["features"], batch["edges"], cfg)
    assert logits.shape == (200, cfg.n_classes)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_gat_sampled_minibatch_smoke():
    cfg = cfg_lib.get_smoke_config("gat-cora")
    g = gd.synthetic_graph(500, 3000, cfg.d_feat, n_classes=cfg.n_classes, seed=1)
    indptr, indices = gd.to_csr(g.edges, g.num_nodes)
    nodes, edges_local, _ = gd.neighbor_sample(
        indptr, indices, np.arange(16), [5, 3], seed=0
    )
    sub = gd.pad_subgraph(g, nodes, edges_local, 256)
    batch = {k: jnp.asarray(v) for k, v in sub.items()}
    params = gnn.init_params(jax.random.PRNGKey(0), cfg)
    loss = gnn.loss_fn(params, batch, cfg)
    assert np.isfinite(float(loss))
    # edges reference only real node slots
    real_edges = sub["edges"][sub["edge_mask"] > 0]
    assert real_edges.max() < len(nodes)


def test_fm_smoke_with_pruning():
    cfg = cfg_lib.get_smoke_config("fm")
    params = recsys.init_fm_params(jax.random.PRNGKey(0), cfg)
    batch = {k: jnp.asarray(v) for k, v in clicks.fm_batch(
        256, n_fields=cfg.n_fields, vocab_per_field=cfg.vocab_per_field
    ).items()}
    opt = Sgd(lr=0.5)
    losses = []
    for _ in range(5):
        loss, grads = jax.value_and_grad(
            lambda p: recsys.fm_loss(p, batch, cfg)
        )(params)
        params, _ = opt.apply(params, {}, grads)
        losses.append(float(loss))
    assert losses[-1] < losses[0]

    # pruned forward: threshold 0 == dense exactly; threshold>0 stays finite
    dense = recsys.fm_forward(params, batch["ids"], cfg, 0.0)
    pruned = recsys.fm_forward(params, batch["ids"], cfg, 0.05)
    assert bool(jnp.all(jnp.isfinite(pruned)))
    assert not bool(jnp.allclose(dense, pruned)) or float(
        jnp.max(jnp.abs(dense))
    ) == 0.0


def test_dlrm_smoke():
    cfg = cfg_lib.get_smoke_config("dlrm-mlperf")
    params = recsys.init_dlrm_params(jax.random.PRNGKey(0), cfg)
    batch = {k: jnp.asarray(v) for k, v in clicks.criteo_batch(
        128, n_dense=cfg.n_dense, vocab_sizes=cfg.vocab_sizes
    ).items()}
    opt = Sgd(lr=0.1)
    losses = []
    for _ in range(5):
        loss, grads = jax.value_and_grad(
            lambda p: recsys.dlrm_loss(p, batch, cfg)
        )(params)
        params, _ = opt.apply(params, {}, grads)
        losses.append(float(loss))
    assert losses[-1] < losses[0]
    scores = recsys.dlrm_retrieval(
        params, batch["dense"][:1], batch["sparse"][:1], jnp.arange(16), cfg
    )
    assert scores.shape == (16,) and bool(jnp.all(jnp.isfinite(scores)))


def test_sasrec_smoke():
    cfg = cfg_lib.get_smoke_config("sasrec")
    params = recsys.init_sasrec_params(jax.random.PRNGKey(0), cfg)
    batch = {k: jnp.asarray(v) for k, v in clicks.sasrec_batch(
        64, seq_len=cfg.seq_len, n_items=cfg.n_items
    ).items()}
    opt = Adam(lr=1e-2)
    state = opt.init(params)
    losses = []
    for _ in range(5):
        loss, grads = jax.value_and_grad(
            lambda p: recsys.sasrec_loss(p, batch, cfg)
        )(params)
        params, state = opt.apply(params, state, grads)
        losses.append(float(loss))
    assert losses[-1] < losses[0]
    scores = recsys.sasrec_retrieval(params, batch["seq"], cfg, 0.0,
                                     use_kernel=False)
    assert scores.shape == (64, cfg.n_items + 1)
    assert bool(jnp.all(jnp.isfinite(scores)))


def test_bst_smoke():
    cfg = cfg_lib.get_smoke_config("bst")
    params = recsys.init_bst_params(jax.random.PRNGKey(0), cfg)
    batch = {k: jnp.asarray(v) for k, v in clicks.bst_batch(
        64, seq_len=cfg.seq_len, n_items=cfg.n_items, n_profile=cfg.n_profile
    ).items()}
    opt = Adam(lr=1e-3)
    state = opt.init(params)
    losses = []
    for _ in range(6):
        loss, grads = jax.value_and_grad(
            lambda p: recsys.bst_loss(p, batch, cfg)
        )(params)
        params, state = opt.apply(params, state, grads)
        losses.append(float(loss))
    assert losses[-1] < losses[0]


def test_dpmf_smoke():
    from repro.core import mf
    from repro.optim.optimizers import RowOptimizer

    cfg = cfg_lib.get_smoke_config("dpmf")
    params = mf.init_params(
        jax.random.PRNGKey(0), cfg.num_users, cfg.num_items, cfg.k
    )
    opt = RowOptimizer(name="adagrad")
    state = mf.init_opt_state(params, opt)
    rng = np.random.default_rng(0)
    batch = {
        "user": jnp.asarray(rng.integers(0, cfg.num_users, 512), jnp.int32),
        "item": jnp.asarray(rng.integers(0, cfg.num_items, 512), jnp.int32),
        "rating": jnp.asarray(rng.uniform(1, 5, 512), jnp.float32),
    }
    params, state, metrics = mf.train_step(
        params, state, batch, jnp.float32(0.02), jnp.float32(0.02),
        jnp.float32(0.05), jnp.ones((cfg.k,)), opt=opt, lam=cfg.lam,
    )
    assert np.isfinite(float(metrics["abs_err"]))
    assert 0.0 < float(metrics["work_fraction"]) <= 1.0


@pytest.mark.parametrize("arch", list(cfg_lib.ALL_ARCHS))
def test_cells_buildable(arch):
    """Every cell materializes abstract args (no allocation) with the
    expected structure."""
    for sid in cfg_lib.shape_ids(arch):
        cell = cfg_lib.build_cell(arch, sid)
        assert cell.abstract_args, (arch, sid)
        leaves = jax.tree_util.tree_leaves(cell.abstract_args)
        assert all(hasattr(l, "shape") for l in leaves)
