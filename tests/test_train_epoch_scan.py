"""Parity + safety suite for the epoch-compiled training path.

The contract under test: ``mf.train_epoch_scan`` (one donated lax.scan per
epoch over packed device-resident batches) is *numerically equivalent* to
folding ``mf.train_step`` over the same batches from Python — for every row
optimizer, every variant, and the weighted/biased fused-kernel cases — and
the donation never lets stale buffers leak back into the caller.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import mf
from repro.data import build_user_history, synthetic_ratings
from repro.data import loader
from repro.kernels import fused_mf_sgd, ref
from repro.optim.optimizers import RowOptimizer

OPTIMIZERS = ("sgd", "momentum", "adagrad", "adadelta", "adam")
# momentum compounds duplicate-row updates; a smaller lr keeps it stable
LR = {"sgd": 0.02, "momentum": 0.005, "adagrad": 0.05,
      "adadelta": 1.0, "adam": 0.005}

M, N, K = 120, 150, 16


@pytest.fixture(scope="module")
def packed():
    ds = synthetic_ratings(M, N, 6000, seed=1)
    return loader.pack_ratings(ds, 256)


def _fold_train_step(params, state, batches, *, opt, hist=None, t=0.04,
                     lr=0.05, use_fused_kernel=False):
    steps = batches["user"].shape[0]
    errs, works = [], []
    for i in range(steps):
        b = {key: v[i] for key, v in batches.items()}
        if hist is not None:
            b["hist"] = hist[b["user"]]
        params, state, m = mf.train_step(
            params, state, b, jnp.float32(t), jnp.float32(t),
            jnp.float32(lr), jnp.ones((K,)), opt=opt, lam=0.02,
            use_fused_kernel=use_fused_kernel,
        )
        errs.append(float(m["abs_err"]))
        works.append(float(m["work_fraction"]))
    return params, state, {"abs_err": np.mean(errs),
                           "work_fraction": np.mean(works)}


def _fresh(opt, variant="funk"):
    params = mf.init_params(
        jax.random.PRNGKey(0), M, N, K, variant=variant, global_mean=3.2
    )
    return params, mf.init_opt_state(params, opt)


def _assert_params_close(a, b, atol=1e-6):
    for name in a._fields:
        va, vb = getattr(a, name), getattr(b, name)
        if va is None:
            assert vb is None
            continue
        np.testing.assert_allclose(
            np.asarray(va), np.asarray(vb), atol=atol, rtol=0, err_msg=name
        )


@pytest.mark.parametrize("opt_name", OPTIMIZERS)
def test_scan_epoch_matches_per_batch_loop(packed, opt_name):
    opt = RowOptimizer(name=opt_name)
    batches = packed.epoch_batches(0, 0)
    lr = LR[opt_name]

    params, state = _fresh(opt)
    want_p, want_s, want_m = _fold_train_step(
        params, state, batches, opt=opt, lr=lr
    )
    params2, state2 = _fresh(opt)
    got_p, got_s, got_m = mf.train_epoch_scan(
        params2, state2, batches, jnp.float32(0.04), jnp.float32(0.04),
        jnp.float32(lr), jnp.ones((K,)), opt=opt, lam=0.02,
    )
    _assert_params_close(want_p, got_p)
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), atol=1e-6, rtol=0
        ),
        want_s, got_s,
    )
    assert abs(want_m["abs_err"] - float(got_m["abs_err"])) < 1e-5
    assert abs(want_m["work_fraction"] - float(got_m["work_fraction"])) < 1e-5


@pytest.mark.parametrize("variant", ["bias", "svdpp"])
def test_scan_epoch_variants(packed, variant):
    ds = synthetic_ratings(M, N, 6000, seed=1)
    hist = (
        jnp.asarray(build_user_history(ds, 8)) if variant == "svdpp" else None
    )
    opt = RowOptimizer(name="adagrad")
    batches = packed.epoch_batches(0, 3)

    params, state = _fresh(opt, variant)
    want_p, _, _ = _fold_train_step(params, state, batches, opt=opt, hist=hist)
    params2, state2 = _fresh(opt, variant)
    got_p, _, _ = mf.train_epoch_scan(
        params2, state2, batches, jnp.float32(0.04), jnp.float32(0.04),
        jnp.float32(0.05), jnp.ones((K,)), hist,
        opt=opt, lam=0.02,
    )
    _assert_params_close(want_p, got_p)


def test_scan_epoch_weighted_batches(packed):
    """A weight column in the packed batches rides through the scan."""
    opt = RowOptimizer(name="adagrad")
    batches = dict(packed.epoch_batches(0, 1))
    rng = np.random.default_rng(0)
    batches["weight"] = jnp.asarray(
        rng.uniform(0.0, 1.0, batches["rating"].shape).astype(np.float32)
    )
    params, state = _fresh(opt)
    want_p, _, _ = _fold_train_step(params, state, batches, opt=opt)
    params2, state2 = _fresh(opt)
    got_p, _, _ = mf.train_epoch_scan(
        params2, state2, batches, jnp.float32(0.04), jnp.float32(0.04),
        jnp.float32(0.05), jnp.ones((K,)), opt=opt, lam=0.02,
    )
    _assert_params_close(want_p, got_p)


@pytest.mark.parametrize("weighted", [False, True])
def test_fused_kernel_bias_weight_vs_ref(weighted):
    """The generalized kernel (biases + weight in-kernel, interpret mode)
    matches the pure-jnp reference bit-for-bit at f32."""
    rng = np.random.default_rng(2)
    b, k = 96, 24
    p = jnp.asarray(rng.normal(0, 0.1, (b, k)).astype(np.float32))
    q = jnp.asarray(rng.normal(0, 0.1, (b, k)).astype(np.float32))
    r = jnp.asarray(rng.uniform(1, 5, b).astype(np.float32))
    bu = jnp.asarray(rng.normal(0, 0.05, b).astype(np.float32))
    bi = jnp.asarray(rng.normal(0, 0.05, b).astype(np.float32))
    w = (
        jnp.asarray(rng.uniform(0, 1, b).astype(np.float32))
        if weighted else None
    )
    kw = dict(lr=0.05, lam=0.02, bias_u=bu, bias_i=bi, global_mean=3.1,
              weight=w)
    want = ref.fused_mf_sgd_ref(p, q, r, jnp.float32(0.06), jnp.float32(0.06),
                                **kw)
    got = fused_mf_sgd(p, q, r, 0.06, 0.06, block_b=32, **kw)
    for name, a, b_ in zip(("p", "q", "bu", "bi", "err"), want, got):
        np.testing.assert_allclose(
            np.asarray(b_), np.asarray(a), atol=1e-6, rtol=0, err_msg=name
        )


def test_fused_train_step_biased_weighted_matches_xla(packed):
    """use_fused_kernel=True now covers BiasSVD and weighted batches."""
    opt = RowOptimizer(name="sgd")
    batches = dict(packed.epoch_batches(0, 2))
    rng = np.random.default_rng(1)
    batches["weight"] = jnp.asarray(
        rng.uniform(0.0, 1.0, batches["rating"].shape).astype(np.float32)
    )
    b = {key: v[0] for key, v in batches.items()}
    params, state = _fresh(opt, "bias")
    args = (jnp.float32(0.04), jnp.float32(0.04), jnp.float32(0.02),
            jnp.ones((K,)))
    want_p, _, want_m = mf.train_step(
        params, state, b, *args, opt=opt, lam=0.02, use_fused_kernel=False
    )
    got_p, _, got_m = mf.train_step(
        params, state, b, *args, opt=opt, lam=0.02, use_fused_kernel=True
    )
    _assert_params_close(want_p, got_p)
    assert abs(float(want_m["abs_err"]) - float(got_m["abs_err"])) < 1e-5


def test_donation_safety(packed):
    """No use-after-donate: chained epochs only ever touch the returned
    arrays, and the donated inputs are really gone (when the backend honors
    donation) — reading them must not silently alias the new state."""
    opt = RowOptimizer(name="adagrad")
    params, state = _fresh(opt)
    params_copy = jax.tree_util.tree_map(jnp.copy, params)
    chain_p, chain_s = params, state
    for epoch in range(3):
        batches = packed.epoch_batches(0, epoch)
        chain_p, chain_s, metrics = mf.train_epoch_scan(
            chain_p, chain_s, batches, jnp.float32(0.04), jnp.float32(0.04),
            jnp.float32(0.05), jnp.ones((K,)), opt=opt, lam=0.02,
        )
    assert np.isfinite(float(metrics["abs_err"]))
    # the original buffers were either invalidated (donation honored) or left
    # intact (backend ignored the hint) — never mutated in place
    try:
        leaked = np.asarray(params.p)
    except RuntimeError:
        pass  # deleted by donation: any read after donate must raise
    else:
        np.testing.assert_array_equal(leaked, np.asarray(params_copy.p))
    # and the chained result must not alias the donated input
    assert not np.array_equal(np.asarray(chain_p.p), np.asarray(params_copy.p))


def test_eval_epoch_scan_matches_loop():
    ds = synthetic_ratings(M, N, 3000, seed=3)
    params = mf.init_params(jax.random.PRNGKey(1), M, N, K)
    t = jnp.float32(0.04)
    total = count = 0.0
    for b_np in loader.iterate_batches(ds, 512, shuffle=False,
                                       drop_remainder=False):
        b = {key: jnp.asarray(v) for key, v in b_np.items()}
        s, c = mf.eval_mae(params, b, t, t)
        total += float(s)
        count += float(c)
    packed_eval = loader.pack_eval_batches(ds, 512)
    tot, cnt = mf.eval_epoch_scan(params, packed_eval, t, t)
    assert abs(float(cnt) - count) < 1e-6
    assert abs(float(tot) - total) < 1e-3


def test_packed_epoch_batches_deterministic_and_complete(packed):
    a = packed.epoch_batches(5, 2)
    b = packed.epoch_batches(5, 2)
    np.testing.assert_array_equal(np.asarray(a["user"]), np.asarray(b["user"]))
    c = packed.epoch_batches(5, 3)
    assert not np.array_equal(np.asarray(a["user"]), np.asarray(c["user"]))
    # the (steps, B) arrays are a permutation prefix: no duplicate examples
    n = packed.num_examples
    flat_r = np.asarray(a["rating"]).ravel()
    assert flat_r.shape[0] == packed.num_steps * packed.batch_size <= n
    # reconstruct positions by matching (user, item) pairs is overkill; the
    # permutation property is visible through unique (user, item, rating)
    # triple counts not exceeding their dataset multiplicity
    flat = np.stack([
        np.asarray(a["user"]).ravel(), np.asarray(a["item"]).ravel()
    ], 1)
    pairs, counts = np.unique(flat, axis=0, return_counts=True)
    ds_pairs, ds_counts = np.unique(
        np.stack([np.asarray(packed.user), np.asarray(packed.item)], 1),
        axis=0, return_counts=True,
    )
    lookup = {tuple(p): c for p, c in zip(ds_pairs, ds_counts)}
    assert all(c <= lookup[tuple(p)] for p, c in zip(pairs, counts))


def test_route_batch_to_owner_shards_contract():
    from repro.distributed.sharding import route_batch_to_owner_shards

    rng = np.random.default_rng(0)
    users = rng.integers(0, 16, 37).astype(np.int32)
    items = rng.integers(0, 9, 37).astype(np.int32)
    ratings = rng.uniform(1, 5, 37).astype(np.float32)
    routed = route_batch_to_owner_shards(
        users, items, ratings, num_users=16, n_dp=4, pad_to_pow2=True
    )
    total = routed["user"].shape[0]
    assert total % 4 == 0
    length = total // 4
    assert (length & (length - 1)) == 0  # pow2
    for s in range(4):
        chunk_u = routed["user"][s * length : (s + 1) * length]
        assert np.all((chunk_u >= s * 4) & (chunk_u < (s + 1) * 4))
    # every real row survives exactly once, padding carries weight 0
    assert routed["weight"].sum() == 37
    real = routed["weight"] > 0
    got = np.stack([routed["user"][real], routed["item"][real],
                    routed["rating"][real]], 1)
    want = np.stack([users, items, ratings], 1)
    got_sorted = got[np.lexsort(got.T)]
    want_sorted = want[np.lexsort(want.T)]
    np.testing.assert_allclose(got_sorted, want_sorted)


def test_scan_shard_map_matches_single_device():
    """Sharded epoch scan == single-device epoch scan on the 4-device CI
    mesh (owner-routed batches, adagrad)."""
    if jax.device_count() < 4:
        pytest.skip("needs >= 4 devices (run under the 4-device CI mesh job)")
    from repro.distributed.mesh_compat import use_mesh
    from repro.distributed.sharding import route_batch_to_owner_shards

    mesh = jax.make_mesh((2, 2), ("data", "model"))
    m, n, k, B, steps = 16, 8, 12, 16, 4
    rng = np.random.default_rng(0)
    routed_steps = []
    plain_steps = []
    for _ in range(steps):
        users = rng.integers(0, m, B).astype(np.int32)
        items = rng.integers(0, n, B).astype(np.int32)
        ratings = rng.uniform(1, 5, B).astype(np.float32)
        plain_steps.append({"user": users, "item": items, "rating": ratings,
                            "weight": np.ones(B, np.float32)})
        routed_steps.append(route_batch_to_owner_shards(
            users, items, ratings, num_users=m, n_dp=2
        ))
    lengths = {r["user"].shape[0] for r in routed_steps}
    length = max(lengths)
    for r in routed_steps:  # repad to a common (steps, L) stack
        pad = length - r["user"].shape[0]
        if pad:
            half = r["user"].shape[0] // 2
            for key in r:
                fill = (
                    np.repeat([0, m // 2], pad // 2 + 1)[:pad]
                    if key == "user" else np.zeros(pad, r[key].dtype)
                )
                r[key] = np.concatenate(
                    [r[key][:half], fill[: pad // 2], r[key][half:],
                     fill[pad // 2 :]]
                )
    routed = {
        key: jnp.asarray(np.stack([r[key] for r in routed_steps]))
        for key in routed_steps[0]
    }
    plain = {
        key: jnp.asarray(np.stack([b[key] for b in plain_steps]))
        for key in plain_steps[0]
    }

    opt = RowOptimizer(name="adagrad")
    params = mf.init_params(jax.random.PRNGKey(0), m, n, k)
    state = mf.init_opt_state(params, opt)
    want_p, want_s, want_m = mf.train_epoch_scan(
        params, state, plain, jnp.float32(0.05), jnp.float32(0.05),
        jnp.float32(0.05), jnp.ones((k,)), opt=opt, lam=0.02,
    )
    params2 = mf.init_params(jax.random.PRNGKey(0), m, n, k)
    state2 = mf.init_opt_state(params2, opt)
    with use_mesh(mesh):
        got_p, got_s, got_m = mf.train_epoch_scan_shard_map(
            params2, state2, routed, 0.05, 0.05, lr=0.05, lam=0.02,
            opt_name="adagrad", mesh=mesh.abstract_mesh,
        )
    np.testing.assert_allclose(np.asarray(want_p.p), np.asarray(got_p.p),
                               atol=2e-7, rtol=0)
    np.testing.assert_allclose(np.asarray(want_p.q), np.asarray(got_p.q),
                               atol=2e-7, rtol=0)
    np.testing.assert_allclose(np.asarray(want_s.q["acc"]),
                               np.asarray(got_s.q["acc"]), atol=2e-7, rtol=0)
    assert abs(float(want_m["abs_err"]) - float(got_m["abs_err"])) < 1e-5


def test_momentum_optimizer_learns(packed):
    opt = RowOptimizer(name="momentum")
    params, state = _fresh(opt)
    first = None
    for epoch in range(4):
        params, state, m = mf.train_epoch_scan(
            params, state, packed.epoch_batches(0, epoch),
            jnp.float32(0.0), jnp.float32(0.0), jnp.float32(0.002),
            jnp.ones((K,)), opt=opt, lam=0.02,
        )
        if first is None:
            first = float(m["abs_err"])
    assert float(m["abs_err"]) < first

def test_route_batch_rejects_out_of_range_users():
    from repro.distributed.sharding import route_batch_to_owner_shards

    with pytest.raises(ValueError, match="grow the tables"):
        route_batch_to_owner_shards(
            np.asarray([20, 3]), np.asarray([1, 2]),
            np.asarray([4.0, 5.0], np.float32), num_users=16, n_dp=4,
        )


def test_shard_map_rejects_unknown_optimizer():
    with pytest.raises(ValueError, match="sgd and adagrad only"):
        mf.train_epoch_scan_shard_map(
            None, None, {}, 0.0, 0.0, lr=0.05, lam=0.02,
            opt_name="adam", mesh=object(),
        )
