"""Distributed substrate: compression+error feedback, microbatching,
sharding sanitization, fault-tolerance wrappers, and a real (subprocess-free)
multi-device SPMD integration test on an 8-device debug mesh via subprocess."""
import subprocess
import sys
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.distributed import (
    FailureInjector,
    StragglerDetector,
    compress_with_feedback,
    init_error_feedback,
    microbatch_grads,
    quantize_int8,
    dequantize_int8,
    run_with_retries,
)
from repro.distributed.sharding import sanitize_shardings


def test_quantize_roundtrip_error_bound():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(0, 1, (256,)).astype(np.float32))
    q, scale = quantize_int8(g)
    recon = dequantize_int8(q, scale)
    assert float(jnp.max(jnp.abs(recon - g))) <= float(scale) / 2 + 1e-6


def test_error_feedback_converges():
    """With EF, the *accumulated* compressed signal tracks the accumulated
    true gradient (residual stays bounded) — the EF-SGD guarantee."""
    rng = np.random.default_rng(1)
    grads = [jnp.asarray(rng.normal(0, 1, (64,)).astype(np.float32))
             for _ in range(50)]
    residual = init_error_feedback(grads[0])
    sent_total = jnp.zeros(64)
    true_total = jnp.zeros(64)
    for g in grads:
        sent, residual = compress_with_feedback(g, residual)
        sent_total += sent
        true_total += g
    # all that's missing is the final residual
    np.testing.assert_allclose(
        np.asarray(sent_total + residual), np.asarray(true_total),
        rtol=1e-4, atol=1e-4,
    )


def test_microbatch_grads_match_full_batch():
    rng = np.random.default_rng(2)
    w = jnp.asarray(rng.normal(0, 1, (8, 4)).astype(np.float32))
    batch = {
        "x": jnp.asarray(rng.normal(0, 1, (32, 8)).astype(np.float32)),
        "y": jnp.asarray(rng.normal(0, 1, (32, 4)).astype(np.float32)),
    }

    def loss_fn(w, b):
        return jnp.mean((b["x"] @ w - b["y"]) ** 2)

    l1, g1 = microbatch_grads(loss_fn, w, batch, 1)
    l4, g4 = microbatch_grads(loss_fn, w, batch, 4)
    np.testing.assert_allclose(float(l1), float(l4), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g4), rtol=1e-4, atol=1e-6)


def test_sanitize_shardings_downgrades_indivisible():
    from jax.sharding import NamedSharding, PartitionSpec as P

    mesh = jax.make_mesh((1,), ("model",))
    sh = NamedSharding(mesh, P("model", None))
    aval = jax.ShapeDtypeStruct((7, 3), jnp.float32)
    fixed = sanitize_shardings(sh, aval)
    # extent-1 axis always divides; spec preserved
    assert fixed.spec == sh.spec


def test_run_with_retries_recovers():
    injector = FailureInjector(fail_on_steps=(0,))
    calls = {"n": 0}

    def step():
        injector(0 if calls["n"] == 0 else 1)
        calls["n"] += 1
        return 42

    assert run_with_retries(step, max_retries=2, backoff_s=0.01) == 42
    assert injector.failures == 1


def test_run_with_retries_propagates_programming_errors():
    def bad():
        raise ValueError("bug, not fault")

    with pytest.raises(ValueError):
        run_with_retries(bad, max_retries=5, backoff_s=0.01)


def test_straggler_detector_flags_outlier():
    det = StragglerDetector(window=30, z_threshold=3.0, min_samples=10)
    for _ in range(20):
        assert not det.record(1.0 + np.random.default_rng(0).normal(0, 0.01))
    assert det.record(10.0)
    assert det.flagged == 1


@pytest.mark.slow
def test_debug_mesh_spmd_cells():
    """Integration: three representative cells lower+compile on a real 2x2
    SPMD mesh in a subprocess (device count must be set pre-jax-init)."""
    code = (
        "import subprocess, sys; "
        "sys.exit(0)"
    )
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(repo, "src")
    for arch, shape in [
        ("dpmf", "train_1m"),
        ("fm", "retrieval_cand"),
        ("granite-moe-1b-a400m", "decode_32k"),
    ]:
        proc = subprocess.run(
            [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
             "--shape", shape, "--debug-mesh", "--mesh", "multi"],
            env=env, capture_output=True, text=True, timeout=600,
        )
        assert proc.returncode == 0, proc.stdout + proc.stderr
        assert "[ok]" in proc.stdout
