"""Property tests pinning the vectorized pruning machinery to the paper's
scalar algorithms (Algs. 1-3, Eqs. 7-8)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis_compat import given, settings, st

from repro.core import ranks, rearrange, threshold
from repro.kernels import ref


def factor_matrices(draw, max_rows=24, max_k=16):
    m = draw(st.integers(1, max_rows))
    n = draw(st.integers(1, max_rows))
    k = draw(st.integers(1, max_k))
    rng = np.random.default_rng(draw(st.integers(0, 2**31 - 1)))
    p = rng.normal(0, 0.1, (m, k)).astype(np.float32)
    q = rng.normal(0, 0.1, (n, k)).astype(np.float32)
    return p, q


@st.composite
def pq_strategy(draw):
    return factor_matrices(draw)


@given(pq_strategy(), st.floats(0.0, 0.25), st.floats(0.0, 0.25))
@settings(max_examples=40, deadline=None)
def test_masked_dot_equals_paper_loop(pq, t_p, t_q):
    """ranks.pruned_pair_dot == Algorithm 2's early-stopped scalar loop."""
    p, q = pq
    m, k = p.shape
    n = q.shape[0]
    r_u = ranks.effective_ranks(jnp.asarray(p), t_p)
    r_i = ranks.effective_ranks(jnp.asarray(q), t_q)
    out = ref.pruned_matmul_ref(jnp.asarray(p), jnp.asarray(q), r_u, r_i)
    for u in range(min(m, 4)):
        for i in range(min(n, 4)):
            expected = ref.early_stop_dot_loop(p[u], q[i], t_p, t_q)
            np.testing.assert_allclose(float(out[u, i]), expected, atol=1e-5)


@given(pq_strategy(), st.floats(0.01, 0.2))
@settings(max_examples=30, deadline=None)
def test_rearrangement_preserves_predictions(pq, t):
    """Permuting the shared latent axis never changes ANY unpruned inner
    product (the rearrangement is error-free by itself)."""
    p, q = pq
    res = rearrange.rearrangement(jnp.asarray(p), jnp.asarray(q), t, t)
    p2, q2 = rearrange.apply_perm(jnp.asarray(p), jnp.asarray(q), res.perm)
    np.testing.assert_allclose(
        np.asarray(p2 @ q2.T), p @ q.T, rtol=1e-5, atol=1e-6
    )
    # joint sparsity is ascending after rearrangement (paper Eq. 11)
    js = np.asarray(res.joint_sparsity)
    assert np.all(np.diff(js) >= -1e-7)


@given(pq_strategy())
@settings(max_examples=30, deadline=None)
def test_zero_threshold_is_dense(pq):
    """Thresholds 0 must recover the dense computation exactly (the paper's
    rate-0 baseline shares the code path)."""
    p, q = pq
    r_u = ranks.effective_ranks(jnp.asarray(p), 0.0)
    assert int(jnp.min(r_u)) == p.shape[1]
    out = ref.pruned_matmul_ref(
        jnp.asarray(p), jnp.asarray(q), r_u, ranks.effective_ranks(jnp.asarray(q), 0.0)
    )
    np.testing.assert_allclose(np.asarray(out), p @ q.T, rtol=1e-5, atol=1e-6)


@given(
    st.floats(-0.05, 0.05),   # mu
    st.floats(0.02, 0.5),     # sigma
    st.floats(0.01, 0.95),    # rate
)
@settings(max_examples=50, deadline=None)
def test_threshold_solves_eq8(mu, sigma, rate):
    """T from Eqs. 7/8 prunes exactly `rate` mass of N(mu, sigma^2)."""
    from jax.scipy.stats import norm

    t = threshold.threshold_for_rate(
        threshold.MatrixStats(jnp.float32(mu), jnp.float32(sigma)), rate
    )
    t = float(t)
    mass = float(norm.cdf((t - mu) / sigma) - norm.cdf((-t - mu) / sigma))
    assert abs(mass - rate) < 1e-3


@given(st.floats(0.02, 0.5), st.lists(st.floats(0.05, 0.9), min_size=2, max_size=5))
@settings(max_examples=25, deadline=None)
def test_threshold_monotone_in_rate(sigma, rates):
    stats = threshold.MatrixStats(jnp.float32(0.0), jnp.float32(sigma))
    ts = [float(threshold.threshold_for_rate(stats, r)) for r in sorted(rates)]
    assert all(b >= a - 1e-7 for a, b in zip(ts, ts[1:]))


def test_threshold_matches_empirical_fraction():
    """End-to-end: measured matrices + Eq. 7/8 -> empirical pruned fraction
    close to the requested rate (the paper's §4.2 claim)."""
    rng = np.random.default_rng(0)
    m = jnp.asarray(rng.normal(0.01, 0.09, (4000, 40)).astype(np.float32))
    for rate in (0.1, 0.3, 0.5):
        t = threshold.threshold_for_rate(threshold.measure_stats(m), rate)
        frac = float(threshold.empirical_pruned_fraction(m, t))
        assert abs(frac - rate) < 0.02, (rate, frac)


@given(pq_strategy(), st.floats(0.01, 0.2), st.floats(0.01, 0.1), st.floats(0.0, 0.1))
@settings(max_examples=25, deadline=None)
def test_fused_sgd_matches_paper_update_loop(pq, t, lr, lam):
    """fused ref == Algorithm 3's truncated scalar update, pair by pair."""
    p, q = pq
    n_pairs = min(p.shape[0], q.shape[0], 5)
    p_rows = p[:n_pairs]
    q_rows = q[:n_pairs]
    ratings = np.linspace(1, 5, n_pairs).astype(np.float32)
    new_p, new_q, _, _, err = ref.fused_mf_sgd_ref(
        jnp.asarray(p_rows), jnp.asarray(q_rows), jnp.asarray(ratings),
        jnp.float32(t), jnp.float32(t), lr=lr, lam=lam,
    )
    for b in range(n_pairs):
        exp_p, exp_q, exp_err = ref.early_stop_update_loop(
            p_rows[b], q_rows[b], float(ratings[b]), t, t, lr, lam
        )
        np.testing.assert_allclose(np.asarray(new_p[b]), exp_p, atol=1e-5)
        np.testing.assert_allclose(np.asarray(new_q[b]), exp_q, atol=1e-5)
        np.testing.assert_allclose(float(err[b]), exp_err, atol=1e-5)


@given(pq_strategy(), st.floats(0.0, 0.3))
@settings(max_examples=25, deadline=None)
def test_work_fraction_bounds(pq, t):
    p, q = pq
    r_u = ranks.effective_ranks(jnp.asarray(p), t)
    r_i = ranks.effective_ranks(jnp.asarray(q), t)
    frac = float(
        ranks.work_fraction(r_u[:, None], r_i[None, :], p.shape[1])
    )
    assert 0.0 <= frac <= 1.0 + 1e-6


def test_rank_mask_matches_mask_rows():
    rng = np.random.default_rng(1)
    rows = jnp.asarray(rng.normal(0, 0.1, (32, 16)).astype(np.float32))
    t = 0.06
    masked = ranks.mask_rows(rows, t)
    r = ranks.effective_ranks(rows, t)
    for i in range(32):
        ri = int(r[i])
        assert bool(jnp.all(masked[i, ri:] == 0))
        np.testing.assert_array_equal(
            np.asarray(masked[i, :ri]), np.asarray(rows[i, :ri])
        )
