"""Out-of-core store tests: Feistel permutation properties, columnar store
roundtrips, the prefetched slab loader's determinism/resume contract, the
store-mode trainer's mid-epoch checkpoint parity, and the device-resident
reshuffle of the in-memory ``PackedRatings`` path.

The bitwise assertions are deliberate: the resume story ("a killed run
replays the remaining slabs identically") only holds if the shuffled epoch
order is a pure function of ``(n, seed, epoch)`` and slab boundaries never
change what an example's batch assignment is.
"""
import os

import numpy as np
import jax
import pytest

from hypothesis_compat import given, settings, st
from repro.core import trainer as trainer_lib
from repro.core.trainer import DPMFTrainer, TrainConfig
from repro.data import synthetic_ratings
from repro.data.loader import pack_ratings
from repro.store import (
    FeistelPermutation,
    RatingsStore,
    ShardedRatingsLoader,
    build_store,
)
from repro.store.ratings_store import permuted_indices


def _ds(n_ratings=2048, users=150, items=80, seed=0):
    return synthetic_ratings(users, items, n_ratings, seed=seed)


# ---------------------------------------------------------------------------
# Feistel permutation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [1, 2, 3, 5, 64, 1000, 1024, 1025])
@pytest.mark.parametrize("seed,epoch", [(0, 0), (0, 7), (3, 1)])
def test_feistel_is_a_permutation(n, seed, epoch):
    perm = FeistelPermutation(n, seed, epoch)
    out = perm(np.arange(n))
    assert np.array_equal(np.sort(out), np.arange(n))


@given(
    n=st.integers(min_value=1, max_value=5000),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    epoch=st.integers(min_value=0, max_value=500),
)
@settings(max_examples=40, deadline=None)
def test_feistel_bijection_property(n, seed, epoch):
    out = FeistelPermutation(n, seed, epoch)(np.arange(n))
    assert out.min() >= 0 and out.max() < n
    assert np.unique(out).size == n, "collision — not a bijection"


def test_feistel_slice_parity():
    n, seed, epoch = 1337, 11, 4
    full = FeistelPermutation(n, seed, epoch)(np.arange(n))
    for start, count in [(0, 10), (100, 257), (n - 5, 5)]:
        got = permuted_indices(n, seed, epoch, start, count)
        assert np.array_equal(got, full[start:start + count])


def test_feistel_epochs_differ():
    n = 4096
    a = FeistelPermutation(n, 0, 0)(np.arange(n))
    b = FeistelPermutation(n, 0, 1)(np.arange(n))
    assert not np.array_equal(a, b)


# ---------------------------------------------------------------------------
# Columnar store
# ---------------------------------------------------------------------------

def test_store_roundtrip_multi_shard(tmp_path):
    ds = _ds()
    directory = str(tmp_path / "store")
    # force several shards so gather crosses shard boundaries
    build_store(ds, directory, shard_rows=300)
    store = RatingsStore(directory)
    assert len(store) == len(ds)
    assert store.num_users == ds.num_users
    assert store.num_items == ds.num_items
    assert store.global_mean == pytest.approx(float(ds.global_mean))
    back = store.to_dataset()
    assert np.array_equal(back.user, ds.user)
    assert np.array_equal(back.item, ds.item)
    assert np.array_equal(back.rating, ds.rating)


def test_store_gather_arbitrary_order(tmp_path):
    ds = _ds()
    store = RatingsStore(build_store(ds, str(tmp_path / "s"), shard_rows=257))
    rng = np.random.default_rng(0)
    idx = rng.integers(0, len(ds), 500)   # random order, duplicates likely
    user, item, rating = store.gather(idx)
    assert np.array_equal(user, ds.user[idx])
    assert np.array_equal(item, ds.item[idx])
    assert np.array_equal(rating, ds.rating[idx])
    with pytest.raises(IndexError):
        store.gather(np.array([len(ds)]))


def test_store_rejects_wrong_version(tmp_path):
    ds = _ds(256, 30, 20)
    directory = build_store(ds, str(tmp_path / "s"))
    import json

    path = os.path.join(directory, "index.json")
    with open(path) as f:
        index = json.load(f)
    index["version"] = 999
    with open(path, "w") as f:
        json.dump(index, f)
    with pytest.raises(ValueError, match="version"):
        RatingsStore(directory)


# ---------------------------------------------------------------------------
# Streaming slab loader
# ---------------------------------------------------------------------------

def _collect(loader, seed, epoch, **kw):
    slabs = list(loader.epoch_slabs(seed, epoch, **kw))
    return {
        key: np.concatenate([np.asarray(s.batches[key]) for s in slabs])
        for key in ("user", "item", "rating")
    }, slabs


def test_loader_epoch_determinism_and_coverage(tmp_path):
    ds = _ds()
    store = RatingsStore(build_store(ds, str(tmp_path / "s"), shard_rows=500))
    loader = ShardedRatingsLoader(store, 64, slab_steps=7, prefetch=2)
    a, slabs = _collect(loader, seed=3, epoch=5)
    b, _ = _collect(loader, seed=3, epoch=5)
    for key in a:
        assert np.array_equal(a[key], b[key]), "same (seed, epoch) diverged"
    assert sum(s.steps for s in slabs) == loader.num_steps
    assert [s.slab_idx for s in slabs] == list(range(loader.num_slabs))
    # the epoch covers num_steps*B distinct examples (shuffle is a bijection)
    perm = FeistelPermutation(len(store), 3, 5)
    idx = perm(np.arange(loader.num_steps * loader.batch_size))
    assert np.array_equal(a["rating"].reshape(-1), ds.rating[idx])
    c, _ = _collect(loader, seed=3, epoch=6)
    assert not np.array_equal(a["user"], c["user"]), "epochs share an order"


def test_loader_resume_matches_uninterrupted_tail(tmp_path):
    ds = _ds()
    store = RatingsStore(build_store(ds, str(tmp_path / "s")))
    loader = ShardedRatingsLoader(store, 64, slab_steps=5, prefetch=2)
    _, full = _collect(loader, seed=0, epoch=2)
    for start in (1, loader.num_slabs - 1, loader.num_slabs):
        tail = list(loader.epoch_slabs(0, 2, start_slab=start))
        assert len(tail) == loader.num_slabs - start
        for s_full, s_tail in zip(full[start:], tail):
            assert s_full.slab_idx == s_tail.slab_idx
            for key in s_full.batches:
                assert np.array_equal(
                    np.asarray(s_full.batches[key]),
                    np.asarray(s_tail.batches[key]),
                ), "resumed slab differs from the uninterrupted epoch"


def test_loader_no_shuffle_is_sequential(tmp_path):
    ds = _ds(640, 50, 30)
    store = RatingsStore(build_store(ds, str(tmp_path / "s")))
    loader = ShardedRatingsLoader(store, 32, slab_steps=4)
    got, _ = _collect(loader, seed=0, epoch=0, shuffle=False)
    n = loader.num_steps * loader.batch_size
    assert np.array_equal(got["user"].reshape(-1), ds.user[:n])


def test_loader_early_close_shuts_down_worker(tmp_path):
    ds = _ds()
    store = RatingsStore(build_store(ds, str(tmp_path / "s")))
    loader = ShardedRatingsLoader(store, 32, slab_steps=2, prefetch=2)
    before = threading_active_prefetchers()
    gen = loader.epoch_slabs(0, 0)
    next(gen)
    gen.close()   # abandon mid-epoch: must not hang or leak the thread
    assert threading_active_prefetchers() <= before + 0


def threading_active_prefetchers():
    import threading

    return sum(
        t.name == "ratings-prefetch" and t.is_alive()
        for t in threading.enumerate()
    )


def test_loader_validation():
    ds = _ds(100, 20, 10)
    import tempfile

    with tempfile.TemporaryDirectory() as d:
        store = RatingsStore(build_store(ds, d))
        with pytest.raises(ValueError, match="batch_size"):
            ShardedRatingsLoader(store, 0)
        with pytest.raises(ValueError, match="nothing to stream"):
            # batch_size clamps to the dataset but 0 steps is an error only
            # when examples < 1 batch; craft that via huge batch over clamp
            ShardedRatingsLoader(
                RatingsStore(build_store(_ds(0, 5, 5), d + "/empty")), 8
            )
        loader = ShardedRatingsLoader(store, 16, slab_steps=2)
        with pytest.raises(ValueError, match="start_slab"):
            list(loader.epoch_slabs(0, 0, start_slab=loader.num_slabs + 1))


# ---------------------------------------------------------------------------
# Store-mode trainer: streamed epochs + mid-epoch checkpoint parity
# ---------------------------------------------------------------------------

def _store_cfg(store_dir, ckpt_dir=None):
    return TrainConfig(
        k=6, epochs=2, batch_size=32, lr=0.05, pruning_rate=0.5, seed=0,
        store_dir=store_dir, slab_steps=4, prefetch_slabs=2,
        checkpoint_dir=ckpt_dir, checkpoint_every_epochs=1,
        checkpoint_every_slabs=2,
    )


def _run_epochs(trainer, *, kill_after_scans=0):
    calls = {"n": 0}
    original = trainer_lib.mf.train_epoch_scan

    def counting(*args, **kwargs):
        calls["n"] += 1
        if kill_after_scans and calls["n"] > kill_after_scans:
            raise KeyboardInterrupt
        return original(*args, **kwargs)

    trainer_lib.mf.train_epoch_scan = counting
    try:
        while trainer.epoch < trainer.config.epochs:
            trainer.run_epoch()
    except KeyboardInterrupt:
        pass
    finally:
        trainer_lib.mf.train_epoch_scan = original
        if trainer._ckpt is not None:
            trainer._ckpt.wait()


def test_store_trainer_mid_epoch_resume_bitwise(tmp_path):
    ds = _ds(1024, 100, 60)
    store_dir = build_store(ds, str(tmp_path / "store"))

    baseline = DPMFTrainer(_store_cfg(store_dir))
    _run_epochs(baseline)
    num_slabs = baseline._loader.num_slabs
    assert num_slabs >= 4

    ckpt_dir = str(tmp_path / "ckpt")
    killed = DPMFTrainer(_store_cfg(store_dir, ckpt_dir))
    # epoch 0 runs num_slabs scans; die 3 scans into epoch 1, past the
    # slab-2 mid-epoch checkpoint
    _run_epochs(killed, kill_after_scans=num_slabs + 3)
    assert killed.epoch == 1, "kill should land mid-epoch-1"

    resumed = DPMFTrainer(_store_cfg(store_dir, ckpt_dir))
    assert resumed.maybe_restore()
    assert resumed.epoch == 1 and resumed._resume_slab == 2
    _run_epochs(resumed)

    assert np.array_equal(np.asarray(baseline.params.p),
                          np.asarray(resumed.params.p))
    assert np.array_equal(np.asarray(baseline.params.q),
                          np.asarray(resumed.params.q))
    for group in baseline.opt_state._fields:
        ga = getattr(baseline.opt_state, group)
        gb = getattr(resumed.opt_state, group)
        if isinstance(ga, dict):
            for key in ga:
                assert np.array_equal(np.asarray(ga[key]),
                                      np.asarray(gb[key])), (group, key)
    # the logged epoch metric is rebuilt from the checkpointed accumulators
    assert (baseline.history[-1].train_abs_err
            == resumed.history[-1].train_abs_err)


def test_store_trainer_matches_metadata(tmp_path):
    ds = _ds(512, 60, 40)
    store_dir = build_store(ds, str(tmp_path / "store"))
    trainer = DPMFTrainer(_store_cfg(store_dir))
    assert trainer.params.p.shape[0] == ds.num_users
    assert trainer.params.q.shape[0] == ds.num_items
    trainer.run_epoch()
    assert len(trainer.history) == 1
    assert np.isfinite(trainer.history[-1].train_abs_err)


def test_store_trainer_requires_scan_mode(tmp_path):
    ds = _ds(256, 30, 20)
    store_dir = build_store(ds, str(tmp_path / "store"))
    cfg = TrainConfig(k=4, epochs=1, batch_size=32, store_dir=store_dir,
                      epoch_mode="python")
    with pytest.raises(ValueError, match="scan"):
        DPMFTrainer(cfg)


# ---------------------------------------------------------------------------
# PackedRatings device-resident reshuffle (in-memory path)
# ---------------------------------------------------------------------------

def test_packed_reshuffle_determinism_and_distinct_epochs():
    ds = _ds(512, 60, 40)
    packed = pack_ratings(ds, 32)
    a = packed.epoch_batches(seed=1, epoch=3)
    b = packed.epoch_batches(seed=1, epoch=3)
    c = packed.epoch_batches(seed=1, epoch=4)
    for key in a:
        assert np.array_equal(np.asarray(a[key]), np.asarray(b[key]))
    assert not np.array_equal(np.asarray(a["user"]), np.asarray(c["user"]))


def test_packed_reshuffle_stays_on_device():
    ds = _ds(512, 60, 40)
    packed = pack_ratings(ds, 32)
    packed.epoch_batches(seed=0, epoch=0)   # warm: key upload + jit compile
    with jax.transfer_guard("disallow"):
        # later epochs must not round-trip the table (or the key) through
        # the host; the epoch scalar crosses via an explicit device_put
        out = packed.epoch_batches(seed=0, epoch=1)
    assert out["user"].shape == (packed.num_steps, 32)


# ---------------------------------------------------------------------------
# shard integrity (CRC-32 in index.json)
# ---------------------------------------------------------------------------

def test_corrupt_shard_quarantined(tmp_path):
    """A bit-flipped shard fails its index.json CRC on first open: the
    loader raises CorruptShardError and the file is quarantined."""
    from repro.store import CorruptShardError

    d = str(tmp_path / "store")
    build_store(_ds(), d, shard_rows=512)
    shard_path = os.path.join(d, "shard_00001.bin")
    blob = bytearray(open(shard_path, "rb").read())
    blob[len(blob) // 2] ^= 0xFF
    open(shard_path, "wb").write(bytes(blob))

    store = RatingsStore(d)
    store._columns(0)                              # intact shard: fine
    with pytest.raises(CorruptShardError, match="crc32"):
        store._columns(1)
    assert os.path.exists(shard_path + ".corrupt")  # quarantined
    assert not os.path.exists(shard_path)


def test_corrupt_shard_caught_via_gather(tmp_path):
    from repro.store import CorruptShardError

    d = str(tmp_path / "store")
    build_store(_ds(), d, shard_rows=512)
    shard_path = os.path.join(d, "shard_00000.bin")
    blob = bytearray(open(shard_path, "rb").read())
    blob[0] ^= 0x01
    open(shard_path, "wb").write(bytes(blob))
    store = RatingsStore(d)
    with pytest.raises(CorruptShardError):
        store.gather(np.arange(16))


def test_shard_verification_is_once_and_optional(tmp_path):
    d = str(tmp_path / "store")
    build_store(_ds(), d, shard_rows=512)
    store = RatingsStore(d)
    store._columns(0)
    assert 0 in store._verified
    # opting out (trusted local disk): corrupt bytes flow through unchecked
    blob_path = os.path.join(d, "shard_00000.bin")
    unchecked = RatingsStore(d, verify_checksums=False)
    unchecked._columns(0)
    assert not unchecked._verified


def test_legacy_index_without_crc_loads(tmp_path):
    """Stores built before the checksum landed (no crc32 key) keep
    loading — verification is simply skipped for those shards."""
    import json

    d = str(tmp_path / "store")
    build_store(_ds(), d, shard_rows=512)
    index_path = os.path.join(d, "index.json")
    index = json.loads(open(index_path).read())
    for s in index["shards"]:
        s.pop("crc32")
    open(index_path, "w").write(json.dumps(index))
    store = RatingsStore(d)
    u, i, r = store.gather(np.arange(32))
    assert len(u) == 32 and not store._verified
