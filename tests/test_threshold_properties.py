"""Property tests: the Eq. 7/8 threshold solve round-trips with the
empirical pruned fraction, and ``rate=0`` means *exact* dense parity on
every serving path.

The solver fits N(mu, sigma) to the factor matrix and bisects Eq. 8, so
the round-trip ``rate -> threshold_for_rate -> empirical_pruned_fraction``
is exact for the fitted normal and approximate for the sample; Gaussian-
family matrices (dense, near-sparse small-sigma, shifted, column-permuted)
keep the model error small enough to bound tightly.  A column permutation
changes nothing the fit sees, so the measured fraction must be exactly
invariant — that pins the solve to the value *distribution*, not the
latent layout (rearrangement-safe, which online recalibration relies on).
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from hypothesis_compat import given, settings, st
from repro.core import mf
from repro.core.threshold import (
    MatrixStats,
    _pruned_fraction,
    empirical_pruned_fraction,
    measure_stats,
    solve_x,
    threshold_for_rate,
)
from repro.kernels import ops, ref
from repro.serving import ServingEngine


def _gaussian(m, k, mu, sigma, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(mu, sigma, (m, k)).astype(np.float32))


# ---------------------------------------------------------------------------
# solver exactness on its own model
# ---------------------------------------------------------------------------


@settings(max_examples=40, deadline=None)
@given(
    mu=st.floats(-0.5, 0.5),
    sigma=st.floats(0.02, 1.0),
    rate=st.floats(0.01, 0.95),
)
def test_solve_x_inverts_pruned_fraction(mu, sigma, rate):
    """Bisection must land on the x whose fitted-normal pruned mass is the
    asked rate — the solver is exact on its own model."""
    x = solve_x(jnp.float32(mu), jnp.float32(sigma), jnp.float32(rate))
    frac = float(_pruned_fraction(x, jnp.float32(mu), jnp.float32(sigma)))
    assert frac == pytest.approx(rate, abs=1e-4)


@settings(max_examples=30, deadline=None)
@given(rate=st.floats(0.05, 0.9), seed=st.integers(0, 50))
def test_threshold_rate_roundtrip_dense_gaussian(rate, seed):
    """rate -> T -> measured fraction round-trips within sampling error on
    a matrix the fitted normal describes well."""
    q = _gaussian(512, 64, 0.0, 0.1, seed)
    t = threshold_for_rate(measure_stats(q), rate)
    measured = float(empirical_pruned_fraction(q, t))
    assert measured == pytest.approx(rate, abs=0.03)


@pytest.mark.parametrize("mu,sigma,label", [
    (0.0, 0.1, "centered"),
    (0.05, 0.1, "shifted"),
    (0.0, 0.005, "near-sparse"),   # tiny magnitudes: most factors prunable
    (-0.08, 0.2, "negative-mean"),
])
@pytest.mark.parametrize("rate", [0.1, 0.45, 0.8])
def test_threshold_rate_roundtrip_matrix_families(mu, sigma, label, rate):
    q = _gaussian(1024, 32, mu, sigma, seed=7)
    t = threshold_for_rate(measure_stats(q), rate)
    measured = float(empirical_pruned_fraction(q, t))
    assert measured == pytest.approx(rate, abs=0.03), label


@settings(max_examples=20, deadline=None)
@given(rate=st.floats(0.05, 0.9), seed=st.integers(0, 20))
def test_rearranged_matrix_same_threshold_same_fraction(rate, seed):
    """Column permutation (what online recalibration's rearrange does) must
    change neither the fitted stats nor the measured pruned fraction."""
    q = _gaussian(256, 48, 0.01, 0.12, seed)
    perm = np.random.default_rng(seed + 1).permutation(48)
    q_re = q[:, perm]
    s, s_re = measure_stats(q), measure_stats(q_re)
    np.testing.assert_allclose(float(s.mu), float(s_re.mu), atol=1e-7)
    np.testing.assert_allclose(float(s.sigma), float(s_re.sigma), atol=1e-7)
    t = threshold_for_rate(s, rate)
    assert float(empirical_pruned_fraction(q, t)) == float(
        empirical_pruned_fraction(q_re, t)
    )


def test_rate_zero_threshold_is_exactly_zero():
    """Not approximately zero: the serving stack treats T == 0.0 as
    "pruning disabled" and the SLO relax-to-floor path needs bit-exact
    dense parity, so the bisection's float residue must be masked out."""
    for seed in range(5):
        q = _gaussian(128, 16, 0.02, 0.3, seed)
        t = threshold_for_rate(measure_stats(q), 0.0)
        assert float(t) == 0.0
        assert float(threshold_for_rate(measure_stats(q), -0.1)) == 0.0
        assert float(empirical_pruned_fraction(q, t)) == 0.0


# ---------------------------------------------------------------------------
# rate=0 ==> bitwise dense parity on every serving path
# ---------------------------------------------------------------------------


def _dense_oracle(p, q, topk):
    scores = np.asarray(p) @ np.asarray(q).T
    idx = np.argsort(-scores, axis=1, kind="stable")[:, :topk]
    return np.take_along_axis(scores, idx, axis=1), idx


def test_rate_zero_is_bitwise_dense_on_serving_paths():
    params = mf.init_params(jax.random.PRNGKey(0), 24, 400, 16,
                            variant="plain")
    t_p = threshold_for_rate(measure_stats(params.p), 0.0)
    t_q = threshold_for_rate(measure_stats(params.q), 0.0)
    users = np.arange(24)

    # streaming scan path
    engine = ServingEngine(params, t_p, t_q, use_kernel=False, block_n=128)
    s_stream, i_stream = engine.topk(users, 9)
    # interpreted Pallas kernel path
    s_kern, i_kern = ops.pruned_topk(
        params.p, params.q, t_p, t_q, 9, use_kernel=True, interpret=True
    )
    # reference pruned implementation at full ranks
    from repro.core.ranks import effective_ranks
    r_u = effective_ranks(params.p, t_p)
    r_i = effective_ranks(params.q, t_q)
    assert int(jnp.min(r_u)) == 16 and int(jnp.min(r_i)) == 16  # nothing cut
    s_ref, i_ref = ref.pruned_topk_ref(params.p, params.q, r_u, r_i, 9)

    _, i_dense = _dense_oracle(params.p, params.q, 9)
    for name, (s, i) in {
        "stream": (s_stream, i_stream),
        "kernel": (s_kern, i_kern),
        "ref": (s_ref, i_ref),
    }.items():
        assert np.array_equal(np.asarray(i), i_dense), name
        assert np.array_equal(np.asarray(s), np.asarray(s_ref)), name
