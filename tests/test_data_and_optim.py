"""Data pipeline determinism + embedding-bag/optimizer correctness."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis_compat import given, settings, st

from repro.data import loader
from repro.data.graphs import synthetic_graph, to_csr, neighbor_sample
from repro.data.ratings import synthetic_ratings, build_user_history
from repro.models.recsys import embedding_bag
from repro.optim.optimizers import RowOptimizer


def test_loader_deterministic_and_resumable():
    ds = synthetic_ratings(50, 60, 1000, seed=0)
    a = list(loader.iterate_batches(ds, 128, seed=3, epoch=2))
    b = list(loader.iterate_batches(ds, 128, seed=3, epoch=2))
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x["user"], y["user"])
    # resume mid-epoch
    c = list(loader.iterate_batches(ds, 128, seed=3, epoch=2, start_step=3))
    np.testing.assert_array_equal(a[3]["user"], c[0]["user"])
    # different epoch -> different order
    d = next(iter(loader.iterate_batches(ds, 128, seed=3, epoch=4)))
    assert not np.array_equal(a[0]["user"], d["user"])


def test_loader_eval_padding_weights():
    ds = synthetic_ratings(50, 60, 1000, seed=0)
    batches = list(loader.iterate_batches(ds, 300, shuffle=False,
                                          drop_remainder=False))
    assert len(batches) == 4
    assert batches[-1]["weight"].sum() == 1000 - 3 * 300
    assert all(b["user"].shape == (300,) for b in batches)


def test_user_history_padding():
    ds = synthetic_ratings(20, 30, 500, seed=0)
    hist = build_user_history(ds, max_hist=8)
    assert hist.shape == (20, 8)
    assert hist.max() <= 30  # padding value == num_items


def test_neighbor_sampler_is_valid_subgraph():
    g = synthetic_graph(300, 2000, 8, seed=0)
    indptr, indices = to_csr(g.edges, g.num_nodes)
    seeds = np.arange(10)
    nodes, edges_local, n_seeds = neighbor_sample(indptr, indices, seeds, [4, 3], seed=1)
    assert n_seeds == 10
    assert (nodes[:10] == seeds).all()
    real = edges_local[edges_local[:, 0] >= 0]
    # every local edge maps to a real global edge
    edge_set = {(int(s), int(d)) for s, d in g.edges}
    for src_l, dst_l in real[:200]:
        assert (int(nodes[src_l]), int(nodes[dst_l])) in edge_set
    # fanout respected: each dst draws at most fanout distinct srcs per layer
    assert len(real) <= 10 * 4 + (len(nodes) - 10) * 3


@given(
    st.integers(2, 40),   # vocab
    st.integers(1, 6),    # dim
    st.integers(1, 30),   # nnz
    st.integers(1, 8),    # bags
    st.sampled_from(["sum", "mean"]),
)
@settings(max_examples=30, deadline=None)
def test_embedding_bag_equals_onehot_matmul(vocab, dim, nnz, bags, combiner):
    rng = np.random.default_rng(0)
    table = jnp.asarray(rng.normal(0, 1, (vocab, dim)).astype(np.float32))
    values = jnp.asarray(rng.integers(0, vocab, nnz), jnp.int32)
    segments = jnp.asarray(np.sort(rng.integers(0, bags, nnz)), jnp.int32)
    out = embedding_bag(table, values, segments, bags, combiner=combiner)

    onehot = jax.nn.one_hot(values, vocab)  # (nnz, V)
    seg_onehot = jax.nn.one_hot(segments, bags).T  # (bags, nnz)
    expected = seg_onehot @ (onehot @ table)
    if combiner == "mean":
        counts = np.maximum(np.bincount(np.asarray(segments), minlength=bags), 1)
        expected = expected / counts[:, None]
    np.testing.assert_allclose(np.asarray(out), np.asarray(expected),
                               rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("name", ["sgd", "adagrad", "adadelta", "adam"])
def test_row_optimizer_masked_update(name):
    """Masked coordinates never move; unmasked ones follow the update rule."""
    opt = RowOptimizer(name=name)
    param = jnp.ones((10, 4))
    state = opt.init(param)
    idx = jnp.asarray([2, 5])
    grad = jnp.ones((2, 4))
    mask = jnp.asarray([[1.0, 1, 0, 0], [1, 1, 1, 1]])
    new_param, _ = opt.apply_rows(param, state, idx, grad, mask, 0.1)
    np.testing.assert_array_equal(np.asarray(new_param[2, 2:]), [1.0, 1.0])
    assert float(new_param[2, 0]) < 1.0
    assert float(new_param[5, 3]) < 1.0
    untouched = np.delete(np.arange(10), [2, 5])
    np.testing.assert_array_equal(np.asarray(new_param[untouched]), 1.0)


def test_row_sgd_matches_closed_form():
    opt = RowOptimizer(name="sgd")
    param = jnp.zeros((4, 3))
    idx = jnp.asarray([1, 1])  # duplicate rows accumulate
    grad = jnp.ones((2, 3))
    mask = jnp.ones((2, 3))
    new_param, _ = opt.apply_rows(param, {}, idx, grad, mask, 0.5)
    np.testing.assert_allclose(np.asarray(new_param[1]), -1.0)  # 2 * -0.5


def test_row_adagrad_matches_closed_form():
    opt = RowOptimizer(name="adagrad", eps=0.0)
    param = jnp.zeros((2, 2))
    state = opt.init(param)
    idx = jnp.asarray([0])
    grad = 2.0 * jnp.ones((1, 2))
    mask = jnp.ones((1, 2))
    p1, s1 = opt.apply_rows(param, state, idx, grad, mask, 0.1)
    # delta = -lr * g / sqrt(g^2) = -lr
    np.testing.assert_allclose(np.asarray(p1[0]), -0.1, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(s1["acc"][0]), 4.0)
