"""Optimizers.

Two families:

* **Row optimizers** — the MF/embedding path.  State lives alongside the
  (rows, k) table; updates touch only gathered rows and are scattered back
  with duplicate-safe ``.at[].add``.  All of them accept the paper's pruning
  ``mask`` so Algorithm 3's truncated update composes with any optimizer
  (paper §5.3 shows the method is optimizer-agnostic; we implement SGD,
  momentum, Adagrad — LibMF's default — AdaDelta and Adam).
* **Dense optimizers** — pytree-wide Adam/SGD for the non-MF architectures
  (transformers, GNN, recsys MLPs).

All functions are jit-safe and shard-transparent: they are elementwise or
gather/scatter ops, so SPMD partitioning propagates table shardings into the
optimizer state untouched.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

Pytree = Any


# ---------------------------------------------------------------------------
# Row optimizers (embedding tables / factor matrices)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RowOptimizer:
    """Interface: ``init(param) -> state``;  ``apply_rows`` returns updates."""

    name: str = "sgd"
    eps: float = 1e-8
    rho: float = 0.95     # adadelta decay
    beta1: float = 0.9    # adam
    beta2: float = 0.999  # adam
    mu: float = 0.9       # momentum

    def init(self, param: jax.Array) -> Dict[str, jax.Array]:
        zeros = lambda: jnp.zeros_like(param)  # noqa: E731
        if self.name == "sgd":
            return {}
        if self.name == "momentum":
            return {"mom": zeros()}
        if self.name == "adagrad":
            return {"acc": zeros()}
        if self.name == "adadelta":
            return {"eg2": zeros(), "edx2": zeros()}
        if self.name == "adam":
            return {"m": zeros(), "v": zeros(), "t": jnp.zeros((), jnp.int32)}
        raise ValueError(f"unknown row optimizer {self.name!r}")

    def apply_rows(
        self,
        param: jax.Array,
        state: Dict[str, jax.Array],
        idx: jax.Array,        # (B,) row indices (duplicates allowed)
        grad_rows: jax.Array,  # (B, k) gradient of the gathered rows
        mask: jax.Array,       # (B, k) 0/1 pruning mask (Alg. 3); 1s = update
        lr: float | jax.Array,
    ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        g = grad_rows.astype(jnp.float32) * mask
        if self.name == "sgd":
            return param.at[idx].add((-lr * g).astype(param.dtype)), state

        if self.name == "momentum":
            # Heavy ball on the masked gradient.  Like adadelta/adam,
            # duplicate rows collapse to the last write and an all-zero mask
            # still decays + writes back the row's momentum — zero-weight
            # rows gate the param update, not the state (mf.train_step NB).
            mom_rows = self.mu * state["mom"][idx] + g
            return (
                param.at[idx].add((-lr * mom_rows * mask).astype(param.dtype)),
                {"mom": state["mom"].at[idx].set(mom_rows)},
            )

        if self.name == "adagrad":
            acc_rows = state["acc"][idx] + g * g
            delta = -lr * g / jnp.sqrt(acc_rows + self.eps) * mask
            return (
                param.at[idx].add(delta.astype(param.dtype)),
                {"acc": state["acc"].at[idx].add(g * g)},
            )

        if self.name == "adadelta":
            eg2_rows = self.rho * state["eg2"][idx] + (1 - self.rho) * g * g
            dx = (
                -jnp.sqrt(state["edx2"][idx] + self.eps)
                / jnp.sqrt(eg2_rows + self.eps)
                * g
            ) * mask
            edx2_rows = self.rho * state["edx2"][idx] + (1 - self.rho) * dx * dx
            # EMA state is written back per-row (set, not add): duplicates in a
            # batch collapse to the last occurrence, matching sequential SGD up
            # to batch reordering.
            return (
                param.at[idx].add(dx.astype(param.dtype)),
                {
                    "eg2": state["eg2"].at[idx].set(eg2_rows),
                    "edx2": state["edx2"].at[idx].set(edx2_rows),
                },
            )

        if self.name == "adam":
            t = state["t"] + 1
            m_rows = self.beta1 * state["m"][idx] + (1 - self.beta1) * g
            v_rows = self.beta2 * state["v"][idx] + (1 - self.beta2) * g * g
            mhat = m_rows / (1 - self.beta1 ** t.astype(jnp.float32))
            vhat = v_rows / (1 - self.beta2 ** t.astype(jnp.float32))
            delta = -lr * mhat / (jnp.sqrt(vhat) + self.eps) * mask
            return (
                param.at[idx].add(delta.astype(param.dtype)),
                {
                    "m": state["m"].at[idx].set(m_rows),
                    "v": state["v"].at[idx].set(v_rows),
                    "t": t,
                },
            )
        raise ValueError(f"unknown row optimizer {self.name!r}")


# ---------------------------------------------------------------------------
# Dense optimizers (full-model pytrees)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Adam:
    lr: float = 1e-3
    beta1: float = 0.9
    beta2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0

    def init(self, params: Pytree) -> Pytree:
        zeros = jax.tree_util.tree_map(lambda p: jnp.zeros_like(p, jnp.float32), params)
        return {
            "m": zeros,
            "v": jax.tree_util.tree_map(jnp.zeros_like, zeros),
            "t": jnp.zeros((), jnp.int32),
        }

    def apply(self, params: Pytree, state: Pytree, grads: Pytree, lr_scale=1.0):
        t = state["t"] + 1
        tf = t.astype(jnp.float32)
        b1c = 1 - self.beta1 ** tf
        b2c = 1 - self.beta2 ** tf

        def upd(p, g, m, v):
            g = g.astype(jnp.float32)
            m = self.beta1 * m + (1 - self.beta1) * g
            v = self.beta2 * v + (1 - self.beta2) * g * g
            step = self.lr * lr_scale * (m / b1c) / (jnp.sqrt(v / b2c) + self.eps)
            if self.weight_decay:
                step = step + self.lr * lr_scale * self.weight_decay * p.astype(
                    jnp.float32
                )
            return (p.astype(jnp.float32) - step).astype(p.dtype), m, v

        flat_p, treedef = jax.tree_util.tree_flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_m = treedef.flatten_up_to(state["m"])
        flat_v = treedef.flatten_up_to(state["v"])
        out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
        new_p = treedef.unflatten([o[0] for o in out])
        new_m = treedef.unflatten([o[1] for o in out])
        new_v = treedef.unflatten([o[2] for o in out])
        return new_p, {"m": new_m, "v": new_v, "t": t}


@dataclasses.dataclass(frozen=True)
class Sgd:
    lr: float = 1e-2
    momentum: float = 0.0

    def init(self, params: Pytree) -> Pytree:
        if self.momentum == 0.0:
            return {}
        return {
            "mom": jax.tree_util.tree_map(
                lambda p: jnp.zeros_like(p, jnp.float32), params
            )
        }

    def apply(self, params: Pytree, state: Pytree, grads: Pytree, lr_scale=1.0):
        if self.momentum == 0.0:
            new_p = jax.tree_util.tree_map(
                lambda p, g: (p - self.lr * lr_scale * g.astype(p.dtype)).astype(
                    p.dtype
                ),
                params,
                grads,
            )
            return new_p, state

        def upd(p, g, m):
            m = self.momentum * m + g.astype(jnp.float32)
            return (p.astype(jnp.float32) - self.lr * lr_scale * m).astype(p.dtype), m

        flat_p, treedef = jax.tree_util.tree_flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_m = treedef.flatten_up_to(state["mom"])
        out = [upd(p, g, m) for p, g, m in zip(flat_p, flat_g, flat_m)]
        return (
            treedef.unflatten([o[0] for o in out]),
            {"mom": treedef.unflatten([o[1] for o in out])},
        )
