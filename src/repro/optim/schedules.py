"""Learning-rate schedules and optimization strategies.

Includes the *twin-learners* strategy (Chin et al., PAKDD'15) evaluated in the
paper's §5.3: a subset of latent dimensions is frozen during the first epoch
so that, under adaptive optimizers, their accumulators stay empty and they
later train with an effectively fresh (large) learning rate — escaping the
"learning rate only changes dramatically in the first few epochs" problem.
"""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: lr


def cosine(lr: float, total_steps: int, warmup: int = 0, floor: float = 0.0):
    def fn(step):
        step = jnp.asarray(step, jnp.float32)
        warm = lr * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total_steps - warmup, 1), 0.0, 1.0)
        cos = floor + (lr - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, cos)

    return fn


def twin_learners_mask(k: int, epoch: int, twin_fraction: float = 0.5, dtype=jnp.float32):
    """Per-dimension update mask for the twin-learners strategy.

    Epoch 1 (``epoch == 0``): the trailing ``twin_fraction`` of latent dims is
    frozen.  All later epochs: everything trains.  Composes multiplicatively
    with the pruning mask from Algorithm 3.
    """
    if epoch > 0:
        return jnp.ones((k,), dtype)
    cut = int(round(k * (1.0 - twin_fraction)))
    return (jnp.arange(k) < cut).astype(dtype)
