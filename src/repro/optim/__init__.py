from repro.optim.optimizers import Adam, RowOptimizer, Sgd  # noqa: F401
from repro.optim.schedules import constant, cosine, twin_learners_mask  # noqa: F401
