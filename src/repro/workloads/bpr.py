"""BPR pairwise ranking (Rendle et al., UAI'09) under dynamic pruning.

BPR optimizes AUC-like pairwise order: for a user ``u``, an interacted item
``i`` and a sampled non-interacted item ``j``, minimize

    -log σ(s_ui - s_uj)  +  0.5·lam·(||x_u||² + ||y_i||² + ||y_j||²).

Every score ``s_ui = x_u·y_i`` is the latent dot product the paper's
dynamic pruning truncates: each pair stops at ``min(rank(x_u), rank(y_i))``
dims (the same ``effective_ranks`` / ``rank_mask`` machinery as
``mf.train_step``), regularization is masked by each row's own rank, and —
as in ``mf._train_step`` — the masks are treated as constants
(``stop_gradient``), so :func:`bpr_train_step` IS the exact gradient of the
masked loss.  Rate 0 recovers dense BPR bit-for-bit.  The differential
oracle tests pin both properties (``tests/test_workloads.py``): parity with
``jax.grad`` of the masked loss, and with the NumPy reference
``kernels.ref.bpr_step_ref`` on 1/8-grid factors.

The epoch driver mirrors the explicit path: :class:`BPRSampler` draws the
per-epoch (user, pos, neg) triples on the host (fresh negatives every
epoch, deterministic in ``(seed, epoch)``), and :func:`bpr_epoch_scan`
folds :func:`bpr_train_step` over the uploaded triples with the same
donated ``lax.scan`` as ``mf.train_epoch_scan``.
"""
from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mf
from repro.core.ranks import effective_ranks, rank_mask
from repro.data.ratings import RatingsDataset
from repro.optim.optimizers import RowOptimizer
from repro.workloads.implicit import _positive_sets, _sample_negatives


def _bpr_train_step(
    params: mf.MFParams,
    opt_state: mf.MFOptState,
    batch: Dict[str, jax.Array],   # {"user", "pos", "neg", opt. "weight"}
    t_p: jax.Array,
    t_q: jax.Array,
    lr: jax.Array,
    dim_mask: jax.Array,
    *,
    opt: RowOptimizer,
    lam: float,
) -> Tuple[mf.MFParams, mf.MFOptState, Dict[str, jax.Array]]:
    """One pruned BPR update on (user, pos, neg) triples.

    Pair scores truncate at ``min(r_u, r_item)`` exactly like
    ``predict_pairs``; the regularizer is masked by each row's own rank.
    With ``params.user_bias`` present the item bias joins the score (the
    user bias and global mean cancel in the pairwise difference and stay
    untouched).  An optional ``batch["weight"]`` gates triples out of the
    update and the metrics, mirroring ``train_step``'s weight contract
    (weight 0 = triple fully inert under SGD/Adagrad).  Both positive and
    negative q-rows scatter through ONE ``apply_rows`` call on concatenated
    indices, so a triple whose ``pos == neg`` accumulates additively
    (duplicate-safe) instead of racing.
    """
    u, i, j = batch["user"], batch["pos"], batch["neg"]
    weight = batch.get("weight")
    k = params.p.shape[-1]

    x_u = params.p[u]
    y_i = params.q[i]
    y_j = params.q[j]
    r_u = effective_ranks(x_u, t_p)
    r_i = effective_ranks(y_i, t_q)
    r_j = effective_ranks(y_j, t_q)
    rank_ui = jnp.minimum(r_u, r_i)
    rank_uj = jnp.minimum(r_u, r_j)
    m_ui = rank_mask(rank_ui, k) * dim_mask[None, :]
    m_uj = rank_mask(rank_uj, k) * dim_mask[None, :]
    m_u = rank_mask(r_u, k) * dim_mask[None, :]
    m_i = rank_mask(r_i, k) * dim_mask[None, :]
    m_j = rank_mask(r_j, k) * dim_mask[None, :]

    xf = x_u.astype(jnp.float32)
    yif = y_i.astype(jnp.float32)
    yjf = y_j.astype(jnp.float32)
    s_ui = jnp.sum(xf * yif * m_ui, axis=-1)
    s_uj = jnp.sum(xf * yjf * m_uj, axis=-1)
    if params.item_bias is not None:
        s_ui = s_ui + params.item_bias[i, 0]
        s_uj = s_uj + params.item_bias[j, 0]
    diff = s_ui - s_uj
    # d(-log σ(diff))/d(diff) = -(1 - σ(diff)) = -σ(-diff)
    sig = jax.nn.sigmoid(-diff)
    w = (
        jnp.ones_like(diff) if weight is None else weight.astype(jnp.float32)
    )

    g_p = -sig[:, None] * (yif * m_ui - yjf * m_uj) + lam * xf * m_u
    g_qi = -sig[:, None] * xf * m_ui + lam * yif * m_i
    g_qj = sig[:, None] * xf * m_uj + lam * yjf * m_j

    w_col = jnp.broadcast_to(w[:, None], (w.shape[0], k))
    new_p, st_p = opt.apply_rows(params.p, opt_state.p, u, g_p, w_col, lr)
    idx_q = jnp.concatenate([i, j])
    g_q = jnp.concatenate([g_qi, g_qj])
    new_q, st_q = opt.apply_rows(
        params.q, opt_state.q, idx_q, g_q,
        jnp.concatenate([w_col, w_col]), lr,
    )
    new_params = params._replace(p=new_p, q=new_q)
    new_state = opt_state._replace(p=st_p, q=st_q)

    if params.item_bias is not None:
        g_bi = -sig[:, None] + lam * params.item_bias[i]
        g_bj = sig[:, None] + lam * params.item_bias[j]
        new_bi, st_bi = opt.apply_rows(
            params.item_bias, opt_state.item_bias, idx_q,
            jnp.concatenate([g_bi, g_bj]),
            jnp.concatenate([w[:, None], w[:, None]]), lr,
        )
        new_params = new_params._replace(item_bias=new_bi)
        new_state = new_state._replace(item_bias=st_bi)

    denom = jnp.maximum(jnp.sum(w), 1e-9)
    loss = jnp.log1p(jnp.exp(-jnp.abs(diff))) + jnp.maximum(-diff, 0.0)
    metrics = {
        # abs_err carries the mean BPR loss so the shared epoch-scan
        # accumulators (and EpochRecord.train_abs_err) stay meaningful
        "abs_err": jnp.sum(loss * w) / denom,
        "work_fraction": jnp.sum(
            (rank_ui + rank_uj).astype(jnp.float32) * w
        ) / (denom * 2 * k),
    }
    return new_params, new_state, metrics


bpr_train_step = jax.jit(_bpr_train_step, static_argnames=("opt", "lam"))


@functools.partial(
    jax.jit, static_argnames=("opt", "lam"), donate_argnums=(0, 1)
)
def bpr_epoch_scan(
    params: mf.MFParams,
    opt_state: mf.MFOptState,
    batches: Dict[str, jax.Array],   # each value (steps, B)
    t_p: jax.Array,
    t_q: jax.Array,
    lr: jax.Array,
    dim_mask: jax.Array,
    *,
    opt: RowOptimizer,
    lam: float,
) -> Tuple[mf.MFParams, mf.MFOptState, Dict[str, jax.Array]]:
    """A whole BPR epoch as one donated computation — the pairwise analogue
    of ``mf.train_epoch_scan``, folding :func:`bpr_train_step` over packed
    (user, pos, neg) triples with the shared ``mf._epoch_scan`` body."""

    def step(p, s, batch):
        return _bpr_train_step(
            p, s, batch, t_p, t_q, lr, dim_mask, opt=opt, lam=lam
        )

    return mf._epoch_scan(step, params, opt_state, batches)


class BPRSampler:
    """Per-epoch (user, pos, neg) triples from an interaction log.

    Every interaction of ``ds`` is a positive; negatives are drawn fresh
    each epoch, uniformly over the catalog with rejection against the
    user's positive set (:func:`~repro.workloads.implicit._sample_negatives`
    semantics).  Deterministic in ``(seed, epoch)`` like the training
    loader, so checkpoint restarts replay identical triples.  Triples are
    uploaded per epoch as ``(steps, B)`` device arrays — the operand of
    :func:`bpr_epoch_scan`.
    """

    def __init__(self, ds: RatingsDataset, batch_size: int, *, seed: int = 0):
        self.user = np.asarray(ds.user, np.int32)
        self.item = np.asarray(ds.item, np.int32)
        self.num_items = ds.num_items
        self.seed = seed
        self.batch_size = min(int(batch_size), max(self.user.size, 1))
        self._pos_sets = _positive_sets(self.user, self.item, ds.num_users)

    @property
    def num_steps(self) -> int:
        return self.user.size // self.batch_size

    def epoch_triples(self, epoch: int) -> Dict[str, jnp.ndarray]:
        """Shuffled positives + fresh negatives for one epoch, shaped
        ``(steps, batch_size)`` on device."""
        if self.num_steps == 0:
            raise ValueError(
                f"batch_size {self.batch_size} exceeds the dataset "
                f"({self.user.size} interactions)"
            )
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, epoch, 0xB9])
        )
        take = rng.permutation(self.user.size)[
            : self.num_steps * self.batch_size
        ]
        users = self.user[take]
        pos = self.item[take]
        neg = _sample_negatives(rng, users, self._pos_sets, self.num_items)
        shape = (self.num_steps, self.batch_size)
        return {
            "user": jnp.asarray(users.reshape(shape)),
            "pos": jnp.asarray(pos.reshape(shape)),
            "neg": jnp.asarray(neg.reshape(shape)),
        }
