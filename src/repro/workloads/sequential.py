"""Sequential recommendation served by the pruned MF engine.

SASRec (``models/recsys.py``) encodes an item-id session into a hidden
state whose dot product with the item embedding table ranks the next item
— structurally identical to MF serving, where a *user vector* scores
against the item factor matrix.  So the dormant sequential path wires into
the existing serving stack with zero engine changes: treat the final-state
encodings as the rows of ``MFParams.p`` and the item embedding table
(minus its padding row 0) as ``MFParams.q``, and every
:class:`~repro.serving.engine.ServingEngine` path — streaming top-k,
Pallas kernel, ``topk_sharded`` on a mesh, pruned or dense — serves
sessions.

Id mapping: SASRec item ids are 1-based (id 0 is the padding token), the
engine's item axis is 0-based; engine item index ``j`` is item id
``j + 1``.  :func:`serve_sessions` applies the shift so callers see item
ids.  "User" ids on the session engine are session indices — row ``s`` of
the ``seqs`` batch it was built from.

Parity contract (pinned in ``tests/test_eval_ranking.py`` /
``tests/test_pruned_topk_properties.py``): at thresholds 0 the engine's
top-k over session vectors equals the brute-force ``dense_topk`` oracle
and the dense ``sasrec_retrieval`` argsort exactly, on every serving path.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mf
from repro.models import recsys
from repro.serving.engine import ServingEngine


def encode_sessions(
    sasrec_params,
    seqs: jax.Array,   # (S, L) item ids, 0 = pad, prefix-padded
    cfg: recsys.SASRecConfig,
) -> jax.Array:
    """Final-position SASRec hidden states: one (d,) user vector per
    session — exactly the query vector ``sasrec_retrieval`` scores with."""
    return recsys.sasrec_encode(sasrec_params, jnp.asarray(seqs), cfg)[:, -1]


def session_params(
    sasrec_params,
    seqs: jax.Array,
    cfg: recsys.SASRecConfig,
) -> mf.MFParams:
    """Session encodings + item embeddings as an :class:`~repro.core.mf.
    MFParams` view: ``p[s]`` is session ``s``'s vector, ``q[j]`` is item id
    ``j + 1`` (padding row 0 dropped), no biases — the factor pair the
    pruned serving stack consumes unchanged."""
    p = encode_sessions(sasrec_params, seqs, cfg)
    q = sasrec_params["item_embed"][1:]
    return mf.MFParams(
        p=p, q=q, user_bias=None, item_bias=None,
        global_mean=None, implicit=None,
    )


def session_engine(
    sasrec_params,
    seqs: jax.Array,
    cfg: recsys.SASRecConfig,
    t_p: float = 0.0,
    t_q: float = 0.0,
    **engine_kwargs,
) -> ServingEngine:
    """A :class:`ServingEngine` over the encoded sessions.

    ``engine_kwargs`` pass through (``use_kernel``, ``max_batch``,
    ``block_n``, ...); thresholds prune session vectors (``t_p``) and item
    embeddings (``t_q``) with the usual rate-0-is-dense contract.
    """
    return ServingEngine(
        session_params(sasrec_params, seqs, cfg), t_p, t_q, **engine_kwargs
    )


def serve_sessions(
    engine: ServingEngine,
    session_ids,
    topk: int = 10,
    *,
    mesh=None,
) -> Tuple[np.ndarray, np.ndarray]:
    """Top-k *item ids* (1-based, as SASRec speaks them) for session rows.

    Routes through ``engine.topk`` — or ``topk_sharded`` when ``mesh`` is
    given — and shifts the engine's 0-based item indices back to ids.
    """
    if mesh is not None:
        scores, idx = engine.topk_sharded(session_ids, topk, mesh=mesh)
    else:
        scores, idx = engine.topk(session_ids, topk)
    return np.asarray(scores), np.asarray(idx) + 1
