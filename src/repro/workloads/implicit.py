"""Confidence-weighted implicit-feedback MF (Hu/Koren/Volinsky, ICDM'08).

Implicit feedback gives no ratings — only observed interactions (clicks,
plays, purchases).  The WALS formulation trains on *binary preference*
``p_ui ∈ {0, 1}`` with a per-example *confidence* ``c_ui = 1 + alpha·r_ui``
(``r_ui`` = interaction strength; 1 for a bare click), minimizing

    sum_ui  c_ui · (p_ui - x_u·y_i)^2  +  lam·(||X||^2 + ||Y||^2).

That is exactly the weighted least-squares objective the existing stack
already speaks: the binary preference becomes the ``rating`` column and the
confidence becomes the ``batch["weight"]`` gate of ``mf.train_step`` /
``fused_mf_sgd`` — the weight scales the update (and metrics), never the
prediction, which is precisely the WALS gradient ``c_ui·err·y_i``.  So the
implicit objective flows through ``train_epoch_scan``, the fused Pallas
kernel, and the ``OnlineUpdater`` *unchanged*; this module only owns the
data transformation (positives + sampled negatives + confidence column).

Unobserved (user, item) pairs are weak negatives: preference 0 at the floor
confidence 1.  Training on every unobserved cell is O(m·n), so — as in
cuMF/implicit-ALS practice for SGD solvers — we sample ``negatives``
unobserved items per positive.
"""
from __future__ import annotations

from typing import Iterable, Iterator, Optional, Tuple

import numpy as np

from repro.data.ratings import RatingsDataset
from repro.online.stream import Event, EventBatch, iter_microbatches


def confidence_weights(ratings: np.ndarray, alpha: float) -> np.ndarray:
    """WALS confidence ``c = 1 + alpha·r`` for interaction strengths ``r``."""
    return (1.0 + alpha * np.asarray(ratings, np.float32)).astype(np.float32)


def _positive_sets(user: np.ndarray, item: np.ndarray, num_users: int):
    """Per-user sets of interacted items, for negative rejection."""
    sets = [set() for _ in range(num_users)]
    for u, i in zip(user, item):
        sets[u].add(int(i))
    return sets


def _sample_negatives(
    rng: np.random.Generator,
    users: np.ndarray,
    pos_sets,
    num_items: int,
    *,
    max_tries: int = 16,
) -> np.ndarray:
    """One uniformly-sampled unobserved item per row of ``users``.

    Rejection against the user's positive set, bounded at ``max_tries``
    draws per row (a user who interacted with the whole catalog keeps the
    last draw — a true negative does not exist for them).
    """
    neg = rng.integers(0, num_items, users.size).astype(np.int32)
    for _ in range(max_tries):
        clash = np.asarray(
            [int(n) in pos_sets[u] for u, n in zip(users, neg)], bool
        )
        if not clash.any():
            break
        neg[clash] = rng.integers(0, num_items, int(clash.sum()))
    return neg


def implicit_dataset(
    ds: RatingsDataset,
    *,
    alpha: float = 40.0,
    negatives: int = 4,
    seed: int = 0,
) -> Tuple[RatingsDataset, np.ndarray]:
    """Derive the WALS training set from an interaction log.

    Every interaction of ``ds`` becomes a positive example — preference
    (rating) 1 with confidence ``1 + alpha·r`` where ``r`` is the original
    rating column read as interaction strength — and each positive draws
    ``negatives`` sampled unobserved items at preference 0, confidence 1
    (the floor every unobserved cell carries in Hu et al.).

    Returns ``(binary_ds, confidence)``: a :class:`RatingsDataset` with
    ratings in {0, 1} on the same (num_users, num_items) geometry, plus the
    aligned confidence column to pass as ``pack_ratings(..., weight=...)``
    (or a batch's ``weight`` key).  Deterministic in ``seed``.
    """
    if negatives < 0:
        raise ValueError(f"negatives must be >= 0, got {negatives}")
    rng = np.random.default_rng(np.random.SeedSequence([seed, 7]))
    user = np.asarray(ds.user, np.int32)
    item = np.asarray(ds.item, np.int32)
    strength = np.asarray(ds.rating, np.float32)
    n = user.size

    pos_sets = _positive_sets(user, item, ds.num_users)
    users = [user]
    items = [item]
    ratings = [np.ones(n, np.float32)]
    weights = [confidence_weights(strength, alpha)]
    for _ in range(negatives):
        users.append(user)
        items.append(_sample_negatives(rng, user, pos_sets, ds.num_items))
        ratings.append(np.zeros(n, np.float32))
        weights.append(np.ones(n, np.float32))

    binary = RatingsDataset(
        user=np.concatenate(users),
        item=np.concatenate(items),
        rating=np.concatenate(ratings),
        num_users=ds.num_users,
        num_items=ds.num_items,
        rating_min=0.0,
        rating_max=1.0,
    )
    return binary, np.concatenate(weights)


def binarize_positives(ds: RatingsDataset) -> RatingsDataset:
    """Held-out positives as preference-1 examples (no negatives) — the
    eval-side counterpart of :func:`implicit_dataset`: test error becomes
    "how far from 1 does the model score the user's actual interactions"."""
    return RatingsDataset(
        user=np.asarray(ds.user, np.int32),
        item=np.asarray(ds.item, np.int32),
        rating=np.ones(len(ds), np.float32),
        num_users=ds.num_users,
        num_items=ds.num_items,
        rating_min=0.0,
        rating_max=1.0,
    )


def implicit_event_batch(
    batch: EventBatch,
    *,
    num_items: int,
    alpha: float = 40.0,
    negatives: int = 4,
    rng: Optional[np.random.Generator] = None,
) -> EventBatch:
    """Convert one click micro-batch into a WALS update batch.

    The streaming analogue of :func:`implicit_dataset`: each event becomes
    a preference-1 example at confidence ``1 + alpha·r`` (``r = 1`` when the
    batch is rating-free) plus ``negatives`` uniformly-sampled items at
    preference 0, confidence 1 — negatives reuse the event's user, so the
    update touches no rows serving has not already seen for this user.  The
    result always carries ratings and weights, so it feeds
    ``OnlineUpdater.apply`` directly.  If the incoming batch already has a
    recency ``weight`` column, it multiplies the confidence (both gate the
    update, so they compose multiplicatively).
    """
    if rng is None:
        rng = np.random.default_rng(0)
    n = len(batch)
    user = np.asarray(batch.user, np.int32)
    item = np.asarray(batch.item, np.int32)
    strength = (
        np.ones(n, np.float32) if batch.rating is None
        else np.asarray(batch.rating, np.float32)
    )
    conf = confidence_weights(strength, alpha)
    if batch.weight is not None:
        conf = conf * np.asarray(batch.weight, np.float32)

    users = [user]
    items = [item]
    ratings = [np.ones(n, np.float32)]
    weights = [conf]
    # per-batch positive rejection only: the stream owns no global catalog
    # view, so a negative is "not clicked in this batch by this user"
    seen = {(int(u), int(i)) for u, i in zip(user, item)}
    for _ in range(negatives):
        neg = rng.integers(0, num_items, n).astype(np.int32)
        for row in range(n):
            tries = 0
            while (int(user[row]), int(neg[row])) in seen and tries < 16:
                neg[row] = rng.integers(0, num_items)
                tries += 1
        users.append(user)
        items.append(neg)
        ratings.append(np.zeros(n, np.float32))
        weights.append(
            np.ones(n, np.float32) if batch.weight is None
            else np.asarray(batch.weight, np.float32)
        )
    return EventBatch(
        user=np.concatenate(users),
        item=np.concatenate(items),
        rating=np.concatenate(ratings),
        weight=np.concatenate(weights),
    )


def implicit_microbatches(
    source: Iterable[Event],
    batch_size: int,
    *,
    num_items: int,
    alpha: float = 40.0,
    negatives: int = 4,
    seed: int = 0,
    max_events: Optional[int] = None,
    half_life_s: Optional[float] = None,
) -> Iterator[EventBatch]:
    """Click stream → WALS update batches: :func:`iter_microbatches`
    composed with :func:`implicit_event_batch` (seeded, deterministic)."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, 0x11]))
    for batch in iter_microbatches(
        source, batch_size, max_events=max_events, half_life_s=half_life_s
    ):
        yield implicit_event_batch(
            batch, num_items=num_items, alpha=alpha,
            negatives=negatives, rng=rng,
        )


def strip_ratings(source: Iterable[Event]) -> Iterator[Event]:
    """View a rated stream as a rating-free click stream (``rating=None``)
    — what a click log looks like to the ranking-only prequential path."""
    for event in source:
        yield Event(event.user, event.item, None, event.timestamp)
