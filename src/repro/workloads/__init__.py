"""Workloads: the objectives the pruned engine serves beyond explicit MF.

The paper's machinery — thresholds, effective ranks, early-stopped matmul
and factor update — is objective-agnostic; this package opens the same
train → serve → refresh → evaluate pipeline to the workloads the field
actually runs:

* :mod:`repro.workloads.implicit` — confidence-weighted implicit MF
  (Hu/Koren/Volinsky 2008): clicks become binary preferences with
  per-example confidence weights that ride ``train_step``'s existing
  ``batch["weight"]`` gate, so the weighted objective flows through the
  epoch scan, the fused Pallas kernel and the online updater unchanged;
* :mod:`repro.workloads.bpr` — Bayesian Personalized Ranking (Rendle
  2009): a pairwise ``-log σ(s_ui - s_uj)`` objective whose masked
  gradients apply the same dynamic pruning per (user, item) pair;
* :mod:`repro.workloads.sequential` — SASRec session encodings served as
  user vectors by the unchanged pruned top-k engine.
"""
from repro.workloads.bpr import (  # noqa: F401
    BPRSampler,
    bpr_epoch_scan,
    bpr_train_step,
)
from repro.workloads.implicit import (  # noqa: F401
    binarize_positives,
    confidence_weights,
    implicit_dataset,
    implicit_event_batch,
    implicit_microbatches,
    strip_ratings,
)
from repro.workloads.sequential import (  # noqa: F401
    encode_sessions,
    session_params,
    session_engine,
    serve_sessions,
)
