"""Deterministic, resumable batch iteration — host-side and device-resident.

Shuffle order is a pure function of (seed, epoch), so a job restored from a
checkpoint at (epoch, step) replays the identical data order — the property
fault-tolerant restarts depend on (tests/test_checkpoint.py exercises it).
Batches are fixed-shape (pad-with-weight for eval, drop-remainder for train)
so a single compiled step serves the whole epoch.

Two data paths share these contracts:

* :func:`iterate_batches` — the legacy host loop: numpy slices yielded per
  step, uploaded by the caller.  Still the owner of mid-epoch resume
  (``start_step``) and of ad-hoc iteration.
* :class:`PackedRatings` / :func:`pack_eval_batches` — the epoch-compiled
  path: the ratings table is uploaded to the device ONCE at construction;
  each epoch draws a jitted on-device permutation (keyed on ``(seed,
  epoch)``, so it is exactly as deterministic as the host path, though the
  two orders differ) and reshapes into ``(steps, B)`` arrays that
  ``mf.train_epoch_scan`` folds over.  No per-step host→device uploads, no
  per-step dispatch.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.ratings import RatingsDataset


def epoch_permutation(n: int, seed: int, epoch: int) -> np.ndarray:
    rng = np.random.default_rng(np.random.SeedSequence([seed, epoch]))
    return rng.permutation(n)


def iterate_batches(
    ds: RatingsDataset,
    batch_size: int,
    *,
    seed: int = 0,
    epoch: int = 0,
    shuffle: bool = True,
    drop_remainder: bool = True,
    start_step: int = 0,
    hist: Optional[np.ndarray] = None,
) -> Iterator[Dict[str, np.ndarray]]:
    """Yield fixed-shape batches; resume mid-epoch with ``start_step``."""
    n = len(ds)
    order = epoch_permutation(n, seed, epoch) if shuffle else np.arange(n)
    num_full = n // batch_size
    steps = num_full if drop_remainder else -(-n // batch_size)
    for step in range(start_step, steps):
        idx = order[step * batch_size : (step + 1) * batch_size]
        weight = np.ones(batch_size, np.float32)
        if idx.shape[0] < batch_size:  # padded tail (eval only)
            pad = batch_size - idx.shape[0]
            weight[idx.shape[0]:] = 0.0
            idx = np.concatenate([idx, np.zeros(pad, idx.dtype)])
        batch = {
            "user": ds.user[idx],
            "item": ds.item[idx],
            "rating": ds.rating[idx],
        }
        if not drop_remainder:
            # train batches (drop_remainder) are always full: omitting the
            # all-ones weight keeps train_step's weight-free fast path (and
            # the fused-kernel route) eligible
            batch["weight"] = weight
        if hist is not None:
            batch["hist"] = hist[ds.user[idx]]
        yield batch


def num_steps(ds: RatingsDataset, batch_size: int, drop_remainder: bool = True) -> int:
    n = len(ds)
    return n // batch_size if drop_remainder else -(-n // batch_size)


# ---------------------------------------------------------------------------
# Device-resident packed epochs (the train_epoch_scan data path)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("steps", "batch_size", "shuffle"))
def _permute_and_batch(
    user: jax.Array,
    item: jax.Array,
    rating: jax.Array,
    base_key: jax.Array,
    epoch: jax.Array,
    weight: Optional[jax.Array] = None,
    *,
    steps: int,
    batch_size: int,
    shuffle: bool,
) -> Dict[str, jax.Array]:
    n = user.shape[0]
    if shuffle:
        # fold_in runs inside the jit so the per-epoch key derivation never
        # leaves the device; only the 4-byte epoch scalar crosses the host
        # boundary per epoch (and that via an explicit device_put)
        take = jax.random.permutation(jax.random.fold_in(base_key, epoch), n)[
            : steps * batch_size
        ]
    else:
        take = jnp.arange(steps * batch_size, dtype=jnp.int32)

    def gather(x):
        return x[take].reshape(steps, batch_size)

    out = {"user": gather(user), "item": gather(item), "rating": gather(rating)}
    if weight is not None:
        out["weight"] = gather(weight)
    return out


@dataclasses.dataclass(frozen=True)
class PackedRatings:
    """A ratings table uploaded to the device once, reshuffled on-device.

    ``epoch_batches(seed, epoch)`` returns ``{"user", "item", "rating"}``
    arrays shaped ``(steps, batch_size)`` — the operand of
    ``mf.train_epoch_scan``.  The permutation is a jitted
    ``jax.random.permutation`` keyed on ``fold_in(seed, epoch)``:
    deterministic per (seed, epoch), so checkpoint restarts replay the
    identical order, and no bytes cross the host boundary after
    construction.  Train semantics (drop-remainder) only; eval packing is
    :func:`pack_eval_batches`.
    """

    user: jax.Array     # (N,) int32, device-resident
    item: jax.Array     # (N,) int32
    rating: jax.Array   # (N,) float32
    batch_size: int
    # optional per-example importance weights (confidence weighting for the
    # implicit objective): shuffled alongside and emitted as the batches'
    # "weight" column, which train_step's update gate consumes
    weight: Optional[jax.Array] = None   # (N,) float32
    # per-seed base PRNG keys, uploaded once and reused every epoch so the
    # reshuffle stays device-resident (no hidden host round-trips); cache
    # state, not identity — excluded from eq/repr of the frozen dataclass
    _base_keys: Dict[int, jax.Array] = dataclasses.field(
        default_factory=dict, repr=False, compare=False
    )

    @property
    def num_examples(self) -> int:
        return int(self.user.shape[0])

    @property
    def num_steps(self) -> int:
        return self.num_examples // self.batch_size

    def epoch_batches(
        self, seed: int, epoch: int, *, shuffle: bool = True
    ) -> Dict[str, jax.Array]:
        if self.num_steps == 0:
            raise ValueError(
                f"batch_size {self.batch_size} exceeds the dataset "
                f"({self.num_examples} ratings)"
            )
        base = self._base_keys.get(seed)
        if base is None:
            base = self._base_keys.setdefault(
                seed, jax.device_put(jax.random.PRNGKey(seed))
            )
        return _permute_and_batch(
            self.user, self.item, self.rating, base,
            jax.device_put(np.uint32(epoch)), self.weight,
            steps=self.num_steps, batch_size=self.batch_size, shuffle=shuffle,
        )


def pack_ratings(
    ds: RatingsDataset,
    batch_size: int,
    *,
    weight: Optional[np.ndarray] = None,
) -> PackedRatings:
    """Upload the ratings table once; see :class:`PackedRatings`.

    ``weight`` attaches per-example importance weights (e.g. the implicit
    objective's confidence column) that ride through the epoch shuffle into
    each batch's ``weight`` gate.
    """
    if weight is not None and weight.shape[0] != len(ds):
        raise ValueError(
            f"weight length {weight.shape[0]} != dataset size {len(ds)}"
        )
    return PackedRatings(
        user=jnp.asarray(ds.user, jnp.int32),
        item=jnp.asarray(ds.item, jnp.int32),
        rating=jnp.asarray(ds.rating, jnp.float32),
        batch_size=int(batch_size),
        weight=None if weight is None else jnp.asarray(weight, jnp.float32),
    )


def pack_eval_batches(
    ds: RatingsDataset, batch_size: int
) -> Dict[str, jax.Array]:
    """Pre-packed ``(steps, B)`` eval batches, built and uploaded once.

    Deterministic order, padded tail carried by a zero ``weight`` column —
    the operand of ``mf.eval_epoch_scan`` (SVD++ histories are gathered on
    device inside the scan, not packed here).
    """
    n = len(ds)
    batch_size = min(batch_size, max(n, 1))
    steps = -(-n // batch_size)
    pad = steps * batch_size - n
    idx = np.concatenate([np.arange(n), np.zeros(pad, np.int64)])
    weight = np.concatenate(
        [np.ones(n, np.float32), np.zeros(pad, np.float32)]
    )
    return {
        "user": jnp.asarray(ds.user[idx].reshape(steps, batch_size)),
        "item": jnp.asarray(ds.item[idx].reshape(steps, batch_size)),
        "rating": jnp.asarray(ds.rating[idx].reshape(steps, batch_size)),
        "weight": jnp.asarray(weight.reshape(steps, batch_size)),
    }
