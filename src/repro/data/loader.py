"""Deterministic, resumable batch iteration.

Shuffle order is a pure function of (seed, epoch), so a job restored from a
checkpoint at (epoch, step) replays the identical data order — the property
fault-tolerant restarts depend on (tests/test_checkpoint.py exercises it).
Batches are fixed-shape (pad-with-weight for eval, drop-remainder for train)
so a single compiled step serves the whole epoch.
"""
from __future__ import annotations

from typing import Dict, Iterator, Optional

import numpy as np

from repro.data.ratings import RatingsDataset


def epoch_permutation(n: int, seed: int, epoch: int) -> np.ndarray:
    rng = np.random.default_rng(np.random.SeedSequence([seed, epoch]))
    return rng.permutation(n)


def iterate_batches(
    ds: RatingsDataset,
    batch_size: int,
    *,
    seed: int = 0,
    epoch: int = 0,
    shuffle: bool = True,
    drop_remainder: bool = True,
    start_step: int = 0,
    hist: Optional[np.ndarray] = None,
) -> Iterator[Dict[str, np.ndarray]]:
    """Yield fixed-shape batches; resume mid-epoch with ``start_step``."""
    n = len(ds)
    order = epoch_permutation(n, seed, epoch) if shuffle else np.arange(n)
    num_full = n // batch_size
    steps = num_full if drop_remainder else -(-n // batch_size)
    for step in range(start_step, steps):
        idx = order[step * batch_size : (step + 1) * batch_size]
        weight = np.ones(batch_size, np.float32)
        if idx.shape[0] < batch_size:  # padded tail (eval only)
            pad = batch_size - idx.shape[0]
            weight[idx.shape[0]:] = 0.0
            idx = np.concatenate([idx, np.zeros(pad, idx.dtype)])
        batch = {
            "user": ds.user[idx],
            "item": ds.item[idx],
            "rating": ds.rating[idx],
        }
        if not drop_remainder:
            # train batches (drop_remainder) are always full: omitting the
            # all-ones weight keeps train_step's weight-free fast path (and
            # the fused-kernel route) eligible
            batch["weight"] = weight
        if hist is not None:
            batch["hist"] = hist[ds.user[idx]]
        yield batch


def num_steps(ds: RatingsDataset, batch_size: int, drop_remainder: bool = True) -> int:
    n = len(ds)
    return n // batch_size if drop_remainder else -(-n // batch_size)
