from repro.data.loader import iterate_batches, num_steps  # noqa: F401
from repro.data.ratings import (  # noqa: F401
    RatingsDataset,
    build_user_history,
    load_csv,
    paper_dataset,
    synthetic_ratings,
    train_test_split,
)
