"""Graph data: synthetic cora/products-shaped graphs, a real fanout neighbor
sampler (GraphSAGE-style, uniform without replacement), and block-diagonal
batching for small molecule graphs.

All outputs are fixed-shape (padded) numpy arrays so one compiled GAT step
serves every minibatch — the padding contract is ``edge_mask``/label == -1.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import numpy as np


@dataclasses.dataclass
class Graph:
    features: np.ndarray   # (N, d) float32
    edges: np.ndarray      # (E, 2) int32 [src, dst]
    labels: np.ndarray     # (N,) int32; -1 = unlabeled
    n_classes: int

    @property
    def num_nodes(self) -> int:
        return self.features.shape[0]

    @property
    def num_edges(self) -> int:
        return self.edges.shape[0]


def synthetic_graph(
    num_nodes: int,
    num_edges: int,
    d_feat: int,
    n_classes: int = 7,
    *,
    labeled_fraction: float = 0.1,
    seed: int = 0,
    add_self_loops: bool = True,
) -> Graph:
    """Community-structured random graph: nodes get a class; edges prefer
    same-class endpoints (2:1), features = class centroid + noise, so a GAT
    can actually learn (smoke tests check loss decreases)."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, n_classes, num_nodes).astype(np.int32)
    centroids = rng.normal(0, 1, (n_classes, d_feat)).astype(np.float32)
    feats = centroids[labels] + rng.normal(0, 1.0, (num_nodes, d_feat)).astype(
        np.float32
    )

    n_intra = (2 * num_edges) // 3
    src_a = rng.integers(0, num_nodes, n_intra).astype(np.int32)
    # same-class destination: random node of the same label via per-class pools
    order = np.argsort(labels, kind="stable")
    class_start = np.searchsorted(labels[order], np.arange(n_classes))
    class_count = np.bincount(labels, minlength=n_classes)
    rand_off = rng.random(n_intra)
    dst_a = order[
        class_start[labels[src_a]]
        + (rand_off * np.maximum(class_count[labels[src_a]], 1)).astype(np.int64)
    ].astype(np.int32)
    src_b = rng.integers(0, num_nodes, num_edges - n_intra).astype(np.int32)
    dst_b = rng.integers(0, num_nodes, num_edges - n_intra).astype(np.int32)
    edges = np.stack(
        [np.concatenate([src_a, src_b]), np.concatenate([dst_a, dst_b])], axis=1
    )
    if add_self_loops:
        loops = np.stack([np.arange(num_nodes)] * 2, axis=1).astype(np.int32)
        edges = np.concatenate([edges, loops], axis=0)

    masked = labels.copy()
    unlabeled = rng.random(num_nodes) > labeled_fraction
    masked[unlabeled] = -1
    return Graph(features=feats, edges=edges, labels=masked, n_classes=n_classes)


def to_csr(edges: np.ndarray, num_nodes: int) -> Tuple[np.ndarray, np.ndarray]:
    """Incoming-edge CSR: for each dst node, the list of src neighbors."""
    dst = edges[:, 1]
    order = np.argsort(dst, kind="stable")
    sorted_src = edges[order, 0]
    counts = np.bincount(dst, minlength=num_nodes)
    indptr = np.concatenate([[0], np.cumsum(counts)]).astype(np.int64)
    return indptr, sorted_src.astype(np.int32)


def neighbor_sample(
    indptr: np.ndarray,
    indices: np.ndarray,
    seeds: np.ndarray,
    fanouts: Sequence[int],
    *,
    seed: int = 0,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Layer-wise uniform neighbor sampling (GraphSAGE).

    Returns (nodes, edges_local, seed_count): ``nodes`` are global ids with
    seeds first; ``edges_local`` index into ``nodes`` and are padded with
    (-1, -1) to the static size ``len(seeds) * prod-expansion``.
    """
    rng = np.random.default_rng(seed)
    node_ids: List[int] = list(seeds)
    local = {int(n): idx for idx, n in enumerate(seeds)}
    edge_src: List[int] = []
    edge_dst: List[int] = []
    frontier = list(seeds)
    max_edges = 0
    expansion = len(seeds)
    for fanout in fanouts:
        max_edges += expansion * fanout
        expansion *= fanout
        next_frontier: List[int] = []
        for dst_node in frontier:
            start, stop = indptr[dst_node], indptr[dst_node + 1]
            deg = stop - start
            if deg == 0:
                continue
            take = min(fanout, int(deg))
            picks = rng.choice(indices[start:stop], size=take, replace=False)
            for src_node in picks:
                src_node = int(src_node)
                if src_node not in local:
                    local[src_node] = len(node_ids)
                    node_ids.append(src_node)
                    next_frontier.append(src_node)
                edge_src.append(local[src_node])
                edge_dst.append(local[dst_node])
        frontier = next_frontier

    nodes = np.asarray(node_ids, np.int32)
    edges = np.full((max_edges, 2), -1, np.int32)
    if edge_src:
        edges[: len(edge_src), 0] = edge_src
        edges[: len(edge_dst), 1] = edge_dst
    return nodes, edges, len(seeds)


def pad_subgraph(
    graph: Graph,
    nodes: np.ndarray,
    edges_local: np.ndarray,
    num_nodes_pad: int,
):
    """Materialize a fixed-shape minibatch from a sampled subgraph."""
    n = min(len(nodes), num_nodes_pad)
    feats = np.zeros((num_nodes_pad, graph.features.shape[1]), np.float32)
    feats[:n] = graph.features[nodes[:n]]
    labels = np.full(num_nodes_pad, -1, np.int32)
    labels[:n] = graph.labels[nodes[:n]]
    mask = (edges_local[:, 0] >= 0) & (edges_local[:, 0] < n) & (
        edges_local[:, 1] < n
    )
    safe = np.where(edges_local < 0, 0, edges_local)
    return {
        "features": feats,
        "edges": safe.astype(np.int32),
        "edge_mask": mask.astype(np.float32),
        "labels": labels,
    }


def batch_molecules(
    graphs: List[Graph], nodes_per_graph: int, edges_per_graph: int
):
    """Block-diagonal batching: graph g's node i -> global g*nodes_per_graph+i."""
    b = len(graphs)
    d = graphs[0].features.shape[1]
    feats = np.zeros((b * nodes_per_graph, d), np.float32)
    edges = np.zeros((b * edges_per_graph, 2), np.int32)
    edge_mask = np.zeros(b * edges_per_graph, np.float32)
    labels = np.full(b * nodes_per_graph, -1, np.int32)
    for g, graph in enumerate(graphs):
        n = min(graph.num_nodes, nodes_per_graph)
        e = min(graph.num_edges, edges_per_graph)
        feats[g * nodes_per_graph : g * nodes_per_graph + n] = graph.features[:n]
        labels[g * nodes_per_graph : g * nodes_per_graph + n] = graph.labels[:n]
        off = g * nodes_per_graph
        edges[g * edges_per_graph : g * edges_per_graph + e] = graph.edges[:e] + off
        edge_mask[g * edges_per_graph : g * edges_per_graph + e] = 1.0
    return {
        "features": feats,
        "edges": edges,
        "edge_mask": edge_mask,
        "labels": labels,
    }
