"""Rating datasets: synthetic planted-low-rank generators shaped like the
paper's four benchmarks, plus a CSV loader for real data.

The container has no network access, so experiments run on synthetic data
whose (users, items, #ratings, rating scale) match Table 1 of the paper; the
generator plants a low-rank structure so MF has signal to recover and MAE
trends are meaningful.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np


@dataclasses.dataclass
class RatingsDataset:
    user: np.ndarray    # (N,) int32
    item: np.ndarray    # (N,) int32
    rating: np.ndarray  # (N,) float32
    num_users: int
    num_items: int
    rating_min: float = 1.0
    rating_max: float = 5.0

    def __len__(self) -> int:
        return self.user.shape[0]

    @property
    def global_mean(self) -> float:
        return float(self.rating.mean()) if len(self) else 0.0


def synthetic_ratings(
    num_users: int,
    num_items: int,
    num_ratings: int,
    *,
    k_true: int = 24,
    spectrum_decay: float = 0.7,
    noise: float = 0.35,
    rating_min: float = 1.0,
    rating_max: float = 5.0,
    seed: int = 0,
    integer_ratings: bool = True,
) -> RatingsDataset:
    """Planted low-rank ratings with a power-law item popularity and a
    *decaying factor spectrum* (sigma_j ~ (j+1)^-decay), the shape real
    rating data takes: a few blockbusters, a long tail, and singular values
    that fall off.  The spectral decay is what induces the paper's
    fine-grained structured sparsity in the *learned* factors (Fig. 3) —
    equal-variance planted factors would make per-dim sparsity uniform and
    the early-stopping regime degenerate (verified in EXPERIMENTS.md)."""
    rng = np.random.default_rng(seed)
    spectrum = (np.arange(1, k_true + 1) ** -spectrum_decay).astype(np.float32)
    spectrum *= (k_true / (spectrum ** 2).sum()) ** 0.5  # keep total variance
    scale = spectrum / np.sqrt(k_true)
    p_true = (rng.normal(0.0, 1.0, (num_users, k_true)) * scale).astype(np.float32)
    q_true = (rng.normal(0.0, 1.0, (num_items, k_true)) * scale).astype(np.float32)
    u_bias = rng.normal(0.0, 0.25, num_users).astype(np.float32)
    i_bias = rng.normal(0.0, 0.25, num_items).astype(np.float32)

    users = rng.integers(0, num_users, num_ratings).astype(np.int32)
    pop = rng.zipf(1.3, size=4 * num_ratings)
    pop = pop[pop <= num_items][:num_ratings] - 1
    if pop.shape[0] < num_ratings:  # zipf tail too thin; fill uniformly
        fill = rng.integers(0, num_items, num_ratings - pop.shape[0])
        pop = np.concatenate([pop, fill])
    items = pop.astype(np.int32)

    mid = 0.5 * (rating_min + rating_max)
    spread = 0.5 * (rating_max - rating_min)
    raw = (
        mid
        + spread * np.einsum("nk,nk->n", p_true[users], q_true[items])
        + 0.5 * (u_bias[users] + i_bias[items])
        + rng.normal(0.0, noise, num_ratings)
    )
    r = np.clip(raw, rating_min, rating_max).astype(np.float32)
    if integer_ratings:
        r = np.round(r).astype(np.float32)
    return RatingsDataset(
        user=users,
        item=items,
        rating=r,
        num_users=num_users,
        num_items=num_items,
        rating_min=rating_min,
        rating_max=rating_max,
    )


# The paper's Table 1, reproduced as synthetic datasets of identical shape.
_TABLE1 = {
    "movielens100k": dict(num_users=943, num_items=1682, num_ratings=100000,
                          rating_min=1.0, rating_max=5.0, integer_ratings=True),
    "appliances": dict(num_users=30252, num_items=515650, num_ratings=602777,
                       rating_min=1.0, rating_max=5.0, integer_ratings=True),
    "bookcrossings": dict(num_users=105284, num_items=340554, num_ratings=1149779,
                          rating_min=0.0, rating_max=10.0, integer_ratings=True),
    "jester": dict(num_users=73418, num_items=100, num_ratings=4136210,
                   rating_min=-10.0, rating_max=10.0, integer_ratings=False),
}


def paper_dataset(name: str, *, seed: int = 0, scale: float = 1.0) -> RatingsDataset:
    """One of the paper's four datasets (Table 1) at ``scale`` of its size."""
    spec = dict(_TABLE1[name])
    for key in ("num_users", "num_items", "num_ratings"):
        spec[key] = max(int(spec[key] * scale), 8)
    integer = spec.pop("integer_ratings")
    return synthetic_ratings(seed=seed, integer_ratings=integer, **spec)


def train_test_split(
    ds: RatingsDataset, test_fraction: float = 0.2, seed: int = 0
) -> Tuple[RatingsDataset, RatingsDataset]:
    rng = np.random.default_rng(seed)
    perm = rng.permutation(len(ds))
    cut = int(len(ds) * (1.0 - test_fraction))
    tr, te = perm[:cut], perm[cut:]

    def take(idx):
        return RatingsDataset(
            user=ds.user[idx],
            item=ds.item[idx],
            rating=ds.rating[idx],
            num_users=ds.num_users,
            num_items=ds.num_items,
            rating_min=ds.rating_min,
            rating_max=ds.rating_max,
        )

    return take(tr), take(te)


def load_csv(
    path: str,
    *,
    delimiter: str = ",",
    num_users: Optional[int] = None,
    num_items: Optional[int] = None,
) -> RatingsDataset:
    """``user,item,rating`` rows (0-indexed ids)."""
    raw = np.loadtxt(path, delimiter=delimiter, dtype=np.float64)
    user = raw[:, 0].astype(np.int32)
    item = raw[:, 1].astype(np.int32)
    rating = raw[:, 2].astype(np.float32)
    return RatingsDataset(
        user=user,
        item=item,
        rating=rating,
        num_users=num_users or int(user.max()) + 1,
        num_items=num_items or int(item.max()) + 1,
        rating_min=float(rating.min()),
        rating_max=float(rating.max()),
    )


def build_user_history(
    ds: RatingsDataset, max_hist: int = 32
) -> np.ndarray:
    """(num_users, max_hist) padded item ids for SVD++'s implicit term.

    Padding value is ``num_items`` — the inert extra row of the implicit
    factor table.
    """
    hist = np.full((ds.num_users, max_hist), ds.num_items, np.int32)
    counts = np.zeros(ds.num_users, np.int32)
    for u, i in zip(ds.user, ds.item):
        c = counts[u]
        if c < max_hist:
            hist[u, c] = i
            counts[u] = c + 1
    return hist
