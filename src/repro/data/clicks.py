"""Synthetic click/CTR data for the recsys archs (Criteo-shaped for DLRM/FM,
behavior sequences for SASRec/BST).

Labels are generated from a planted logistic model over latent factors so the
models have learnable signal and smoke tests can assert loss decrease.
"""
from __future__ import annotations

from typing import Dict, Sequence, Tuple

import numpy as np


def criteo_batch(
    batch: int,
    *,
    n_dense: int = 13,
    vocab_sizes: Sequence[int] = (),
    seed: int = 0,
) -> Dict[str, np.ndarray]:
    rng = np.random.default_rng(seed)
    dense = rng.normal(0, 1, (batch, n_dense)).astype(np.float32)
    sparse = np.stack(
        [rng.integers(0, v, batch) for v in vocab_sizes], axis=1
    ).astype(np.int32)
    # planted signal: label correlates with a hash-derived score of the ids
    score = dense[:, 0] * 0.5 + np.sum((sparse % 7) - 3, axis=1) * 0.1
    prob = 1.0 / (1.0 + np.exp(-score))
    label = (rng.random(batch) < prob).astype(np.float32)
    return {"dense": dense, "sparse": sparse, "label": label}


def fm_batch(
    batch: int, *, n_fields: int = 39, vocab_per_field: int = 1_000_000, seed: int = 0
) -> Dict[str, np.ndarray]:
    rng = np.random.default_rng(seed)
    ids = rng.integers(0, vocab_per_field, (batch, n_fields)).astype(np.int32)
    score = np.sum((ids % 5) - 2, axis=1) * 0.15
    prob = 1.0 / (1.0 + np.exp(-score))
    label = (rng.random(batch) < prob).astype(np.float32)
    return {"ids": ids, "label": label}


def sasrec_batch(
    batch: int, *, seq_len: int = 50, n_items: int = 1_000_000, seed: int = 0
) -> Dict[str, np.ndarray]:
    rng = np.random.default_rng(seed)
    seq = rng.integers(1, n_items + 1, (batch, seq_len)).astype(np.int32)
    # prefix padding for short histories
    lengths = rng.integers(seq_len // 2, seq_len + 1, batch)
    for row, length in enumerate(lengths):
        seq[row, : seq_len - length] = 0
    pos = np.roll(seq, -1, axis=1)
    pos[:, -1] = rng.integers(1, n_items + 1, batch)
    neg = rng.integers(1, n_items + 1, (batch, seq_len)).astype(np.int32)
    return {"seq": seq, "pos": pos.astype(np.int32), "neg": neg}


def bst_batch(
    batch: int,
    *,
    seq_len: int = 20,
    n_items: int = 1_000_000,
    n_profile: int = 16,
    seed: int = 0,
) -> Dict[str, np.ndarray]:
    rng = np.random.default_rng(seed)
    hist = rng.integers(1, n_items + 1, (batch, seq_len)).astype(np.int32)
    target = rng.integers(1, n_items + 1, batch).astype(np.int32)
    profile = rng.normal(0, 1, (batch, n_profile)).astype(np.float32)
    score = ((target % 11) - 5) * 0.2 + profile[:, 0] * 0.3
    prob = 1.0 / (1.0 + np.exp(-score))
    label = (rng.random(batch) < prob).astype(np.float32)
    return {"hist": hist, "target": target, "profile": profile, "label": label}
