"""Online learning: streaming pruned factor updates + zero-downtime serving.

The third pillar of the system (train, serve, **refresh**): consume fresh
``(user, item, rating)`` events, apply the paper's dynamically-pruned row
updates to only the touched rows, and hot-swap versioned factor snapshots
into a running :class:`~repro.serving.engine.ServingEngine` without dropping
requests.
"""
from repro.online.publisher import (  # noqa: F401
    SnapshotPublisher,
    SwapReport,
    fold_deltas,
)
from repro.online.stream import (  # noqa: F401
    Event,
    EventBatch,
    IteratorSource,
    PoissonSource,
    RatingFreeStreamError,
    ReplaySource,
    iter_microbatches,
)
from repro.online.updater import (  # noqa: F401
    OnlineUpdater,
    PublishSnapshot,
)
