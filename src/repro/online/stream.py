"""Event sources for the online learning subsystem.

Production freshness starts with a stream of ``(user, item, rating)``
interaction events.  Three sources cover the lifecycle:

* :class:`ReplaySource` — replay a :class:`~repro.data.ratings.RatingsDataset`
  (held-out events, a log dump) in deterministic order, optionally for
  multiple passes;
* :class:`PoissonSource` — synthetic traffic: Zipf-popular items, uniform
  users, exponential inter-arrival times under a target event rate, and a
  configurable probability of emitting a *never-seen* user/item id one past
  the current frontier (the cold-start path the updater must handle);
* :class:`IteratorSource` — adapt any iterator of ``(user, item, rating)``
  tuples (a Kafka consumer, a socket reader) into the same interface.

All sources iterate single :class:`Event` records; :func:`iter_microbatches`
accumulates them into fixed-arrays :class:`EventBatch` micro-batches — the
unit the updater consumes.  Everything here is host-side numpy: the stream is
I/O, not math.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, Iterable, Iterator, Optional

import numpy as np


class RatingFreeStreamError(TypeError):
    """A rating-free batch reached a consumer that needs ratings.

    Click/impression streams carry no rating column (``Event.rating is
    None``).  Rating-driven consumers — :class:`~repro.online.updater.
    OnlineUpdater.apply` and :class:`~repro.eval.prequential.
    PrequentialEvaluator` — raise this typed error instead of crashing in a
    numpy cast.  Rating-free streams are served by the ranking-only path
    instead: convert clicks into weighted binary preferences with
    :func:`repro.workloads.implicit.implicit_event_batch`, and evaluate with
    :class:`repro.eval.prequential_ranking.PrequentialRankingEvaluator`.
    """


@dataclasses.dataclass(frozen=True)
class Event:
    """One interaction record on the stream's simulated clock.

    ``rating`` is ``None`` on rating-free streams (clicks, plays,
    impressions) — see :class:`RatingFreeStreamError` for how those are
    consumed.
    """

    user: int
    item: int
    rating: Optional[float]
    timestamp: float = 0.0  # seconds on the source's simulated clock


@dataclasses.dataclass
class EventBatch:
    """A micro-batch of events as contiguous arrays (the updater's unit).

    ``weight`` (optional) is a per-event importance weight in (0, 1] —
    time-decayed recency by default (:func:`iter_microbatches` with
    ``half_life_s``).  It flows through ``batch["weight"]`` in
    ``mf.train_step``: the update (not the prediction) scales by it, so
    stale events move the factors less.
    """

    user: np.ndarray    # (B,) int32
    item: np.ndarray    # (B,) int32
    rating: Optional[np.ndarray]  # (B,) float32; None = rating-free stream
    weight: Optional[np.ndarray] = None  # (B,) float32 update gate

    def __len__(self) -> int:
        return int(self.user.shape[0])

    @classmethod
    def from_events(
        cls,
        events: Iterable[Event],
        *,
        half_life_s: Optional[float] = None,
        now: Optional[float] = None,
    ) -> "EventBatch":
        """``half_life_s`` turns on exponential time decay: an event
        ``half_life_s`` seconds older than ``now`` (default: the newest
        event in the batch) gets weight 0.5, twice that 0.25, ...  The
        newest event always carries weight 1, so a trickle of fresh events
        is never down-weighted as a group.

        Rating-free events (``rating is None``) produce a rating-free batch
        (``batch.rating is None``); mixing rated and rating-free events in
        one batch is a :class:`ValueError` — a stream either carries ratings
        or it does not."""
        ev = list(events)
        rated = [e for e in ev if e.rating is not None]
        if rated and len(rated) != len(ev):
            raise ValueError(
                "cannot mix rated and rating-free events in one batch "
                f"({len(rated)}/{len(ev)} carry ratings)"
            )
        batch = cls(
            user=np.asarray([e.user for e in ev], np.int32),
            item=np.asarray([e.item for e in ev], np.int32),
            rating=(
                np.asarray([e.rating for e in ev], np.float32)
                if rated or not ev
                else None
            ),
        )
        if half_life_s is not None and ev:
            if half_life_s <= 0:
                raise ValueError(
                    f"half_life_s must be positive, got {half_life_s}"
                )
            ts = np.asarray([e.timestamp for e in ev], np.float64)
            ref = float(ts.max()) if now is None else float(now)
            batch.weight = np.exp2(
                -np.maximum(ref - ts, 0.0) / half_life_s
            ).astype(np.float32)
        return batch


class ReplaySource:
    """Replay a ratings dataset as an event stream.

    ``epochs`` passes (``None`` = forever); ``shuffle`` draws a fresh
    deterministic permutation per pass (seeded, like the training loader),
    otherwise events replay in stored order — the natural choice for a
    time-ordered log.
    """

    def __init__(self, ds, *, epochs: Optional[int] = 1,
                 shuffle: bool = False, seed: int = 0):
        self.ds = ds
        self.epochs = epochs
        self.shuffle = shuffle
        self.seed = seed
        self.num_users = ds.num_users
        self.num_items = ds.num_items

    def __iter__(self) -> Iterator[Event]:
        passes = itertools.count() if self.epochs is None else range(self.epochs)
        clock = 0.0
        for epoch in passes:
            if self.shuffle:
                rng = np.random.default_rng(
                    np.random.SeedSequence([self.seed, epoch])
                )
                order = rng.permutation(len(self.ds))
            else:
                order = np.arange(len(self.ds))
            for j in order:
                yield Event(
                    int(self.ds.user[j]), int(self.ds.item[j]),
                    float(self.ds.rating[j]), clock,
                )
                clock += 1.0


class PoissonSource:
    """Synthetic live traffic: a Poisson process over a catalog.

    Users are uniform, items Zipf-popular (the long-tail shape real
    interaction streams have), inter-arrival gaps exponential with mean
    ``1 / rate`` on a simulated clock (no wall-clock sleeping — pacing
    belongs to the caller).  With probability ``new_user_prob`` /
    ``new_item_prob`` an event instead introduces a brand-new id one past
    the largest seen so far, which is what exercises the updater's
    cold-start row initialization.  ``rating_fn(user, item, rng)``
    customizes ratings; the default is uniform on ``[rating_min,
    rating_max]``.  Infinite: bound it with ``iter_microbatches(...,
    max_events=N)`` or ``itertools.islice``.
    """

    def __init__(
        self,
        num_users: int,
        num_items: int,
        *,
        rate: float = 1000.0,
        seed: int = 0,
        zipf_a: float = 1.3,
        rating_min: float = 1.0,
        rating_max: float = 5.0,
        new_user_prob: float = 0.0,
        new_item_prob: float = 0.0,
        rating_fn: Optional[Callable[[int, int, np.random.Generator], float]] = None,
    ):
        if rate <= 0:
            raise ValueError(f"rate must be positive, got {rate}")
        self.num_users = num_users
        self.num_items = num_items
        self.rate = rate
        self.seed = seed
        self.zipf_a = zipf_a
        self.rating_min = rating_min
        self.rating_max = rating_max
        self.new_user_prob = new_user_prob
        self.new_item_prob = new_item_prob
        self.rating_fn = rating_fn

    def __iter__(self) -> Iterator[Event]:
        rng = np.random.default_rng(self.seed)
        next_user = self.num_users
        next_item = self.num_items
        clock = 0.0
        while True:
            clock += float(rng.exponential(1.0 / self.rate))
            if self.new_user_prob and rng.random() < self.new_user_prob:
                user, next_user = next_user, next_user + 1
            else:
                user = int(rng.integers(0, next_user))
            if self.new_item_prob and rng.random() < self.new_item_prob:
                item, next_item = next_item, next_item + 1
            else:
                # Zipf with rejection onto the current catalog: popular head,
                # long tail, like the synthetic training data
                item = int(rng.zipf(self.zipf_a)) - 1
                while item >= next_item:
                    item = int(rng.zipf(self.zipf_a)) - 1
            if self.rating_fn is not None:
                rating = float(self.rating_fn(user, item, rng))
            else:
                rating = float(
                    rng.uniform(self.rating_min, self.rating_max)
                )
            yield Event(user, item, rating, clock)


class IteratorSource:
    """Adapt any iterable of ``(user, item, rating)`` / ``(user, item)``
    tuples (or :class:`Event` records) into an event source; two-element
    tuples yield rating-free click events."""

    def __init__(self, it: Iterable):
        self._it = it

    def __iter__(self) -> Iterator[Event]:
        clock = 0.0
        for row in self._it:
            if isinstance(row, Event):
                yield row
            else:
                user, item = row[0], row[1]
                rating = row[2] if len(row) > 2 else None
                yield Event(
                    int(user), int(item),
                    None if rating is None else float(rating), clock,
                )
            clock += 1.0


def iter_microbatches(
    source: Iterable[Event],
    batch_size: int,
    *,
    max_events: Optional[int] = None,
    max_batch_span_s: Optional[float] = None,
    half_life_s: Optional[float] = None,
) -> Iterator[EventBatch]:
    """Accumulate events into :class:`EventBatch` micro-batches.

    A batch closes when it reaches ``batch_size`` events or (if
    ``max_batch_span_s`` is set) when the next event's *simulated* timestamp
    is more than that many seconds past the batch's first event — the
    freshness bound: a trickle of events still reaches the model.  The final
    partial batch is always flushed.  ``max_events`` bounds the total drawn
    from an infinite source.

    ``half_life_s`` enables recency importance weighting: each batch gets a
    ``weight`` column decaying by 0.5 per half-life of age relative to the
    batch's newest event (see :meth:`EventBatch.from_events`), which the
    updater feeds through ``train_step``'s weight gate — older events move
    the factors proportionally less.
    """
    if batch_size <= 0:
        raise ValueError(f"batch_size must be positive, got {batch_size}")
    if max_events is not None:
        source = itertools.islice(iter(source), max_events)
    pending: list = []
    first_ts = 0.0
    for event in source:
        if (
            pending
            and max_batch_span_s is not None
            and event.timestamp - first_ts > max_batch_span_s
        ):
            yield EventBatch.from_events(pending, half_life_s=half_life_s)
            pending = []
        if not pending:
            first_ts = event.timestamp
        pending.append(event)
        if len(pending) >= batch_size:
            yield EventBatch.from_events(pending, half_life_s=half_life_s)
            pending = []
    if pending:
        yield EventBatch.from_events(pending, half_life_s=half_life_s)
