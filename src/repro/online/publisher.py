"""Versioned factor publication: updater -> serving engine, without downtime.

:class:`SnapshotPublisher` drains the updater's accumulated delta
(:meth:`OnlineUpdater.snapshot`) and pushes it into a running
:class:`~repro.serving.engine.ServingEngine` via :meth:`ServingEngine.swap`
— the double-buffered atomic flip.  In-flight request batches finish on the
version they started on; the hot-user LRU and the catalog tile layouts are
invalidated/patched for the touched rows only (a full rebuild only after
threshold recalibration, a latent rearrange, or catalog growth).

Durability rides along as **delta checkpoints**: instead of serializing the
full factor tables per swap, the publisher writes only the touched rows
(plus thresholds and bookkeeping) through the existing
:class:`~repro.checkpoint.checkpoint.AsyncCheckpointer` — serialization
overlaps the next update batches exactly as training checkpoints overlap
epochs.  ``kind=full`` checkpoints are written whenever a delta cannot
describe the change (recalibration permuted the latent axis).
:func:`fold_deltas` replays a delta chain over a base checkpoint and
returns the reconstructed state — the restart path for an online job.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.checkpoint import checkpoint as ckpt_lib
from repro.core import mf
from repro.online.updater import OnlineUpdater, PublishSnapshot


@dataclasses.dataclass
class SwapReport:
    """What one :meth:`SnapshotPublisher.publish` did (kept on
    ``publisher.reports`` and aggregated by the launchers/benches)."""

    version: int
    swap_s: float               # wall time of the double-buffered swap
    touched_users: int
    touched_items: int
    full_rebuild: bool
    events_seen: int
    checkpoint_step: Optional[int] = None


class SnapshotPublisher:
    """Publish updater snapshots into a live engine, optionally checkpointing.

    ``checkpoint_dir`` enables async delta checkpoints (one per publish,
    step = engine version, ``keep`` retention on top of whatever full
    checkpoints the chain needs).  The publisher never stops the engine:
    :meth:`publish` is safe under concurrent request traffic.
    """

    def __init__(
        self,
        engine,
        updater: OnlineUpdater,
        *,
        checkpoint_dir: Optional[str] = None,
        keep: int = 8,
    ):
        self.engine = engine
        self.updater = updater
        self.keep = keep
        self._ckpt = (
            ckpt_lib.AsyncCheckpointer(checkpoint_dir, keep=keep)
            if checkpoint_dir
            else None
        )
        self._last_step = 0       # previous checkpoint step (0 = the base)
        self._last_full_step = 0  # most recent kind=full anchor
        self._force_full_next = False
        if checkpoint_dir:
            # Resume an existing chain: steps keep counting from the
            # directory's frontier (engine versions restart at 0 per
            # process, so step numbers must NOT come from them — reusing a
            # step would overwrite a live link of the chain), and the first
            # post-restart checkpoint is a full anchor so the fold never
            # depends on the restarted process's in-memory lineage.
            frontier = ckpt_lib.latest_step(checkpoint_dir)
            if frontier is not None:
                self._last_step = frontier
                self._force_full_next = True
        self.reports: list = []

    def publish(self) -> SwapReport:
        """One snapshot -> swap -> (async) checkpoint cycle."""
        snap = self.updater.snapshot()
        start = time.perf_counter()
        version = self.engine.swap(
            snap.params,
            snap.t_p,
            snap.t_q,
            touched_users=None if snap.full_rebuild else snap.touched_users,
            touched_items=None if snap.full_rebuild else snap.touched_items,
            touched_implicit_items=snap.touched_implicit_items,
            user_history=snap.user_history,
        )
        swap_s = time.perf_counter() - start
        step = None
        if self._ckpt is not None:
            step = self._last_step + 1
            # Keep-N retention deletes the oldest steps; a delta whose
            # predecessors were GC'd is unusable.  Writing a full anchor at
            # least every keep-1 publishes guarantees the surviving window
            # always contains one, so fold_deltas always has a valid chain.
            full = (
                snap.full_rebuild
                or self._force_full_next
                or step - self._last_full_step >= max(self.keep - 1, 1)
            )
            self._ckpt.save(
                step,
                _delta_tree(snap, full=full),
                metadata={
                    "kind": "full" if full else "delta",
                    "prev_step": self._last_step,
                    "version": version,
                    "events_seen": snap.events_seen,
                    "num_users": snap.params.p.shape[0],
                    "num_items": snap.params.q.shape[0],
                },
            )
            self._last_step = step
            self._force_full_next = False
            if full:
                self._last_full_step = step
        report = SwapReport(
            version=version,
            swap_s=swap_s,
            touched_users=len(snap.touched_users),
            touched_items=len(snap.touched_items),
            full_rebuild=snap.full_rebuild,
            events_seen=snap.events_seen,
            checkpoint_step=step,
        )
        self.reports.append(report)
        return report

    def close(self) -> None:
        """Join the in-flight checkpoint write (surfaces async errors)."""
        if self._ckpt is not None:
            self._ckpt.wait()


# ---------------------------------------------------------------------------
# Delta checkpoint format
# ---------------------------------------------------------------------------


def _delta_tree(snap: PublishSnapshot, *, full: bool) -> dict:
    """Checkpoint payload for one publish.

    ``kind=delta``: touched row indices + their current values — O(touched)
    bytes.  ``kind=full``: the whole params — required after a
    recalibration/rearrange (a row delta cannot express a latent-axis
    permutation) and written periodically as a retention anchor.
    """
    params = snap.params
    if full:
        tree = {"params": params}
    else:
        u = jnp.asarray(snap.touched_users, jnp.int32)
        i = jnp.asarray(snap.touched_items, jnp.int32)
        tree = {
            "user_idx": u,
            "p_rows": params.p[u],
            "item_idx": i,
            "q_rows": params.q[i],
        }
        if params.user_bias is not None:
            tree["user_bias_rows"] = params.user_bias[u]
            tree["item_bias_rows"] = params.item_bias[i]
            tree["global_mean"] = params.global_mean
        if params.implicit is not None:
            y = jnp.asarray(snap.touched_implicit_items, jnp.int32)
            tree["implicit_idx"] = y
            tree["implicit_rows"] = params.implicit[y]
    tree["t_p"] = snap.t_p
    tree["t_q"] = snap.t_q
    if snap.user_history is not None:
        # histories are small int32 and change with every event batch; the
        # chain replays them wholesale
        tree["user_history"] = jnp.asarray(snap.user_history)
    return tree


def _grow_like(params: mf.MFParams, num_users: int, num_items: int) -> mf.MFParams:
    """Zero-extend a params pytree to (num_users, num_items) before a delta
    scatter — grown rows are always in the delta's touched set, so the zero
    fill is immediately overwritten."""
    m, k = params.p.shape
    n = params.q.shape[0]
    if num_users <= m and num_items <= n:
        return params
    out = params
    if num_items > n:
        out = out._replace(
            q=jnp.pad(out.q, ((0, num_items - n), (0, 0))),
            item_bias=(
                None if out.item_bias is None
                else jnp.pad(out.item_bias, ((0, num_items - n), (0, 0)))
            ),
            implicit=(
                None if out.implicit is None
                else jnp.concatenate([
                    out.implicit[:n],
                    jnp.zeros((num_items - n, k), out.implicit.dtype),
                    out.implicit[n:],
                ])
            ),
        )
    if num_users > m:
        out = out._replace(
            p=jnp.pad(out.p, ((0, num_users - m), (0, 0))),
            user_bias=(
                None if out.user_bias is None
                else jnp.pad(out.user_bias, ((0, num_users - m), (0, 0)))
            ),
        )
    return out


def fold_deltas(
    directory: str,
    params: mf.MFParams,
    t_p,
    t_q,
    *,
    user_history: Optional[np.ndarray] = None,
    from_step: int = 0,
) -> Tuple[mf.MFParams, jnp.ndarray, jnp.ndarray, Optional[np.ndarray], int]:
    """Replay the delta chain under ``directory`` onto a base state.

    Steps are applied ascending, skipping anything at or below ``from_step``.
    Returns ``(params, t_p, t_q, user_history, last_step)`` — the state a
    restarted online job resumes from.  The base state comes from the
    training checkpoint (``serving.load_mf_checkpoint``).

    Keep-N retention may have deleted old deltas; replay therefore anchors
    on the latest surviving ``kind=full`` checkpoint (which subsumes
    everything before it) and verifies chain continuity from there via the
    ``prev_step`` metadata — a delta whose predecessor is missing raises
    instead of silently reconstructing stale factors.
    """
    t_p = jnp.asarray(t_p, jnp.float32)
    t_q = jnp.asarray(t_q, jnp.float32)
    history = None if user_history is None else np.asarray(user_history)
    last = from_step
    steps = [s for s in ckpt_lib.all_steps(directory) if s > from_step]
    metas = {s: ckpt_lib.load_metadata(directory, s) for s in steps}
    fulls = [s for s in steps if metas[s].get("kind", "delta") == "full"]
    if fulls:  # everything before the latest full is subsumed by it
        steps = [s for s in steps if s >= fulls[-1]]
    for step in steps:
        meta = metas[step]
        tree, _ = ckpt_lib.load_raw(directory, step, metadata=meta)
        kind = meta.get("kind", "delta")
        if kind == "delta":
            prev = meta.get("prev_step")
            if prev is not None and int(prev) != last:
                raise ValueError(
                    f"delta chain broken at step {step}: expects predecessor "
                    f"{prev} but replay state is at {last} (retention "
                    "deleted intermediate deltas?)"
                )
        if kind == "full":
            params = mf.params_from_flat(tree)
        else:
            params = _grow_like(
                params, int(meta["num_users"]), int(meta["num_items"])
            )
            u = jnp.asarray(tree["user_idx"], jnp.int32)
            i = jnp.asarray(tree["item_idx"], jnp.int32)
            params = params._replace(
                p=params.p.at[u].set(jnp.asarray(tree["p_rows"])),
                q=params.q.at[i].set(jnp.asarray(tree["q_rows"])),
            )
            if "user_bias_rows" in tree and params.user_bias is not None:
                params = params._replace(
                    user_bias=params.user_bias.at[u].set(
                        jnp.asarray(tree["user_bias_rows"])
                    ),
                    item_bias=params.item_bias.at[i].set(
                        jnp.asarray(tree["item_bias_rows"])
                    ),
                )
            if "implicit_idx" in tree and params.implicit is not None:
                y = jnp.asarray(tree["implicit_idx"], jnp.int32)
                params = params._replace(
                    implicit=params.implicit.at[y].set(
                        jnp.asarray(tree["implicit_rows"])
                    )
                )
        t_p = jnp.asarray(tree["t_p"], jnp.float32)
        t_q = jnp.asarray(tree["t_q"], jnp.float32)
        if "user_history" in tree:
            history = np.asarray(tree["user_history"])
        last = step
    return params, t_p, t_q, history, last


