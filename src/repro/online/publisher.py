"""Versioned factor publication: updater -> serving engine(s), without downtime.

:class:`SnapshotPublisher` drains the updater's accumulated delta
(:meth:`OnlineUpdater.snapshot`) and pushes it into a running
:class:`~repro.serving.engine.ServingEngine` via :meth:`ServingEngine.swap`
— the double-buffered atomic flip.  In-flight request batches finish on the
version they started on; the hot-user LRU and the catalog tile layouts are
invalidated/patched for the touched rows only (a full rebuild only after
threshold recalibration, a latent rearrange, or catalog growth).

The publisher is also the **replication bus** for a serving fleet
(``serving/fleet``): :meth:`subscribe` registers any sink exposing
``apply_update(msg) -> ack`` (a replica, or a router fanning out to many),
and every :meth:`publish` ships one versioned
:class:`~repro.serving.fleet.bus.DeltaMessage` — touched rows only,
losslessly compressed, ``kind=full`` after recalibration — to each
subscriber **in order** (rolling: at most one replica is mid-swap at any
instant, so the fleet never dips below N-1 fully-live replicas).  Acked
versions are tracked per subscriber; a subscriber that falls behind
(missed/failed delivery) is healed by forcing the next publish to
``kind=full``, which its version gate can always apply.

Durability rides along as **delta checkpoints**: instead of serializing the
full factor tables per swap, the publisher writes only the touched rows
(plus thresholds and bookkeeping) through the existing
:class:`~repro.checkpoint.checkpoint.AsyncCheckpointer` — serialization
overlaps the next update batches exactly as training checkpoints overlap
epochs.  ``kind=full`` checkpoints are written whenever a delta cannot
describe the change (recalibration permuted the latent axis).
:func:`fold_deltas` replays a delta chain over a base checkpoint and
returns the reconstructed state — the restart path for an online job and
the catch-up path for a replica joining the fleet late.  Checkpoint steps
and wire versions share one number line: a replica reconstructed by
:func:`fold_deltas` at step ``v`` can join the live bus at version ``v``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.checkpoint import checkpoint as ckpt_lib
from repro.core import mf
from repro.online.updater import OnlineUpdater, PublishSnapshot


@dataclasses.dataclass
class SwapReport:
    """What one :meth:`SnapshotPublisher.publish` did (kept on
    ``publisher.reports`` and aggregated by the launchers/benches)."""

    version: int
    swap_s: float               # wall time of the swap + rolling fan-out
    touched_users: int
    touched_items: int
    full_rebuild: bool
    events_seen: int
    checkpoint_step: Optional[int] = None
    kind: str = "delta"                       # wire/checkpoint payload kind
    acked: Optional[Dict[str, int]] = None    # per-subscriber acked version
    wire_bytes: int = 0                       # compressed message payload
    wire_raw_bytes: int = 0                   # uncompressed equivalent


class SnapshotPublisher:
    """Publish updater snapshots into live engines, optionally checkpointing.

    ``engine`` is the co-located primary (swapped directly, no serialization)
    and may be ``None`` for a fleet-only topology where every engine is a
    subscriber.  ``checkpoint_dir`` enables async delta checkpoints (one per
    publish, step = publish version, ``keep`` retention on top of whatever
    full checkpoints the chain needs).  ``compress`` turns lossless
    byte-shuffle+DEFLATE row compression on for shipped messages (bit-exact;
    see ``distributed/compression.py``).  The publisher never stops an
    engine: :meth:`publish` is safe under concurrent request traffic.
    """

    def __init__(
        self,
        engine,
        updater: OnlineUpdater,
        *,
        checkpoint_dir: Optional[str] = None,
        keep: int = 8,
        compress: bool = True,
    ):
        self.engine = engine
        self.updater = updater
        self.keep = keep
        self.compress = compress
        self._ckpt = (
            ckpt_lib.AsyncCheckpointer(checkpoint_dir, keep=keep)
            if checkpoint_dir
            else None
        )
        self._last_step = 0       # previous checkpoint step (0 = the base)
        self._last_full_step = 0  # most recent kind=full anchor
        self._force_full_next = False
        if checkpoint_dir:
            # Resume an existing chain: steps keep counting from the
            # directory's frontier (engine versions restart at 0 per
            # process, so step numbers must NOT come from them — reusing a
            # step would overwrite a live link of the chain), and the first
            # post-restart checkpoint is a full anchor so the fold never
            # depends on the restarted process's in-memory lineage.
            frontier = ckpt_lib.latest_step(checkpoint_dir)
            if frontier is not None:
                self._last_step = frontier
                self._force_full_next = True
        # Wire versions share the checkpoint step number line (0 when no
        # chain exists yet), so fold_deltas-reconstructed replicas can join
        # the live bus without translation.
        self._version = self._last_step
        # Eviction remap epoch last published: a bump (compaction renumbered
        # the physical user rows) forces the next payload to kind=full so
        # every follower heals through the barrier.
        self._last_remap_epoch = 0
        self.subscribers: List = []
        self.acked: Dict[str, int] = {}
        self.reports: list = []
        # SLO serving-threshold pin (set_serving_thresholds): while set,
        # the primary engine swaps in with THESE thresholds instead of the
        # snapshot's model thresholds, so a publish cannot silently revert
        # the controller's degradation.  Checkpoints and wire messages keep
        # the model values — durability records the model, not the runtime
        # load response (subscriber sinks pin their own override).
        self._serving_thresholds: Optional[Tuple[float, float]] = None

    # -- replication bus ------------------------------------------------------
    @property
    def version(self) -> int:
        """Version of the most recently published snapshot (and the step of
        its checkpoint, when checkpointing is on)."""
        return self._version

    def subscribe(self, sink, *, name: Optional[str] = None):
        """Register a replication sink: anything exposing
        ``apply_update(msg)`` returning either an acked version (int) or a
        ``{replica_id: version}`` dict (a router fanning out to a fleet).
        Sinks are shipped to in subscription order — the rolling order.
        A sink whose current version is behind the bus (a late joiner that
        caught up from checkpoints, or a fresh replica at version 0) is
        healed by the next publish going out ``kind=full``.  Returns the
        sink for chaining."""
        self.subscribers.append(sink)
        sink_name = name or getattr(sink, "replica_id", None)
        if sink_name is not None:
            self.acked[sink_name] = int(getattr(sink, "version", 0))
        return sink

    def set_serving_thresholds(self, t_p, t_q) -> None:
        """Pin the serving thresholds the primary engine swaps in with —
        the :class:`~repro.serving.slo.SLOController` hook.  Overrides the
        snapshot's model thresholds on every subsequent :meth:`publish`
        until :meth:`clear_serving_thresholds`; keeping engine and override
        thresholds equal also preserves the incremental ``same_geometry``
        swap fast path between controller moves."""
        self._serving_thresholds = (float(t_p), float(t_q))

    def clear_serving_thresholds(self) -> None:
        """Unpin: the next publish reverts to the snapshot's thresholds."""
        self._serving_thresholds = None

    def lag(self) -> int:
        """Worst-case subscriber staleness in publish versions (0 = every
        subscriber acked the latest publish)."""
        if not self.acked:
            return 0
        return self._version - min(self.acked.values())

    def _record_ack(self, sink, ack) -> None:
        if isinstance(ack, dict):
            for rid, v in ack.items():
                self.acked[str(rid)] = int(v)
        else:
            name = getattr(sink, "replica_id", None)
            self.acked[str(name) if name is not None else f"sink{id(sink)}"] = int(ack)

    def publish(self) -> SwapReport:
        """One snapshot -> swap -> rolling fan-out -> (async) checkpoint
        cycle."""
        snap = self.updater.snapshot()
        self._version += 1
        version = self._version
        # A full payload is needed whenever a row delta cannot describe the
        # change (recalibration), the chain restarts (first post-resume
        # checkpoint), retention would orphan the delta chain, or a
        # subscriber is behind by more than this one delta (gap: its gate
        # would buffer the delta forever).
        full = (
            snap.full_rebuild
            or self._force_full_next
            or snap.remap_epoch != self._last_remap_epoch
            or (
                self._ckpt is not None
                and version - self._last_full_step >= max(self.keep - 1, 1)
            )
            or any(a < version - 1 for a in self.acked.values())
        )
        self._last_remap_epoch = snap.remap_epoch

        start = time.perf_counter()
        engine_version = None
        pin = self._serving_thresholds
        serve_t_p = snap.t_p if pin is None else jnp.float32(pin[0])
        serve_t_q = snap.t_q if pin is None else jnp.float32(pin[1])
        remap_kwargs = (
            {} if snap.user_remap is None
            else {
                "user_remap": snap.user_remap,
                "remap_epoch": snap.remap_epoch,
            }
        )
        if self.engine is not None:
            engine_version = self.engine.swap(
                snap.params,
                serve_t_p,
                serve_t_q,
                touched_users=None if snap.full_rebuild else snap.touched_users,
                touched_items=None if snap.full_rebuild else snap.touched_items,
                touched_implicit_items=snap.touched_implicit_items,
                user_history=snap.user_history,
                **remap_kwargs,
            )

        msg = None
        acked = None
        if self.subscribers:
            from repro.serving.fleet import bus

            msg = bus.make_message(
                snap, version, version - 1,
                full=full, compress=self.compress,
            )
            # Rolling: ship to one subscriber at a time, in order, waiting
            # for each ack — at most one replica is mid-swap at any instant.
            for sink in self.subscribers:
                self._record_ack(sink, sink.apply_update(msg))
            acked = dict(self.acked)
        swap_s = time.perf_counter() - start

        step = None
        if self._ckpt is not None:
            step = version
            self._ckpt.save(
                step,
                _delta_tree(snap, full=full),
                metadata={
                    "kind": "full" if full else "delta",
                    "prev_step": self._last_step,
                    "version": (
                        engine_version if engine_version is not None else version
                    ),
                    "events_seen": snap.events_seen,
                    "snapshot_id": snap.snapshot_id,
                    "num_users": snap.params.p.shape[0],
                    "num_items": snap.params.q.shape[0],
                    "remap_epoch": snap.remap_epoch,
                },
            )
            self._last_step = step
            if full:
                self._last_full_step = step
        self._force_full_next = False
        report = SwapReport(
            version=engine_version if engine_version is not None else version,
            swap_s=swap_s,
            touched_users=len(snap.touched_users),
            touched_items=len(snap.touched_items),
            full_rebuild=snap.full_rebuild,
            events_seen=snap.events_seen,
            checkpoint_step=step,
            kind="full" if full else "delta",
            acked=acked,
            wire_bytes=0 if msg is None else msg.wire_bytes,
            wire_raw_bytes=0 if msg is None else msg.raw_bytes,
        )
        self.reports.append(report)
        return report

    def close(self) -> None:
        """Join the in-flight checkpoint write (surfaces async errors)."""
        if self._ckpt is not None:
            self._ckpt.wait()


# ---------------------------------------------------------------------------
# Delta checkpoint format (shared with the wire format in serving/fleet/bus)
# ---------------------------------------------------------------------------


def _delta_tree(snap: PublishSnapshot, *, full: bool) -> dict:
    """Checkpoint payload for one publish.

    ``kind=delta``: touched row indices + their current values — O(touched)
    bytes.  ``kind=full``: the whole params — required after a
    recalibration/rearrange (a row delta cannot express a latent-axis
    permutation) and written periodically as a retention anchor.  The same
    tree, flattened, is the fleet wire format (``fleet/bus.make_message``).
    """
    params = snap.params
    if full:
        tree = {"params": params}
    else:
        u = jnp.asarray(snap.touched_users, jnp.int32)
        i = jnp.asarray(snap.touched_items, jnp.int32)
        tree = {
            "user_idx": u,
            "p_rows": params.p[u],
            "item_idx": i,
            "q_rows": params.q[i],
        }
        if params.user_bias is not None:
            tree["user_bias_rows"] = params.user_bias[u]
            tree["item_bias_rows"] = params.item_bias[i]
            tree["global_mean"] = params.global_mean
        if params.implicit is not None:
            y = jnp.asarray(snap.touched_implicit_items, jnp.int32)
            tree["implicit_idx"] = y
            tree["implicit_rows"] = params.implicit[y]
    tree["t_p"] = snap.t_p
    tree["t_q"] = snap.t_q
    if snap.user_history is not None:
        # histories are small int32 and change with every event batch; the
        # chain replays them wholesale
        tree["user_history"] = jnp.asarray(snap.user_history)
    if snap.user_remap is not None:
        # eviction armed: every payload carries the current ext->phys table
        # (cold-start events extend it between compactions, so a delta-only
        # follower still needs the fresh tail) plus the compaction counter.
        # O(n_external) int32 — small next to the row payloads, and the
        # byte-shuffle+DEFLATE wire compression eats the mostly-monotonic
        # table for breakfast.
        tree["user_remap"] = np.asarray(snap.user_remap, np.int32)
        tree["remap_epoch"] = np.int64(snap.remap_epoch)
    return tree


def _grow_like(params: mf.MFParams, num_users: int, num_items: int) -> mf.MFParams:
    """Zero-extend a params pytree to (num_users, num_items) before a delta
    scatter — grown rows are always in the delta's touched set, so the zero
    fill is immediately overwritten."""
    m, k = params.p.shape
    n = params.q.shape[0]
    if num_users <= m and num_items <= n:
        return params
    out = params
    if num_items > n:
        out = out._replace(
            q=jnp.pad(out.q, ((0, num_items - n), (0, 0))),
            item_bias=(
                None if out.item_bias is None
                else jnp.pad(out.item_bias, ((0, num_items - n), (0, 0)))
            ),
            implicit=(
                None if out.implicit is None
                else jnp.concatenate([
                    out.implicit[:n],
                    jnp.zeros((num_items - n, k), out.implicit.dtype),
                    out.implicit[n:],
                ])
            ),
        )
    if num_users > m:
        out = out._replace(
            p=jnp.pad(out.p, ((0, num_users - m), (0, 0))),
            user_bias=(
                None if out.user_bias is None
                else jnp.pad(out.user_bias, ((0, num_users - m), (0, 0)))
            ),
        )
    return out


def apply_delta_tree(
    params: mf.MFParams,
    t_p,
    t_q,
    history: Optional[np.ndarray],
    tree: dict,
    *,
    kind: str,
    num_users: int,
    num_items: int,
    extras: Optional[dict] = None,
) -> Tuple[mf.MFParams, jnp.ndarray, jnp.ndarray, Optional[np.ndarray]]:
    """Fold one delta/full payload tree into ``(params, t_p, t_q, history)``.

    The single applier both readers share: :func:`fold_deltas` feeds it
    checkpoint trees off disk, the fleet's replicas
    (``serving/fleet/bus.apply_message``) feed it decompressed wire
    payloads — so a replica that replays the chain and a replica that
    followed the live bus end bitwise identical.

    ``extras`` (optional out-param dict) receives side-channel state the
    4-tuple cannot carry: the eviction remap (``user_remap``,
    ``remap_epoch``) when the payload has one.
    """
    if kind == "full":
        params = mf.params_from_flat(tree)
    else:
        params = _grow_like(params, num_users, num_items)
        u = jnp.asarray(tree["user_idx"], jnp.int32)
        i = jnp.asarray(tree["item_idx"], jnp.int32)
        params = params._replace(
            p=params.p.at[u].set(jnp.asarray(tree["p_rows"])),
            q=params.q.at[i].set(jnp.asarray(tree["q_rows"])),
        )
        if "user_bias_rows" in tree and params.user_bias is not None:
            params = params._replace(
                user_bias=params.user_bias.at[u].set(
                    jnp.asarray(tree["user_bias_rows"])
                ),
                item_bias=params.item_bias.at[i].set(
                    jnp.asarray(tree["item_bias_rows"])
                ),
            )
        if "implicit_idx" in tree and params.implicit is not None:
            y = jnp.asarray(tree["implicit_idx"], jnp.int32)
            params = params._replace(
                implicit=params.implicit.at[y].set(
                    jnp.asarray(tree["implicit_rows"])
                )
            )
    t_p = jnp.asarray(tree["t_p"], jnp.float32)
    t_q = jnp.asarray(tree["t_q"], jnp.float32)
    if "user_history" in tree:
        history = np.asarray(tree["user_history"])
    if extras is not None and "user_remap" in tree:
        extras["user_remap"] = np.asarray(tree["user_remap"], np.int32)
        extras["remap_epoch"] = int(np.asarray(tree["remap_epoch"]))
    return params, t_p, t_q, history


def fold_deltas(
    directory: str,
    params: mf.MFParams,
    t_p,
    t_q,
    *,
    user_history: Optional[np.ndarray] = None,
    from_step: int = 0,
    extras: Optional[dict] = None,
) -> Tuple[mf.MFParams, jnp.ndarray, jnp.ndarray, Optional[np.ndarray], int]:
    """Replay the delta chain under ``directory`` onto a base state.

    Steps are applied ascending, skipping anything at or below ``from_step``.
    Returns ``(params, t_p, t_q, user_history, last_step)`` — the state a
    restarted online job resumes from, and the state a replica joining the
    fleet late catches up to (its version gate then starts at ``last_step``).
    The base state comes from the training checkpoint
    (``serving.load_mf_checkpoint``).  When ``extras`` is given, remap
    metadata (``user_remap`` / ``remap_epoch``) carried by the replayed
    payloads is written into it, so callers can rebuild the external-id
    view of an evicting updater.

    Keep-N retention may have deleted old deltas; replay therefore anchors
    on the latest surviving ``kind=full`` checkpoint (which subsumes
    everything before it) and verifies chain continuity from there via the
    ``prev_step`` metadata — a delta whose predecessor is missing raises
    instead of silently reconstructing stale factors.
    """
    t_p = jnp.asarray(t_p, jnp.float32)
    t_q = jnp.asarray(t_q, jnp.float32)
    history = None if user_history is None else np.asarray(user_history)
    last = from_step
    steps = [s for s in ckpt_lib.all_steps(directory) if s > from_step]
    metas = {s: ckpt_lib.load_metadata(directory, s) for s in steps}
    fulls = [s for s in steps if metas[s].get("kind", "delta") == "full"]
    if fulls:  # everything before the latest full is subsumed by it
        steps = [s for s in steps if s >= fulls[-1]]
    for step in steps:
        meta = metas[step]
        tree, _ = ckpt_lib.load_raw(directory, step, metadata=meta)
        kind = meta.get("kind", "delta")
        if kind == "delta":
            prev = meta.get("prev_step")
            if prev is not None and int(prev) != last:
                raise ValueError(
                    f"delta chain broken at step {step}: expects predecessor "
                    f"{prev} but replay state is at {last} (retention "
                    "deleted intermediate deltas?)"
                )
        params, t_p, t_q, history = apply_delta_tree(
            params, t_p, t_q, history, tree,
            kind=kind,
            num_users=int(meta.get("num_users", params.p.shape[0])),
            num_items=int(meta.get("num_items", params.q.shape[0])),
            extras=extras,
        )
        last = step
    return params, t_p, t_q, history, last
