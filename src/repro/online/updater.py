"""Incremental pruned factor updates — the paper's Alg. 2/3 applied online.

The trainer (``core/trainer.py``) exercises the dynamically-pruned update
only in offline epochs; here the same masked update (``mf.train_step`` with
the trained thresholds, through any :class:`~repro.optim.optimizers.
RowOptimizer`) is applied to *streaming* event micro-batches.  Each batch
touches only its gathered rows of P/Q (plus biases / implicit rows), and the
early-stopping mask gates the per-row work exactly as in training — the
pruned incremental step does ``work_fraction < 1`` of the dense MACs.

Beyond the step itself the updater owns the three maintenance jobs a
long-running stream needs:

* **cold start** — events naming a user/item id past the current tables grow
  P/Q (and biases, implicit factors, optimizer state, histories) with
  freshly initialized rows, so the catalog follows the stream;
* **threshold drift** — the serving thresholds were calibrated against the
  factor distribution at training time; as online updates move (mu, sigma),
  :meth:`maybe_recalibrate` re-solves Eq. 7/8 and, past ``drift_budget``,
  adopts the new thresholds and re-runs the joint-sparsity rearrangement
  (§4.3) — permuting P, Q, implicit factors AND optimizer accumulators with
  one latent permutation so every inner product is preserved;
* **publish bookkeeping** — touched row sets and a ``layout_dirty`` flag,
  consumed by :class:`~repro.online.publisher.SnapshotPublisher` to drive
  the engine's touched-rows-only hot swap vs. a full layout rebuild.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Set

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mf, rearrange, threshold
from repro.data import loader
from repro.online.stream import EventBatch, RatingFreeStreamError
from repro.optim.optimizers import RowOptimizer


@dataclasses.dataclass
class PublishSnapshot:
    """What one :meth:`OnlineUpdater.snapshot` hands the publisher."""

    params: mf.MFParams
    t_p: jnp.ndarray
    t_q: jnp.ndarray
    touched_users: np.ndarray
    touched_items: np.ndarray
    touched_implicit_items: np.ndarray
    user_history: Optional[np.ndarray]
    full_rebuild: bool          # thresholds/permutation/geometry changed
    events_seen: int            # cumulative over the updater's lifetime
    snapshot_id: int = 0        # monotonic per updater; publisher/bus audit
    user_remap: Optional[np.ndarray] = None  # ext->phys (store/eviction.py)
    remap_epoch: int = 0        # compaction counter; bump => full heal


class OnlineUpdater:
    """Apply streaming event micro-batches as pruned row updates.

    ``batch_size`` caps a compiled step: event batches split into
    power-of-two chunks (so the jit cache stays bounded, as in serving —
    see :meth:`_chunk_sizes`).  ``pruning_rate``
    (needed only for drift recalibration) defaults to the rate implied by
    nothing — pass the training rate to enable :meth:`maybe_recalibrate`.
    """

    def __init__(
        self,
        params: mf.MFParams,
        opt_state: Optional[mf.MFOptState] = None,
        t_p=0.0,
        t_q=0.0,
        *,
        optimizer: str | RowOptimizer = "adagrad",
        lr: float = 0.05,
        lam: float = 0.02,
        pruning_rate: float = 0.0,
        drift_budget: float = 0.25,
        user_history: Optional[np.ndarray] = None,
        batch_size: int = 256,
        init_scale: float = 0.1,
        seed: int = 0,
        mesh=None,
        grad_compression: str = "none",
    ):
        self.opt = (
            optimizer if isinstance(optimizer, RowOptimizer)
            else RowOptimizer(name=optimizer)
        )
        self.params = params
        self.opt_state = (
            opt_state if opt_state is not None
            else mf.init_opt_state(params, self.opt)
        )
        self.mesh = mesh
        self._user_multiple = self._item_multiple = 1
        if mesh is not None:
            # Distributed refresh: event batches route through the owner-
            # compute train_step_shard_map (ROADMAP "distributed online
            # updates").  Only the FunkSVD variants the sharded step
            # implements are eligible.
            if self.opt.name not in ("sgd", "adagrad"):
                raise ValueError(
                    "mesh-backed online updates support sgd/adagrad only "
                    f"(got {self.opt.name!r})"
                )
            if params.user_bias is not None or params.implicit is not None:
                raise ValueError(
                    "mesh-backed online updates support the FunkSVD variant "
                    "only (no biases / implicit factors)"
                )
            self._n_dp = 1
            for axis in ("pod", "data"):
                if axis in mesh.axis_names:
                    self._n_dp *= mesh.shape[axis]
            self._user_multiple = self._n_dp
            self._item_multiple = mesh.shape["model"]
            if (
                params.p.shape[0] % self._user_multiple
                or params.q.shape[0] % self._item_multiple
            ):
                raise ValueError(
                    "factor tables must divide over the mesh: "
                    f"P rows {params.p.shape[0]} over {self._user_multiple}, "
                    f"Q rows {params.q.shape[0]} over {self._item_multiple}"
                )
            if grad_compression == "int8_ef":
                # per-sender quantization residuals ride in the opt_state
                # (row-indexed, so capacity growth keeps them aligned)
                self.opt_state = mf.init_error_feedback_state(
                    params, self.opt_state, mesh
                )
            self._sharded_step = jax.jit(
                functools.partial(
                    mf.train_step_shard_map,
                    lr=float(lr), lam=float(lam), opt_name=self.opt.name,
                    grad_compression=grad_compression, mesh=mesh,
                )
            )
        self.t_p = jnp.asarray(t_p, jnp.float32)
        self.t_q = jnp.asarray(t_q, jnp.float32)
        self.lr = jnp.float32(lr)
        self.lam = float(lam)
        self.pruning_rate = float(pruning_rate)
        self.drift_budget = float(drift_budget)
        self.batch_size = int(batch_size)
        self.init_scale = float(init_scale)
        self._rng = np.random.default_rng(seed)
        if params.implicit is not None and user_history is None:
            raise ValueError(
                "SVD++ params need user_history (data.build_user_history) so "
                "online events can extend the implicit-feedback sets"
            )
        self.user_history = (
            None if user_history is None
            else np.array(user_history, np.int32, copy=True)
        )
        self._dim_mask = jnp.ones((params.p.shape[1],), jnp.float32)
        self.evictor = None  # store.eviction.UserEvictor via attach_evictor

        # publish bookkeeping
        self._touched_users: Set[int] = set()
        self._touched_items: Set[int] = set()
        self._touched_implicit: Set[int] = set()
        self._layout_dirty = False
        self.events_seen = 0
        self.snapshots_taken = 0
        self.batches_applied = 0
        self._work_sum = 0.0
        self._abs_err_sum = 0.0

    # -- construction --------------------------------------------------------
    @classmethod
    def from_trainer(cls, trainer, **kwargs) -> "OnlineUpdater":
        """Continue a :class:`~repro.core.trainer.DPMFTrainer` run online:
        same params, optimizer state, thresholds, and history."""
        cfg = trainer.config
        kwargs.setdefault("optimizer", trainer.opt)
        kwargs.setdefault("lr", cfg.lr)
        kwargs.setdefault("lam", cfg.lam)
        kwargs.setdefault("pruning_rate", cfg.pruning_rate)
        kwargs.setdefault("user_history", trainer.hist)
        kwargs.setdefault("batch_size", min(cfg.batch_size, 4096))
        kwargs.setdefault("grad_compression", cfg.grad_compression)
        return cls(
            trainer.params, trainer.opt_state, trainer.t_p, trainer.t_q,
            **kwargs,
        )

    def attach_evictor(self, evictor) -> None:
        """Arm cold-row eviction (``store/eviction.UserEvictor``): event
        user ids become *external* ids, translated to physical rows on
        every apply; ``evictor.maybe_evict()`` may spill + compact the user
        tables at publish points."""
        evictor.bind(self)
        self.evictor = evictor

    def resolve_users(self, users: np.ndarray) -> np.ndarray:
        """External user ids → physical rows for an *update* (grows /
        revives as needed).  Identity + cold-start growth when no evictor
        is attached — the prequential evaluator and other scorers call this
        instead of ``ensure_capacity`` so they stay remap-correct."""
        users = np.asarray(users, np.int32)
        if users.size == 0:
            return users
        if self.evictor is None:
            self.ensure_capacity(int(users.max()), -1)
            return users
        return self.evictor.resolve(users).astype(np.int32)

    # -- properties ----------------------------------------------------------
    @property
    def num_users(self) -> int:
        """Current user-table rows (grows with cold-start events)."""
        return self.params.p.shape[0]

    @property
    def num_items(self) -> int:
        """Current catalog size (grows with cold-start events)."""
        return self.params.q.shape[0]

    @property
    def mean_work_fraction(self) -> float:
        """Mean executed share of dense MACs over the updater's lifetime —
        the online analogue of the trainer's per-epoch work_fraction."""
        return self._work_sum / max(self.batches_applied, 1)

    @property
    def mean_abs_err(self) -> float:
        """Mean per-batch training |error| over the updater's lifetime — the
        streaming analogue of the trainer's per-epoch train_abs_err."""
        return self._abs_err_sum / max(self.batches_applied, 1)

    # -- cold start ----------------------------------------------------------
    def _fresh_rows(self, rows: int, k: int, dtype) -> jnp.ndarray:
        return jnp.asarray(
            self.init_scale * self._rng.standard_normal((rows, k)),
            dtype,
        )

    def _grow_state(self, state: Dict, rows: int, axis0: int) -> Dict:
        def grow(v):
            if getattr(v, "ndim", 0) >= 1 and v.shape[0] == axis0:
                pad = [(0, rows)] + [(0, 0)] * (v.ndim - 1)
                return jnp.pad(v, pad)
            return v

        return {key: grow(value) for key, value in state.items()}

    def ensure_capacity(self, max_user: int, max_item: int) -> bool:
        """Grow the factor tables so ``max_user``/``max_item`` are valid ids.

        New rows get the training init (``init_scale * N(0, 1)``) so pruning
        thresholds remain meaningful; optimizer accumulators start at zero;
        new SVD++ history rows start empty (all padding).  Returns True if
        anything grew.  Growth only ever appends — live request ids stay
        valid (the engine's swap enforces the same).
        """
        params, grew = self.params, False
        m, k = params.p.shape
        n = params.q.shape[0]

        # In mesh mode, growth rounds up to the mesh multiples so the grown
        # tables keep dividing over the data/model axes.
        def round_up(v: int, mult: int) -> int:
            return -(-v // mult) * mult

        add_n = max(0, round_up(max_item + 1, self._item_multiple) - n)
        add_m = max(0, round_up(max_user + 1, self._user_multiple) - m)
        if self.mesh is not None and (add_n or add_m):
            # Gather the sharded tables to replicated host arrays before
            # growing: jnp.concatenate of a mesh-sharded table with fresh
            # rows re-shards the longer result and (jax 0.4.x) scrambles the
            # existing rows.  The next sharded step re-shards its inputs
            # anyway, exactly like the first step after construction.
            def unshard(tree):
                return jax.tree_util.tree_map(
                    lambda x: jnp.asarray(np.asarray(x)), tree
                )

            params = unshard(params)
            self.opt_state = unshard(self.opt_state)
        if add_n:
            grew = True
            new_n = n + add_n
            params = params._replace(
                q=jnp.concatenate([params.q, self._fresh_rows(add_n, k, params.q.dtype)]),
                item_bias=(
                    None if params.item_bias is None
                    else jnp.pad(params.item_bias, ((0, add_n), (0, 0)))
                ),
            )
            if params.implicit is not None:
                # (n + 1, k) with the inert padding row LAST: old rows, fresh
                # rows, then a new zero padding row at index new_n
                params = params._replace(
                    implicit=jnp.concatenate([
                        params.implicit[:n],
                        self._fresh_rows(add_n, k, params.implicit.dtype),
                        jnp.zeros((1, k), params.implicit.dtype),
                    ])
                )
                if self.user_history is not None:
                    # remap the old padding sentinel to the new one
                    self.user_history[self.user_history == n] = new_n
            self.opt_state = self.opt_state._replace(
                q=self._grow_state(self.opt_state.q, add_n, n),
                item_bias=(
                    None if self.opt_state.item_bias is None
                    else self._grow_state(self.opt_state.item_bias, add_n, n)
                ),
                implicit=(
                    None if self.opt_state.implicit is None
                    else {
                        key: jnp.concatenate(
                            [v[:n], jnp.zeros((add_n,) + v.shape[1:], v.dtype), v[n:]]
                        )
                        if getattr(v, "ndim", 0) >= 1 and v.shape[0] == n + 1
                        else v
                        for key, v in self.opt_state.implicit.items()
                    }
                ),
            )
            self._touched_items.update(range(n, new_n))
            self._touched_implicit.update(range(n, new_n))
            n = new_n

        if add_m:
            grew = True
            params = params._replace(
                p=jnp.concatenate([params.p, self._fresh_rows(add_m, k, params.p.dtype)]),
                user_bias=(
                    None if params.user_bias is None
                    else jnp.pad(params.user_bias, ((0, add_m), (0, 0)))
                ),
            )
            self.opt_state = self.opt_state._replace(
                p=self._grow_state(self.opt_state.p, add_m, m),
                user_bias=(
                    None if self.opt_state.user_bias is None
                    else self._grow_state(self.opt_state.user_bias, add_m, m)
                ),
            )
            if self.user_history is not None:
                self.user_history = np.concatenate([
                    self.user_history,
                    np.full((add_m, self.user_history.shape[1]), n, np.int32),
                ])
            self._touched_users.update(range(m, m + add_m))

        if grew:
            # Growth does NOT mark the layout dirty: the engine's swap
            # detects a changed catalog geometry on its own (and rebuilds),
            # user-only growth patches incrementally, and grown rows are all
            # in the touched sets so a row delta still describes the change.
            self.params = params
        return grew

    # -- the incremental step ------------------------------------------------
    def _append_history(self, users: np.ndarray, items: np.ndarray) -> None:
        """Record new interactions in the SVD++ implicit sets: first free
        slot, or FIFO eviction of the oldest entry when the bounded history
        is full (slots fill left to right, so slot 0 is oldest) — fresh
        interactions always make it into the implicit set."""
        hist = self.user_history
        pad = self.num_items
        for u, i in zip(users, items):
            row = hist[u]
            if i in row:
                continue
            free = np.nonzero(row == pad)[0]
            if free.size:
                row[free[0]] = i
            else:
                row[:-1] = row[1:]
                row[-1] = i

    @staticmethod
    def _chunk_sizes(total: int, cap: int):
        """Binary decomposition of ``total`` into power-of-two chunk sizes
        (capped at ``cap``): jit sees only O(log cap) distinct batch shapes,
        and — unlike zero-weight padding — no row is ever duplicated, so the
        stateful optimizers (momentum/adadelta/adam), whose duplicate-index
        scatter write-back is nondeterministic and whose state decays even
        for zero-weight rows, stay exact too."""
        sizes = []
        while total >= cap:
            sizes.append(cap)
            total -= cap
        bit = 1
        while total:
            if total & bit:
                sizes.append(bit)
                total &= ~bit
            bit <<= 1
        sizes.sort(reverse=True)
        return sizes

    def apply(self, batch: EventBatch) -> Dict[str, float]:
        """Apply one event micro-batch; returns step metrics.

        The batch is split into power-of-two chunks (largest first, capped
        at ``batch_size``) so the compiled-step cache stays bounded without
        any padding rows.  ``work_fraction`` is the executed share of dense
        MACs over the real events — the online analogue of the trainer's
        per-epoch number.
        """
        if len(batch) == 0:
            return {"abs_err": 0.0, "work_fraction": 1.0, "events": 0}
        if batch.rating is None:
            raise RatingFreeStreamError(
                "OnlineUpdater.apply trains on the rating column and this "
                "batch is rating-free.  Convert clicks into weighted binary "
                "preferences first — repro.workloads.implicit."
                "implicit_event_batch(batch, num_items=...) — then apply "
                "the converted batch."
            )
        users = np.asarray(batch.user, np.int32)
        items = np.asarray(batch.item, np.int32)
        ratings = np.asarray(batch.rating, np.float32)
        weights = (
            None if getattr(batch, "weight", None) is None
            else np.asarray(batch.weight, np.float32)
        )
        if self.evictor is not None:
            # external ids -> physical rows (reviving spilled users); from
            # here on every array/bookkeeping index is physical
            users = self.evictor.resolve(users)
        self.ensure_capacity(int(users.max()), int(items.max()))
        if self.user_history is not None:
            self._append_history(users, items)

        total = len(users)
        if self.mesh is not None:
            # Distributed refresh: one owner-compute sharded step per event
            # batch.  The router buckets rows by user owner and pads with
            # weight-0 rows (pow2 lengths keep the jit cache bounded).
            from repro.distributed.sharding import route_batch_to_owner_shards

            routed = route_batch_to_owner_shards(
                users, items, ratings,
                num_users=self.num_users, n_dp=self._n_dp,
                weight=weights, pad_to_pow2=True,
            )
            step_batch = {key: jnp.asarray(v) for key, v in routed.items()}
            self.params, self.opt_state, metrics = self._sharded_step(
                self.params, self.opt_state, step_batch, self.t_p, self.t_q
            )
            abs_err = float(metrics["abs_err"]) * total
            work = float(metrics["work_fraction"]) * total
        else:
            abs_err = work = 0.0
            lo = 0
            for size in self._chunk_sizes(total, self.batch_size):
                u = users[lo : lo + size]
                i = items[lo : lo + size]
                r = ratings[lo : lo + size]
                step_batch = {
                    "user": jnp.asarray(u),
                    "item": jnp.asarray(i),
                    "rating": jnp.asarray(r),
                }
                if weights is not None:
                    step_batch["weight"] = jnp.asarray(weights[lo : lo + size])
                lo += size
                if self.user_history is not None:
                    step_batch["hist"] = jnp.asarray(self.user_history[u])
                self.params, self.opt_state, metrics = mf.train_step(
                    self.params, self.opt_state, step_batch,
                    self.t_p, self.t_q, self.lr, self._dim_mask,
                    opt=self.opt, lam=self.lam,
                )
                abs_err += float(metrics["abs_err"]) * size
                work += float(metrics["work_fraction"]) * size

        self._touched_users.update(int(x) for x in users)
        self._touched_items.update(int(x) for x in items)
        if self.params.implicit is not None:
            # train_step updates the implicit rows of every history item of
            # the batch users — all of them are now stale for serving caches
            hist_rows = self.user_history[users]
            live = hist_rows[hist_rows < self.num_items]
            self._touched_implicit.update(int(x) for x in live)
        self.events_seen += total
        self.batches_applied += 1
        self._work_sum += work / total
        self._abs_err_sum += abs_err / total
        return {
            "abs_err": abs_err / total,
            "work_fraction": work / total,
            "events": total,
        }

    # -- threshold drift maintenance -----------------------------------------
    def _candidate_thresholds(self):
        """(cand_p, cand_q, drift): thresholds the CURRENT factor
        distribution implies, plus their relative distance from the live
        ones.  One (mu, sigma) solve — drift() and maybe_recalibrate()
        share it rather than re-deriving."""
        cand_p, cand_q = threshold.thresholds_from_matrices(
            self.params.p, self.params.q, self.pruning_rate
        )
        ref_p = max(float(self.t_p), 1e-8)
        ref_q = max(float(self.t_q), 1e-8)
        drift = max(
            abs(float(cand_p) - float(self.t_p)) / ref_p,
            abs(float(cand_q) - float(self.t_q)) / ref_q,
        )
        return cand_p, cand_q, drift

    def drift(self) -> float:
        """Relative distance between the live thresholds and the ones the
        current factor distribution implies (0 when pruning is off)."""
        if self.pruning_rate <= 0.0:
            return 0.0
        return self._candidate_thresholds()[2]

    def maybe_recalibrate(self, *, force: bool = False) -> Optional[Dict]:
        """Re-measure (mu, sigma), and when drift exceeds ``drift_budget``
        adopt fresh thresholds and re-run the §4.3 rearrangement.

        The latent permutation is applied to P, Q, the implicit factors AND
        every 2-D optimizer accumulator — one permutation, every inner
        product preserved (the same discipline as ``DPMFTrainer.calibrate``).
        Marks the snapshot ``layout_dirty``: the engine must rebuild its
        catalog layouts, since both the masks (new t_q) and the latent order
        changed.  Returns a report dict, or None if within budget.
        """
        if self.pruning_rate <= 0.0:
            return None
        cand_p, cand_q, drift = self._candidate_thresholds()
        if not force and drift <= self.drift_budget:
            return None
        old_t_p, old_t_q = float(self.t_p), float(self.t_q)
        self.t_p, self.t_q = cand_p, cand_q
        result = rearrange.rearrangement(
            self.params.p, self.params.q, self.t_p, self.t_q
        )
        perm = result.perm
        new_p, new_q = rearrange.apply_perm(
            self.params.p, self.params.q, perm
        )
        self.params = self.params._replace(p=new_p, q=new_q)
        if self.params.implicit is not None:
            self.params = self.params._replace(
                implicit=jnp.take(self.params.implicit, perm, axis=1)
            )
        k = self.params.p.shape[1]

        def permute_state(state):
            if state is None:
                return None
            return {
                key: (
                    jnp.take(value, perm, axis=1)
                    if getattr(value, "ndim", 0) == 2 and value.shape[1] == k
                    else value
                )
                for key, value in state.items()
            }

        self.opt_state = self.opt_state._replace(
            p=permute_state(self.opt_state.p),
            q=permute_state(self.opt_state.q),
            implicit=permute_state(self.opt_state.implicit),
        )
        self._layout_dirty = True
        return {
            "drift": drift,
            "t_p": (old_t_p, float(self.t_p)),
            "t_q": (old_t_q, float(self.t_q)),
            "perm": np.asarray(perm),
        }

    # -- publishing ----------------------------------------------------------
    def snapshot(self) -> PublishSnapshot:
        """Freeze the accumulated delta for publication and reset the
        touched-row bookkeeping.  The history matrix is copied so the
        updater can keep appending while the engine serves the snapshot."""
        self.snapshots_taken += 1
        snap = PublishSnapshot(
            params=self.params,
            t_p=self.t_p,
            t_q=self.t_q,
            touched_users=np.fromiter(
                sorted(self._touched_users), np.int64,
                len(self._touched_users),
            ),
            touched_items=np.fromiter(
                sorted(self._touched_items), np.int64,
                len(self._touched_items),
            ),
            touched_implicit_items=np.fromiter(
                sorted(self._touched_implicit), np.int64,
                len(self._touched_implicit),
            ),
            user_history=(
                None if self.user_history is None
                else self.user_history.copy()
            ),
            full_rebuild=self._layout_dirty,
            events_seen=self.events_seen,
            snapshot_id=self.snapshots_taken,
            user_remap=(
                None if self.evictor is None
                else self.evictor.remap.as_array()
            ),
            remap_epoch=(
                0 if self.evictor is None else self.evictor.remap.epoch
            ),
        )
        self._touched_users.clear()
        self._touched_items.clear()
        self._touched_implicit.clear()
        self._layout_dirty = False
        return snap

    # -- evaluation ----------------------------------------------------------
    def evaluate(self, ds, batch_size: int = 8192) -> float:
        """Test MAE (Eq. 12) of the current online params + thresholds.

        With an evictor attached the dataset's user ids are *external*:
        live users score through their physical rows, spilled/unseen users
        score bias-only (global mean + item bias when the bias variant is
        trained, else 0) — the same fallback contract the serving engine
        applies.  Evaluation never revives rows.
        """
        if self.evictor is not None:
            return self._evaluate_remapped(ds, batch_size)
        total, count = 0.0, 0.0
        for batch_np in loader.iterate_batches(
            ds, min(batch_size, max(len(ds), 1)), shuffle=False,
            drop_remainder=False, hist=self.user_history,
        ):
            batch = {key: jnp.asarray(val) for key, val in batch_np.items()}
            s, c = mf.eval_mae(self.params, batch, self.t_p, self.t_q)
            total += float(s)
            count += float(c)
        return total / max(count, 1.0)

    def _evaluate_remapped(self, ds, batch_size: int) -> float:
        remap = self.evictor.remap
        total, count = 0.0, 0.0
        for batch_np in loader.iterate_batches(
            ds, min(batch_size, max(len(ds), 1)), shuffle=False,
            drop_remainder=False,
        ):
            users = np.asarray(batch_np["user"], np.int64)
            items = np.asarray(batch_np["item"], np.int64)
            phys = remap.lookup(users)
            live = phys >= 0
            safe = np.where(live, phys, 0).astype(np.int32)
            pred, _ = mf.predict_pairs(
                self.params, jnp.asarray(safe),
                jnp.asarray(items.astype(np.int32)), self.t_p, self.t_q,
            )
            pred = np.asarray(pred, np.float64)
            fallback = np.zeros(users.shape, np.float64)
            if self.params.global_mean is not None:
                fallback += float(self.params.global_mean)
            if self.params.item_bias is not None:
                fallback += np.asarray(
                    self.params.item_bias, np.float64
                ).reshape(-1)[items]
            pred = np.where(live, pred, fallback)
            w = batch_np.get("weight")
            w = (
                np.ones(users.shape, np.float64) if w is None
                else np.asarray(w, np.float64)
            )
            rating = np.asarray(batch_np["rating"], np.float64)
            total += float((np.abs(rating - pred) * w).sum())
            count += float(w.sum())
        return total / max(count, 1.0)
