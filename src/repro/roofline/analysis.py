"""Roofline-term derivation from compiled dry-run artifacts.

Three terms per (arch, mesh), all in seconds (EXPERIMENTS.md §Roofline):

    compute    = HLO_FLOPs      / (chips * peak bf16 FLOP/s)
    memory     = HLO_bytes      / (chips * HBM bandwidth)
    collective = coll_bytes     / (chips * ICI link bandwidth)

HLO_FLOPs / bytes come from ``compiled.cost_analysis()``.  Collective bytes
are NOT in cost_analysis: they are parsed from the partitioned HLO text by
summing the shaped-buffer sizes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute result (result bytes ==
bytes crossing links per participating device for AG/AR; a documented
approximation for the rest).
"""
from __future__ import annotations

import re
from collections import Counter
from typing import Dict, Optional

from repro.roofline import hw

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVE_KINDS = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# e.g. "f32[256,1024]{1,0}" — dtype + dims (layout suffix optional)
_SHAPE_RE = re.compile(r"\b([a-z]+\d*)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s*((?:\([^)]*\)|[a-z0-9\[\],{}\. ]+?)?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\("
)


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum result-buffer bytes per collective kind over the HLO module.

    ``-done`` ops are skipped (their ``-start`` twin already counted).  Bytes
    are per participating device (HLO is SPMD: one program, every device runs
    it), which is the right numerator for a per-chip link-bandwidth roofline.
    """
    per_kind: Counter = Counter()
    counts: Counter = Counter()
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if m is None:
            continue
        if f"{m.group(2)}-done(" in line:
            continue
        kind = m.group(2)
        result_part = line.split("=", 1)[0] if "=" not in line else line
        # result shape sits between '=' and the op name
        eq = line.find("=")
        op_pos = line.find(kind, eq)
        result_part = line[eq + 1 : op_pos]
        size = _shape_bytes(result_part)
        per_kind[kind] += size
        counts[kind] += 1
    out = {f"{kind}_bytes": float(per_kind.get(kind, 0)) for kind in _COLLECTIVE_KINDS}
    out.update(
        {f"{kind}_count": int(counts.get(kind, 0)) for kind in _COLLECTIVE_KINDS}
    )
    out["total_bytes"] = float(sum(per_kind.values()))
    return out


def op_histogram(hlo_text: str) -> Dict[str, int]:
    """Histogram of interesting op kinds (fusion/reshape/gather/etc.) — the
    'profile' available without hardware; used by the §Perf iterations."""
    kinds = (
        "fusion", "convolution", "dot", "gather", "scatter", "reshape",
        "transpose", "sort", "while", "custom-call",
    ) + _COLLECTIVE_KINDS
    hist: Counter = Counter()
    op_re = re.compile(r"=\s*(?:[a-z0-9\[\],{}\(\) ]+?)\s*([a-z][a-z0-9-]*)\(")
    for line in hlo_text.splitlines():
        m = op_re.search(line)
        if m and m.group(1) in kinds:
            hist[m.group(1)] += 1
    return dict(hist)


def roofline_terms(
    flops: float,
    bytes_accessed: float,
    coll_bytes: float,
    chips: int,
    *,
    model_flops: Optional[float] = None,
) -> Dict[str, float]:
    compute_s = flops / (chips * hw.PEAK_BF16_FLOPS)
    memory_s = bytes_accessed / (chips * hw.HBM_BANDWIDTH)
    collective_s = coll_bytes / (chips * hw.ICI_LINK_BANDWIDTH)
    dominant = max(
        ("compute", compute_s), ("memory", memory_s), ("collective", collective_s),
        key=lambda kv: kv[1],
    )[0]
    out = {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "dominant": dominant,
        "bound_s": max(compute_s, memory_s, collective_s),
    }
    if model_flops:
        out["model_flops"] = model_flops
        out["useful_flop_fraction"] = model_flops / max(flops, 1.0)
        # roofline fraction: time the useful math would take at peak, over the
        # time the dominant term actually costs.
        out["roofline_fraction"] = (
            model_flops / (chips * hw.PEAK_BF16_FLOPS)
        ) / max(out["bound_s"], 1e-30)
    return out


def extrapolate_depth(
    calib1: Dict, calib2: Dict, scan_layers: int
) -> Dict[str, float]:
    """Exact per-step cost from two unrolled depth variants.

    XLA costs while-loop bodies once per program, so a scanned L-layer stack
    under-reports.  With homogeneous layers, cost(depth d, unrolled)
    = entry + d * body, hence from depth-1 and depth-2 compiles:

        body  = c2 - c1
        entry = 2*c1 - c2
        total(L) = entry + L * body

    Applied to flops, bytes_accessed, and every collective-byte counter.
    """

    def get(rec, *keys):
        node = rec
        for key in keys:
            node = node.get(key, 0.0) if isinstance(node, dict) else 0.0
        return float(node or 0.0)

    out: Dict[str, float] = {}
    for field, keys in (
        ("flops", ("cost", "flops")),
        ("bytes_accessed", ("cost", "bytes_accessed")),
        ("collective_bytes", ("collectives", "total_bytes")),
    ):
        c1 = get(calib1, *keys)
        c2 = get(calib2, *keys)
        body = c2 - c1
        entry = 2 * c1 - c2
        out[field] = max(entry + scan_layers * body, 0.0)
    return out


def lm_model_flops(param_count: int, active_param_count: int, tokens: int,
                   kind: str) -> float:
    """MODEL_FLOPS: 6*N*D for training, 2*N*D for forward-only (N = active
    params for MoE)."""
    mult = 6.0 if kind == "train" else 2.0
    return mult * active_param_count * tokens
