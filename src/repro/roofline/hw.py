"""Hardware model: TPU v5e, per chip (the target platform)."""

PEAK_BF16_FLOPS = 197e12      # FLOP/s
HBM_BANDWIDTH = 819e9         # bytes/s
ICI_LINK_BANDWIDTH = 50e9     # bytes/s per link

CHIPS_SINGLE_POD = 256        # 16 x 16
CHIPS_MULTI_POD = 512         # 2 x 16 x 16
