"""Fused pruned-score + tiled top-k — the serving-time form of Alg. 2.

Computes, for every user row, the top-k items of
``score[u, i] = sum_{t < min(r_u[u], r_i[i])} p[u, t] * q[i, t] + bias[i]``
WITHOUT ever materializing the (M, N) score matrix in HBM.  At catalog scale
the dense serve path is memory-bound on exactly that matrix (score + argsort
over N items per user); here each (M-tile, N-tile) block of scores lives only
in a VMEM accumulator and is folded into a running per-user top-k before the
next item tile is scored.

Structure (reuses the ragged-K tile skipping of ``pruned_matmul.py``):

* grid (M-tiles, N-tiles, K-blocks); the N/K axes are sequential
  ("arbitrary") because the running top-k scratch carries state across item
  tiles, M-tiles are parallel;
* whole K-blocks past the tile bound ``min(max(r_u), max(r_i))`` are skipped
  with ``pl.when`` — the paper's "unnecessary computation" not executed;
* partially-covered K-blocks are element-masked with ``broadcasted_iota`` so
  scores are exactly the oracle's;
* on the last K-block the (bm, bn) score tile is merged into the running
  (bm, topk) scores/indices scratch by iterative max-extraction (k vector
  passes — no sort network needed on the VPU; ties resolve to the lower item
  index, matching a stable dense argsort);
* the merged result is written to the output only on the final item tile.

Peak HBM for serving B users is therefore O(B * topk) instead of O(B * N).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.pruned_matmul import _VMEM, pltpu

_NEG_INF = float("-inf")

# Default block geometry.  Every producer of padded operands (kernels.ops
# wrappers, the serving engine's precomputed catalog layouts) imports these,
# so retuning the kernel retunes the whole layout contract at once.
TOPK_BLOCK_M = 128
TOPK_BLOCK_N = 256
TOPK_BLOCK_K = 128


def _compiler_params():
    """Unlike pruned_matmul, the N (item-tile) axis is sequential: it carries
    the running top-k scratch.  Only the user-tile axis is parallel."""
    if pltpu is None:
        return None
    semantics = ("parallel", "arbitrary", "arbitrary")
    try:
        return pltpu.CompilerParams(dimension_semantics=semantics)
    except (AttributeError, TypeError):
        try:
            return pltpu.TPUCompilerParams(dimension_semantics=semantics)
        except (AttributeError, TypeError):
            return None


def _merge_topk(run_s, run_i, tile_s, tile_i, topk: int):
    """Merge a (bm, bn) score tile into the (bm, P) running top-k buffers.

    Iterative max-extraction: ``topk`` passes of rowwise max + first-match
    select over the concatenated candidates.  First-match (minimum position)
    prefers the running buffer, i.e. earlier = lower item indices, which is
    exactly the tie order of a stable dense argsort.
    """
    bm = run_s.shape[0]
    cand_s = jnp.concatenate([run_s, tile_s], axis=1)
    cand_i = jnp.concatenate([run_i, tile_i], axis=1)
    width = cand_s.shape[1]
    pos = jax.lax.broadcasted_iota(jnp.int32, (bm, width), 1)

    out_s, out_i = [], []
    for _ in range(topk):
        best = jnp.max(cand_s, axis=1, keepdims=True)
        sel = jnp.min(
            jnp.where((cand_s == best) & (best > _NEG_INF), pos, width),
            axis=1,
            keepdims=True,
        )
        hit = pos == sel  # one-hot row mask; all-False once a row runs dry
        out_s.append(jnp.max(jnp.where(hit, cand_s, _NEG_INF), axis=1, keepdims=True))
        out_i.append(jnp.max(jnp.where(hit, cand_i, 0), axis=1, keepdims=True))
        cand_s = jnp.where(hit, _NEG_INF, cand_s)

    pad = run_s.shape[1] - topk
    if pad:
        out_s.append(jnp.full((bm, pad), _NEG_INF, run_s.dtype))
        out_i.append(jnp.zeros((bm, pad), run_i.dtype))
    return jnp.concatenate(out_s, axis=1), jnp.concatenate(out_i, axis=1)


def _kernel(
    p_ref, q_ref, ru_ref, ri_ref, bias_ref, os_ref, oi_ref,
    acc_ref, ts_ref, ti_ref,
    *, block_k: int, topk: int, n_items: int,
):
    jn, ik = pl.program_id(1), pl.program_id(2)
    nj, nk = pl.num_programs(1), pl.num_programs(2)

    @pl.when((jn == 0) & (ik == 0))
    def _init_topk():
        ts_ref[...] = jnp.full_like(ts_ref, _NEG_INF)
        ti_ref[...] = jnp.zeros_like(ti_ref)

    @pl.when(ik == 0)
    def _init_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Ragged-K tile skipping, identical to pruned_matmul: every product term
    # in K-blocks at or past the tile's pair-rank bound is zero.
    bound = jnp.minimum(jnp.max(ru_ref[...]), jnp.max(ri_ref[...]))

    @pl.when(ik * block_k < bound)
    def _compute():
        bm, bk = p_ref.shape
        bn = q_ref.shape[0]
        t0 = ik * block_k
        tp_idx = t0 + jax.lax.broadcasted_iota(jnp.int32, (bm, bk), 1)
        tq_idx = t0 + jax.lax.broadcasted_iota(jnp.int32, (bn, bk), 1)
        pm = jnp.where(tp_idx < ru_ref[...], p_ref[...], 0.0).astype(jnp.float32)
        qm = jnp.where(tq_idx < ri_ref[...], q_ref[...], 0.0).astype(jnp.float32)
        acc_ref[...] += jax.lax.dot_general(
            pm, qm,
            dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    @pl.when(ik == nk - 1)
    def _merge():
        bm, bn = acc_ref.shape
        col = jax.lax.broadcasted_iota(jnp.int32, (bm, bn), 1)
        gidx = jn * bn + col
        scores = acc_ref[...] + bias_ref[...].reshape(1, bn)
        # padded catalog rows (q rows past n_items) must never be selected
        scores = jnp.where(gidx < n_items, scores, _NEG_INF)
        new_s, new_i = _merge_topk(ts_ref[...], ti_ref[...], scores, gidx, topk)
        ts_ref[...] = new_s
        ti_ref[...] = new_i

    @pl.when((jn == nj - 1) & (ik == nk - 1))
    def _store():
        os_ref[...] = ts_ref[...]
        oi_ref[...] = ti_ref[...]


@functools.partial(
    jax.jit,
    static_argnames=(
        "topk", "n_items", "block_m", "block_n", "block_k", "interpret"
    ),
)
def pruned_topk_padded(
    p: jax.Array,     # (M, K), M % block_m == 0, K % block_k == 0
    q: jax.Array,     # (N, K), N % block_n == 0 (rows >= n_items are padding)
    r_u: jax.Array,   # (M, 1) int32
    r_i: jax.Array,   # (N, 1) int32
    bias: jax.Array,  # (N, 1) float32 per-item additive bias (zeros if none)
    *,
    topk: int,
    n_items: int,
    block_m: int = TOPK_BLOCK_M,
    block_n: int = TOPK_BLOCK_N,
    block_k: int = TOPK_BLOCK_K,
    interpret: bool = False,
):
    """Padded-shape kernel entry.  Returns ``(scores, indices)`` shaped
    (M, topk_pad) with ``topk_pad = topk`` rounded up to the 128-lane tile;
    columns past ``topk`` are -inf / 0 filler."""
    m, k = p.shape
    n = q.shape[0]
    topk_pad = -(-topk // 128) * 128
    grid = (m // block_m, n // block_n, k // block_k)

    if _VMEM is None:
        raise RuntimeError(
            "jax.experimental.pallas.tpu is unavailable on this jax install; "
            "pruned_topk_padded needs pltpu.VMEM scratch. Use the streaming "
            "XLA path instead (kernels.ops.pruned_topk(use_kernel=False))."
        )
    kernel = functools.partial(
        _kernel, block_k=block_k, topk=topk, n_items=n_items
    )
    params = _compiler_params()
    kwargs = {"compiler_params": params} if params is not None else {}

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda im, jn, ik: (im, ik)),
            pl.BlockSpec((block_n, block_k), lambda im, jn, ik: (jn, ik)),
            pl.BlockSpec((block_m, 1), lambda im, jn, ik: (im, 0)),
            pl.BlockSpec((block_n, 1), lambda im, jn, ik: (jn, 0)),
            pl.BlockSpec((block_n, 1), lambda im, jn, ik: (jn, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_m, topk_pad), lambda im, jn, ik: (im, 0)),
            pl.BlockSpec((block_m, topk_pad), lambda im, jn, ik: (im, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, topk_pad), jnp.float32),
            jax.ShapeDtypeStruct((m, topk_pad), jnp.int32),
        ],
        scratch_shapes=[
            _VMEM((block_m, block_n), jnp.float32),
            _VMEM((block_m, topk_pad), jnp.float32),
            _VMEM((block_m, topk_pad), jnp.int32),
        ],
        interpret=interpret,
        **kwargs,
    )(p, q, r_u, r_i, bias)
