"""Pure-jnp oracles for the Pallas kernels, bit-exact to the paper's loops.

Every kernel in this package is validated against these references across
shape/dtype sweeps (``tests/test_kernels.py``).  ``early_stop_dot_loop`` is
additionally a direct numpy transcription of the paper's Algorithm 2 used by
the hypothesis property tests to pin the masked formulation to the paper.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.ranks import effective_ranks, rank_mask


def masked_factors(rows: jax.Array, ranks: jax.Array) -> jax.Array:
    """Zero columns ``t >= rank`` of each row."""
    return rows * rank_mask(ranks, rows.shape[-1], rows.dtype)


def pruned_matmul_ref(
    p: jax.Array,  # (m, k)
    q: jax.Array,  # (n, k)  item-major
    r_u: jax.Array,  # (m,) int32
    r_i: jax.Array,  # (n,) int32
    *,
    out_dtype=jnp.float32,
) -> jax.Array:
    """All-pairs early-stopped product: out[u, i] = sum_{t < min(r_u, r_i)}.

    Masking each operand by its own rank makes the product mask the AND of
    the two prefix masks, i.e. exactly ``t < min(r_u, r_i)``.
    """
    pm = masked_factors(p, r_u).astype(jnp.float32)
    qm = masked_factors(q, r_i).astype(jnp.float32)
    return jnp.dot(pm, qm.T, preferred_element_type=jnp.float32).astype(out_dtype)


def pruned_topk_ref(
    p: jax.Array,    # (m, k)
    q: jax.Array,    # (n, k)
    r_u: jax.Array,  # (m,) int32
    r_i: jax.Array,  # (n,) int32
    topk: int,
    *,
    item_bias: jax.Array | None = None,  # (n,) folded in before ranking
):
    """Serving oracle: dense pruned scores, full argsort, take top-k.

    Deliberately materializes the (m, n) score matrix — this is the
    score-everything-then-argsort baseline the serving engine replaces; the
    engine's streaming paths must return identical (scores, indices).
    Stable argsort resolves score ties toward the lower item index, matching
    the streaming merges (earlier tiles win ties).
    """
    scores = pruned_matmul_ref(p, q, r_u, r_i)
    if item_bias is not None:
        scores = scores + item_bias[None, :].astype(jnp.float32)
    order = jnp.argsort(-scores, axis=1)[:, :topk].astype(jnp.int32)
    return jnp.take_along_axis(scores, order, axis=1), order


def pruned_pair_dot_ref(
    p_rows: jax.Array,  # (b, k)
    q_rows: jax.Array,  # (b, k)
    r_u: jax.Array,     # (b,)
    r_i: jax.Array,     # (b,)
) -> jax.Array:
    pm = masked_factors(p_rows, r_u).astype(jnp.float32)
    qm = masked_factors(q_rows, r_i).astype(jnp.float32)
    return jnp.sum(pm * qm, axis=-1)


def fused_mf_sgd_ref(
    p_rows: jax.Array,   # (b, k) gathered user factors
    q_rows: jax.Array,   # (b, k) gathered item factors
    ratings: jax.Array,  # (b,)
    t_p: jax.Array,
    t_q: jax.Array,
    *,
    lr: float,
    lam: float,
    bias_u: jax.Array | None = None,   # (b,) gathered user biases
    bias_i: jax.Array | None = None,   # (b,) gathered item biases
    global_mean: jax.Array | float = 0.0,
    weight: jax.Array | None = None,   # (b,) update gate / importance weight
):
    """Alg. 2 + Alg. 3 fused: masked dot, error, masked SGD row updates.

    Returns ``(new_p_rows, new_q_rows, new_bias_u, new_bias_i, err)`` —
    the bias outputs are None when the inputs are.  Ranks are computed from
    the *current* row values (dynamic pruning); the update touches only the
    computed prefix ``t < min(r_u, r_i)``, per Eq. 5/6 restricted by Alg. 3.
    ``weight`` scales the updates only (0 = inert row); the prediction —
    including biases and the global mean — is always the full model output,
    so the error matches ``mf.train_step``.
    """
    k = p_rows.shape[-1]
    r_u = effective_ranks(p_rows, t_p)
    r_i = effective_ranks(q_rows, t_q)
    mask = rank_mask(jnp.minimum(r_u, r_i), k, jnp.float32)
    w = (
        jnp.ones((p_rows.shape[0],), jnp.float32)
        if weight is None
        else weight.astype(jnp.float32)
    )

    pf = p_rows.astype(jnp.float32)
    qf = q_rows.astype(jnp.float32)
    pred = jnp.sum(pf * qf * mask, axis=-1)
    if bias_u is not None:
        pred = (
            pred
            + jnp.asarray(global_mean, jnp.float32)
            + bias_u.astype(jnp.float32)
            + bias_i.astype(jnp.float32)
        )
    err = ratings.astype(jnp.float32) - pred

    wm = mask * w[:, None]
    new_p = pf + lr * (err[:, None] * qf - lam * pf) * wm
    new_q = qf + lr * (err[:, None] * pf - lam * qf) * wm
    new_bu = new_bi = None
    if bias_u is not None:
        buf = bias_u.astype(jnp.float32)
        bif = bias_i.astype(jnp.float32)
        new_bu = (buf + lr * (err - lam * buf) * w).astype(bias_u.dtype)
        new_bi = (bif + lr * (err - lam * bif) * w).astype(bias_i.dtype)
    return new_p.astype(p_rows.dtype), new_q.astype(q_rows.dtype), new_bu, new_bi, err


def _ranks_np(rows: np.ndarray, threshold: float) -> np.ndarray:
    """NumPy transcription of :func:`repro.core.ranks.effective_ranks`."""
    insig = np.abs(rows) < threshold
    first = np.argmax(insig, axis=-1).astype(np.int32)
    return np.where(np.any(insig, axis=-1), first, rows.shape[-1]).astype(
        np.int32
    )


def _rank_mask_np(ranks: np.ndarray, k: int) -> np.ndarray:
    return (np.arange(k)[None, :] < ranks[:, None]).astype(np.float32)


def bpr_step_ref(
    p: np.ndarray,         # (m, k) full user table
    q: np.ndarray,         # (n, k) full item table
    user: np.ndarray,      # (b,)
    pos: np.ndarray,       # (b,)
    neg: np.ndarray,       # (b,)
    t_p: float,
    t_q: float,
    *,
    lr: float,
    lam: float,
    item_bias: np.ndarray | None = None,   # (n,) optional
    weight: np.ndarray | None = None,      # (b,) update gate
):
    """NumPy reference for one plain-SGD pruned BPR step (whole tables).

    The differential oracle for ``workloads.bpr.bpr_train_step``: pair
    scores truncate at ``min(r_u, r_item)``, regularization masks by each
    row's own rank, duplicate rows accumulate additively (``np.add.at``,
    matching the scatter-add), all in float32 so grid-valued factors match
    the jitted step exactly.  Returns ``(new_p, new_q, new_item_bias,
    mean_loss)``.
    """
    k = p.shape[-1]
    pf = p.astype(np.float32)
    qf = q.astype(np.float32)
    x_u, y_i, y_j = pf[user], qf[pos], qf[neg]
    r_u = _ranks_np(x_u, t_p)
    r_i = _ranks_np(y_i, t_q)
    r_j = _ranks_np(y_j, t_q)
    m_ui = _rank_mask_np(np.minimum(r_u, r_i), k)
    m_uj = _rank_mask_np(np.minimum(r_u, r_j), k)
    m_u = _rank_mask_np(r_u, k)
    m_i = _rank_mask_np(r_i, k)
    m_j = _rank_mask_np(r_j, k)

    s_ui = np.sum(x_u * y_i * m_ui, axis=-1, dtype=np.float32)
    s_uj = np.sum(x_u * y_j * m_uj, axis=-1, dtype=np.float32)
    new_bias = None
    if item_bias is not None:
        bf = item_bias.astype(np.float32)
        s_ui = s_ui + bf[pos]
        s_uj = s_uj + bf[neg]
    diff = (s_ui - s_uj).astype(np.float32)
    sig = (1.0 / (1.0 + np.exp(diff))).astype(np.float32)  # σ(-diff)
    w = (
        np.ones_like(diff) if weight is None
        else weight.astype(np.float32)
    )

    g_p = (-sig[:, None] * (y_i * m_ui - y_j * m_uj) + lam * x_u * m_u)
    g_qi = (-sig[:, None] * x_u * m_ui + lam * y_i * m_i)
    g_qj = (sig[:, None] * x_u * m_uj + lam * y_j * m_j)
    new_p = pf.copy()
    new_q = qf.copy()
    np.add.at(new_p, user, (-lr * g_p * w[:, None]).astype(np.float32))
    np.add.at(new_q, pos, (-lr * g_qi * w[:, None]).astype(np.float32))
    np.add.at(new_q, neg, (-lr * g_qj * w[:, None]).astype(np.float32))
    if item_bias is not None:
        new_bias = item_bias.astype(np.float32).copy()
        np.add.at(new_bias, pos, -lr * (-sig + lam * bf[pos]) * w)
        np.add.at(new_bias, neg, -lr * (sig + lam * bf[neg]) * w)
    loss = np.log1p(np.exp(-np.abs(diff))) + np.maximum(-diff, 0.0)
    denom = max(float(w.sum()), 1e-9)
    return new_p, new_q, new_bias, float((loss * w).sum() / denom)


def early_stop_dot_loop(
    p_row: np.ndarray, q_row: np.ndarray, t_p: float, t_q: float
) -> float:
    """Direct transcription of the paper's Algorithm 2 (scalar, CPU)."""
    acc = 0.0
    for t in range(p_row.shape[0]):
        if abs(float(p_row[t])) < t_p or abs(float(q_row[t])) < t_q:
            break
        acc += float(p_row[t]) * float(q_row[t])
    return acc


def early_stop_update_loop(
    p_row: np.ndarray,
    q_row: np.ndarray,
    rating: float,
    t_p: float,
    t_q: float,
    lr: float,
    lam: float,
):
    """Algorithm 3 (scalar): prediction with Alg. 2 then truncated Eq. 5/6."""
    pred = early_stop_dot_loop(p_row, q_row, t_p, t_q)
    err = rating - pred
    new_p = p_row.astype(np.float64).copy()
    new_q = q_row.astype(np.float64).copy()
    for t in range(p_row.shape[0]):
        if abs(float(p_row[t])) < t_p or abs(float(q_row[t])) < t_q:
            break
        new_p[t] = p_row[t] + lr * (err * q_row[t] - lam * p_row[t])
        new_q[t] = q_row[t] + lr * (err * p_row[t] - lam * q_row[t])
    return new_p, new_q, err
