"""Public jit'd wrappers around the Pallas kernels.

Handles padding to block multiples, interpret-mode selection (the container
is CPU-only; TPU is the target), and instrumentation of tile-level skipped
work.  All wrappers are shape-polymorphic at the Python level and fixed-shape
under jit.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.ranks import effective_ranks, rank_mask
from repro.kernels import ref
from repro.kernels.fused_mf_sgd import fused_mf_sgd_padded
from repro.kernels.pruned_matmul import pruned_matmul_padded
from repro.kernels.pruned_topk import (
    TOPK_BLOCK_K,
    TOPK_BLOCK_M,
    TOPK_BLOCK_N,
    pruned_topk_padded,
)


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x: jax.Array, multiple: int, axis: int, value=0) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def pruned_matmul(
    p: jax.Array,
    q: jax.Array,
    t_p: jax.Array | float,
    t_q: jax.Array | float,
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    out_dtype=jnp.float32,
    interpret: bool | None = None,
    use_kernel: bool = True,
) -> jax.Array:
    """All-pairs early-stopped product ``(m, k) x (n, k) -> (m, n)``.

    Ranks are derived from the current factor values (dynamic pruning).  With
    ``use_kernel=False`` falls back to the XLA masked formulation — same
    numerics, no block skipping (used on meshes where the kernel is not the
    bottleneck and for the dry-run's SPMD partitioning).
    """
    r_u = effective_ranks(p, t_p)
    r_i = effective_ranks(q, t_q)
    if not use_kernel:
        return ref.pruned_matmul_ref(p, q, r_u, r_i, out_dtype=out_dtype)

    if interpret is None:
        interpret = _default_interpret()
    m, n = p.shape[0], q.shape[0]
    pp = _pad_to(_pad_to(p, block_m, 0), block_k, 1)
    qp = _pad_to(_pad_to(q, block_n, 0), block_k, 1)
    rup = _pad_to(r_u[:, None].astype(jnp.int32), block_m, 0)
    rip = _pad_to(r_i[:, None].astype(jnp.int32), block_n, 0)
    out = pruned_matmul_padded(
        pp,
        qp,
        rup,
        rip,
        block_m=block_m,
        block_n=block_n,
        block_k=block_k,
        out_dtype=out_dtype,
        interpret=interpret,
    )
    return out[:m, :n]


@functools.partial(jax.jit, static_argnames=("topk",))
def stream_topk_tiles(pm, q_tiles, b_tiles, offs, *, topk):
    """Streaming XLA top-k over pre-tiled item factors: scan item tiles,
    folding each (m, block_n) score tile into a running (m, topk) buffer
    with ``lax.top_k`` over the concatenation.

    ``pm`` is the rank-masked user block (m, k); ``q_tiles`` the rank-masked
    item factors (tiles, block_n, k); ``b_tiles`` per-item additive biases
    with ``-inf`` on padding rows (so they can never be selected); ``offs``
    each tile's first global item index.  Peak live memory is
    O(m * (topk + block_n)) — the (m, n) score matrix is never materialized.
    Concatenating the running buffer FIRST makes ``lax.top_k``'s
    lowest-index tie preference resolve toward earlier item tiles, matching
    the stable dense argsort oracle.  Shared by :func:`pruned_topk`
    (``use_kernel=False``) and the serving engine's local + sharded paths —
    the tie-order subtlety lives in exactly one place.
    """
    m = pm.shape[0]
    block_n = q_tiles.shape[1]

    def merge(carry, tile):
        run_s, run_i = carry
        qt, bt, off = tile
        s = pm @ qt.T + bt[None, :]
        gidx = off + jnp.arange(block_n, dtype=jnp.int32)
        cand_s = jnp.concatenate([run_s, s], axis=1)
        cand_i = jnp.concatenate(
            [run_i, jnp.broadcast_to(gidx, (m, block_n))], axis=1
        )
        new_s, sel = jax.lax.top_k(cand_s, topk)
        return (new_s, jnp.take_along_axis(cand_i, sel, axis=1)), None

    init = (
        jnp.full((m, topk), -jnp.inf, jnp.float32),
        jnp.zeros((m, topk), jnp.int32),
    )
    (scores, idx), _ = jax.lax.scan(merge, init, (q_tiles, b_tiles, offs))
    return scores, idx


def tile_catalog(qm, bias, block_n: int):
    """Pad + reshape rank-masked item factors into the streaming layout:
    ``(tiles, block_n, k)`` factors, ``(tiles, block_n)`` biases with -inf
    on padding rows, ``(tiles,)`` global offsets."""
    n, k = qm.shape
    pad = (-n) % block_n
    qm_p = jnp.pad(qm, ((0, pad), (0, 0)))
    bias_p = jnp.pad(bias, (0, pad), constant_values=-jnp.inf)
    tiles = (n + pad) // block_n
    return (
        qm_p.reshape(tiles, block_n, k),
        bias_p.reshape(tiles, block_n),
        jnp.arange(tiles, dtype=jnp.int32) * block_n,
    )


def _pruned_topk_scan(p, q, r_u, r_i, item_bias, *, topk, block_n):
    k = p.shape[1]
    pm = p.astype(jnp.float32) * rank_mask(r_u, k)
    qm = q.astype(jnp.float32) * rank_mask(r_i, k)
    q_tiles, b_tiles, offs = tile_catalog(
        qm, item_bias.astype(jnp.float32), block_n
    )
    return stream_topk_tiles(pm, q_tiles, b_tiles, offs, topk=topk)


def pad_catalog_for_topk_kernel(
    q, r_i, item_bias, *, block_n: int = TOPK_BLOCK_N,
    block_k: int = TOPK_BLOCK_K,
):
    """Item-side operands of ``pruned_topk_padded``: raw factors, ranks, and
    biases padded to the kernel's block multiples.  The single definition of
    the kernel's catalog-layout contract — the serving engine precomputes
    this once at load time and :func:`pruned_topk` builds it per call."""
    n = q.shape[0]
    bias = item_bias if item_bias is not None else jnp.zeros((n,), jnp.float32)
    return (
        _pad_to(_pad_to(q, block_n, 0), block_k, 1),
        _pad_to(r_i[:, None].astype(jnp.int32), block_n, 0),
        _pad_to(bias.astype(jnp.float32)[:, None], block_n, 0),
    )


def pad_users_for_topk_kernel(
    p, r_u, *, block_m: int = TOPK_BLOCK_M, block_k: int = TOPK_BLOCK_K
):
    """User-side operands of ``pruned_topk_padded`` (see above)."""
    return (
        _pad_to(_pad_to(p, block_m, 0), block_k, 1),
        _pad_to(r_u[:, None].astype(jnp.int32), block_m, 0),
    )


def pruned_topk(
    p: jax.Array,
    q: jax.Array,
    t_p: jax.Array | float,
    t_q: jax.Array | float,
    topk: int,
    *,
    item_bias: jax.Array | None = None,
    block_m: int = TOPK_BLOCK_M,
    block_n: int = TOPK_BLOCK_N,
    block_k: int = TOPK_BLOCK_K,
    interpret: bool | None = None,
    use_kernel: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Top-k pruned scores per user row: ``(m, k) x (n, k) -> 2 x (m, topk)``.

    The serving hot path.  Returns ``(scores, item_indices)`` identical to
    scoring everything and argsorting (``ref.pruned_topk_ref``) but without
    materializing the (m, n) score matrix: the Pallas kernel keeps a running
    top-k in VMEM across item tiles; ``use_kernel=False`` selects the
    streaming ``lax.top_k``-merge formulation (the production CPU path).
    """
    n = q.shape[0]
    if not 0 < topk <= n:
        raise ValueError(f"topk must be in [1, {n}], got {topk}")
    r_u = effective_ranks(p, t_p)
    r_i = effective_ranks(q, t_q)

    if not use_kernel:
        bias = item_bias if item_bias is not None else jnp.zeros((n,), jnp.float32)
        return _pruned_topk_scan(
            p, q, r_u, r_i, bias, topk=topk, block_n=block_n
        )

    if interpret is None:
        interpret = _default_interpret()
    m = p.shape[0]
    pp, rup = pad_users_for_topk_kernel(p, r_u, block_m=block_m, block_k=block_k)
    qp, rip, biasp = pad_catalog_for_topk_kernel(
        q, r_i, item_bias, block_n=block_n, block_k=block_k
    )
    scores, idx = pruned_topk_padded(
        pp, qp, rup, rip, biasp,
        topk=topk,
        n_items=n,
        block_m=block_m,
        block_n=block_n,
        block_k=block_k,
        interpret=interpret,
    )
    return scores[:m, :topk], idx[:m, :topk]


def fused_mf_sgd(
    p_rows: jax.Array,
    q_rows: jax.Array,
    ratings: jax.Array,
    t_p: jax.Array | float,
    t_q: jax.Array | float,
    *,
    lr: float,
    lam: float,
    bias_u: jax.Array | None = None,
    bias_i: jax.Array | None = None,
    global_mean: jax.Array | float = 0.0,
    weight: jax.Array | None = None,
    block_b: int = 256,
    interpret: bool | None = None,
    use_kernel: bool = True,
):
    """Fused Alg. 2 + Alg. 3 over a batch of gathered rows.

    Returns ``(new_p_rows, new_q_rows, new_bias_u, new_bias_i, err)`` with
    ``err`` shaped (B,); the bias outputs are None when the inputs are.
    Optional per-row biases + global mean fold into the prediction (BiasSVD)
    and an optional ``weight`` column gates the updates — both run inside
    the kernel, so the biased/weighted cases share the fused path.
    """
    t_p = jnp.asarray(t_p, jnp.float32)
    t_q = jnp.asarray(t_q, jnp.float32)
    if not use_kernel:
        return ref.fused_mf_sgd_ref(
            p_rows, q_rows, ratings, t_p, t_q, lr=lr, lam=lam,
            bias_u=bias_u, bias_i=bias_i, global_mean=global_mean,
            weight=weight,
        )
    if interpret is None:
        interpret = _default_interpret()
    b = p_rows.shape[0]
    has_bias = bias_u is not None

    def col(v, fill):
        full = jnp.full((b,), fill, jnp.float32) if v is None else v
        return _pad_to(full.astype(jnp.float32)[:, None], block_b, 0)

    pp = _pad_to(p_rows, block_b, 0)
    qp = _pad_to(q_rows, block_b, 0)
    rp = _pad_to(ratings[:, None].astype(jnp.float32), block_b, 0)
    mu = jnp.asarray(global_mean if has_bias else 0.0, jnp.float32)
    new_p, new_q, new_bu, new_bi, err = fused_mf_sgd_padded(
        pp,
        qp,
        rp,
        col(bias_u, 0.0),
        col(bias_i, 0.0),
        col(weight, 1.0),  # padding rows get weight 0 from _pad_to
        t_p.reshape(1, 1),
        t_q.reshape(1, 1),
        mu.reshape(1, 1),
        lr=lr,
        lam=lam,
        block_b=block_b,
        interpret=interpret,
    )
    return (
        new_p[:b],
        new_q[:b],
        new_bu[:b, 0] if has_bias else None,
        new_bi[:b, 0] if has_bias else None,
        err[:b, 0],
    )


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k", "k"))
def tile_block_stats(
    r_u: jax.Array,
    r_i: jax.Array,
    k: int,
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
):
    """Instrumentation: fraction of K-blocks the kernel executes vs dense.

    Deterministic from the ranks (the kernel's ``pl.when`` bound), so it can
    be computed without instrumenting the kernel itself.  Also returns the
    element-exact work fraction (the paper's per-element early stop) to show
    how much the tile quantization gives back.
    """
    rup = _pad_to(r_u.astype(jnp.int32), block_m, 0)
    rip = _pad_to(r_i.astype(jnp.int32), block_n, 0)
    tu = jnp.max(rup.reshape(-1, block_m), axis=1)  # per-M-tile max rank
    ti = jnp.max(rip.reshape(-1, block_n), axis=1)  # per-N-tile max rank
    bound = jnp.minimum(tu[:, None], ti[None, :]).astype(jnp.float32)
    nk = -(-k // block_k)
    blocks = jnp.ceil(bound / block_k)
    tile_fraction = jnp.mean(blocks) / nk
    elem_fraction = jnp.mean(
        jnp.minimum(r_u[:, None], r_i[None, :]).astype(jnp.float32)
    ) / float(k)
    return tile_fraction, elem_fraction
