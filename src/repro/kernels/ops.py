"""Public jit'd wrappers around the Pallas kernels.

Handles padding to block multiples, interpret-mode selection (the container
is CPU-only; TPU is the target), and instrumentation of tile-level skipped
work.  All wrappers are shape-polymorphic at the Python level and fixed-shape
under jit.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.ranks import effective_ranks
from repro.kernels import ref
from repro.kernels.fused_mf_sgd import fused_mf_sgd_padded
from repro.kernels.pruned_matmul import pruned_matmul_padded


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x: jax.Array, multiple: int, axis: int, value=0) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def pruned_matmul(
    p: jax.Array,
    q: jax.Array,
    t_p: jax.Array | float,
    t_q: jax.Array | float,
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    out_dtype=jnp.float32,
    interpret: bool | None = None,
    use_kernel: bool = True,
) -> jax.Array:
    """All-pairs early-stopped product ``(m, k) x (n, k) -> (m, n)``.

    Ranks are derived from the current factor values (dynamic pruning).  With
    ``use_kernel=False`` falls back to the XLA masked formulation — same
    numerics, no block skipping (used on meshes where the kernel is not the
    bottleneck and for the dry-run's SPMD partitioning).
    """
    r_u = effective_ranks(p, t_p)
    r_i = effective_ranks(q, t_q)
    if not use_kernel:
        return ref.pruned_matmul_ref(p, q, r_u, r_i, out_dtype=out_dtype)

    if interpret is None:
        interpret = _default_interpret()
    m, n = p.shape[0], q.shape[0]
    pp = _pad_to(_pad_to(p, block_m, 0), block_k, 1)
    qp = _pad_to(_pad_to(q, block_n, 0), block_k, 1)
    rup = _pad_to(r_u[:, None].astype(jnp.int32), block_m, 0)
    rip = _pad_to(r_i[:, None].astype(jnp.int32), block_n, 0)
    out = pruned_matmul_padded(
        pp,
        qp,
        rup,
        rip,
        block_m=block_m,
        block_n=block_n,
        block_k=block_k,
        out_dtype=out_dtype,
        interpret=interpret,
    )
    return out[:m, :n]


def fused_mf_sgd(
    p_rows: jax.Array,
    q_rows: jax.Array,
    ratings: jax.Array,
    t_p: jax.Array | float,
    t_q: jax.Array | float,
    *,
    lr: float,
    lam: float,
    block_b: int = 256,
    interpret: bool | None = None,
    use_kernel: bool = True,
):
    """Fused Alg. 2 + Alg. 3 over a batch of gathered rows.

    Returns ``(new_p_rows, new_q_rows, err)`` with ``err`` shaped (B,).
    """
    t_p = jnp.asarray(t_p, jnp.float32)
    t_q = jnp.asarray(t_q, jnp.float32)
    if not use_kernel:
        return ref.fused_mf_sgd_ref(
            p_rows, q_rows, ratings, t_p, t_q, lr=lr, lam=lam
        )
    if interpret is None:
        interpret = _default_interpret()
    b = p_rows.shape[0]
    pp = _pad_to(p_rows, block_b, 0)
    qp = _pad_to(q_rows, block_b, 0)
    rp = _pad_to(ratings[:, None].astype(jnp.float32), block_b, 0)
    new_p, new_q, err = fused_mf_sgd_padded(
        pp,
        qp,
        rp,
        t_p.reshape(1, 1),
        t_q.reshape(1, 1),
        lr=lr,
        lam=lam,
        block_b=block_b,
        interpret=interpret,
    )
    return new_p[:b], new_q[:b], err[:b, 0]


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k", "k"))
def tile_block_stats(
    r_u: jax.Array,
    r_i: jax.Array,
    k: int,
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
):
    """Instrumentation: fraction of K-blocks the kernel executes vs dense.

    Deterministic from the ranks (the kernel's ``pl.when`` bound), so it can
    be computed without instrumenting the kernel itself.  Also returns the
    element-exact work fraction (the paper's per-element early stop) to show
    how much the tile quantization gives back.
    """
    rup = _pad_to(r_u.astype(jnp.int32), block_m, 0)
    rip = _pad_to(r_i.astype(jnp.int32), block_n, 0)
    tu = jnp.max(rup.reshape(-1, block_m), axis=1)  # per-M-tile max rank
    ti = jnp.max(rip.reshape(-1, block_n), axis=1)  # per-N-tile max rank
    bound = jnp.minimum(tu[:, None], ti[None, :]).astype(jnp.float32)
    nk = -(-k // block_k)
    blocks = jnp.ceil(bound / block_k)
    tile_fraction = jnp.mean(blocks) / nk
    elem_fraction = jnp.mean(
        jnp.minimum(r_u[:, None], r_i[None, :]).astype(jnp.float32)
    ) / float(k)
    return tile_fraction, elem_fraction
