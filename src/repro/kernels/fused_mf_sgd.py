"""Fused dynamic-pruned MF-SGD step kernel (paper Algs. 2 + 3 in one pass).

For a batch of gathered factor rows this kernel computes, entirely in VMEM:

    r_u, r_i  = first-insignificant index of each row (dynamic, from the
                *current* values — the paper's per-epoch/per-rating sparsity)
    pred      = sum_{t < min(r_u, r_i)} p[t] * q[t]            (Alg. 2)
    err       = rating - pred                                  (Eq. 4)
    p', q'    = truncated SGD update on t < min(r_u, r_i)      (Alg. 3 / Eq. 5-6)

Fusing avoids three HBM round-trips of the (B, k) row blocks (dot, then two
updates) — the latent-factor-update half of the paper's savings.  The
surrounding gather/scatter stays in XLA (bandwidth-bound; XLA's dynamic
gather/scatter-add is already roofline there).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ranks(rows: jax.Array, threshold: jax.Array, k: int) -> jax.Array:
    """First-insignificant index per row, TPU-safe (2D iota)."""
    bb = rows.shape[0]
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (bb, k), 1)
    insig = jnp.abs(rows) < threshold
    return jnp.min(jnp.where(insig, t_idx, jnp.int32(k)), axis=1, keepdims=True)


def _kernel(p_ref, q_ref, r_ref, tp_ref, tq_ref, np_ref, nq_ref, err_ref, *, lr, lam):
    bb, k = p_ref.shape
    p = p_ref[...].astype(jnp.float32)
    q = q_ref[...].astype(jnp.float32)
    t_p = tp_ref[0, 0]
    t_q = tq_ref[0, 0]

    r_u = _ranks(p, t_p, k)
    r_i = _ranks(q, t_q, k)
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (bb, k), 1)
    mask = (t_idx < jnp.minimum(r_u, r_i)).astype(jnp.float32)

    pred = jnp.sum(p * q * mask, axis=1, keepdims=True)
    err = r_ref[...].astype(jnp.float32) - pred

    np_ref[...] = (p + lr * (err * q - lam * p) * mask).astype(np_ref.dtype)
    nq_ref[...] = (q + lr * (err * p - lam * q) * mask).astype(nq_ref.dtype)
    err_ref[...] = err.astype(err_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("lr", "lam", "block_b", "interpret")
)
def fused_mf_sgd_padded(
    p_rows: jax.Array,   # (B, k), B % block_b == 0
    q_rows: jax.Array,   # (B, k)
    ratings: jax.Array,  # (B, 1)
    t_p: jax.Array,      # (1, 1) f32
    t_q: jax.Array,      # (1, 1) f32
    *,
    lr: float,
    lam: float,
    block_b: int = 256,
    interpret: bool = False,
):
    b, k = p_rows.shape
    grid = (b // block_b,)
    kernel = functools.partial(_kernel, lr=lr, lam=lam)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, k), lambda ib: (ib, 0)),
            pl.BlockSpec((block_b, k), lambda ib: (ib, 0)),
            pl.BlockSpec((block_b, 1), lambda ib: (ib, 0)),
            pl.BlockSpec((1, 1), lambda ib: (0, 0)),
            pl.BlockSpec((1, 1), lambda ib: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_b, k), lambda ib: (ib, 0)),
            pl.BlockSpec((block_b, k), lambda ib: (ib, 0)),
            pl.BlockSpec((block_b, 1), lambda ib: (ib, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, k), p_rows.dtype),
            jax.ShapeDtypeStruct((b, k), q_rows.dtype),
            jax.ShapeDtypeStruct((b, 1), jnp.float32),
        ],
        interpret=interpret,
    )(p_rows, q_rows, ratings, t_p, t_q)
