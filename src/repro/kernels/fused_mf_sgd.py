"""Fused dynamic-pruned MF-SGD step kernel (paper Algs. 2 + 3 in one pass).

For a batch of gathered factor rows this kernel computes, entirely in VMEM:

    r_u, r_i  = first-insignificant index of each row (dynamic, from the
                *current* values — the paper's per-epoch/per-rating sparsity)
    pred      = sum_{t < min(r_u, r_i)} p[t] * q[t] + mu + b_u + b_i (Alg. 2)
    err       = rating - pred                                        (Eq. 4)
    p', q'    = truncated SGD update on t < min(r_u, r_i)   (Alg. 3 / Eq. 5-6)
    b_u', b_i'= SGD bias updates gated by the same row weight

Fusing avoids three HBM round-trips of the (B, k) row blocks (dot, then two
updates) — the latent-factor-update half of the paper's savings.  The
surrounding gather/scatter stays in XLA (bandwidth-bound; XLA's dynamic
gather/scatter-add is already roofline there).

Bias rows, the global mean, and a per-row importance ``weight`` column ride
along as (B, 1) / (1, 1) operands: negligible bandwidth next to the (B, k)
blocks, and they let the BiasSVD and weighted-update cases (online
importance weighting, padded batches) share the fused path instead of
falling back to the unfused XLA formulation.  The weight gates the *update*
only — the prediction (and thus the error) always uses the full model
output, matching ``mf.train_step``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ranks(rows: jax.Array, threshold: jax.Array, k: int) -> jax.Array:
    """First-insignificant index per row, TPU-safe (2D iota)."""
    bb = rows.shape[0]
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (bb, k), 1)
    insig = jnp.abs(rows) < threshold
    return jnp.min(jnp.where(insig, t_idx, jnp.int32(k)), axis=1, keepdims=True)


def _kernel(
    p_ref, q_ref, r_ref, bu_ref, bi_ref, w_ref, tp_ref, tq_ref, mu_ref,
    np_ref, nq_ref, nbu_ref, nbi_ref, err_ref, *, lr, lam,
):
    bb, k = p_ref.shape
    p = p_ref[...].astype(jnp.float32)
    q = q_ref[...].astype(jnp.float32)
    bu = bu_ref[...].astype(jnp.float32)   # (bb, 1)
    bi = bi_ref[...].astype(jnp.float32)   # (bb, 1)
    w = w_ref[...].astype(jnp.float32)     # (bb, 1)
    t_p = tp_ref[0, 0]
    t_q = tq_ref[0, 0]
    mu = mu_ref[0, 0]

    r_u = _ranks(p, t_p, k)
    r_i = _ranks(q, t_q, k)
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (bb, k), 1)
    mask = (t_idx < jnp.minimum(r_u, r_i)).astype(jnp.float32)

    pred = jnp.sum(p * q * mask, axis=1, keepdims=True) + mu + bu + bi
    err = r_ref[...].astype(jnp.float32) - pred
    wm = mask * w  # the update gate; pred above stays the full model output

    np_ref[...] = (p + lr * (err * q - lam * p) * wm).astype(np_ref.dtype)
    nq_ref[...] = (q + lr * (err * p - lam * q) * wm).astype(nq_ref.dtype)
    nbu_ref[...] = (bu + lr * (err - lam * bu) * w).astype(nbu_ref.dtype)
    nbi_ref[...] = (bi + lr * (err - lam * bi) * w).astype(nbi_ref.dtype)
    err_ref[...] = err.astype(err_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("lr", "lam", "block_b", "interpret")
)
def fused_mf_sgd_padded(
    p_rows: jax.Array,   # (B, k), B % block_b == 0
    q_rows: jax.Array,   # (B, k)
    ratings: jax.Array,  # (B, 1)
    bias_u: jax.Array,   # (B, 1) f32 (zeros when unbiased)
    bias_i: jax.Array,   # (B, 1) f32
    weight: jax.Array,   # (B, 1) f32 (ones when unweighted; 0 = inert row)
    t_p: jax.Array,      # (1, 1) f32
    t_q: jax.Array,      # (1, 1) f32
    mu: jax.Array,       # (1, 1) f32 global mean (0 when unbiased)
    *,
    lr: float,
    lam: float,
    block_b: int = 256,
    interpret: bool = False,
):
    b, k = p_rows.shape
    grid = (b // block_b,)
    kernel = functools.partial(_kernel, lr=lr, lam=lam)
    row_spec = pl.BlockSpec((block_b, 1), lambda ib: (ib, 0))
    blk_spec = pl.BlockSpec((block_b, k), lambda ib: (ib, 0))
    scalar_spec = pl.BlockSpec((1, 1), lambda ib: (0, 0))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            blk_spec, blk_spec, row_spec, row_spec, row_spec, row_spec,
            scalar_spec, scalar_spec, scalar_spec,
        ],
        out_specs=[blk_spec, blk_spec, row_spec, row_spec, row_spec],
        out_shape=[
            jax.ShapeDtypeStruct((b, k), p_rows.dtype),
            jax.ShapeDtypeStruct((b, k), q_rows.dtype),
            jax.ShapeDtypeStruct((b, 1), jnp.float32),
            jax.ShapeDtypeStruct((b, 1), jnp.float32),
            jax.ShapeDtypeStruct((b, 1), jnp.float32),
        ],
        interpret=interpret,
    )(p_rows, q_rows, ratings, bias_u, bias_i, weight, t_p, t_q, mu)
