"""Pallas TPU kernels for the paper's compute hot-spots.

The paper's contribution *is* a compute-kernel optimization (early-stopped
dot products and truncated factor updates), so this package carries the two
perf-critical kernels plus their jit wrappers (``ops.py``) and pure-jnp
oracles (``ref.py``).
"""
from repro.kernels.ops import (  # noqa: F401
    fused_mf_sgd,
    pruned_matmul,
    pruned_topk,
    tile_block_stats,
)
