"""Tile-ragged-K pruned matmul — the TPU-native form of the paper's Alg. 2.

Computes ``out[u, i] = sum_{t < min(r_u[u], r_i[i])} p[u, t] * q[i, t]`` for
all pairs, where ``r_u``/``r_i`` are the per-row effective ranks of the
(rearranged) factor matrices.

TPU adaptation of the paper's scalar early-exit (see DESIGN.md §2):

* the (M, N, K) iteration space is tiled into MXU-aligned blocks held in VMEM
  via ``BlockSpec``;
* for each (M-tile, N-tile), whole K-blocks past the tile bound
  ``min(max_tile(r_u), max_tile(r_i))`` are skipped with ``pl.when`` — this is
  where the paper's "unnecessary computation" is actually not executed;
* partially-covered K-blocks are element-masked with ``broadcasted_iota`` so
  the result equals the reference oracle exactly (not approximately).

Because Alg. 1 sorts the latent axis by joint sparsity, rank values are
front-loaded and correlated, so the per-tile ``max`` stays close to individual
ranks and tile-level skipping recovers most of the element-level savings
(measured in benchmarks/bench_kernels.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # pltpu naming moved across JAX versions; scratch VMEM spec lives here.
    from jax.experimental.pallas import tpu as pltpu

    _VMEM = pltpu.VMEM

    def _compiler_params():
        try:
            return pltpu.CompilerParams(
                dimension_semantics=("parallel", "parallel", "arbitrary")
            )
        except (AttributeError, TypeError):
            try:
                return pltpu.TPUCompilerParams(
                    dimension_semantics=("parallel", "parallel", "arbitrary")
                )
            except (AttributeError, TypeError):
                return None

except ImportError:  # pragma: no cover - pallas.tpu always present on jax>=0.4
    pltpu = None
    _VMEM = None

    def _compiler_params():
        return None


def _kernel(p_ref, q_ref, ru_ref, ri_ref, o_ref, acc_ref, *, block_k: int):
    """One (M-tile, N-tile, K-block) grid step."""
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Tile bound: the largest pair rank within this (M, N) tile.  Every
    # product term in K-blocks at or past the bound is zero by construction,
    # so the whole block is skipped — the TPU analogue of the paper's break.
    bound = jnp.minimum(jnp.max(ru_ref[...]), jnp.max(ri_ref[...]))

    @pl.when(ik * block_k < bound)
    def _compute():
        bm, bk = p_ref.shape
        bn = q_ref.shape[0]
        t0 = ik * block_k
        # Element masks: zero each operand's suffix (t >= own rank).  The
        # product mask is then t < min(r_u, r_i), matching Alg. 2 exactly.
        tp_idx = t0 + jax.lax.broadcasted_iota(jnp.int32, (bm, bk), 1)
        tq_idx = t0 + jax.lax.broadcasted_iota(jnp.int32, (bn, bk), 1)
        pm = jnp.where(tp_idx < ru_ref[...], p_ref[...], 0.0).astype(jnp.float32)
        qm = jnp.where(tq_idx < ri_ref[...], q_ref[...], 0.0).astype(jnp.float32)
        acc_ref[...] += jax.lax.dot_general(
            pm,
            qm,
            dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    @pl.when(ik == nk - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_n", "block_k", "out_dtype", "interpret"),
)
def pruned_matmul_padded(
    p: jax.Array,    # (M, K), M % block_m == 0, K % block_k == 0
    q: jax.Array,    # (N, K), N % block_n == 0
    r_u: jax.Array,  # (M, 1) int32
    r_i: jax.Array,  # (N, 1) int32
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    out_dtype=jnp.float32,
    interpret: bool = False,
) -> jax.Array:
    m, k = p.shape
    n = q.shape[0]
    grid = (m // block_m, n // block_n, k // block_k)

    kernel = functools.partial(_kernel, block_k=block_k)
    if _VMEM is None:
        raise RuntimeError(
            "jax.experimental.pallas.tpu is unavailable on this jax install; "
            "pruned_matmul_padded needs a pltpu.VMEM accumulator. Use the XLA "
            "reference path instead (kernels.ops.pruned_matmul(use_kernel=False))."
        )
    scratch = [_VMEM((block_m, block_n), jnp.float32)]
    params = _compiler_params()
    kwargs = {"compiler_params": params} if params is not None else {}

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda im, jn, ik: (im, ik)),
            pl.BlockSpec((block_n, block_k), lambda im, jn, ik: (jn, ik)),
            pl.BlockSpec((block_m, 1), lambda im, jn, ik: (im, 0)),
            pl.BlockSpec((block_n, 1), lambda im, jn, ik: (jn, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda im, jn, ik: (im, jn)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=scratch,
        interpret=interpret,
        **kwargs,
    )(p, q, r_u, r_i)
