"""Fault-tolerant checkpointing.

Design constraints for thousand-node deployments:

* **Atomicity** — a checkpoint is written to a temp directory and published
  with ``os.rename`` (atomic on POSIX), so a preempted writer never leaves a
  half-checkpoint that a restart could load.
* **Resumability** — metadata carries (epoch, step, data seed) so the loader
  replays the exact data order (see data/loader.py).
* **Keep-N retention** — bounded disk usage under frequent checkpointing.
* **Async save** — a background thread serializes while the accelerators keep
  training; ``wait()`` joins before the next save or job exit.
* **Elastic restore** — arrays are saved with logical shapes only; the caller
  re-shards onto whatever mesh the restarted job has (``elastic_load`` simply
  returns host arrays + a helper to ``device_put`` with new shardings).

Storage is ``.npz`` + JSON — the container has no orbax; the format is
deliberately dependency-free and append-only.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

Pytree = Any

_SEP = "__"


def _flatten_with_paths(tree: Pytree) -> List[Tuple[str, np.ndarray]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = _SEP.join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path
        )
        out.append((key or "root", np.asarray(leaf)))
    return out


def save(
    directory: str,
    step: int,
    tree: Pytree,
    *,
    metadata: Optional[Dict[str, Any]] = None,
    keep: int = 3,
) -> str:
    """Blocking atomic save.  Returns the published checkpoint path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:012d}")
    tmp = final + f".tmp.{os.getpid()}.{int(time.time() * 1e6)}"
    os.makedirs(tmp, exist_ok=True)

    arrays = dict(_flatten_with_paths(tree))
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    meta = {"step": step, "keys": sorted(arrays), **(metadata or {})}
    with open(os.path.join(tmp, "metadata.json"), "w") as f:
        json.dump(meta, f, indent=2, default=str)
    # fsync the payload before publishing so a crash cannot publish garbage.
    for name in ("arrays.npz", "metadata.json"):
        fd = os.open(os.path.join(tmp, name), os.O_RDONLY)
        try:
            os.fsync(fd)
        finally:
            os.close(fd)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _garbage_collect(directory, keep)
    return final


def _garbage_collect(directory: str, keep: int) -> None:
    steps = all_steps(directory)
    for step in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(directory, f"step_{step:012d}"), ignore_errors=True)
    # stale temp dirs from crashed writers
    for name in os.listdir(directory):
        if ".tmp." in name:
            path = os.path.join(directory, name)
            if time.time() - os.path.getmtime(path) > 3600:
                shutil.rmtree(path, ignore_errors=True)


def all_steps(directory: str) -> List[int]:
    if not os.path.isdir(directory):
        return []
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and ".tmp." not in name:
            try:
                steps.append(int(name[len("step_"):]))
            except ValueError:
                continue
    return sorted(steps)


def latest_step(directory: str) -> Optional[int]:
    steps = all_steps(directory)
    return steps[-1] if steps else None


def step_path(directory: str, step: int) -> str:
    """The on-disk directory of one step — the single definition of the
    layout every reader (restore, serving loader, online delta folds) uses."""
    return os.path.join(directory, f"step_{step:012d}")


def load_metadata(directory: str, step: int) -> Dict[str, Any]:
    with open(os.path.join(step_path(directory, step), "metadata.json")) as f:
        return json.load(f)


def load_raw(
    directory: str,
    step: Optional[int] = None,
    *,
    metadata: Optional[Dict[str, Any]] = None,
) -> Tuple[Dict[str, np.ndarray], Dict[str, Any]]:
    """Load a step's flat ``{key: array}`` payload + metadata, no structure
    imposed — the layer :func:`restore` (pytree shaping) and the online
    delta folds build on.  Pass ``metadata`` if already read to skip the
    re-read."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    if metadata is None:
        metadata = load_metadata(directory, step)
    with np.load(os.path.join(step_path(directory, step), "arrays.npz")) as data:
        arrays = {key: data[key] for key in data.files}
    return arrays, metadata


def restore(
    directory: str,
    tree_like: Pytree,
    *,
    step: Optional[int] = None,
) -> Tuple[Pytree, Dict[str, Any]]:
    """Restore into the structure of ``tree_like``.  Returns (tree, metadata)."""
    arrays, meta = load_raw(directory, step)

    keys = [k for k, _ in _flatten_with_paths(tree_like)]
    leaves, treedef = jax.tree_util.tree_flatten(tree_like)
    restored = []
    for key, like in zip(keys, leaves):
        if key not in arrays:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = arrays[key]
        if tuple(arr.shape) != tuple(np.shape(like)):
            raise ValueError(
                f"leaf {key!r}: checkpoint shape {arr.shape} != expected "
                f"{np.shape(like)}"
            )
        restored.append(arr)
    return treedef.unflatten(restored), meta


def elastic_load(
    directory: str,
    tree_like: Pytree,
    shard_fn: Callable[[Pytree], Pytree],
    *,
    step: Optional[int] = None,
) -> Tuple[Pytree, Dict[str, Any]]:
    """Restore then re-shard onto the *current* mesh (which may differ from
    the mesh the checkpoint was written under — elastic scaling)."""
    host_tree, meta = restore(directory, tree_like, step=step)
    return shard_fn(host_tree), meta


class AsyncCheckpointer:
    """Overlap serialization with training; at most one save in flight."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, tree: Pytree, metadata=None) -> None:
        self.wait()
        host_tree = jax.tree_util.tree_map(np.asarray, tree)  # snapshot now

        def work():
            try:
                save(self.directory, step, host_tree, metadata=metadata, keep=self.keep)
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err
