"""Fault-tolerant checkpointing.

Design constraints for thousand-node deployments:

* **Atomicity** — a checkpoint's payload is written and fsynced into a
  uniquely-named ``step_X.data.*`` directory, then published by atomically
  replacing a ``step_X`` symlink (``os.replace``) and fsyncing the parent
  directory.  A preempted writer never leaves a half-checkpoint, and a
  reader racing a re-save of the same step never observes the checkpoint
  missing: superseded payload directories linger until the retention sweep,
  so a reader that already resolved the link keeps a consistent view.
* **Resumability** — metadata carries (epoch, step, data seed) so the loader
  replays the exact data order (see data/loader.py).
* **Keep-N retention** — bounded disk usage under frequent checkpointing.
* **Async save** — a background thread serializes while the accelerators keep
  training; ``wait()`` joins before the next save or job exit.
* **Elastic restore** — arrays are saved with logical shapes only; the caller
  re-shards onto whatever mesh the restarted job has (``elastic_load`` simply
  returns host arrays + a helper to ``device_put`` with new shardings).

Storage is ``.npz`` + JSON — the container has no orbax; the format is
deliberately dependency-free and append-only.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
import zlib
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.testing import faults

Pytree = Any

_SEP = "__"


class CorruptCheckpointError(RuntimeError):
    """A checkpoint payload failed its integrity check (CRC mismatch,
    truncated/unreadable npz).  Restores fall back to an older step
    instead of propagating an opaque zipfile/numpy exception — corrupt
    bytes must never become NaN factors."""


def _flatten_with_paths(tree: Pytree) -> List[Tuple[str, np.ndarray]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = _SEP.join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path
        )
        out.append((key or "root", np.asarray(leaf)))
    return out


# Unreferenced payload dirs / temp files must outlive any reader that
# resolved the step symlink before a re-save superseded them; one hour is
# far beyond any read.  Module constant so tests can force an eager sweep.
_STALE_SECONDS = 3600.0


def _fsync_dir(path: str) -> None:
    """fsync a directory so its entry creations/renames survive a crash."""
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def save(
    directory: str,
    step: int,
    tree: Pytree,
    *,
    metadata: Optional[Dict[str, Any]] = None,
    keep: int = 3,
) -> str:
    """Blocking atomic save.  Returns the published checkpoint path.

    Publication is a symlink swap: the payload lands (fsynced) in a
    uniquely-named ``step_X.data.<nonce>`` directory, then the ``step_X``
    symlink is atomically repointed with ``os.replace`` and the parent
    directory fsynced.  Re-saving an existing step therefore never opens a
    missing-checkpoint window (the old ``rmtree``+``rename`` publish did),
    and a concurrent reader that already resolved the link keeps reading a
    complete payload — superseded payload dirs are only collected by the
    retention sweep once they are ``_STALE_SECONDS`` old.
    """
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:012d}")
    nonce = f"{os.getpid()}.{int(time.time() * 1e6)}"
    data_name = f"step_{step:012d}.data.{nonce}"
    data_dir = os.path.join(directory, data_name)
    os.makedirs(data_dir, exist_ok=True)

    arrays = dict(_flatten_with_paths(tree))
    np.savez(os.path.join(data_dir, "arrays.npz"), **arrays)
    # CRC the payload as written: restores verify these exact bytes, so a
    # truncation or bit flip between here and the restore is detected
    # instead of deserialized
    meta = {
        "step": step,
        "keys": sorted(arrays),
        "payload_crc32": _file_crc32(os.path.join(data_dir, "arrays.npz")),
        **(metadata or {}),
    }
    with open(os.path.join(data_dir, "metadata.json"), "w") as f:
        json.dump(meta, f, indent=2, default=str)
    # fsync the payload before publishing so a crash cannot publish garbage.
    if faults._PLAN is not None:
        for act in faults.fire("checkpoint.fsync"):
            if act.op == "error":
                raise OSError("injected fsync failure (chaos harness)")
    for name in ("arrays.npz", "metadata.json"):
        fd = os.open(os.path.join(data_dir, name), os.O_RDONLY)
        try:
            os.fsync(fd)
        finally:
            os.close(fd)
    _fsync_dir(data_dir)

    if os.path.isdir(final) and not os.path.islink(final):
        # Legacy layout: step_X is a real directory from an older writer.
        # Move it aside so the symlink can take the name (one non-atomic
        # transition per legacy step; the sweep collects the remains).
        os.rename(final, os.path.join(directory, f"{data_name}.legacy"))
    link_tmp = os.path.join(directory, f"step_{step:012d}.lnk.{nonce}")
    os.symlink(data_name, link_tmp)  # relative target: dir stays relocatable
    os.replace(link_tmp, final)      # atomic publish / re-publish
    _fsync_dir(directory)
    _garbage_collect(directory, keep)
    return final


def _file_crc32(path: str, *, chunk: int = 1 << 20) -> int:
    """Streaming CRC-32 of one file (constant memory)."""
    crc = 0
    with open(path, "rb") as f:
        while True:
            block = f.read(chunk)
            if not block:
                return crc
            crc = zlib.crc32(block, crc)


def _remove_step(directory: str, step: int) -> None:
    """Retire one published step: drop the symlink first (readers stop
    resolving to the payload), then the payload it referenced."""
    path = os.path.join(directory, f"step_{step:012d}")
    if os.path.islink(path):
        target = os.path.join(directory, os.readlink(path))
        try:
            os.unlink(path)
        except OSError:
            pass
        shutil.rmtree(target, ignore_errors=True)
    else:
        shutil.rmtree(path, ignore_errors=True)


def _garbage_collect(directory: str, keep: int) -> None:
    steps = all_steps(directory)
    for step in steps[:-keep] if keep > 0 else []:
        _remove_step(directory, step)
    # payload dirs still referenced by a live step symlink must survive
    live = set()
    for step in all_steps(directory):
        path = os.path.join(directory, f"step_{step:012d}")
        if os.path.islink(path):
            live.add(os.readlink(path))
    # stale leftovers: crashed-writer temp dirs/links and payload dirs a
    # re-save superseded — swept only once old enough that no reader can
    # still hold a resolved path into them
    now = time.time()
    for name in os.listdir(directory):
        stale = ".tmp." in name or ".lnk." in name or (
            ".data." in name and name not in live
        )
        if not stale:
            continue
        path = os.path.join(directory, name)
        try:
            age = now - os.lstat(path).st_mtime
        except OSError:
            continue
        if age > _STALE_SECONDS:
            if os.path.islink(path):
                try:
                    os.unlink(path)
                except OSError:
                    pass
            else:
                shutil.rmtree(path, ignore_errors=True)


def all_steps(directory: str) -> List[int]:
    if not os.path.isdir(directory):
        return []
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and ".tmp." not in name:
            try:
                steps.append(int(name[len("step_"):]))
            except ValueError:
                continue
    return sorted(steps)


def latest_step(directory: str) -> Optional[int]:
    steps = all_steps(directory)
    return steps[-1] if steps else None


def step_path(directory: str, step: int) -> str:
    """The on-disk directory of one step — the single definition of the
    layout every reader (restore, serving loader, online delta folds) uses."""
    return os.path.join(directory, f"step_{step:012d}")


def load_metadata(directory: str, step: int) -> Dict[str, Any]:
    base = os.path.realpath(step_path(directory, step))
    with open(os.path.join(base, "metadata.json")) as f:
        return json.load(f)


def load_raw(
    directory: str,
    step: Optional[int] = None,
    *,
    metadata: Optional[Dict[str, Any]] = None,
) -> Tuple[Dict[str, np.ndarray], Dict[str, Any]]:
    """Load a step's flat ``{key: array}`` payload + metadata, no structure
    imposed — the layer :func:`restore` (pytree shaping) and the online
    delta folds build on.  Pass ``metadata`` if already read to skip the
    re-read.

    Integrity: when the metadata carries ``payload_crc32`` (every save
    since the checksum landed) the npz bytes are verified against it
    before deserialization; any mismatch — and any unreadable/truncated
    payload, stamped or legacy — raises :class:`CorruptCheckpointError`.
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    # resolve the step symlink ONCE so metadata and arrays come from the
    # same payload even while a concurrent writer re-publishes the step
    base = os.path.realpath(step_path(directory, step))
    try:
        if metadata is None:
            with open(os.path.join(base, "metadata.json")) as f:
                metadata = json.load(f)
        npz_path = os.path.join(base, "arrays.npz")
        expected = metadata.get("payload_crc32")
        if expected is not None and _file_crc32(npz_path) != int(expected):
            raise CorruptCheckpointError(
                f"step {step}: arrays.npz fails its payload_crc32 check"
            )
        with np.load(npz_path) as data:
            arrays = {key: data[key] for key in data.files}
    except CorruptCheckpointError:
        raise
    except FileNotFoundError:
        raise
    except Exception as exc:
        # zipfile.BadZipFile, json decode errors, pickle/OS errors from a
        # torn write — one clean type the caller can fall back on
        raise CorruptCheckpointError(
            f"step {step}: unreadable payload ({type(exc).__name__}: {exc})"
        ) from exc
    return arrays, metadata


def restore(
    directory: str,
    tree_like: Pytree,
    *,
    step: Optional[int] = None,
) -> Tuple[Pytree, Dict[str, Any]]:
    """Restore into the structure of ``tree_like``.  Returns (tree, metadata).

    When ``step`` is None (restore-latest — the crash-recovery path), a
    corrupt newest checkpoint falls back to the next older step until one
    verifies; only when *every* retained step is corrupt does the
    :class:`CorruptCheckpointError` propagate.  An explicitly requested
    step never falls back — the caller asked for those exact bytes.
    """
    if step is None:
        steps = all_steps(directory)
        if not steps:
            raise FileNotFoundError(f"no checkpoints under {directory}")
        last_err: Optional[Exception] = None
        for candidate in reversed(steps):
            try:
                arrays, meta = load_raw(directory, candidate)
                break
            except CorruptCheckpointError as exc:
                last_err = exc
        else:
            raise CorruptCheckpointError(
                f"every retained checkpoint under {directory} is corrupt"
            ) from last_err
        return _shape_restore(tree_like, arrays), meta
    arrays, meta = load_raw(directory, step)
    return _shape_restore(tree_like, arrays), meta


def _shape_restore(tree_like: Pytree, arrays: Dict[str, np.ndarray]) -> Pytree:
    """Unflatten a raw payload into ``tree_like``'s structure, shape-checked."""
    keys = [k for k, _ in _flatten_with_paths(tree_like)]
    leaves, treedef = jax.tree_util.tree_flatten(tree_like)
    restored = []
    for key, like in zip(keys, leaves):
        if key not in arrays:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = arrays[key]
        if tuple(arr.shape) != tuple(np.shape(like)):
            raise ValueError(
                f"leaf {key!r}: checkpoint shape {arr.shape} != expected "
                f"{np.shape(like)}"
            )
        restored.append(arr)
    return treedef.unflatten(restored)


def elastic_load(
    directory: str,
    tree_like: Pytree,
    shard_fn: Callable[[Pytree], Pytree],
    *,
    step: Optional[int] = None,
) -> Tuple[Pytree, Dict[str, Any]]:
    """Restore then re-shard onto the *current* mesh (which may differ from
    the mesh the checkpoint was written under — elastic scaling)."""
    host_tree, meta = restore(directory, tree_like, step=step)
    return shard_fn(host_tree), meta


class AsyncCheckpointer:
    """Overlap serialization with training; at most one save in flight."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, tree: Pytree, metadata=None) -> None:
        self.wait()
        host_tree = jax.tree_util.tree_map(np.asarray, tree)  # snapshot now

        def work():
            try:
                save(self.directory, step, host_tree, metadata=metadata, keep=self.keep)
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err
