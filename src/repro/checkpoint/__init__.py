from repro.checkpoint.checkpoint import (  # noqa: F401
    AsyncCheckpointer,
    CorruptCheckpointError,
    all_steps,
    elastic_load,
    latest_step,
    load_metadata,
    load_raw,
    restore,
    save,
    step_path,
)
