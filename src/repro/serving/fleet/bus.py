"""Replication wire format + per-replica version gating.

One :class:`DeltaMessage` is one published snapshot on the wire: the same
touched-rows-only tree the delta checkpoints store (``kind=full`` carries
the whole params), flattened to ``{key: array}`` exactly as the
checkpointer's npz payload and losslessly compressed per array
(byte-shuffle + DEFLATE, ``distributed/compression.py``) — so a replica
that decompresses a message and a replica that replays the checkpoint
chain run the **same** applier (:func:`repro.online.publisher.apply_delta_tree`)
over the **same** bytes and end bitwise identical.

Delivery over processes is at-least-once and unordered in general; each
replica therefore fronts its engine with a :class:`VersionGate`:

* duplicate / stale (``version <= current``): acked, not applied —
  idempotent.
* in-order delta (``prev_version == current``): applied, then any buffered
  successors chain-apply.
* out-of-order delta (gap): buffered until the chain fills in, or until a
  ``kind=full`` message fast-forwards past it.
* ``kind=full``: always applicable — the heal path for any replica that
  fell behind (the publisher forces one when it sees a lagging ack).

:class:`EngineDeltaSink` is the gate bound to one
:class:`~repro.serving.engine.ServingEngine`: an accepted message folds
into host state and hot-swaps in via ``engine.swap`` (incremental,
touched-rows-only, unless the message says ``full_rebuild``).
"""
from __future__ import annotations

import dataclasses
import threading
import zlib
from typing import Callable, Dict, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.checkpoint import checkpoint as ckpt_lib
from repro.core import mf
from repro.distributed.compression import (
    CompressedArray,
    compress_array,
    decompress_array,
)
from repro.online import publisher as publisher_lib
from repro.online.updater import PublishSnapshot


@dataclasses.dataclass(frozen=True)
class DeltaMessage:
    """One versioned snapshot publication on the replication bus.

    ``tree`` is the flattened delta/full checkpoint payload
    (``{key: CompressedArray | np.ndarray}``); ``kind`` says how to apply
    it ("delta" scatters touched rows, "full" rebuilds params wholesale).
    ``full_rebuild`` is deliberately separate from ``kind``: a periodic
    retention-anchor full still describes a touched-rows-only *change*, so
    replicas apply it with an incremental layout patch — only a genuine
    recalibration/rearrange (``full_rebuild=True``) forces the engine to
    rebuild layouts and drop its hot-user cache.  Everything here pickles
    (numpy + bytes only), so a message crosses a ``multiprocessing`` pipe
    as-is.
    """

    version: int
    prev_version: int
    kind: str                       # "delta" | "full"
    full_rebuild: bool
    num_users: int
    num_items: int
    touched_users: np.ndarray
    touched_items: np.ndarray
    touched_implicit_items: np.ndarray
    tree: Dict[str, object]         # CompressedArray or raw np.ndarray
    events_seen: int = 0
    snapshot_id: int = 0
    # Eviction remap generation of the publishing updater.  A bump relative
    # to the receiving engine forces a full-layout swap there (rows moved
    # under the external ids); the remap table itself rides in ``tree``.
    remap_epoch: int = 0
    # CRC-32 over the payload (``payload_checksum``), stamped at publish.
    # Sinks verify before gating: a mismatch is NAK'd (version unchanged)
    # so the publisher's lag check forces a ``kind=full`` heal instead of
    # the replica applying corrupt factors.  ``-1`` = unstamped (legacy).
    payload_crc: int = -1

    @property
    def wire_bytes(self) -> int:
        """Payload bytes as shipped (compressed where compression won)."""
        return sum(
            v.nbytes if isinstance(v, CompressedArray) else int(np.asarray(v).nbytes)
            for v in self.tree.values()
        )

    @property
    def raw_bytes(self) -> int:
        """Payload bytes before compression (the apples-to-apples baseline
        for the compression ratio in ``BENCH_fleet.json``)."""
        return sum(
            v.raw_nbytes if isinstance(v, CompressedArray) else int(np.asarray(v).nbytes)
            for v in self.tree.values()
        )


def payload_checksum(tree: Dict[str, object]) -> int:
    """CRC-32 over a wire payload: sorted keys, then each value's exact
    bytes (compressed blob for :class:`CompressedArray`, dtype/shape-tagged
    raw bytes for plain arrays).  zlib's C CRC-32 — the strongest integrity
    check available without new dependencies; at delta-payload sizes it is
    a negligible fraction of the DEFLATE cost already paid per publish."""
    crc = 0
    for key in sorted(tree):
        val = tree[key]
        crc = zlib.crc32(key.encode(), crc)
        if isinstance(val, CompressedArray):
            crc = zlib.crc32(val.data, crc)
        else:
            arr = np.ascontiguousarray(np.asarray(val))
            crc = zlib.crc32(f"{arr.dtype}{arr.shape}".encode(), crc)
            crc = zlib.crc32(arr.tobytes(), crc)
    return crc


def verify_message(msg: DeltaMessage) -> bool:
    """True when the payload matches its stamped checksum (or the message
    predates stamping) — every sink's admission precondition."""
    if msg.payload_crc < 0:
        return True
    return payload_checksum(msg.tree) == msg.payload_crc


def _flat_payload(tree: dict, *, compress: bool) -> Dict[str, object]:
    """Flatten a delta/full checkpoint tree to the wire ``{key: payload}``
    dict (same keys as the checkpoint npz), compressing each array."""
    flat = ckpt_lib._flatten_with_paths(tree)
    if compress:
        return {key: compress_array(arr) for key, arr in flat}
    return {key: np.asarray(arr) for key, arr in flat}


def _unflatten_payload(payload: Dict[str, object]) -> Dict[str, np.ndarray]:
    return {
        key: decompress_array(v) if isinstance(v, CompressedArray) else np.asarray(v)
        for key, v in payload.items()
    }


def make_message(
    snap: PublishSnapshot,
    version: int,
    prev_version: int,
    *,
    full: bool,
    compress: bool = True,
) -> DeltaMessage:
    """Serialize one updater snapshot for the bus.

    The payload tree is exactly what the delta checkpoint for this publish
    stores (``publisher._delta_tree``), so wire version ``v`` and
    checkpoint step ``v`` describe identical bytes.
    """
    tree = publisher_lib._delta_tree(snap, full=full)
    payload = _flat_payload(tree, compress=compress)
    return DeltaMessage(
        version=int(version),
        prev_version=int(prev_version),
        kind="full" if full else "delta",
        full_rebuild=bool(snap.full_rebuild),
        num_users=int(snap.params.p.shape[0]),
        num_items=int(snap.params.q.shape[0]),
        touched_users=np.asarray(snap.touched_users, np.int64),
        touched_items=np.asarray(snap.touched_items, np.int64),
        touched_implicit_items=np.asarray(snap.touched_implicit_items, np.int64),
        tree=payload,
        events_seen=int(snap.events_seen),
        snapshot_id=int(snap.snapshot_id),
        remap_epoch=int(getattr(snap, "remap_epoch", 0)),
        payload_crc=payload_checksum(payload),
    )


def state_message(
    params: mf.MFParams,
    t_p,
    t_q,
    *,
    user_history: Optional[np.ndarray] = None,
    version: int = 0,
    compress: bool = True,
) -> DeltaMessage:
    """A ``kind=full`` message carrying an entire model state — the
    bootstrap payload a :class:`~repro.serving.fleet.replica.ProcessReplica`
    is spawned with, and the catch-up payload for tests."""
    tree = {"params": params, "t_p": np.float32(t_p), "t_q": np.float32(t_q)}
    if user_history is not None:
        tree["user_history"] = np.asarray(user_history)
    payload = _flat_payload(tree, compress=compress)
    return DeltaMessage(
        version=int(version),
        prev_version=int(version),
        kind="full",
        full_rebuild=True,
        num_users=int(params.p.shape[0]),
        num_items=int(params.q.shape[0]),
        touched_users=np.empty(0, np.int64),
        touched_items=np.empty(0, np.int64),
        touched_implicit_items=np.empty(0, np.int64),
        tree=payload,
        payload_crc=payload_checksum(payload),
    )


def state_from_message(msg: DeltaMessage):
    """Reconstruct ``(params, t_p, t_q, user_history)`` from a ``kind=full``
    message — the inverse of :func:`state_message`."""
    if msg.kind != "full":
        raise ValueError("state_from_message needs a kind=full message")
    return publisher_lib.apply_delta_tree(
        None, 0.0, 0.0, None, _unflatten_payload(msg.tree),
        kind="full", num_users=msg.num_users, num_items=msg.num_items,
    )


def apply_message(
    params: Optional[mf.MFParams],
    t_p,
    t_q,
    history: Optional[np.ndarray],
    msg: DeltaMessage,
    *,
    extras: Optional[dict] = None,
) -> Tuple[mf.MFParams, object, object, Optional[np.ndarray]]:
    """Decompress a message and fold it into ``(params, t_p, t_q,
    history)`` — the wire-side twin of the checkpoint fold in
    :func:`repro.online.publisher.fold_deltas` (both call
    ``apply_delta_tree``, so the results are bitwise identical).  When
    ``extras`` is given, remap metadata riding in the payload
    (``user_remap`` / ``remap_epoch``) is written into it."""
    return publisher_lib.apply_delta_tree(
        params, t_p, t_q, history, _unflatten_payload(msg.tree),
        kind=msg.kind, num_users=msg.num_users, num_items=msg.num_items,
        extras=extras,
    )


class VersionGate:
    """Idempotent, monotonic delta admission for one replica.

    ``offer`` returns the replica's version after considering the message —
    the ack the publisher tracks.  Application happens through ``apply_fn``
    (called with each admitted message, oldest first); the gate guarantees
    ``apply_fn`` sees every version at most once, in order, with no gaps.
    Thread-safe: the publisher's rolling fan-out and a catch-up path may
    race on one replica.
    """

    def __init__(self, apply_fn: Callable[[DeltaMessage], None], *, version: int = 0,
                 max_buffer: int = 64):
        self._apply = apply_fn
        self.version = int(version)
        self._pending: Dict[int, DeltaMessage] = {}  # keyed by prev_version
        self._max_buffer = max_buffer
        self._lock = threading.Lock()
        self.applied = 0
        self.duplicates = 0
        self.buffered = 0

    def offer(self, msg: DeltaMessage) -> int:
        """Consider one delivery; returns the current version (the ack)."""
        with self._lock:
            if msg.version <= self.version:
                self.duplicates += 1      # duplicate or stale: ack, drop
                return self.version
            if msg.kind == "full" or msg.prev_version == self.version:
                self._apply_chain(msg)
            else:
                # gap: hold until the missing predecessor (or a full) lands
                self._pending[msg.prev_version] = msg
                self.buffered += 1
                if len(self._pending) > self._max_buffer:
                    oldest = min(self._pending)
                    del self._pending[oldest]
            return self.version

    def _apply_chain(self, msg: DeltaMessage) -> None:
        self._apply(msg)
        self.version = msg.version
        self.applied += 1
        while self.version in self._pending:
            nxt = self._pending.pop(self.version)
            if nxt.version <= self.version:
                continue
            self._apply(nxt)
            self.version = nxt.version
            self.applied += 1
        # anything a full fast-forwarded past is now stale
        self._pending = {
            base: m for base, m in self._pending.items() if m.version > self.version
        }


class EngineDeltaSink:
    """A :class:`VersionGate` bound to one live engine.

    Admitted messages fold into host-side ``(params, t_p, t_q, history)``
    and hot-swap in via ``engine.swap`` — incremental (touched rows patch
    the layouts, hot-user cache keeps warm entries) unless the message
    carries ``full_rebuild``.  ``apply_update`` is the subscriber interface
    :meth:`repro.online.publisher.SnapshotPublisher.subscribe` expects.
    """

    def __init__(self, engine, *, user_history: Optional[np.ndarray] = None,
                 version: int = 0, replica_id: Optional[str] = None):
        self.engine = engine
        self.replica_id = replica_id
        self._history = None if user_history is None else np.asarray(user_history)
        self._gate = VersionGate(self._apply_one, version=version)
        # SLO serving-threshold pin: while set, replicated snapshots swap in
        # with THESE thresholds instead of the message's model thresholds —
        # otherwise every publish would silently revert the controller's
        # degradation.  Runtime state only; checkpoints keep model values.
        self._threshold_override: Optional[Tuple[float, float]] = None
        self.corrupt_dropped = 0

    @property
    def version(self) -> int:
        """Version of the snapshot the engine currently serves."""
        return self._gate.version

    @property
    def gate(self) -> VersionGate:
        """The underlying gate (stats: applied/duplicates/buffered)."""
        return self._gate

    def apply_update(self, msg: DeltaMessage) -> int:
        """Offer one delivery to the gate; returns the acked version.

        Corrupt payloads (CRC mismatch) are dropped *before* the gate —
        the stale ack this returns is the NAK: the publisher sees the
        replica lagging and forces a ``kind=full`` heal on the next
        publish, instead of the engine swapping in garbage factors."""
        if not verify_message(msg):
            self.corrupt_dropped += 1
            return self._gate.version
        return self._gate.offer(msg)

    def state_message(self, *, compress: bool = True) -> DeltaMessage:
        """Snapshot the engine's *served* state as a ``kind=full`` message —
        what a healthy peer hands the supervisor to heal a respawned
        replica.  Carries the engine's live thresholds (including any SLO
        pin), which is exactly what the healed replica should serve."""
        return state_message(
            self.engine.params, self.engine.t_p, self.engine.t_q,
            user_history=self.engine.user_history,
            version=self._gate.version, compress=compress,
        )

    def set_thresholds(self, t_p, t_q) -> int:
        """Pin SLO serving thresholds: swap them into the engine now and
        keep applying them over the model thresholds of every later
        replicated snapshot (:class:`SLOController` decisions replicate
        like any rolling update).  Pass ``None, None`` to unpin.  Returns
        the replication version (unchanged — thresholds are orthogonal to
        the snapshot chain)."""
        if t_p is None and t_q is None:
            self._threshold_override = None
        else:
            self._threshold_override = (float(t_p), float(t_q))
            self.engine.swap(
                self.engine.params,
                jnp.float32(t_p), jnp.float32(t_q),
                user_history=self.engine.user_history,
            )
        return self._gate.version

    def _apply_one(self, msg: DeltaMessage) -> None:
        # a full that fast-forwards over a version gap replaced MORE than
        # this publish's touched rows relative to what this replica serves
        # (missed deltas, or an arbitrary cold state) — the touched-rows
        # layout patch is only sound for the sequential next version
        sequential = msg.prev_version == self._gate.version
        extras: Dict[str, object] = {}
        params, t_p, t_q, history = apply_message(
            self.engine.params, self.engine.t_p, self.engine.t_q,
            self._history, msg, extras=extras,
        )
        self._history = history
        if self._threshold_override is not None:
            # serve with the pinned SLO thresholds, not the model's — the
            # folded (model) values stay authoritative on the wire/disk
            t_p, t_q = (jnp.float32(v) for v in self._threshold_override)
        # remap metadata rides in the payload when the publisher evicts;
        # a remap-epoch bump makes engine.swap drop touched-rows patching
        # itself (rows moved under the external ids)
        remap_kwargs = {}
        if "user_remap" in extras:
            remap_kwargs = {
                "user_remap": extras["user_remap"],
                "remap_epoch": extras["remap_epoch"],
            }
        if msg.full_rebuild or (msg.kind == "full" and not sequential):
            self.engine.swap(params, t_p, t_q, user_history=history,
                             **remap_kwargs)
        else:
            self.engine.swap(
                params, t_p, t_q,
                touched_users=msg.touched_users,
                touched_items=msg.touched_items,
                touched_implicit_items=msg.touched_implicit_items,
                user_history=history,
                **remap_kwargs,
            )
