"""Multi-replica serving fleet: replicated engines behind a cache-aware
router, refreshed by compressed delta replication, supervised for failure.

Layers (each its own module):

* :mod:`~repro.serving.fleet.bus` — the wire format
  (:class:`~repro.serving.fleet.bus.DeltaMessage`: the delta-checkpoint
  tree, flattened, losslessly compressed, CRC-stamped) and the per-replica
  :class:`~repro.serving.fleet.bus.VersionGate` (idempotent, monotonic,
  out-of-order-safe application; corrupt payloads NAK'd before the gate).
* :mod:`~repro.serving.fleet.replica` —
  :class:`~repro.serving.fleet.replica.LocalReplica` (in-process) and
  :class:`~repro.serving.fleet.replica.ProcessReplica`
  (``multiprocessing``-spawned), one engine + queue + gate each; death
  surfaces as :class:`~repro.serving.fleet.replica.ReplicaDiedError`,
  never a stranded future.
* :mod:`~repro.serving.fleet.router` —
  :class:`~repro.serving.fleet.router.Router` (queue-depth load balancing,
  hot-user affinity, priority classes, rolling refresh, health-aware
  failover) and the :class:`~repro.serving.fleet.router.ServingFleet`
  facade.
* :mod:`~repro.serving.fleet.supervisor` —
  :class:`~repro.serving.fleet.supervisor.FleetSupervisor`: heartbeat
  probes, the replica state machine, auto-respawn, and
  convergence-gated readmission.

Import layering: this package may import :mod:`repro.online` (the
publisher owns the delta format); nothing in :mod:`repro.online` or the
core :mod:`repro.serving` modules imports the fleet.
"""
from repro.serving.fleet.bus import (
    DeltaMessage,
    EngineDeltaSink,
    VersionGate,
    apply_message,
    make_message,
    payload_checksum,
    state_from_message,
    state_message,
    verify_message,
)
from repro.serving.fleet.replica import (
    LocalReplica,
    ProcessReplica,
    ReplicaDiedError,
)
from repro.serving.fleet.router import (
    NoHealthyReplicaError,
    Router,
    ServingFleet,
)
from repro.serving.fleet.supervisor import (
    FleetSupervisor,
    Incident,
    ReplicaState,
)

__all__ = [
    "DeltaMessage",
    "EngineDeltaSink",
    "VersionGate",
    "apply_message",
    "make_message",
    "payload_checksum",
    "state_from_message",
    "state_message",
    "verify_message",
    "LocalReplica",
    "ProcessReplica",
    "ReplicaDiedError",
    "NoHealthyReplicaError",
    "Router",
    "ServingFleet",
    "FleetSupervisor",
    "Incident",
    "ReplicaState",
]
