"""Multi-replica serving fleet: replicated engines behind a cache-aware
router, refreshed by compressed delta replication.

Layers (each its own module):

* :mod:`~repro.serving.fleet.bus` — the wire format
  (:class:`~repro.serving.fleet.bus.DeltaMessage`: the delta-checkpoint
  tree, flattened and losslessly compressed) and the per-replica
  :class:`~repro.serving.fleet.bus.VersionGate` (idempotent, monotonic,
  out-of-order-safe application).
* :mod:`~repro.serving.fleet.replica` —
  :class:`~repro.serving.fleet.replica.LocalReplica` (in-process) and
  :class:`~repro.serving.fleet.replica.ProcessReplica`
  (``multiprocessing``-spawned), one engine + queue + gate each.
* :mod:`~repro.serving.fleet.router` —
  :class:`~repro.serving.fleet.router.Router` (queue-depth load balancing,
  hot-user affinity, priority classes, rolling refresh) and the
  :class:`~repro.serving.fleet.router.ServingFleet` facade.

Import layering: this package may import :mod:`repro.online` (the
publisher owns the delta format); nothing in :mod:`repro.online` or the
core :mod:`repro.serving` modules imports the fleet.
"""
from repro.serving.fleet.bus import (
    DeltaMessage,
    EngineDeltaSink,
    VersionGate,
    apply_message,
    make_message,
    state_from_message,
    state_message,
)
from repro.serving.fleet.replica import LocalReplica, ProcessReplica
from repro.serving.fleet.router import Router, ServingFleet

__all__ = [
    "DeltaMessage",
    "EngineDeltaSink",
    "VersionGate",
    "apply_message",
    "make_message",
    "state_from_message",
    "state_message",
    "LocalReplica",
    "ProcessReplica",
    "Router",
    "ServingFleet",
]
