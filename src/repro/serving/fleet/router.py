"""Cache-aware request routing over a fleet of replicas.

The router's job is to keep each replica's hot-user LRU warm: a user's
cached aggregation vector only pays off if their next request lands on the
**same** replica.  Policy per request:

* **affinity** (default): pin each user to a replica in an LRU map on
  first sight (pinned to the then-least-loaded); route repeat users to
  their pin while its queue depth is within ``overload_slack`` of the
  least-loaded replica — beyond that, spill to least-loaded and re-pin
  (a thrashing pin is worse than one cold miss).
* **deadline/priority class**: requests with ``priority > 0`` are
  background class — routed purely by least depth and never recorded in
  the affinity map, so bulk/backfill traffic can neither evict
  interactive pins nor pollute replica caches with one-shot users.
* ``policy="least"`` / ``policy="random"`` ignore affinity entirely —
  the baselines ``benchmarks/bench_fleet.py`` compares against.

:class:`ServingFleet` is the one-call topology: N replicas (in-process or
spawned) + a router, exposing ``submit``/``apply_update`` so it can be a
drop-in subscriber for
:meth:`repro.online.publisher.SnapshotPublisher.subscribe` — the publisher
ships each version once and the router applies it rollingly, one replica
at a time, so the fleet never has fewer than N-1 replicas accepting
requests mid-refresh.
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import Future
from typing import Any, Dict, List, Optional

import numpy as np

from repro.testing import faults

from repro.serving.batching import LRUCache
from repro.serving.fleet import bus
from repro.serving.fleet.replica import (
    LocalReplica,
    ProcessReplica,
    ReplicaDiedError,
)


class NoHealthyReplicaError(RuntimeError):
    """Every replica is marked unhealthy — nothing can take the request."""


class Router:
    """Load-balance requests across replicas, cache-affine for hot users."""

    def __init__(
        self,
        replicas: List,
        *,
        policy: str = "affinity",
        affinity_capacity: int = 65536,
        overload_slack: int = 8,
        seed: int = 0,
    ):
        if not replicas:
            raise ValueError("router needs at least one replica")
        if policy not in ("affinity", "least", "random"):
            raise ValueError(f"unknown routing policy {policy!r}")
        self.replicas = list(replicas)
        self.policy = policy
        self.overload_slack = overload_slack
        self._affinity = LRUCache(affinity_capacity)
        self._rng = np.random.default_rng(seed)
        self._lock = threading.Lock()
        self._healthy = [True] * len(self.replicas)
        self.routed = 0
        self.affinity_hits = 0   # repeat user sent to their pinned replica
        self.affinity_cold = 0   # first-seen user (new pin)
        self.affinity_spills = 0  # pin overloaded: spilled + re-pinned
        self.affinity_repins = 0  # pin pointed at a dead replica: re-pinned
        self.failovers = 0       # submits retried onto another replica

    # -- health --------------------------------------------------------------
    def mark_unhealthy(self, idx: int) -> None:
        """Take replica ``idx`` out of routing (dead or suspected dead).
        Its affinity pins re-pin lazily on the pinned users' next requests —
        no stop-the-world walk over the LRU."""
        with self._lock:
            self._healthy[idx] = False

    def mark_healthy(self, idx: int) -> None:
        """Readmit replica ``idx`` to routing (after supervised respawn +
        convergence — see ``fleet/supervisor.py``)."""
        with self._lock:
            self._healthy[idx] = True

    def is_healthy(self, idx: int) -> bool:
        """Whether replica ``idx`` currently takes traffic."""
        with self._lock:
            return self._healthy[idx]

    def replace_replica(self, idx: int, replica) -> None:
        """Swap a respawned replica into slot ``idx`` and readmit it.
        Affinity pins keyed by slot index become valid again unchanged —
        the replacement starts cache-cold but converged."""
        with self._lock:
            self.replicas[idx] = replica
            self._healthy[idx] = True

    def _healthy_indices(self) -> List[int]:
        return [i for i, ok in enumerate(self._healthy) if ok]

    def pick(self, user_id: int, priority: int = 0) -> int:
        """Choose a replica index for one request (does not submit).
        Only healthy replicas are considered; a user pinned to a dead
        replica is re-pinned to the least-loaded healthy one."""
        with self._lock:
            self.routed += 1
            live = self._healthy_indices()
            if not live:
                raise NoHealthyReplicaError("no healthy replica to route to")
            if self.policy == "random":
                # random ignores load entirely — polling depth() on every
                # replica under the lock (the old behaviour) was pure
                # per-request overhead and needless lock contention
                return live[int(self._rng.integers(len(live)))]
            depths = {i: self.replicas[i].depth() for i in live}
            least = min(live, key=depths.__getitem__)
            if self.policy == "least" or priority > 0:
                # background class: depth only, never pinned — bulk traffic
                # must not evict interactive users' affinity entries
                return least
            pinned = self._affinity.get(user_id)
            if pinned is not None:
                if pinned not in depths:
                    self.affinity_repins += 1  # pinned replica is dead
                elif depths[pinned] <= depths[least] + self.overload_slack:
                    self.affinity_hits += 1
                    return pinned
                else:
                    self.affinity_spills += 1
            else:
                self.affinity_cold += 1
            self._affinity.put(user_id, least)
            return least

    def submit(self, user_id: int, topk: int = 10, *, timeout=None,
               priority: int = 0) -> Future:
        """Route one request and enqueue it on the chosen replica.

        Failover: if the chosen replica is dead at submit time — or dies
        mid-flight, failing the pending future with ``ReplicaDiedError`` —
        the request is retried on another healthy replica (the dead one is
        marked unhealthy on the spot).  The caller's future only fails
        when every replica has been exhausted, so a single replica death
        never strands or errors a request."""
        outer: Future = Future()
        self._submit_attempt(outer, int(user_id), topk, timeout, priority,
                             retries_left=len(self.replicas))
        return outer

    def _submit_attempt(self, outer: Future, user_id: int, topk, timeout,
                        priority: int, retries_left: int) -> None:
        try:
            idx = self.pick(user_id, priority)
        except NoHealthyReplicaError as exc:
            _resolve(outer, error=exc)
            return
        try:
            inner = self.replicas[idx].submit(
                user_id, topk, timeout=timeout, priority=priority
            )
        except ReplicaDiedError as exc:
            self.mark_unhealthy(idx)
            if retries_left > 0:
                self.failovers += 1
                self._submit_attempt(outer, user_id, topk, timeout, priority,
                                     retries_left - 1)
            else:
                _resolve(outer, error=exc)
            return

        def relay(done: Future, idx=idx) -> None:
            exc = done.exception()
            if exc is None:
                _resolve(outer, result=done.result())
            elif isinstance(exc, ReplicaDiedError) and retries_left > 0:
                # died mid-flight: the read-loop failed the inner future;
                # same request, different replica, caller none the wiser
                self.mark_unhealthy(idx)
                self.failovers += 1
                self._submit_attempt(outer, user_id, topk, timeout, priority,
                                     retries_left - 1)
            else:
                _resolve(outer, error=exc)

        inner.add_done_callback(relay)

    @property
    def version(self) -> int:
        """Lowest healthy-replica version — what the traffic-taking fleet
        is guaranteed to serve at least (the publisher's lag view).  Dead
        replicas don't count: their stale version is the supervisor's
        problem, not the publisher's."""
        with self._lock:
            live = [self.replicas[i] for i in self._healthy_indices()]
        reps = live or self.replicas
        return min(r.version for r in reps)

    def apply_update(self, msg: bus.DeltaMessage) -> Dict[str, int]:
        """Rolling refresh: ship ``msg`` to one replica at a time, in
        order, waiting for each ack before the next — at most one replica
        is mid-swap at any instant, the rest keep serving.  Returns
        ``{replica_id: acked_version}`` (the dict-ack form the publisher's
        subscriber bookkeeping flattens).

        Unhealthy replicas are skipped (no ack — the publisher sees them
        lag and will force a full heal when they return); a replica dying
        mid-rollout is marked unhealthy and skipped the same way instead
        of failing the whole publish."""
        acks: Dict[str, int] = {}
        for idx, rep in enumerate(self.replicas):
            if not self.is_healthy(idx):
                continue
            delivery, extra = msg, 0
            if faults._PLAN is not None:
                # the chaos seam models the wire: this one delivery can be
                # dropped, duplicated, corrupted, or delayed — the gate +
                # CRC machinery downstream must absorb all of it
                drop = False
                for act in faults.fire("bus.deliver", rep.replica_id):
                    if act.op == "drop":
                        drop = True
                    elif act.op == "dup":
                        extra += 1
                    elif act.op == "corrupt":
                        delivery = faults.corrupt_message(delivery)
                    elif act.op == "delay":
                        time.sleep(act.arg)
                if drop:
                    continue
            try:
                acks[rep.replica_id] = rep.apply_update(delivery)
                for _ in range(extra):
                    acks[rep.replica_id] = rep.apply_update(delivery)
            except (ReplicaDiedError, TimeoutError, BrokenPipeError, OSError):
                self.mark_unhealthy(idx)
        return acks

    def apply_thresholds(self, t_p, t_q) -> Dict[str, int]:
        """Rolling serving-threshold rollout — the SLO controller's fleet
        fan-out.  Same one-replica-at-a-time discipline as
        :meth:`apply_update` (the fleet never dips below N-1 live
        replicas mid-swap); each replica pins the thresholds in its delta
        sink so later replicated snapshots keep them.  Returns
        ``{replica_id: replication_version}`` acks.  Dead replicas are
        skipped/marked like :meth:`apply_update`."""
        acks: Dict[str, int] = {}
        for idx, rep in enumerate(self.replicas):
            if not self.is_healthy(idx):
                continue
            try:
                acks[rep.replica_id] = rep.set_thresholds(t_p, t_q)
            except (ReplicaDiedError, TimeoutError, BrokenPipeError, OSError):
                self.mark_unhealthy(idx)
        return acks

    def stats(self) -> Dict[str, Any]:
        """Routing counters + per-replica stats (pipe round-trips for
        process replicas — don't call on the hot path)."""
        per_replica = []
        for idx, rep in enumerate(self.replicas):
            if not self.is_healthy(idx):
                per_replica.append(
                    {"replica_id": rep.replica_id, "healthy": False}
                )
                continue
            try:
                per_replica.append({**rep.stats(), "healthy": True})
            except (ReplicaDiedError, TimeoutError, BrokenPipeError, OSError):
                per_replica.append(
                    {"replica_id": rep.replica_id, "healthy": False}
                )
        return {
            "policy": self.policy,
            "routed": self.routed,
            "affinity_hits": self.affinity_hits,
            "affinity_cold": self.affinity_cold,
            "affinity_spills": self.affinity_spills,
            "affinity_repins": self.affinity_repins,
            "failovers": self.failovers,
            "replicas": per_replica,
        }

    def close(self) -> None:
        """Drain and close every replica (each completes its in-flight
        requests — the engine/queue graceful-drain contract).  Dead
        replicas still get a close (reaps the child process)."""
        for rep in self.replicas:
            try:
                rep.close()
            except (ReplicaDiedError, TimeoutError, BrokenPipeError, OSError):
                pass


def _resolve(fut: Future, *, result=None, error: Optional[Exception] = None) -> None:
    """Resolve a router-owned future, tolerating caller-side cancellation."""
    try:
        if error is not None:
            fut.set_exception(error)
        else:
            fut.set_result(result)
    except Exception:
        pass  # cancelled or already resolved — the caller moved on


class ServingFleet:
    """N replicas + a router, built from one model state.

    ``backend="local"`` runs every replica in-process (CI, benches);
    ``backend="process"`` spawns each as a ``multiprocessing`` child
    bootstrapped from a ``kind=full`` bus message of the given state.
    The fleet object quacks like a replica (``submit`` / ``apply_update``
    / ``version`` / ``stats`` / ``close``), so
    ``publisher.subscribe(fleet.router)`` wires live replication and
    ``fleet.submit(user)`` serves — see the router quickstart in README.
    """

    def __init__(
        self,
        params,
        t_p=0.0,
        t_q=0.0,
        *,
        replicas: int = 2,
        backend: str = "local",
        user_history: Optional[np.ndarray] = None,
        base_version: int = 0,
        engine_kwargs: Optional[dict] = None,
        queue_kwargs: Optional[dict] = None,
        router_kwargs: Optional[dict] = None,
    ):
        if replicas < 1:
            raise ValueError("fleet needs at least one replica")
        if backend not in ("local", "process"):
            raise ValueError(f"unknown fleet backend {backend!r}")
        self.backend = backend
        members: List = []
        if backend == "process":
            boot = bus.state_message(
                params, t_p, t_q, user_history=user_history,
                version=base_version,
            )
            for i in range(replicas):
                members.append(ProcessReplica(
                    f"r{i}", init_msg=boot,
                    engine_kwargs=engine_kwargs, queue_kwargs=queue_kwargs,
                ))
        else:
            for i in range(replicas):
                members.append(LocalReplica(
                    f"r{i}", params, t_p, t_q,
                    user_history=user_history, base_version=base_version,
                    engine_kwargs=engine_kwargs, queue_kwargs=queue_kwargs,
                ))
        self.router = Router(members, **(router_kwargs or {}))

    @property
    def replicas(self) -> List:
        """The replica handles, in rolling order."""
        return self.router.replicas

    @property
    def version(self) -> int:
        """Lowest replica version (see :attr:`Router.version`)."""
        return self.router.version

    @property
    def num_users(self) -> int:
        """User-table rows replicas currently serve (min across fleet)."""
        return min(r.num_users for r in self.replicas)

    def submit(self, user_id: int, topk: int = 10, *, timeout=None,
               priority: int = 0) -> Future:
        """Route + enqueue one request (see :meth:`Router.submit`)."""
        return self.router.submit(user_id, topk, timeout=timeout,
                                  priority=priority)

    def apply_update(self, msg: bus.DeltaMessage) -> Dict[str, int]:
        """Rolling refresh across the fleet (see :meth:`Router.apply_update`)."""
        return self.router.apply_update(msg)

    def supervise(self, **kwargs):
        """Attach and start a :class:`~repro.serving.fleet.supervisor.
        FleetSupervisor` over this fleet's router (probe → failover →
        respawn → readmit).  Returns the started supervisor; stop it
        before :meth:`close`."""
        from repro.serving.fleet.supervisor import FleetSupervisor

        sup = FleetSupervisor(self.router, **kwargs)
        sup.start()
        return sup

    def stats(self) -> Dict[str, Any]:
        """Router + per-replica counters (see :meth:`Router.stats`)."""
        return self.router.stats()

    def close(self) -> None:
        """Drain and shut down every replica."""
        self.router.close()
