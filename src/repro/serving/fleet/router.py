"""Cache-aware request routing over a fleet of replicas.

The router's job is to keep each replica's hot-user LRU warm: a user's
cached aggregation vector only pays off if their next request lands on the
**same** replica.  Policy per request:

* **affinity** (default): pin each user to a replica in an LRU map on
  first sight (pinned to the then-least-loaded); route repeat users to
  their pin while its queue depth is within ``overload_slack`` of the
  least-loaded replica — beyond that, spill to least-loaded and re-pin
  (a thrashing pin is worse than one cold miss).
* **deadline/priority class**: requests with ``priority > 0`` are
  background class — routed purely by least depth and never recorded in
  the affinity map, so bulk/backfill traffic can neither evict
  interactive pins nor pollute replica caches with one-shot users.
* ``policy="least"`` / ``policy="random"`` ignore affinity entirely —
  the baselines ``benchmarks/bench_fleet.py`` compares against.

:class:`ServingFleet` is the one-call topology: N replicas (in-process or
spawned) + a router, exposing ``submit``/``apply_update`` so it can be a
drop-in subscriber for
:meth:`repro.online.publisher.SnapshotPublisher.subscribe` — the publisher
ships each version once and the router applies it rollingly, one replica
at a time, so the fleet never has fewer than N-1 replicas accepting
requests mid-refresh.
"""
from __future__ import annotations

import threading
from concurrent.futures import Future
from typing import Any, Dict, List, Optional

import numpy as np

from repro.serving.batching import LRUCache
from repro.serving.fleet import bus
from repro.serving.fleet.replica import LocalReplica, ProcessReplica


class Router:
    """Load-balance requests across replicas, cache-affine for hot users."""

    def __init__(
        self,
        replicas: List,
        *,
        policy: str = "affinity",
        affinity_capacity: int = 65536,
        overload_slack: int = 8,
        seed: int = 0,
    ):
        if not replicas:
            raise ValueError("router needs at least one replica")
        if policy not in ("affinity", "least", "random"):
            raise ValueError(f"unknown routing policy {policy!r}")
        self.replicas = list(replicas)
        self.policy = policy
        self.overload_slack = overload_slack
        self._affinity = LRUCache(affinity_capacity)
        self._rng = np.random.default_rng(seed)
        self._lock = threading.Lock()
        self.routed = 0
        self.affinity_hits = 0   # repeat user sent to their pinned replica
        self.affinity_cold = 0   # first-seen user (new pin)
        self.affinity_spills = 0  # pin overloaded: spilled + re-pinned

    def pick(self, user_id: int, priority: int = 0) -> int:
        """Choose a replica index for one request (does not submit)."""
        with self._lock:
            self.routed += 1
            if self.policy == "random":
                # random ignores load entirely — polling depth() on every
                # replica under the lock (the old behaviour) was pure
                # per-request overhead and needless lock contention
                return int(self._rng.integers(len(self.replicas)))
            depths = [r.depth() for r in self.replicas]
            least = int(np.argmin(depths))
            if self.policy == "least" or priority > 0:
                # background class: depth only, never pinned — bulk traffic
                # must not evict interactive users' affinity entries
                return least
            pinned = self._affinity.get(user_id)
            if pinned is not None:
                if depths[pinned] <= depths[least] + self.overload_slack:
                    self.affinity_hits += 1
                    return pinned
                self.affinity_spills += 1
            else:
                self.affinity_cold += 1
            self._affinity.put(user_id, least)
            return least

    def submit(self, user_id: int, topk: int = 10, *, timeout=None,
               priority: int = 0) -> Future:
        """Route one request and enqueue it on the chosen replica."""
        idx = self.pick(int(user_id), priority)
        return self.replicas[idx].submit(
            user_id, topk, timeout=timeout, priority=priority
        )

    @property
    def version(self) -> int:
        """Lowest replica version — what the whole fleet is guaranteed to
        serve at least (the publisher's lag view)."""
        return min(r.version for r in self.replicas)

    def apply_update(self, msg: bus.DeltaMessage) -> Dict[str, int]:
        """Rolling refresh: ship ``msg`` to one replica at a time, in
        order, waiting for each ack before the next — at most one replica
        is mid-swap at any instant, the rest keep serving.  Returns
        ``{replica_id: acked_version}`` (the dict-ack form the publisher's
        subscriber bookkeeping flattens)."""
        acks: Dict[str, int] = {}
        for rep in self.replicas:
            acks[rep.replica_id] = rep.apply_update(msg)
        return acks

    def apply_thresholds(self, t_p, t_q) -> Dict[str, int]:
        """Rolling serving-threshold rollout — the SLO controller's fleet
        fan-out.  Same one-replica-at-a-time discipline as
        :meth:`apply_update` (the fleet never dips below N-1 live
        replicas mid-swap); each replica pins the thresholds in its delta
        sink so later replicated snapshots keep them.  Returns
        ``{replica_id: replication_version}`` acks."""
        acks: Dict[str, int] = {}
        for rep in self.replicas:
            acks[rep.replica_id] = rep.set_thresholds(t_p, t_q)
        return acks

    def stats(self) -> Dict[str, Any]:
        """Routing counters + per-replica stats (pipe round-trips for
        process replicas — don't call on the hot path)."""
        return {
            "policy": self.policy,
            "routed": self.routed,
            "affinity_hits": self.affinity_hits,
            "affinity_cold": self.affinity_cold,
            "affinity_spills": self.affinity_spills,
            "replicas": [r.stats() for r in self.replicas],
        }

    def close(self) -> None:
        """Drain and close every replica (each completes its in-flight
        requests — the engine/queue graceful-drain contract)."""
        for rep in self.replicas:
            rep.close()


class ServingFleet:
    """N replicas + a router, built from one model state.

    ``backend="local"`` runs every replica in-process (CI, benches);
    ``backend="process"`` spawns each as a ``multiprocessing`` child
    bootstrapped from a ``kind=full`` bus message of the given state.
    The fleet object quacks like a replica (``submit`` / ``apply_update``
    / ``version`` / ``stats`` / ``close``), so
    ``publisher.subscribe(fleet.router)`` wires live replication and
    ``fleet.submit(user)`` serves — see the router quickstart in README.
    """

    def __init__(
        self,
        params,
        t_p=0.0,
        t_q=0.0,
        *,
        replicas: int = 2,
        backend: str = "local",
        user_history: Optional[np.ndarray] = None,
        base_version: int = 0,
        engine_kwargs: Optional[dict] = None,
        queue_kwargs: Optional[dict] = None,
        router_kwargs: Optional[dict] = None,
    ):
        if replicas < 1:
            raise ValueError("fleet needs at least one replica")
        if backend not in ("local", "process"):
            raise ValueError(f"unknown fleet backend {backend!r}")
        self.backend = backend
        members: List = []
        if backend == "process":
            boot = bus.state_message(
                params, t_p, t_q, user_history=user_history,
                version=base_version,
            )
            for i in range(replicas):
                members.append(ProcessReplica(
                    f"r{i}", init_msg=boot,
                    engine_kwargs=engine_kwargs, queue_kwargs=queue_kwargs,
                ))
        else:
            for i in range(replicas):
                members.append(LocalReplica(
                    f"r{i}", params, t_p, t_q,
                    user_history=user_history, base_version=base_version,
                    engine_kwargs=engine_kwargs, queue_kwargs=queue_kwargs,
                ))
        self.router = Router(members, **(router_kwargs or {}))

    @property
    def replicas(self) -> List:
        """The replica handles, in rolling order."""
        return self.router.replicas

    @property
    def version(self) -> int:
        """Lowest replica version (see :attr:`Router.version`)."""
        return self.router.version

    @property
    def num_users(self) -> int:
        """User-table rows replicas currently serve (min across fleet)."""
        return min(r.num_users for r in self.replicas)

    def submit(self, user_id: int, topk: int = 10, *, timeout=None,
               priority: int = 0) -> Future:
        """Route + enqueue one request (see :meth:`Router.submit`)."""
        return self.router.submit(user_id, topk, timeout=timeout,
                                  priority=priority)

    def apply_update(self, msg: bus.DeltaMessage) -> Dict[str, int]:
        """Rolling refresh across the fleet (see :meth:`Router.apply_update`)."""
        return self.router.apply_update(msg)

    def stats(self) -> Dict[str, Any]:
        """Router + per-replica counters (see :meth:`Router.stats`)."""
        return self.router.stats()

    def close(self) -> None:
        """Drain and shut down every replica."""
        self.router.close()
