"""Fleet replicas: one serving engine + queue + version gate per replica.

Two implementations of the same contract (``replica_id``, ``version``,
``submit() -> Future``, ``apply_update(msg) -> ack``, ``depth()``,
``stats()``, ``close()``):

* :class:`LocalReplica` — everything in this process.  What CI exercises
  for the replication/ routing logic, what the benches use to measure
  routing policies without IPC noise, and the building block the process
  replica runs inside its child.

* :class:`ProcessReplica` — a ``multiprocessing`` (spawn) child running a
  ``LocalReplica``, talked to over a duplex pipe.  Spawn (not fork) so the
  child re-imports cleanly next to JAX's threadpools — the only mode safe
  on CPU CI.  The child bootstraps either from a ``kind=full``
  :class:`~repro.serving.fleet.bus.DeltaMessage` or from a checkpoint
  directory (training base + ``fold_deltas`` over the online delta chain —
  the late-join path, which leaves the replica at the chain's last version
  so the live bus can resume with deltas).

Requests return ``concurrent.futures.Future`` either way; for process
replicas a reader thread resolves them from pipe replies, so the router
never blocks on a slow replica.
"""
from __future__ import annotations

import multiprocessing as mp
import threading
from concurrent.futures import Future
from typing import Any, Dict, Optional, Tuple

import numpy as np

from repro.serving.fleet import bus
from repro.testing import faults


class ReplicaDiedError(RuntimeError):
    """The replica behind a request/control call is dead.

    Raised fast by ``submit`` once death is known (no writing into a broken
    pipe, no waiting out a timeout), and set on every future that was still
    pending when the pipe broke — the router catches exactly this type to
    fail over to a healthy replica, and the supervisor to trigger respawn.
    """


class LocalReplica:
    """One in-process replica: engine + started request queue + gated sink."""

    def __init__(
        self,
        replica_id: str,
        params,
        t_p=0.0,
        t_q=0.0,
        *,
        user_history: Optional[np.ndarray] = None,
        base_version: int = 0,
        engine_kwargs: Optional[dict] = None,
        queue_kwargs: Optional[dict] = None,
    ):
        from repro.serving.engine import ServingEngine

        self.replica_id = replica_id
        self.engine = ServingEngine(
            params, t_p, t_q, user_history=user_history, **(engine_kwargs or {})
        )
        self.queue = self.engine.start(**(queue_kwargs or {}))
        self._sink = bus.EngineDeltaSink(
            self.engine,
            user_history=user_history,
            version=base_version,
            replica_id=replica_id,
        )
        self._dead = False

    @property
    def version(self) -> int:
        """Replication version this replica currently serves."""
        return self._sink.version

    @property
    def num_users(self) -> int:
        """User-table rows of the served snapshot."""
        return self.engine.num_users

    @property
    def alive(self) -> bool:
        """Liveness flag — the supervisor's health probe for in-process
        replicas (a thread can't vanish the way a child process can, so a
        local replica only dies via :meth:`kill`)."""
        return not self._dead

    def ping(self, timeout: float = 5.0) -> bool:
        """Heartbeat probe: True iff the replica would serve a request."""
        return not self._dead

    def kill(self) -> None:
        """Simulated crash (chaos harness): every queued request fails with
        :class:`ReplicaDiedError` immediately, later submits raise fast —
        the in-process twin of ``ProcessReplica``'s child dying."""
        if self._dead:
            return
        self._dead = True
        self.queue.abort(
            ReplicaDiedError(f"replica {self.replica_id} died (injected)")
        )

    def submit(self, user_id: int, topk: int = 10, *, timeout=None,
               priority: int = 0) -> Future:
        """Enqueue one request on this replica's queue.

        Raises :class:`ReplicaDiedError` immediately once the replica is
        dead — callers (the router) fail over instead of queueing into a
        corpse."""
        if faults._PLAN is not None:
            for act in faults.fire("replica.submit", self.replica_id):
                if act.op == "kill":
                    self.kill()
        if self._dead:
            raise ReplicaDiedError(f"replica {self.replica_id} is dead")
        return self.engine.submit(user_id, topk, timeout=timeout,
                                  priority=priority)

    def apply_update(self, msg: bus.DeltaMessage) -> int:
        """Offer a bus message to the version gate; returns the ack.

        The hot swap happens under live traffic: requests in flight finish
        on the old snapshot, the queue never pauses."""
        if self._dead:
            raise ReplicaDiedError(f"replica {self.replica_id} is dead")
        return self._sink.apply_update(msg)

    def state_message(self) -> bus.DeltaMessage:
        """Full served state as a ``kind=full`` message — what the
        supervisor pulls from a healthy peer to heal a respawn."""
        return self._sink.state_message()

    def set_thresholds(self, t_p, t_q) -> int:
        """Pin SLO serving thresholds on this replica (see
        :meth:`~repro.serving.fleet.bus.EngineDeltaSink.set_thresholds`)."""
        return self._sink.set_thresholds(t_p, t_q)

    def depth(self) -> int:
        """Queued + in-scoring requests — the router's load signal."""
        return self.engine.queue_depth

    def stats(self) -> Dict[str, Any]:
        """Counters for benches/CI: version, load, cache hit rate, queue."""
        cache = self.engine.vector_cache
        gate = self._sink.gate
        return {
            "replica_id": self.replica_id,
            "version": self.version,
            "depth": self.depth(),
            "num_users": self.engine.num_users,
            "n_items": self.engine.n_items,
            "requests_served": self.queue.requests_served,
            "batches_served": self.queue.batches_served,
            "expired": self.queue.expired,
            "cache_hits": cache.hits,
            "cache_misses": cache.misses,
            "updates_applied": gate.applied,
            "updates_duplicate": gate.duplicates,
            "updates_buffered": gate.buffered,
            "updates_corrupt": self._sink.corrupt_dropped,
        }

    def close(self) -> None:
        """Drain the queue (every accepted request completes) and stop."""
        self.engine.stop()


# ---------------------------------------------------------------------------
# Process replicas
# ---------------------------------------------------------------------------


def _child_bootstrap(init: dict):
    """Build the child's initial ``(params, t_p, t_q, history, version)``
    from the spawn payload: a full message, or checkpoint dirs to fold."""
    if "msg" in init:
        m = init["msg"]
        params, t_p, t_q, history = bus.state_from_message(m)
        return params, t_p, t_q, history, int(m.version)
    from repro.online.publisher import fold_deltas
    from repro.serving.engine import load_mf_checkpoint

    params, t_p, t_q, _, _ = load_mf_checkpoint(init["checkpoint"])
    version = 0
    history = None
    if init.get("online_dir"):
        params, t_p, t_q, history, version = fold_deltas(
            init["online_dir"], params, t_p, t_q
        )
    return params, t_p, t_q, history, version


def _replica_main(conn, replica_id: str, init: dict,
                  engine_kwargs: Optional[dict],
                  queue_kwargs: Optional[dict]) -> None:
    """Child process entry: run a :class:`LocalReplica`, serve the pipe.

    Protocol (parent -> child): ``("submit", rid, user, topk, timeout,
    priority)``, ``("update", msg)``, ``("thresholds", t_p, t_q)``,
    ``("stats",)``, ``("ping", seq)``, ``("state",)``, ``("close",)``.
    Child -> parent: ``("ready", version, num_users)``, ``("result", rid,
    scores, items)``, ``("error", rid, repr)``, ``("ack", version, ack)``,
    ``("tack", ack)``, ``("stats", dict)``, ``("pong", seq)``,
    ``("state_msg", DeltaMessage)``, ``("bye",)``.
    """
    send_lock = threading.Lock()

    def send(*payload):
        with send_lock:  # queue scheduler + pipe loop both reply
            try:
                conn.send(payload)
            except (BrokenPipeError, OSError):
                pass

    try:
        params, t_p, t_q, history, version = _child_bootstrap(init)
        replica = LocalReplica(
            replica_id, params, t_p, t_q,
            user_history=history, base_version=version,
            engine_kwargs=engine_kwargs, queue_kwargs=queue_kwargs,
        )
    except Exception as exc:  # surface the spawn failure to the parent
        send("error", -1, f"{type(exc).__name__}: {exc}")
        conn.close()
        return
    send("ready", replica.version, replica.num_users)

    def reply(rid: int, fut: Future) -> None:
        try:
            scores, items = fut.result()
            send("result", rid, np.asarray(scores), np.asarray(items))
        except Exception as exc:
            send("error", rid, f"{type(exc).__name__}: {exc}")

    try:
        while True:
            try:
                op, *rest = conn.recv()
            except (EOFError, OSError):
                break
            if op == "submit":
                rid, user, topk, timeout, priority = rest
                try:
                    fut = replica.submit(int(user), int(topk),
                                         timeout=timeout, priority=priority)
                except Exception as exc:
                    send("error", rid, f"{type(exc).__name__}: {exc}")
                else:
                    fut.add_done_callback(
                        lambda f, rid=rid: reply(rid, f)
                    )
            elif op == "update":
                (msg,) = rest
                try:
                    ack = replica.apply_update(msg)
                except Exception as exc:
                    send("error", -1, f"{type(exc).__name__}: {exc}")
                else:
                    send("ack", msg.version, ack)
            elif op == "thresholds":
                tp, tq = rest
                try:
                    ack = replica.set_thresholds(tp, tq)
                except Exception as exc:
                    send("error", -1, f"{type(exc).__name__}: {exc}")
                else:
                    send("tack", ack)
            elif op == "stats":
                send("stats", replica.stats())
            elif op == "ping":
                # heartbeat: answered from the pipe loop, so a wedged pipe
                # loop (or dead process) reads as probe timeout upstream
                send("pong", *rest)
            elif op == "state":
                try:
                    send("state_msg", replica.state_message())
                except Exception as exc:
                    send("error", -1, f"{type(exc).__name__}: {exc}")
            elif op == "close":
                replica.close()  # drains: every queued future resolves+sends
                send("bye")
                break
    finally:
        conn.close()


class ProcessReplica:
    """Parent-side handle to a replica running in a spawned child process.

    Bootstrap with either ``init_msg`` (a ``kind=full``
    :class:`~repro.serving.fleet.bus.DeltaMessage`; build one with
    ``bus.state_message``) or ``checkpoint=...`` (+ optional
    ``online_dir=...`` to fold the delta chain — the late-join catch-up).
    ``submit`` returns a local Future resolved by the reader thread;
    ``apply_update`` blocks for the child's ack (the publisher's rolling
    fan-out needs the ack before moving to the next replica).
    """

    def __init__(
        self,
        replica_id: str,
        *,
        init_msg: Optional[bus.DeltaMessage] = None,
        checkpoint: Optional[str] = None,
        online_dir: Optional[str] = None,
        engine_kwargs: Optional[dict] = None,
        queue_kwargs: Optional[dict] = None,
        start_timeout: float = 180.0,
    ):
        if (init_msg is None) == (checkpoint is None):
            raise ValueError("pass exactly one of init_msg / checkpoint")
        init = {"msg": init_msg} if init_msg is not None else {
            "checkpoint": checkpoint, "online_dir": online_dir,
        }
        self.replica_id = replica_id
        # everything needed to spawn an equivalent replacement — the
        # supervisor's respawn spec (it overrides the boot state itself)
        self.spawn_kwargs = {
            "checkpoint": checkpoint, "online_dir": online_dir,
            "engine_kwargs": engine_kwargs, "queue_kwargs": queue_kwargs,
            "start_timeout": start_timeout,
        }
        ctx = mp.get_context("spawn")
        self._conn, child_conn = ctx.Pipe()
        self._proc = ctx.Process(
            target=_replica_main,
            args=(child_conn, replica_id, init, engine_kwargs, queue_kwargs),
            daemon=True,
        )
        self._proc.start()
        child_conn.close()
        self._lock = threading.Lock()          # pipe writes
        self._futs: Dict[int, Future] = {}
        self._futs_lock = threading.Lock()
        self._next_rid = 0
        self._acks: Dict[int, int] = {}
        self._ack_event = threading.Condition()
        self._stats: Optional[dict] = None
        self._stats_event = threading.Event()
        self._tack: Optional[int] = None
        self._tack_event = threading.Event()
        self._pongs: set = set()
        self._pong_event = threading.Condition()
        self._ping_seq = 0
        self._state_msg: Optional[bus.DeltaMessage] = None
        self._state_event = threading.Event()
        self._ready = threading.Event()
        self._bye = threading.Event()
        self._dead = threading.Event()
        self.version = 0
        self.num_users = 0
        self._spawn_error: Optional[str] = None
        self._reader = threading.Thread(
            target=self._read_loop, name=f"fleet-{replica_id}-reader",
            daemon=True,
        )
        self._reader.start()
        if not self._ready.wait(start_timeout):
            self._proc.terminate()
            raise TimeoutError(f"replica {replica_id} did not come up")
        if self._spawn_error is not None:
            raise RuntimeError(
                f"replica {replica_id} failed to start: {self._spawn_error}"
            )

    def _read_loop(self) -> None:
        while True:
            try:
                op, *rest = self._conn.recv()
            except (EOFError, OSError):
                break
            if op == "ready":
                self.version, self.num_users = rest
                self._ready.set()
            elif op == "result":
                rid, scores, items = rest
                fut = self._pop_fut(rid)
                if fut is not None:
                    fut.set_result((scores, items))
            elif op == "error":
                rid, text = rest
                if rid == -1 and not self._ready.is_set():
                    self._spawn_error = text
                    self._ready.set()
                    continue
                fut = self._pop_fut(rid)
                if fut is not None:
                    fut.set_exception(RuntimeError(text))
            elif op == "ack":
                version, ack = rest
                with self._ack_event:
                    self._acks[version] = ack
                    self._ack_event.notify_all()
            elif op == "tack":
                (self._tack,) = rest
                self._tack_event.set()
            elif op == "stats":
                (self._stats,) = rest
                self._stats_event.set()
            elif op == "pong":
                (seq,) = rest
                with self._pong_event:
                    self._pongs.add(seq)
                    self._pong_event.notify_all()
            elif op == "state_msg":
                (self._state_msg,) = rest
                self._state_event.set()
            elif op == "bye":
                self._bye.set()
        # Pipe gone: the child died (or closed).  Mark death FIRST so new
        # submits raise fast, then fail everything outstanding — futures,
        # ack/pong/stats waiters, even a constructor still waiting on
        # "ready" (a child that crashes during bootstrap must not cost the
        # caller the full start timeout).
        self._dead.set()
        if not self._ready.is_set():
            if self._spawn_error is None:
                self._spawn_error = "process exited during bootstrap"
            self._ready.set()
        with self._futs_lock:
            leftovers, self._futs = list(self._futs.values()), {}
        exc = ReplicaDiedError(
            f"replica {self.replica_id} died (pipe closed, "
            f"exitcode={self._proc.exitcode})"
        )
        for fut in leftovers:
            if not fut.done():
                fut.set_exception(exc)
        with self._ack_event:
            self._ack_event.notify_all()
        with self._pong_event:
            self._pong_event.notify_all()
        self._tack_event.set()
        self._stats_event.set()
        self._state_event.set()
        self._bye.set()

    def _pop_fut(self, rid: int) -> Optional[Future]:
        with self._futs_lock:
            return self._futs.pop(rid, None)

    def _send(self, *payload) -> None:
        with self._lock:
            self._conn.send(payload)

    @property
    def alive(self) -> bool:
        """False once the child died or its pipe broke — the supervisor's
        cheap (no round-trip) liveness signal."""
        return not self._dead.is_set() and self._proc.is_alive()

    @property
    def exitcode(self) -> Optional[int]:
        """The child's exit code (None while running) — nonzero after a
        crash/kill, part of the supervisor's death evidence."""
        return self._proc.exitcode

    def kill(self) -> None:
        """Hard-kill the child (SIGKILL) — the chaos harness's process
        death.  The reader thread observes the pipe EOF and fails every
        outstanding future with :class:`ReplicaDiedError`."""
        self._proc.kill()

    def ping(self, timeout: float = 5.0) -> bool:
        """Round-trip heartbeat through the child's pipe loop.  False on
        timeout, dead child, or broken pipe — never raises: this is the
        probe the supervisor calls on every tick."""
        if self._dead.is_set():
            return False
        with self._pong_event:
            seq = self._ping_seq
            self._ping_seq += 1
        try:
            self._send("ping", seq)
        except (BrokenPipeError, OSError, ReplicaDiedError):
            return False
        with self._pong_event:
            self._pong_event.wait_for(
                lambda: seq in self._pongs or self._dead.is_set(), timeout
            )
            got = seq in self._pongs
            self._pongs.discard(seq)
        return got

    def _raise_if_dead(self) -> None:
        if self._dead.is_set():
            raise ReplicaDiedError(
                f"replica {self.replica_id} is dead "
                f"(exitcode={self._proc.exitcode})"
            )

    def submit(self, user_id: int, topk: int = 10, *, timeout=None,
               priority: int = 0) -> Future:
        """Forward one request to the child; the reader thread resolves the
        returned Future from the pipe reply.  Raises
        :class:`ReplicaDiedError` fast once the child is dead (never writes
        into a broken pipe, never strands a future)."""
        if faults._PLAN is not None:
            for act in faults.fire("replica.submit", self.replica_id):
                if act.op == "kill":
                    self.kill()
        self._raise_if_dead()
        fut: Future = Future()
        with self._futs_lock:
            rid = self._next_rid
            self._next_rid += 1
            self._futs[rid] = fut
        try:
            self._send("submit", rid, int(user_id), int(topk), timeout,
                       int(priority))
        except (BrokenPipeError, OSError):
            # lost the race with death: behave exactly like a fast-raise
            self._pop_fut(rid)
            raise ReplicaDiedError(
                f"replica {self.replica_id} died (pipe write failed)"
            ) from None
        return fut

    def apply_update(self, msg: bus.DeltaMessage, *, timeout: float = 180.0) -> int:
        """Ship a bus message and block for the child's ack (its version
        after gating) — the rolling fan-out's synchronization point."""
        self._raise_if_dead()
        try:
            self._send("update", msg)
        except (BrokenPipeError, OSError):
            raise ReplicaDiedError(
                f"replica {self.replica_id} died (pipe write failed)"
            ) from None
        with self._ack_event:
            if not self._ack_event.wait_for(
                lambda: msg.version in self._acks or self._dead.is_set(),
                timeout,
            ):
                raise TimeoutError(
                    f"replica {self.replica_id}: no ack for v{msg.version}"
                )
            if msg.version not in self._acks:
                self._raise_if_dead()
            ack = self._acks.pop(msg.version)
        self.version = max(self.version, ack)
        return ack

    def state_message(self, *, timeout: float = 180.0) -> bus.DeltaMessage:
        """Fetch the child's full served state as a ``kind=full`` message —
        the peer-heal payload the supervisor replicates into a respawn."""
        self._raise_if_dead()
        self._state_event.clear()
        self._state_msg = None
        self._send("state")
        if not self._state_event.wait(timeout):
            raise TimeoutError(f"replica {self.replica_id}: state timed out")
        if self._state_msg is None:
            self._raise_if_dead()
            raise RuntimeError(f"replica {self.replica_id}: state fetch failed")
        return self._state_msg

    def set_thresholds(self, t_p, t_q, *, timeout: float = 120.0) -> int:
        """Pin SLO serving thresholds in the child and block for its ack —
        same synchronization discipline as :meth:`apply_update` (the
        rolling rollout must not move on before the swap lands)."""
        self._raise_if_dead()
        self._tack_event.clear()
        self._tack = None
        tp = None if t_p is None else float(t_p)
        tq = None if t_q is None else float(t_q)
        self._send("thresholds", tp, tq)
        if not self._tack_event.wait(timeout):
            raise TimeoutError(
                f"replica {self.replica_id}: threshold swap not acked"
            )
        if self._tack is None:
            self._raise_if_dead()
            raise RuntimeError(f"replica {self.replica_id}: no threshold ack")
        return int(self._tack)

    def depth(self) -> int:
        """Requests submitted here and not yet resolved — the parent-side
        load proxy (no pipe round-trip, so the router can poll it hot)."""
        with self._futs_lock:
            return len(self._futs)

    def stats(self, *, timeout: float = 60.0) -> Dict[str, Any]:
        """Fetch the child's counter snapshot over the pipe."""
        self._raise_if_dead()
        self._stats_event.clear()
        self._stats = None
        self._send("stats")
        if not self._stats_event.wait(timeout):
            raise TimeoutError(f"replica {self.replica_id}: stats timed out")
        if self._stats is None:
            self._raise_if_dead()
            raise RuntimeError(f"replica {self.replica_id}: no stats reply")
        return dict(self._stats)

    def close(self, *, timeout: float = 120.0) -> None:
        """Drain the child (in-flight requests complete and their results
        flow back), then join the process."""
        try:
            self._send("close")
        except (BrokenPipeError, OSError):
            pass
        self._bye.wait(timeout)
        self._proc.join(timeout)
        if self._proc.is_alive():
            self._proc.terminate()
            self._proc.join(10)
        try:
            self._conn.close()
        except OSError:
            pass
