"""Fleet replicas: one serving engine + queue + version gate per replica.

Two implementations of the same contract (``replica_id``, ``version``,
``submit() -> Future``, ``apply_update(msg) -> ack``, ``depth()``,
``stats()``, ``close()``):

* :class:`LocalReplica` — everything in this process.  What CI exercises
  for the replication/ routing logic, what the benches use to measure
  routing policies without IPC noise, and the building block the process
  replica runs inside its child.

* :class:`ProcessReplica` — a ``multiprocessing`` (spawn) child running a
  ``LocalReplica``, talked to over a duplex pipe.  Spawn (not fork) so the
  child re-imports cleanly next to JAX's threadpools — the only mode safe
  on CPU CI.  The child bootstraps either from a ``kind=full``
  :class:`~repro.serving.fleet.bus.DeltaMessage` or from a checkpoint
  directory (training base + ``fold_deltas`` over the online delta chain —
  the late-join path, which leaves the replica at the chain's last version
  so the live bus can resume with deltas).

Requests return ``concurrent.futures.Future`` either way; for process
replicas a reader thread resolves them from pipe replies, so the router
never blocks on a slow replica.
"""
from __future__ import annotations

import multiprocessing as mp
import threading
from concurrent.futures import Future
from typing import Any, Dict, Optional, Tuple

import numpy as np

from repro.serving.fleet import bus


class LocalReplica:
    """One in-process replica: engine + started request queue + gated sink."""

    def __init__(
        self,
        replica_id: str,
        params,
        t_p=0.0,
        t_q=0.0,
        *,
        user_history: Optional[np.ndarray] = None,
        base_version: int = 0,
        engine_kwargs: Optional[dict] = None,
        queue_kwargs: Optional[dict] = None,
    ):
        from repro.serving.engine import ServingEngine

        self.replica_id = replica_id
        self.engine = ServingEngine(
            params, t_p, t_q, user_history=user_history, **(engine_kwargs or {})
        )
        self.queue = self.engine.start(**(queue_kwargs or {}))
        self._sink = bus.EngineDeltaSink(
            self.engine,
            user_history=user_history,
            version=base_version,
            replica_id=replica_id,
        )

    @property
    def version(self) -> int:
        """Replication version this replica currently serves."""
        return self._sink.version

    @property
    def num_users(self) -> int:
        """User-table rows of the served snapshot."""
        return self.engine.num_users

    def submit(self, user_id: int, topk: int = 10, *, timeout=None,
               priority: int = 0) -> Future:
        """Enqueue one request on this replica's queue."""
        return self.engine.submit(user_id, topk, timeout=timeout,
                                  priority=priority)

    def apply_update(self, msg: bus.DeltaMessage) -> int:
        """Offer a bus message to the version gate; returns the ack.

        The hot swap happens under live traffic: requests in flight finish
        on the old snapshot, the queue never pauses."""
        return self._sink.apply_update(msg)

    def set_thresholds(self, t_p, t_q) -> int:
        """Pin SLO serving thresholds on this replica (see
        :meth:`~repro.serving.fleet.bus.EngineDeltaSink.set_thresholds`)."""
        return self._sink.set_thresholds(t_p, t_q)

    def depth(self) -> int:
        """Queued + in-scoring requests — the router's load signal."""
        return self.engine.queue_depth

    def stats(self) -> Dict[str, Any]:
        """Counters for benches/CI: version, load, cache hit rate, queue."""
        cache = self.engine.vector_cache
        gate = self._sink.gate
        return {
            "replica_id": self.replica_id,
            "version": self.version,
            "depth": self.depth(),
            "num_users": self.engine.num_users,
            "n_items": self.engine.n_items,
            "requests_served": self.queue.requests_served,
            "batches_served": self.queue.batches_served,
            "expired": self.queue.expired,
            "cache_hits": cache.hits,
            "cache_misses": cache.misses,
            "updates_applied": gate.applied,
            "updates_duplicate": gate.duplicates,
            "updates_buffered": gate.buffered,
        }

    def close(self) -> None:
        """Drain the queue (every accepted request completes) and stop."""
        self.engine.stop()


# ---------------------------------------------------------------------------
# Process replicas
# ---------------------------------------------------------------------------


def _child_bootstrap(init: dict):
    """Build the child's initial ``(params, t_p, t_q, history, version)``
    from the spawn payload: a full message, or checkpoint dirs to fold."""
    if "msg" in init:
        m = init["msg"]
        params, t_p, t_q, history = bus.state_from_message(m)
        return params, t_p, t_q, history, int(m.version)
    from repro.online.publisher import fold_deltas
    from repro.serving.engine import load_mf_checkpoint

    params, t_p, t_q, _, _ = load_mf_checkpoint(init["checkpoint"])
    version = 0
    history = None
    if init.get("online_dir"):
        params, t_p, t_q, history, version = fold_deltas(
            init["online_dir"], params, t_p, t_q
        )
    return params, t_p, t_q, history, version


def _replica_main(conn, replica_id: str, init: dict,
                  engine_kwargs: Optional[dict],
                  queue_kwargs: Optional[dict]) -> None:
    """Child process entry: run a :class:`LocalReplica`, serve the pipe.

    Protocol (parent -> child): ``("submit", rid, user, topk, timeout,
    priority)``, ``("update", msg)``, ``("thresholds", t_p, t_q)``,
    ``("stats",)``, ``("close",)``.
    Child -> parent: ``("ready", version, num_users)``, ``("result", rid,
    scores, items)``, ``("error", rid, repr)``, ``("ack", version, ack)``,
    ``("tack", ack)``, ``("stats", dict)``, ``("bye",)``.
    """
    send_lock = threading.Lock()

    def send(*payload):
        with send_lock:  # queue scheduler + pipe loop both reply
            try:
                conn.send(payload)
            except (BrokenPipeError, OSError):
                pass

    try:
        params, t_p, t_q, history, version = _child_bootstrap(init)
        replica = LocalReplica(
            replica_id, params, t_p, t_q,
            user_history=history, base_version=version,
            engine_kwargs=engine_kwargs, queue_kwargs=queue_kwargs,
        )
    except Exception as exc:  # surface the spawn failure to the parent
        send("error", -1, f"{type(exc).__name__}: {exc}")
        conn.close()
        return
    send("ready", replica.version, replica.num_users)

    def reply(rid: int, fut: Future) -> None:
        try:
            scores, items = fut.result()
            send("result", rid, np.asarray(scores), np.asarray(items))
        except Exception as exc:
            send("error", rid, f"{type(exc).__name__}: {exc}")

    try:
        while True:
            try:
                op, *rest = conn.recv()
            except (EOFError, OSError):
                break
            if op == "submit":
                rid, user, topk, timeout, priority = rest
                try:
                    fut = replica.submit(int(user), int(topk),
                                         timeout=timeout, priority=priority)
                except Exception as exc:
                    send("error", rid, f"{type(exc).__name__}: {exc}")
                else:
                    fut.add_done_callback(
                        lambda f, rid=rid: reply(rid, f)
                    )
            elif op == "update":
                (msg,) = rest
                try:
                    ack = replica.apply_update(msg)
                except Exception as exc:
                    send("error", -1, f"{type(exc).__name__}: {exc}")
                else:
                    send("ack", msg.version, ack)
            elif op == "thresholds":
                tp, tq = rest
                try:
                    ack = replica.set_thresholds(tp, tq)
                except Exception as exc:
                    send("error", -1, f"{type(exc).__name__}: {exc}")
                else:
                    send("tack", ack)
            elif op == "stats":
                send("stats", replica.stats())
            elif op == "close":
                replica.close()  # drains: every queued future resolves+sends
                send("bye")
                break
    finally:
        conn.close()


class ProcessReplica:
    """Parent-side handle to a replica running in a spawned child process.

    Bootstrap with either ``init_msg`` (a ``kind=full``
    :class:`~repro.serving.fleet.bus.DeltaMessage`; build one with
    ``bus.state_message``) or ``checkpoint=...`` (+ optional
    ``online_dir=...`` to fold the delta chain — the late-join catch-up).
    ``submit`` returns a local Future resolved by the reader thread;
    ``apply_update`` blocks for the child's ack (the publisher's rolling
    fan-out needs the ack before moving to the next replica).
    """

    def __init__(
        self,
        replica_id: str,
        *,
        init_msg: Optional[bus.DeltaMessage] = None,
        checkpoint: Optional[str] = None,
        online_dir: Optional[str] = None,
        engine_kwargs: Optional[dict] = None,
        queue_kwargs: Optional[dict] = None,
        start_timeout: float = 180.0,
    ):
        if (init_msg is None) == (checkpoint is None):
            raise ValueError("pass exactly one of init_msg / checkpoint")
        init = {"msg": init_msg} if init_msg is not None else {
            "checkpoint": checkpoint, "online_dir": online_dir,
        }
        self.replica_id = replica_id
        ctx = mp.get_context("spawn")
        self._conn, child_conn = ctx.Pipe()
        self._proc = ctx.Process(
            target=_replica_main,
            args=(child_conn, replica_id, init, engine_kwargs, queue_kwargs),
            daemon=True,
        )
        self._proc.start()
        child_conn.close()
        self._lock = threading.Lock()          # pipe writes
        self._futs: Dict[int, Future] = {}
        self._futs_lock = threading.Lock()
        self._next_rid = 0
        self._acks: Dict[int, int] = {}
        self._ack_event = threading.Condition()
        self._stats: Optional[dict] = None
        self._stats_event = threading.Event()
        self._tack: Optional[int] = None
        self._tack_event = threading.Event()
        self._ready = threading.Event()
        self._bye = threading.Event()
        self.version = 0
        self.num_users = 0
        self._spawn_error: Optional[str] = None
        self._reader = threading.Thread(
            target=self._read_loop, name=f"fleet-{replica_id}-reader",
            daemon=True,
        )
        self._reader.start()
        if not self._ready.wait(start_timeout):
            self._proc.terminate()
            raise TimeoutError(f"replica {replica_id} did not come up")
        if self._spawn_error is not None:
            raise RuntimeError(
                f"replica {replica_id} failed to start: {self._spawn_error}"
            )

    def _read_loop(self) -> None:
        while True:
            try:
                op, *rest = self._conn.recv()
            except (EOFError, OSError):
                break
            if op == "ready":
                self.version, self.num_users = rest
                self._ready.set()
            elif op == "result":
                rid, scores, items = rest
                fut = self._pop_fut(rid)
                if fut is not None:
                    fut.set_result((scores, items))
            elif op == "error":
                rid, text = rest
                if rid == -1 and not self._ready.is_set():
                    self._spawn_error = text
                    self._ready.set()
                    continue
                fut = self._pop_fut(rid)
                if fut is not None:
                    fut.set_exception(RuntimeError(text))
            elif op == "ack":
                version, ack = rest
                with self._ack_event:
                    self._acks[version] = ack
                    self._ack_event.notify_all()
            elif op == "tack":
                (self._tack,) = rest
                self._tack_event.set()
            elif op == "stats":
                (self._stats,) = rest
                self._stats_event.set()
            elif op == "bye":
                self._bye.set()
        # pipe gone: fail anything still outstanding
        with self._futs_lock:
            leftovers, self._futs = list(self._futs.values()), {}
        for fut in leftovers:
            if not fut.done():
                fut.set_exception(RuntimeError("replica process exited"))
        self._bye.set()

    def _pop_fut(self, rid: int) -> Optional[Future]:
        with self._futs_lock:
            return self._futs.pop(rid, None)

    def _send(self, *payload) -> None:
        with self._lock:
            self._conn.send(payload)

    def submit(self, user_id: int, topk: int = 10, *, timeout=None,
               priority: int = 0) -> Future:
        """Forward one request to the child; the reader thread resolves the
        returned Future from the pipe reply."""
        fut: Future = Future()
        with self._futs_lock:
            rid = self._next_rid
            self._next_rid += 1
            self._futs[rid] = fut
        try:
            self._send("submit", rid, int(user_id), int(topk), timeout,
                       int(priority))
        except (BrokenPipeError, OSError):
            self._pop_fut(rid)
            fut.set_exception(RuntimeError("replica process exited"))
        return fut

    def apply_update(self, msg: bus.DeltaMessage, *, timeout: float = 180.0) -> int:
        """Ship a bus message and block for the child's ack (its version
        after gating) — the rolling fan-out's synchronization point."""
        self._send("update", msg)
        with self._ack_event:
            if not self._ack_event.wait_for(
                lambda: msg.version in self._acks, timeout
            ):
                raise TimeoutError(
                    f"replica {self.replica_id}: no ack for v{msg.version}"
                )
            ack = self._acks.pop(msg.version)
        self.version = max(self.version, ack)
        return ack

    def set_thresholds(self, t_p, t_q, *, timeout: float = 120.0) -> int:
        """Pin SLO serving thresholds in the child and block for its ack —
        same synchronization discipline as :meth:`apply_update` (the
        rolling rollout must not move on before the swap lands)."""
        self._tack_event.clear()
        tp = None if t_p is None else float(t_p)
        tq = None if t_q is None else float(t_q)
        self._send("thresholds", tp, tq)
        if not self._tack_event.wait(timeout):
            raise TimeoutError(
                f"replica {self.replica_id}: threshold swap not acked"
            )
        return int(self._tack)

    def depth(self) -> int:
        """Requests submitted here and not yet resolved — the parent-side
        load proxy (no pipe round-trip, so the router can poll it hot)."""
        with self._futs_lock:
            return len(self._futs)

    def stats(self, *, timeout: float = 60.0) -> Dict[str, Any]:
        """Fetch the child's counter snapshot over the pipe."""
        self._stats_event.clear()
        self._send("stats")
        if not self._stats_event.wait(timeout):
            raise TimeoutError(f"replica {self.replica_id}: stats timed out")
        return dict(self._stats)

    def close(self, *, timeout: float = 120.0) -> None:
        """Drain the child (in-flight requests complete and their results
        flow back), then join the process."""
        try:
            self._send("close")
        except (BrokenPipeError, OSError):
            pass
        self._bye.wait(timeout)
        self._proc.join(timeout)
        if self._proc.is_alive():
            self._proc.terminate()
            self._proc.join(10)
        try:
            self._conn.close()
        except OSError:
            pass
