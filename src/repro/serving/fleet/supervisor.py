"""Fleet supervision: health probes, failover, auto-respawn, readmission.

The supervisor closes the failure loop the rest of the fleet only
half-handles: the router *reacts* to a dead replica (fails over, stops
routing to it) but nothing ever brings the replica back.
:class:`FleetSupervisor` runs the probe → declare → respawn → catch-up →
readmit cycle, tracking each replica through the state machine::

    HEALTHY ──probe miss──▶ SUSPECT ──misses ≥ dead_after──▶ DEAD
       ▲                       │  (pipe EOF / nonzero exitcode:   │
       │                       └──────── straight to DEAD ───────┘
       │                                                          ▼
    HEALTHY ◀── version converged, router readmits ── CATCHING_UP ◀── RESPAWNING

Death evidence, in order of strength: a broken pipe / nonzero exitcode
(``replica.alive`` false) declares DEAD immediately; a missed heartbeat
(``ping`` timeout) only *suspects* — ``dead_after`` consecutive misses
declare death, so one slow probe under load never triggers a respawn.

Respawn rebuilds the replica from the strongest available source:
``checkpoint=`` + ``online_dir=`` (the late-join ``fold_deltas``
bootstrap) when configured, else a ``kind=full`` state message pulled
from a healthy peer.  Either way the replacement is *readmitted only
after convergence*: its version must reach the fleet's current version
(the peer pull repeats until it does), so the router never routes to a
stale replica — the same behind-the-``VersionGate`` discipline the bus
applies to every delta.

Every incident is recorded (detection → respawn → healthy timestamps);
``report()`` summarizes MTTR for ``BENCH_chaos.json`` and
``launch.online --supervise``.
"""
from __future__ import annotations

import enum
import threading
import time
from typing import Any, Callable, Dict, List, Optional

from repro.serving.fleet import bus
from repro.serving.fleet.replica import (
    LocalReplica,
    ProcessReplica,
    ReplicaDiedError,
)


class ReplicaState(enum.Enum):
    """Where a replica slot is in the supervision lifecycle."""

    HEALTHY = "healthy"
    SUSPECT = "suspect"
    DEAD = "dead"
    RESPAWNING = "respawning"
    CATCHING_UP = "catching_up"


class Incident:
    """One detected replica death and its recovery timeline."""

    def __init__(self, replica_id: str, reason: str):
        self.replica_id = replica_id
        self.reason = reason
        self.detected_at = time.monotonic()
        self.respawned_at: Optional[float] = None
        self.healthy_at: Optional[float] = None
        self.error: Optional[str] = None

    @property
    def mttr_s(self) -> Optional[float]:
        """Detection → readmission, seconds (None while unrecovered)."""
        if self.healthy_at is None:
            return None
        return self.healthy_at - self.detected_at

    def as_dict(self) -> Dict[str, Any]:
        return {
            "replica_id": self.replica_id,
            "reason": self.reason,
            "mttr_s": self.mttr_s,
            "recovered": self.healthy_at is not None,
            "error": self.error,
        }


class FleetSupervisor:
    """Probe replicas, declare death, respawn, readmit after convergence.

    Drive it with :meth:`start`/:meth:`stop` (background thread) or call
    :meth:`poll_once` directly — deterministic tests and the chaos bench
    step the loop by hand so detection latency doesn't depend on thread
    scheduling.

    Parameters
    ----------
    router:
        The fleet's :class:`~repro.serving.fleet.router.Router`.
    probe_interval_s:
        Background-thread tick; each tick is one :meth:`poll_once`.
    ping_timeout_s:
        Heartbeat budget per probe.
    dead_after:
        Consecutive probe misses before a SUSPECT replica is declared
        DEAD.  Hard evidence (broken pipe, exited process) skips the
        suspicion ladder entirely.
    respawn:
        When False the supervisor only detects + fails over (routing
        excludes the corpse) — no replacement is spawned.
    checkpoint / online_dir:
        Respawn source for process replicas: training checkpoint plus
        online delta chain (the ``fold_deltas`` late-join path).  Without
        it, a ``kind=full`` state message is pulled from a healthy peer.
    state_provider:
        Override for the heal payload: a callable returning a
        ``kind=full`` :class:`~repro.serving.fleet.bus.DeltaMessage` of
        the current fleet state (e.g. ``publisher``-side).  Defaults to
        pulling from a healthy peer.
    max_respawns:
        Per-slot respawn budget; a slot that keeps dying stays DEAD once
        exhausted (crash-loop brake).
    """

    def __init__(
        self,
        router,
        *,
        probe_interval_s: float = 0.5,
        ping_timeout_s: float = 10.0,
        dead_after: int = 2,
        respawn: bool = True,
        checkpoint: Optional[str] = None,
        online_dir: Optional[str] = None,
        state_provider: Optional[Callable[[], bus.DeltaMessage]] = None,
        max_respawns: int = 3,
    ):
        self.router = router
        self.probe_interval_s = float(probe_interval_s)
        self.ping_timeout_s = float(ping_timeout_s)
        self.dead_after = int(dead_after)
        self.respawn = bool(respawn)
        self.checkpoint = checkpoint
        self.online_dir = online_dir
        self.state_provider = state_provider
        self.max_respawns = int(max_respawns)
        n = len(router.replicas)
        self.states: List[ReplicaState] = [ReplicaState.HEALTHY] * n
        self._misses = [0] * n
        self._respawns = [0] * n
        self.incidents: List[Incident] = []
        self._open: Dict[int, Incident] = {}  # slot -> unrecovered incident
        self.probes = 0
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._poll_lock = threading.Lock()

    # -- lifecycle -----------------------------------------------------------
    def start(self) -> "FleetSupervisor":
        """Launch the background probe loop (idempotent)."""
        if self._thread is not None:
            return self
        self._stop.clear()
        self._thread = threading.Thread(
            target=self._run, name="fleet-supervisor", daemon=True
        )
        self._thread.start()
        return self

    def stop(self) -> None:
        """Stop the probe loop (any in-progress respawn completes first)."""
        self._stop.set()
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _run(self) -> None:
        while not self._stop.wait(self.probe_interval_s):
            try:
                self.poll_once()
            except Exception:
                # supervision must never take the fleet down with it; the
                # next tick re-probes from scratch
                pass

    # -- one supervision round ----------------------------------------------
    def poll_once(self) -> None:
        """Probe every slot, declare deaths, run recoveries.

        Serialized: the background loop and a test driving the supervisor
        manually can't respawn the same slot twice."""
        with self._poll_lock:
            for idx in range(len(self.router.replicas)):
                self._probe_slot(idx)

    def _probe_slot(self, idx: int) -> None:
        rep = self.router.replicas[idx]
        state = self.states[idx]
        if state in (ReplicaState.DEAD, ReplicaState.RESPAWNING,
                     ReplicaState.CATCHING_UP):
            # a dead slot only moves through _recover (or stays dead once
            # the respawn budget is spent)
            if state is ReplicaState.DEAD:
                self._maybe_recover(idx)
            return
        self.probes += 1
        alive = getattr(rep, "alive", True)
        exitcode = getattr(rep, "exitcode", None)
        if not alive or (exitcode is not None and exitcode != 0):
            self._declare_dead(
                idx, f"hard evidence: alive={alive} exitcode={exitcode}"
            )
            return
        ok = True
        ping = getattr(rep, "ping", None)
        if ping is not None:
            try:
                ok = bool(ping(self.ping_timeout_s))
            except (ReplicaDiedError, BrokenPipeError, OSError, EOFError):
                ok = False
        if ok:
            self._misses[idx] = 0
            self.states[idx] = ReplicaState.HEALTHY
            return
        self._misses[idx] += 1
        self.states[idx] = ReplicaState.SUSPECT
        if self._misses[idx] >= self.dead_after:
            self._declare_dead(
                idx, f"heartbeat: {self._misses[idx]} consecutive misses"
            )

    def _declare_dead(self, idx: int, reason: str) -> None:
        rep = self.router.replicas[idx]
        self.states[idx] = ReplicaState.DEAD
        self._misses[idx] = 0
        self.router.mark_unhealthy(idx)
        incident = Incident(rep.replica_id, reason)
        self.incidents.append(incident)
        self._open[idx] = incident
        self._maybe_recover(idx)

    def _maybe_recover(self, idx: int) -> None:
        if not self.respawn or self._respawns[idx] >= self.max_respawns:
            return
        incident = self._open.get(idx)
        self._respawns[idx] += 1
        self.states[idx] = ReplicaState.RESPAWNING
        try:
            replacement = self._respawn_slot(idx)
            self.states[idx] = ReplicaState.CATCHING_UP
            if incident is not None:
                incident.respawned_at = time.monotonic()
            self._converge(replacement)
        except Exception as exc:
            # respawn failed: back to DEAD, retry on a later tick while
            # the budget lasts
            if incident is not None:
                incident.error = f"{type(exc).__name__}: {exc}"
            self.states[idx] = ReplicaState.DEAD
            return
        # converged: swap into the routing table and readmit
        old = self.router.replicas[idx]
        self.router.replace_replica(idx, replacement)
        self.states[idx] = ReplicaState.HEALTHY
        if incident is not None:
            incident.healthy_at = time.monotonic()
            self._open.pop(idx, None)
        self._reap(old)

    # -- respawn mechanics ---------------------------------------------------
    def _fleet_version(self) -> int:
        """Highest healthy-replica version — the convergence target."""
        versions = [
            self.router.replicas[i].version
            for i in range(len(self.router.replicas))
            if self.router.is_healthy(i)
        ]
        return max(versions) if versions else 0

    def _heal_message(self) -> bus.DeltaMessage:
        if self.state_provider is not None:
            return self.state_provider()
        for i in range(len(self.router.replicas)):
            if not self.router.is_healthy(i):
                continue
            rep = self.router.replicas[i]
            try:
                return rep.state_message()
            except (ReplicaDiedError, TimeoutError, BrokenPipeError, OSError):
                self.router.mark_unhealthy(i)
        raise ReplicaDiedError(
            "no healthy peer (and no state_provider) to heal from"
        )

    def _respawn_slot(self, idx: int):
        old = self.router.replicas[idx]
        if isinstance(old, ProcessReplica):
            spec = dict(old.spawn_kwargs)
            if spec.get("checkpoint"):
                # late-join bootstrap: training base + fold_deltas over the
                # online chain — lands at the chain's latest version
                return ProcessReplica(old.replica_id, **spec)
            spec.pop("checkpoint", None)
            spec.pop("online_dir", None)
            return ProcessReplica(
                old.replica_id, init_msg=self._heal_message(), **spec
            )
        # local replica: rebuild in-process from the heal payload
        msg = self._heal_message()
        params, t_p, t_q, history = bus.state_from_message(msg)
        return LocalReplica(
            old.replica_id, params, t_p, t_q,
            user_history=history, base_version=msg.version,
        )

    def _converge(self, replacement, *, max_rounds: int = 8) -> None:
        """Apply fresh fleet state until the replacement's version reaches
        the fleet's — the readmission gate.  The pull repeats because the
        fleet may have advanced while the respawn was in flight."""
        for _ in range(max_rounds):
            target = self._fleet_version()
            if replacement.version >= target:
                return
            replacement.apply_update(self._heal_message())
        raise RuntimeError(
            f"replica {replacement.replica_id} failed to converge to fleet "
            f"version {self._fleet_version()} (at {replacement.version})"
        )

    @staticmethod
    def _reap(old) -> None:
        """Release the dead replica's resources (join the child, close the
        pipe) — best-effort; it is already out of the routing table."""
        try:
            old.close(timeout=5.0)
        except TypeError:
            try:
                old.close()
            except Exception:
                pass
        except Exception:
            pass

    # -- reporting -----------------------------------------------------------
    def report(self) -> Dict[str, Any]:
        """Counters + incident log for launch reports and the chaos bench:
        per-slot states, respawn counts, and MTTR aggregates."""
        mttrs = [i.mttr_s for i in self.incidents if i.mttr_s is not None]
        return {
            "probes": self.probes,
            "states": {
                self.router.replicas[i].replica_id: self.states[i].value
                for i in range(len(self.states))
            },
            "incidents": [i.as_dict() for i in self.incidents],
            "deaths": len(self.incidents),
            "recovered": sum(
                1 for i in self.incidents if i.healthy_at is not None
            ),
            "respawns": sum(self._respawns),
            "mttr_max_s": max(mttrs) if mttrs else None,
            "mttr_mean_s": (sum(mttrs) / len(mttrs)) if mttrs else None,
        }
