"""Batched top-k recommendation engine over a trained DP-MF checkpoint.

Replaces the score-everything-then-argsort serve path.  The old path
materialized a (B, n) score matrix in HBM and argsorted the full catalog per
request — exactly the "unnecessary operations" the paper prunes, and the
memory-bound pattern GPU-MF studies identify at catalog scale.  The engine:

* **loads once, serves many** — per-item effective ranks ``r_i``, the masked
  (rank-truncated) item factors, item biases, and the kernel's padded/tiled
  layouts are all computed at load time, not per request;
* **never materializes (B, n)** — scoring streams over item tiles keeping a
  running per-user top-k: the Pallas fused pruned-score+top-k kernel on TPU
  (``kernels/pruned_topk.py``), a ``lax.top_k``-merge scan on CPU;
* **micro-batches** — request batches are padded to power-of-two buckets so
  the jit cache stays bounded (``serving/batching.py``);
* **caches hot users** — computed user vectors (the SVD++ history
  aggregation in particular) go through an LRU;
* **shards both operand axes** — ``topk_sharded`` scores per-shard top-k
  under ``shard_map`` with item tiles over the "model" mesh axis and user
  rows over the data axes (2-D when the mesh has both), cross-merging the
  shard winners, so one engine spans item tables bigger than one device
  *and* fans request batches out across the user axis;
* **pipelines requests** — ``submit()`` hands a request to the continuous
  batching queue (``serving/queue.py``) and returns a future; concurrent
  callers coalesce into deadline-ordered batches instead of serializing
  full scoring launches.

Scores returned are full model scores (user/global biases folded back in
after ranking — per-user constants never change the ranking itself).
"""
from __future__ import annotations

import json
import os
import threading
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import checkpoint as ckpt_lib
from repro.core import mf
from repro.core.ranks import effective_ranks, rank_mask
from repro.kernels.ops import (
    pad_catalog_for_topk_kernel,
    pad_users_for_topk_kernel,
    stream_topk_tiles,
    tile_catalog,
)
from repro.kernels.pruned_topk import pruned_topk_padded
from repro.serving.batching import LRUCache, bucket_size

_NEG_INF = float("-inf")


# ---------------------------------------------------------------------------
# Checkpoint loading (full MFParams — biases and implicit factors included)
# ---------------------------------------------------------------------------


def load_mf_checkpoint(
    directory: str, *, step: Optional[int] = None
) -> Tuple[mf.MFParams, jnp.ndarray, jnp.ndarray, Optional[jnp.ndarray], dict]:
    """Load a DP-MF trainer checkpoint for serving.

    Restores the FULL ``MFParams`` — ``p``/``q`` plus user/item biases,
    global mean, and SVD++ implicit factors when the checkpoint has them
    (the old serve loader dropped everything but ``p``/``q``, silently
    serving wrong scores for BiasSVD/SVD++ checkpoints).  Returns
    ``(params, t_p, t_q, perm, metadata)``.
    """
    if step is None:
        step = ckpt_lib.latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    path = os.path.join(directory, f"step_{step:012d}")
    with open(os.path.join(path, "metadata.json")) as f:
        meta = json.load(f)
    with np.load(os.path.join(path, "arrays.npz")) as data:
        present = set(data.files)

        def opt(key):
            return jnp.asarray(data[key]) if key in present else None

        params = mf.MFParams(
            p=jnp.asarray(data["params__p"]),
            q=jnp.asarray(data["params__q"]),
            user_bias=opt("params__user_bias"),
            item_bias=opt("params__item_bias"),
            global_mean=opt("params__global_mean"),
            implicit=opt("params__implicit"),
        )
        t_p = opt("t_p")
        t_q = opt("t_q")
        perm = opt("perm")
    t_p = jnp.float32(0.0) if t_p is None else t_p.astype(jnp.float32)
    t_q = jnp.float32(0.0) if t_q is None else t_q.astype(jnp.float32)
    return params, t_p, t_q, perm, meta


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------


class ServingEngine:
    """Load a DP-MF model once; answer batched top-k requests forever.

    ``block_n`` sizes the item tiles of the *streaming* (``use_kernel=False``)
    layout only; the Pallas kernel path uses the MXU/VMEM-aligned block
    defaults of ``kernels.ops.pad_catalog_for_topk_kernel``.  ``max_batch``
    caps a scoring launch; larger requests are chunked.  All top-k entry
    points return ``(scores, indices)`` — the ``jax.lax.top_k`` ordering.
    """

    def __init__(
        self,
        params: mf.MFParams,
        t_p=0.0,
        t_q=0.0,
        *,
        max_batch: int = 256,
        block_n: int = 1024,
        use_kernel: Optional[bool] = None,
        interpret: Optional[bool] = None,
        cache_size: int = 4096,
        user_history: Optional[np.ndarray] = None,
        allow_missing_history: bool = False,
    ):
        self.params = params
        self.t_p = jnp.asarray(t_p, jnp.float32)
        self.t_q = jnp.asarray(t_q, jnp.float32)
        self.num_users, self.k = params.p.shape
        self.n_items = params.q.shape[0]
        self.max_batch = max_batch
        self.block_n = block_n
        if use_kernel is None:
            use_kernel = jax.default_backend() == "tpu"
        self.use_kernel = use_kernel
        self.interpret = interpret
        self.user_history = (
            None if user_history is None else np.asarray(user_history)
        )
        if params.implicit is not None and self.user_history is None:
            if not allow_missing_history:
                raise ValueError(
                    "SVD++ params need user_history (see "
                    "data.build_user_history), or pass "
                    "allow_missing_history=True to serve from p alone"
                )
            # Empty histories: every entry is the implicit table's padding
            # row, so user vectors reduce to p_u exactly.
            self.user_history = np.full(
                (self.num_users, 1), self.n_items, np.int32
            )

        # ---- load-time precompute (was per-request in the old path) ------
        # Per-item effective ranks are frozen with the factors; biases are a
        # (n,) vector shared by both scoring layouts.
        self.r_i = effective_ranks(params.q, self.t_q)
        self._item_bias_vec = (
            params.item_bias[:, 0].astype(jnp.float32)
            if params.item_bias is not None
            else jnp.zeros((self.n_items,), jnp.float32)
        )

        # Scoring layouts are built lazily on first use so an engine only
        # holds the catalog copies its configured path actually reads:
        # streaming tiles (rank-masked f32), or the kernel's padded raw
        # factors + ranks (it re-masks per K-block so it can skip K-blocks).
        self._stream_layout_cache = None
        self._kernel_layout = None
        # Sharded scoring: catalog layout per shard count, compiled program
        # per (mesh, topk) — jit caches by function identity, so the
        # shard_map closure must be built once, and the padded catalog only
        # once per shard count (not per topk).
        self._shard_layouts = {}
        self._sharded_fns = {}
        self._queue = None  # async frontend, created by start()/submit()
        self._queue_lock = threading.Lock()  # guards _queue transitions

        # per-user additive constant (never changes ranking; folded back in
        # after top-k so returned scores equal full model scores); host-side
        # because it is applied to host result arrays per request
        if params.user_bias is not None:
            self._user_const = np.asarray(
                params.user_bias[:, 0].astype(jnp.float32) + params.global_mean
            )
        else:
            self._user_const = None

        self.vector_cache = LRUCache(
            cache_size if params.implicit is not None else 0
        )

    # -- construction -------------------------------------------------------
    @classmethod
    def from_checkpoint(
        cls, directory: str, *, step: Optional[int] = None, **kwargs
    ) -> "ServingEngine":
        params, t_p, t_q, _, _ = load_mf_checkpoint(directory, step=step)
        return cls(params, t_p, t_q, **kwargs)

    # -- user vectors --------------------------------------------------------
    def _user_vectors(self, user_ids: np.ndarray) -> jnp.ndarray:
        """(B, k) user vectors: plain rows, or SVD++ history-aggregated rows
        memoized per user in the LRU (the hot-user cache)."""
        if self.params.implicit is None:
            return self.params.p[jnp.asarray(user_ids)]
        rows = [self.vector_cache.get(int(u)) for u in user_ids]
        missing = [i for i, r in enumerate(rows) if r is None]
        if missing:
            miss_ids = np.asarray([user_ids[i] for i in missing], np.int32)
            hist = jnp.asarray(self.user_history[miss_ids])
            fresh = np.asarray(
                mf._user_vector(self.params, jnp.asarray(miss_ids), hist)
            )
            for slot, row in zip(missing, fresh):
                rows[slot] = row
                self.vector_cache.put(int(user_ids[slot]), row)
        return jnp.asarray(np.stack(rows))

    # -- scoring -------------------------------------------------------------
    def _masked_user_block(self, pu: jnp.ndarray) -> jnp.ndarray:
        r_u = effective_ranks(pu, self.t_p)
        return pu.astype(jnp.float32) * rank_mask(r_u, self.k)

    def _stream_layout(self):
        if self._stream_layout_cache is None:
            qm = self.params.q.astype(jnp.float32) * rank_mask(
                self.r_i, self.k
            )
            self._stream_layout_cache = tile_catalog(
                qm, self._item_bias_vec, self.block_n
            )
        return self._stream_layout_cache

    def _topk_block(self, pu: jnp.ndarray, topk: int):
        if self.use_kernel:
            return self._topk_block_kernel(pu, topk)
        q_tiles, b_tiles, offs = self._stream_layout()
        return stream_topk_tiles(
            self._masked_user_block(pu), q_tiles, b_tiles, offs, topk=topk
        )

    def _topk_block_kernel(self, pu: jnp.ndarray, topk: int):
        if self._kernel_layout is None:
            self._kernel_layout = pad_catalog_for_topk_kernel(
                self.params.q, self.r_i, self._item_bias_vec
            )
        qp, rip, biasp = self._kernel_layout
        r_u = effective_ranks(pu, self.t_p)
        pp, rup = pad_users_for_topk_kernel(pu, r_u)
        interpret = (
            jax.default_backend() != "tpu"
            if self.interpret is None
            else self.interpret
        )
        scores, idx = pruned_topk_padded(
            pp, qp, rup, rip, biasp,
            topk=topk, n_items=self.n_items,
            interpret=interpret,
        )
        return scores[: pu.shape[0], :topk], idx[: pu.shape[0], :topk]

    def _validate_request(self, user_ids, topk: int) -> np.ndarray:
        if not 0 < topk <= self.n_items:
            raise ValueError(f"topk must be in [1, {self.n_items}], got {topk}")
        ids = np.asarray(user_ids, np.int32).reshape(-1)
        # jnp gathers clamp out-of-range indices silently — that would serve
        # the *last* user's recommendations to an unknown user id.
        bad = (ids < 0) | (ids >= self.num_users)
        if bad.any():
            raise ValueError(
                f"unknown user ids {ids[bad][:5].tolist()} "
                f"(catalog has {self.num_users} users)"
            )
        return ids

    def _run_chunked(self, ids: np.ndarray, topk: int, block_fn):
        """Shared request loop: split into max_batch chunks, pad each chunk
        to its power-of-two bucket (bounds the jit cache to log2(max_batch)
        shapes per scoring program), score, fold user constants back in."""
        out_s = np.empty((len(ids), topk), np.float32)
        out_i = np.empty((len(ids), topk), np.int32)
        for lo in range(0, len(ids), self.max_batch):
            chunk = ids[lo : lo + self.max_batch]
            bucket = bucket_size(len(chunk), self.max_batch)
            padded = np.pad(chunk, (0, bucket - len(chunk)), mode="edge")
            pu = self._user_vectors(padded)
            scores, idx = block_fn(pu, topk)
            scores = np.asarray(scores[: len(chunk)])
            idx = np.asarray(idx[: len(chunk)])
            if self._user_const is not None:
                scores = scores + self._user_const[chunk][:, None]
            out_s[lo : lo + len(chunk)] = scores
            out_i[lo : lo + len(chunk)] = idx
        return out_s, out_i

    def topk(
        self, user_ids, topk: int = 10
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Top-k items for a batch of users.  Returns ``(scores, indices)``
        as (B, topk) numpy arrays — the ``jax.lax.top_k`` ordering, same as
        ``kernels.ops.pruned_topk`` and ``ref.pruned_topk_ref`` — identical
        to dense score-and-argsort."""
        ids = self._validate_request(user_ids, topk)
        return self._run_chunked(ids, topk, self._topk_block)

    # -- sharded catalog -----------------------------------------------------
    def _shard_layout(self, n_model: int):
        """Catalog tiles padded so the tile axis splits evenly over
        ``n_model`` shards; padding tiles carry -inf biases and can never
        win the merge.  One copy per shard count (NOT per topk)."""
        if n_model not in self._shard_layouts:
            q_tiles, b_tiles, offs = self._stream_layout()
            pad_t = (-q_tiles.shape[0]) % n_model
            self._shard_layouts[n_model] = (
                jnp.pad(q_tiles, ((0, pad_t), (0, 0), (0, 0))),
                jnp.pad(b_tiles, ((0, pad_t), (0, 0)),
                        constant_values=_NEG_INF),
                jnp.pad(offs, (0, pad_t)),
            )
        return self._shard_layouts[n_model]

    def _sharded_program(self, mesh, topk: int):
        """Compiled shard_map scoring program for (mesh, topk).  Built once:
        jit caches by function identity, so rebuilding the closure per
        request would retrace and recompile every call."""
        from repro.distributed import mesh_compat
        from repro.distributed.sharding import serving_topk_specs

        key = (mesh, topk)
        if key not in self._sharded_fns:
            in_specs, out_specs = serving_topk_specs(mesh)

            def body(pm_blk, qt, bt, off):
                local_s, local_i = stream_topk_tiles(
                    pm_blk, qt, bt, off, topk=topk
                )
                gs = jax.lax.all_gather(local_s, "model")  # (n_model, b, topk)
                gi = jax.lax.all_gather(local_i, "model")
                b = pm_blk.shape[0]
                cand_s = jnp.moveaxis(gs, 0, 1).reshape(b, -1)
                cand_i = jnp.moveaxis(gi, 0, 1).reshape(b, -1)
                merged_s, sel = jax.lax.top_k(cand_s, topk)
                return merged_s, jnp.take_along_axis(cand_i, sel, axis=1)

            self._sharded_fns[key] = jax.jit(mesh_compat.shard_map(
                body,
                mesh=mesh,
                in_specs=in_specs,
                out_specs=out_specs,
                check_vma=False,
            ))
        return self._sharded_fns[key]

    def topk_sharded(
        self, user_ids, topk: int = 10, *, mesh=None
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Mesh-sharded top-k, 2-D when the mesh has both axes.

        Item tiles shard over the mesh's "model" axis (PR 1); user rows —
        and with them the per-request user-factor fan-out — shard over the
        data axes when present (``distributed.sharding.serving_topk_specs``),
        so a (2, 4) ``("data", "model")`` mesh scores each user slab against
        each catalog slice on its own device.  Per shard: streaming top-k,
        one all-gather of the (b, topk) shard winners over "model", local
        merge — collective traffic is O(b * topk), independent of catalog
        size, and the batch axis never leaves its data shard.  Returns
        ``(scores, indices)`` like :meth:`topk`; requests go through the
        same chunk/bucket loop, so batch shapes (and thus compiled programs)
        stay bounded."""
        from repro.distributed import mesh_compat
        from repro.distributed.sharding import serving_row_multiple

        ids = self._validate_request(user_ids, topk)
        mesh = mesh_compat.resolve_mesh(mesh)
        if mesh is None or "model" not in mesh.axis_names:
            raise ValueError("topk_sharded needs a mesh with a 'model' axis")
        layout = self._shard_layout(mesh.shape["model"])
        fn = self._sharded_program(mesh, topk)
        row_mult = serving_row_multiple(mesh)

        def block_fn(pu, k):
            b = pu.shape[0]
            pad = (-b) % row_mult  # equal user slabs per data shard
            pm = self._masked_user_block(pu)
            if pad:
                pm = jnp.pad(pm, ((0, pad), (0, 0)))
            scores, idx = fn(pm, *layout)
            return scores[:b], idx[:b]

        return self._run_chunked(ids, topk, block_fn)

    # -- async frontend ------------------------------------------------------
    def start(self, *, mesh=None, **queue_kwargs):
        """Start the async request pipeline; returns the
        :class:`~repro.serving.queue.RequestQueue`.

        With ``mesh`` the queue scores through :meth:`topk_sharded` on that
        mesh (1-D or 2-D); otherwise through the local :meth:`topk` path.
        Queue kwargs (``max_batch``, ``max_pending``, ``linger_ms``) pass
        through.  The queue's single scheduler thread is the only thread
        that touches the scoring paths, so no engine locking is needed.
        """
        with self._queue_lock:
            return self._start_locked(mesh=mesh, **queue_kwargs)

    def _start_locked(self, *, mesh=None, **queue_kwargs):
        from repro.serving.queue import RequestQueue

        if self._queue is not None:
            raise RuntimeError("engine already has a running request queue")
        score_fn = None
        if mesh is not None:
            score_fn = lambda users, k: self.topk_sharded(users, k, mesh=mesh)
        self._queue = RequestQueue(self, score_fn=score_fn, **queue_kwargs)
        return self._queue

    def submit(self, user_id: int, topk: int = 10, *, timeout=None):
        """Async single-user request: returns a ``concurrent.futures.Future``
        resolving to ``(scores, item_ids)`` — (topk,) rows, byte-identical
        to the caller's row of :meth:`topk`.  Poll with ``future.done()``,
        block with ``future.result(timeout)``.  Starts a default queue on
        first use; call :meth:`start` first to configure it.  Safe from any
        thread (first-submit races resolve to one shared queue)."""
        with self._queue_lock:
            if self._queue is None:
                self._start_locked()
            queue = self._queue
        return queue.submit(user_id, topk, timeout=timeout)

    def stop(self) -> None:
        """Drain and stop the async pipeline (no-op if never started)."""
        with self._queue_lock:
            queue, self._queue = self._queue, None
        if queue is not None:
            queue.close()  # outside the lock: close() joins the scheduler

    # -- convenience ---------------------------------------------------------
    def recommend(self, user_ids, topk: int = 10):
        """JSON-friendly form: list of per-user [{item, score}, ...]."""
        scores, idx = self.topk(user_ids, topk)
        return [
            [
                {"item": int(i), "score": round(float(s), 4)}
                for i, s in zip(row_i, row_s)
            ]
            for row_i, row_s in zip(idx, scores)
        ]
