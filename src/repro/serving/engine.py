"""Batched top-k recommendation engine over a trained DP-MF checkpoint.

Replaces the score-everything-then-argsort serve path.  The old path
materialized a (B, n) score matrix in HBM and argsorted the full catalog per
request — exactly the "unnecessary operations" the paper prunes, and the
memory-bound pattern GPU-MF studies identify at catalog scale.  The engine:

* **loads once, serves many** — per-item effective ranks ``r_i``, the masked
  (rank-truncated) item factors, item biases, and the kernel's padded/tiled
  layouts are all computed at load time, not per request;
* **never materializes (B, n)** — scoring streams over item tiles keeping a
  running per-user top-k: the Pallas fused pruned-score+top-k kernel on TPU
  (``kernels/pruned_topk.py``), a ``lax.top_k``-merge scan on CPU;
* **micro-batches** — request batches are padded to power-of-two buckets so
  the jit cache stays bounded (``serving/batching.py``);
* **caches hot users** — computed user vectors (the SVD++ history
  aggregation in particular) go through an LRU;
* **shards both operand axes** — ``topk_sharded`` scores per-shard top-k
  under ``shard_map`` with item tiles over the "model" mesh axis and user
  rows over the data axes (2-D when the mesh has both), cross-merging the
  shard winners, so one engine spans item tables bigger than one device
  *and* fans request batches out across the user axis;
* **pipelines requests** — ``submit()`` hands a request to the continuous
  batching queue (``serving/queue.py``) and returns a future; concurrent
  callers coalesce into deadline-ordered batches instead of serializing
  full scoring launches;
* **hot-swaps factor versions** — :meth:`swap` publishes a new
  ``(params, t_p, t_q)`` snapshot without dropping requests.  All
  model-derived state (factors, ranks, tiled layouts, user constants, the
  hot-user LRU) lives in an immutable per-version :class:`_Snapshot`; every
  scoring batch captures the current snapshot ONCE at entry, so a concurrent
  swap never changes results mid-batch and each result is deterministic for
  the version that served it.  Swaps are double-buffered: the next version's
  layouts are built (incrementally, for touched item rows only, when the
  thresholds and catalog geometry are unchanged) before the atomic flip.

Scores returned are full model scores (user/global biases folded back in
after ranking — per-user constants never change the ranking itself).
"""
from __future__ import annotations

import threading
from typing import Iterable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import checkpoint as ckpt_lib
from repro.core import mf
from repro.core.ranks import effective_ranks, rank_mask
from repro.kernels.ops import (
    TOPK_BLOCK_K,
    TOPK_BLOCK_N,
    pad_catalog_for_topk_kernel,
    pad_users_for_topk_kernel,
    stream_topk_tiles,
    tile_catalog,
)
from repro.kernels.pruned_topk import pruned_topk_padded
from repro.serving.batching import LRUCache, bucket_size

_NEG_INF = float("-inf")


# ---------------------------------------------------------------------------
# Checkpoint loading (full MFParams — biases and implicit factors included)
# ---------------------------------------------------------------------------


def load_mf_checkpoint(
    directory: str, *, step: Optional[int] = None
) -> Tuple[mf.MFParams, jnp.ndarray, jnp.ndarray, Optional[jnp.ndarray], dict]:
    """Load a DP-MF trainer checkpoint for serving.

    Restores the FULL ``MFParams`` — ``p``/``q`` plus user/item biases,
    global mean, and SVD++ implicit factors when the checkpoint has them
    (the old serve loader dropped everything but ``p``/``q``, silently
    serving wrong scores for BiasSVD/SVD++ checkpoints).  Returns
    ``(params, t_p, t_q, perm, metadata)``.
    """
    data, meta = ckpt_lib.load_raw(directory, step)
    params = mf.params_from_flat(data)

    def opt(key):
        return jnp.asarray(data[key]) if key in data else None

    t_p = opt("t_p")
    t_q = opt("t_q")
    perm = opt("perm")
    t_p = jnp.float32(0.0) if t_p is None else t_p.astype(jnp.float32)
    t_q = jnp.float32(0.0) if t_q is None else t_q.astype(jnp.float32)
    return params, t_p, t_q, perm, meta


# ---------------------------------------------------------------------------
# Versioned model snapshots
# ---------------------------------------------------------------------------


class _Snapshot:
    """One immutable factor version plus everything derived from it.

    Scoring entry points capture ``engine._snap`` exactly once per request
    batch and thread it through the whole launch, so :meth:`ServingEngine.swap`
    (a plain attribute store, atomic under the GIL) can flip versions while
    requests are in flight: a batch that started on version v finishes on
    version v, bit-for-bit.  Layouts are built lazily under ``_build_lock``
    and reused (or incrementally patched) across swaps.
    """

    def __init__(
        self,
        version: int,
        params: mf.MFParams,
        t_p,
        t_q,
        *,
        block_n: int,
        cache: LRUCache,
        user_history: Optional[np.ndarray],
        r_i: Optional[jnp.ndarray] = None,
        user_const: Optional[np.ndarray] = None,
        compact_latent: bool = False,
        user_remap: Optional[np.ndarray] = None,
        remap_epoch: int = 0,
    ):
        self.version = version
        self.params = params
        self.t_p = jnp.asarray(t_p, jnp.float32)
        self.t_q = jnp.asarray(t_q, jnp.float32)
        self.num_users, self.k = params.p.shape
        self.n_items = params.q.shape[0]
        self.block_n = block_n
        self.cache = cache
        self.user_history = user_history
        self.compact_latent = compact_latent
        # Cold-row eviction (store/eviction.py): request ids are *external*;
        # ``user_remap[ext] -> physical row or -1 (spilled)``.  Without an
        # evictor upstream the remap is None and ids are physical as before.
        self.user_remap = (
            None if user_remap is None else np.asarray(user_remap, np.int32)
        )
        self.remap_epoch = int(remap_epoch)
        self.num_external = (
            self.num_users if self.user_remap is None
            else int(self.user_remap.shape[0])
        )
        self._fallback_topk = {}  # topk -> (scores, idx) for spilled users

        # ``r_i``/``user_const`` accept precomputed values so an incremental
        # swap can patch the previous snapshot's at the touched rows instead
        # of re-reducing the full catalog / user table
        self.r_i = (
            effective_ranks(params.q, self.t_q) if r_i is None else r_i
        )
        self.item_bias_vec = (
            params.item_bias[:, 0].astype(jnp.float32)
            if params.item_bias is not None
            else jnp.zeros((self.n_items,), jnp.float32)
        )
        # per-user additive constant (never changes ranking; folded back in
        # after top-k so returned scores equal full model scores); host-side
        # because it is applied to host result arrays per request
        if user_const is not None:
            self.user_const = user_const
        elif params.user_bias is not None:
            self.user_const = np.asarray(
                params.user_bias[:, 0].astype(jnp.float32) + params.global_mean
            )
        else:
            self.user_const = None

        # Scoring layouts are built lazily on first use so a snapshot only
        # holds the catalog copies its configured path actually reads:
        # streaming tiles (rank-masked f32), or the kernel's padded raw
        # factors + ranks (it re-masks per K-block so it can skip K-blocks).
        self._stream_layout = None
        self._kernel_layout = None
        self._shard_layouts = {}
        self._kernel_shard_layouts = {}
        self._build_lock = threading.Lock()

    # -- spilled-user fallback ----------------------------------------------
    def fallback_topk(self, topk: int) -> Tuple[np.ndarray, np.ndarray]:
        """Bias-only/popularity top-k for spilled (evicted) users.

        Scores are ``global_mean + item_bias`` for the bias variants (the
        personalization term of an absent row is unknowable) and zeros for
        funk — ``jax.lax.top_k`` ordering, so the item order is the same
        deterministic tie-break the personalized paths use.  Built once per
        (snapshot, topk) and cached: every spilled user gets the same row.
        """
        with self._build_lock:
            got = self._fallback_topk.get(topk)
            if got is None:
                scores = jnp.asarray(self.item_bias_vec, jnp.float32)
                if self.params.global_mean is not None:
                    scores = scores + jnp.float32(self.params.global_mean)
                s, i = jax.lax.top_k(scores, topk)
                got = (
                    np.asarray(s, np.float32),
                    np.asarray(i, np.int32),
                )
                self._fallback_topk[topk] = got
            return got

    # -- layouts -------------------------------------------------------------
    def stream_layout(self):
        with self._build_lock:
            return self._stream_layout_locked()

    def kernel_layout(self):
        with self._build_lock:
            if self._kernel_layout is None:
                self._kernel_layout = pad_catalog_for_topk_kernel(
                    self.params.q, self.r_i, self.item_bias_vec
                )
            return self._kernel_layout

    def shard_layout(self, n_model: int):
        """Streaming catalog tiles padded so the tile axis splits evenly over
        ``n_model`` shards; padding tiles carry -inf biases and can never
        win the merge.  One copy per shard count (NOT per topk)."""
        with self._build_lock:
            if n_model not in self._shard_layouts:
                q_tiles, b_tiles, offs = self._stream_layout_locked()
                pad_t = (-q_tiles.shape[0]) % n_model
                self._shard_layouts[n_model] = (
                    jnp.pad(q_tiles, ((0, pad_t), (0, 0), (0, 0))),
                    jnp.pad(b_tiles, ((0, pad_t), (0, 0)),
                            constant_values=_NEG_INF),
                    jnp.pad(offs, (0, pad_t)),
                )
            return self._shard_layouts[n_model]

    def _compact_k(self) -> int:
        """Latent columns the streaming layout must keep under compaction:
        every masked item row is zero beyond its effective rank, so columns
        past ``max(r_i)`` are zero for the *whole* catalog and can be
        truncated — this is what turns a tighter threshold into real CPU
        FLOP savings instead of multiply-by-zero work.  Rounded up to a
        multiple of 8 so threshold moves land on a handful of compiled
        shapes instead of retracing per distinct rank."""
        if not self.compact_latent or float(self.t_q) <= 0.0:
            return self.k
        r_max = max(int(jnp.max(self.r_i)), 1) if self.n_items else self.k
        return min(self.k, ((r_max + 7) // 8) * 8)

    def _stream_layout_locked(self):
        # shard_layout holds _build_lock already; inline the lazy build
        if self._stream_layout is None:
            qm = self.params.q.astype(jnp.float32) * rank_mask(self.r_i, self.k)
            k_eff = self._compact_k()
            if k_eff < self.k:
                qm = qm[:, :k_eff]
            self._stream_layout = tile_catalog(
                qm, self.item_bias_vec, self.block_n
            )
        return self._stream_layout

    def kernel_shard_layout(self, n_model: int):
        """Kernel-path catalog operands padded so each of ``n_model`` shards
        gets an equal, block-aligned item slab.  Padding rows carry rank 0
        and -inf bias, so the kernel's running top-k can never select them
        regardless of which shard they land on."""
        with self._build_lock:
            if n_model not in self._kernel_shard_layouts:
                q, r_i, bias = self.params.q, self.r_i, self.item_bias_vec
                n = q.shape[0]
                mult = TOPK_BLOCK_N * n_model
                pad_n = (-n) % mult
                pad_k = (-self.k) % TOPK_BLOCK_K
                qp = jnp.pad(q, ((0, pad_n), (0, pad_k)))
                rip = jnp.pad(r_i[:, None].astype(jnp.int32), ((0, pad_n), (0, 0)))
                biasp = jnp.pad(
                    bias.astype(jnp.float32)[:, None],
                    ((0, pad_n), (0, 0)),
                    constant_values=_NEG_INF,
                )
                self._kernel_shard_layouts[n_model] = (qp, rip, biasp)
            return self._kernel_shard_layouts[n_model]

    # -- incremental rebuilds (hot-swap fast path) ---------------------------
    def layouts_view(self):
        """Consistent copy of the built-layout set, taken under the build
        lock — the swap thread iterates it while the scheduler thread may
        still be lazily building layouts into this (previous) snapshot."""
        with self._build_lock:
            return (
                self._stream_layout,
                self._kernel_layout,
                dict(self._shard_layouts),
                dict(self._kernel_shard_layouts),
            )

    def clone_layouts_from(
        self, prev: "_Snapshot", touched_items: np.ndarray
    ) -> bool:
        """Carry ``prev``'s built layouts over to this snapshot, patching only
        the rows of ``touched_items`` — valid ONLY when thresholds, the
        catalog size, and the latent permutation are unchanged (the caller
        checks).  This is the double-buffer build of a hot swap: the
        rank/mask compute drops to O(touched * k), but note each ``.at[].set``
        runs outside jit and therefore copies its full buffer — per-swap
        memory traffic stays O(n * k), only the recompute is saved.

        Returns False — meaning "patch unsound, caller must full-rebuild" —
        when a latent-compacted layout is too narrow for a touched row's new
        effective rank (online updates grew a factor past the truncation
        width; the 8-column rounding slack in ``_compact_k`` makes this
        rare)."""
        k = self.k
        idx = jnp.asarray(touched_items, jnp.int32)
        q_rows = self.params.q[idx]
        r_rows = self.r_i[idx]
        qm_rows = q_rows.astype(jnp.float32) * rank_mask(r_rows, k)
        b_rows = self.item_bias_vec[idx]
        stream, kernel, shard, kernel_shard = prev.layouts_view()

        compact_widths = [
            layout[0].shape[2]
            for layout in (stream, *shard.values())
            if layout is not None and layout[0].shape[2] < k
        ]
        if compact_widths and int(jnp.max(r_rows)) > min(compact_widths):
            return False

        if stream is not None:
            q_tiles, b_tiles, offs = stream
            block_n = q_tiles.shape[1]
            kc = q_tiles.shape[2]
            t_idx, slot = idx // block_n, idx % block_n
            self._stream_layout = (
                q_tiles.at[t_idx, slot].set(qm_rows[:, :kc]),
                b_tiles.at[t_idx, slot].set(b_rows),
                offs,
            )
        if kernel is not None:
            qp, rip, biasp = kernel
            self._kernel_layout = (
                qp.at[idx, :k].set(q_rows.astype(qp.dtype)),
                rip.at[idx, 0].set(r_rows),
                biasp.at[idx, 0].set(b_rows),
            )
        for n_model, (q_tiles, b_tiles, offs) in shard.items():
            block_n = q_tiles.shape[1]
            kc = q_tiles.shape[2]
            t_idx, slot = idx // block_n, idx % block_n
            self._shard_layouts[n_model] = (
                q_tiles.at[t_idx, slot].set(qm_rows[:, :kc]),
                b_tiles.at[t_idx, slot].set(b_rows),
                offs,
            )
        for n_model, (qp, rip, biasp) in kernel_shard.items():
            self._kernel_shard_layouts[n_model] = (
                qp.at[idx, :k].set(q_rows.astype(qp.dtype)),
                rip.at[idx, 0].set(r_rows),
                biasp.at[idx, 0].set(b_rows),
            )
        return True

    def build_like(self, prev: "_Snapshot"):
        """Eagerly build every layout ``prev`` had built (full rebuild path —
        thresholds/geometry changed).  Keeps the first post-swap request from
        paying the build: the swap is double-buffered, not lazy."""
        stream, kernel, shard, kernel_shard = prev.layouts_view()
        if stream is not None:
            self.stream_layout()
        if kernel is not None:
            self.kernel_layout()
        for n_model in shard:
            self.shard_layout(n_model)
        for n_model in kernel_shard:
            self.kernel_shard_layout(n_model)

    def built_layouts(self):
        """Every device array currently materialized for this snapshot (used
        to block until the double-buffered build is actually resident)."""
        out = []
        for layout in (self._stream_layout, self._kernel_layout):
            if layout is not None:
                out.extend(layout)
        for table in (self._shard_layouts, self._kernel_shard_layouts):
            for layout in table.values():
                out.extend(layout)
        return out


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------


class ServingEngine:
    """Load a DP-MF model once; answer batched top-k requests forever.

    ``block_n`` sizes the item tiles of the *streaming* (``use_kernel=False``)
    layout only; the Pallas kernel path uses the MXU/VMEM-aligned block
    defaults of ``kernels.ops.pad_catalog_for_topk_kernel``.  ``max_batch``
    caps a scoring launch; larger requests are chunked.  All top-k entry
    points return ``(scores, indices)`` — the ``jax.lax.top_k`` ordering.

    The model state behind those entry points is a versioned snapshot;
    :meth:`swap` atomically publishes a new one (see the module docstring
    for the consistency contract).
    """

    def __init__(
        self,
        params: mf.MFParams,
        t_p=0.0,
        t_q=0.0,
        *,
        max_batch: int = 256,
        block_n: int = 1024,
        use_kernel: Optional[bool] = None,
        interpret: Optional[bool] = None,
        cache_size: int = 4096,
        user_history: Optional[np.ndarray] = None,
        allow_missing_history: bool = False,
        compact_latent: bool = False,
        user_remap: Optional[np.ndarray] = None,
        remap_epoch: int = 0,
    ):
        self.max_batch = max_batch
        self.block_n = block_n
        if use_kernel is None:
            use_kernel = jax.default_backend() == "tpu"
        self.use_kernel = use_kernel
        self.interpret = interpret
        self.cache_size = cache_size
        # ``compact_latent=True`` truncates the streaming layout's latent
        # axis to the catalog's max effective rank (rounded up to 8): with
        # pruning on, scoring FLOPs actually drop with the threshold — the
        # lever the SLO controller degrades along.  Scores can differ from
        # the full-width path by reduction-order ulps at t > 0 (exact at
        # t == 0, where no truncation happens), so it is opt-in.
        self.compact_latent = compact_latent

        history = self._resolve_history(
            params, user_history, allow_missing_history
        )
        cache = LRUCache(cache_size if params.implicit is not None else 0)
        self._snap = _Snapshot(
            0, params, t_p, t_q,
            block_n=block_n, cache=cache, user_history=history,
            compact_latent=compact_latent,
            user_remap=user_remap, remap_epoch=remap_epoch,
        )
        # Sharded scoring: compiled program per (mesh, topk, kernel-path) —
        # jit caches by function identity, so the shard_map closure must be
        # built once.  Layouts are passed as arguments, so compiled programs
        # survive swaps (recompiling only if the catalog geometry changes).
        self._sharded_fns = {}
        self._queue = None  # async frontend, created by start()/submit()
        self._queue_lock = threading.Lock()  # guards _queue transitions
        self._stopping = False               # stop() drain in progress
        self._swap_lock = threading.Lock()   # serializes swap() builders

    @staticmethod
    def _resolve_history(params, user_history, allow_missing_history):
        history = None if user_history is None else np.asarray(user_history)
        if params.implicit is not None and history is None:
            if not allow_missing_history:
                raise ValueError(
                    "SVD++ params need user_history (see "
                    "data.build_user_history), or pass "
                    "allow_missing_history=True to serve from p alone"
                )
            # Empty histories: every entry is the implicit table's padding
            # row, so user vectors reduce to p_u exactly.
            history = np.full(
                (params.p.shape[0], 1), params.q.shape[0], np.int32
            )
        return history

    # -- construction -------------------------------------------------------
    @classmethod
    def from_checkpoint(
        cls, directory: str, *, step: Optional[int] = None, **kwargs
    ) -> "ServingEngine":
        """Build an engine from a trainer checkpoint directory: restores the
        full ``MFParams`` plus the trained thresholds
        (:func:`load_mf_checkpoint`); ``kwargs`` pass to the constructor."""
        params, t_p, t_q, _, _ = load_mf_checkpoint(directory, step=step)
        return cls(params, t_p, t_q, **kwargs)

    # -- versioned state accessors ------------------------------------------
    @property
    def version(self) -> int:
        """Monotonic version of the currently served snapshot (0 at load;
        each :meth:`swap` increments it)."""
        return self._snap.version

    @property
    def params(self) -> mf.MFParams:
        """Factor tables of the current snapshot."""
        return self._snap.params

    @property
    def t_p(self):
        """User-side pruning threshold of the current snapshot."""
        return self._snap.t_p

    @property
    def t_q(self):
        """Item-side pruning threshold of the current snapshot."""
        return self._snap.t_q

    @property
    def r_i(self):
        """(n,) per-item effective ranks of the current snapshot."""
        return self._snap.r_i

    @property
    def num_users(self) -> int:
        """User-table rows of the current snapshot (valid request ids are
        ``[0, num_users)``)."""
        return self._snap.num_users

    @property
    def num_external(self) -> int:
        """Size of the valid *request* id domain: equals :attr:`num_users`
        without an eviction remap, else the external-id domain (grow-only
        even while compactions shrink the physical table)."""
        return self._snap.num_external

    @property
    def remap_epoch(self) -> int:
        """Compaction counter of the current snapshot's id remap (0 when
        eviction was never armed upstream)."""
        return self._snap.remap_epoch

    @property
    def n_items(self) -> int:
        """Catalog size of the current snapshot."""
        return self._snap.n_items

    @property
    def k(self) -> int:
        """Latent dimension."""
        return self._snap.k

    @property
    def user_history(self) -> Optional[np.ndarray]:
        """(m, H) SVD++ implicit-history matrix, or None for non-SVD++."""
        return self._snap.user_history

    @property
    def vector_cache(self) -> LRUCache:
        """Hot-user vector LRU of the current snapshot (SVD++ only holds
        entries; other variants use a zero-capacity cache)."""
        return self._snap.cache

    # -- hot swap ------------------------------------------------------------
    def swap(
        self,
        params: mf.MFParams,
        t_p=None,
        t_q=None,
        *,
        touched_users: Optional[Iterable[int]] = None,
        touched_items: Optional[Iterable[int]] = None,
        touched_implicit_items: Optional[Iterable[int]] = None,
        user_history: Optional[np.ndarray] = None,
        user_remap: Optional[np.ndarray] = None,
        remap_epoch: Optional[int] = None,
    ) -> int:
        """Atomically publish a new factor version; returns its number.

        Zero-downtime contract: requests never observe a half-swapped model.
        A scoring batch in flight when the swap lands completes on the old
        snapshot (per-version determinism); batches popped afterwards score
        on the new one.  The new snapshot's layouts are built double-buffered
        *before* the flip:

        * ``touched_items`` given, thresholds/catalog-geometry unchanged —
          the previous layouts are patched at only those rows: O(touched * k)
          compute (rank/mask work), though each patched buffer is still
          copied whole (XLA scatter outside jit), so memory traffic per swap
          remains O(n * k);
        * otherwise (recalibrated thresholds, a latent-axis rearrange, or a
          grown catalog) — full rebuild of whatever layouts were in use.

        The hot-user LRU survives the swap minus the stale entries: the
        ``touched_users`` plus, for SVD++, every user whose history contains
        a row of ``touched_implicit_items``/``touched_items`` (their cached
        aggregation folds those implicit rows in).  Pass
        ``touched_users=None`` to drop the whole cache.

        Tables may grow (cold-start users/items appended by the online
        updater); they may not shrink — queued request ids stay valid.
        The one exception is an eviction compaction: a ``remap_epoch``
        *bump* (with its ``user_remap`` table) may shrink the user table —
        external request ids stay valid through the remap, in-flight
        batches finish on the previous snapshot, and the swap is forced
        down the full-rebuild path with a fresh vector cache (physical
        indices moved).  Omitting both remap kwargs carries the previous
        snapshot's remap forward unchanged.
        """
        # normalize one-shot iterables up front: the touched sets are walked
        # several times below (layout patch, user-const patch, LRU pruning)
        if touched_users is not None:
            touched_users = np.asarray(list(touched_users), np.int64)
        if touched_items is not None:
            touched_items = np.asarray(list(touched_items), np.int64)
        if touched_implicit_items is not None:
            touched_implicit_items = np.asarray(
                list(touched_implicit_items), np.int64
            )
        with self._swap_lock:
            prev = self._snap
            if remap_epoch is None:
                remap_epoch = prev.remap_epoch
                if user_remap is None:
                    user_remap = prev.user_remap
            remap_changed = int(remap_epoch) != prev.remap_epoch
            if remap_changed:
                # compaction barrier: physical rows were renumbered, so no
                # previous layout, cached vector, or touched-row delta can
                # be patched — full rebuild, whole-cache drop
                if user_remap is None:
                    raise ValueError(
                        "a remap_epoch bump must carry its user_remap table"
                    )
                touched_users = None
                touched_items = None
                touched_implicit_items = None
            if not remap_changed and (
                params.p.shape[0] < prev.num_users
                or params.q.shape[0] < prev.n_items
            ):
                raise ValueError(
                    "swap cannot shrink the user/item tables "
                    f"({prev.num_users}x{prev.n_items} -> "
                    f"{params.p.shape[0]}x{params.q.shape[0]}): queued "
                    "requests may already reference the trailing rows "
                    "(only an eviction compaction — a remap_epoch bump — "
                    "may shrink the user table)"
                )
            t_p = prev.t_p if t_p is None else t_p
            t_q = prev.t_q if t_q is None else t_q

            if user_history is None and prev.user_history is not None:
                user_history = self._grow_history(
                    prev.user_history, params, prev.n_items
                )
            elif params.implicit is not None and user_history is None:
                user_history = self._resolve_history(params, None, True)

            same_geometry = (
                params.q.shape[0] == prev.n_items
                and params.p.shape[1] == prev.k
                and float(jnp.asarray(t_q, jnp.float32)) == float(prev.t_q)
            )
            incremental = touched_items is not None and same_geometry
            idx = None
            r_i_pre = None
            user_const_pre = None
            if incremental:
                idx = np.unique(np.asarray(list(touched_items), np.int64))
                if idx.size:
                    # pad to the next power of two (duplicating the last
                    # index — a duplicate .set writes the same row value) so
                    # the scatter programs retrace O(log n) times, not once
                    # per distinct touched count
                    bucket = 1 << (int(idx.size) - 1).bit_length()
                    idx = np.pad(idx, (0, bucket - idx.size), mode="edge")
                    jidx = jnp.asarray(idx, jnp.int32)
                    # item ranks: reduce only the touched rows, patch the rest
                    r_i_pre = prev.r_i.at[jidx].set(
                        effective_ranks(
                            params.q[jidx], jnp.asarray(t_q, jnp.float32)
                        )
                    )
                else:
                    r_i_pre = prev.r_i
                user_const_pre = self._patch_user_const(
                    prev, params, touched_users
                )

            new = _Snapshot(
                prev.version + 1, params, t_p, t_q,
                block_n=self.block_n,
                cache=self._carry_cache(
                    prev, params, touched_users, touched_items,
                    touched_implicit_items, user_history,
                ),
                user_history=user_history,
                r_i=r_i_pre,
                user_const=user_const_pre,
                compact_latent=self.compact_latent,
                user_remap=user_remap,
                remap_epoch=int(remap_epoch),
            )

            if incremental:
                if idx is not None and idx.size:
                    if not new.clone_layouts_from(prev, idx):
                        # a touched row's rank outgrew the compacted latent
                        # width: the patch would truncate real factors —
                        # rebuild the layouts at the new width instead
                        new._stream_layout = None
                        new._kernel_layout = None
                        new._shard_layouts = {}
                        new._kernel_shard_layouts = {}
                        new.build_like(prev)
                else:  # nothing touched on the item side: layouts carry over
                    (new._stream_layout, new._kernel_layout,
                     new._shard_layouts,
                     new._kernel_shard_layouts) = prev.layouts_view()
            else:
                new.build_like(prev)
            # the flip must publish a *resident* double buffer, not a pile of
            # pending device computations the first request would wait on
            built = new.built_layouts()
            if built:
                jax.block_until_ready(built)

            self._snap = new  # atomic: in-flight batches hold `prev`
            return new.version

    @staticmethod
    def _patch_user_const(prev, params, touched_users) -> Optional[np.ndarray]:
        """Incremental-swap user constants: copy the previous (m,) vector and
        rewrite only the touched (and newly grown) rows.  Returns None —
        meaning "recompute from scratch" — whenever the patch could be wrong:
        no bias term, no touched-user list, or a moved global mean."""
        if params.user_bias is None:
            return None
        if prev.user_const is None or touched_users is None:
            return None
        if (
            prev.params.global_mean is None
            or float(params.global_mean) != float(prev.params.global_mean)
        ):
            return None
        m_new = params.p.shape[0]
        tu = np.asarray(list(touched_users), np.int64)
        if m_new > prev.num_users:
            # grown rows are rewritten unconditionally — correctness must not
            # depend on the caller having listed them as touched
            tu = np.concatenate(
                [tu, np.arange(prev.num_users, m_new, dtype=np.int64)]
            )
        uc = np.empty((m_new,), np.float32)
        uc[: prev.num_users] = prev.user_const
        if tu.size:
            uc[tu] = np.asarray(
                params.user_bias[jnp.asarray(tu), 0].astype(jnp.float32)
                + params.global_mean
            )
        return uc

    @staticmethod
    def _grow_history(history, params, old_n_items):
        """Pad the history matrix for grown user tables and remap the padding
        sentinel (== old catalog size) when the item table grew under it."""
        new_m = params.p.shape[0]
        new_n = params.q.shape[0]
        out = history
        if new_n != old_n_items and params.implicit is not None:
            out = out.copy()
            out[out == old_n_items] = new_n
        if new_m > history.shape[0]:
            pad_rows = np.full(
                (new_m - history.shape[0], history.shape[1]),
                new_n if params.implicit is not None else old_n_items,
                history.dtype,
            )
            out = np.concatenate([out, pad_rows], axis=0)
        return out

    def _carry_cache(
        self, prev, params, touched_users, touched_items,
        touched_implicit_items, user_history,
    ) -> LRUCache:
        """Hot-user LRU for the next snapshot: previous entries minus the
        stale ones (touched-rows-only invalidation)."""
        capacity = self.cache_size if params.implicit is not None else 0
        if capacity != prev.cache.capacity or touched_users is None:
            return LRUCache(capacity)
        stale = set(int(u) for u in touched_users)
        if params.implicit is not None:
            # an SVD++ user vector folds in the implicit rows of its history:
            # users whose history intersects the touched implicit rows are
            # stale even though their own p row never moved.  Only users
            # actually IN the cache can hold a stale entry, so the scan is
            # O(|cache| * hist) — not O(num_users * hist) — per swap.
            items = set(
                int(i) for i in
                (touched_items if touched_items is not None else ())
            ) | set(
                int(i) for i in
                (touched_implicit_items
                 if touched_implicit_items is not None else ())
            )
            cached = [u for u in prev.cache.keys() if u not in stale]
            if items and cached and user_history is not None:
                hit = np.isin(
                    user_history[np.asarray(cached, np.int64)],
                    np.fromiter(items, np.int64, len(items)),
                ).any(axis=1)
                stale |= set(
                    int(u) for u, h in zip(cached, hit) if h
                )
        return prev.cache.copy_without(stale)

    # -- user vectors --------------------------------------------------------
    def _user_vectors(self, snap: _Snapshot, user_ids: np.ndarray) -> jnp.ndarray:
        """(B, k) user vectors: plain rows, or SVD++ history-aggregated rows
        memoized per user in the LRU (the hot-user cache)."""
        if snap.params.implicit is None:
            return snap.params.p[jnp.asarray(user_ids)]
        rows = [snap.cache.get(int(u)) for u in user_ids]
        missing = [i for i, r in enumerate(rows) if r is None]
        if missing:
            miss_ids = np.asarray([user_ids[i] for i in missing], np.int32)
            hist = jnp.asarray(snap.user_history[miss_ids])
            fresh = np.asarray(
                mf._user_vector(snap.params, jnp.asarray(miss_ids), hist)
            )
            for slot, row in zip(missing, fresh):
                rows[slot] = row
                snap.cache.put(int(user_ids[slot]), row)
        return jnp.asarray(np.stack(rows))

    # -- scoring -------------------------------------------------------------
    def _masked_user_block(self, snap: _Snapshot, pu: jnp.ndarray) -> jnp.ndarray:
        r_u = effective_ranks(pu, snap.t_p)
        return pu.astype(jnp.float32) * rank_mask(r_u, snap.k)

    def _topk_block(self, snap: _Snapshot, pu: jnp.ndarray, topk: int):
        if self.use_kernel:
            return self._topk_block_kernel(snap, pu, topk)
        q_tiles, b_tiles, offs = snap.stream_layout()
        pm = self._masked_user_block(snap, pu)
        if q_tiles.shape[2] < pm.shape[1]:
            # latent-compacted layout: user columns past the catalog's max
            # effective rank only ever multiply zeros — drop them too
            pm = pm[:, : q_tiles.shape[2]]
        return stream_topk_tiles(pm, q_tiles, b_tiles, offs, topk=topk)

    def _topk_block_kernel(self, snap: _Snapshot, pu: jnp.ndarray, topk: int):
        qp, rip, biasp = snap.kernel_layout()
        r_u = effective_ranks(pu, snap.t_p)
        pp, rup = pad_users_for_topk_kernel(pu, r_u)
        scores, idx = pruned_topk_padded(
            pp, qp, rup, rip, biasp,
            topk=topk, n_items=snap.n_items,
            interpret=self._interpret(),
        )
        return scores[: pu.shape[0], :topk], idx[: pu.shape[0], :topk]

    def _interpret(self) -> bool:
        return (
            jax.default_backend() != "tpu"
            if self.interpret is None
            else self.interpret
        )

    def _validate_request(self, user_ids, topk: int) -> np.ndarray:
        return self._validate_for(self._snap, user_ids, topk)

    @staticmethod
    def _validate_for(snap: _Snapshot, user_ids, topk: int) -> np.ndarray:
        if not 0 < topk <= snap.n_items:
            raise ValueError(
                f"topk must be in [1, {snap.n_items}], got {topk}"
            )
        ids = np.asarray(user_ids, np.int32).reshape(-1)
        # jnp gathers clamp out-of-range indices silently — that would serve
        # the *last* user's recommendations to an unknown user id.  With an
        # eviction remap the request domain is the *external* ids (which
        # only ever grows), not the physical table.
        bad = (ids < 0) | (ids >= snap.num_external)
        if bad.any():
            raise ValueError(
                f"unknown user ids {ids[bad][:5].tolist()} "
                f"(catalog has {snap.num_external} users)"
            )
        return ids

    @staticmethod
    def _translate_ids(
        snap: _Snapshot, ids: np.ndarray
    ) -> Tuple[np.ndarray, Optional[np.ndarray]]:
        """External ids → physical rows under the snapshot's remap.

        Returns ``(physical_ids, evicted_mask-or-None)``; evicted users
        point at placeholder row 0 (scored then discarded — their result
        rows are overwritten by :meth:`_Snapshot.fallback_topk`)."""
        if snap.user_remap is None:
            return ids, None
        phys = snap.user_remap[ids].astype(np.int64)
        evicted = phys < 0
        if not evicted.any():
            return phys.astype(np.int32), None
        return np.where(evicted, 0, phys).astype(np.int32), evicted

    @staticmethod
    def _apply_fallback(
        snap: _Snapshot,
        evicted: Optional[np.ndarray],
        topk: int,
        out_s: np.ndarray,
        out_i: np.ndarray,
    ) -> Tuple[np.ndarray, np.ndarray]:
        if evicted is not None:
            fs, fi = snap.fallback_topk(topk)
            out_s[evicted] = fs
            out_i[evicted] = fi
        return out_s, out_i

    def _run_chunked(self, snap: _Snapshot, ids: np.ndarray, topk: int, block_fn):
        """Shared request loop: split into max_batch chunks, pad each chunk
        to its power-of-two bucket (bounds the jit cache to log2(max_batch)
        shapes per scoring program), score, fold user constants back in."""
        out_s = np.empty((len(ids), topk), np.float32)
        out_i = np.empty((len(ids), topk), np.int32)
        for lo in range(0, len(ids), self.max_batch):
            chunk = ids[lo : lo + self.max_batch]
            bucket = bucket_size(len(chunk), self.max_batch)
            padded = np.pad(chunk, (0, bucket - len(chunk)), mode="edge")
            pu = self._user_vectors(snap, padded)
            scores, idx = block_fn(pu, topk)
            scores = np.asarray(scores[: len(chunk)])
            idx = np.asarray(idx[: len(chunk)])
            if snap.user_const is not None:
                scores = scores + snap.user_const[chunk][:, None]
            out_s[lo : lo + len(chunk)] = scores
            out_i[lo : lo + len(chunk)] = idx
        return out_s, out_i

    def topk(
        self, user_ids, topk: int = 10
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Top-k items for a batch of users.  Returns ``(scores, indices)``
        as (B, topk) numpy arrays — the ``jax.lax.top_k`` ordering, same as
        ``kernels.ops.pruned_topk`` and ``ref.pruned_topk_ref`` — identical
        to dense score-and-argsort."""
        snap = self._snap  # captured once: the whole batch serves one version
        ids = self._validate_for(snap, user_ids, topk)
        phys, evicted = self._translate_ids(snap, ids)
        out_s, out_i = self._run_chunked(
            snap, phys, topk,
            lambda pu, k_: self._topk_block(snap, pu, k_),
        )
        return self._apply_fallback(snap, evicted, topk, out_s, out_i)

    # -- sharded catalog -----------------------------------------------------
    def _sharded_program(self, mesh, topk: int, kernel: bool):
        """Compiled shard_map scoring program for (mesh, topk, path).  Built
        once: jit caches by function identity, so rebuilding the closure per
        request would retrace and recompile every call.  Layouts enter as
        arguments, so the program survives hot swaps."""
        from repro.distributed import mesh_compat
        from repro.distributed.sharding import (
            serving_topk_kernel_specs,
            serving_topk_specs,
        )

        key = (mesh, topk, kernel)
        if key not in self._sharded_fns:
            if kernel:
                in_specs, out_specs = serving_topk_kernel_specs(mesh)
                interpret = self._interpret()

                def body(pu_blk, t_p, qp, rip, biasp):
                    n_loc = qp.shape[0]
                    r_u = effective_ranks(pu_blk, t_p)
                    pp, rup = pad_users_for_topk_kernel(pu_blk, r_u)
                    # padding rows inside the slab carry -inf bias, so every
                    # slab can claim its full extent as valid items
                    s, i = pruned_topk_padded(
                        pp, qp, rup, rip, biasp,
                        topk=topk, n_items=n_loc, interpret=interpret,
                    )
                    b = pu_blk.shape[0]
                    local_s = s[:b, :topk]
                    local_i = (
                        i[:b, :topk] + jax.lax.axis_index("model") * n_loc
                    )
                    return _merge_over_model(local_s, local_i, b, topk)
            else:
                in_specs, out_specs = serving_topk_specs(mesh)

                def body(pm_blk, qt, bt, off):
                    local_s, local_i = stream_topk_tiles(
                        pm_blk, qt, bt, off, topk=topk
                    )
                    return _merge_over_model(
                        local_s, local_i, pm_blk.shape[0], topk
                    )

            self._sharded_fns[key] = jax.jit(mesh_compat.shard_map(
                body,
                mesh=mesh,
                in_specs=in_specs,
                out_specs=out_specs,
                check_vma=False,
            ))
        return self._sharded_fns[key]

    def topk_sharded(
        self, user_ids, topk: int = 10, *, mesh=None
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Mesh-sharded top-k, 2-D when the mesh has both axes.

        Item tiles shard over the mesh's "model" axis (PR 1); user rows —
        and with them the per-request user-factor fan-out — shard over the
        data axes when present (``distributed.sharding.serving_topk_specs``),
        so a (2, 4) ``("data", "model")`` mesh scores each user slab against
        each catalog slice on its own device.  Per shard: streaming top-k
        (or the Pallas kernel when ``use_kernel=True`` — each shard runs the
        fused pruned-score+top-k kernel on its own item slab), one all-gather
        of the (b, topk) shard winners over "model", local merge —
        collective traffic is O(b * topk), independent of catalog size, and
        the batch axis never leaves its data shard.  Returns ``(scores,
        indices)`` like :meth:`topk`; requests go through the same
        chunk/bucket loop, so batch shapes (and thus compiled programs) stay
        bounded."""
        from repro.distributed import mesh_compat
        from repro.distributed.sharding import serving_row_multiple

        snap = self._snap
        ids = self._validate_for(snap, user_ids, topk)
        ids, evicted = self._translate_ids(snap, ids)
        mesh = mesh_compat.resolve_mesh(mesh)
        if mesh is None or "model" not in mesh.axis_names:
            raise ValueError("topk_sharded needs a mesh with a 'model' axis")
        n_model = mesh.shape["model"]
        kernel = self.use_kernel
        layout = (
            snap.kernel_shard_layout(n_model) if kernel
            else snap.shard_layout(n_model)
        )
        fn = self._sharded_program(mesh, topk, kernel)
        row_mult = serving_row_multiple(mesh)

        def block_fn(pu, k_):
            b = pu.shape[0]
            pad = (-b) % row_mult  # equal user slabs per data shard
            if kernel:
                pm = pu.astype(jnp.float32)
            else:
                pm = self._masked_user_block(snap, pu)
                if layout[0].shape[2] < pm.shape[1]:
                    pm = pm[:, : layout[0].shape[2]]
            if pad:
                pm = jnp.pad(pm, ((0, pad), (0, 0)))
            if kernel:
                scores, idx = fn(pm, snap.t_p, *layout)
            else:
                scores, idx = fn(pm, *layout)
            return scores[:b], idx[:b]

        out_s, out_i = self._run_chunked(snap, ids, topk, block_fn)
        return self._apply_fallback(snap, evicted, topk, out_s, out_i)

    # -- async frontend ------------------------------------------------------
    def start(self, *, mesh=None, **queue_kwargs):
        """Start the async request pipeline; returns the
        :class:`~repro.serving.queue.RequestQueue`.

        With ``mesh`` the queue scores through :meth:`topk_sharded` on that
        mesh (1-D or 2-D); otherwise through the local :meth:`topk` path.
        Queue kwargs (``max_batch``, ``max_pending``, ``linger_ms``) pass
        through.  The queue's single scheduler thread is the only thread
        that touches the scoring paths, so no engine locking is needed.

        Restartable: after :meth:`stop` (or after the attached queue was
        closed directly) ``start`` brings up a fresh queue — the lifecycle
        the online publisher's swap-time drains rely on.
        """
        with self._queue_lock:
            return self._start_locked(mesh=mesh, **queue_kwargs)

    def _start_locked(self, *, mesh=None, **queue_kwargs):
        from repro.serving.queue import RequestQueue

        if self._queue is not None:
            if not self._queue.closed:
                raise RuntimeError("engine already has a running request queue")
            self._queue = None  # stale handle: queue was closed directly
        score_fn = None
        if mesh is not None:
            score_fn = lambda users, k: self.topk_sharded(users, k, mesh=mesh)
        self._queue = RequestQueue(self, score_fn=score_fn, **queue_kwargs)
        return self._queue

    def submit(
        self, user_id: int, topk: int = 10, *, timeout=None, priority: int = 0
    ):
        """Async single-user request: returns a ``concurrent.futures.Future``
        resolving to ``(scores, item_ids)`` — (topk,) rows, byte-identical
        to the caller's row of :meth:`topk`.  Poll with ``future.done()``,
        block with ``future.result(timeout)``.  ``priority`` orders requests
        inside a deadline bucket (lower = sooner; see ``serving/queue.py``).
        Starts a default queue on first use; call :meth:`start` first to
        configure it.  Safe from any thread (first-submit races resolve to
        one shared queue).  While :meth:`stop` is draining, new submits are
        rejected with ``RuntimeError`` — they must NOT resurrect a fresh
        queue mid-shutdown (the pre-fix behaviour: a zombie queue nobody
        owned, whose futures stranded forever at process exit)."""
        with self._queue_lock:
            if self._stopping:
                raise RuntimeError("engine is stopping; request rejected")
            if self._queue is None or self._queue.closed:
                self._start_locked()
            queue = self._queue
        return queue.submit(user_id, topk, timeout=timeout, priority=priority)

    @property
    def queue_depth(self) -> int:
        """Requests currently queued or being scored by the async frontend
        (0 when no queue is attached) — the fleet router's load signal."""
        with self._queue_lock:
            queue = self._queue
        return 0 if queue is None or queue.closed else queue.depth

    def stop(self) -> None:
        """Drain and stop the async pipeline: every request already accepted
        completes (scored, expired, or failed — never stranded) before this
        returns.  Concurrent :meth:`submit` calls during the drain are
        rejected instead of auto-starting a new queue.  Idempotent: a second
        stop (or stop before any start) is a no-op; :meth:`start` /
        :meth:`submit` work again afterwards."""
        with self._queue_lock:
            if self._stopping:
                return  # another thread's stop() owns the drain
            queue, self._queue = self._queue, None
            self._stopping = True
        try:
            if queue is not None:
                queue.close()  # outside the lock: close() joins the scheduler
        finally:
            with self._queue_lock:
                self._stopping = False

    # -- convenience ---------------------------------------------------------
    def recommend(self, user_ids, topk: int = 10):
        """JSON-friendly form: list of per-user [{item, score}, ...]."""
        scores, idx = self.topk(user_ids, topk)
        return [
            [
                {"item": int(i), "score": round(float(s), 4)}
                for i, s in zip(row_i, row_s)
            ]
            for row_i, row_s in zip(idx, scores)
        ]


def _merge_over_model(local_s, local_i, b: int, topk: int):
    """Cross-shard merge of per-shard (b, topk) winners: one all-gather over
    "model", then a local top-k over the n_model * topk candidates."""
    gs = jax.lax.all_gather(local_s, "model")  # (n_model, b, topk)
    gi = jax.lax.all_gather(local_i, "model")
    cand_s = jnp.moveaxis(gs, 0, 1).reshape(b, -1)
    cand_i = jnp.moveaxis(gi, 0, 1).reshape(b, -1)
    merged_s, sel = jax.lax.top_k(cand_s, topk)
    return merged_s, jnp.take_along_axis(cand_i, sel, axis=1)
