"""Request-side plumbing for the serving engine: micro-batching + caching.

Production recommendation traffic arrives as a stream of single-user
requests; scoring them one by one wastes the accelerator (every launch pays
the same fixed cost) while batching naively over arbitrary request counts
recompiles the scoring program per batch shape.  The two pieces here bound
both costs:

* ``bucket_size`` quantizes batch sizes to powers of two so the jit cache
  holds at most log2(max_batch) scoring programs;
* ``MicroBatcher`` accumulates individual requests and flushes them through
  the engine as one padded batch;
* ``LRUCache`` memoizes computed user vectors (the per-request gather +
  implicit-history aggregation for SVD++) for hot users.
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from typing import Any, Dict, Hashable, List, Tuple

import numpy as np


def bucket_size(n: int, max_batch: int) -> int:
    """Smallest power of two >= n, capped at ``max_batch``."""
    if n <= 0:
        raise ValueError(f"batch must be positive, got {n}")
    b = 1
    while b < n and b < max_batch:
        b <<= 1
    return min(b, max_batch)


class LRUCache:
    """Tiny LRU keyed by user id; tracks hits/misses for bench reporting.

    Thread-safe: the async queue's scheduler thread and direct callers of
    ``engine.topk`` may hit the same cache concurrently, and an OrderedDict
    mutated from two threads can corrupt its link list.
    """

    def __init__(self, capacity: int):
        self.capacity = capacity
        self._data: "OrderedDict[Hashable, Any]" = OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0

    def get(self, key: Hashable):
        """Return the cached value (refreshing its recency) or None."""
        with self._lock:
            if key in self._data:
                self._data.move_to_end(key)
                self.hits += 1
                return self._data[key]
            self.misses += 1
            return None

    def put(self, key: Hashable, value: Any) -> None:
        """Insert/refresh ``key``, evicting the least-recent past capacity
        (a zero-capacity cache silently drops every put)."""
        if self.capacity <= 0:
            return
        with self._lock:
            self._data[key] = value
            self._data.move_to_end(key)
            while len(self._data) > self.capacity:
                self._data.popitem(last=False)

    def keys(self):
        """Snapshot of the cached keys (thread-safe copy)."""
        with self._lock:
            return list(self._data.keys())

    def invalidate(self, keys) -> int:
        """Drop the given keys (missing ones are ignored); returns the number
        of entries actually removed.  Used by the serving engine's hot swap
        to evict exactly the users whose vectors a factor update staled."""
        removed = 0
        with self._lock:
            for key in keys:
                if self._data.pop(key, None) is not None:
                    removed += 1
        return removed

    def copy_without(self, keys) -> "LRUCache":
        """New cache with the same capacity, entries minus ``keys``, and the
        hit/miss counters carried over.  The old cache is untouched — an
        in-flight batch may still be writing old-version entries into it,
        which is exactly why hot swaps copy instead of mutating."""
        drop = set(keys)
        clone = LRUCache(self.capacity)
        with self._lock:
            for key, value in self._data.items():
                if key not in drop:
                    clone._data[key] = value
            clone.hits = self.hits
            clone.misses = self.misses
        return clone

    def __len__(self) -> int:
        with self._lock:
            return len(self._data)


class MicroBatcher:
    """Collects single-user requests and serves them as one engine batch.

    Synchronous flush model (the event-loop / thread wiring belongs to the
    RPC layer, not here): ``submit`` enqueues and returns a ticket, ``drain``
    scores every pending request in engine-sized chunks and returns
    ``{ticket: (item_ids, scores)}``.  Duplicate user ids within a flush are
    scored once and fanned back out to every ticket.
    """

    def __init__(self, engine, *, topk: int = 10):
        if not 0 < topk <= engine.n_items:
            raise ValueError(
                f"topk must be in [1, {engine.n_items}], got {topk}"
            )
        self.engine = engine
        self.topk = topk
        self._pending: List[Tuple[int, int]] = []  # (ticket, user_id)
        self._next_ticket = 0

    def submit(self, user_id: int) -> int:
        """Enqueue one user's request; returns the ticket to look up in the
        next :meth:`drain`'s result dict."""
        # Validate here, where only the offending request fails — a bad id
        # surfacing inside drain() would take every queued ticket with it.
        uid = int(user_id)
        if not 0 <= uid < self.engine.num_users:
            raise ValueError(
                f"unknown user id {uid} "
                f"(catalog has {self.engine.num_users} users)"
            )
        ticket = self._next_ticket
        self._next_ticket += 1
        self._pending.append((ticket, uid))
        return ticket

    def drain(self) -> Dict[int, Tuple[np.ndarray, np.ndarray]]:
        """Score all pending tickets; returns {ticket: (scores, item_ids)}."""
        if not self._pending:
            return {}
        pending = self._pending
        users = sorted({uid for _, uid in pending})
        scores, idx = self.engine.topk(users, self.topk)
        self._pending = []  # only after scoring: a failure keeps tickets
        by_user = {uid: row for row, uid in enumerate(users)}
        return {
            ticket: (scores[by_user[uid]], idx[by_user[uid]])
            for ticket, uid in pending
        }
