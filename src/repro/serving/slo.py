"""SLO-aware adaptive pruning: the threshold as a live degradation dial.

The paper fixes the pruning threshold after epoch 1 and never touches it
again.  In serving, that constant is actually a *control input*: raising
the threshold truncates more latent factors, which (with the engine's
latent-axis compaction) directly sheds scoring FLOPs, at a ranking cost
``eval/ranking.py`` can measure against the dense oracle.  LLM servers
facing the same overload problem degrade gracefully (shorter contexts,
draft models) instead of admission-rejecting; this module closes the same
loop for pruned MF serving:

::

            ┌────────────────────────────────────────────────┐
            │                SLOController.tick()            │
            │                                                │
    queue ──┤ depth, expired, latency histogram (p50/p99)    │
            │        │                                       │
            │        ▼                                       │
            │  control law: p99 vs budget, depth watermarks  │
            │  quality guardrail: prequential drift hook     │
            │        │                                       │
            │        ▼                                       │
            │  per-priority-class effective pruning rates    │
            │        │  threshold_for_rate (Eq. 7/8 solve)   │
            │        ▼                                       │
            │  engine.swap(t_p=, t_q=)  +  publisher pin     │
            │  router.apply_thresholds (rolling, per replica)│
            └────────────────────────────────────────────────┘

* **Load signals** come from the request queue: its per-request latency
  histogram (:class:`LatencyWindow`, recorded at completion in
  ``RequestQueue._serve_inner``), queue ``depth``, and the ``expired``
  counter.  p99 over budget, depth over the high watermark, or any expiry
  ⇒ degrade (raise the base pruning rate by ``step_up``); comfortably
  under budget ⇒ relax by ``step_down`` (AIMD-flavoured: recover slower
  than you shed).
* **Per-priority-class rates**: background traffic (``priority > 0``)
  carries an extra rate offset, so maintenance work is always served
  more-pruned than interactive traffic.  The threshold actually applied
  to the engine follows the most latency-sensitive class observed in the
  window (one engine serves one ``(t_p, t_q)`` at a time); all class
  rates are reported and replicated as controller state.
* **Quality guardrail**: :meth:`SLOController.quality_hook` plugs into
  :meth:`repro.eval.prequential.PrequentialEvaluator.add_drift_hook` —
  when windowed prequential error creeps past
  ``quality_bound * ema`` the next tick relaxes instead of degrading,
  whatever the load says.  Latency SLOs never get to silently destroy
  model quality.
* **Application** goes through the existing full-rebuild swap path
  (``engine.swap(params, t_p, t_q)``), pins the publisher's serving
  thresholds (so subsequent snapshot publishes don't revert the
  degradation), and rolls across a fleet one replica at a time
  (:meth:`repro.serving.fleet.router.Router.apply_thresholds`) — exactly
  the discipline model refreshes use.

``benchmarks/bench_slo.py`` maps the resulting throughput/NDCG@K frontier
and replays an overload scenario; ``launch/serve.py --slo-p99-ms`` turns
the loop on for real traffic and exits non-zero if the budget is violated
at steady state.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, Dict, List, Mapping, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.threshold import (
    empirical_pruned_fraction,
    measure_stats,
    threshold_for_rate,
)


class LatencyWindow:
    """Thread-safe ring buffer of per-request ``(latency, priority)`` pairs.

    The queue records one entry per completed request; the controller reads
    percentiles over the surviving window.  ``count`` is the *monotonic*
    total ever recorded (not the window occupancy), so a tick can compute
    "requests completed since my last tick" without a second counter.
    """

    def __init__(self, capacity: int = 2048):
        if capacity <= 0:
            raise ValueError(f"capacity must be positive, got {capacity}")
        self.capacity = capacity
        self._lat = np.zeros(capacity, np.float64)
        self._prio = np.zeros(capacity, np.int32)
        self._pos = 0
        self._filled = 0
        self._total = 0
        self._lock = threading.Lock()

    def record(self, latency_s: float, priority: int = 0) -> None:
        """Append one completed request's queue-to-completion latency."""
        with self._lock:
            self._lat[self._pos] = latency_s
            self._prio[self._pos] = priority
            self._pos = (self._pos + 1) % self.capacity
            self._filled = min(self._filled + 1, self.capacity)
            self._total += 1

    @property
    def count(self) -> int:
        """Total requests ever recorded (monotonic)."""
        with self._lock:
            return self._total

    def snapshot(self) -> Tuple[np.ndarray, np.ndarray]:
        """Copies of the windowed ``(latencies_s, priorities)`` arrays."""
        with self._lock:
            n = self._filled
            return self._lat[:n].copy(), self._prio[:n].copy()

    def percentile(self, p: float, *, priority: Optional[int] = None) -> float:
        """Windowed latency percentile in seconds (NaN when empty);
        ``priority`` restricts to one request class."""
        lat, prio = self.snapshot()
        if priority is not None:
            lat = lat[prio == priority]
        if lat.size == 0:
            return float("nan")
        return float(np.percentile(lat, p))


@dataclasses.dataclass(frozen=True)
class SLOConfig:
    """Knobs of the closed loop (see module docstring for the control law).

    ``p99_budget_ms`` is the deadline budget p99 is held under.  Rates are
    pruning fractions in [0, 1]; ``max_rate`` caps degradation (the floor
    on quality), ``min_rate=None`` floors relaxation at the model's own
    trained pruning rate (measured at attach time) rather than 0.
    """

    p99_budget_ms: float = 50.0
    max_rate: float = 0.8
    min_rate: Optional[float] = None
    step_up: float = 0.15        # additive degrade per overloaded tick
    step_down: float = 0.05      # additive relax per comfortable tick
    relax_margin: float = 0.5    # relax only when p99 < margin * budget
    depth_high: int = 64         # queue depth that alone means overload
    depth_low: int = 4
    min_window: int = 16         # completed requests a tick needs to act
    rate_eps: float = 0.01       # smallest rate move worth a re-solve+swap
    tick_interval_s: float = 0.1
    background_offset: float = 0.15   # extra rate for priority > 0 traffic
    class_offsets: Mapping[int, float] = dataclasses.field(
        default_factory=dict
    )
    quality_bound: float = 1.25  # window err > bound * ema err => relax
    quality_min_events: int = 64


@dataclasses.dataclass(frozen=True)
class SLODecision:
    """One tick's observation + action, kept on ``controller.decisions``."""

    tick: int
    action: str              # "degrade" | "relax" | "quality_relax" | "hold"
    p50_ms: float
    p99_ms: float
    depth: int
    expired: int             # expirations since the previous tick
    completed: int           # completions since the previous tick
    base_rate: float
    rates: Dict[int, float]  # per-priority-class effective rates
    applied_class: int
    applied_rate: float
    t_p: float
    t_q: float
    swapped: bool            # thresholds actually re-solved and applied

    def as_dict(self) -> Dict[str, object]:
        """Flat form for JSON reports."""
        d = dataclasses.asdict(self)
        d["rates"] = {str(c): r for c, r in self.rates.items()}
        return d


class SLOController:
    """Closed-loop pruning-rate controller for one serving deployment.

    ``engine`` is the co-located primary (may be None for a fleet-only
    topology); ``queue`` supplies load signals (its :class:`LatencyWindow`,
    ``depth`` and ``expired`` counters) — pass an explicit ``window`` /
    ``depth_fn`` / ``expired_fn`` instead when latency is observed
    elsewhere (e.g. client-side, for process-replica fleets).
    ``publisher`` gets its serving thresholds pinned on every apply so
    snapshot publishes cannot revert a degradation; ``router`` receives
    every decision as a rolling per-replica threshold update.

    ``tick()`` runs one observe→decide→apply cycle; ``maybe_tick()``
    rate-limits it to ``config.tick_interval_s`` for call sites that tick
    from a hot loop.  Thread-safe; applies serialize on an internal lock.
    """

    def __init__(
        self,
        engine=None,
        *,
        config: Optional[SLOConfig] = None,
        queue=None,
        window: Optional[LatencyWindow] = None,
        depth_fn: Optional[Callable[[], int]] = None,
        expired_fn: Optional[Callable[[], int]] = None,
        publisher=None,
        router=None,
        params_fn: Optional[Callable[[], object]] = None,
    ):
        self.config = config or SLOConfig()
        self.engine = engine
        self.queue = queue
        self.publisher = publisher
        self.router = router
        self._params_fn = params_fn
        if window is None:
            window = queue.latency if queue is not None else LatencyWindow()
        self.window = window
        self._depth_fn = depth_fn or self._default_depth
        self._expired_fn = expired_fn or self._default_expired
        self._lock = threading.Lock()
        self._last_count = 0
        self._last_expired = 0
        self._last_tick_at = 0.0
        self._quality_pressure = False
        self.ticks = 0
        self.degrades = 0
        self.relaxes = 0
        self.quality_relaxes = 0
        self.swaps = 0
        self.decisions: List[SLODecision] = []

        params = self._params()
        measured = float(
            empirical_pruned_fraction(params.q, self._initial_t_q())
        )
        floor = (
            measured if self.config.min_rate is None
            else float(self.config.min_rate)
        )
        self.floor_rate = min(floor, self.config.max_rate)
        self.base_rate = self.floor_rate
        # thresholds currently applied (None until the first apply)
        self.applied: Optional[Tuple[float, float]] = None
        self._applied_rate: Optional[float] = None

    # -- signal / state plumbing --------------------------------------------
    def _default_depth(self) -> int:
        if self.queue is not None:
            return self.queue.depth
        if self.router is not None:
            return sum(r.depth() for r in self.router.replicas)
        if self.engine is not None:
            return self.engine.queue_depth
        return 0

    def _default_expired(self) -> int:
        return 0 if self.queue is None else self.queue.expired

    def _params(self):
        """Factor tables the threshold solve measures — primary engine,
        else the updater behind the publisher, else a local replica."""
        if self.engine is not None:
            return self.engine.params
        if self._params_fn is not None:
            return self._params_fn()
        if self.publisher is not None and self.publisher.updater is not None:
            return self.publisher.updater.params
        if self.router is not None:
            for rep in self.router.replicas:
                eng = getattr(rep, "engine", None)
                if eng is not None:
                    return eng.params
        raise ValueError(
            "SLOController needs an engine, params_fn, publisher, or a "
            "fleet with at least one in-process replica to measure factor "
            "statistics from"
        )

    def _initial_t_q(self) -> float:
        if self.engine is not None:
            return float(self.engine.t_q)
        if self.publisher is not None and self.publisher.updater is not None:
            return float(self.publisher.updater.t_q)
        if self.router is not None:
            for rep in self.router.replicas:
                eng = getattr(rep, "engine", None)
                if eng is not None:
                    return float(eng.t_q)
        return 0.0

    # -- per-class rates -----------------------------------------------------
    def _class_offset(self, priority: int) -> float:
        if priority in self.config.class_offsets:
            return float(self.config.class_offsets[priority])
        return self.config.background_offset if priority > 0 else 0.0

    def effective_rates(
        self, classes: Optional[Tuple[int, ...]] = None
    ) -> Dict[int, float]:
        """Per-priority-class pruning rate: base + class offset, clamped to
        ``[floor_rate, max_rate]``.  Background classes are always served
        at least as pruned as interactive traffic."""
        if classes is None:
            classes = tuple(sorted({0, *self.config.class_offsets}))
        return {
            int(c): float(
                np.clip(
                    self.base_rate + self._class_offset(int(c)),
                    self.floor_rate,
                    self.config.max_rate,
                )
            )
            for c in classes
        }

    # -- quality guardrail ---------------------------------------------------
    def note_quality(self, stats) -> None:
        """Feed one :class:`~repro.eval.prequential.PrequentialStats`; flags
        quality pressure when the windowed error has crept past
        ``quality_bound`` times the long-term EMA."""
        cfg = self.config
        if (
            stats.events >= cfg.quality_min_events
            and stats.window_events > 0
            and np.isfinite(stats.ema_mae)
            and stats.ema_mae > 0
            and stats.window_mae > cfg.quality_bound * stats.ema_mae
        ):
            self._quality_pressure = True

    def quality_hook(self) -> Callable:
        """A drift hook for
        :meth:`~repro.eval.prequential.PrequentialEvaluator.add_drift_hook`:
        forwards prequential stats into :meth:`note_quality`."""
        def hook(stats):
            self.note_quality(stats)
        hook.controller = self
        return hook

    # -- the loop ------------------------------------------------------------
    def maybe_tick(self) -> Optional[SLODecision]:
        """Run :meth:`tick` if ``tick_interval_s`` has elapsed (hot-loop
        call sites); returns None when skipped."""
        now = time.monotonic()
        if now - self._last_tick_at < self.config.tick_interval_s:
            return None
        return self.tick()

    def tick(self) -> SLODecision:
        """One observe → decide → (solve + apply) cycle."""
        cfg = self.config
        with self._lock:
            self._last_tick_at = time.monotonic()
            total = self.window.count
            completed = total - self._last_count
            self._last_count = total
            expired_total = int(self._expired_fn())
            expired = expired_total - self._last_expired
            self._last_expired = expired_total
            depth = int(self._depth_fn())
            lat, prio = self.window.snapshot()
            p50_ms = float(np.percentile(lat, 50) * 1e3) if lat.size else float("nan")
            p99_ms = float(np.percentile(lat, 99) * 1e3) if lat.size else float("nan")

            have_latency = completed >= cfg.min_window and np.isfinite(p99_ms)
            overloaded = (
                (have_latency and p99_ms > cfg.p99_budget_ms)
                or depth >= cfg.depth_high
                or expired > 0
            )
            comfortable = (
                have_latency
                and p99_ms < cfg.relax_margin * cfg.p99_budget_ms
                and depth <= cfg.depth_low
                and expired == 0
            )
            action = "hold"
            if self._quality_pressure:
                # model quality is drifting: relax regardless of load —
                # latency SLOs don't get to silently destroy accuracy
                self.base_rate = max(
                    self.floor_rate, self.base_rate - cfg.step_down
                )
                action = "quality_relax"
                self.quality_relaxes += 1
                self._quality_pressure = False
            elif overloaded:
                self.base_rate = min(
                    cfg.max_rate, self.base_rate + cfg.step_up
                )
                action = "degrade"
                self.degrades += 1
            elif comfortable and self.base_rate > self.floor_rate:
                self.base_rate = max(
                    self.floor_rate, self.base_rate - cfg.step_down
                )
                action = "relax"
                self.relaxes += 1

            # the engine serves ONE (t_p, t_q); follow the most
            # latency-sensitive class seen in the window (default class 0)
            seen = tuple(sorted(set(int(c) for c in prio))) or (0,)
            applied_class = min(seen)
            rates = self.effective_rates(
                tuple(sorted({*seen, 0, *self.config.class_offsets}))
            )
            applied_rate = rates[applied_class]

            swapped = False
            if (
                self._applied_rate is None
                or abs(applied_rate - self._applied_rate) >= cfg.rate_eps
            ):
                t_p, t_q = self._solve(applied_rate)
                self._apply(t_p, t_q)
                self._applied_rate = applied_rate
                self.applied = (t_p, t_q)
                self.swaps += 1
                swapped = True
            t_p, t_q = self.applied if self.applied is not None else (0.0, 0.0)

            self.ticks += 1
            decision = SLODecision(
                tick=self.ticks,
                action=action,
                p50_ms=p50_ms,
                p99_ms=p99_ms,
                depth=depth,
                expired=expired,
                completed=completed,
                base_rate=float(self.base_rate),
                rates=rates,
                applied_class=applied_class,
                applied_rate=float(applied_rate),
                t_p=float(t_p),
                t_q=float(t_q),
                swapped=swapped,
            )
            self.decisions.append(decision)
            return decision

    # -- solve + apply -------------------------------------------------------
    def _solve(self, rate: float) -> Tuple[float, float]:
        """Pruning rate -> (t_p, t_q) via the paper's Eq. 7/8 solve against
        the *current* factor statistics (re-measured per solve, so online
        drift in the tables is tracked)."""
        params = self._params()
        if rate <= 0.0:
            return 0.0, 0.0  # exact dense parity, no fitted-normal residue
        t_p = float(threshold_for_rate(measure_stats(params.p), rate))
        t_q = float(threshold_for_rate(measure_stats(params.q), rate))
        return t_p, t_q

    def _apply(self, t_p: float, t_q: float) -> None:
        """Push thresholds everywhere a stale copy could serve from:
        primary engine (full-rebuild swap), publisher pin (so the next
        snapshot publish keeps them), rolling fleet fan-out."""
        if self.engine is not None:
            self.engine.swap(
                self.engine.params,
                jnp.float32(t_p), jnp.float32(t_q),
                user_history=self.engine.user_history,
            )
        if self.publisher is not None:
            self.publisher.set_serving_thresholds(t_p, t_q)
        if self.router is not None:
            self.router.apply_thresholds(t_p, t_q)

    # -- reporting -----------------------------------------------------------
    def report(self) -> Dict[str, object]:
        """JSON-friendly controller summary for launchers and benches."""
        last = self.decisions[-1] if self.decisions else None
        return {
            "ticks": self.ticks,
            "degrades": self.degrades,
            "relaxes": self.relaxes,
            "quality_relaxes": self.quality_relaxes,
            "swaps": self.swaps,
            "p99_budget_ms": self.config.p99_budget_ms,
            "floor_rate": self.floor_rate,
            "max_rate": self.config.max_rate,
            "base_rate": float(self.base_rate),
            "applied_rate": (
                None if self._applied_rate is None
                else float(self._applied_rate)
            ),
            "applied_t_p": None if self.applied is None else self.applied[0],
            "applied_t_q": None if self.applied is None else self.applied[1],
            "rates": {
                str(c): r for c, r in self.effective_rates().items()
            },
            "last_decision": None if last is None else last.as_dict(),
        }
