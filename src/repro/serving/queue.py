"""Async request pipeline in front of the serving engine.

``MicroBatcher`` (``serving/batching.py``) batches synchronously: the caller
owns the flush.  Production traffic is concurrent — many callers, none of
whom should flush anyone else's work — so the queue here is the continuous
batching loop rtp-llm-style LLM servers run: requests enter from any thread,
a single scheduler thread repeatedly pops the best batch and scores it while
new arrivals accumulate behind it, and every caller gets a
``concurrent.futures.Future`` to poll or block on.

Scheduling policy (deterministic, and what the tests pin down):

* requests are ordered by **(deadline bucket, priority, arrival)** —
  deadlines are quantized into ``deadline_bucket_ms`` buckets, and within a
  bucket lower ``priority`` values go first (priority 0 is the default
  request class; online maintenance work submits at low priority, e.g. 10,
  so model-refresh traffic can never crowd out user requests, while a
  deadline that is a whole bucket earlier still wins regardless of class);
  a batch is formed from the winning request's ``topk`` **bucket** (mixing
  topk values in one launch would change the compiled program shape), taking
  up to ``max_batch`` same-bucket requests in that order;
* within a batch, duplicate user ids are scored once and fanned back out;
  futures resolve in deadline order;
* **admission control**: at ``max_pending`` queued requests ``submit`` either
  raises :class:`QueueFullError` or, with ``block=True``, waits for space —
  backpressure instead of unbounded memory;
* **timeouts**: a request whose deadline passes before it is *scheduled*
  fails with :class:`RequestTimeout`; a request already in a scoring launch
  completes (the launch is paid for either way);
* results are byte-identical to calling ``engine.topk([user], topk)``
  sequentially — batching never changes numerics, only wall-clock.

The scheduler thread is the only thread that touches the engine, so the
engine itself needs no locking for the async path.
"""
from __future__ import annotations

import heapq
import itertools
import threading
import time
from concurrent.futures import Future
from dataclasses import dataclass, field
from typing import Callable, List, Optional

import numpy as np

from repro.serving.slo import LatencyWindow

_INF = float("inf")


class QueueFullError(RuntimeError):
    """Admission control rejected the request: ``max_pending`` reached."""


class RequestTimeout(TimeoutError):
    """The request's deadline passed before a scheduler slot reached it."""


@dataclass(order=True)
class _Pending:
    bucket: float                        # quantized deadline (inf = none)
    priority: int                        # lower = scheduled sooner
    seq: int
    deadline: float = field(compare=False)   # exact deadline, for expiry
    topk: int = field(compare=False)
    user_id: int = field(compare=False)
    future: Future = field(compare=False)
    submitted: float = field(compare=False, default=0.0)  # arrival time


def _fail(fut: Future, exc: Exception) -> None:
    """set_exception tolerating a future the caller already cancelled —
    an InvalidStateError here would kill the scheduler thread."""
    try:
        fut.set_exception(exc)
    except Exception:  # noqa: BLE001 - cancelled/raced future: nothing to do
        pass


class RequestQueue:
    """Continuous-batching scheduler over a :class:`ServingEngine`.

    ``submit(user_id, topk, timeout=...)`` returns a ``Future`` resolving to
    ``(scores, item_ids)`` — two (topk,) numpy rows, exactly the caller's row
    of :meth:`ServingEngine.topk`.  ``score_fn(users, topk)`` overrides the
    scoring callable (e.g. a mesh-bound ``topk_sharded``); it must accept a
    sorted list of unique user ids and return ``(B, topk)`` arrays.

    ``linger_ms`` trades a bounded scheduling delay for larger batches: the
    scheduler waits that long (or until ``max_batch`` requests are queued)
    before popping a batch.  Leave it at 0 for latency-critical paths —
    continuous batching already coalesces whatever arrives while the previous
    launch is in flight.

    ``deadline_bucket_ms`` quantizes deadlines for the priority comparison:
    requests whose deadlines fall in the same bucket are ordered by
    ``priority`` (then arrival), so a latency-insensitive background request
    cannot jump ahead of user traffic just by carrying a marginally earlier
    deadline, while genuinely earlier deadlines still dominate.  Set it to 0
    to recover strict earliest-deadline-first with priority as a tiebreak.

    ``start=False`` skips the scheduler thread; tests (and anyone wanting
    strict determinism) call :meth:`drain_once` manually.
    """

    def __init__(
        self,
        engine,
        *,
        score_fn: Optional[Callable] = None,
        max_batch: Optional[int] = None,
        max_pending: int = 4096,
        linger_ms: float = 0.0,
        deadline_bucket_ms: float = 50.0,
        latency_window: int = 2048,
        start: bool = True,
    ):
        if max_pending <= 0:
            raise ValueError(f"max_pending must be positive, got {max_pending}")
        self.engine = engine
        self._score = score_fn if score_fn is not None else engine.topk
        self.max_batch = max_batch if max_batch is not None else engine.max_batch
        self.max_pending = max_pending
        self.linger_s = linger_ms / 1e3
        self.bucket_s = deadline_bucket_ms / 1e3
        self._cond = threading.Condition()
        self._heap: List[_Pending] = []
        self._seq = itertools.count()
        self._closed = False
        self._scoring = 0  # requests inside the current scoring launch
        # bench / observability counters
        self.requests_served = 0
        self.batches_served = 0
        self.expired = 0
        self.rejected = 0
        # per-request submit->completion latency histogram over the last
        # ``latency_window`` requests — the SLO controller's p50/p99 signal
        self.latency = LatencyWindow(latency_window)
        self._thread: Optional[threading.Thread] = None
        if start:
            self.start()

    # -- lifecycle -----------------------------------------------------------
    @property
    def closed(self) -> bool:
        """True once :meth:`close` ran — the queue rejects new submits and
        the engine's ``start()`` may build a fresh one."""
        with self._cond:
            return self._closed

    def start(self) -> None:
        """Launch the scheduler thread (idempotent; ``start=False``
        constructions call this, or drive :meth:`drain_once` manually)."""
        if self._thread is not None:
            return
        self._thread = threading.Thread(
            target=self._loop, name="serving-scheduler", daemon=True
        )
        self._thread.start()

    def close(self, *, cancel_pending: bool = False) -> None:
        """Stop accepting requests.  Pending work is drained (scored) before
        the scheduler exits, unless ``cancel_pending`` fails it fast."""
        with self._cond:
            self._closed = True
            if cancel_pending:
                for req in self._heap:
                    _fail(
                        req.future,
                        RequestTimeout("queue closed before request was scheduled"),
                    )
                self._heap.clear()
            self._cond.notify_all()
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        else:
            while self.drain_once():
                pass
            with self._cond:  # anything left is expired-only residue: fail it
                for req in self._heap:
                    _fail(
                        req.future,
                        RequestTimeout("queue closed before request was scheduled"),
                    )
                self._heap.clear()

    def abort(self, exc: Exception) -> None:
        """Crash-stop (the chaos harness's simulated replica death): fail
        every queued request with ``exc`` — not the graceful-drain
        ``RequestTimeout`` — reject new submits, and stop the scheduler
        without scoring the backlog.  A batch already mid-score completes
        (its callers see results), matching a real process whose in-flight
        work raced the crash."""
        with self._cond:
            self._closed = True
            for req in self._heap:
                _fail(req.future, exc)
            self._heap.clear()
            self._cond.notify_all()
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def __enter__(self) -> "RequestQueue":
        return self

    def __exit__(self, *exc) -> None:
        self.close(cancel_pending=exc[0] is not None)

    def __len__(self) -> int:
        with self._cond:
            return len(self._heap)

    @property
    def depth(self) -> int:
        """Requests queued plus in the current scoring launch — the load
        signal the fleet router balances on (a replica whose scheduler is
        mid-launch is busier than its heap length alone says)."""
        with self._cond:
            return len(self._heap) + self._scoring

    # -- submission ----------------------------------------------------------
    def submit(
        self,
        user_id: int,
        topk: int = 10,
        *,
        timeout: Optional[float] = None,
        priority: int = 0,
        block: bool = False,
        block_timeout: Optional[float] = None,
    ) -> Future:
        """Enqueue one top-k request; returns its ``Future``.

        Validation happens here so a bad request fails its own submit and can
        never poison a batch.  ``timeout`` (seconds) bounds time-to-schedule;
        ``priority`` (lower = sooner) orders requests within a deadline
        bucket — use a high value (e.g. 10) for background/maintenance work;
        ``block=True`` waits up to ``block_timeout`` for queue space instead
        of raising :class:`QueueFullError`.
        """
        # engine validation gives the uniform messages for bad ids / topk
        self.engine._validate_request([user_id], topk)
        deadline = _INF if timeout is None else time.monotonic() + timeout
        bucket = (
            deadline if self.bucket_s <= 0 or deadline == _INF
            else (deadline // self.bucket_s) * self.bucket_s
        )
        fut: Future = Future()
        req = _Pending(
            bucket, int(priority), next(self._seq),
            deadline, int(topk), int(user_id), fut,
            time.monotonic(),
        )
        with self._cond:
            if self._closed:
                raise RuntimeError("queue is closed")
            if len(self._heap) >= self.max_pending and block:
                limit = (
                    _INF if block_timeout is None
                    else time.monotonic() + block_timeout
                )
                while len(self._heap) >= self.max_pending and not self._closed:
                    remaining = limit - time.monotonic()
                    if remaining <= 0 or not self._cond.wait(
                        None if remaining == _INF else remaining
                    ):
                        break
                if self._closed:
                    raise RuntimeError("queue is closed")
            if len(self._heap) >= self.max_pending:
                self.rejected += 1
                raise QueueFullError(
                    f"{self.max_pending} requests already pending"
                )
            heapq.heappush(self._heap, req)
            self._cond.notify_all()
        return fut

    # -- scheduling ----------------------------------------------------------
    def _schedulable_locked(self) -> int:
        """Requests the next :meth:`_pop_batch` would actually schedule:
        un-expired entries in the scheduling-order winner's topk bucket.
        This is what the linger wait must count toward ``max_batch`` —
        counting raw heap length (the old behaviour) ends the linger early
        on expired requests and other-bucket requests that cannot join the
        batch.  Caller holds ``self._cond``."""
        now = time.monotonic()
        best: Optional[_Pending] = None
        for req in self._heap:
            if req.deadline < now:
                continue
            if best is None or req < best:
                best = req
        if best is None:
            return 0
        win = best.topk
        return sum(
            1 for req in self._heap
            if req.deadline >= now and req.topk == win
        )

    def _pop_batch(self) -> List[_Pending]:
        """Pop the next batch under the lock: the scheduling-order winner
        (deadline bucket, then priority, then arrival) defines the topk
        bucket; same-bucket requests join in scheduling order up to
        ``max_batch``.  Expired requests fail here, never score."""
        now = time.monotonic()
        batch: List[_Pending] = []
        skipped: List[_Pending] = []
        dropped = 0
        bucket: Optional[int] = None
        while self._heap and len(batch) < self.max_batch:
            req = heapq.heappop(self._heap)
            if req.deadline < now:
                _fail(
                    req.future,
                    RequestTimeout(
                        f"request for user {req.user_id} expired after "
                        f"waiting in queue"
                    ),
                )
                self.expired += 1
                dropped += 1
                continue
            if bucket is None:
                bucket = req.topk
            if req.topk != bucket:
                skipped.append(req)  # stays PENDING: may be claimed later
                continue
            # claim the future: a caller-side cancel() after this point can
            # no longer race the batch's set_result (RUNNING != cancellable)
            if not req.future.set_running_or_notify_cancel():
                dropped += 1
                continue
            batch.append(req)
        for req in skipped:
            heapq.heappush(self._heap, req)
        if batch or dropped:
            self._cond.notify_all()  # space freed: wake blocked submitters
        return batch

    def _serve(self, batch: List[_Pending]) -> None:
        with self._cond:
            self._scoring = len(batch)
        try:
            self._serve_inner(batch)
        finally:
            with self._cond:
                self._scoring = 0

    def _serve_inner(self, batch: List[_Pending]) -> None:
        topk = batch[0].topk
        users = sorted({req.user_id for req in batch})
        try:
            scores, idx = self._score(users, topk)
            scores = np.asarray(scores)
            idx = np.asarray(idx)
        except Exception as exc:  # noqa: BLE001 - fail the batch, not the loop
            for req in batch:
                _fail(req.future, exc)
            return
        row = {uid: i for i, uid in enumerate(users)}
        done = time.monotonic()
        for req in batch:  # deadline order == batch order
            r = row[req.user_id]
            req.future.set_result((scores[r].copy(), idx[r].copy()))
            self.latency.record(done - req.submitted, priority=req.priority)
        self.requests_served += len(batch)
        self.batches_served += 1

    def drain_once(self) -> int:
        """Pop and score one batch (no waiting).  Returns requests served.
        The manual pump for ``start=False`` queues — one call is exactly one
        scoring launch, so tests can pin batch composition."""
        with self._cond:
            batch = self._pop_batch()
        if not batch:
            return 0
        self._serve(batch)
        return len(batch)

    def _loop(self) -> None:
        try:
            while True:
                with self._cond:
                    while not self._heap and not self._closed:
                        self._cond.wait()
                    if self.linger_s > 0 and self._heap and not self._closed:
                        limit = time.monotonic() + self.linger_s
                        while (
                            self._schedulable_locked() < self.max_batch
                            and not self._closed
                        ):
                            remaining = limit - time.monotonic()
                            if remaining <= 0:
                                break
                            self._cond.wait(remaining)
                    batch = self._pop_batch()
                    if not batch and self._closed and not self._heap:
                        return
                if batch:
                    self._serve(batch)
        finally:
            # A scheduler that exits for ANY reason (normal drain included)
            # must leave no pending future behind: anything still queued is
            # failed loudly rather than stranded forever.  After a normal
            # drain the heap is empty and this is a no-op.
            with self._cond:
                for req in self._heap:
                    _fail(
                        req.future,
                        RuntimeError("scheduler exited with request pending"),
                    )
                self._heap.clear()
                self._closed = True
                self._cond.notify_all()
