"""Serving: batched, pruned top-k recommendation from trained checkpoints."""
from repro.serving.batching import (  # noqa: F401
    LRUCache,
    MicroBatcher,
    bucket_size,
)
from repro.serving.engine import (  # noqa: F401
    ServingEngine,
    load_mf_checkpoint,
)
from repro.serving.queue import (  # noqa: F401
    QueueFullError,
    RequestQueue,
    RequestTimeout,
)
from repro.serving.slo import (  # noqa: F401
    LatencyWindow,
    SLOConfig,
    SLOController,
    SLODecision,
)
