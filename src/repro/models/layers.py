"""Shared neural building blocks (norms, gated MLPs, RoPE, embeddings).

Everything is a pure function over explicit param pytrees — no module
framework — so params stay transparent to pjit partitioning and to the
checkpoint layer.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * (1.0 + scale.astype(jnp.float32))).astype(
        dtype
    )


def rms_norm_lean(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    """Memory-lean RMSNorm (§Perf): the variance is accumulated in f32 via the
    dot-accumulator (no f32 materialization of the (B, S, D) stream), and the
    normalize/scale multiplies stay in the residual dtype.  Halves the
    norm-chain HBM traffic at bf16; numerics differ from :func:`rms_norm` only
    by bf16 rounding of the elementwise products."""
    d = x.shape[-1]
    var = jnp.einsum(
        "...d,...d->...", x, x, preferred_element_type=jnp.float32
    ) / d
    inv = jax.lax.rsqrt(var + eps)[..., None].astype(x.dtype)
    return x * inv * (1.0 + scale).astype(x.dtype)


def layer_norm(
    x: jax.Array, scale: jax.Array, bias: jax.Array, eps: float = 1e-6
) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dtype)


def dense(x: jax.Array, w: jax.Array, b: Optional[jax.Array] = None) -> jax.Array:
    y = jnp.einsum("...d,df->...f", x, w.astype(x.dtype))
    if b is not None:
        y = y + b.astype(y.dtype)
    return y


def gated_mlp(
    x: jax.Array, params: Dict[str, jax.Array], activation: str = "swiglu"
) -> jax.Array:
    """SwiGLU / GeGLU feed-forward: act(x W_g) * (x W_i) W_o."""
    gate = dense(x, params["wg"])
    up = dense(x, params["wi"])
    if activation == "swiglu":
        act = jax.nn.silu(gate)
    elif activation == "geglu":
        act = jax.nn.gelu(gate, approximate=True)
    else:
        raise ValueError(f"unknown activation {activation!r}")
    return dense(act * up, params["wo"])


def mlp(x: jax.Array, params: Dict[str, jax.Array], activation: str = "relu") -> jax.Array:
    """Plain 2-layer MLP (recsys towers)."""
    h = dense(x, params["wi"], params.get("bi"))
    h = jax.nn.relu(h) if activation == "relu" else jax.nn.gelu(h)
    return dense(h, params["wo"], params.get("bo"))


def rope_frequencies(
    head_dim: int, max_pos: int, theta: float = 10000.0
) -> jax.Array:
    """(max_pos, head_dim // 2) complex-free cos/sin table, computed lazily."""
    inv = 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))
    pos = jnp.arange(max_pos, dtype=jnp.float32)
    return jnp.outer(pos, inv)  # (max_pos, head_dim/2)


def apply_rope(
    x: jax.Array,        # (..., seq, heads, head_dim)
    positions: jax.Array,  # (..., seq) int32 absolute positions
    theta: float = 10000.0,
) -> jax.Array:
    head_dim = x.shape[-1]
    inv = 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))
    ang = positions.astype(jnp.float32)[..., None] * inv  # (..., seq, hd/2)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def embed_lookup(table: jax.Array, ids: jax.Array) -> jax.Array:
    return jnp.take(table, ids, axis=0)


def init_dense(rng, d_in: int, d_out: int, *, bias: bool = False, dtype=jnp.float32):
    scale = (2.0 / (d_in + d_out)) ** 0.5
    params = {"w": scale * jax.random.normal(rng, (d_in, d_out), dtype)}
    if bias:
        params["b"] = jnp.zeros((d_out,), dtype)
    return params
