"""Graph attention network (GAT, Velickovic et al. 2018) for the gat-cora arch.

JAX has no sparse SpMM beyond BCOO, so message passing is built from the
edge-index primitive set — gather by src, SDDMM-style edge scores,
segment-softmax over incoming edges, scatter-sum to dst — exactly the
GE-SpMM/FeatGraph regime the kernel taxonomy describes.  The same forward
serves full-batch (cora / ogbn-products shapes), sampled minibatches
(fanout subgraphs from data/graphs.py) and block-diagonal molecule batches.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class GATConfig:
    name: str
    d_feat: int
    n_classes: int
    n_layers: int = 2
    d_hidden: int = 8
    n_heads: int = 8
    negative_slope: float = 0.2
    dtype: Any = jnp.float32

    def layer_dims(self):
        """[(d_in, heads, d_out, concat?)] per layer; last layer averages."""
        dims = []
        d_in = self.d_feat
        for layer in range(self.n_layers):
            last = layer == self.n_layers - 1
            d_out = self.n_classes if last else self.d_hidden
            heads = 1 if last and self.n_layers > 1 else self.n_heads
            dims.append((d_in, heads, d_out, not last))
            d_in = heads * d_out if not last else d_out
        return dims


def init_params(rng, cfg: GATConfig) -> Params:
    layers = []
    for d_in, heads, d_out, _ in cfg.layer_dims():
        rng, kw, ka, kb = jax.random.split(rng, 4)
        scale = (2.0 / (d_in + heads * d_out)) ** 0.5
        layers.append(
            {
                "w": scale * jax.random.normal(kw, (d_in, heads * d_out), cfg.dtype),
                "a_src": 0.1 * jax.random.normal(ka, (heads, d_out), cfg.dtype),
                "a_dst": 0.1 * jax.random.normal(kb, (heads, d_out), cfg.dtype),
                "bias": jnp.zeros((heads * d_out,), cfg.dtype),
            }
        )
    return {"layers": layers}


def _segment_softmax(
    scores: jax.Array, segment_ids: jax.Array, num_segments: int
) -> jax.Array:
    """Numerically-stable softmax over edges grouped by destination node."""
    smax = jax.ops.segment_max(scores, segment_ids, num_segments=num_segments)
    smax = jnp.where(jnp.isfinite(smax), smax, 0.0)  # empty segments
    ex = jnp.exp(scores - smax[segment_ids])
    denom = jax.ops.segment_sum(ex, segment_ids, num_segments=num_segments)
    return ex / (denom[segment_ids] + 1e-9)


def gat_layer(
    x: jax.Array,          # (N, d_in)
    edges: jax.Array,      # (E, 2) [src, dst]; messages flow src -> dst
    layer: Params,
    *,
    heads: int,
    d_out: int,
    concat: bool,
    negative_slope: float,
    edge_mask: jax.Array | None = None,  # (E,) 1/0 for padded edges
) -> jax.Array:
    n = x.shape[0]
    src, dst = edges[:, 0], edges[:, 1]
    h = jnp.einsum("nd,df->nf", x, layer["w"]).reshape(n, heads, d_out)

    e_src = jnp.sum(h * layer["a_src"][None], axis=-1)  # (N, H)
    e_dst = jnp.sum(h * layer["a_dst"][None], axis=-1)
    scores = jax.nn.leaky_relu(e_src[src] + e_dst[dst], negative_slope)  # (E, H)
    if edge_mask is not None:
        scores = jnp.where(edge_mask[:, None] > 0, scores, -1e30)

    alpha = _segment_softmax(scores, dst, n)  # (E, H)
    if edge_mask is not None:
        alpha = alpha * edge_mask[:, None]
    msgs = alpha[..., None] * h[src]  # (E, H, d_out)
    out = jax.ops.segment_sum(msgs, dst, num_segments=n)  # (N, H, d_out)

    if concat:
        return jax.nn.elu(out.reshape(n, heads * d_out) + layer["bias"])
    return jnp.mean(out, axis=1) + layer["bias"]


def forward(
    params: Params,
    x: jax.Array,
    edges: jax.Array,
    cfg: GATConfig,
    edge_mask: jax.Array | None = None,
) -> jax.Array:
    for layer, (d_in, heads, d_out, concat) in zip(
        params["layers"], cfg.layer_dims()
    ):
        x = gat_layer(
            x,
            edges,
            layer,
            heads=heads,
            d_out=d_out,
            concat=concat,
            negative_slope=cfg.negative_slope,
            edge_mask=edge_mask,
        )
    return x  # (N, n_classes) logits


def loss_fn(
    params: Params, batch: Dict[str, jax.Array], cfg: GATConfig
) -> jax.Array:
    """Masked node-classification cross entropy (labels < 0 ignored)."""
    logits = forward(
        params, batch["features"], batch["edges"], cfg, batch.get("edge_mask")
    ).astype(jnp.float32)
    labels = batch["labels"]
    mask = (labels >= 0).astype(jnp.float32)
    safe = jnp.maximum(labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, safe[:, None], axis=-1)[:, 0]
    return jnp.sum((logz - gold) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
