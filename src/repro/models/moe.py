"""Mixture-of-Experts FFN with sort-based (dropping) dispatch and EP sharding.

Dispatch is the static-shape sort/permute formulation (no (T, E, C) one-hot):
tokens are ordered by assigned expert, placed into per-expert capacity
buffers, processed by a batched expert einsum (experts shardable over the
"model" mesh axis — EP), and combined back with gate weights.  Overflowing
tokens are dropped (standard GShard-style capacity semantics); shared experts
(DeepSeek-style) bypass routing entirely.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import gated_mlp


class MoEConfig(NamedTuple):
    num_experts: int
    top_k: int
    d_ff: int                      # per-expert hidden
    num_shared: int = 0            # always-on experts (DeepSeek)
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01


def init_moe_params(
    rng, d_model: int, cfg: MoEConfig, *, activation: str = "swiglu", dtype=jnp.float32
) -> Dict[str, jax.Array]:
    ks = jax.random.split(rng, 5)
    e, f = cfg.num_experts, cfg.d_ff
    scale_in = d_model ** -0.5
    scale_out = f ** -0.5
    params = {
        "router": scale_in * jax.random.normal(ks[0], (d_model, e), jnp.float32),
        "wg": scale_in * jax.random.normal(ks[1], (e, d_model, f), dtype),
        "wi": scale_in * jax.random.normal(ks[2], (e, d_model, f), dtype),
        "wo": scale_out * jax.random.normal(ks[3], (e, f, d_model), dtype),
    }
    if cfg.num_shared:
        sf = cfg.num_shared * f
        kg, ki, ko = jax.random.split(ks[4], 3)
        params["shared"] = {
            "wg": scale_in * jax.random.normal(kg, (d_model, sf), dtype),
            "wi": scale_in * jax.random.normal(ki, (d_model, sf), dtype),
            "wo": sf ** -0.5 * jax.random.normal(ko, (sf, d_model), dtype),
        }
    return params


def _capacity(num_tokens: int, cfg: MoEConfig) -> int:
    cap = int(num_tokens * cfg.top_k * cfg.capacity_factor / cfg.num_experts)
    return max(cap, cfg.top_k)


def moe_ffn(
    x: jax.Array,
    params: Dict[str, jax.Array],
    cfg: MoEConfig,
    *,
    activation: str = "swiglu",
    use_shard_map: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Dispatch entry point.  ``use_shard_map`` selects the replicated-
    dispatch EP formulation (moe_ffn_shard_map) when an ambient mesh with a
    "model" axis is set; otherwise falls back to the XLA-SPMD path."""
    if use_shard_map:
        from repro.distributed import mesh_compat

        am = mesh_compat.get_abstract_mesh()
        if (
            am is not None
            and "model" in getattr(am, "axis_names", ())
            and cfg.num_experts % am.shape["model"] == 0
        ):
            return moe_ffn_shard_map(x, params, cfg, activation=activation, mesh=am)
    return moe_ffn_xla(x, params, cfg, activation=activation)


def moe_ffn_xla(
    x: jax.Array,  # (T, d) flattened tokens
    params: Dict[str, jax.Array],
    cfg: MoEConfig,
    *,
    activation: str = "swiglu",
) -> Tuple[jax.Array, jax.Array]:
    """Returns (output (T, d), aux_loss ()) — aux is the standard load-balance
    loss (mean fraction * mean router prob per expert, scaled by E)."""
    t, d = x.shape
    e, k = cfg.num_experts, cfg.top_k
    cap = _capacity(t, cfg)

    logits = jnp.einsum("td,de->te", x.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)  # (T, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # Load-balance aux loss (Switch/GShard form).
    density = jnp.mean(
        jnp.sum(jax.nn.one_hot(expert_ids, e, dtype=jnp.float32), axis=1), axis=0
    ) / k
    router_mean = jnp.mean(probs, axis=0)
    aux = cfg.router_aux_weight * e * jnp.sum(density * router_mean)

    # ---- sort-based dispatch -------------------------------------------
    flat_expert = expert_ids.reshape(-1)              # (T*k,)
    flat_token = jnp.arange(t * k, dtype=jnp.int32) // k
    flat_gate = gate_vals.reshape(-1)

    order = jnp.argsort(flat_expert, stable=True)     # group by expert
    sorted_expert = flat_expert[order]
    counts = jnp.bincount(flat_expert, length=e)
    starts = jnp.cumsum(counts) - counts              # exclusive prefix
    pos_in_expert = jnp.arange(t * k, dtype=jnp.int32) - starts[sorted_expert]

    keep = pos_in_expert < cap
    slot = jnp.where(keep, sorted_expert * cap + pos_in_expert, e * cap)

    # Scatter tokens into (E*cap + 1, d); the extra row absorbs drops.
    buf = jnp.zeros((e * cap + 1, d), x.dtype)
    buf = buf.at[slot].set(x[flat_token[order]])
    buf = buf[: e * cap].reshape(e, cap, d)

    # ---- expert compute (EP: leading axis shards over "model") ---------
    def expert_fn(xb, wg, wi, wo):
        return gated_mlp(xb, {"wg": wg, "wi": wi, "wo": wo}, activation)

    out_buf = jax.vmap(expert_fn)(buf, params["wg"], params["wi"], params["wo"])
    out_buf = jnp.concatenate(
        [out_buf.reshape(e * cap, d), jnp.zeros((1, d), x.dtype)], axis=0
    )

    # ---- combine ---------------------------------------------------------
    gathered = out_buf[slot] * (flat_gate[order] * keep.astype(jnp.float32))[
        :, None
    ].astype(x.dtype)
    combined = jnp.zeros((t, d), x.dtype).at[flat_token[order]].add(gathered)

    if "shared" in params:
        combined = combined + gated_mlp(x, params["shared"], activation)
    return combined, aux


# ---------------------------------------------------------------------------
# shard_map replicated-dispatch EP (§Perf iteration 1)
# ---------------------------------------------------------------------------
#
# The XLA-SPMD lowering of the sort/scatter dispatch materializes the
# (T*top_k, d) gathered-token buffers REPLICATED along the model axis and
# all-reduces them (~50 GB each at the deepseek train_4k shape — measured in
# EXPERIMENTS.md §Perf).  But with tokens sharded over the data axes, every
# model rank already holds a full copy of its data shard's tokens, so expert
# parallelism needs no token exchange at all:
#
#   * each model rank routes its local token block (routing is cheap),
#   * keeps only the pairs whose expert lives in its local expert slab,
#   * runs its local experts,
#   * and ONE psum over "model" combines the partial outputs (each token's
#     top-k experts live on <= k ranks; other ranks contribute zeros).
#
# Collectives per layer drop from O(T*k*d) all-reduces to a single (T_loc, d)
# psum — ~300x less ICI traffic at the deepseek shape.  Capacity becomes
# per-data-shard (the standard formulation in real EP systems).


def moe_ffn_shard_map(
    x: jax.Array,  # (T, d)
    params: Dict[str, jax.Array],
    cfg: MoEConfig,
    *,
    activation: str = "swiglu",
    mesh=None,
) -> Tuple[jax.Array, jax.Array]:
    from jax.sharding import PartitionSpec as P

    from repro.distributed import mesh_compat

    mesh = mesh_compat.resolve_mesh(mesh)
    if mesh is None:
        raise ValueError(
            "moe_ffn_shard_map needs a mesh: pass mesh= or enter a "
            "mesh_compat.use_mesh(...) context"
        )
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    n_model = mesh.shape["model"]
    e_loc = cfg.num_experts // n_model
    e, k = cfg.num_experts, cfg.top_k

    def body(x_blk, router, wg, wi, wo):
        t_loc, d = x_blk.shape
        cap = max(int(t_loc * k * cfg.capacity_factor / e), k)

        logits = jnp.einsum("td,de->te", x_blk.astype(jnp.float32), router)
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, expert_ids = jax.lax.top_k(probs, k)
        gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

        density = jnp.mean(
            jnp.sum(jax.nn.one_hot(expert_ids, e, dtype=jnp.float32), axis=1),
            axis=0,
        ) / k
        router_mean = jnp.mean(probs, axis=0)
        aux = cfg.router_aux_weight * e * jnp.sum(density * router_mean)
        if dp:
            aux = jax.lax.pmean(aux, dp)

        flat_expert = expert_ids.reshape(-1)
        flat_token = jnp.arange(t_loc * k, dtype=jnp.int32) // k
        flat_gate = gate_vals.reshape(-1)

        order = jnp.argsort(flat_expert, stable=True)
        sorted_expert = flat_expert[order]
        counts = jnp.bincount(flat_expert, length=e)
        starts = jnp.cumsum(counts) - counts
        pos = jnp.arange(t_loc * k, dtype=jnp.int32) - starts[sorted_expert]

        off = jax.lax.axis_index("model").astype(jnp.int32) * e_loc
        local = (sorted_expert >= off) & (sorted_expert < off + e_loc)
        keep = local & (pos < cap)
        slot = jnp.where(keep, (sorted_expert - off) * cap + pos, e_loc * cap)

        buf = jnp.zeros((e_loc * cap + 1, d), x_blk.dtype)
        buf = buf.at[slot].set(x_blk[flat_token[order]])
        buf = buf[: e_loc * cap].reshape(e_loc, cap, d)

        def expert_fn(xb, g, i, o):
            return gated_mlp(xb, {"wg": g, "wi": i, "wo": o}, activation)

        out_buf = jax.vmap(expert_fn)(buf, wg, wi, wo)
        out_buf = jnp.concatenate(
            [out_buf.reshape(e_loc * cap, d), jnp.zeros((1, d), x_blk.dtype)],
            axis=0,
        )
        gathered = out_buf[slot].astype(jnp.float32) * (
            flat_gate[order] * keep.astype(jnp.float32)
        )[:, None]
        out_loc = (
            jnp.zeros((t_loc, d), jnp.float32)
            .at[flat_token[order]]
            .add(gathered)
        )
        out = jax.lax.psum(out_loc, "model").astype(x_blk.dtype)
        return out, aux[None]

    out, aux = mesh_compat.shard_map(
        body,
        mesh=mesh,
        in_specs=(
            P(dp if dp else None, None),
            P(None, None),
            P("model", None, None),
            P("model", None, None),
            P("model", None, None),
        ),
        out_specs=(P(dp if dp else None, None), P(None)),
        check_vma=False,
    )(x, params["router"], params["wg"], params["wi"], params["wo"])
    combined = out
    if "shared" in params:
        combined = combined + gated_mlp(x, params["shared"], activation)
    return combined, aux[0]
