"""Attention: GQA (with optional qk-norm / biases), MLA, KV caches, and a
memory-chunked causal attention usable at 32k prefill without materializing
the full (S, S) score matrix per head.

Chunked attention scans over query blocks; each block materializes only a
(chunk, S) score slice (rematerialized in the backward pass), which is the
structural property FlashAttention provides on real hardware — compute stays
O(S^2), live memory O(chunk * S).  Decode attends one query against the
cache: O(S) compute, which is why the 500k long-context *decode* cells are
runnable with full attention (DESIGN.md §4).
"""
from __future__ import annotations

import functools
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, dense, rms_norm

NEG_INF = -2.0e38


class KVCache(NamedTuple):
    """Decode-time cache.  For GQA: k/v are (B, S, KH, hd).  For MLA the
    compressed cache is (B, S, kv_lora) + (B, S, rope_dim) — MLA's point is
    exactly that the cache holds the low-rank latent, not full K/V."""

    k: jax.Array
    v: jax.Array
    length: jax.Array  # () int32 — tokens currently valid


def _grouped_scores(q: jax.Array, k: jax.Array) -> jax.Array:
    """q: (B, Sq, H, hd), k: (B, Skv, KH, hd) -> (B, H, Sq, Skv) with GQA
    head grouping (H == KH * group)."""
    b, sq, h, hd = q.shape
    kh = k.shape[2]
    group = h // kh
    qg = q.reshape(b, sq, kh, group, hd)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", qg, k)
    return scores.reshape(b, h, sq, k.shape[1])


def _grouped_combine(probs: jax.Array, v: jax.Array) -> jax.Array:
    """probs: (B, H, Sq, Skv), v: (B, Skv, KH, hd) -> (B, Sq, H, hd)."""
    b, h, sq, skv = probs.shape
    kh = v.shape[2]
    group = h // kh
    pg = probs.reshape(b, kh, group, sq, skv)
    out = jnp.einsum("bkgqs,bskh->bqkgh", pg, v)
    return out.reshape(b, sq, h, v.shape[3])


def causal_attention(
    q: jax.Array,  # (B, S, H, hd)
    k: jax.Array,  # (B, S, KH, hd)
    v: jax.Array,  # (B, S, KH, hd)
    *,
    chunk_size: int = 1024,
    softmax_scale: Optional[float] = None,
    softmax_dtype=jnp.float32,
) -> jax.Array:
    """Memory-chunked causal self-attention (training / prefill).

    ``softmax_dtype=bf16`` (§Perf memory iteration) halves the byte traffic
    of the score/mask/softmax chain — the dominant HBM term of dense-LM
    training; jax.nn.softmax subtracts the row max, so bf16 stays stable at
    these context lengths (max |logit error| ~= 2^-8 * logit).
    """
    b, s, h, hd = q.shape
    scale = softmax_scale if softmax_scale is not None else hd ** -0.5
    chunk = min(chunk_size, s)
    if s % chunk != 0:  # fall back to one chunk for ragged smoke shapes
        chunk = s
    n_chunks = s // chunk

    q = q * scale

    # Python loop (not lax.scan): chunk count is small and static, each chunk
    # is rematerialized in the backward pass, and an unrolled loop keeps
    # cost_analysis exact (while-loop bodies are counted once, not per trip —
    # see DESIGN.md §5 / roofline notes).
    neg = jnp.asarray(jnp.finfo(softmax_dtype).min, softmax_dtype)

    @jax.checkpoint
    def chunk_out(q_blk, idx):
        scores = _grouped_scores(q_blk, k).astype(softmax_dtype)  # (B,H,chunk,S)
        qpos = idx * chunk + jnp.arange(chunk)[:, None]
        kpos = jnp.arange(s)[None, :]
        scores = jnp.where(kpos <= qpos, scores, neg)
        probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
        return _grouped_combine(probs, v)  # (B, chunk, H, hd)

    outs = [
        chunk_out(q[:, i * chunk : (i + 1) * chunk], i) for i in range(n_chunks)
    ]
    return jnp.concatenate(outs, axis=1).reshape(b, s, h, v.shape[-1])


def decode_attention(
    q: jax.Array,      # (B, 1, H, hd)
    cache_k: jax.Array,  # (B, S, KH, hd)
    cache_v: jax.Array,  # (B, S, KH, hd)
    length: jax.Array,   # () or (B,) valid length
    *,
    softmax_scale: Optional[float] = None,
) -> jax.Array:
    hd = q.shape[-1]
    scale = softmax_scale if softmax_scale is not None else hd ** -0.5
    scores = _grouped_scores(q * scale, cache_k).astype(jnp.float32)  # (B,H,1,S)
    valid = jnp.arange(cache_k.shape[1])[None, :] < jnp.reshape(length, (-1, 1))
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(cache_v.dtype)
    return _grouped_combine(probs, cache_v)  # (B, 1, H, hd)


# ---------------------------------------------------------------------------
# GQA block (gemma / qwen families)
# ---------------------------------------------------------------------------


def init_gqa_params(
    rng,
    d_model: int,
    n_heads: int,
    n_kv_heads: int,
    head_dim: int,
    *,
    qkv_bias: bool = False,
    qk_norm: bool = False,
    dtype=jnp.float32,
) -> Dict[str, jax.Array]:
    ks = jax.random.split(rng, 4)
    scale = d_model ** -0.5
    params = {
        "wq": scale * jax.random.normal(ks[0], (d_model, n_heads * head_dim), dtype),
        "wk": scale * jax.random.normal(ks[1], (d_model, n_kv_heads * head_dim), dtype),
        "wv": scale * jax.random.normal(ks[2], (d_model, n_kv_heads * head_dim), dtype),
        "wo": (n_heads * head_dim) ** -0.5
        * jax.random.normal(ks[3], (n_heads * head_dim, d_model), dtype),
    }
    if qkv_bias:
        params["bq"] = jnp.zeros((n_heads * head_dim,), dtype)
        params["bk"] = jnp.zeros((n_kv_heads * head_dim,), dtype)
        params["bv"] = jnp.zeros((n_kv_heads * head_dim,), dtype)
    if qk_norm:
        params["q_norm"] = jnp.zeros((head_dim,), dtype)
        params["k_norm"] = jnp.zeros((head_dim,), dtype)
    return params


def gqa_qkv(
    x: jax.Array,
    params: Dict[str, jax.Array],
    positions: jax.Array,
    *,
    n_heads: int,
    n_kv_heads: int,
    head_dim: int,
    rope_theta: float,
    norm_eps: float = 1e-6,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    b, s, _ = x.shape
    q = dense(x, params["wq"], params.get("bq")).reshape(b, s, n_heads, head_dim)
    k = dense(x, params["wk"], params.get("bk")).reshape(b, s, n_kv_heads, head_dim)
    v = dense(x, params["wv"], params.get("bv")).reshape(b, s, n_kv_heads, head_dim)
    if "q_norm" in params:  # qwen3-style per-head RMS qk-norm, pre-RoPE
        q = rms_norm(q, params["q_norm"], norm_eps)
        k = rms_norm(k, params["k_norm"], norm_eps)
    q = apply_rope(q, positions, rope_theta)
    k = apply_rope(k, positions, rope_theta)
    return q, k, v


def gqa_self_attention(
    x: jax.Array,
    params: Dict[str, jax.Array],
    positions: jax.Array,
    *,
    n_heads: int,
    n_kv_heads: int,
    head_dim: int,
    rope_theta: float = 10000.0,
    norm_eps: float = 1e-6,
    chunk_size: int = 1024,
    softmax_dtype=jnp.float32,
) -> jax.Array:
    q, k, v = gqa_qkv(
        x,
        params,
        positions,
        n_heads=n_heads,
        n_kv_heads=n_kv_heads,
        head_dim=head_dim,
        rope_theta=rope_theta,
        norm_eps=norm_eps,
    )
    out = causal_attention(
        q, k, v, chunk_size=chunk_size, softmax_dtype=softmax_dtype
    )
    return dense(out.reshape(x.shape[0], x.shape[1], -1), params["wo"])


def gqa_decode_attention(
    x: jax.Array,  # (B, 1, d)
    params: Dict[str, jax.Array],
    cache: KVCache,
    *,
    n_heads: int,
    n_kv_heads: int,
    head_dim: int,
    rope_theta: float = 10000.0,
    norm_eps: float = 1e-6,
) -> Tuple[jax.Array, KVCache]:
    positions = jnp.reshape(cache.length, (1, 1)).astype(jnp.int32) * jnp.ones(
        (x.shape[0], 1), jnp.int32
    )
    q, k, v = gqa_qkv(
        x,
        params,
        positions,
        n_heads=n_heads,
        n_kv_heads=n_kv_heads,
        head_dim=head_dim,
        rope_theta=rope_theta,
        norm_eps=norm_eps,
    )
    new_k = jax.lax.dynamic_update_slice_in_dim(cache.k, k.astype(cache.k.dtype), cache.length, axis=1)
    new_v = jax.lax.dynamic_update_slice_in_dim(cache.v, v.astype(cache.v.dtype), cache.length, axis=1)
    out = decode_attention(q, new_k, new_v, cache.length + 1)
    new_cache = KVCache(k=new_k, v=new_v, length=cache.length + 1)
    return dense(out.reshape(x.shape[0], 1, -1), params["wo"]), new_cache


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2): low-rank compressed KV with decoupled RoPE
# ---------------------------------------------------------------------------


class MLAConfig(NamedTuple):
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


def init_mla_params(
    rng, d_model: int, n_heads: int, cfg: MLAConfig, *, dtype=jnp.float32
) -> Dict[str, jax.Array]:
    ks = jax.random.split(rng, 5)
    qk_head = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
    scale = d_model ** -0.5
    return {
        # queries are full-rank in V2-Lite (q_lora_rank = None)
        "wq": scale * jax.random.normal(ks[0], (d_model, n_heads * qk_head), dtype),
        # joint down-projection: [c_kv ; k_rope]
        "wkv_a": scale
        * jax.random.normal(
            ks[1], (d_model, cfg.kv_lora_rank + cfg.qk_rope_head_dim), dtype
        ),
        "kv_a_norm": jnp.zeros((cfg.kv_lora_rank,), dtype),
        # up-projections from the latent: k_nope and v per head
        "wk_b": cfg.kv_lora_rank ** -0.5
        * jax.random.normal(
            ks[2], (cfg.kv_lora_rank, n_heads * cfg.qk_nope_head_dim), dtype
        ),
        "wv_b": cfg.kv_lora_rank ** -0.5
        * jax.random.normal(
            ks[3], (cfg.kv_lora_rank, n_heads * cfg.v_head_dim), dtype
        ),
        "wo": (n_heads * cfg.v_head_dim) ** -0.5
        * jax.random.normal(ks[4], (n_heads * cfg.v_head_dim, d_model), dtype),
    }


def mla_self_attention(
    x: jax.Array,
    params: Dict[str, jax.Array],
    positions: jax.Array,
    cfg: MLAConfig,
    *,
    n_heads: int,
    rope_theta: float = 10000.0,
    norm_eps: float = 1e-6,
    chunk_size: int = 1024,
    softmax_dtype=jnp.float32,
) -> jax.Array:
    """Prefill/training form: latent is expanded to per-head K/V (compute-
    optimal when Sq == Skv; the compressed cache matters only for decode)."""
    b, s, _ = x.shape
    qk_head = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
    q = dense(x, params["wq"]).reshape(b, s, n_heads, qk_head)
    q_nope, q_rope = jnp.split(q, [cfg.qk_nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, rope_theta)

    kv = dense(x, params["wkv_a"])
    c_kv, k_rope = jnp.split(kv, [cfg.kv_lora_rank], axis=-1)
    c_kv = rms_norm(c_kv, params["kv_a_norm"], norm_eps)
    k_rope = apply_rope(k_rope[:, :, None, :], positions, rope_theta)  # MQA-style

    k_nope = dense(c_kv, params["wk_b"]).reshape(
        b, s, n_heads, cfg.qk_nope_head_dim
    )
    v = dense(c_kv, params["wv_b"]).reshape(b, s, n_heads, cfg.v_head_dim)

    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_full = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (b, s, n_heads, cfg.qk_rope_head_dim))],
        axis=-1,
    )
    out = causal_attention(
        q_full, k_full, v, chunk_size=chunk_size,
        softmax_scale=qk_head ** -0.5, softmax_dtype=softmax_dtype,
    )
    return dense(out.reshape(b, s, -1), params["wo"])


def mla_decode_attention(
    x: jax.Array,  # (B, 1, d)
    params: Dict[str, jax.Array],
    cache: KVCache,  # k := c_kv (B, S, lora), v := k_rope (B, S, rope)
    cfg: MLAConfig,
    *,
    n_heads: int,
    rope_theta: float = 10000.0,
    norm_eps: float = 1e-6,
) -> Tuple[jax.Array, KVCache]:
    """Absorbed-matmul decode: queries are mapped *into* the latent space so
    attention runs against the compressed cache directly — the whole point of
    MLA (cache is kv_lora + rope wide instead of 2 * H * hd)."""
    b = x.shape[0]
    qk_head = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
    positions = jnp.reshape(cache.length, (1, 1)).astype(jnp.int32) * jnp.ones(
        (b, 1), jnp.int32
    )

    q = dense(x, params["wq"]).reshape(b, 1, n_heads, qk_head)
    q_nope, q_rope = jnp.split(q, [cfg.qk_nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, rope_theta)

    kv = dense(x, params["wkv_a"])
    c_kv_new, k_rope_new = jnp.split(kv, [cfg.kv_lora_rank], axis=-1)
    c_kv_new = rms_norm(c_kv_new, params["kv_a_norm"], norm_eps)
    k_rope_new = apply_rope(k_rope_new[:, :, None, :], positions, rope_theta)[
        :, :, 0, :
    ]

    ckv = jax.lax.dynamic_update_slice_in_dim(
        cache.k, c_kv_new.astype(cache.k.dtype), cache.length, axis=1
    )
    krope = jax.lax.dynamic_update_slice_in_dim(
        cache.v, k_rope_new.astype(cache.v.dtype), cache.length, axis=1
    )
    length = cache.length + 1

    # Absorb W_UK into the query: q_lat[h] = q_nope[h] @ W_UK[h]^T
    wk_b = params["wk_b"].reshape(cfg.kv_lora_rank, n_heads, cfg.qk_nope_head_dim)
    q_lat = jnp.einsum("bqhd,lhd->bqhl", q_nope, wk_b)  # (B,1,H,lora)

    scale = qk_head ** -0.5
    scores = (
        jnp.einsum("bqhl,bsl->bhqs", q_lat, ckv)
        + jnp.einsum("bqhd,bsd->bhqs", q_rope, krope)
    ).astype(jnp.float32) * scale
    valid = jnp.arange(ckv.shape[1])[None, :] < jnp.reshape(length, (-1, 1))
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(ckv.dtype)

    ctx_lat = jnp.einsum("bhqs,bsl->bqhl", probs, ckv)  # latent context
    wv_b = params["wv_b"].reshape(cfg.kv_lora_rank, n_heads, cfg.v_head_dim)
    ctx = jnp.einsum("bqhl,lhv->bqhv", ctx_lat, wv_b)  # absorb W_UV
    out = dense(ctx.reshape(b, 1, -1), params["wo"])
    return out, KVCache(k=ckv, v=krope, length=length)
