"""RecSys architectures: FM, DLRM (MLPerf config), SASRec, BST.

These are the archs where the paper's technique is live (DESIGN.md §4): every
latent interaction — FM's pairwise term, DLRM's dot-interaction block,
SASRec/BST retrieval scoring — runs through the dynamic-pruning machinery
(thresholds + effective ranks), with rate 0 recovering the dense model
bit-for-bit.

JAX has no native EmbeddingBag; ``embedding_bag`` below builds it from
``jnp.take`` + ``jax.ops.segment_sum`` (the multi-hot path) — part of the
system, per the kernel taxonomy's RecSys notes.  Single-valued categorical
fields use the plain-gather fast path.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ranks import effective_ranks, rank_mask
from repro.kernels import ops as kops
from repro.models.layers import dense

Params = Dict[str, Any]

# Criteo-1TB per-field cardinalities as used by the MLPerf DLRM benchmark.
MLPERF_CRITEO_VOCABS: Tuple[int, ...] = (
    39884406, 39043, 17289, 7420, 20263, 3, 7120, 1543, 63, 38532951,
    2953546, 403346, 10, 2208, 11938, 155, 4, 976, 14, 39979771,
    25641295, 39664984, 585935, 12972, 108, 36,
)


def embedding_bag(
    table: jax.Array,        # (V, d)
    values: jax.Array,       # (nnz,) flat ids
    segment_ids: jax.Array,  # (nnz,) bag index per id
    num_bags: int,
    *,
    combiner: str = "sum",
    weights: Optional[jax.Array] = None,
) -> jax.Array:
    """torch.nn.EmbeddingBag equivalent: ragged gather + segment reduce."""
    rows = jnp.take(table, values, axis=0)
    if weights is not None:
        rows = rows * weights[:, None]
    if combiner == "sum":
        return jax.ops.segment_sum(rows, segment_ids, num_segments=num_bags)
    if combiner == "mean":
        sums = jax.ops.segment_sum(rows, segment_ids, num_segments=num_bags)
        counts = jax.ops.segment_sum(
            jnp.ones_like(values, jnp.float32), segment_ids, num_segments=num_bags
        )
        return sums / jnp.maximum(counts, 1.0)[:, None]
    if combiner == "max":
        return jax.ops.segment_max(rows, segment_ids, num_segments=num_bags)
    raise ValueError(f"unknown combiner {combiner!r}")


def _mask_by_rank(rows: jax.Array, threshold) -> jax.Array:
    """Zero each row's suffix from its first insignificant factor (Alg. 2)."""
    r = effective_ranks(rows, threshold)
    return rows * rank_mask(r, rows.shape[-1], rows.dtype)


# ---------------------------------------------------------------------------
# FM — Rendle ICDM'10, O(nk) sum-square trick; pruning is first-class here.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FMConfig:
    name: str = "fm"
    n_fields: int = 39
    embed_dim: int = 10
    vocab_per_field: int = 1_000_000
    dtype: Any = jnp.float32

    @property
    def total_vocab(self) -> int:
        return self.n_fields * self.vocab_per_field

    def field_offsets(self) -> np.ndarray:
        return (np.arange(self.n_fields) * self.vocab_per_field).astype(np.int32)


def init_fm_params(rng, cfg: FMConfig) -> Params:
    kv, kw = jax.random.split(rng)
    return {
        "w0": jnp.zeros((), cfg.dtype),
        "w": jnp.zeros((cfg.total_vocab,), cfg.dtype),
        "v": 0.01 * jax.random.normal(kv, (cfg.total_vocab, cfg.embed_dim), cfg.dtype),
    }


def fm_forward(
    params: Params,
    ids: jax.Array,  # (B, F) per-field local ids
    cfg: FMConfig,
    t_v: jax.Array | float = 0.0,
) -> jax.Array:
    """Logit per example.  With ``t_v > 0`` every pairwise term <v_i, v_j> is
    truncated at min(rank_i, rank_j): masking each row by its own rank makes
    the sum-square identity compute exactly the paper's early-stopped sum."""
    offsets = jnp.asarray(cfg.field_offsets())
    flat = ids + offsets[None, :]
    rows = jnp.take(params["v"], flat.reshape(-1), axis=0)  # (B*F, k)
    rows = _mask_by_rank(rows, t_v)
    rows = rows.reshape(ids.shape[0], cfg.n_fields, cfg.embed_dim)

    s = jnp.sum(rows, axis=1)             # (B, k)
    ss = jnp.sum(rows * rows, axis=1)     # (B, k)
    pairwise = 0.5 * jnp.sum(s * s - ss, axis=-1)
    linear = jnp.sum(jnp.take(params["w"], flat.reshape(-1)).reshape(ids.shape), axis=1)
    return (params["w0"] + linear + pairwise).astype(jnp.float32)


def fm_loss(params: Params, batch: Dict[str, jax.Array], cfg: FMConfig, t_v=0.0):
    logits = fm_forward(params, batch["ids"], cfg, t_v)
    labels = batch["label"].astype(jnp.float32)
    return jnp.mean(
        jnp.maximum(logits, 0) - logits * labels + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    )


def fm_retrieval(
    params: Params,
    user_ids: jax.Array,   # (B, F-1) context fields
    cand_ids: jax.Array,   # (C,) candidate ids of the item field (field F-1)
    cfg: FMConfig,
    t_v: jax.Array | float = 0.0,
    *,
    use_kernel: bool = True,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Score B contexts against C candidate items (retrieval_cand shape).

    FM decomposes: score(u, c) = const(u) + w_c + <s_u, v_c> with
    s_u = sum of context-field factors — so candidate scoring is one
    (B, k) x (C, k) pruned matmul over the million-row candidate slab.
    """
    offsets = jnp.asarray(cfg.field_offsets())
    flat_u = user_ids + offsets[None, : user_ids.shape[1]]
    rows_u = jnp.take(params["v"], flat_u.reshape(-1), axis=0)
    rows_u = _mask_by_rank(rows_u, t_v).reshape(
        user_ids.shape[0], user_ids.shape[1], cfg.embed_dim
    )
    s_u = jnp.sum(rows_u, axis=1)  # (B, k)
    ss_u = jnp.sum(rows_u * rows_u, axis=1)
    const_u = (
        0.5 * jnp.sum(s_u * s_u - ss_u, axis=-1)
        + jnp.sum(jnp.take(params["w"], flat_u.reshape(-1)).reshape(user_ids.shape), axis=1)
        + params["w0"]
    )

    flat_c = cand_ids + offsets[user_ids.shape[1]]
    v_c = jnp.take(params["v"], flat_c, axis=0)  # (C, k)
    if use_kernel:
        cross = kops.pruned_matmul(s_u, v_c, 0.0, t_v, interpret=interpret)
    else:
        cross = jnp.einsum("bk,ck->bc", s_u, _mask_by_rank(v_c, t_v))
    w_c = jnp.take(params["w"], flat_c)
    return (const_u[:, None] + cross + w_c[None, :]).astype(jnp.float32)


# ---------------------------------------------------------------------------
# DLRM — MLPerf config; dot interaction optionally pruned.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DLRMConfig:
    name: str = "dlrm-mlperf"
    n_dense: int = 13
    embed_dim: int = 128
    vocab_sizes: Tuple[int, ...] = MLPERF_CRITEO_VOCABS
    bot_mlp: Tuple[int, ...] = (512, 256, 128)
    top_mlp: Tuple[int, ...] = (1024, 1024, 512, 256, 1)
    dtype: Any = jnp.float32

    @property
    def n_sparse(self) -> int:
        return len(self.vocab_sizes)

    @property
    def n_interact(self) -> int:
        f = self.n_sparse + 1
        return f * (f - 1) // 2


def _init_mlp(rng, dims: Sequence[int], dtype) -> list:
    layers = []
    for d_in, d_out in zip(dims[:-1], dims[1:]):
        rng, kw = jax.random.split(rng)
        scale = (2.0 / (d_in + d_out)) ** 0.5
        layers.append(
            {
                "w": scale * jax.random.normal(kw, (d_in, d_out), dtype),
                "b": jnp.zeros((d_out,), dtype),
            }
        )
    return layers


def _run_mlp(x: jax.Array, layers: list, *, final_act: bool = False) -> jax.Array:
    for idx, layer in enumerate(layers):
        x = dense(x, layer["w"], layer["b"])
        if idx < len(layers) - 1 or final_act:
            x = jax.nn.relu(x)
    return x


def init_dlrm_params(rng, cfg: DLRMConfig) -> Params:
    kb, kt, ke = jax.random.split(rng, 3)
    tables = []
    for idx, vocab in enumerate(cfg.vocab_sizes):
        key = jax.random.fold_in(ke, idx)
        tables.append(
            (vocab ** -0.5)
            * jax.random.normal(key, (vocab, cfg.embed_dim), cfg.dtype)
        )
    top_in = cfg.bot_mlp[-1] + cfg.n_interact
    return {
        "tables": tables,
        "bot": _init_mlp(kb, (cfg.n_dense,) + cfg.bot_mlp, cfg.dtype),
        "top": _init_mlp(kt, (top_in,) + cfg.top_mlp, cfg.dtype),
    }


def dlrm_forward(
    params: Params,
    dense_feats: jax.Array,  # (B, 13)
    sparse_ids: jax.Array,   # (B, 26)
    cfg: DLRMConfig,
    t_v: jax.Array | float = 0.0,
) -> jax.Array:
    b = dense_feats.shape[0]
    d_vec = _run_mlp(dense_feats, params["bot"], final_act=True)  # (B, 128)
    emb = jnp.stack(
        [
            jnp.take(table, sparse_ids[:, idx], axis=0)
            for idx, table in enumerate(params["tables"])
        ],
        axis=1,
    )  # (B, 26, d)
    # Paper technique: prune embedding factor suffixes; the bottom-MLP vector
    # is not a factor-table row and stays dense (DESIGN.md §4).
    emb = _mask_by_rank(emb.reshape(-1, cfg.embed_dim), t_v).reshape(emb.shape)
    z = jnp.concatenate([d_vec[:, None, :], emb], axis=1)  # (B, 27, d)
    inter = jnp.einsum("bfd,bgd->bfg", z, z)
    iu, ju = jnp.triu_indices(z.shape[1], k=1)
    flat = inter[:, iu, ju]  # (B, 351)
    top_in = jnp.concatenate([d_vec, flat.astype(d_vec.dtype)], axis=-1)
    return _run_mlp(top_in, params["top"])[:, 0].astype(jnp.float32)


def dlrm_loss(params, batch, cfg: DLRMConfig, t_v=0.0):
    logits = dlrm_forward(params, batch["dense"], batch["sparse"], cfg, t_v)
    labels = batch["label"].astype(jnp.float32)
    return jnp.mean(
        jnp.maximum(logits, 0) - logits * labels + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    )


def dlrm_retrieval(
    params: Params,
    dense_feats: jax.Array,   # (1, 13) one user context
    sparse_ids: jax.Array,    # (1, 26) user's categorical ids
    cand_ids: jax.Array,      # (C,) candidates for the item field (field 0)
    cfg: DLRMConfig,
    t_v: jax.Array | float = 0.0,
) -> jax.Array:
    """Score one context against C candidate items by swapping field 0."""
    c = cand_ids.shape[0]
    dense_rep = jnp.broadcast_to(dense_feats, (c, cfg.n_dense))
    sparse_rep = jnp.broadcast_to(sparse_ids, (c, cfg.n_sparse))
    sparse_rep = sparse_rep.at[:, 0].set(cand_ids)
    return dlrm_forward(params, dense_rep, sparse_rep, cfg, t_v)


# ---------------------------------------------------------------------------
# SASRec — self-attentive sequential recommendation.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SASRecConfig:
    name: str = "sasrec"
    n_items: int = 1_000_000
    embed_dim: int = 50
    n_blocks: int = 2
    n_heads: int = 1
    seq_len: int = 50
    dropout: float = 0.0
    dtype: Any = jnp.float32


def init_sasrec_params(rng, cfg: SASRecConfig) -> Params:
    ke, kp, kb = jax.random.split(rng, 3)
    blocks = []
    d = cfg.embed_dim
    for idx in range(cfg.n_blocks):
        key = jax.random.fold_in(kb, idx)
        kq, kk, kv, ko, k1, k2 = jax.random.split(key, 6)
        s = d ** -0.5
        blocks.append(
            {
                "wq": s * jax.random.normal(kq, (d, d), cfg.dtype),
                "wk": s * jax.random.normal(kk, (d, d), cfg.dtype),
                "wv": s * jax.random.normal(kv, (d, d), cfg.dtype),
                "wo": s * jax.random.normal(ko, (d, d), cfg.dtype),
                "ffn_w1": s * jax.random.normal(k1, (d, d), cfg.dtype),
                "ffn_b1": jnp.zeros((d,), cfg.dtype),
                "ffn_w2": s * jax.random.normal(k2, (d, d), cfg.dtype),
                "ffn_b2": jnp.zeros((d,), cfg.dtype),
                "ln1": jnp.ones((d,), cfg.dtype),
                "ln1_b": jnp.zeros((d,), cfg.dtype),
                "ln2": jnp.ones((d,), cfg.dtype),
                "ln2_b": jnp.zeros((d,), cfg.dtype),
            }
        )
    return {
        # row 0 is the padding item
        "item_embed": 0.01
        * jax.random.normal(ke, (cfg.n_items + 1, d), cfg.dtype),
        "pos_embed": 0.01 * jax.random.normal(kp, (cfg.seq_len, d), cfg.dtype),
        "blocks": blocks,
        "ln_f": jnp.ones((d,), cfg.dtype),
        "ln_f_b": jnp.zeros((d,), cfg.dtype),
    }


def _ln(x, scale, bias, eps=1e-6):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * scale + bias


def sasrec_encode(params: Params, seq: jax.Array, cfg: SASRecConfig) -> jax.Array:
    """seq (B, S) item ids (0 = pad) -> hidden states (B, S, d)."""
    b, s = seq.shape
    x = jnp.take(params["item_embed"], seq, axis=0) * (cfg.embed_dim ** 0.5)
    x = x + params["pos_embed"][None, :s]
    pad = (seq == 0)
    causal = jnp.tril(jnp.ones((s, s), bool))
    attn_mask = causal[None] & ~pad[:, None, :]  # (B, S, S)

    for blk in params["blocks"]:
        h = _ln(x, blk["ln1"], blk["ln1_b"])
        q = dense(h, blk["wq"]).reshape(b, s, cfg.n_heads, -1)
        k = dense(h, blk["wk"]).reshape(b, s, cfg.n_heads, -1)
        v = dense(h, blk["wv"]).reshape(b, s, cfg.n_heads, -1)
        scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) / (q.shape[-1] ** 0.5)
        scores = jnp.where(attn_mask[:, None], scores.astype(jnp.float32), -1e30)
        probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
        att = jnp.einsum("bhqk,bkhd->bqhd", probs, v).reshape(b, s, -1)
        x = x + dense(att, blk["wo"])
        h = _ln(x, blk["ln2"], blk["ln2_b"])
        f = jax.nn.relu(dense(h, blk["ffn_w1"], blk["ffn_b1"]))
        x = x + dense(f, blk["ffn_w2"], blk["ffn_b2"])
    x = _ln(x, params["ln_f"], params["ln_f_b"])
    return x * (~pad)[..., None]


def sasrec_loss(params: Params, batch: Dict[str, jax.Array], cfg: SASRecConfig):
    """BCE over (positive, sampled-negative) next items, as in the paper."""
    h = sasrec_encode(params, batch["seq"], cfg)  # (B, S, d)
    pos = jnp.take(params["item_embed"], batch["pos"], axis=0)
    neg = jnp.take(params["item_embed"], batch["neg"], axis=0)
    pos_logit = jnp.sum(h * pos, axis=-1)
    neg_logit = jnp.sum(h * neg, axis=-1)
    mask = (batch["pos"] > 0).astype(jnp.float32)

    def bce(logit, label):
        return jnp.maximum(logit, 0) - logit * label + jnp.log1p(
            jnp.exp(-jnp.abs(logit))
        )

    per_tok = bce(pos_logit, 1.0) + bce(neg_logit, 0.0)
    return jnp.sum(per_tok * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def sasrec_retrieval(
    params: Params,
    seq: jax.Array,  # (B, S)
    cfg: SASRecConfig,
    t_v: jax.Array | float = 0.0,
    *,
    use_kernel: bool = True,
    cand_ids: Optional[jax.Array] = None,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Final-state retrieval scores against all (or C candidate) items —
    the latent dot product where the paper's pruning applies."""
    h = sasrec_encode(params, seq, cfg)[:, -1]  # (B, d)
    table = params["item_embed"]
    if cand_ids is not None:
        table = jnp.take(table, cand_ids, axis=0)
    if use_kernel:
        return kops.pruned_matmul(h, table, 0.0, t_v, interpret=interpret)
    return jnp.einsum("bd,cd->bc", h, _mask_by_rank(table, t_v))


# ---------------------------------------------------------------------------
# BST — Behavior Sequence Transformer (Alibaba).
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class BSTConfig:
    name: str = "bst"
    n_items: int = 1_000_000
    embed_dim: int = 32
    seq_len: int = 20            # history; the target item is appended
    n_blocks: int = 1
    n_heads: int = 8
    mlp_dims: Tuple[int, ...] = (1024, 512, 256)
    n_profile: int = 16          # dense user-profile features
    dtype: Any = jnp.float32


def init_bst_params(rng, cfg: BSTConfig) -> Params:
    ke, kp, kb, km = jax.random.split(rng, 4)
    d = cfg.embed_dim
    blocks = []
    for idx in range(cfg.n_blocks):
        key = jax.random.fold_in(kb, idx)
        kq, kk, kv, ko, k1, k2 = jax.random.split(key, 6)
        s = d ** -0.5
        blocks.append(
            {
                "wq": s * jax.random.normal(kq, (d, d), cfg.dtype),
                "wk": s * jax.random.normal(kk, (d, d), cfg.dtype),
                "wv": s * jax.random.normal(kv, (d, d), cfg.dtype),
                "wo": s * jax.random.normal(ko, (d, d), cfg.dtype),
                "ffn_w1": s * jax.random.normal(k1, (d, 4 * d), cfg.dtype),
                "ffn_b1": jnp.zeros((4 * d,), cfg.dtype),
                "ffn_w2": (4 * d) ** -0.5 * jax.random.normal(k2, (4 * d, d), cfg.dtype),
                "ffn_b2": jnp.zeros((d,), cfg.dtype),
                "ln1": jnp.ones((d,), cfg.dtype),
                "ln1_b": jnp.zeros((d,), cfg.dtype),
                "ln2": jnp.ones((d,), cfg.dtype),
                "ln2_b": jnp.zeros((d,), cfg.dtype),
            }
        )
    total_seq = cfg.seq_len + 1
    mlp_in = total_seq * d + cfg.n_profile
    return {
        "item_embed": 0.01 * jax.random.normal(ke, (cfg.n_items + 1, d), cfg.dtype),
        "pos_embed": 0.01 * jax.random.normal(kp, (total_seq, d), cfg.dtype),
        "blocks": blocks,
        "mlp": _init_mlp(km, (mlp_in,) + cfg.mlp_dims + (1,), cfg.dtype),
    }


def bst_forward(
    params: Params,
    hist: jax.Array,     # (B, S) history item ids (0 = pad)
    target: jax.Array,   # (B,) target item id
    profile: jax.Array,  # (B, n_profile) dense user features
    cfg: BSTConfig,
) -> jax.Array:
    b = hist.shape[0]
    seq = jnp.concatenate([hist, target[:, None]], axis=1)  # (B, S+1)
    s = seq.shape[1]
    x = jnp.take(params["item_embed"], seq, axis=0) + params["pos_embed"][None, :s]
    pad = (seq == 0)
    attn_mask = ~pad[:, None, :]  # bidirectional over the (hist, target) set

    hd = cfg.embed_dim // cfg.n_heads
    for blk in params["blocks"]:
        h = _ln(x, blk["ln1"], blk["ln1_b"])
        q = dense(h, blk["wq"]).reshape(b, s, cfg.n_heads, hd)
        k = dense(h, blk["wk"]).reshape(b, s, cfg.n_heads, hd)
        v = dense(h, blk["wv"]).reshape(b, s, cfg.n_heads, hd)
        scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) / (hd ** 0.5)
        scores = jnp.where(attn_mask[:, None], scores.astype(jnp.float32), -1e30)
        probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
        att = jnp.einsum("bhqk,bkhd->bqhd", probs, v).reshape(b, s, -1)
        x = x + dense(att, blk["wo"])
        h = _ln(x, blk["ln2"], blk["ln2_b"])
        f = jax.nn.relu(dense(h, blk["ffn_w1"], blk["ffn_b1"]))
        x = x + dense(f, blk["ffn_w2"], blk["ffn_b2"])

    flat = x.reshape(b, -1)
    mlp_in = jnp.concatenate([flat, profile.astype(flat.dtype)], axis=-1)
    return _run_mlp(mlp_in, params["mlp"])[:, 0].astype(jnp.float32)


def bst_loss(params, batch, cfg: BSTConfig):
    logits = bst_forward(
        params, batch["hist"], batch["target"], batch["profile"], cfg
    )
    labels = batch["label"].astype(jnp.float32)
    return jnp.mean(
        jnp.maximum(logits, 0) - logits * labels + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    )
