"""Decoder-only transformer family covering the assigned LM architectures:
gemma-7b / qwen1.5-4b (GQA, biases) / qwen3-4b (qk-norm) — dense — and
deepseek-v2-lite (MLA + shared/routed MoE) / granite-moe (MoE) — sparse.

The layer stack is ``lax.scan`` over stacked per-layer params with
``jax.checkpoint`` (remat): compile time stays O(1) in depth (one layer is
compiled once) and live activation memory is one layer deep — both required
for the 512-device dry-run on a CPU host.  Heterogeneous leading layers
(DeepSeek's dense layer 0) sit outside the scan.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models.attention import KVCache, MLAConfig
from repro.models.layers import dense, gated_mlp, rms_norm, rms_norm_lean
from repro.models.moe import MoEConfig, init_moe_params, moe_ffn

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    activation: str = "swiglu"        # swiglu | geglu
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    embed_scale: bool = False         # gemma multiplies embeddings by sqrt(d)
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    first_dense_layers: int = 0       # leading dense-FFN layers (deepseek: 1)
    first_dense_ff: int = 0
    attn_chunk: int = 1024
    unroll: bool = False              # python-loop layers (exact cost_analysis)
    moe_shard_map: bool = False       # replicated-dispatch EP (§Perf iter 1)
    attn_softmax_dtype: str = "f32"   # "bf16" halves score-chain bytes (§Perf)
    remat_policy: str = "full"        # "dots" saves matmul outputs (§Perf)
    mem_lean: bool = False            # lean norms + bf16 CE (§Perf memory iter)
    dtype: Any = jnp.bfloat16

    @property
    def _softmax_dtype(self):
        return jnp.float32 if self.attn_softmax_dtype == "f32" else jnp.bfloat16

    @property
    def scan_layers(self) -> int:
        return self.n_layers - self.first_dense_layers

    def param_count(self) -> int:
        """Total parameters (embedding included) — used for MODEL_FLOPS."""
        d, hd = self.d_model, self.head_dim
        att = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd + self.n_heads * hd * d
        if self.mla is not None:
            m = self.mla
            qk = m.qk_nope_head_dim + m.qk_rope_head_dim
            att = (
                d * self.n_heads * qk
                + d * (m.kv_lora_rank + m.qk_rope_head_dim)
                + m.kv_lora_rank * self.n_heads * (m.qk_nope_head_dim + m.v_head_dim)
                + self.n_heads * m.v_head_dim * d
            )
        if self.moe is not None:
            ffn = self.moe.num_experts * 3 * d * self.moe.d_ff + d * self.moe.num_experts
            ffn += self.moe.num_shared * 3 * d * self.moe.d_ff
        else:
            ffn = 3 * d * self.d_ff
        dense_extra = (
            self.first_dense_layers * (att + 3 * d * self.first_dense_ff)
            if self.first_dense_layers
            else 0
        )
        body = self.scan_layers * (att + ffn) + dense_extra
        embed = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return body + embed

    def active_param_count(self) -> int:
        """Activated params per token (MoE counts top_k + shared experts)."""
        if self.moe is None:
            return self.param_count()
        d = self.d_model
        full = self.param_count()
        all_experts = self.scan_layers * self.moe.num_experts * 3 * d * self.moe.d_ff
        active = self.scan_layers * self.moe.top_k * 3 * d * self.moe.d_ff
        return full - all_experts + active


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _init_layer(rng, cfg: TransformerConfig, *, dense_ff: Optional[int] = None) -> Params:
    ka, kf = jax.random.split(rng)
    if cfg.mla is not None:
        a = attn.init_mla_params(ka, cfg.d_model, cfg.n_heads, cfg.mla, dtype=cfg.dtype)
    else:
        a = attn.init_gqa_params(
            ka,
            cfg.d_model,
            cfg.n_heads,
            cfg.n_kv_heads,
            cfg.head_dim,
            qkv_bias=cfg.qkv_bias,
            qk_norm=cfg.qk_norm,
            dtype=cfg.dtype,
        )
    layer: Params = {
        "attn": a,
        "norm1": jnp.zeros((cfg.d_model,), cfg.dtype),
        "norm2": jnp.zeros((cfg.d_model,), cfg.dtype),
    }
    if cfg.moe is not None and dense_ff is None:
        layer["moe"] = init_moe_params(
            kf, cfg.d_model, cfg.moe, activation=cfg.activation, dtype=cfg.dtype
        )
    else:
        ff = dense_ff or cfg.d_ff
        kg, ki, ko = jax.random.split(kf, 3)
        s_in, s_out = cfg.d_model ** -0.5, ff ** -0.5
        layer["mlp"] = {
            "wg": s_in * jax.random.normal(kg, (cfg.d_model, ff), cfg.dtype),
            "wi": s_in * jax.random.normal(ki, (cfg.d_model, ff), cfg.dtype),
            "wo": s_out * jax.random.normal(ko, (ff, cfg.d_model), cfg.dtype),
        }
    return layer


def init_params(rng, cfg: TransformerConfig) -> Params:
    ke, kl, kh = jax.random.split(rng, 3)
    params: Params = {
        "embed": cfg.d_model ** -0.5
        * jax.random.normal(ke, (cfg.vocab_size, cfg.d_model), cfg.dtype),
        "final_norm": jnp.zeros((cfg.d_model,), cfg.dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = cfg.d_model ** -0.5 * jax.random.normal(
            kh, (cfg.d_model, cfg.vocab_size), cfg.dtype
        )
    if cfg.first_dense_layers:
        keys = jax.random.split(kl, cfg.first_dense_layers + 1)
        params["first"] = [
            _init_layer(keys[idx], cfg, dense_ff=cfg.first_dense_ff or cfg.d_ff)
            for idx in range(cfg.first_dense_layers)
        ]
        kl = keys[-1]
    # Stacked scan layers: init one rng per layer, stack leaves on axis 0.
    layer_keys = jax.random.split(kl, cfg.scan_layers)
    layers = [_init_layer(key, cfg) for key in layer_keys]
    params["layers"] = jax.tree_util.tree_map(
        lambda *leaves: jnp.stack(leaves), *layers
    )
    return params


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _block(
    x: jax.Array,
    layer: Params,
    positions: jax.Array,
    cfg: TransformerConfig,
) -> Tuple[jax.Array, jax.Array]:
    """Pre-norm block.  Returns (output, moe_aux)."""
    norm = rms_norm_lean if cfg.mem_lean else rms_norm
    h = norm(x, layer["norm1"], cfg.norm_eps)
    if cfg.mla is not None:
        a = attn.mla_self_attention(
            h,
            layer["attn"],
            positions,
            cfg.mla,
            n_heads=cfg.n_heads,
            rope_theta=cfg.rope_theta,
            norm_eps=cfg.norm_eps,
            chunk_size=cfg.attn_chunk,
            softmax_dtype=cfg._softmax_dtype,
        )
    else:
        a = attn.gqa_self_attention(
            h,
            layer["attn"],
            positions,
            n_heads=cfg.n_heads,
            n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.head_dim,
            rope_theta=cfg.rope_theta,
            norm_eps=cfg.norm_eps,
            chunk_size=cfg.attn_chunk,
            softmax_dtype=cfg._softmax_dtype,
        )
    x = x + a
    h = norm(x, layer["norm2"], cfg.norm_eps)
    if "moe" in layer:
        b, s, d = h.shape
        out, aux = moe_ffn(
            h.reshape(b * s, d), layer["moe"], cfg.moe,
            activation=cfg.activation, use_shard_map=cfg.moe_shard_map,
        )
        return x + out.reshape(b, s, d), aux
    return x + gated_mlp(h, layer["mlp"], cfg.activation), jnp.float32(0.0)


def forward(
    params: Params, tokens: jax.Array, cfg: TransformerConfig
) -> Tuple[jax.Array, jax.Array]:
    """tokens (B, S) -> (logits (B, S, V) f32, moe_aux ())."""
    b, s = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None, :], (b, s))

    aux_total = jnp.float32(0.0)
    for layer in params.get("first", []):
        x, aux = _block(x, layer, positions, cfg)
        aux_total += aux

    policy = (
        None
        if cfg.remat_policy == "full"
        else jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    )
    if cfg.unroll:
        # Same math as the scan below, python-unrolled (each layer remat'd):
        # used by the dry-run calibration variants so cost_analysis counts
        # every layer (while bodies are costed once).
        block = jax.checkpoint(
            lambda x, lyr: _block(x, lyr, positions, cfg), policy=policy
        )
        for idx in range(cfg.scan_layers):
            layer = jax.tree_util.tree_map(lambda a: a[idx], params["layers"])
            x, aux = block(x, layer)
            aux_total += aux
    else:

        def body(carry, layer):
            x, aux_total = carry
            x, aux = _block(x, layer, positions, cfg)
            return (x, aux_total + aux), None

        (x, aux_total), _ = jax.lax.scan(
            jax.checkpoint(body, policy=policy), (x, aux_total), params["layers"]
        )
    norm = rms_norm_lean if cfg.mem_lean else rms_norm
    x = norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    out_dtype = x.dtype if cfg.mem_lean else jnp.float32
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(x.dtype)).astype(out_dtype)
    return logits, aux_total


def lm_loss(
    params: Params, batch: Dict[str, jax.Array], cfg: TransformerConfig
) -> jax.Array:
    """Next-token cross entropy; labels < 0 are masked.

    With ``mem_lean`` the (B, S, V) logit chain stays in the residual dtype
    and only the reductions (row max, exp-sum, nll) accumulate in f32 —
    removing the two largest f32 buffers of the entry computation (§Perf).
    """
    logits, aux = forward(params, batch["tokens"], cfg)
    labels = batch["labels"]
    mask = (labels >= 0).astype(jnp.float32)
    safe = jnp.maximum(labels, 0)
    if cfg.mem_lean:
        row_max = jnp.max(logits, axis=-1, keepdims=True)
        shifted = logits - row_max  # residual dtype
        sumexp = jnp.sum(jnp.exp(shifted), axis=-1, dtype=jnp.float32)
        logz = jnp.log(sumexp) + row_max[..., 0].astype(jnp.float32)
        gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[
            ..., 0
        ].astype(jnp.float32)
    else:
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0) + aux


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------


class DecodeState(NamedTuple):
    caches: Any          # stacked KVCache over scan layers
    first_caches: Any    # tuple of per-layer KVCache for leading dense layers


def init_decode_state(
    cfg: TransformerConfig, batch: int, max_len: int, *, length: int = 0
) -> DecodeState:
    if cfg.mla is not None:
        kshape = (batch, max_len, cfg.mla.kv_lora_rank)
        vshape = (batch, max_len, cfg.mla.qk_rope_head_dim)
    else:
        kshape = vshape = (batch, max_len, cfg.n_kv_heads, cfg.head_dim)

    def one(shape_prefix=()):
        return KVCache(
            k=jnp.zeros(shape_prefix + kshape, cfg.dtype),
            v=jnp.zeros(shape_prefix + vshape, cfg.dtype),
            length=jnp.asarray(length, jnp.int32),
        )

    stacked = KVCache(
        k=jnp.zeros((cfg.scan_layers,) + kshape, cfg.dtype),
        v=jnp.zeros((cfg.scan_layers,) + vshape, cfg.dtype),
        length=jnp.asarray(length, jnp.int32),
    )
    first = tuple(one() for _ in range(cfg.first_dense_layers))
    return DecodeState(caches=stacked, first_caches=first)


def _decode_block(
    x: jax.Array, layer: Params, cache: KVCache, cfg: TransformerConfig
) -> Tuple[jax.Array, KVCache]:
    norm = rms_norm_lean if cfg.mem_lean else rms_norm
    h = norm(x, layer["norm1"], cfg.norm_eps)
    if cfg.mla is not None:
        a, new_cache = attn.mla_decode_attention(
            h,
            layer["attn"],
            cache,
            cfg.mla,
            n_heads=cfg.n_heads,
            rope_theta=cfg.rope_theta,
            norm_eps=cfg.norm_eps,
        )
    else:
        a, new_cache = attn.gqa_decode_attention(
            h,
            layer["attn"],
            cache,
            n_heads=cfg.n_heads,
            n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.head_dim,
            rope_theta=cfg.rope_theta,
            norm_eps=cfg.norm_eps,
        )
    x = x + a
    h = norm(x, layer["norm2"], cfg.norm_eps)
    if "moe" in layer:
        b, s, d = h.shape
        out, _ = moe_ffn(
            h.reshape(b * s, d), layer["moe"], cfg.moe,
            activation=cfg.activation, use_shard_map=cfg.moe_shard_map,
        )
        return x + out.reshape(b, s, d), new_cache
    return x + gated_mlp(h, layer["mlp"], cfg.activation), new_cache


def decode_step(
    params: Params,
    tokens: jax.Array,  # (B, 1)
    state: DecodeState,
    cfg: TransformerConfig,
) -> Tuple[jax.Array, DecodeState]:
    """One decode step: (B, 1) token -> (B, V) logits + updated caches."""
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)

    new_first = []
    for layer, cache in zip(params.get("first", []), state.first_caches):
        x, new_cache = _decode_block(x, layer, cache, cfg)
        new_first.append(new_cache)

    if cfg.unroll:
        new_ks, new_vs = [], []
        for idx in range(cfg.scan_layers):
            layer = jax.tree_util.tree_map(lambda a: a[idx], params["layers"])
            cache = KVCache(
                k=state.caches.k[idx], v=state.caches.v[idx],
                length=state.caches.length,
            )
            x, new_cache = _decode_block(x, layer, cache, cfg)
            new_ks.append(new_cache.k)
            new_vs.append(new_cache.v)
        ks, vs = jnp.stack(new_ks), jnp.stack(new_vs)
    else:

        def body(x, inputs):
            layer, k, v = inputs
            cache = KVCache(k=k, v=v, length=state.caches.length)
            x, new_cache = _decode_block(x, layer, cache, cfg)
            return x, (new_cache.k, new_cache.v)

        x, (ks, vs) = jax.lax.scan(
            body, x, (params["layers"], state.caches.k, state.caches.v)
        )
    x = (rms_norm_lean if cfg.mem_lean else rms_norm)(
        x, params["final_norm"], cfg.norm_eps
    )
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(x.dtype)).astype(jnp.float32)
    new_state = DecodeState(
        caches=KVCache(k=ks, v=vs, length=state.caches.length + 1),
        first_caches=tuple(new_first),
    )
    return logits[:, 0], new_state


def prefill(
    params: Params, tokens: jax.Array, cfg: TransformerConfig
) -> jax.Array:
    """Prefill forward (logits only; cache fill elided in the dry-run cell —
    the compute/memory-dominant part is the forward itself)."""
    logits, _ = forward(params, tokens, cfg)
    return logits[:, -1]
