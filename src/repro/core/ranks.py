"""Effective ranks: the vectorized form of the paper's early stopping.

Algorithms 2/3 scan ``t = 1..k`` and break at the first ``t`` with
``|p_{u,t}| < T_p`` or ``|q_{t,i}| < T_q``.  Define

    r_u = first insignificant index of row u (k if none)
    r_i = first insignificant index of row i (k if none)

Then the early-stopped dot product is exactly ``sum_{t < min(r_u, r_i)}``
and the early-stopped update touches exactly ``t < min(r_u, r_i)``.  All
pruned paths in this codebase are expressed through these ranks; the
equivalence with the scalar loop is property-tested.

Ranks are *dynamic*: they are recomputed from the current factor values at
every use site (per batch for training, per call for serving), matching the
paper's "dynamically performed based on the actual sparsity ... of certain
epochs".
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def effective_ranks(rows: jax.Array, threshold: jax.Array) -> jax.Array:
    """First-insignificant index per row of ``rows`` (…, k) -> (…,) int32.

    ``threshold == 0`` disables pruning (no ``|v| < 0``): all ranks are k.
    """
    insig = jnp.abs(rows) < threshold
    first = jnp.argmax(insig, axis=-1).astype(jnp.int32)
    any_insig = jnp.any(insig, axis=-1)
    k = rows.shape[-1]
    return jnp.where(any_insig, first, jnp.int32(k))


def pair_rank(r_u: jax.Array, r_i: jax.Array) -> jax.Array:
    """k_eff(u, i) — broadcastable min of the two ranks."""
    return jnp.minimum(r_u, r_i)


def rank_mask(ranks: jax.Array, k: int, dtype=jnp.float32) -> jax.Array:
    """(…,) ranks -> (…, k) 0/1 mask selecting the computed prefix."""
    iota = jnp.arange(k, dtype=jnp.int32)
    return (iota < ranks[..., None]).astype(dtype)


def mask_rows(rows: jax.Array, threshold: jax.Array) -> jax.Array:
    """Zero the suffix starting at each row's first insignificant factor.

    Note this is *not* ``where(|rows| < T, 0, rows)``: significant factors
    sitting after the first insignificant one are zeroed too, exactly as the
    paper's ``break`` skips them.
    """
    r = effective_ranks(rows, threshold)
    return rows * rank_mask(r, rows.shape[-1], rows.dtype)


def pruned_pair_dot(
    p_rows: jax.Array,
    q_rows: jax.Array,
    t_p: jax.Array,
    t_q: jax.Array,
) -> jax.Array:
    """Batched Alg. 2: early-stopped dot of paired rows (B, k) x (B, k) -> (B,).

    Masking each operand by its own rank makes every term with
    ``t >= min(r_u, r_i)`` vanish, reproducing the break exactly.
    """
    return jnp.sum(mask_rows(p_rows, t_p) * mask_rows(q_rows, t_q), axis=-1)


def work_fraction(r_u: jax.Array, r_i: jax.Array, k: int) -> jax.Array:
    """Fraction of the dense k-MACs actually executed for a batch of pairs —
    the work-proportional speedup denominator reported in EXPERIMENTS.md."""
    return jnp.mean(pair_rank(r_u, r_i).astype(jnp.float32)) / float(k)


def sparsity_per_dim(matrix: jax.Array, threshold: jax.Array) -> jax.Array:
    """Per-latent-dim insignificance fraction (paper Figs. 3/5/8)."""
    return jnp.mean((jnp.abs(matrix) < threshold).astype(jnp.float32), axis=0)
