"""The paper's contribution: dynamic pruning for accelerated MF."""
from repro.core.mf import (  # noqa: F401
    MFOptState,
    MFParams,
    eval_epoch_scan,
    eval_mae,
    init_opt_state,
    init_params,
    predict_all_items,
    predict_pairs,
    train_epoch_scan,
    train_epoch_scan_shard_map,
    train_step,
    train_step_shard_map,
)
from repro.core.ranks import (  # noqa: F401
    effective_ranks,
    mask_rows,
    pair_rank,
    pruned_pair_dot,
    rank_mask,
    sparsity_per_dim,
    work_fraction,
)
from repro.core.rearrange import (  # noqa: F401
    apply_perm,
    apply_perm_tree,
    joint_sparsity,
    rearrangement,
)
from repro.core.threshold import (  # noqa: F401
    MatrixStats,
    empirical_pruned_fraction,
    measure_stats,
    threshold_for_rate,
    thresholds_from_matrices,
)
from repro.core.trainer import (  # noqa: F401
    DPMFTrainer,
    EpochRecord,
    TrainConfig,
    percentage_mae,
    work_speedup,
)
