"""DP-MF training driver — the paper's overall procedure (Figs. 6 & 10).

Schedule:
  epoch 1   : standard (unpruned) training — thresholds don't exist yet
  after ep 1: measure (mu, sigma) of P and Q  -> T_p, T_q   (§4.2, once)
              rearrange latent axis by joint sparsity        (§4.3, once)
  epoch 2.. : dynamically pruned training                    (§4.4, per batch)

The dense baseline is the same driver with ``pruning_rate = 0`` (thresholds
collapse to 0 and every mask is all-ones — one code path, as in the paper's
"runtime of the conventional training process is measured by setting the
pruning rate as 0").
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint as ckpt_lib
from repro.core import mf, rearrange, threshold
from repro.data import loader
from repro.data.ratings import RatingsDataset, build_user_history
from repro.distributed.fault_tolerance import (
    StragglerDetector,
    run_with_retries,
)
from repro.optim.optimizers import RowOptimizer
from repro.optim.schedules import twin_learners_mask
from repro.testing import faults


@dataclasses.dataclass
class TrainConfig:
    k: int = 50
    epochs: int = 15
    batch_size: int = 4096
    lr: float = 0.05
    lam: float = 0.02
    pruning_rate: float = 0.0          # 0 disables pruning (dense baseline)
    optimizer: str = "adagrad"         # LibMF's default, as in the paper
    strategy: str = "standard"         # standard | twin  (paper §5.3)
    init_method: str = "normal"        # normal | uniform (paper §5.3)
    variant: str = "funk"              # funk | bias | svdpp
    # -- training objective (repro.workloads) -------------------------------
    # explicit: squared rating error (the paper's setting)
    # implicit: WALS confidence-weighted binary preference (Hu et al. 2008)
    #           — the interaction log is expanded once at init into
    #           positives + sampled negatives with a confidence weight
    #           column riding train_step's batch["weight"] gate
    # bpr:      pairwise -log σ(s_ui - s_uj) on per-epoch sampled triples
    #           (scan mode only; test_mae is NaN, ranking metrics carry)
    objective: str = "explicit"        # explicit | implicit | bpr
    implicit_alpha: float = 40.0       # confidence c = 1 + alpha·r
    implicit_negatives: int = 4        # sampled unobserved items / positive
    use_fused_kernel: bool = False     # Pallas path (interpret mode on CPU)
    epoch_mode: str = "scan"           # scan: one donated lax.scan per epoch
    #                                  # python: legacy per-batch host loop
    seed: int = 0
    eval_batch_size: int = 8192
    max_hist: int = 32                 # svd++ implicit history length
    rearrange: bool = True             # Alg. 1; False = ablation (§Repro)
    ranking_topk: int = 0              # >0: per-epoch HR/NDCG/recall@K too
    ranking_max_users: Optional[int] = 512   # eval-user cap for ranking
    checkpoint_dir: Optional[str] = None
    checkpoint_every_epochs: int = 0   # 0 = only final
    keep_checkpoints: int = 3
    # -- out-of-core streaming (src/repro/store) ----------------------------
    store_dir: Optional[str] = None    # train from an on-disk RatingsStore
    slab_steps: int = 256              # steps per streamed slab
    prefetch_slabs: int = 2            # bounded host prefetch queue depth
    checkpoint_every_slabs: int = 0    # 0 = no mid-epoch checkpoints
    # bounded retries around each streamed slab (store mode): a transient
    # step failure re-runs the slab instead of killing the epoch.  Safe
    # because failures injected/raised before dispatch leave params
    # untouched; 0 disables the wrapper entirely.
    max_step_retries: int = 0
    # -- distributed gradient exchange (shard_map path) ---------------------
    grad_compression: str = "none"     # none | int8 | int8_ef


@dataclasses.dataclass
class EpochRecord:
    """One epoch's logged measurements (``DPMFTrainer.history`` entries).

    The ranking fields are NaN unless ``TrainConfig.ranking_topk > 0`` —
    they come from ``mf.eval_ranking_epoch_scan`` over the test split, so
    the accuracy trajectory carries the served quantity (top-k quality),
    not only the paper's rating error.
    """

    epoch: int
    wall_time_s: float
    train_abs_err: float
    test_mae: float
    work_fraction: float   # mean k_eff / k — the work-proportional cost
    t_p: float
    t_q: float
    hr: float = float("nan")       # HR@K at ranking_topk
    ndcg: float = float("nan")     # NDCG@K
    recall: float = float("nan")   # recall@K
    straggler_slabs: int = 0       # slabs flagged as wall-time outliers
    step_retries: int = 0          # slab retries consumed this epoch


class DPMFTrainer:
    """End-to-end trainer implementing the paper + checkpoint/restart."""

    def __init__(
        self,
        config: TrainConfig,
        train_ds: Optional[RatingsDataset] = None,
        test_ds: Optional[RatingsDataset] = None,
    ):
        self.config = config
        self.opt = RowOptimizer(name=config.optimizer)
        if config.epoch_mode not in ("scan", "python"):
            raise ValueError(f"unknown epoch_mode {config.epoch_mode!r}")
        if config.objective not in ("explicit", "implicit", "bpr"):
            raise ValueError(f"unknown objective {config.objective!r}")
        self._train_weight = None      # implicit confidence column
        self._bpr_sampler = None
        if config.objective != "explicit":
            if config.store_dir is not None:
                raise ValueError(
                    "store-backed training supports only the explicit "
                    "objective"
                )
            if config.epoch_mode != "scan":
                raise ValueError(
                    f"objective {config.objective!r} requires "
                    "epoch_mode='scan'"
                )
            if config.variant == "svdpp":
                raise ValueError(
                    "svdpp histories assume a rated log; use variant "
                    "'funk' or 'bias' with implicit/bpr objectives"
                )
            if train_ds is None:
                raise ValueError(
                    f"objective {config.objective!r} requires train_ds"
                )
        if config.objective == "implicit":
            from repro.workloads import implicit as implicit_wl

            # one-time expansion: positives + sampled negatives, with the
            # WALS confidence column carried as per-example weights
            train_ds, self._train_weight = implicit_wl.implicit_dataset(
                train_ds,
                alpha=config.implicit_alpha,
                negatives=config.implicit_negatives,
                seed=config.seed,
            )
            if test_ds is not None:
                # held-out interactions as preference-1 targets: test MAE
                # reads "distance from 1 on the user's actual items"
                test_ds = implicit_wl.binarize_positives(test_ds)
        self.train_ds = train_ds
        self.test_ds = test_ds
        self._store = None
        self._loader = None
        self._resume_slab = 0
        self._resume_sums = (0.0, 0.0, 0)   # (err_sum, work_sum, steps_done)
        # slab-level fault tolerance: wall-time outlier detection feeding
        # the epoch record, plus an optional test-injected failure source
        # (FailureInjector) exercised under TrainConfig.max_step_retries
        self.straggler = StragglerDetector(window=20, z_threshold=4.0)
        self.failure_injector = None
        self._slab_counter = 0              # global slab index across epochs
        if config.store_dir is not None:
            # Out-of-core path: the ratings stay on disk (mmap) and stream
            # through a bounded prefetch queue as (slab_steps, B) slabs —
            # host memory is bounded by the queue depth, not the dataset.
            from repro.store import RatingsStore, ShardedRatingsLoader

            if config.epoch_mode != "scan":
                raise ValueError("store-backed training requires epoch_mode='scan'")
            if config.variant == "svdpp":
                raise ValueError(
                    "store-backed training does not support svdpp (the "
                    "implicit-history matrix is itself O(users))"
                )
            self._store = RatingsStore(config.store_dir)
            self._loader = ShardedRatingsLoader(
                self._store,
                config.batch_size,
                slab_steps=config.slab_steps,
                prefetch=config.prefetch_slabs,
            )
        elif train_ds is None:
            raise ValueError("either train_ds or config.store_dir is required")
        self.hist = (
            build_user_history(train_ds, config.max_hist)
            if config.variant == "svdpp"
            else None
        )
        if config.epoch_mode == "scan":
            # Upload the ratings (and eval set / SVD++ history) ONCE;
            # per-epoch reshuffles happen on device (data/loader.py).  The
            # batch size is clamped so a tiny dataset trains as one batch
            # per epoch instead of degenerating to zero steps (which is
            # what the drop-remainder host loop silently does).  In store
            # mode the train table never lands on device wholesale.
            self._packed_train = (
                loader.pack_ratings(
                    train_ds,
                    min(config.batch_size, max(len(train_ds), 1)),
                    weight=self._train_weight,
                )
                if self._loader is None and config.objective != "bpr"
                else None
            )
            if config.objective == "bpr":
                from repro.workloads.bpr import BPRSampler

                self._bpr_sampler = BPRSampler(
                    train_ds, config.batch_size, seed=config.seed
                )
            self._packed_eval = (
                loader.pack_eval_batches(test_ds, config.eval_batch_size)
                if test_ds is not None
                else None
            )
        self._hist_dev = None if self.hist is None else jnp.asarray(self.hist)
        self._packed_ranking = None
        if config.ranking_topk > 0 and test_ds is not None:
            from repro.eval import ranking as ranking_eval

            self._packed_ranking = ranking_eval.pack_ranking_batches(
                test_ds, batch_size=256, max_users=config.ranking_max_users
            )

        rng = jax.random.PRNGKey(config.seed)
        src = train_ds if train_ds is not None else self._store
        self.params = mf.init_params(
            rng,
            src.num_users,
            src.num_items,
            config.k,
            variant=config.variant,
            init_method=config.init_method,
            global_mean=src.global_mean,
        )
        self.opt_state = mf.init_opt_state(self.params, self.opt)
        self.t_p = jnp.float32(0.0)
        self.t_q = jnp.float32(0.0)
        self.perm: Optional[jax.Array] = None
        self.epoch = 0
        self.history: List[EpochRecord] = []
        self._ckpt = (
            ckpt_lib.AsyncCheckpointer(
                config.checkpoint_dir, keep=config.keep_checkpoints
            )
            if config.checkpoint_dir
            else None
        )

    # -- checkpoint/restart ------------------------------------------------
    def _state_tree(self) -> Dict[str, Any]:
        return {
            "params": self.params,
            "opt_state": self.opt_state,
            "t_p": self.t_p,
            "t_q": self.t_q,
            "perm": self.perm if self.perm is not None else jnp.arange(
                self.config.k, dtype=jnp.int32
            ),
        }

    def _ckpt_step(self, slabs_done: int = 0) -> int:
        """Checkpoint step numbering.

        Epoch-granular runs use the epoch count directly.  Store-backed runs
        number by slab — ``epoch * num_slabs + slabs_done`` — so an
        epoch-boundary save and a mid-epoch save can never collide, and
        steps stay monotonic across the whole run.
        """
        if self._loader is None:
            return self.epoch
        return self.epoch * self._loader.num_slabs + slabs_done

    def save(self, step: int, *, extra_metadata: Optional[Dict[str, Any]] = None) -> None:
        if self._ckpt is None:
            return
        metadata = {
            "epoch": self.epoch,
            "seed": self.config.seed,
            "pruning_rate": self.config.pruning_rate,
        }
        if extra_metadata:
            metadata.update(extra_metadata)
        self._ckpt.save(step, self._state_tree(), metadata=metadata)

    def _save_mid_epoch(
        self, slabs_done: int, err_sum: float, work_sum: float, steps_done: int
    ) -> None:
        """Checkpoint inside an epoch (store mode): params/opt_state plus the
        running metric accumulators, so a restart replays only the remaining
        slabs and still reports the identical epoch metrics."""
        self.save(
            self._ckpt_step(slabs_done),
            extra_metadata={
                "slab_idx": slabs_done,
                "err_sum": err_sum,
                "work_sum": work_sum,
                "steps_done": steps_done,
            },
        )

    def maybe_restore(self) -> bool:
        if self.config.checkpoint_dir is None:
            return False
        if ckpt_lib.latest_step(self.config.checkpoint_dir) is None:
            return False
        tree, meta = ckpt_lib.restore(self.config.checkpoint_dir, self._state_tree())
        self.params = tree["params"]
        self.opt_state = tree["opt_state"]
        self.t_p = jnp.asarray(tree["t_p"], jnp.float32)
        self.t_q = jnp.asarray(tree["t_q"], jnp.float32)
        self.perm = tree["perm"]
        self.epoch = int(meta["epoch"])
        self._resume_slab = int(meta.get("slab_idx", 0))
        self._resume_sums = (
            float(meta.get("err_sum", 0.0)),
            float(meta.get("work_sum", 0.0)),
            int(meta.get("steps_done", 0)),
        )
        return True

    # -- the paper's one-time calibration (after epoch 1) -------------------
    def calibrate(self) -> None:
        cfg = self.config
        if cfg.pruning_rate <= 0.0:
            return
        self.t_p, self.t_q = threshold.thresholds_from_matrices(
            self.params.p, self.params.q, cfg.pruning_rate
        )
        if not cfg.rearrange:  # ablation: prune without Algorithm 1
            self.perm = jnp.arange(cfg.k, dtype=jnp.int32)
            return
        result = rearrange.rearrangement(
            self.params.p, self.params.q, self.t_p, self.t_q
        )
        self.perm = result.perm
        new_p, new_q = rearrange.apply_perm(self.params.p, self.params.q, self.perm)
        self.params = self.params._replace(p=new_p, q=new_q)
        if self.params.implicit is not None:
            self.params = self.params._replace(
                implicit=jnp.take(self.params.implicit, self.perm, axis=1)
            )
        # Keep optimizer accumulators aligned with the permuted latent axis.
        def permute_state(state):
            return {
                key: (
                    jnp.take(value, self.perm, axis=1)
                    if getattr(value, "ndim", 0) == 2
                    and value.shape[1] == self.config.k
                    else value
                )
                for key, value in state.items()
            }

        self.opt_state = self.opt_state._replace(
            p=permute_state(self.opt_state.p),
            q=permute_state(self.opt_state.q),
            implicit=(
                None
                if self.opt_state.implicit is None
                else permute_state(self.opt_state.implicit)
            ),
        )

    # -- epochs --------------------------------------------------------------
    def run_epoch(self) -> EpochRecord:
        cfg = self.config
        pruning_active = cfg.pruning_rate > 0.0 and self.epoch >= 1
        t_p = self.t_p if pruning_active else jnp.float32(0.0)
        t_q = self.t_q if pruning_active else jnp.float32(0.0)
        dim_mask = (
            twin_learners_mask(cfg.k, self.epoch)
            if cfg.strategy == "twin"
            else jnp.ones((cfg.k,), jnp.float32)
        )
        lr = jnp.float32(cfg.lr)

        start = time.perf_counter()
        straggler_slabs = 0
        retry_count = [0]
        if self._loader is not None:
            # Store mode: the epoch is a sequence of slab-chunked scans fed
            # by the prefetch queue.  Metric means accumulate step-weighted
            # in host float64 so a mid-epoch resume (which restores the
            # partial sums from metadata) reports bitwise-identical epoch
            # numbers to an uninterrupted run — both execute this same
            # chunked path over the same deterministic slab order.
            err_sum, work_sum, steps_done = self._resume_sums
            start_slab = self._resume_slab
            self._resume_slab = 0
            self._resume_sums = (0.0, 0.0, 0)
            num_slabs = self._loader.num_slabs
            for slab in self._loader.epoch_slabs(
                cfg.seed, self.epoch, start_slab=start_slab
            ):
                def run_slab(slab=slab):
                    # faults fire BEFORE the dispatch so a retry re-runs
                    # the slab against untouched params (no donation hazard)
                    if self.failure_injector is not None:
                        self.failure_injector(self._slab_counter)
                    if faults._PLAN is not None:
                        for act in faults.fire("trainer.slab"):
                            if act.op == "error":
                                raise faults.FaultError(
                                    "injected slab failure"
                                )
                    return mf.train_epoch_scan(
                        self.params,
                        self.opt_state,
                        slab.batches,
                        t_p,
                        t_q,
                        lr,
                        dim_mask,
                        self._hist_dev,
                        opt=self.opt,
                        lam=cfg.lam,
                        use_fused_kernel=cfg.use_fused_kernel,
                    )

                slab_start = time.perf_counter()
                if cfg.max_step_retries > 0:
                    self.params, self.opt_state, metrics = run_with_retries(
                        run_slab,
                        max_retries=cfg.max_step_retries,
                        backoff_s=0.05,
                        on_retry=lambda n, exc: retry_count.__setitem__(
                            0, retry_count[0] + 1
                        ),
                    )
                else:
                    self.params, self.opt_state, metrics = run_slab()
                jax.block_until_ready(self.params.p)
                if self.straggler.record(time.perf_counter() - slab_start):
                    straggler_slabs += 1
                self._slab_counter += 1
                err_sum += float(metrics["abs_err"]) * slab.steps
                work_sum += float(metrics["work_fraction"]) * slab.steps
                steps_done += slab.steps
                slabs_done = slab.slab_idx + 1
                if (
                    self._ckpt is not None
                    and cfg.checkpoint_every_slabs
                    and slabs_done % cfg.checkpoint_every_slabs == 0
                    and slabs_done < num_slabs
                ):
                    self._save_mid_epoch(slabs_done, err_sum, work_sum, steps_done)
            abs_err = err_sum / max(steps_done, 1)
            work = work_sum / max(steps_done, 1)
        elif cfg.objective == "bpr":
            # Pairwise epoch: freshly sampled (user, pos, neg) triples folded
            # through the same scan machinery; abs_err carries the BPR loss.
            from repro.workloads import bpr as bpr_wl

            triples = self._bpr_sampler.epoch_triples(self.epoch)
            self.params, self.opt_state, metrics = bpr_wl.bpr_epoch_scan(
                self.params,
                self.opt_state,
                triples,
                t_p,
                t_q,
                lr,
                dim_mask,
                opt=self.opt,
                lam=cfg.lam,
            )
            jax.block_until_ready(self.params.p)
            abs_err = float(metrics["abs_err"])
            work = float(metrics["work_fraction"])
        elif cfg.epoch_mode == "scan":
            # One donated, compiled computation for the whole epoch: on-device
            # reshuffle, lax.scan of train_step, metrics summed on device.
            batches = self._packed_train.epoch_batches(cfg.seed, self.epoch)
            self.params, self.opt_state, metrics = mf.train_epoch_scan(
                self.params,
                self.opt_state,
                batches,
                t_p,
                t_q,
                lr,
                dim_mask,
                self._hist_dev,
                opt=self.opt,
                lam=cfg.lam,
                use_fused_kernel=cfg.use_fused_kernel,
            )
            jax.block_until_ready(self.params.p)
            # the epoch's single host sync: two scalars
            abs_err = float(metrics["abs_err"])
            work = float(metrics["work_fraction"])
        else:
            # Legacy per-batch loop.  Metrics accumulate as device scalars —
            # fetched once after the loop, never per step (a float() here
            # would serialize every dispatch on a host sync).
            abs_err_sum = jnp.zeros((), jnp.float32)
            work_sum = jnp.zeros((), jnp.float32)
            steps = 0
            for batch_np in loader.iterate_batches(
                self.train_ds,
                cfg.batch_size,
                seed=cfg.seed,
                epoch=self.epoch,
                hist=self.hist,
            ):
                batch = {key: jnp.asarray(value) for key, value in batch_np.items()}
                self.params, self.opt_state, metrics = mf.train_step(
                    self.params,
                    self.opt_state,
                    batch,
                    t_p,
                    t_q,
                    lr,
                    dim_mask,
                    opt=self.opt,
                    lam=cfg.lam,
                    use_fused_kernel=cfg.use_fused_kernel,
                )
                abs_err_sum = abs_err_sum + metrics["abs_err"]
                work_sum = work_sum + metrics["work_fraction"]
                steps += 1
            jax.block_until_ready(self.params.p)
            abs_err = float(abs_err_sum) / max(steps, 1)
            work = float(work_sum) / max(steps, 1)
        wall = time.perf_counter() - start

        test_mae = self.evaluate(t_p, t_q) if self.test_ds is not None else float("nan")
        ranking = self.evaluate_ranking(t_p, t_q)
        record = EpochRecord(
            epoch=self.epoch,
            wall_time_s=wall,
            train_abs_err=abs_err,
            test_mae=test_mae,
            work_fraction=work,
            t_p=float(t_p),
            t_q=float(t_q),
            straggler_slabs=straggler_slabs,
            step_retries=retry_count[0],
            **(
                {"hr": ranking.hr, "ndcg": ranking.ndcg,
                 "recall": ranking.recall}
                if ranking is not None else {}
            ),
        )
        self.history.append(record)

        if self.epoch == 0:
            self.calibrate()  # paper: once, right after the first epoch
        self.epoch += 1
        if (
            self._ckpt is not None
            and cfg.checkpoint_every_epochs
            and self.epoch % cfg.checkpoint_every_epochs == 0
        ):
            self.save(self._ckpt_step())
        return record

    def run(self) -> List[EpochRecord]:
        start_epoch = self.epoch
        for _ in range(start_epoch, self.config.epochs):
            self.run_epoch()
        if self._ckpt is not None:
            self.save(self._ckpt_step())
            self._ckpt.wait()
        return self.history

    def evaluate(self, t_p=None, t_q=None) -> float:
        """Test MAE (Eq. 12) with the current pruning thresholds.

        NaN when there is no test split, and under the ``bpr`` objective —
        pairwise scores have no rating scale, so rating error is undefined;
        use :meth:`evaluate_ranking` there instead.
        """
        if self.test_ds is None or self.config.objective == "bpr":
            return float("nan")
        t_p = self.t_p if t_p is None else t_p
        t_q = self.t_q if t_q is None else t_q
        if self.config.epoch_mode == "scan":
            total, count = mf.eval_epoch_scan(
                self.params, self._packed_eval, t_p, t_q, self._hist_dev
            )
            return float(total) / max(float(count), 1.0)
        # Legacy loop: accumulate on device, fetch once at the end.
        total = jnp.zeros((), jnp.float32)
        count = jnp.zeros((), jnp.float32)
        for batch_np in loader.iterate_batches(
            self.test_ds,
            self.config.eval_batch_size,
            shuffle=False,
            drop_remainder=False,
            hist=self.hist,
        ):
            batch = {key: jnp.asarray(value) for key, value in batch_np.items()}
            s, c = mf.eval_mae(self.params, batch, t_p, t_q)
            total = total + s
            count = count + c
        return float(total) / max(float(count), 1.0)

    def evaluate_ranking(self, t_p=None, t_q=None):
        """Test-split HR/NDCG/recall@``ranking_topk`` at the given (default:
        current) thresholds, as a :class:`~repro.eval.ranking.RankingReport`.
        Returns None unless ``TrainConfig.ranking_topk > 0`` and a test
        split exists.  Runs as one compiled scan
        (``mf.eval_ranking_epoch_scan``) over batches packed at init."""
        if self._packed_ranking is None:
            return None
        from repro.eval import ranking as ranking_eval

        t_p = self.t_p if t_p is None else t_p
        t_q = self.t_q if t_q is None else t_q
        sums = mf.eval_ranking_epoch_scan(
            self.params, self._packed_ranking, t_p, t_q, self._hist_dev,
            topk=self.config.ranking_topk,
        )
        return ranking_eval.report_from_sums(
            {key: float(value) for key, value in sums.items()},
            self.config.ranking_topk,
        )

    # -- summary metrics matching the paper's Eqs. 12-14 ---------------------
    def total_train_time(self) -> float:
        return sum(r.wall_time_s for r in self.history)

    def mean_work_fraction(self) -> float:
        pruned = [r.work_fraction for r in self.history if r.epoch >= 1]
        return float(np.mean(pruned)) if pruned else 1.0


def percentage_mae(mae_accelerated: float, mae_original: float) -> float:
    """Eq. 13."""
    return (mae_accelerated - mae_original) / mae_original * 100.0


def work_speedup(history: List[EpochRecord]) -> float:
    """Work-proportional speedup: dense MACs / executed MACs over the whole
    run (epoch 1 is always dense, as in the paper)."""
    total = len(history)
    if total == 0:
        return 1.0
    executed = sum(r.work_fraction for r in history)
    return total / max(executed, 1e-9)
