"""MF model family: FunkSVD, BiasSVD, SVD++ with first-class dynamic pruning.

The paper develops its method on FunkSVD and notes it applies unchanged to
BiasSVD and SVD++ ("they have the same training process"); all three are
implemented here behind one step function.  Pruning is always expressed
through thresholds ``(t_p, t_q)`` — passing zeros disables it *numerically*
(no factor satisfies ``|v| < 0``), so the dense baseline and the accelerated
path share one code path and one compiled program.

Conventions: ``p`` is (m, k) user-major, ``q`` is (n, k) item-major (the
paper's ``Q_{k x n}`` transposed), biases are (rows, 1) so the row-optimizer
API applies uniformly.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.ranks import effective_ranks, rank_mask
from repro.kernels import ops as kops
from repro.optim.optimizers import RowOptimizer

Batch = Dict[str, jax.Array]


class MFParams(NamedTuple):
    p: jax.Array                       # (m, k)
    q: jax.Array                       # (n, k)
    user_bias: Optional[jax.Array]     # (m, 1) | None
    item_bias: Optional[jax.Array]     # (n, 1) | None
    global_mean: Optional[jax.Array]   # ()     | None
    implicit: Optional[jax.Array]      # (n + 1, k) | None; row n is padding


def init_params(
    rng: jax.Array,
    num_users: int,
    num_items: int,
    k: int,
    *,
    variant: str = "funk",          # funk | bias | svdpp
    init_method: str = "normal",    # normal | uniform | libmf  (paper §5.3)
    scale: float = 0.1,
    global_mean: float = 0.0,
    dtype=jnp.float32,
) -> MFParams:
    kp, kq, ky = jax.random.split(rng, 3)
    if init_method == "normal":
        p = scale * jax.random.normal(kp, (num_users, k), dtype)
        q = scale * jax.random.normal(kq, (num_items, k), dtype)
        y = scale * jax.random.normal(ky, (num_items + 1, k), dtype)
    elif init_method == "uniform":
        # Same std as the normal init so thresholds are comparable.
        lim = scale * (3.0 ** 0.5)
        p = jax.random.uniform(kp, (num_users, k), dtype, -lim, lim)
        q = jax.random.uniform(kq, (num_items, k), dtype, -lim, lim)
        y = jax.random.uniform(ky, (num_items + 1, k), dtype, -lim, lim)
    elif init_method == "libmf":
        # LibMF's non-negative init, U(0, 1/sqrt(k)).  The positive common
        # component it induces is what concentrates significance in leading
        # latent dims (the paper's Fig. 7 distributions have mu > 0, and
        # Eq. 8 explicitly handles the asymmetric case) — the regime where
        # dynamic pruning keeps P_MAE <= 20% (EXPERIMENTS.md §Repro).
        lim = k ** -0.5
        p = jax.random.uniform(kp, (num_users, k), dtype, 0.0, lim)
        q = jax.random.uniform(kq, (num_items, k), dtype, 0.0, lim)
        y = jax.random.uniform(ky, (num_items + 1, k), dtype, 0.0, lim)
    else:
        raise ValueError(f"unknown init {init_method!r}")

    with_bias = variant in ("bias", "svdpp")
    return MFParams(
        p=p,
        q=q,
        user_bias=jnp.zeros((num_users, 1), dtype) if with_bias else None,
        item_bias=jnp.zeros((num_items, 1), dtype) if with_bias else None,
        global_mean=jnp.asarray(global_mean, dtype) if with_bias else None,
        implicit=y.at[num_items].set(0.0) if variant == "svdpp" else None,
    )


def params_from_flat(arrays: Dict[str, Any], prefix: str = "params__") -> MFParams:
    """Rebuild :class:`MFParams` from a flat ``{key: array}`` checkpoint
    payload (the ``params__p``-style keys the checkpointer's path flattening
    produces).  The single owner of that key mapping — the serving loader
    and the online delta folds both go through here."""

    def opt(name):
        key = prefix + name
        return jnp.asarray(arrays[key]) if key in arrays else None

    return MFParams(
        p=jnp.asarray(arrays[prefix + "p"]),
        q=jnp.asarray(arrays[prefix + "q"]),
        user_bias=opt("user_bias"),
        item_bias=opt("item_bias"),
        global_mean=opt("global_mean"),
        implicit=opt("implicit"),
    )


def _user_vector(
    params: MFParams, u: jax.Array, hist: Optional[jax.Array]
) -> jax.Array:
    """p_u, or SVD++'s p_u + |N(u)|^-1/2 * sum_{j in N(u)} y_j."""
    p_rows = params.p[u]
    if params.implicit is None or hist is None:
        return p_rows
    # hist: (B, H) item ids padded with num_items (the zero row of `implicit`).
    n_items = params.implicit.shape[0] - 1
    y_sum = jnp.sum(params.implicit[hist], axis=1)
    counts = jnp.sum((hist < n_items).astype(jnp.float32), axis=1, keepdims=True)
    return p_rows + y_sum * jax.lax.rsqrt(jnp.maximum(counts, 1.0))


def predict_pairs(
    params: MFParams,
    u: jax.Array,
    i: jax.Array,
    t_p: jax.Array | float = 0.0,
    t_q: jax.Array | float = 0.0,
    hist: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Pruned predictions for (u, i) pairs.  Returns (pred, pair_ranks)."""
    pu = _user_vector(params, u, hist)
    qi = params.q[i]
    r_u = effective_ranks(pu, t_p)
    r_i = effective_ranks(qi, t_q)
    k = pu.shape[-1]
    mask = rank_mask(jnp.minimum(r_u, r_i), k)
    pred = jnp.sum(pu.astype(jnp.float32) * qi.astype(jnp.float32) * mask, axis=-1)
    if params.user_bias is not None:
        pred = (
            pred
            + params.global_mean
            + params.user_bias[u, 0]
            + params.item_bias[i, 0]
        )
    return pred, jnp.minimum(r_u, r_i)


def predict_all_items(
    params: MFParams,
    u: jax.Array,
    t_p: jax.Array | float = 0.0,
    t_q: jax.Array | float = 0.0,
    *,
    use_kernel: bool = True,
    hist: Optional[jax.Array] = None,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Serving / retrieval: score a user batch against *all* items.

    This is the paper's "matrix multiplication" stage at recommendation time
    and the hot path of the `retrieval_cand` shape — routed through the
    tile-ragged Pallas kernel.
    """
    pu = _user_vector(params, u, hist)
    r_u = effective_ranks(pu, t_p)
    r_i = effective_ranks(params.q, t_q)
    if use_kernel:
        scores = kops.pruned_matmul(
            pu, params.q, t_p, t_q, interpret=interpret
        )
    else:
        from repro.kernels import ref

        scores = ref.pruned_matmul_ref(pu, params.q, r_u, r_i)
    if params.user_bias is not None:
        scores = (
            scores
            + params.global_mean
            + params.user_bias[u]
            + params.item_bias[:, 0][None, :]
        )
    return scores


class MFOptState(NamedTuple):
    p: Dict[str, jax.Array]
    q: Dict[str, jax.Array]
    user_bias: Optional[Dict[str, jax.Array]]
    item_bias: Optional[Dict[str, jax.Array]]
    implicit: Optional[Dict[str, jax.Array]]


def init_opt_state(params: MFParams, opt: RowOptimizer) -> MFOptState:
    return MFOptState(
        p=opt.init(params.p),
        q=opt.init(params.q),
        user_bias=None if params.user_bias is None else opt.init(params.user_bias),
        item_bias=None if params.item_bias is None else opt.init(params.item_bias),
        implicit=None if params.implicit is None else opt.init(params.implicit),
    )


def _train_step(
    params: MFParams,
    opt_state: MFOptState,
    batch: Batch,
    t_p: jax.Array,
    t_q: jax.Array,
    lr: jax.Array,
    dim_mask: jax.Array,  # (k,) twin-learners / strategy mask
    *,
    opt: RowOptimizer,
    lam: float,
    use_fused_kernel: bool = False,
    interpret: Optional[bool] = None,
) -> Tuple[MFParams, MFOptState, Dict[str, jax.Array]]:
    """One minibatched, dynamically-pruned MF update (Algs. 2 + 3).

    ``use_fused_kernel`` routes every plain-SGD case without implicit
    feedback — FunkSVD *and* BiasSVD, weighted or not — through the fused
    Pallas kernel (biases and the weight column ride along in-kernel); every
    other (variant, optimizer) combination uses the masked XLA formulation
    with identical semantics.  Duplicate (u, i) rows in a batch accumulate
    additively (scatter-add), the standard minibatch relaxation of the
    paper's sequential SGD.

    An optional ``batch["weight"]`` (B,) gates rows out of the update —
    gradients, bias/implicit updates, and metrics all scale by it (0 = row
    fully inert, fractional = importance weighting).  The weight multiplies
    the *update mask* and the metrics only — never the prediction, which
    must stay the full model output for the error (and thus the gradient
    direction) to be right.  NB: for the stateful-EMA optimizers
    (momentum/adadelta/adam) a zero-weight row still *writes back* its
    row's decayed state, the same caveat duplicate rows already carry —
    which is why the online updater chunks instead of padding.
    """
    u, i, r = batch["user"], batch["item"], batch["rating"].astype(jnp.float32)
    hist = batch.get("hist")
    weight = batch.get("weight")
    k = params.p.shape[-1]

    pu = _user_vector(params, u, hist)
    qi = params.q[i]
    r_u = effective_ranks(pu, t_p)
    r_i = effective_ranks(qi, t_q)
    pair_ranks = jnp.minimum(r_u, r_i)
    pred_mask = rank_mask(pair_ranks, k) * dim_mask[None, :]
    w = (
        jnp.ones_like(r) if weight is None else weight.astype(jnp.float32)
    )
    mask = pred_mask * w[:, None]  # gates updates; predictions use pred_mask

    fused_ok = (
        use_fused_kernel
        and opt.name == "sgd"
        and params.implicit is None
    )
    if fused_ok:
        has_bias = params.user_bias is not None
        new_pu, new_qi, new_bu, new_bi, err = kops.fused_mf_sgd(
            params.p[u],
            qi,
            r,
            t_p,
            t_q,
            lr=1.0,  # lr folded below so it can stay a traced array
            lam=lam,
            bias_u=params.user_bias[u, 0] if has_bias else None,
            bias_i=params.item_bias[i, 0] if has_bias else None,
            global_mean=params.global_mean if has_bias else 0.0,
            weight=weight,
            interpret=interpret,
        )
        # kernel computed rows at lr=1; rescale the delta by the traced lr and
        # the strategy mask, then scatter-add (duplicate-safe).
        dp = (new_pu - params.p[u]) * lr * dim_mask[None, :]
        dq = (new_qi - qi) * lr * dim_mask[None, :]
        new_params = params._replace(
            p=params.p.at[u].add(dp.astype(params.p.dtype)),
            q=params.q.at[i].add(dq.astype(params.q.dtype)),
        )
        if has_bias:
            dbu = (new_bu - params.user_bias[u, 0]) * lr
            dbi = (new_bi - params.item_bias[i, 0]) * lr
            new_params = new_params._replace(
                user_bias=params.user_bias.at[u, 0].add(
                    dbu.astype(params.user_bias.dtype)
                ),
                item_bias=params.item_bias.at[i, 0].add(
                    dbi.astype(params.item_bias.dtype)
                ),
            )
        denom = jnp.maximum(jnp.sum(w), 1e-9)
        metrics = {
            "abs_err": jnp.sum(jnp.abs(err) * w) / denom,
            "work_fraction": jnp.sum(pair_ranks.astype(jnp.float32) * w)
            / (denom * k),
        }
        return new_params, opt_state, metrics

    pred = jnp.sum(
        pu.astype(jnp.float32) * qi.astype(jnp.float32) * pred_mask, axis=-1
    )
    if params.user_bias is not None:
        pred = (
            pred
            + params.global_mean
            + params.user_bias[u, 0]
            + params.item_bias[i, 0]
        )
    err = r - pred

    # Gradients of 0.5*err^2 + 0.5*lam*||.||^2 wrt the gathered rows; the
    # paper's update p += lr*(err*q - lam*p) is descent on exactly this.
    g_p = (lam * pu - err[:, None] * qi).astype(jnp.float32)
    g_q = (lam * qi - err[:, None] * pu).astype(jnp.float32)

    new_p, st_p = opt.apply_rows(params.p, opt_state.p, u, g_p, mask, lr)
    new_q, st_q = opt.apply_rows(params.q, opt_state.q, i, g_q, mask, lr)
    new_params = params._replace(p=new_p, q=new_q)
    new_state = opt_state._replace(p=st_p, q=st_q)

    if params.user_bias is not None:
        w_col = w[:, None]
        g_bu = (lam * params.user_bias[u] - err[:, None]).astype(jnp.float32)
        g_bi = (lam * params.item_bias[i] - err[:, None]).astype(jnp.float32)
        new_bu, st_bu = opt.apply_rows(
            params.user_bias, opt_state.user_bias, u, g_bu, w_col, lr
        )
        new_bi, st_bi = opt.apply_rows(
            params.item_bias, opt_state.item_bias, i, g_bi, w_col, lr
        )
        new_params = new_params._replace(user_bias=new_bu, item_bias=new_bi)
        new_state = new_state._replace(user_bias=st_bu, item_bias=st_bi)

    if params.implicit is not None and hist is not None:
        # dL/dy_j = -err * q_i / sqrt(|N(u)|) for each j in N(u), masked.
        n_items = params.implicit.shape[0] - 1
        counts = jnp.sum((hist < n_items).astype(jnp.float32), axis=1, keepdims=True)
        coef = err[:, None] * jax.lax.rsqrt(jnp.maximum(counts, 1.0))
        # pred_mask here, not mask: the row weight rides in via flat_mask
        # below (apply_rows multiplies it in) — using mask would square it
        g_y = -(coef[:, None, :] * (qi * pred_mask)[:, None, :]) * jnp.ones(
            (1, hist.shape[1], 1), jnp.float32
        )
        g_y = g_y + lam * params.implicit[hist]
        flat_idx = hist.reshape(-1)
        flat_g = g_y.reshape(-1, k)
        flat_mask = jnp.repeat(mask, hist.shape[1], axis=0) * (
            flat_idx < n_items
        ).astype(jnp.float32)[:, None]
        new_y, st_y = opt.apply_rows(
            params.implicit, opt_state.implicit, flat_idx, flat_g, flat_mask, lr
        )
        new_y = new_y.at[n_items].set(0.0)  # keep the padding row inert
        new_params = new_params._replace(implicit=new_y)
        new_state = new_state._replace(implicit=st_y)

    denom = jnp.maximum(jnp.sum(w), 1e-9)  # weighted mean, not deflated
    metrics = {
        "abs_err": jnp.sum(jnp.abs(err) * w) / denom,
        "work_fraction": jnp.sum(pair_ranks.astype(jnp.float32) * w)
        / (denom * k),
    }
    return new_params, new_state, metrics


train_step = jax.jit(
    _train_step,
    static_argnames=("opt", "lam", "use_fused_kernel", "interpret"),
)


def _eval_mae(
    params: MFParams,
    batch: Batch,
    t_p: jax.Array,
    t_q: jax.Array,
) -> Tuple[jax.Array, jax.Array]:
    """Sum |err| and count over a (possibly weight-masked) eval batch."""
    pred, _ = predict_pairs(
        params, batch["user"], batch["item"], t_p, t_q, batch.get("hist")
    )
    w = batch.get("weight", jnp.ones_like(pred))
    abs_err = jnp.abs(batch["rating"].astype(jnp.float32) - pred) * w
    return jnp.sum(abs_err), jnp.sum(w)


eval_mae = jax.jit(_eval_mae)


# ---------------------------------------------------------------------------
# Epoch-compiled training: one donated lax.scan per epoch
# ---------------------------------------------------------------------------


def _epoch_scan(step_fn, params, opt_state, batches):
    """``lax.scan`` of ``step_fn`` over packed ``(steps, B)`` batch arrays.

    Metrics accumulate on device (sum of per-batch means, divided once at the
    end — identical to what the per-batch Python loop computes) so an epoch
    costs exactly one host sync, taken by the *caller* when it fetches the
    returned scalars.
    """
    steps = jax.tree_util.tree_leaves(batches)[0].shape[0]

    def body(carry, batch):
        p, s, err_sum, work_sum = carry
        p, s, m = step_fn(p, s, batch)
        return (p, s, err_sum + m["abs_err"], work_sum + m["work_fraction"]), None

    init = (
        params,
        opt_state,
        jnp.zeros((), jnp.float32),
        jnp.zeros((), jnp.float32),
    )
    (new_params, new_state, err_sum, work_sum), _ = jax.lax.scan(
        body, init, batches
    )
    denom = jnp.float32(max(steps, 1))
    metrics = {"abs_err": err_sum / denom, "work_fraction": work_sum / denom}
    return new_params, new_state, metrics


@functools.partial(
    jax.jit,
    static_argnames=("opt", "lam", "use_fused_kernel", "interpret"),
    donate_argnums=(0, 1),
)
def train_epoch_scan(
    params: MFParams,
    opt_state: MFOptState,
    batches: Batch,       # each value (steps, B, ...) — data/loader.PackedRatings
    t_p: jax.Array,
    t_q: jax.Array,
    lr: jax.Array,
    dim_mask: jax.Array,
    hist: Optional[jax.Array] = None,   # (m, H) device-resident SVD++ history
    *,
    opt: RowOptimizer,
    lam: float,
    use_fused_kernel: bool = False,
    interpret: Optional[bool] = None,
) -> Tuple[MFParams, MFOptState, Dict[str, jax.Array]]:
    """A whole epoch as ONE compiled, donated computation.

    Semantically a fold of :func:`train_step` over the packed batches —
    ``train_step`` stays the single-step owner (the online updater and the
    legacy trainer path call it directly); this is the same body traced once
    into a ``lax.scan``, so the per-step dispatch/upload/sync overhead of
    the Python loop disappears.  ``donate_argnums=(0, 1)`` lets XLA update
    params and optimizer state in place across the epoch.  The SVD++
    history table is passed whole and gathered per step on device, instead
    of being packed into (steps, B, H) batch arrays.
    """

    def step(p, s, batch):
        if hist is not None:
            batch = dict(batch, hist=hist[batch["user"]])
        return _train_step(
            p, s, batch, t_p, t_q, lr, dim_mask,
            opt=opt, lam=lam,
            use_fused_kernel=use_fused_kernel, interpret=interpret,
        )

    return _epoch_scan(step, params, opt_state, batches)


@jax.jit
def eval_epoch_scan(
    params: MFParams,
    batches: Batch,       # each value (steps, B, ...), weight-padded tail
    t_p: jax.Array,
    t_q: jax.Array,
    hist: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Sum |err| and weighted count over pre-packed eval batches — the
    :func:`eval_mae` treatment of a whole pass, fetched once."""

    def body(carry, batch):
        tot, cnt = carry
        if hist is not None:
            batch = dict(batch, hist=hist[batch["user"]])
        s, c = _eval_mae(params, batch, t_p, t_q)
        return (tot + s, cnt + c), None

    (tot, cnt), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)), batches
    )
    return tot, cnt


@functools.partial(jax.jit, static_argnames=("topk",))
def eval_ranking_epoch_scan(
    params: MFParams,
    batches: Batch,       # repro.eval.ranking.pack_ranking_batches output
    t_p: jax.Array,
    t_q: jax.Array,
    hist: Optional[jax.Array] = None,
    *,
    topk: int,
) -> Dict[str, jax.Array]:
    """Ranking-metrics variant of :func:`eval_epoch_scan`: HR@K / NDCG@K /
    recall@K sums over pre-packed user batches, one compiled scan.

    Each step scores its user batch against the full catalog with the
    masked (rank-truncated) formulation — the same math the serving layouts
    bake in, so at equal thresholds the resulting rankings are the engine's
    — takes ``lax.top_k``, and folds the batch through
    :func:`repro.eval.ranking.ranking_counts`.  The per-user additive
    constant (user bias + global mean) is omitted: it never changes a
    ranking.  Item ranks reduce once outside the scan.  ``batches`` comes
    from :func:`repro.eval.ranking.pack_ranking_batches`; divide the metric
    sums by ``weight_sum`` for means (``RankingReport`` semantics).
    """
    from repro.eval.ranking import ranking_counts

    k = params.p.shape[1]
    r_i = effective_ranks(params.q, t_q)
    qm = params.q.astype(jnp.float32) * rank_mask(r_i, k)
    item_bias = (
        None if params.item_bias is None
        else params.item_bias[:, 0].astype(jnp.float32)
    )

    def body(carry, batch):
        u = batch["user"]
        h = None if hist is None else hist[u]
        pu = _user_vector(params, u, h)
        r_u = effective_ranks(pu, t_p)
        pm = pu.astype(jnp.float32) * rank_mask(r_u, k)
        scores = jnp.dot(pm, qm.T, preferred_element_type=jnp.float32)
        if item_bias is not None:
            scores = scores + item_bias[None, :]
        _, idx = jax.lax.top_k(scores, topk)
        counts = ranking_counts(
            idx, batch["relevant"], batch["n_valid"], batch.get("weight")
        )
        return (
            {key: carry[key] + counts[key] for key in carry},
            None,
        )

    init = {
        key: jnp.zeros((), jnp.float32)
        for key in ("hr_sum", "ndcg_sum", "recall_sum", "weight_sum")
    }
    sums, _ = jax.lax.scan(body, init, batches)
    return sums


# ---------------------------------------------------------------------------
# Owner-compute distributed step (§Perf iteration for the paper's model)
# ---------------------------------------------------------------------------


def _check_owner_compute_opt(opt_name: str) -> None:
    if opt_name not in ("adagrad", "sgd"):
        raise ValueError(
            "the owner-compute step implements sgd and adagrad only, got "
            f"{opt_name!r}"
        )


def _resolve_grad_compression(grad_compression: str, compress_grads: bool) -> str:
    """Normalize the two compression knobs: the legacy ``compress_grads``
    bool maps to plain ``"int8"``; the string knob wins when both are set."""
    if grad_compression == "none" and compress_grads:
        return "int8"
    if grad_compression not in ("none", "int8", "int8_ef"):
        raise ValueError(
            f"grad_compression must be none|int8|int8_ef, got {grad_compression!r}"
        )
    return grad_compression


def init_error_feedback_state(
    params: MFParams, opt_state: MFOptState, mesh=None
) -> MFOptState:
    """Attach int8 error-feedback residual tables to ``opt_state``.

    ``grad_compression="int8_ef"`` keeps, per *sender*, the running
    quantization residual of each collective payload and folds it into the
    next step's transmission (EF-SGD: the optimizer trajectory converges as
    if the links were full-precision).  Two residual tables, one per
    compressed collective, shaped so each mesh rank owns exactly its own
    sender state:

    * ``opt_state.p["ef_psum"]``: ``(m, n_model * k)`` over ``P(dp,
      "model")`` — each model rank's untransmitted part of the p-gradient
      psum, keyed by user row.
    * ``opt_state.q["ef_gather"]``: ``(n, n_dp * k)`` over ``P("model",
      dp)`` — each data rank's untransmitted part of the q-delta
      all-gather, keyed by item row.
    """
    from repro.distributed import mesh_compat

    mesh = mesh_compat.resolve_mesh(mesh)
    if mesh is None:
        raise ValueError(
            "init_error_feedback_state needs a mesh: pass mesh= or enter a "
            "mesh_compat.use_mesh(...) context"
        )
    n_dp = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            n_dp *= mesh.shape[a]
    n_model = mesh.shape["model"]
    m, k = params.p.shape
    n = params.q.shape[0]
    return opt_state._replace(
        p={**opt_state.p, "ef_psum": jnp.zeros((m, n_model * k), jnp.float32)},
        q={**opt_state.q, "ef_gather": jnp.zeros((n, n_dp * k), jnp.float32)},
    )


def train_step_shard_map(
    params: MFParams,
    opt_state: MFOptState,
    batch: Batch,
    t_p: jax.Array,
    t_q: jax.Array,
    *,
    lr: float,
    lam: float,
    opt_name: str = "adagrad",
    eps: float = 1e-8,
    compress_grads: bool = False,
    grad_compression: str = "none",
    mesh=None,
) -> Tuple[MFParams, MFOptState, Dict[str, jax.Array]]:
    """DP-MF minibatch step with owner-compute collectives (FunkSVD only).

    The XLA-SPMD lowering of :func:`train_step` all-reduces the gathered
    (B, k) item rows *and* the full (n, k) item-gradient scatter across the
    mesh (~7 GB/device/step at the dpmf train_1m shape).  This formulation
    exploits the sharding contract instead:

      * user rows P are sharded over the data axes; the data pipeline routes
        each rating to its user's shard (standard row-wise sharding), so all
        P traffic is local;
      * item rows Q are sharded over "model"; each model rank computes the
        *partial* masked dot for the ratings whose item it owns (other ranks
        contribute exact zeros, because a zero row has effective rank 0);
      * ONE psum of the (B_loc,) partial predictions and ONE psum of the
        (B_loc, k) masked p-deltas cross the links; the q update never
        leaves its owner.

    ``grad_compression="int8"`` (or the legacy ``compress_grads=True``)
    int8-quantizes the p-gradient psum and the q-delta all-gather payloads
    (4x fewer bytes on the dominant collectives; per-tensor scales psum'd
    alongside).  Quantization error is bounded by scale/2 per element.
    ``"int8_ef"`` adds per-sender error feedback: each rank keeps the
    residual its quantizer dropped (``init_error_feedback_state`` tables in
    ``opt_state``) and folds it into the next transmission of the same row
    — the EF-SGD recipe that keeps long-run convergence at fp32 quality.
    Duplicate rows in one batch fold their residual deltas additively
    (the same duplicate-accumulation caveat as the base step).

    Collectives drop from O(n*k + B*k) all-reduce bytes to O(B_loc*k) —
    measured in EXPERIMENTS.md §Perf.  Semantics are identical to
    :func:`train_step` (same masked Alg. 2/3 math; duplicate rows
    accumulate), including the optional ``batch["weight"]`` update gate —
    zero-weight rows are fully inert, which is what lets the online
    updater's shard router pad per-shard buckets.
    """
    from jax.sharding import PartitionSpec as P

    from repro.distributed import mesh_compat

    mesh = mesh_compat.resolve_mesh(mesh)
    if mesh is None:
        raise ValueError(
            "train_step_shard_map needs a mesh: pass mesh= or enter a "
            "mesh_compat.use_mesh(...) context"
        )
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    n_dp = 1
    for a in dp:
        n_dp *= mesh.shape[a]
    n_model = mesh.shape["model"]
    m_loc = params.p.shape[0] // n_dp
    n_loc = params.q.shape[0] // n_model
    k = params.p.shape[1]
    _check_owner_compute_opt(opt_name)
    adagrad = opt_name == "adagrad"
    gc = _resolve_grad_compression(grad_compression, compress_grads)

    def body(p_blk, q_blk, acc_p, acc_q, ef_p, ef_q, u, i, r, w, t_p, t_q):
        # block-local coordinates
        dp_idx = jnp.int32(0)
        stride = 1
        for a in reversed(dp):
            dp_idx = dp_idx + jax.lax.axis_index(a) * stride
            stride *= mesh.shape[a]
        u_loc = u - dp_idx * m_loc          # pipeline guarantees ownership
        m_idx = jax.lax.axis_index("model")
        off_i = m_idx * n_loc
        is_local = (i >= off_i) & (i < off_i + n_loc)
        i_loc = jnp.clip(i - off_i, 0, n_loc - 1)

        p_rows = p_blk[u_loc].astype(jnp.float32)          # (B_loc, k)
        q_rows = jnp.where(
            is_local[:, None], q_blk[i_loc].astype(jnp.float32), 0.0
        )

        r_u = effective_ranks(p_rows, t_p)
        r_i = effective_ranks(q_rows, t_q)  # 0 on non-owners (zero rows)
        mask_p = rank_mask(r_u, k)
        mask_q = rank_mask(r_i, k)
        pair_mask = mask_p * mask_q

        # Everything is gated by ownership: at t_q == 0 a zero (non-owner)
        # row has effective rank k, so relying on rank-masking alone would
        # multiply the lambda term by n_model through the psum.
        own = is_local[:, None].astype(jnp.float32)
        pred = jax.lax.psum(
            jnp.sum(p_rows * q_rows * pair_mask, axis=-1) * is_local, "model"
        )
        err = r.astype(jnp.float32) - pred
        wv = w.astype(jnp.float32)[:, None]

        # p gradient: assembled on the item owner (it holds q), then one psum.
        # Both gradients carry the full pair mask (Alg. 3 truncates the
        # entire update at min(r_u, r_i)) and the row weight — matching
        # train_step's ``mask = pred_mask * w`` gate exactly.
        g_p_partial = own * pair_mask * wv * (
            lam * p_rows - err[:, None] * q_rows
        )
        if gc == "int8_ef":
            # Sender-side error feedback on the psum: fold this rank's
            # residual for these user rows into the payload, quantize to a
            # mesh-common scale (exact int8 summation), and bank what the
            # quantizer dropped back into the residual table.  The residual
            # update is a scatter-ADD of (partial - transmitted), so
            # duplicate batch rows stay deterministic.
            resid = ef_p[u_loc]
            target = g_p_partial + resid
            local_max = jnp.max(jnp.abs(target))
            scale = jnp.maximum(
                jax.lax.pmax(local_max, "model"), 1e-12
            ) / 127.0
            q8 = jnp.clip(jnp.round(target / scale), -127, 127).astype(jnp.int8)
            recon = q8.astype(jnp.float32) * scale
            g_p = jax.lax.psum(q8.astype(jnp.int32), "model").astype(
                jnp.float32
            ) * scale
            ef_p = ef_p.at[u_loc].add(g_p_partial - recon)
        elif gc == "int8":
            from repro.distributed.compression import compressed_psum

            g_p = compressed_psum(g_p_partial, "model")
        else:
            g_p = jax.lax.psum(g_p_partial, "model")
        g_q = own * pair_mask * wv * (lam * q_rows - err[:, None] * p_rows)
        safe_i = jnp.where(is_local, i_loc, 0)

        if adagrad:
            # The second ``* wv`` mirrors RowOptimizer.apply_rows, whose
            # delta multiplies the mask again after the accumulator update —
            # a no-op for 0/1 weights, required for fractional ones.  (The
            # pair-mask part of that second mask is already folded into g.)
            acc_p_rows = acc_p[u_loc] + g_p * g_p
            dp_rows = -lr * g_p / jnp.sqrt(acc_p_rows + eps) * wv
            acc_p = acc_p.at[u_loc].add(g_p * g_p)
            acc_q_rows = acc_q[safe_i] + g_q * g_q
            dq_rows = jnp.where(
                is_local[:, None],
                -lr * g_q / jnp.sqrt(acc_q_rows + eps) * wv,
                0.0,
            )
        else:  # plain SGD
            dp_rows = -lr * g_p
            dq_rows = -lr * g_q

        p_blk = p_blk.at[u_loc].add(dp_rows.astype(p_blk.dtype))

        # Q is replicated along the data axes, but each data shard computed
        # deltas only for ITS ratings: all-gather the sparse (B_loc, k) delta
        # rows (+ indices, + adagrad g^2) so every replica applies the same
        # total update.  This moves B*k delta floats instead of the dense
        # (n, k) gradient all-reduce XLA emits for train_step.
        if dp:
            if gc in ("int8", "int8_ef"):
                from repro.distributed.compression import (
                    dequantize_int8,
                    quantize_int8,
                )

                if gc == "int8_ef":
                    # residual rows only exist for items this model rank
                    # owns; non-owner rows transmit exact zeros as before
                    payload = jnp.where(
                        is_local[:, None], dq_rows + ef_q[safe_i], 0.0
                    )
                else:
                    payload = dq_rows
                q8, scale = quantize_int8(payload)
                gat_q8 = jax.lax.all_gather(q8, dp)
                gat_scale = jax.lax.all_gather(scale, dp)
                gat_dq = dequantize_int8(
                    gat_q8, gat_scale.reshape((-1,) + (1,) * q8.ndim)
                ).reshape(-1, k)
                if gc == "int8_ef":
                    recon = dequantize_int8(q8, scale)
                    ef_q = ef_q.at[safe_i].add(
                        jnp.where(is_local[:, None], dq_rows - recon, 0.0)
                    )
            else:
                gat_dq = jax.lax.all_gather(dq_rows, dp).reshape(-1, k)
            gat_idx = jax.lax.all_gather(safe_i, dp).reshape(-1)
            q_blk = q_blk.at[gat_idx].add(gat_dq.astype(q_blk.dtype))
            if adagrad:
                gat_g2 = jax.lax.all_gather(g_q * g_q, dp).reshape(-1, k)
                acc_q = acc_q.at[gat_idx].add(gat_g2)
        else:
            q_blk = q_blk.at[safe_i].add(dq_rows.astype(q_blk.dtype))
            if adagrad:
                acc_q = acc_q.at[safe_i].add(g_q * g_q)

        # Weighted epoch metrics, summed on device (err and w are identical
        # on every model rank, so only the data axes need a psum).
        r_i_owner = jax.lax.psum(r_i * is_local, "model")
        wf = w.astype(jnp.float32)
        w_sum = jnp.sum(wf)
        abs_sum = jnp.sum(jnp.abs(err) * wf)
        work_sum = jnp.sum(
            jnp.minimum(r_u, r_i_owner).astype(jnp.float32) * wf
        )
        if dp:
            w_sum = jax.lax.psum(w_sum, dp)
            abs_sum = jax.lax.psum(abs_sum, dp)
            work_sum = jax.lax.psum(work_sum, dp)
        denom = jnp.maximum(w_sum, 1e-9)
        abs_err = abs_sum / denom
        work = work_sum / (denom * k)
        return p_blk, q_blk, acc_p, acc_q, ef_p, ef_q, abs_err[None], work[None]

    acc_p_in = opt_state.p.get("acc") if adagrad else params.p
    acc_q_in = opt_state.q.get("acc") if adagrad else params.q
    if gc == "int8_ef":
        ef_p_in = opt_state.p.get("ef_psum")
        ef_q_in = opt_state.q.get("ef_gather")
        if ef_p_in is None or ef_q_in is None:
            raise ValueError(
                "grad_compression='int8_ef' needs the residual tables: call "
                "mf.init_error_feedback_state(params, opt_state, mesh) first"
            )
    else:
        # placeholder operands so every mode shares one shard_map signature;
        # (n_dp, n_model)-shaped zeros shard to (1, 1) blocks — negligible
        ef_p_in = jnp.zeros((n_dp, n_model), jnp.float32)
        ef_q_in = jnp.zeros((n_model, n_dp), jnp.float32)

    weight = batch.get("weight")
    if weight is None:
        weight = jnp.ones_like(batch["rating"], dtype=jnp.float32)
    new_p, new_q, acc_p, acc_q, ef_p_out, ef_q_out, abs_err, work = (
        mesh_compat.shard_map(
            body,
            mesh=mesh,
            in_specs=(
                P(dp, None), P("model", None), P(dp, None), P("model", None),
                P(dp, "model"), P("model", dp),
                P(dp), P(dp), P(dp), P(dp), P(), P(),
            ),
            out_specs=(
                P(dp, None), P("model", None), P(dp, None), P("model", None),
                P(dp, "model"), P("model", dp),
                P(None), P(None),
            ),
            check_vma=False,
        )(
            params.p, params.q, acc_p_in, acc_q_in, ef_p_in, ef_q_in,
            batch["user"], batch["item"], batch["rating"].astype(jnp.float32),
            weight.astype(jnp.float32),
            jnp.asarray(t_p, jnp.float32), jnp.asarray(t_q, jnp.float32),
        )
    )
    new_params = params._replace(p=new_p, q=new_q)
    if adagrad or gc == "int8_ef":
        p_state = dict(opt_state.p)
        q_state = dict(opt_state.q)
        if adagrad:
            p_state["acc"] = acc_p
            q_state["acc"] = acc_q
        if gc == "int8_ef":
            p_state["ef_psum"] = ef_p_out
            q_state["ef_gather"] = ef_q_out
        new_state = opt_state._replace(p=p_state, q=q_state)
    else:
        new_state = opt_state
    metrics = {"abs_err": abs_err[0], "work_fraction": work[0]}
    return new_params, new_state, metrics


@functools.partial(
    jax.jit,
    static_argnames=(
        "lr", "lam", "opt_name", "eps", "compress_grads", "grad_compression",
        "mesh",
    ),
    donate_argnums=(0, 1),
)
def _train_epoch_scan_shard_map(
    params, opt_state, batches, t_p, t_q,
    *, lr, lam, opt_name, eps, compress_grads, grad_compression, mesh,
):
    def step(p, s, batch):
        return train_step_shard_map(
            p, s, batch, t_p, t_q, lr=lr, lam=lam, opt_name=opt_name,
            eps=eps, compress_grads=compress_grads,
            grad_compression=grad_compression, mesh=mesh,
        )

    return _epoch_scan(step, params, opt_state, batches)


def train_epoch_scan_shard_map(
    params: MFParams,
    opt_state: MFOptState,
    batches: Batch,
    t_p: jax.Array | float,
    t_q: jax.Array | float,
    *,
    lr: float,
    lam: float,
    opt_name: str = "adagrad",
    eps: float = 1e-8,
    compress_grads: bool = False,
    grad_compression: str = "none",
    mesh=None,
) -> Tuple[MFParams, MFOptState, Dict[str, jax.Array]]:
    """Epoch-compiled multi-device training: the owner-compute
    :func:`train_step_shard_map` folded through the same donated
    ``lax.scan`` as :func:`train_epoch_scan`, so single-device and sharded
    training (and the online updater's distributed refresh) share one epoch
    implementation.  ``batches`` follows the same ownership contract as the
    single step: every rating's user must live on its data shard's P block.
    """
    from repro.distributed import mesh_compat

    _check_owner_compute_opt(opt_name)
    mesh = mesh_compat.resolve_mesh(mesh)
    if mesh is None:
        raise ValueError(
            "train_epoch_scan_shard_map needs a mesh: pass mesh= or enter a "
            "mesh_compat.use_mesh(...) context"
        )
    return _train_epoch_scan_shard_map(
        params, opt_state, batches,
        jnp.asarray(t_p, jnp.float32), jnp.asarray(t_q, jnp.float32),
        lr=float(lr), lam=float(lam), opt_name=opt_name, eps=float(eps),
        compress_grads=bool(compress_grads),
        grad_compression=str(grad_compression), mesh=mesh,
    )
