"""Feature-matrix rearrangement based on joint sparsity (paper §4.3, Alg. 1).

Both factor matrices share the latent axis, so permuting that axis of P and Q
with the *same* permutation leaves every inner product unchanged.  Algorithm 1
sorts latent dims by ascending joint sparsity

    JS_k = prob(|P[:,k]| < T_p) * prob(|Q[k,:]| < T_q)       (Eq. 10)

so denser (more significant) dims land at small indices, which is what makes
the later early-stopping prune mostly-insignificant work (paper Fig. 9).

The paper's Alg. 1 is an O(k^2) swap sort; ``jnp.argsort`` is the same
permutation (stable, ascending) at O(k log k).

Conventions: throughout this codebase the item matrix is stored row-major as
``Q[item, latent]`` (the paper writes ``Q_{k x n}``); the latent axis is axis 1
of both matrices.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


class RearrangeResult(NamedTuple):
    perm: jax.Array            # (k,) int32, new_pos -> old latent index
    joint_sparsity: jax.Array  # (k,) sorted ascending after applying perm


def joint_sparsity(
    p_matrix: jax.Array, q_matrix: jax.Array, t_p: jax.Array, t_q: jax.Array
) -> jax.Array:
    """Eq. 10 under the independence assumption stated in the paper."""
    sp_p = jnp.mean((jnp.abs(p_matrix) < t_p).astype(jnp.float32), axis=0)
    sp_q = jnp.mean((jnp.abs(q_matrix) < t_q).astype(jnp.float32), axis=0)
    return sp_p * sp_q


def rearrangement(
    p_matrix: jax.Array, q_matrix: jax.Array, t_p: jax.Array, t_q: jax.Array
) -> RearrangeResult:
    """Compute the ascending-JS permutation of the latent axis (Alg. 1)."""
    js = joint_sparsity(p_matrix, q_matrix, t_p, t_q)
    perm = jnp.argsort(js, stable=True).astype(jnp.int32)
    return RearrangeResult(perm=perm, joint_sparsity=js[perm])


def apply_perm(
    p_matrix: jax.Array, q_matrix: jax.Array, perm: jax.Array
) -> Tuple[jax.Array, jax.Array]:
    """Permute the shared latent axis of both matrices."""
    return p_matrix[:, perm], q_matrix[:, perm]


def apply_perm_tree(tree, perm: jax.Array, axis: int = 1):
    """Permute the latent axis of every array in a pytree (used to keep
    optimizer accumulators aligned with the rearranged factors)."""
    def _permute(x):
        return jnp.take(x, perm, axis=axis)

    return jax.tree_util.tree_map(_permute, tree)
