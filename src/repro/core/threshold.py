"""Threshold determination for dynamic pruning (paper §4.2, Eqs. 7/8, Appendix).

Given a target pruning rate ``p`` and the empirical (mu, sigma) of a feature
matrix measured after the first training epoch, find ``T > 0`` such that a
fraction ``p`` of latent factors fall in ``(-T, T)`` under the fitted normal:

    phi(x) - phi(-x - 2*mu/sigma) = p        (Eq. 8)
    T = sigma * x + mu                       (Eq. 7)

The paper looks ``x`` up in a standard-normal table; we solve the same
monotonic equation by bisection under ``jit``.  The solve runs once per
training job (after epoch 1), so a fixed 64-step bisection is both exact to
float precision and free in the schedule.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
from jax.scipy.stats import norm


class MatrixStats(NamedTuple):
    """Empirical normal fit of one feature matrix."""

    mu: jax.Array
    sigma: jax.Array


def measure_stats(matrix: jax.Array) -> MatrixStats:
    """Fit N(mu, sigma^2) to all latent factors of ``matrix`` (paper Fig. 7)."""
    m = matrix.astype(jnp.float32)
    mu = jnp.mean(m)
    sigma = jnp.std(m)
    return MatrixStats(mu=mu, sigma=sigma)


def _pruned_fraction(x: jax.Array, mu: jax.Array, sigma: jax.Array) -> jax.Array:
    """LHS of Eq. 8: mass of N(0,1) in (-x - 2*mu/sigma, x)."""
    return norm.cdf(x) - norm.cdf(-x - 2.0 * mu / sigma)


@functools.partial(jax.jit, static_argnames=("num_iters",))
def solve_x(
    mu: jax.Array, sigma: jax.Array, rate: jax.Array, num_iters: int = 64
) -> jax.Array:
    """Solve Eq. 8 for ``x`` by bisection.

    ``_pruned_fraction`` is monotonically increasing in ``x`` (both CDF terms
    move mass into the interval), zero at ``x = -mu/sigma`` (empty interval)
    and -> 1 as x -> inf, so bisection on ``[-mu/sigma, hi]`` always brackets.
    """
    mu = jnp.asarray(mu, jnp.float32)
    sigma = jnp.asarray(sigma, jnp.float32)
    rate = jnp.clip(jnp.asarray(rate, jnp.float32), 0.0, 1.0 - 1e-6)

    lo = -mu / sigma  # T = 0: nothing pruned
    hi = jnp.maximum(-mu / sigma, 0.0) + 16.0  # phi saturates far before 16 sigma

    def body(_, bounds):
        lo, hi = bounds
        mid = 0.5 * (lo + hi)
        frac = _pruned_fraction(mid, mu, sigma)
        too_low = frac < rate
        return (jnp.where(too_low, mid, lo), jnp.where(too_low, hi, mid))

    lo, hi = jax.lax.fori_loop(0, num_iters, body, (lo, hi))
    return 0.5 * (lo + hi)


def threshold_for_rate(stats: MatrixStats, rate: float | jax.Array) -> jax.Array:
    """Eq. 7: ``T = sigma * x + mu`` with ``x`` from :func:`solve_x`.

    ``rate == 0`` maps to ``T == 0`` (no factor satisfies ``|v| < 0``), i.e.
    pruning disabled, matching the paper's baseline ("pruning rate as 0, so
    that no latent factors are eliminated").
    """
    x = solve_x(stats.mu, stats.sigma, rate)
    t = stats.sigma * x + stats.mu
    # rate <= 0 must yield T == 0.0 *exactly*, not the bisection's float
    # residue: serving treats T == 0 as "pruning disabled" and the SLO
    # controller's relax-to-floor path relies on bit-exact dense parity.
    t = jnp.where(jnp.asarray(rate, jnp.float32) <= 0.0, 0.0, t)
    return jnp.maximum(t, 0.0)


def thresholds_from_matrices(
    p_matrix: jax.Array, q_matrix: jax.Array, rate: float | jax.Array
) -> Tuple[jax.Array, jax.Array]:
    """Per-matrix thresholds (T_p, T_q) measured once after the first epoch."""
    t_p = threshold_for_rate(measure_stats(p_matrix), rate)
    t_q = threshold_for_rate(measure_stats(q_matrix), rate)
    return t_p, t_q


def empirical_pruned_fraction(matrix: jax.Array, threshold: jax.Array) -> jax.Array:
    """Measured fraction of insignificant factors — validates Eq. 8's fit."""
    return jnp.mean((jnp.abs(matrix) < threshold).astype(jnp.float32))
