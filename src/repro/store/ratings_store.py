"""mmap-backed columnar ratings store + prefetched streaming epoch loader.

The in-memory path (``data/loader.PackedRatings``) uploads the whole ratings
table to the device and materializes a full ``jax.random.permutation`` per
epoch — both are O(dataset).  This module bounds host *and* device memory by
the slab size instead:

* :func:`build_store` writes the ratings as fixed-dtype columnar shards
  (``user int32 | item int32 | rating float32`` contiguous blocks per shard)
  plus an ``index.json`` header; :class:`RatingsStore` reads them back
  through lazily-opened ``np.memmap`` views, so touching a slab faults in
  only that slab's pages.
* :class:`FeistelPermutation` is a bijective index permutation on
  ``[0, n)`` — any *slice* of the shuffled epoch order is computable in
  O(slice) without materializing the O(n) permutation array.
* :class:`ShardedRatingsLoader` streams ``(slab_steps, B)`` epoch slabs
  through a bounded prefetch queue: a background thread gathers the next
  slab from the store and ``jax.device_put``s it while the training scan
  consumes the current one, so host→device transfer overlaps compute.
  Peak host memory is ``O(prefetch * slab_steps * B)``, independent of the
  dataset size (asserted by ``benchmarks/bench_scale.py``).

Determinism contract: for a given ``(seed, epoch)`` the *set* of examples
an epoch visits and their batch assignment are fixed; resuming from slab
``s`` replays slabs ``s..`` identically to an uninterrupted epoch (the
permutation is stateless, keyed only by ``(n, seed, epoch)``).
"""
from __future__ import annotations

import dataclasses
import json
import os
import queue
import threading
import zlib
from typing import Dict, Iterator, List, Optional, Tuple

import jax
import numpy as np

from repro.data.ratings import RatingsDataset

_INDEX_NAME = "index.json"
_STORE_VERSION = 1
_ROW_BYTES = 12  # int32 user + int32 item + float32 rating


class CorruptShardError(RuntimeError):
    """A shard file's bytes fail the CRC-32 recorded in ``index.json``.

    Raised instead of silently feeding flipped bits into training (a
    corrupt float32 block reads as perfectly valid — often NaN/huge —
    ratings).  The offending shard is quarantined (renamed with a
    ``.corrupt`` suffix, best-effort) so a supervised retrain can detect
    and rebuild it."""


# ---------------------------------------------------------------------------
# Feistel permutation
# ---------------------------------------------------------------------------

_GOLDEN = np.uint64(0x9E3779B97F4A7C15)
_MIX1 = np.uint64(0xBF58476D1CE4E5B9)
_MIX2 = np.uint64(0x94D049BB133111EB)


class FeistelPermutation:
    """Bijective shuffle of ``[0, n)`` computable point-wise.

    A balanced Feistel network over the smallest even-bit-width domain
    ``2^(2h) >= n`` with a splitmix64-style round function; indices that
    land outside ``[0, n)`` are cycle-walked (the permutation re-applied)
    back into range.  A Feistel network is a bijection for *any* round
    function, and cycle-walking restricts a bijection of the superset to a
    bijection of the subset — so this is a permutation of ``[0, n)``
    regardless of key material (property-tested in ``tests/test_store.py``).

    Round keys derive from ``np.random.SeedSequence([seed, epoch, ...])``,
    matching the spirit (not the bits) of the in-memory loader's
    ``fold_in(PRNGKey(seed), epoch)``: distinct epochs get independent
    orders, and the order is reproducible from ``(n, seed, epoch)`` alone.
    """

    def __init__(self, n: int, seed: int, epoch: int, *, rounds: int = 4):
        if n <= 0:
            raise ValueError(f"permutation domain must be positive, got {n}")
        self.n = int(n)
        bits = max(int(self.n - 1).bit_length(), 2)
        self._half_bits = np.uint64((bits + 1) // 2)
        self._mask = np.uint64((1 << int(self._half_bits)) - 1)
        ss = np.random.SeedSequence([int(seed), int(epoch), 0x5EED])
        self._keys = [np.uint64(k) for k in ss.generate_state(rounds, np.uint64)]

    def _walk(self, x: np.ndarray) -> np.ndarray:
        h, mask = self._half_bits, self._mask
        left = (x >> h) & mask
        right = x & mask
        with np.errstate(over="ignore"):
            for key in self._keys:
                f = right + key
                f = f * _GOLDEN
                f ^= f >> np.uint64(29)
                f = f * _MIX1
                f ^= f >> np.uint64(32)
                left, right = right, left ^ (f & mask)
        return (left << h) | right

    def __call__(self, idx: np.ndarray) -> np.ndarray:
        """Map indices in ``[0, n)`` through the permutation (vectorized)."""
        out = np.ascontiguousarray(idx, dtype=np.uint64)
        result = np.empty_like(out)
        pos = np.arange(out.size)
        pending = out.reshape(-1)
        while pending.size:
            y = self._walk(pending)
            done = y < np.uint64(self.n)
            result.reshape(-1)[pos[done]] = y[done]
            pending, pos = y[~done], pos[~done]
        return result.astype(np.int64).reshape(np.shape(idx))


def permuted_indices(
    n: int, seed: int, epoch: int, start: int, count: int
) -> np.ndarray:
    """``epoch_permutation(n, seed, epoch)[start:start+count]`` without
    materializing the O(n) permutation — O(count) work and memory."""
    perm = FeistelPermutation(n, seed, epoch)
    return perm(np.arange(start, start + count, dtype=np.int64))


# ---------------------------------------------------------------------------
# Columnar store
# ---------------------------------------------------------------------------

def build_store(
    ds: RatingsDataset, directory: str, *, shard_rows: int = 1 << 20
) -> str:
    """One-shot converter: in-memory arrays → columnar shard files.

    Each shard file is three contiguous columnar blocks
    (``user[int32] | item[int32] | rating[float32]``) of at most
    ``shard_rows`` rows; ``index.json`` carries the dataset-level metadata
    (counts, rating range, global mean) so training never needs the source
    arrays again.  Returns ``directory``.
    """
    if shard_rows <= 0:
        raise ValueError(f"shard_rows must be positive, got {shard_rows}")
    os.makedirs(directory, exist_ok=True)
    n = len(ds)
    shards: List[Dict[str, object]] = []
    for start in range(0, max(n, 1), shard_rows):
        rows = min(shard_rows, n - start)
        if rows <= 0:
            break
        name = f"shard_{len(shards):05d}.bin"
        crc = 0
        with open(os.path.join(directory, name), "wb") as f:
            for block in (
                np.ascontiguousarray(
                    ds.user[start:start + rows], np.int32).tobytes(),
                np.ascontiguousarray(
                    ds.item[start:start + rows], np.int32).tobytes(),
                np.ascontiguousarray(
                    ds.rating[start:start + rows], np.float32).tobytes(),
            ):
                f.write(block)
                crc = zlib.crc32(block, crc)
        shards.append({"file": name, "rows": int(rows), "crc32": crc})
    index = {
        "version": _STORE_VERSION,
        "num_examples": int(n),
        "num_users": int(ds.num_users),
        "num_items": int(ds.num_items),
        "rating_min": float(ds.rating_min),
        "rating_max": float(ds.rating_max),
        "global_mean": float(ds.global_mean),
        "shard_rows": int(shard_rows),
        "shards": shards,
    }
    tmp = os.path.join(directory, _INDEX_NAME + ".tmp")
    with open(tmp, "w") as f:
        json.dump(index, f, indent=2)
    os.replace(tmp, os.path.join(directory, _INDEX_NAME))
    return directory


class RatingsStore:
    """Read side of the columnar store: dataset-shaped metadata plus an
    mmap-backed :meth:`gather` that touches only the pages it needs.

    Integrity: shards written since the checksum landed carry a ``crc32``
    in ``index.json``; each shard is verified once, on first open (one
    sequential page-cache-warming read — the pages are about to be
    gathered anyway).  A mismatch quarantines the shard and raises
    :class:`CorruptShardError` instead of streaming flipped bits into the
    factors.  ``verify_checksums=False`` opts out (benchmarking only).
    """

    def __init__(self, directory: str, *, verify_checksums: bool = True):
        self.directory = directory
        self.verify_checksums = bool(verify_checksums)
        self._verified: set = set()
        with open(os.path.join(directory, _INDEX_NAME)) as f:
            index = json.load(f)
        if index.get("version") != _STORE_VERSION:
            raise ValueError(
                f"unsupported store version {index.get('version')!r} "
                f"(expected {_STORE_VERSION})"
            )
        self.num_examples = int(index["num_examples"])
        self.num_users = int(index["num_users"])
        self.num_items = int(index["num_items"])
        self.rating_min = float(index["rating_min"])
        self.rating_max = float(index["rating_max"])
        self.global_mean = float(index["global_mean"])
        self.shard_rows = int(index["shard_rows"])
        self._shards = [
            (s["file"], int(s["rows"]), s.get("crc32"))
            for s in index["shards"]
        ]
        rows = np.array([r for _, r, _ in self._shards], np.int64)
        self._offsets = np.concatenate([[0], np.cumsum(rows)])
        if self._offsets[-1] != self.num_examples:
            raise ValueError(
                f"index.json inconsistent: shards sum to {self._offsets[-1]} "
                f"rows but num_examples={self.num_examples}"
            )
        self._maps: Dict[int, Tuple[np.memmap, np.memmap, np.memmap]] = {}
        self._maps_lock = threading.Lock()

    def __len__(self) -> int:
        return self.num_examples

    def _verify_shard(self, shard: int, path: str, expected: int) -> None:
        crc = 0
        with open(path, "rb") as f:
            while True:
                block = f.read(1 << 20)
                if not block:
                    break
                crc = zlib.crc32(block, crc)
        if crc != int(expected):
            quarantine = path + ".corrupt"
            try:
                os.rename(path, quarantine)
            except OSError:
                quarantine = path  # couldn't move it; still refuse to serve
            raise CorruptShardError(
                f"shard {shard} ({os.path.basename(path)}) fails its "
                f"index.json crc32 — quarantined at {quarantine}"
            )

    def _columns(self, shard: int) -> Tuple[np.memmap, np.memmap, np.memmap]:
        with self._maps_lock:
            cols = self._maps.get(shard)
            if cols is None:
                name, rows, crc = self._shards[shard]
                path = os.path.join(self.directory, name)
                if (
                    self.verify_checksums
                    and crc is not None
                    and shard not in self._verified
                ):
                    self._verify_shard(shard, path, crc)
                    self._verified.add(shard)
                cols = (
                    np.memmap(path, np.int32, "r", offset=0, shape=(rows,)),
                    np.memmap(path, np.int32, "r", offset=4 * rows,
                              shape=(rows,)),
                    np.memmap(path, np.float32, "r", offset=8 * rows,
                              shape=(rows,)),
                )
                self._maps[shard] = cols
            return cols

    def gather(
        self, idx: np.ndarray
    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Gather rows by global example index (any order, duplicates OK).

        Grouped per shard so each shard's mmap is fancy-indexed once;
        returns fresh host arrays ``(user, item, rating)``."""
        idx = np.asarray(idx, np.int64)
        if idx.size and (idx.min() < 0 or idx.max() >= self.num_examples):
            raise IndexError(
                f"example index out of range [0, {self.num_examples})"
            )
        user = np.empty(idx.shape, np.int32)
        item = np.empty(idx.shape, np.int32)
        rating = np.empty(idx.shape, np.float32)
        shard_of = np.searchsorted(self._offsets, idx, side="right") - 1
        for s in np.unique(shard_of):
            mask = shard_of == s
            local = idx[mask] - self._offsets[s]
            u_col, i_col, r_col = self._columns(int(s))
            user[mask] = u_col[local]
            item[mask] = i_col[local]
            rating[mask] = r_col[local]
        return user, item, rating

    def iter_shards(
        self,
    ) -> Iterator[Tuple[np.ndarray, np.ndarray, np.ndarray]]:
        """Yield each shard's ``(user, item, rating)`` columns in order —
        the sequential-scan primitive for converters and evaluators."""
        for s in range(len(self._shards)):
            yield self._columns(s)

    def to_dataset(self) -> RatingsDataset:
        """Materialize the whole store in memory (small stores / tests)."""
        if self._shards:
            cols = list(zip(*self.iter_shards()))
            user = np.concatenate([np.asarray(c) for c in cols[0]])
            item = np.concatenate([np.asarray(c) for c in cols[1]])
            rating = np.concatenate([np.asarray(c) for c in cols[2]])
        else:
            user = np.empty(0, np.int32)
            item = np.empty(0, np.int32)
            rating = np.empty(0, np.float32)
        return RatingsDataset(
            user=user,
            item=item,
            rating=rating,
            num_users=self.num_users,
            num_items=self.num_items,
            rating_min=self.rating_min,
            rating_max=self.rating_max,
        )


# ---------------------------------------------------------------------------
# Streaming epoch loader
# ---------------------------------------------------------------------------

class _WorkerError:
    def __init__(self, exc: BaseException):
        self.exc = exc


_SENTINEL = object()


@dataclasses.dataclass(frozen=True)
class SlabBatches:
    """One prefetched slab: device-resident ``(steps, B)`` batch arrays."""

    slab_idx: int
    steps: int
    batches: Dict[str, jax.Array]


class ShardedRatingsLoader:
    """Streams shuffled ``(slab_steps, B)`` epoch slabs from a
    :class:`RatingsStore` through a bounded prefetch queue.

    Drop-in replacement for ``PackedRatings.epoch_batches`` for slab-chunked
    scans: ``epoch_slabs(seed, epoch)`` yields :class:`SlabBatches` whose
    concatenation over an epoch is one deterministic shuffled pass keyed by
    ``(seed, epoch)``.  The prefetch worker computes slab ``s+1``'s host
    gather and ``jax.device_put`` while the caller's scan runs slab ``s`` —
    the queue depth (``prefetch``) bounds host memory, not the dataset.
    """

    def __init__(
        self,
        store: RatingsStore,
        batch_size: int,
        *,
        slab_steps: int = 256,
        prefetch: int = 2,
    ):
        if batch_size <= 0:
            raise ValueError(f"batch_size must be positive, got {batch_size}")
        if slab_steps <= 0:
            raise ValueError(f"slab_steps must be positive, got {slab_steps}")
        if prefetch <= 0:
            raise ValueError(f"prefetch must be positive, got {prefetch}")
        self.store = store
        self.batch_size = int(min(batch_size, max(len(store), 1)))
        self.num_steps = len(store) // self.batch_size
        if self.num_steps == 0:
            raise ValueError(
                f"dataset has {len(store)} examples < batch_size "
                f"{self.batch_size}; nothing to stream"
            )
        self.slab_steps = int(min(slab_steps, self.num_steps))
        self.num_slabs = -(-self.num_steps // self.slab_steps)
        self.prefetch = int(prefetch)

    @property
    def num_examples(self) -> int:
        return len(self.store)

    def slab_bounds(self, slab_idx: int) -> Tuple[int, int]:
        """Half-open ``[start_step, end_step)`` of one slab (last is ragged)."""
        if not 0 <= slab_idx < self.num_slabs:
            raise IndexError(f"slab {slab_idx} out of [0, {self.num_slabs})")
        start = slab_idx * self.slab_steps
        return start, min(start + self.slab_steps, self.num_steps)

    def _host_slab(
        self, perm: Optional[FeistelPermutation], slab_idx: int
    ) -> Dict[str, np.ndarray]:
        start, end = self.slab_bounds(slab_idx)
        steps = end - start
        b = self.batch_size
        idx = np.arange(start * b, end * b, dtype=np.int64)
        if perm is not None:
            idx = perm(idx)
        user, item, rating = self.store.gather(idx)
        return {
            "user": user.reshape(steps, b),
            "item": item.reshape(steps, b),
            "rating": rating.reshape(steps, b),
        }

    def epoch_slabs(
        self,
        seed: int,
        epoch: int,
        *,
        start_slab: int = 0,
        shuffle: bool = True,
    ) -> Iterator[SlabBatches]:
        """Yield the epoch's slabs from ``start_slab`` on, prefetched.

        The same ``(seed, epoch)`` always yields the same example→batch
        assignment, so a resume from ``start_slab`` sees exactly the slabs
        an uninterrupted epoch would have run from that point.
        """
        if not 0 <= start_slab <= self.num_slabs:
            raise ValueError(
                f"start_slab {start_slab} out of [0, {self.num_slabs}]"
            )
        perm = (
            FeistelPermutation(self.num_examples, seed, epoch)
            if shuffle else None
        )
        q: "queue.Queue" = queue.Queue(maxsize=self.prefetch)
        stop = threading.Event()

        def worker() -> None:
            try:
                for slab_idx in range(start_slab, self.num_slabs):
                    if stop.is_set():
                        return
                    host = self._host_slab(perm, slab_idx)
                    # async host->device copy; overlaps the consumer's scan
                    dev = {k: jax.device_put(v) for k, v in host.items()}
                    start, end = self.slab_bounds(slab_idx)
                    item = SlabBatches(
                        slab_idx=slab_idx, steps=end - start, batches=dev
                    )
                    while not stop.is_set():
                        try:
                            q.put(item, timeout=0.1)
                            break
                        except queue.Full:
                            continue
                payload = _SENTINEL
            except BaseException as exc:  # surfaced to the consumer
                payload = _WorkerError(exc)
            while not stop.is_set():
                try:
                    q.put(payload, timeout=0.1)
                    return
                except queue.Full:
                    continue

        thread = threading.Thread(
            target=worker, name="ratings-prefetch", daemon=True
        )
        thread.start()
        try:
            while True:
                got = q.get()
                if got is _SENTINEL:
                    return
                if isinstance(got, _WorkerError):
                    raise got.exc
                yield got
        finally:
            stop.set()
            while thread.is_alive():
                try:
                    q.get_nowait()
                except queue.Empty:
                    pass
                thread.join(timeout=0.1)
