"""Cold-row eviction/compaction: the bounded-memory contract for the
grow-only online factor tables.

The online path (``online/updater.py``) grows P (and its optimizer state,
biases) for every cold-start user and never shrinks — after a year of
stream the user table is O(every id ever seen).  This module adds a
watermark: when the table passes ``max_users`` rows, the coldest rows are
*spilled* to disk and *compacted* out of the device tables.

Coldness order (most evictable first):

1. **last-touched step** ascending — rows no event has updated recently;
2. **per-row effective rank** ascending — the §4.3 joint-sparsity
   rearrangement already stores the latent axis most-significant-first, so
   a row's first-insignificant index (``core/ranks.effective_ranks``) is
   its usefulness under the paper's own pruning order: rows the pruned
   dot-product would truncate earliest are the cheapest to lose;
3. physical index ascending — a total order, so eviction is deterministic.

Compaction renumbers the physical rows, so every layer that holds user ids
needs the **id-remap table** (:class:`IdRemap`): external (stream/request)
ids stay stable forever; ``ext_to_phys`` maps them to the current physical
row, ``-1`` meaning spilled.  Each compaction bumps ``remap_epoch`` —
consumers that cached physical geometry (serving snapshots, delta
followers) treat a bump as a barrier: the publisher forces a ``kind=full``
checkpoint/message and the engine rebuilds rather than patching.

Spilled rows are not gone: an event naming a spilled user *revives* it —
the factor row, bias and optimizer-state rows come back from the spill
file into freshly grown physical rows (bitwise what was evicted), so
evict→touch→evict round-trips preserve predictions for every live user
(property-tested in ``tests/test_eviction.py``).  A spilled user that is
merely *scored* (not rated) is served by the engine's bias-only fallback
instead — scoring never mutates the tables.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Dict, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import ranks as ranks_lib


@dataclasses.dataclass
class IdRemap:
    """External-id → physical-row translation table.

    ``ext_to_phys[e]`` is the physical row of external user ``e``, or -1 if
    the row is currently spilled.  ``epoch`` counts compactions: any bump
    invalidates every cached physical index downstream.
    """

    ext_to_phys: np.ndarray  # (n_external,) int32, -1 = spilled
    epoch: int = 0

    @property
    def num_external(self) -> int:
        """Size of the external id domain (grow-only)."""
        return int(self.ext_to_phys.shape[0])

    def lookup(self, ext_ids: np.ndarray) -> np.ndarray:
        """Translate external ids; unknown (never-seen) ids map to -1."""
        ext_ids = np.asarray(ext_ids, np.int64)
        phys = np.full(ext_ids.shape, -1, np.int64)
        known = (ext_ids >= 0) & (ext_ids < self.num_external)
        phys[known] = self.ext_to_phys[ext_ids[known]]
        return phys

    def as_array(self) -> np.ndarray:
        """Frozen copy for snapshots/messages."""
        return np.array(self.ext_to_phys, np.int32, copy=True)


@dataclasses.dataclass
class EvictionConfig:
    """Watermark policy: evict down to ``target_users`` once the physical
    table exceeds ``max_users``; spilled rows land under ``spill_dir``."""

    max_users: int
    spill_dir: str
    target_users: Optional[int] = None  # default: 80% of max_users

    def resolved_target(self) -> int:
        target = (
            self.target_users if self.target_users is not None
            else int(self.max_users * 0.8)
        )
        if not 0 < target <= self.max_users:
            raise ValueError(
                f"target_users {target} must be in (0, max_users="
                f"{self.max_users}]"
            )
        return target


class UserEvictor:
    """Owns the remap table, per-row touch clock, spill files and the
    compaction pass for one :class:`~repro.online.updater.OnlineUpdater`.

    Usage: ``updater.attach_evictor(UserEvictor(config))`` — from then on
    the updater routes every batch through :meth:`resolve` (ext→phys with
    revival) and the driver calls :meth:`maybe_evict` at publish points.
    """

    def __init__(self, config: EvictionConfig):
        config.resolved_target()  # validate eagerly
        self.config = config
        self.updater = None
        self.remap: Optional[IdRemap] = None
        self.phys_to_ext: Optional[np.ndarray] = None
        self.last_touched: Optional[np.ndarray] = None
        self._step = 0
        self._spilled: Dict[int, Tuple[str, int]] = {}  # ext -> (file, row)
        self._spill_seq = 0
        self._spill_cache: Tuple[Optional[str], Optional[Dict]] = (None, None)
        self.evictions = 0          # rows spilled, lifetime
        self.revivals = 0           # rows brought back, lifetime
        self.compactions = 0        # remap-epoch bumps, lifetime

    def spilled_external_ids(self) -> np.ndarray:
        """External ids currently resident on disk (sorted)."""
        return np.array(sorted(self._spilled), dtype=np.int64)

    # -- wiring --------------------------------------------------------------
    def bind(self, updater) -> None:
        """Attach to an updater; the initial remap is the identity over the
        current physical table."""
        if updater.mesh is not None:
            raise ValueError(
                "eviction is a single-host feature: mesh-sharded tables "
                "must keep their row counts divisible over the mesh"
            )
        if updater.params.implicit is not None:
            raise ValueError(
                "eviction does not support the SVD++ variant (per-user "
                "implicit history rows cannot be spilled independently)"
            )
        os.makedirs(self.config.spill_dir, exist_ok=True)
        self.updater = updater
        m = updater.num_users
        self.remap = IdRemap(ext_to_phys=np.arange(m, dtype=np.int32))
        self.phys_to_ext = np.arange(m, dtype=np.int64)
        self.last_touched = np.zeros(m, np.int64)

    def _sync(self) -> None:
        """Track table growth done outside resolve() (direct
        ensure_capacity callers): appended rows are identity-mapped new
        external ids, touched 'now'."""
        m = self.updater.num_users
        have = self.phys_to_ext.shape[0]
        if m > have:
            add = m - have
            new_ext = np.arange(
                self.remap.num_external,
                self.remap.num_external + add, dtype=np.int64,
            )
            self.remap.ext_to_phys = np.concatenate(
                [self.remap.ext_to_phys,
                 np.arange(have, m, dtype=np.int32)]
            )
            self.phys_to_ext = np.concatenate([self.phys_to_ext, new_ext])
            self.last_touched = np.concatenate(
                [self.last_touched, np.full(add, self._step, np.int64)]
            )

    # -- the hot-path translation --------------------------------------------
    def resolve(self, ext_ids: np.ndarray) -> np.ndarray:
        """External ids → physical rows, for an *update*.

        Unseen ids get fresh physical rows (cold-start growth, same init as
        ``ensure_capacity``); spilled ids are revived from their spill
        records.  Every returned row's touch clock is advanced.
        """
        self._sync()
        ext_ids = np.asarray(ext_ids, np.int64)
        remap = self.remap
        max_ext = int(ext_ids.max()) if ext_ids.size else -1
        if max_ext >= remap.num_external:
            # extend the external domain exactly like grow-only cold start:
            # every id up to the max gets a (fresh) physical row
            add = max_ext + 1 - remap.num_external
            base = self.updater.num_users
            remap.ext_to_phys = np.concatenate(
                [remap.ext_to_phys,
                 np.arange(base, base + add, dtype=np.int32)]
            )
            self.phys_to_ext = np.concatenate(
                [self.phys_to_ext,
                 np.arange(remap.num_external - add,
                           remap.num_external, dtype=np.int64)]
            )
            self.updater.ensure_capacity(base + add - 1, -1)
            self.last_touched = np.concatenate(
                [self.last_touched, np.full(add, self._step, np.int64)]
            )
        phys = remap.ext_to_phys[ext_ids].astype(np.int64)
        spilled = np.unique(ext_ids[phys < 0])
        if spilled.size:
            self._revive(spilled)
            phys = remap.ext_to_phys[ext_ids].astype(np.int64)
        self._step += 1
        self.last_touched[phys] = self._step
        return phys.astype(np.int32)

    # -- spill / revive ------------------------------------------------------
    def _row_states(self) -> Dict[str, Dict[str, jnp.ndarray]]:
        """The user-row-indexed optimizer-state dicts, by group name."""
        opt = self.updater.opt_state
        groups = {"p": opt.p}
        if opt.user_bias is not None:
            groups["user_bias"] = opt.user_bias
        return groups

    def _spill(self, victims: np.ndarray) -> None:
        upd = self.updater
        m = upd.num_users
        payload: Dict[str, np.ndarray] = {
            "ext_ids": self.phys_to_ext[victims],
            "last_touched": self.last_touched[victims],
            "p": np.asarray(upd.params.p[victims]),
        }
        if upd.params.user_bias is not None:
            payload["user_bias"] = np.asarray(upd.params.user_bias[victims])
        for group, state in self._row_states().items():
            for key, value in state.items():
                if getattr(value, "ndim", 0) >= 1 and value.shape[0] == m:
                    payload[f"opt.{group}.{key}"] = np.asarray(value[victims])
        name = f"spill_{self._spill_seq:06d}.npz"
        self._spill_seq += 1
        path = os.path.join(self.config.spill_dir, name)
        tmp = path + ".tmp"
        with open(tmp, "wb") as f:
            np.savez(f, **payload)
        os.replace(tmp, path)
        for row, ext in enumerate(payload["ext_ids"]):
            self._spilled[int(ext)] = (path, row)
        self.evictions += victims.size

    def _load_spill(self, path: str) -> Dict[str, np.ndarray]:
        cached_path, cached = self._spill_cache
        if cached_path != path:
            with np.load(path) as data:
                cached = {key: data[key] for key in data.files}
            self._spill_cache = (path, cached)
        return cached

    def _revive(self, ext_ids: np.ndarray) -> None:
        """Grow fresh physical rows, then overwrite them with the spilled
        values — bitwise the rows that were evicted."""
        upd = self.updater
        n_new = int(ext_ids.size)
        base = upd.num_users
        upd.ensure_capacity(base + n_new - 1, -1)
        phys = np.arange(base, base + n_new, dtype=np.int64)
        self.phys_to_ext = np.concatenate([self.phys_to_ext, ext_ids])
        self.last_touched = np.concatenate(
            [self.last_touched, np.full(n_new, self._step, np.int64)]
        )

        rows: Dict[str, list] = {}
        for ext in ext_ids:
            path, row = self._spilled.pop(int(ext))
            data = self._load_spill(path)
            for key, value in data.items():
                if key == "ext_ids":
                    continue
                rows.setdefault(key, []).append(value[row])
        stacked = {key: np.stack(vals) for key, vals in rows.items()}

        idx = jnp.asarray(phys)
        params = upd.params._replace(
            p=upd.params.p.at[idx].set(jnp.asarray(stacked["p"]))
        )
        if "user_bias" in stacked:
            params = params._replace(
                user_bias=upd.params.user_bias.at[idx].set(
                    jnp.asarray(stacked["user_bias"])
                )
            )
        upd.params = params
        opt = upd.opt_state
        new_groups = {}
        for group, state in self._row_states().items():
            new_state = dict(state)
            for key in state:
                skey = f"opt.{group}.{key}"
                if skey in stacked:
                    new_state[key] = state[key].at[idx].set(
                        jnp.asarray(stacked[skey])
                    )
            new_groups[group] = new_state
        upd.opt_state = opt._replace(
            p=new_groups["p"],
            user_bias=new_groups.get("user_bias", opt.user_bias),
        )
        self.remap.ext_to_phys[ext_ids] = phys.astype(np.int32)
        self.revivals += n_new

    # -- the watermark pass --------------------------------------------------
    def maybe_evict(self) -> Optional[Dict[str, float]]:
        """Spill + compact down to the target if past the watermark.

        Returns a report dict when a compaction ran (the caller should
        publish soon after: the updater is marked ``layout_dirty`` and the
        snapshot carries the bumped ``remap_epoch``), else None.
        """
        self._sync()
        upd = self.updater
        m = upd.num_users
        if m <= self.config.max_users:
            return None
        target = self.config.resolved_target()
        n_evict = m - target
        row_ranks = np.asarray(
            ranks_lib.effective_ranks(upd.params.p, upd.t_p)
        )
        order = np.lexsort(
            (np.arange(m), row_ranks, self.last_touched)
        )
        victims = np.sort(order[:n_evict])
        keep = np.sort(order[n_evict:])
        self._spill(victims)
        self._compact(keep, m)
        return {
            "evicted": int(n_evict),
            "num_users": int(upd.num_users),
            "remap_epoch": int(self.remap.epoch),
            "spilled_total": int(len(self._spilled)),
        }

    def _compact(self, keep: np.ndarray, m: int) -> None:
        upd = self.updater
        old_to_new = np.full(m, -1, np.int64)
        old_to_new[keep] = np.arange(keep.size)
        take = jnp.asarray(keep)

        params = upd.params._replace(p=upd.params.p[take])
        if upd.params.user_bias is not None:
            params = params._replace(user_bias=upd.params.user_bias[take])
        upd.params = params

        def shrink(state):
            return {
                key: (
                    value[take]
                    if getattr(value, "ndim", 0) >= 1 and value.shape[0] == m
                    else value
                )
                for key, value in state.items()
            }

        upd.opt_state = upd.opt_state._replace(
            p=shrink(upd.opt_state.p),
            user_bias=(
                None if upd.opt_state.user_bias is None
                else shrink(upd.opt_state.user_bias)
            ),
        )

        live = self.remap.ext_to_phys >= 0
        translated = np.full_like(self.remap.ext_to_phys, -1)
        translated[live] = old_to_new[
            self.remap.ext_to_phys[live]
        ].astype(np.int32)
        self.remap.ext_to_phys = translated
        self.remap.epoch += 1
        self.phys_to_ext = self.phys_to_ext[keep]
        self.last_touched = self.last_touched[keep]
        self.compactions += 1

        # pending-delta bookkeeping: physical indices shifted, so translate
        # the touched set and force the next publish to be a full rebuild
        # (the remap-epoch bump makes every follower heal via kind=full)
        upd._touched_users = {
            int(old_to_new[u]) for u in upd._touched_users
            if u < m and old_to_new[u] >= 0
        }
        upd._layout_dirty = True
