"""Out-of-core data subsystem: mmap-backed ratings store, streamed
slab training, and cold-row eviction for the online path.

``ratings_store`` bounds host memory on the *training* side (the ratings
table lives on disk, epochs stream through a fixed-depth prefetch queue);
``eviction`` bounds device memory on the *serving/refresh* side (grow-only
factor tables get a watermark and cold rows spill back to disk).
"""
from repro.store.ratings_store import (  # noqa: F401
    CorruptShardError,
    FeistelPermutation,
    RatingsStore,
    ShardedRatingsLoader,
    build_store,
)
from repro.store.eviction import (  # noqa: F401
    EvictionConfig,
    IdRemap,
    UserEvictor,
)
